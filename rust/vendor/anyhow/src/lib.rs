//! Minimal, offline, API-compatible shim of the `anyhow` crate covering the
//! subset this repository uses: `Error`, `Result`, the `anyhow!` / `bail!`
//! macros, and the `Context` extension trait. The real crate is not
//! vendorable in this environment; this shim keeps the public error-handling
//! idiom (`anyhow::Result` at binary/app boundaries, typed errors below)
//! intact so swapping the real crate back in is a one-line Cargo change.

use std::error::Error as StdError;
use std::fmt;

/// Dynamic error type: a boxed error plus an optional chain of context
/// messages (most recent first when displayed via `{:#}` / `Debug`).
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
    context: Vec<String>,
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { inner: Box::new(MessageError(message.to_string())), context: Vec::new() }
    }

    /// Construct from any concrete error type.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { inner: Box::new(error), context: Vec::new() }
    }

    fn push_context(mut self, ctx: String) -> Error {
        self.context.push(ctx);
        self
    }

    /// The root cause, for downcasting or inspection.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        self.inner.as_ref()
    }

    /// Attempt to downcast the root cause to a concrete type.
    pub fn downcast_ref<E: StdError + 'static>(&self) -> Option<&E> {
        self.inner.downcast_ref::<E>()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.context.last() {
            Some(ctx) => write!(f, "{ctx}: {}", self.inner),
            None => write!(f, "{}", self.inner),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for ctx in self.context.iter().rev() {
            write!(f, "{ctx}: ")?;
        }
        write!(f, "{}", self.inner)
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Plain-string error payload used by `anyhow!`.
#[derive(Debug)]
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

/// Attach context to errors, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().push_context(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().push_context(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// `anyhow!("...")` — format a message into an [`Error`].
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// `bail!("...")` — early-return an error from a `Result` function.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing"));
    }

    #[test]
    fn context_chains_messages() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "reading manifest").unwrap_err();
        assert!(e.to_string().contains("reading manifest"));
        assert!(e.to_string().contains("missing"));
    }

    #[test]
    fn macro_formats() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(e.to_string(), "empty");
    }
}
