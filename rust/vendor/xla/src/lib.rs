//! Offline stub of the XLA/PJRT Rust binding.
//!
//! This container has no PJRT plugin, so the real binding cannot link.
//! The stub mirrors the exact API surface `cadnn::runtime` consumes and
//! fails fast (and loudly) at `PjRtClient::cpu()` with a descriptive
//! error, which the runtime surfaces as `CadnnError::BackendUnavailable`.
//! Serving still works end-to-end through `cadnn::api::NativeBackend`;
//! to execute AOT HLO artifacts, replace this vendor crate with the real
//! binding in the workspace `Cargo.toml`.

use std::fmt;

/// Stub error: every fallible entry point returns this.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: XLA/PJRT runtime unavailable in this offline build \
             (rust/vendor/xla is a stub; swap in the real binding to run AOT artifacts)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (stub: cannot be constructed).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// Device buffer returned by an execution (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("unavailable"));
    }
}
