//! Property test over randomly generated CNNs: for any valid graph made
//! of the paper's layer vocabulary, (1) the compiler passes preserve
//! shapes and weight counts, and (2) every framework personality computes
//! the same function (fusion / 1x1->GEMM / tiling are semantics-
//! preserving program transformations — the paper's implicit claim).

use cadnn::api::Engine;
use cadnn::exec::Personality;
use cadnn::ir::ops::{ActKind, Op, PoolKind};
use cadnn::ir::{Graph, Shape};
use cadnn::kernels::Tensor;
use cadnn::util::rng::Rng;

/// Random chain CNN with optional residual links, 4-18 layers.
fn random_graph(rng: &mut Rng) -> Graph {
    let h = [8usize, 10, 12, 16][rng.below(4)];
    let c0 = [1usize, 3, 4, 8][rng.below(4)];
    let mut g = Graph::new("rand", Shape::nhwc(1, h, h, c0));
    let mut x = 0usize;
    let mut cin = c0;
    let layers = rng.range(2, 6);
    for i in 0..layers {
        match rng.below(5) {
            // conv+bn+act block
            0 | 1 => {
                let cout = [4usize, 8, 12, 16][rng.below(4)];
                let ksp: (usize, usize, usize) =
                    [(1, 1, 0), (3, 1, 1), (3, 2, 1), (5, 1, 2)][rng.below(4)];
                let cur_h = g.node(x).shape.h();
                if cur_h + 2 * ksp.2 < ksp.0 {
                    continue;
                }
                let c = g.add(
                    format!("l{i}_conv"),
                    Op::conv(ksp.0, ksp.0, cin, cout, ksp.1, ksp.2),
                    vec![x],
                );
                let b = g.add(format!("l{i}_conv_bn"), Op::BatchNorm { c: cout }, vec![c]);
                let kind = [ActKind::Relu, ActKind::Relu6][rng.below(2)];
                x = g.add(format!("l{i}_conv_act"), Op::Activation { kind }, vec![b]);
                cin = cout;
            }
            // depthwise block
            2 => {
                let stride = 1 + rng.below(2);
                if g.node(x).shape.h() + 2 < 3 {
                    continue;
                }
                let d = g.add(
                    format!("l{i}_dw"),
                    Op::DepthwiseConv2d { kh: 3, kw: 3, c: cin, stride, padding: 1 },
                    vec![x],
                );
                let b = g.add(format!("l{i}_dw_bn"), Op::BatchNorm { c: cin }, vec![d]);
                x = g.add(
                    format!("l{i}_dw_act"),
                    Op::Activation { kind: ActKind::Relu },
                    vec![b],
                );
            }
            // pool
            3 => {
                let cur_h = g.node(x).shape.h();
                if cur_h < 2 {
                    continue;
                }
                let kind = [PoolKind::Max, PoolKind::Avg][rng.below(2)];
                x = g.add(
                    format!("l{i}_pool"),
                    Op::Pool { kind, k: 2, stride: 2, padding: 0 },
                    vec![x],
                );
            }
            // residual 1x1 branch + add (shape-preserving)
            _ => {
                let c = g.add(format!("l{i}_res"), Op::conv(1, 1, cin, cin, 1, 0), vec![x]);
                let b = g.add(format!("l{i}_res_bn"), Op::BatchNorm { c: cin }, vec![c]);
                let a = g.add(format!("l{i}_add"), Op::Add, vec![b, x]);
                x = g.add(
                    format!("l{i}_add_act"),
                    Op::Activation { kind: ActKind::Relu },
                    vec![a],
                );
            }
        }
    }
    let gap = g.add("gap", Op::GlobalAvgPool, vec![x]);
    g.add("fc", Op::fc(cin, 10), vec![gap]);
    g
}

#[test]
fn prop_passes_preserve_semantics_on_random_graphs() {
    let cases = 25;
    for case in 0..cases {
        let mut rng = Rng::new(0xBEEF ^ (case as u64) * 0x9E3779B97F4A7C15);
        let g = random_graph(&mut rng);
        g.validate().unwrap_or_else(|e| panic!("case {case}: {e}"));

        // pass invariants
        let lowered = Personality::CadnnDense.lower(&g);
        lowered.validate().unwrap_or_else(|e| panic!("case {case} lowered: {e}"));
        assert_eq!(
            g.weight_count(),
            lowered.weight_count(),
            "case {case}: weights changed"
        );
        assert_eq!(
            g.nodes.last().unwrap().shape,
            lowered.nodes.last().unwrap().shape,
            "case {case}: output shape changed"
        );

        // numeric agreement, driven through the public Engine/Session API
        let mut input = Tensor::zeros(&g.nodes[0].shape.0);
        rng.fill_normal(&mut input.data, 0.5);
        let run = |p: Personality| -> Vec<f32> {
            let engine = Engine::from_graph(g.clone()).personality(p).build().unwrap();
            let mut session = engine.session();
            session.run(&input.data).unwrap()
        };
        let base = run(Personality::TfLiteLike);
        for p in [Personality::TvmLike, Personality::CadnnDense] {
            let out = run(p);
            assert_eq!(base.len(), out.len(), "case {case} {}", p.label());
            let d = base
                .iter()
                .zip(&out)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(d < 5e-3, "case {case} {}: diff {d}", p.label());
        }
    }
}
