//! End-to-end coverage of quantized sparse payloads through the public
//! API: Auto planning + an exported codebook select a quantized pattern
//! payload on a ResNet-50-shaped layer, the engine executes it through
//! the LUT kernels within the fit's error bound, plans round-trip the
//! manifest with the value axis, and q4 pattern payloads land under 40%
//! of the f32 bytes — the issue's acceptance criteria, verbatim.

use cadnn::api::Engine;
use cadnn::compress::csr::CsrMatrix;
use cadnn::compress::pattern::prune_patterns;
use cadnn::compress::profile::{PruneStructure, SparsityProfile};
use cadnn::compress::qsparse::{QPattern, ValueBits};
use cadnn::compress::size::format_bytes_valued;
use cadnn::compress::PatternMatrix;
use cadnn::exec::Personality;
use cadnn::ir::ops::{ActKind, Op};
use cadnn::ir::{Graph, Shape};
use cadnn::planner::{FormatPolicy, SparseFormat, ValuePolicy};
use cadnn::runtime::Manifest;
use cadnn::util::rng::Rng;

/// A ResNet-50-shaped residual-stage fragment: 3x3 conv (the pattern
/// regime) into a 1x1 projection, both pruned, with a pooled classifier
/// head. Channel counts are scaled down from (256, 256) so the test
/// stays unit-test fast while keeping the 3x3-vs-1x1 planning contrast.
fn resnet_shaped() -> Graph {
    let relu = || Op::Activation { kind: ActKind::Relu };
    let mut g = Graph::new("res_quant", Shape::nhwc(1, 14, 14, 16));
    let c1 = g.add("res_3x3", Op::conv(3, 3, 16, 32, 1, 1), vec![0]);
    let b1 = g.add("res_3x3_bn", Op::BatchNorm { c: 32 }, vec![c1]);
    let r1 = g.add("res_3x3_relu", relu(), vec![b1]);
    let c2 = g.add("res_1x1", Op::conv(1, 1, 32, 16, 1, 0), vec![r1]);
    let b2 = g.add("res_1x1_bn", Op::BatchNorm { c: 16 }, vec![c2]);
    let r2 = g.add("res_1x1_relu", relu(), vec![b2]);
    let p = g.add("gap", Op::GlobalAvgPool, vec![r2]);
    g.add("fc", Op::fc(16, 8), vec![p]);
    g.validate().unwrap();
    g
}

fn engine(profile: &SparsityProfile, vp: ValuePolicy) -> Engine {
    Engine::from_graph(resnet_shaped())
        .personality(Personality::CadnnSparse)
        .sparsity_profile(profile.clone())
        .sparse_format(FormatPolicy::Auto)
        .value_bits(vp)
        .build()
        .unwrap()
}

fn image(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0f32; len];
    rng.fill_normal(&mut v, 0.5);
    v
}

/// The acceptance path: pattern-pruned profile + exported codebook →
/// Auto plans a quantized pattern payload → LUT execution within the
/// quantization error bound of the f32 path.
#[test]
fn auto_with_exported_codebook_selects_and_executes_quantized_pattern() {
    let g = resnet_shaped();
    let profile =
        SparsityProfile::uniform_structured(&g, 0.8, PruneStructure::Pattern { entries: 4 });
    let qprofile = profile.clone().with_uniform_quant(4);

    let f32_engine = engine(&profile, ValuePolicy::Auto);
    let q_engine = engine(&qprofile, ValuePolicy::Auto);

    let fplan = f32_engine.exec_plan().unwrap();
    let qplan = q_engine.exec_plan().unwrap();
    let f3 = fplan.get("res_3x3").unwrap();
    let q3 = qplan.get("res_3x3").unwrap();
    assert_eq!(f3.format, SparseFormat::Pattern, "{f3:?}");
    assert_eq!(f3.value_bits, ValueBits::F32, "no codebook -> f32 payload");
    assert_eq!(q3.format, SparseFormat::Pattern, "{q3:?}");
    assert_eq!(q3.value_bits, ValueBits::Q4, "exported codebook -> quantized payload");
    // the plan prices the LUT gather, so serving costs stay honest
    assert!(q3.cost_per_row > f3.cost_per_row);

    // execution: same pruned weights, value store quantized — outputs
    // within a loose propagated bound, and actually different (the LUT
    // path really ran on 4-bit values)
    let img = image(f32_engine.input_len(), 5);
    let a = f32_engine.session().run(&img).unwrap();
    let b = q_engine.session().run(&img).unwrap();
    let max_diff = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max);
    assert!(max_diff > 0.0, "q4 payload must differ from f32 on rich values");
    // kernel-level bit-identity and the instance-level propagated bound
    // are tested elsewhere; here a loose sanity bound distinguishes
    // quantization-sized drift from a broken gather (which diverges at
    // the scale of the logits themselves)
    assert!(max_diff < 1.0, "q4 drift {max_diff} is not quantization-sized");
}

/// The Q-index round-trip is bit-identical: the packed index stream
/// reconstructs exactly the values the fit assigned (pack/unpack is
/// lossless), and a second quantization pass over the dequantized
/// payload is a fixed point — all loss happens in the first fit, none
/// in the index path or the execution.
#[test]
fn q_index_roundtrip_bit_identical() {
    let (kh, kw, cin, cout) = (3usize, 3usize, 16usize, 64usize);
    let mut rng = Rng::new(11);
    let mut w = vec![0.0f32; kh * kw * cin * cout];
    rng.fill_normal(&mut w, 0.5);
    prune_patterns(&mut w, kh, kw, cin, cout, 0.8, 4, 8);
    let pat = PatternMatrix::from_dense(&w, kh, kw, cin, cout);
    for bits in [4u8, 8] {
        let q = QPattern::from_pattern(&pat, bits);
        // unpacked indices gather to exactly the dequantized values
        let idx = q.values.unpack_indices();
        let deq = q.to_pattern();
        deq.validate().unwrap();
        for (i, &ix) in idx.iter().enumerate() {
            assert_eq!(q.values.codebook[ix as usize].to_bits(), deq.values[i].to_bits());
        }
        // a second pass is a lossless fixed point
        let q2 = QPattern::from_pattern(&deq, bits);
        assert_eq!(q2.values.error_bound(), 0.0);
        assert_eq!(q2.to_pattern().values, deq.values, "second pass must be bit-identical");
    }
}

/// The storage acceptance: on a pattern-pruned ResNet-50-shaped layer
/// (3x3, cin=256→64 scaled), the reported q4 pattern payload bytes —
/// codebook charged — are under 40% of the f32 pattern payload.
#[test]
fn q4_pattern_disk_bytes_under_40_percent() {
    let (kh, kw, cin, cout) = (3usize, 3usize, 64usize, 64usize);
    let mut rng = Rng::new(17);
    let mut w = vec![0.0f32; kh * kw * cin * cout];
    rng.fill_normal(&mut w, 0.5);
    prune_patterns(&mut w, kh, kw, cin, cout, 0.9, 4, 8);
    let csr = CsrMatrix::from_dense(&w, kh * kw * cin, cout);
    let hwio = [kh, kw, cin, cout];
    let f32_rows = format_bytes_valued(&csr, hwio, ValueBits::F32);
    let q4_rows = format_bytes_valued(&csr, hwio, ValueBits::Q4);
    let f32_pat = f32_rows.iter().find(|r| r.format == "pattern").unwrap();
    let q4_pat = q4_rows.iter().find(|r| r.format == "pattern+q4").unwrap();
    assert!(
        (q4_pat.bytes_idx16 as f64) < 0.4 * f32_pat.bytes_idx16 as f64,
        "q4 {} vs f32 {} ({:.1}%)",
        q4_pat.bytes_idx16,
        f32_pat.bytes_idx16,
        100.0 * q4_pat.bytes_idx16 as f64 / f32_pat.bytes_idx16 as f64
    );
}

/// Quantized plans survive the artifact manifest; pre-quantization
/// manifests load with f32 payload plans.
#[test]
fn quantized_plan_survives_manifest_roundtrip() {
    let g = resnet_shaped();
    let profile = SparsityProfile::uniform_structured(
        &g,
        0.8,
        PruneStructure::Pattern { entries: 4 },
    )
    .with_uniform_quant(4);
    let plan = engine(&profile, ValuePolicy::Auto).exec_plan().unwrap();
    assert!(plan.layers.values().any(|lp| lp.value_bits == ValueBits::Q4));

    let mut manifest = Manifest::parse(
        r#"{"format": 1, "models": [
            {"name": "m", "variant": "sparse", "batch": 1, "path": "p",
             "input_shape": [1, 14, 14, 16]}
        ]}"#,
    )
    .unwrap();
    manifest.models[0].exec_plan = Some(plan.clone());
    let back = Manifest::parse(&manifest.to_json().to_string_pretty()).unwrap();
    assert_eq!(back.models[0].exec_plan.as_ref(), Some(&plan));
}

/// Pinned value policies through the engine: Q4/Q8/F32 all compute the
/// same function within quantization tolerance on an element-pruned
/// model (CSR payloads riding the LUT kernels).
#[test]
fn pinned_value_policies_agree_on_csr_payloads() {
    let g = resnet_shaped();
    let profile = SparsityProfile::uniform(&g, 0.9);
    let f = engine(&profile, ValuePolicy::F32);
    let q8 = engine(&profile, ValuePolicy::Q8);
    let q4 = engine(&profile, ValuePolicy::Q4);
    // deep scattered pruning keeps CSR; the pinned policies quantize it
    for (e, want) in [(&q8, ValueBits::Q8), (&q4, ValueBits::Q4)] {
        let plan = e.exec_plan().unwrap();
        for (name, lp) in &plan.layers {
            if lp.format != SparseFormat::Dense {
                assert_eq!(lp.value_bits, want, "{name}: {lp:?}");
            }
        }
    }
    let img = image(f.input_len(), 29);
    let a = f.session().run(&img).unwrap();
    let b = q8.session().run(&img).unwrap();
    let c = q4.session().run(&img).unwrap();
    for i in 0..a.len() {
        assert!((a[i] - b[i]).abs() < 0.1, "f32 vs q8 at {i}: {} vs {}", a[i], b[i]);
        assert!((a[i] - c[i]).abs() < 1.0, "f32 vs q4 at {i}: {} vs {}", a[i], c[i]);
    }
}
