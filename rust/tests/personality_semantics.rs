//! Integration: the framework personalities are semantics-preserving on
//! every layer vocabulary the paper's models use — residual adds, channel
//! concat (Inception), depthwise towers (MobileNet), classic conv+bias
//! (VGG). No artifacts required (native executor only). Exercised through
//! the public `Engine`/`Session` API where possible.

use cadnn::api::Engine;
use cadnn::exec::{ModelInstance, Personality};
use cadnn::ir::ops::{ActKind, Op, PoolKind};
use cadnn::ir::{Graph, Shape};
use cadnn::kernels::Tensor;
use cadnn::util::rng::Rng;

fn input_for(g: &Graph, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let mut t = Tensor::zeros(&g.nodes[0].shape.0);
    rng.fill_normal(&mut t.data, 0.5);
    t
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

fn assert_personalities_agree(g: &Graph, tol: f32) {
    let x = input_for(g, 42);
    let batch = g.nodes[0].shape.0[0];
    let run = |p: Personality| -> Vec<f32> {
        let engine = Engine::from_graph(g.clone()).personality(p).build().unwrap();
        let mut session = engine.session();
        session.run_batch(batch, &x.data).unwrap()
    };
    let base = run(Personality::TfLiteLike);
    for p in [Personality::TvmLike, Personality::CadnnDense] {
        let out = run(p);
        assert_eq!(base.len(), out.len(), "{} output length", p.label());
        let d = max_abs_diff(&base, &out);
        assert!(d < tol, "{}: diff {d}", p.label());
    }
}

/// Inception-style: parallel branches + avg-pool branch + channel concat.
#[test]
fn concat_branches_agree() {
    let mut g = Graph::new("mini_inception", Shape::nhwc(1, 12, 12, 8));
    let b1 = {
        let c = g.add("br1_1x1", Op::conv(1, 1, 8, 8, 1, 0), vec![0]);
        let b = g.add("br1_1x1_bn", Op::BatchNorm { c: 8 }, vec![c]);
        g.add("br1_1x1_relu", Op::Activation { kind: ActKind::Relu }, vec![b])
    };
    let b2 = {
        let c = g.add("br2_a", Op::conv(1, 1, 8, 4, 1, 0), vec![0]);
        let b = g.add("br2_a_bn", Op::BatchNorm { c: 4 }, vec![c]);
        let r = g.add("br2_a_relu", Op::Activation { kind: ActKind::Relu }, vec![b]);
        let c2 = g.add("br2_b", Op::conv_asym(1, 5, 4, 8, 1, 0, 2), vec![r]);
        let b2 = g.add("br2_b_bn", Op::BatchNorm { c: 8 }, vec![c2]);
        g.add("br2_b_relu", Op::Activation { kind: ActKind::Relu }, vec![b2])
    };
    let b3 = {
        let p = g.add(
            "br3_pool",
            Op::Pool { kind: PoolKind::Avg, k: 3, stride: 1, padding: 1 },
            vec![0],
        );
        let c = g.add("br3_proj", Op::conv(1, 1, 8, 4, 1, 0), vec![p]);
        let b = g.add("br3_proj_bn", Op::BatchNorm { c: 4 }, vec![c]);
        g.add("br3_proj_relu", Op::Activation { kind: ActKind::Relu }, vec![b])
    };
    let cat = g.add("cat", Op::Concat, vec![b1, b2, b3]);
    let gap = g.add("gap", Op::GlobalAvgPool, vec![cat]);
    g.add("fc", Op::fc(20, 10), vec![gap]);
    g.validate().unwrap();
    assert_personalities_agree(&g, 2e-3);
}

/// MobileNet-style depthwise-separable tower with relu6.
#[test]
fn depthwise_tower_agrees() {
    let mut g = Graph::new("mini_mobilenet", Shape::nhwc(2, 16, 16, 6));
    let mut x = 0;
    let mut cin = 6;
    for (i, (cout, s)) in [(12usize, 2usize), (12, 1), (24, 2)].iter().enumerate() {
        let dw = g.add(
            format!("b{i}_dw"),
            Op::DepthwiseConv2d { kh: 3, kw: 3, c: cin, stride: *s, padding: 1 },
            vec![x],
        );
        let dwb = g.add(format!("b{i}_dw_bn"), Op::BatchNorm { c: cin }, vec![dw]);
        let dwa = g.add(
            format!("b{i}_dw_act"),
            Op::Activation { kind: ActKind::Relu6 },
            vec![dwb],
        );
        let pw = g.add(format!("b{i}_pw"), Op::conv(1, 1, cin, *cout, 1, 0), vec![dwa]);
        let pwb = g.add(format!("b{i}_pw_bn"), Op::BatchNorm { c: *cout }, vec![pw]);
        x = g.add(
            format!("b{i}_pw_act"),
            Op::Activation { kind: ActKind::Relu6 },
            vec![pwb],
        );
        cin = *cout;
    }
    let gap = g.add("gap", Op::GlobalAvgPool, vec![x]);
    g.add("fc", Op::fc(24, 10), vec![gap]);
    g.validate().unwrap();
    assert_personalities_agree(&g, 2e-3);
}

/// VGG-style conv+bias (no BN) with maxpool: fusion must leave it alone
/// but the GEMM engine must still match the direct engine.
#[test]
fn classic_conv_bias_agrees() {
    let mut g = Graph::new("mini_vgg", Shape::nhwc(1, 14, 14, 3));
    let c1 = g.add("c1", Op::conv_b(3, 3, 3, 8, 1, 1), vec![0]);
    let r1 = g.add("c1_relu", Op::Activation { kind: ActKind::Relu }, vec![c1]);
    let p1 = g.add(
        "p1",
        Op::Pool { kind: PoolKind::Max, k: 2, stride: 2, padding: 0 },
        vec![r1],
    );
    let c2 = g.add("c2", Op::conv_b(3, 3, 8, 16, 1, 1), vec![p1]);
    let r2 = g.add("c2_relu", Op::Activation { kind: ActKind::Relu }, vec![c2]);
    let f = g.add("flat", Op::Flatten, vec![r2]);
    let fc = g.add("f1", Op::fc(7 * 7 * 16, 32), vec![f]);
    let rf = g.add("f1_relu", Op::Activation { kind: ActKind::Relu }, vec![fc]);
    g.add("f2", Op::fc(32, 10), vec![rf]);
    g.validate().unwrap();
    assert_personalities_agree(&g, 2e-3);
}

/// ResNet-style strided residual block with 1x1 downsample.
#[test]
fn residual_downsample_agrees() {
    let mut g = Graph::new("mini_resnet", Shape::nhwc(1, 12, 12, 8));
    let c1 = g.add("c1", Op::conv(3, 3, 8, 16, 2, 1), vec![0]);
    let b1 = g.add("c1_bn", Op::BatchNorm { c: 16 }, vec![c1]);
    let r1 = g.add("c1_relu", Op::Activation { kind: ActKind::Relu }, vec![b1]);
    let c2 = g.add("c2", Op::conv(3, 3, 16, 16, 1, 1), vec![r1]);
    let b2 = g.add("c2_bn", Op::BatchNorm { c: 16 }, vec![c2]);
    let dn = g.add("down", Op::conv(1, 1, 8, 16, 2, 0), vec![0]);
    let db = g.add("down_bn", Op::BatchNorm { c: 16 }, vec![dn]);
    let add = g.add("add", Op::Add, vec![b2, db]);
    let out = g.add("out_relu", Op::Activation { kind: ActKind::Relu }, vec![add]);
    let gap = g.add("gap", Op::GlobalAvgPool, vec![out]);
    g.add("fc", Op::fc(16, 10), vec![gap]);
    g.validate().unwrap();
    assert_personalities_agree(&g, 2e-3);
}

/// CadnnSparse at sparsity 0 must agree exactly with CadnnDense.
#[test]
fn sparse_at_zero_sparsity_equals_dense() {
    use cadnn::compress::profile::SparsityProfile;
    let mut g = Graph::new("zsp", Shape::nhwc(1, 8, 8, 4));
    let c = g.add("c1", Op::conv(3, 3, 4, 8, 1, 1), vec![0]);
    let b = g.add("c1_bn", Op::BatchNorm { c: 8 }, vec![c]);
    g.add("c1_relu", Op::Activation { kind: ActKind::Relu }, vec![b]);
    let x = input_for(&g, 3);
    let dense = ModelInstance::build(&g, Personality::CadnnDense, None, None, 1 << 20)
        .unwrap()
        .execute(&x)
        .unwrap();
    let profile = SparsityProfile::default(); // empty -> sparsity 0 everywhere
    let sparse = ModelInstance::build(&g, Personality::CadnnSparse, Some(&profile), None, 1 << 20)
        .unwrap()
        .execute(&x)
        .unwrap();
    assert!(dense.max_abs_diff(&sparse) < 1e-5);
}
