//! Integration: the multi-model `serve::Server` — named routing across
//! engines, deadline-miss semantics, top-k options, and the
//! planner-cost-driven batch scheduler (the `ExecPlan::cost_at` loop
//! from request to kernel choice).

use cadnn::api::{Backend, Engine};
use cadnn::compress::profile::paper_profile;
use cadnn::error::CadnnError;
use cadnn::exec::Personality;
use cadnn::models;
use cadnn::serve::sim::SimServer;
use cadnn::serve::{
    pick_batch, BatchPolicy, QueueConfig, Scheduler, ServeError, ServeRequest, Server, ShedCause,
};
use cadnn::util::rng::Rng;

fn qcfg() -> QueueConfig {
    QueueConfig { max_batch: 4, max_wait_us: 1_000, ..QueueConfig::default() }
}

fn image(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0f32; len];
    rng.fill_normal(&mut v, 0.5);
    v
}

fn sparse_engine(batches: &[usize]) -> Engine {
    let g = models::build("lenet5", 1).unwrap();
    Engine::native("lenet5")
        .personality(Personality::CadnnSparse)
        .sparsity_profile(paper_profile(&g))
        .batch_sizes(batches)
        .build()
        .unwrap()
}

/// The calibration-persistence satellite: a worker seeded with a
/// units→µs scale reports it before any request runs (so a fresh
/// process's scheduler is deadline-accurate from its first batch), and
/// the converged value is exposed for persisting back into the artifact
/// manifest next to `exec_plan`.
#[test]
fn calibration_seeds_fresh_schedulers_and_is_persistable() {
    let sparse = sparse_engine(&[1, 2, 4]);
    assert!(!sparse.plan_costs().is_empty(), "sparse engine must carry plan costs");
    assert_eq!(sparse.calibration(), None, "native engines persist no calibration");
    let seeded = Server::builder()
        .engine_with("m", &sparse, QueueConfig { calibration: Some(0.42), ..qcfg() })
        .build()
        .unwrap();
    // before ANY request: the seeded scale is live and snapshotable
    assert_eq!(seeded.stats()["m"].us_per_unit, Some(0.42));
    // after traffic, the EWMA keeps refining but stays present
    let img = image(28 * 28, 9);
    seeded.infer(ServeRequest::new("m", img.clone())).unwrap().logits().unwrap();
    let converged = seeded.stats()["m"].us_per_unit.expect("observations keep it live");
    assert!(converged > 0.0);
    seeded.shutdown().unwrap();

    // the persistence path: the converged value round-trips through the
    // artifact manifest next to exec_plan
    let mut manifest = cadnn::runtime::Manifest::parse(
        r#"{"format": 1, "models": [
            {"name": "lenet5", "variant": "sparse", "batch": 1, "path": "p",
             "input_shape": [1, 28, 28, 1]}
        ]}"#,
    )
    .unwrap();
    assert_eq!(manifest.record_calibration("lenet5", "sparse", converged), 1);
    let back = cadnn::runtime::Manifest::parse(&manifest.to_json().to_string_pretty()).unwrap();
    assert_eq!(back.models[0].us_per_unit, Some(converged));

    // an unseeded worker starts uncalibrated (online learning only)
    let plain = Server::builder().engine_with("m", &sparse, qcfg()).build().unwrap();
    assert_eq!(plain.stats()["m"].us_per_unit, None);
    plain.shutdown().unwrap();
}

/// Two registered engines, interleaved requests: every response routes
/// back from the model its request named, per-model stats stay separate,
/// and the dense model's answers match a direct session run.
#[test]
fn multi_model_routing_interleaved() {
    let dense = Engine::native("lenet5").batch_sizes(&[1, 2, 4]).build().unwrap();
    let sparse = sparse_engine(&[1, 2, 4]);
    let server = Server::builder()
        .engine_with("dense", &dense, qcfg())
        .engine_with("sparse", &sparse, qcfg())
        .build()
        .unwrap();
    assert_eq!(server.models(), vec!["dense", "sparse"]);
    assert_eq!(server.input_len("dense"), Some(28 * 28));
    assert_eq!(server.classes("sparse"), Some(10));

    let img = image(28 * 28, 3);
    let expected = dense.session().run(&img).unwrap();

    let n = 6;
    let mut rxs = Vec::new();
    for _ in 0..n {
        for m in ["dense", "sparse"] {
            rxs.push((m, server.submit(ServeRequest::new(m, img.clone())).unwrap()));
        }
    }
    for (model, rx) in rxs {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.model, model, "response must carry its model");
        let logits = resp.logits().expect("no backend errors");
        assert_eq!(logits.len(), 10);
        if model == "dense" {
            let d = logits
                .iter()
                .zip(&expected)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(d < 1e-5, "served dense logits diverge from session: {d}");
        }
    }
    let stats = server.stats();
    assert_eq!(stats["dense"].requests as usize, n);
    assert_eq!(stats["sparse"].requests as usize, n);
    assert_eq!(stats["dense"].deadline_misses, 0);
    server.shutdown().unwrap();
}

#[test]
fn unknown_model_and_bad_input_fail_synchronously() {
    let engine = Engine::native("lenet5").build().unwrap();
    let server = Server::builder().engine("lenet5", &engine).build().unwrap();
    match server.submit(ServeRequest::new("nope", vec![0.0; 28 * 28])) {
        Err(CadnnError::UnknownModel { name }) => assert_eq!(name, "nope"),
        other => panic!("expected UnknownModel, got {:?}", other.err()),
    }
    match server.submit(ServeRequest::new("lenet5", vec![0.0; 3])) {
        Err(CadnnError::InvalidInput { reason }) => assert!(reason.contains("3"), "{reason}"),
        other => panic!("expected InvalidInput, got {:?}", other.err()),
    }
    server.shutdown().unwrap();
}

#[test]
fn duplicate_model_name_is_a_config_error() {
    let engine = Engine::native("lenet5").build().unwrap();
    let err = Server::builder()
        .engine("m", &engine)
        .engine("m", &engine)
        .build()
        .err()
        .unwrap();
    assert!(matches!(err, CadnnError::Config { .. }), "{err}");
}

/// A backend the virtual-clock simulator can make arbitrarily slow
/// (execution time is injected; `run_batch` itself is instant).
struct SlowBackend {
    shape: Vec<usize>,
}

impl Backend for SlowBackend {
    fn name(&self) -> &str {
        "slow"
    }
    fn input_shape(&self) -> &[usize] {
        &self.shape
    }
    fn classes(&self) -> usize {
        4
    }
    fn batch_sizes(&self) -> Vec<usize> {
        vec![1, 2]
    }
    fn run_batch(&self, batch: usize, _input: &[f32]) -> Result<Vec<f32>, CadnnError> {
        Ok(vec![0.25; batch * 4])
    }
}

/// The deadline-miss path: a request whose deadline passes while queued
/// is answered with an explicit `ServeError::Deadline` (never executed),
/// counted in the per-model metrics — while the in-flight request still
/// gets its logits. Formerly a sleep-based test; on the virtual clock
/// every number is exact.
#[test]
fn expired_request_gets_explicit_deadline_error() {
    let mut sim = SimServer::new();
    // every batch takes 120ms of virtual time
    sim.register_with_cost(
        "slow",
        Box::new(SlowBackend { shape: vec![2, 2, 1] }),
        qcfg(),
        Box::new(|_| 120_000),
    )
    .unwrap();
    // r1 starts executing (120ms); r2 arrives mid-flight with a 5ms
    // deadline, so it has expired long before the worker frees up
    let r1 = sim.submit_at(0, ServeRequest::new("slow", vec![0.1; 4])).unwrap();
    let r2 = sim
        .submit_at(40_000, ServeRequest::new("slow", vec![0.2; 4]).deadline_ms(5))
        .unwrap();
    sim.run();

    let first = r1.try_recv().expect("served request answered");
    assert!(first.outcome.is_ok(), "in-flight request must succeed: {:?}", first.outcome);
    // 1000µs batching window + 120_000µs execution, exactly
    assert_eq!(first.latency_us, 121_000.0);
    let second = r2.try_recv().expect("expired request still answered");
    assert_eq!(
        second.outcome,
        Err(ServeError::Deadline { deadline_us: 5_000, waited_us: 81_000 }),
        "expired while the first batch ran: 121_000 - 40_000 = 81_000µs waited"
    );
    assert_eq!(second.batch, 0, "expired requests never ride a batch");

    let stats = sim.stats();
    assert_eq!(stats["slow"].deadline_misses, 1);
    assert_eq!(
        stats["slow"].deadline_misses_infeasible, 1,
        "5ms budget < the observed 120ms batch estimate: attributed as infeasible"
    );
    assert_eq!(stats["slow"].requests, 1, "only the served request counts");
}

/// Replica sharding on the threaded server: one logical model backed by
/// two workers. Every request of a burst is answered exactly once, the
/// merged snapshot accounts for all of them, and per-replica snapshots
/// are exposed. (No timing assertions — scheduling across real threads
/// is nondeterministic; the exact load-split properties live in the
/// virtual-clock `fleet_serving` suite.)
#[test]
fn replicated_model_serves_a_burst_across_workers() {
    let engine = Engine::native("lenet5").batch_sizes(&[1, 2, 4]).build().unwrap();
    let server = Server::builder()
        .engine_with("m", &engine, QueueConfig { replicas: 2, ..qcfg() })
        .build()
        .unwrap();
    let img = image(28 * 28, 21);
    let n = 12;
    let rxs: Vec<_> = (0..n)
        .map(|_| server.submit(ServeRequest::new("m", img.clone())).unwrap())
        .collect();
    let mut ids = Vec::new();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.logits().expect("no backend errors").len(), 10);
        ids.push(resp.id);
    }
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n, "every request answered exactly once");
    let stats = server.stats();
    assert_eq!(stats["m"].requests as usize, n);
    assert_eq!(stats["m"].replicas, 2);
    assert_eq!(server.replica_stats("m").unwrap().len(), 2);
    server.shutdown().unwrap();
}

/// A backend that parks inside `run_batch` until the test releases it —
/// a rendezvous, not a sleep — so quota admission can be exercised on
/// the threaded server with zero timing assumptions.
struct GatedBackend {
    started: std::sync::mpsc::Sender<()>,
    gate: std::sync::Mutex<std::sync::mpsc::Receiver<()>>,
}

impl Backend for GatedBackend {
    fn name(&self) -> &str {
        "gated"
    }
    fn input_shape(&self) -> &[usize] {
        &[2, 2, 1]
    }
    fn classes(&self) -> usize {
        4
    }
    fn batch_sizes(&self) -> Vec<usize> {
        vec![1, 2]
    }
    fn run_batch(&self, batch: usize, _input: &[f32]) -> Result<Vec<f32>, CadnnError> {
        let _ = self.started.send(());
        let _ = self.gate.lock().unwrap().recv();
        Ok(vec![0.5; batch * 4])
    }
    fn plan_costs(&self) -> Vec<(usize, f64)> {
        vec![(1, 1.0), (2, 2.0)]
    }
}

/// Per-model quota on the threaded server: while one admitted request
/// holds the entire (tiny) quota in flight, every further submit is
/// refused synchronously with `ServeError::Shed { cause: quota }`, and
/// the shed + served counts exactly partition the offered load.
#[test]
fn quota_sheds_synchronously_while_budget_is_held() {
    let (started_tx, started_rx) = std::sync::mpsc::channel();
    let (gate_tx, gate_rx) = std::sync::mpsc::channel();
    let server = Server::builder()
        .backend_with(
            "gated",
            move || {
                let b: Box<dyn Backend> = Box::new(GatedBackend {
                    started: started_tx,
                    gate: std::sync::Mutex::new(gate_rx),
                });
                Ok(b)
            },
            QueueConfig {
                quota_us: Some(1),
                calibration: Some(1_000.0),
                ..qcfg()
            },
        )
        .build()
        .unwrap();
    let first = server.submit(ServeRequest::new("gated", vec![0.1; 4])).unwrap();
    // rendezvous: the worker is now parked inside run_batch, so the
    // first request's 1000µs commitment is held against the 1µs quota
    started_rx.recv().expect("first batch started");
    let n = 7;
    let shed_rxs: Vec<_> = (0..n)
        .map(|_| server.submit(ServeRequest::new("gated", vec![0.2; 4])).unwrap())
        .collect();
    for rx in &shed_rxs {
        let resp = rx.recv().expect("shed requests are answered immediately");
        match resp.outcome {
            Err(ServeError::Shed { cause, .. }) => assert_eq!(cause, ShedCause::Quota),
            other => panic!("expected quota shed, got {other:?}"),
        }
        assert_eq!(resp.batch, 0);
    }
    gate_tx.send(()).unwrap();
    assert!(first.recv().unwrap().outcome.is_ok(), "the admitted request completes");
    let stats = server.stats();
    assert_eq!(stats["gated"].requests, 1);
    assert_eq!(stats["gated"].shed_quota, n);
    assert_eq!(stats["gated"].quota_us, Some(1));
    server.shutdown().unwrap();
}

/// Per-request top-k rides along with the logits.
#[test]
fn topk_option_attaches_sorted_classes() {
    let engine = Engine::native("lenet5").build().unwrap();
    let server = Server::builder().engine("m", &engine).build().unwrap();
    let resp = server
        .infer(ServeRequest::new("m", image(28 * 28, 7)).topk(3))
        .unwrap();
    let logits = resp.logits().unwrap().to_vec();
    let topk = resp.topk.expect("topk requested");
    assert_eq!(topk.len(), 3);
    let argmax = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    assert_eq!(topk[0].0, argmax);
    assert!(topk[0].1 >= topk[1].1 && topk[1].1 >= topk[2].1, "{topk:?}");
    // without the option, no topk is computed
    let plain = server.infer(ServeRequest::new("m", image(28 * 28, 8))).unwrap();
    assert!(plain.topk.is_none());
    server.shutdown().unwrap();
}

/// The acceptance loop, end to end on a real engine: the registry entry
/// carries the engine's `ExecPlan`, the per-variant scheduler costs ARE
/// `ExecPlan::cost_at(b)`, and under a tight pending deadline the
/// scheduler built from them picks a *smaller* batch than greedy
/// `pick_batch` — one whose estimate fits the slack.
#[test]
fn planner_costs_drive_deadline_aware_batching() {
    let engine = sparse_engine(&[1, 2, 4, 8]);
    let server = Server::builder().engine_with("m", &engine, qcfg()).build().unwrap();
    let entry = server.registry().get("m").expect("registered");
    let plan = entry.plan.as_ref().expect("pruned engine has a plan");
    assert_eq!(entry.batch_sizes, vec![1, 2, 4, 8]);
    assert_eq!(entry.plan_costs.len(), 4, "{:?}", entry.plan_costs);
    for (b, c) in &entry.plan_costs {
        let from_plan = plan.cost_at(*b).unwrap();
        assert!(
            (from_plan - c).abs() < 1e-6,
            "scheduler units must be ExecPlan::cost_at: batch {b}, {c} vs {from_plan}"
        );
    }

    let mut sched = Scheduler::new(
        entry.batch_sizes.clone(),
        entry.plan_costs.clone(),
        BatchPolicy::Greedy,
    );
    assert!(sched.planned());
    sched.calibrate(1.0); // 1 unit = 1µs, deterministic for the assert
    let greedy = pick_batch(8, &entry.batch_sizes, BatchPolicy::Greedy);
    assert_eq!(greedy, 8);
    // slack between the batch-4 and batch-8 estimates: 8 must be refused
    let (e4, e8) = (plan.cost_at(4).unwrap(), plan.cost_at(8).unwrap());
    let slack = (e4 + e8) / 2.0;
    let picked = sched.pick(8, Some(slack));
    assert!(
        picked < greedy,
        "deadline must force a smaller batch than greedy {greedy}, got {picked}"
    );
    assert!(sched.est_us(picked).unwrap() <= slack);
    // without deadline pressure the scheduler serves throughput
    assert_eq!(sched.pick(8, None), 8);
    server.shutdown().unwrap();
}

/// Old-surface smoke through the shim, proving `Coordinator` call sites
/// still behave (the dedicated legacy suite lives in native_serving.rs).
#[test]
fn coordinator_shim_still_serves() {
    use cadnn::coordinator::{BatcherConfig, Coordinator};
    let engine = Engine::native("lenet5").batch_sizes(&[1, 2]).build().unwrap();
    let coord = Coordinator::serve_engine(&engine, BatcherConfig::default()).unwrap();
    assert_eq!(coord.input_len, 28 * 28);
    let resp = coord.infer(image(28 * 28, 11)).unwrap();
    assert_eq!(resp.into_logits().unwrap().len(), 10);
    assert_eq!(coord.metrics.requests(), 1);
    coord.shutdown().unwrap();
}
