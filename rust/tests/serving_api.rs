//! Integration: the multi-model `serve::Server` — named routing across
//! engines, deadline-miss semantics, top-k options, and the
//! planner-cost-driven batch scheduler (the `ExecPlan::cost_at` loop
//! from request to kernel choice).

use cadnn::api::{Backend, Engine};
use cadnn::compress::profile::paper_profile;
use cadnn::error::CadnnError;
use cadnn::exec::Personality;
use cadnn::models;
use cadnn::serve::{
    pick_batch, BatchPolicy, QueueConfig, Scheduler, ServeError, ServeRequest, Server,
};
use cadnn::util::rng::Rng;

fn qcfg() -> QueueConfig {
    QueueConfig { max_batch: 4, max_wait_us: 1_000, ..QueueConfig::default() }
}

fn image(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0f32; len];
    rng.fill_normal(&mut v, 0.5);
    v
}

fn sparse_engine(batches: &[usize]) -> Engine {
    let g = models::build("lenet5", 1).unwrap();
    Engine::native("lenet5")
        .personality(Personality::CadnnSparse)
        .sparsity_profile(paper_profile(&g))
        .batch_sizes(batches)
        .build()
        .unwrap()
}

/// The calibration-persistence satellite: a worker seeded with a
/// units→µs scale reports it before any request runs (so a fresh
/// process's scheduler is deadline-accurate from its first batch), and
/// the converged value is exposed for persisting back into the artifact
/// manifest next to `exec_plan`.
#[test]
fn calibration_seeds_fresh_schedulers_and_is_persistable() {
    let sparse = sparse_engine(&[1, 2, 4]);
    assert!(!sparse.plan_costs().is_empty(), "sparse engine must carry plan costs");
    assert_eq!(sparse.calibration(), None, "native engines persist no calibration");
    let seeded = Server::builder()
        .engine_with("m", &sparse, QueueConfig { calibration: Some(0.42), ..qcfg() })
        .build()
        .unwrap();
    // before ANY request: the seeded scale is live and snapshotable
    assert_eq!(seeded.stats()["m"].us_per_unit, Some(0.42));
    // after traffic, the EWMA keeps refining but stays present
    let img = image(28 * 28, 9);
    seeded.infer(ServeRequest::new("m", img.clone())).unwrap().logits().unwrap();
    let converged = seeded.stats()["m"].us_per_unit.expect("observations keep it live");
    assert!(converged > 0.0);
    seeded.shutdown().unwrap();

    // the persistence path: the converged value round-trips through the
    // artifact manifest next to exec_plan
    let mut manifest = cadnn::runtime::Manifest::parse(
        r#"{"format": 1, "models": [
            {"name": "lenet5", "variant": "sparse", "batch": 1, "path": "p",
             "input_shape": [1, 28, 28, 1]}
        ]}"#,
    )
    .unwrap();
    assert_eq!(manifest.record_calibration("lenet5", "sparse", converged), 1);
    let back = cadnn::runtime::Manifest::parse(&manifest.to_json().to_string_pretty()).unwrap();
    assert_eq!(back.models[0].us_per_unit, Some(converged));

    // an unseeded worker starts uncalibrated (online learning only)
    let plain = Server::builder().engine_with("m", &sparse, qcfg()).build().unwrap();
    assert_eq!(plain.stats()["m"].us_per_unit, None);
    plain.shutdown().unwrap();
}

/// Two registered engines, interleaved requests: every response routes
/// back from the model its request named, per-model stats stay separate,
/// and the dense model's answers match a direct session run.
#[test]
fn multi_model_routing_interleaved() {
    let dense = Engine::native("lenet5").batch_sizes(&[1, 2, 4]).build().unwrap();
    let sparse = sparse_engine(&[1, 2, 4]);
    let server = Server::builder()
        .engine_with("dense", &dense, qcfg())
        .engine_with("sparse", &sparse, qcfg())
        .build()
        .unwrap();
    assert_eq!(server.models(), vec!["dense", "sparse"]);
    assert_eq!(server.input_len("dense"), Some(28 * 28));
    assert_eq!(server.classes("sparse"), Some(10));

    let img = image(28 * 28, 3);
    let expected = dense.session().run(&img).unwrap();

    let n = 6;
    let mut rxs = Vec::new();
    for _ in 0..n {
        for m in ["dense", "sparse"] {
            rxs.push((m, server.submit(ServeRequest::new(m, img.clone())).unwrap()));
        }
    }
    for (model, rx) in rxs {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.model, model, "response must carry its model");
        let logits = resp.logits().expect("no backend errors");
        assert_eq!(logits.len(), 10);
        if model == "dense" {
            let d = logits
                .iter()
                .zip(&expected)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(d < 1e-5, "served dense logits diverge from session: {d}");
        }
    }
    let stats = server.stats();
    assert_eq!(stats["dense"].requests as usize, n);
    assert_eq!(stats["sparse"].requests as usize, n);
    assert_eq!(stats["dense"].deadline_misses, 0);
    server.shutdown().unwrap();
}

#[test]
fn unknown_model_and_bad_input_fail_synchronously() {
    let engine = Engine::native("lenet5").build().unwrap();
    let server = Server::builder().engine("lenet5", &engine).build().unwrap();
    match server.submit(ServeRequest::new("nope", vec![0.0; 28 * 28])) {
        Err(CadnnError::UnknownModel { name }) => assert_eq!(name, "nope"),
        other => panic!("expected UnknownModel, got {:?}", other.err()),
    }
    match server.submit(ServeRequest::new("lenet5", vec![0.0; 3])) {
        Err(CadnnError::InvalidInput { reason }) => assert!(reason.contains("3"), "{reason}"),
        other => panic!("expected InvalidInput, got {:?}", other.err()),
    }
    server.shutdown().unwrap();
}

#[test]
fn duplicate_model_name_is_a_config_error() {
    let engine = Engine::native("lenet5").build().unwrap();
    let err = Server::builder()
        .engine("m", &engine)
        .engine("m", &engine)
        .build()
        .err()
        .unwrap();
    assert!(matches!(err, CadnnError::Config { .. }), "{err}");
}

/// A backend slow enough that a short-deadline request expires while the
/// previous batch executes.
struct SlowBackend {
    shape: Vec<usize>,
    delay_ms: u64,
}

impl Backend for SlowBackend {
    fn name(&self) -> &str {
        "slow"
    }
    fn input_shape(&self) -> &[usize] {
        &self.shape
    }
    fn classes(&self) -> usize {
        4
    }
    fn batch_sizes(&self) -> Vec<usize> {
        vec![1, 2]
    }
    fn run_batch(&self, batch: usize, _input: &[f32]) -> Result<Vec<f32>, CadnnError> {
        std::thread::sleep(std::time::Duration::from_millis(self.delay_ms));
        Ok(vec![0.25; batch * 4])
    }
}

/// The deadline-miss path: a request whose deadline passes while queued
/// is answered with an explicit `ServeError::Deadline` (never executed),
/// counted in the per-model metrics — while the in-flight request still
/// gets its logits.
#[test]
fn expired_request_gets_explicit_deadline_error() {
    let server = Server::builder()
        .backend_with(
            "slow",
            || {
                let b: Box<dyn Backend> =
                    Box::new(SlowBackend { shape: vec![2, 2, 1], delay_ms: 120 });
                Ok(b)
            },
            qcfg(),
        )
        .build()
        .unwrap();
    // r1 starts executing (~120ms); r2 arrives mid-flight with a 5ms
    // deadline, so it has expired long before the worker frees up
    let r1 = server.submit(ServeRequest::new("slow", vec![0.1; 4])).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(40));
    let r2 = server
        .submit(ServeRequest::new("slow", vec![0.2; 4]).deadline_ms(5))
        .unwrap();

    let first = r1.recv().expect("served request answered");
    assert!(first.outcome.is_ok(), "in-flight request must succeed: {:?}", first.outcome);
    let second = r2.recv().expect("expired request still answered");
    match second.outcome {
        Err(ServeError::Deadline { deadline_us, waited_us }) => {
            assert_eq!(deadline_us, 5_000);
            assert!(waited_us >= 5_000, "waited {waited_us}µs < budget");
        }
        other => panic!("expected Deadline, got {other:?}"),
    }
    assert_eq!(second.batch, 0, "expired requests never ride a batch");

    let stats = server.stats();
    assert_eq!(stats["slow"].deadline_misses, 1);
    assert_eq!(stats["slow"].requests, 1, "only the served request counts");
    server.shutdown().unwrap();
}

/// Per-request top-k rides along with the logits.
#[test]
fn topk_option_attaches_sorted_classes() {
    let engine = Engine::native("lenet5").build().unwrap();
    let server = Server::builder().engine("m", &engine).build().unwrap();
    let resp = server
        .infer(ServeRequest::new("m", image(28 * 28, 7)).topk(3))
        .unwrap();
    let logits = resp.logits().unwrap().to_vec();
    let topk = resp.topk.expect("topk requested");
    assert_eq!(topk.len(), 3);
    let argmax = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    assert_eq!(topk[0].0, argmax);
    assert!(topk[0].1 >= topk[1].1 && topk[1].1 >= topk[2].1, "{topk:?}");
    // without the option, no topk is computed
    let plain = server.infer(ServeRequest::new("m", image(28 * 28, 8))).unwrap();
    assert!(plain.topk.is_none());
    server.shutdown().unwrap();
}

/// The acceptance loop, end to end on a real engine: the registry entry
/// carries the engine's `ExecPlan`, the per-variant scheduler costs ARE
/// `ExecPlan::cost_at(b)`, and under a tight pending deadline the
/// scheduler built from them picks a *smaller* batch than greedy
/// `pick_batch` — one whose estimate fits the slack.
#[test]
fn planner_costs_drive_deadline_aware_batching() {
    let engine = sparse_engine(&[1, 2, 4, 8]);
    let server = Server::builder().engine_with("m", &engine, qcfg()).build().unwrap();
    let entry = server.registry().get("m").expect("registered");
    let plan = entry.plan.as_ref().expect("pruned engine has a plan");
    assert_eq!(entry.batch_sizes, vec![1, 2, 4, 8]);
    assert_eq!(entry.plan_costs.len(), 4, "{:?}", entry.plan_costs);
    for (b, c) in &entry.plan_costs {
        let from_plan = plan.cost_at(*b).unwrap();
        assert!(
            (from_plan - c).abs() < 1e-6,
            "scheduler units must be ExecPlan::cost_at: batch {b}, {c} vs {from_plan}"
        );
    }

    let mut sched = Scheduler::new(
        entry.batch_sizes.clone(),
        entry.plan_costs.clone(),
        BatchPolicy::Greedy,
    );
    assert!(sched.planned());
    sched.calibrate(1.0); // 1 unit = 1µs, deterministic for the assert
    let greedy = pick_batch(8, &entry.batch_sizes, BatchPolicy::Greedy);
    assert_eq!(greedy, 8);
    // slack between the batch-4 and batch-8 estimates: 8 must be refused
    let (e4, e8) = (plan.cost_at(4).unwrap(), plan.cost_at(8).unwrap());
    let slack = (e4 + e8) / 2.0;
    let picked = sched.pick(8, Some(slack));
    assert!(
        picked < greedy,
        "deadline must force a smaller batch than greedy {greedy}, got {picked}"
    );
    assert!(sched.est_us(picked).unwrap() <= slack);
    // without deadline pressure the scheduler serves throughput
    assert_eq!(sched.pick(8, None), 8);
    server.shutdown().unwrap();
}

/// Old-surface smoke through the shim, proving `Coordinator` call sites
/// still behave (the dedicated legacy suite lives in native_serving.rs).
#[test]
fn coordinator_shim_still_serves() {
    use cadnn::coordinator::{BatcherConfig, Coordinator};
    let engine = Engine::native("lenet5").batch_sizes(&[1, 2]).build().unwrap();
    let coord = Coordinator::serve_engine(&engine, BatcherConfig::default()).unwrap();
    assert_eq!(coord.input_len, 28 * 28);
    let resp = coord.infer(image(28 * 28, 11)).unwrap();
    assert_eq!(resp.into_logits().unwrap().len(), 10);
    assert_eq!(coord.metrics.requests(), 1);
    coord.shutdown().unwrap();
}
