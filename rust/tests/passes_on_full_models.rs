//! Integration: compiler passes on the full paper architectures —
//! structural post-conditions per model, cost-model consistency, and the
//! tuned-instance path.

use cadnn::costmodel::{calibrate::CalibrationTable, devices, graph_cost};
use cadnn::exec::{ModelInstance, Personality};
use cadnn::ir::Op;
use cadnn::models;
use cadnn::passes::{conv1x1_gemm::Conv1x1ToGemm, fusion::FusionPass, Pass};
use cadnn::tuner::TunerCache;

fn count(g: &cadnn::ir::Graph, name: &str) -> usize {
    g.nodes.iter().filter(|n| n.op.name() == name).count()
}

#[test]
fn inception_v3_fusion_postconditions() {
    let g = models::build("inception_v3", 1).unwrap();
    let f = FusionPass.run(&g);
    f.validate().unwrap();
    // every conv has a BN: all fuse, none remain
    assert_eq!(count(&f, "batchnorm"), 0);
    assert_eq!(count(&f, "conv2d"), 0);
    assert_eq!(count(&f, "fused_conv_bn_act"), 94); // 94 convs
    // concat structure untouched (11 mixed blocks + 2x2 inner concats)
    assert_eq!(count(&f, "concat"), count(&g, "concat"));
}

#[test]
fn mobilenet_v2_linear_bottleneck_preserved() {
    // the projection conv has NO activation; fusion must fold bn with
    // act=None, not invent a relu
    let g = models::build("mobilenet_v2", 1).unwrap();
    let f = FusionPass.run(&g);
    let t = Conv1x1ToGemm.run(&f);
    t.validate().unwrap();
    let relu_none_gemms = t
        .nodes
        .iter()
        .filter(|n| {
            matches!(
                n.op,
                Op::Gemm { act: cadnn::ir::ops::ActKind::None, .. }
            )
        })
        .count();
    // 17 projection convs (1 per inverted-residual block) are linear
    assert!(relu_none_gemms >= 17, "{relu_none_gemms}");
}

#[test]
fn gemm_transform_counts_per_model() {
    // 1x1 conv population is a well-known architectural fact per model
    for (name, min_gemms) in [("resnet50", 30), ("mobilenet_v2", 30), ("inception_v3", 40)] {
        let g = models::build(name, 1).unwrap();
        let t = Conv1x1ToGemm.run(&FusionPass.run(&g));
        let gemms = count(&t, "gemm");
        assert!(gemms >= min_gemms, "{name}: {gemms} gemms");
    }
}

#[test]
fn cost_model_batch_monotone() {
    let calib = CalibrationTable::nominal();
    let dev = devices::snapdragon835_cpu();
    for name in ["mobilenet_v1", "resnet50"] {
        let g1 = models::build(name, 1).unwrap();
        let g4 = models::build(name, 4).unwrap();
        let (c1, _) = graph_cost(&g1, &dev, &calib, false, None, None);
        let (c4, _) = graph_cost(&g4, &dev, &calib, false, None, None);
        assert!(c4 > c1 * 3.0 && c4 < c1 * 4.5, "{name}: {c1} -> {c4}");
    }
}

#[test]
fn node_costs_all_positive_and_sum() {
    let calib = CalibrationTable::nominal();
    let dev = devices::adreno540_gpu();
    let g = models::build("inception_v3", 1).unwrap();
    let (total, costs) = graph_cost(&g, &dev, &calib, false, None, None);
    let sum: f64 = costs.iter().map(|c| c.us).sum();
    assert!((total - sum).abs() < 1e-6);
    assert!(costs.iter().all(|c| c.us > 0.0));
    // a GPU projection of inception has some compute-bound conv layers
    assert!(costs.iter().any(|c| c.compute_bound));
}

#[test]
fn tuned_instance_builds_and_runs() {
    use cadnn::ir::{Graph, Shape};
    use cadnn::ir::ops::ActKind;
    use cadnn::kernels::Tensor;
    let mut g = Graph::new("tuned", Shape::nhwc(1, 16, 16, 8));
    let c = g.add("c1", Op::conv(3, 3, 8, 16, 1, 1), vec![0]);
    let b = g.add("c1_bn", Op::BatchNorm { c: 16 }, vec![c]);
    g.add("c1_relu", Op::Activation { kind: ActKind::Relu }, vec![b]);
    let mut cache = TunerCache::new();
    let inst =
        ModelInstance::build(&g, Personality::CadnnDense, None, Some(&mut cache), 1 << 20)
            .unwrap();
    assert!(!cache.is_empty(), "tuner cache unpopulated");
    let x = Tensor::zeros(&[1, 16, 16, 8]);
    let out = inst.execute(&x).unwrap();
    assert_eq!(out.shape, vec![1, 16, 16, 16]);
}

#[test]
fn grouped_conv_models_rejected_by_executor() {
    // AlexNet has grouped convs; the native executor declines them
    // explicitly (typed) rather than silently computing the wrong thing.
    let g = models::build("alexnet", 1).unwrap();
    let r = ModelInstance::build(&g, Personality::TfLiteLike, None, None, 1 << 20);
    match r {
        Err(cadnn::error::CadnnError::UnsupportedOp { reason, .. }) => {
            assert!(reason.contains("grouped"), "{reason}");
        }
        other => panic!("expected UnsupportedOp, got {:?}", other.err()),
    }
}

#[test]
fn tuned_engine_builds_through_api() {
    use cadnn::api::Engine;
    use cadnn::ir::ops::ActKind;
    use cadnn::ir::{Graph, Shape};
    let mut g = Graph::new("tuned_api", Shape::nhwc(1, 16, 16, 8));
    let c = g.add("c1", Op::conv(3, 3, 8, 16, 1, 1), vec![0]);
    let b = g.add("c1_bn", Op::BatchNorm { c: 16 }, vec![c]);
    g.add("c1_relu", Op::Activation { kind: ActKind::Relu }, vec![b]);
    let engine = Engine::from_graph(g).tuned(true).cache_bytes(1 << 20).build().unwrap();
    let mut session = engine.session();
    let out = session.run(&vec![0.25f32; engine.input_len()]).unwrap();
    assert_eq!(out.len(), 16 * 16 * 16);
}

#[test]
fn profiler_accounts_all_nodes() {
    use cadnn::ir::{Graph, Shape};
    use cadnn::ir::ops::ActKind;
    use cadnn::kernels::Tensor;
    let mut g = Graph::new("prof", Shape::nhwc(1, 12, 12, 4));
    let c = g.add("c1", Op::conv(3, 3, 4, 8, 1, 1), vec![0]);
    let b = g.add("c1_bn", Op::BatchNorm { c: 8 }, vec![c]);
    let r = g.add("c1_relu", Op::Activation { kind: ActKind::Relu }, vec![b]);
    let gap = g.add("gap", Op::GlobalAvgPool, vec![r]);
    g.add("fc", Op::fc(8, 10), vec![gap]);
    let inst = ModelInstance::build(&g, Personality::CadnnDense, None, None, 1 << 20).unwrap();
    let x = Tensor::zeros(&[1, 12, 12, 4]);
    let prof = inst.profile(&x, 1).unwrap();
    // fused graph: fused_conv_bn_act + gap + fc = 3 nodes after input
    assert_eq!(prof.len(), inst.graph.len() - 1);
    assert!(prof.iter().all(|p| p.us >= 0.0));
    let conv = prof.iter().find(|p| p.kind == "fused_conv_bn_act").unwrap();
    assert!(conv.flops > 0);
    assert!(conv.gflops() >= 0.0);
}
