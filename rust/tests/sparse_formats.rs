//! End-to-end coverage of the sparse-format subsystem through the public
//! API: format policies compute the same function, the planner's Auto
//! mode respects the CSR baseline on scattered sparsity, and exec plans
//! survive the manifest.

use cadnn::api::Engine;
use cadnn::compress::bsr::BsrMatrix;
use cadnn::compress::csr::CsrMatrix;
use cadnn::compress::pattern::{prune_patterns, PatternMatrix};
use cadnn::compress::profile::{PruneStructure, SparsityProfile};
use cadnn::exec::Personality;
use cadnn::ir::ops::{ActKind, Op};
use cadnn::ir::{Graph, Shape};
use cadnn::kernels::conv::{conv2d_csr, conv2d_gemm, conv2d_pattern};
use cadnn::kernels::{Epilogue, Tensor, PARALLEL_M_CUTOVER};
use cadnn::passes::layout::TileConfig;
use cadnn::planner::{choose, ExecPlan, FormatPolicy, LayerPlan, SparseFormat};
use cadnn::runtime::Manifest;
use cadnn::util::rng::Rng;

fn conv_stack() -> Graph {
    let relu = || Op::Activation { kind: ActKind::Relu };
    let mut g = Graph::new("formats_e2e", Shape::nhwc(1, 10, 10, 4));
    let c1 = g.add("c1", Op::conv(3, 3, 4, 32, 1, 1), vec![0]);
    let b1 = g.add("c1_bn", Op::BatchNorm { c: 32 }, vec![c1]);
    let r1 = g.add("c1_relu", relu(), vec![b1]);
    let c2 = g.add("c2", Op::conv(1, 1, 32, 32, 1, 0), vec![r1]);
    let b2 = g.add("c2_bn", Op::BatchNorm { c: 32 }, vec![c2]);
    let r2 = g.add("c2_relu", relu(), vec![b2]);
    let p = g.add("gap", Op::GlobalAvgPool, vec![r2]);
    g.add("fc", Op::fc(32, 8), vec![p]);
    g.validate().unwrap();
    g
}

fn engine_with(policy: FormatPolicy, sparsity: f64) -> Engine {
    let g = conv_stack();
    let profile = SparsityProfile::uniform(&g, sparsity);
    Engine::from_graph(conv_stack())
        .personality(Personality::CadnnSparse)
        .sparsity_profile(profile)
        .sparse_format(policy)
        .build()
        .unwrap()
}

fn image(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0f32; len];
    rng.fill_normal(&mut v, 0.5);
    v
}

#[test]
fn all_policies_compute_the_same_function() {
    let csr = engine_with(FormatPolicy::Csr, 0.8);
    let bsr = engine_with(FormatPolicy::Bsr, 0.8);
    let auto = engine_with(FormatPolicy::Auto, 0.8);
    let img = image(csr.input_len(), 1);
    let a = csr.session().run(&img).unwrap();
    let b = bsr.session().run(&img).unwrap();
    let c = auto.session().run(&img).unwrap();
    assert_eq!(a.len(), 8);
    for i in 0..a.len() {
        assert!((a[i] - b[i]).abs() < 1e-3, "csr vs bsr at {i}: {} vs {}", a[i], b[i]);
        assert!((a[i] - c[i]).abs() < 1e-3, "csr vs auto at {i}: {} vs {}", a[i], c[i]);
    }
}

#[test]
fn auto_never_leaves_csr_for_scattered_deep_pruning() {
    // magnitude pruning of generated weights scatters the support; at
    // 92% sparsity the planner must keep every layer on the CSR baseline
    let auto = engine_with(FormatPolicy::Auto, 0.92);
    let inst = auto.native_backend().unwrap().instance(1).unwrap();
    assert!(!inst.plan.is_empty());
    for (name, lp) in &inst.plan.layers {
        assert_eq!(lp.format, SparseFormat::Csr, "{name} left the baseline: {lp:?}");
    }
}

#[test]
fn planner_prefers_bsr_on_block_structured_weights() {
    // whole 4x4 blocks at 30% density: fill ratio 1.0, BSR must win
    let (k, n) = (64usize, 32usize);
    let mut rng = Rng::new(7);
    let mut dense = vec![0.0f32; k * n];
    for b in 0..k / 4 {
        for j in 0..n / 4 {
            if rng.f64() >= 0.3 {
                continue;
            }
            for p in 0..4 {
                for x in 0..4 {
                    dense[(b * 4 + p) * n + j * 4 + x] = rng.normal() as f32;
                }
            }
        }
    }
    let csr = CsrMatrix::from_dense(&dense, k, n);
    let lp = choose(FormatPolicy::Auto, &csr, 128, [1, 1, k, n]);
    assert!(matches!(lp.format, SparseFormat::Bsr { .. }), "{lp:?}");
    // and the chosen encoding really is padding-free
    if let SparseFormat::Bsr { br, bc } = lp.format {
        let bsr = BsrMatrix::from_dense(&dense, k, n, br, bc);
        assert!(bsr.fill_ratio() > 0.99, "fill {}", bsr.fill_ratio());
    }
}

fn engine_with_structure(policy: FormatPolicy, sparsity: f64, structure: PruneStructure) -> Engine {
    let g = conv_stack();
    let profile = SparsityProfile::uniform_structured(&g, sparsity, structure);
    Engine::from_graph(conv_stack())
        .personality(Personality::CadnnSparse)
        .sparsity_profile(profile)
        .sparse_format(policy)
        .build()
        .unwrap()
}

/// Cross-format execution equivalence on pattern-pruned weights, at the
/// kernel level where the reduction order is provable: with a single
/// input channel every output channel is fed by at most one kernel
/// slice, so the Dense (blocked GEMM), CSR, and Pattern conv paths all
/// reduce over K in the same ascending order — outputs must be
/// **bit-identical**, not just close.
#[test]
fn dense_csr_pattern_conv_outputs_bit_identical_single_channel() {
    let (kh, kw, cin, cout) = (3usize, 3usize, 1usize, 16usize);
    let k = kh * kw * cin;
    let mut rng = Rng::new(41);
    let x = Tensor::randn(&[1, 8, 8, cin], &mut rng, 1.0);
    let mut w = vec![0.0f32; k * cout];
    rng.fill_normal(&mut w, 0.5);
    prune_patterns(&mut w, kh, kw, cin, cout, 0.6, 4, 8);
    let scale: Vec<f32> = (0..cout).map(|_| 0.5 + rng.f32()).collect();
    let shift: Vec<f32> = (0..cout).map(|_| rng.f32() + 0.1).collect();
    let epi = Epilogue::bn_act(scale, shift, true, false);
    let cut = PARALLEL_M_CUTOVER;

    let dense = conv2d_gemm(&x, &w, kh, kw, cout, 1, 1, 1, &TileConfig::DEFAULT, &epi);
    let csr = CsrMatrix::from_dense(&w, k, cout);
    let via_csr = conv2d_csr(&x, &csr, kh, kw, 1, 1, 1, &epi, cut);
    let pat = PatternMatrix::from_dense(&w, kh, kw, cin, cout);
    let via_pat = conv2d_pattern(&x, &pat, kh, kw, 1, 1, 1, &epi, cut);

    assert_eq!(dense.data, via_csr.data, "dense vs csr must be bit-identical");
    assert_eq!(via_csr.data, via_pat.data, "csr vs pattern must be bit-identical");
}

/// Multi-channel pattern-pruned weights through the full engine under
/// every policy: same function within float-reassociation tolerance
/// (multiple kernels feed one output channel, so the formats reduce in
/// different orders).
#[test]
fn pattern_policy_agrees_with_csr_on_pattern_pruned_model() {
    let s = PruneStructure::Pattern { entries: 4 };
    let csr = engine_with_structure(FormatPolicy::Csr, 0.8, s);
    let pat = engine_with_structure(FormatPolicy::Pattern, 0.8, s);
    let auto = engine_with_structure(FormatPolicy::Auto, 0.8, s);
    let img = image(csr.input_len(), 31);
    let a = csr.session().run(&img).unwrap();
    let b = pat.session().run(&img).unwrap();
    let c = auto.session().run(&img).unwrap();
    for i in 0..a.len() {
        assert!((a[i] - b[i]).abs() < 1e-3, "csr vs pattern at {i}: {} vs {}", a[i], b[i]);
        assert!((a[i] - c[i]).abs() < 1e-3, "csr vs auto at {i}: {} vs {}", a[i], c[i]);
    }
}

/// On a pattern-pruned profile, Auto must move the 3x3 conv onto the
/// pattern format (the PatDNN co-design working end-to-end), while the
/// 1x1 conv — ineligible for patterns — stays on a baseline format.
#[test]
fn auto_picks_pattern_for_3x3_on_pattern_pruned_profile() {
    let auto = engine_with_structure(
        FormatPolicy::Auto,
        0.8,
        PruneStructure::Pattern { entries: 4 },
    );
    let inst = auto.native_backend().unwrap().instance(1).unwrap();
    let c1 = inst.plan.get("c1").expect("c1 planned");
    assert_eq!(c1.format, SparseFormat::Pattern, "3x3 conv: {c1:?}");
    let c2 = inst.plan.get("c2").expect("c2 planned");
    assert_ne!(c2.format, SparseFormat::Pattern, "1x1 conv is not pattern-eligible: {c2:?}");
}

/// Pinning Pattern on an element-pruned (scattered) profile still
/// executes correctly — the format tolerates arbitrary supports even
/// when the planner would not choose it.
#[test]
fn pinned_pattern_policy_is_correct_on_scattered_support() {
    let csr = engine_with(FormatPolicy::Csr, 0.8);
    let pat = engine_with(FormatPolicy::Pattern, 0.8);
    let img = image(csr.input_len(), 37);
    let a = csr.session().run(&img).unwrap();
    let b = pat.session().run(&img).unwrap();
    for i in 0..a.len() {
        assert!((a[i] - b[i]).abs() < 1e-3, "at {i}: {} vs {}", a[i], b[i]);
    }
}

#[test]
fn exec_plan_survives_a_manifest_round_trip() {
    let mut manifest = Manifest::parse(
        r#"{"format": 1, "models": [
            {"name": "m", "variant": "sparse", "batch": 1, "path": "p",
             "input_shape": [1, 8, 8, 3]}
        ]}"#,
    )
    .unwrap();
    let mut plan = ExecPlan::default();
    plan.layers.insert("c1".into(), LayerPlan::csr());
    plan.layers.insert(
        "c2".into(),
        LayerPlan {
            format: SparseFormat::Bsr { br: 4, bc: 4 },
            value_bits: cadnn::compress::qsparse::ValueBits::Q8,
            reorder: true,
            parallel_cutover: 256,
            cost_per_row: 172.8,
            rows_per_image: 64,
        },
    );
    plan.layers.insert(
        "c3".into(),
        LayerPlan {
            format: SparseFormat::Pattern,
            value_bits: cadnn::compress::qsparse::ValueBits::Q4,
            parallel_cutover: 128,
            cost_per_row: 96.5,
            rows_per_image: 100,
            ..LayerPlan::csr()
        },
    );
    manifest.models[0].exec_plan = Some(plan.clone());
    let text = manifest.to_json().to_string_pretty();
    let back = Manifest::parse(&text).unwrap();
    assert_eq!(back.models[0].exec_plan.as_ref(), Some(&plan));
}
