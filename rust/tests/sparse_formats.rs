//! End-to-end coverage of the sparse-format subsystem through the public
//! API: format policies compute the same function, the planner's Auto
//! mode respects the CSR baseline on scattered sparsity, and exec plans
//! survive the manifest.

use cadnn::api::Engine;
use cadnn::compress::bsr::BsrMatrix;
use cadnn::compress::csr::CsrMatrix;
use cadnn::compress::profile::SparsityProfile;
use cadnn::exec::Personality;
use cadnn::ir::ops::{ActKind, Op};
use cadnn::ir::{Graph, Shape};
use cadnn::planner::{choose, ExecPlan, FormatPolicy, LayerPlan, SparseFormat};
use cadnn::runtime::Manifest;
use cadnn::util::rng::Rng;

fn conv_stack() -> Graph {
    let relu = || Op::Activation { kind: ActKind::Relu };
    let mut g = Graph::new("formats_e2e", Shape::nhwc(1, 10, 10, 4));
    let c1 = g.add("c1", Op::conv(3, 3, 4, 32, 1, 1), vec![0]);
    let b1 = g.add("c1_bn", Op::BatchNorm { c: 32 }, vec![c1]);
    let r1 = g.add("c1_relu", relu(), vec![b1]);
    let c2 = g.add("c2", Op::conv(1, 1, 32, 32, 1, 0), vec![r1]);
    let b2 = g.add("c2_bn", Op::BatchNorm { c: 32 }, vec![c2]);
    let r2 = g.add("c2_relu", relu(), vec![b2]);
    let p = g.add("gap", Op::GlobalAvgPool, vec![r2]);
    g.add("fc", Op::fc(32, 8), vec![p]);
    g.validate().unwrap();
    g
}

fn engine_with(policy: FormatPolicy, sparsity: f64) -> Engine {
    let g = conv_stack();
    let profile = SparsityProfile::uniform(&g, sparsity);
    Engine::from_graph(conv_stack())
        .personality(Personality::CadnnSparse)
        .sparsity_profile(profile)
        .sparse_format(policy)
        .build()
        .unwrap()
}

fn image(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0f32; len];
    rng.fill_normal(&mut v, 0.5);
    v
}

#[test]
fn all_policies_compute_the_same_function() {
    let csr = engine_with(FormatPolicy::Csr, 0.8);
    let bsr = engine_with(FormatPolicy::Bsr, 0.8);
    let auto = engine_with(FormatPolicy::Auto, 0.8);
    let img = image(csr.input_len(), 1);
    let a = csr.session().run(&img).unwrap();
    let b = bsr.session().run(&img).unwrap();
    let c = auto.session().run(&img).unwrap();
    assert_eq!(a.len(), 8);
    for i in 0..a.len() {
        assert!((a[i] - b[i]).abs() < 1e-3, "csr vs bsr at {i}: {} vs {}", a[i], b[i]);
        assert!((a[i] - c[i]).abs() < 1e-3, "csr vs auto at {i}: {} vs {}", a[i], c[i]);
    }
}

#[test]
fn auto_never_leaves_csr_for_scattered_deep_pruning() {
    // magnitude pruning of generated weights scatters the support; at
    // 92% sparsity the planner must keep every layer on the CSR baseline
    let auto = engine_with(FormatPolicy::Auto, 0.92);
    let inst = auto.native_backend().unwrap().instance(1).unwrap();
    assert!(!inst.plan.is_empty());
    for (name, lp) in &inst.plan.layers {
        assert_eq!(lp.format, SparseFormat::Csr, "{name} left the baseline: {lp:?}");
    }
}

#[test]
fn planner_prefers_bsr_on_block_structured_weights() {
    // whole 4x4 blocks at 30% density: fill ratio 1.0, BSR must win
    let (k, n) = (64usize, 32usize);
    let mut rng = Rng::new(7);
    let mut dense = vec![0.0f32; k * n];
    for b in 0..k / 4 {
        for j in 0..n / 4 {
            if rng.f64() >= 0.3 {
                continue;
            }
            for p in 0..4 {
                for x in 0..4 {
                    dense[(b * 4 + p) * n + j * 4 + x] = rng.normal() as f32;
                }
            }
        }
    }
    let csr = CsrMatrix::from_dense(&dense, k, n);
    let lp = choose(FormatPolicy::Auto, &csr, 128, [1, 1, k, n]);
    assert!(matches!(lp.format, SparseFormat::Bsr { .. }), "{lp:?}");
    // and the chosen encoding really is padding-free
    if let SparseFormat::Bsr { br, bc } = lp.format {
        let bsr = BsrMatrix::from_dense(&dense, k, n, br, bc);
        assert!(bsr.fill_ratio() > 0.99, "fill {}", bsr.fill_ratio());
    }
}

#[test]
fn exec_plan_survives_a_manifest_round_trip() {
    let mut manifest = Manifest::parse(
        r#"{"format": 1, "models": [
            {"name": "m", "variant": "sparse", "batch": 1, "path": "p",
             "input_shape": [1, 8, 8, 3]}
        ]}"#,
    )
    .unwrap();
    let mut plan = ExecPlan::default();
    plan.layers.insert("c1".into(), LayerPlan::csr());
    plan.layers.insert(
        "c2".into(),
        LayerPlan { format: SparseFormat::Bsr { br: 4, bc: 4 }, reorder: true, parallel_cutover: 256 },
    );
    manifest.models[0].exec_plan = Some(plan.clone());
    let text = manifest.to_json().to_string_pretty();
    let back = Manifest::parse(&text).unwrap();
    assert_eq!(back.models[0].exec_plan.as_ref(), Some(&plan));
}
