//! Integration: the serving coordinator over real PJRT artifacts —
//! batching invariants, response integrity, shutdown under load.
//! Requires `make artifacts`; no-ops otherwise. The burst/batching
//! invariant also runs artifact-free and deterministically on the
//! virtual-clock simulator (`burst_batches_deterministically_on_the_
//! virtual_clock`), so the coalescing property is always exercised.

use cadnn::api::{Backend, Engine};
use cadnn::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig};
use cadnn::serve::sim::SimServer;
use cadnn::serve::{QueueConfig, ServeRequest};
use cadnn::util::rng::Rng;

fn cfg(variant: &str) -> Option<CoordinatorConfig> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(CoordinatorConfig {
        artifacts_dir: "artifacts".into(),
        model: "lenet5".into(),
        variant: variant.into(),
        max_batch: 8,
        max_wait_us: 1_000,
        policy: BatchPolicy::PadToFit,
    })
}

#[test]
fn serves_burst_and_batches() {
    let Some(cfg) = cfg("dense") else { return };
    let coord = Coordinator::start(cfg).unwrap();
    let mut rng = Rng::new(5);
    // a burst: all submitted at once -> batcher should coalesce
    let n = 24;
    let mut rxs = Vec::new();
    for _ in 0..n {
        let mut img = vec![0.0f32; coord.input_len];
        rng.fill_normal(&mut img, 0.5);
        rxs.push(coord.submit(img).unwrap());
    }
    let mut ids = Vec::new();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        let logits = resp.logits().expect("backend must not error");
        assert_eq!(logits.len(), coord.classes);
        assert!(logits.iter().all(|v| v.is_finite()));
        assert!(resp.latency_us > 0.0);
        assert!(resp.batch >= 1 && resp.batch <= 8);
        ids.push(resp.id);
    }
    // every request answered exactly once
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n);
    let m = &coord.metrics;
    assert_eq!(m.requests() as usize, n);
    // a burst must produce some multi-request batches
    assert!(
        (m.batches() as usize) < n,
        "no batching happened: {} batches for {} requests",
        m.batches(),
        n
    );
    coord.shutdown().unwrap();
}

#[test]
fn rejects_wrong_input_length() {
    let Some(cfg) = cfg("dense") else { return };
    let coord = Coordinator::start(cfg).unwrap();
    assert!(coord.submit(vec![0.0; 3]).is_err());
    coord.shutdown().unwrap();
}

#[test]
fn sparse_variant_serves() {
    let Some(cfg) = cfg("sparse") else { return };
    let coord = Coordinator::start(cfg).unwrap();
    let resp = coord.infer(vec![0.2f32; coord.input_len]).unwrap();
    assert_eq!(resp.into_logits().unwrap().len(), 10);
    coord.shutdown().unwrap();
}

#[test]
fn shutdown_drains_pending() {
    let Some(cfg) = cfg("dense") else { return };
    let coord = Coordinator::start(cfg).unwrap();
    let mut rxs = Vec::new();
    for _ in 0..8 {
        rxs.push(coord.submit(vec![0.1f32; coord.input_len]).unwrap());
    }
    coord.shutdown().unwrap();
    // all pending requests either answered or their channel closed — but
    // none should hang
    let mut answered = 0;
    for rx in rxs {
        if rx.recv_timeout(std::time::Duration::from_secs(5)).is_ok() {
            answered += 1;
        }
    }
    assert!(answered >= 1, "shutdown dropped every pending request");
}

#[test]
fn unknown_model_fails_fast() {
    let Some(mut cfg) = cfg("dense") else { return };
    cfg.model = "nonexistent".into();
    assert!(Coordinator::start(cfg).is_err());
}

/// The `serves_burst_and_batches` invariant, artifact-free and with no
/// wall-clock dependence: a real lenet5 engine runs as the backend of
/// the virtual-clock simulator, a 24-request burst at t = 0 coalesces
/// into max-batch groups, and every request is answered exactly once.
#[test]
fn burst_batches_deterministically_on_the_virtual_clock() {
    let engine = Engine::native("lenet5").batch_sizes(&[1, 2, 4, 8]).build().unwrap();
    let input_len: usize = engine.input_shape().iter().product();
    let mut sim = SimServer::new();
    let qcfg = QueueConfig { max_batch: 8, max_wait_us: 1_000, ..QueueConfig::default() };
    sim.register_with_cost(
        "lenet5",
        Box::new(engine) as Box<dyn Backend>,
        qcfg,
        Box::new(|b| 500 + 250 * b as u64),
    )
    .unwrap();
    let mut rng = Rng::new(5);
    let n = 24;
    let rxs: Vec<_> = (0..n)
        .map(|_| {
            let mut img = vec![0.0f32; input_len];
            rng.fill_normal(&mut img, 0.5);
            sim.submit_at(0, ServeRequest::new("lenet5", img)).unwrap()
        })
        .collect();
    sim.run();
    let mut ids = Vec::new();
    for rx in rxs {
        let resp = rx.try_recv().unwrap();
        let logits = resp.logits().expect("backend must not error");
        assert_eq!(logits.len(), 10);
        assert!(logits.iter().all(|v| v.is_finite()));
        assert!(resp.latency_us > 0.0);
        assert!(resp.batch >= 1 && resp.batch <= 8);
        ids.push(resp.id);
    }
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n, "every request answered exactly once");
    let s = &sim.stats()["lenet5"];
    assert_eq!(s.requests as usize, n);
    assert_eq!(s.batches, 3, "a 24-burst at max_batch 8 forms exactly 3 full batches");
}
