//! Integration: always-on production tracing end to end. A served
//! workload with `--telemetry-out`-style export reconstructs every
//! request's full lifecycle (admit → request → batch → exec → kernel,
//! one trace id throughout) purely from the telemetry file; spans
//! recorded before `Server::shutdown` land in the final flush; an
//! unwritable export path degrades to a warning while serving
//! continues; the simulator drives the sampler deterministically across
//! seeds, and the tail keeper retains 100% of shed / deadline-miss
//! traces under 2× overload.
//!
//! The span recorder is process-global, so every test here holds `LOCK`
//! and starts from `obs::reset()`.

use cadnn::api::{Backend, Engine};
use cadnn::error::CadnnError;
use cadnn::obs::{self, SampleConfig, Sampler, Span};
use cadnn::obs::export::{read_telemetry, TelemetryLine};
use cadnn::serve::sim::SimServer;
use cadnn::serve::{QueueConfig, ServeRequest, Server, TelemetryConfig};
use cadnn::util::rng::Rng;
use std::sync::Mutex;

static LOCK: Mutex<()> = Mutex::new(());

fn serialize() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Unique scratch path per test (process id + name keeps parallel
/// `cargo test` invocations apart).
fn scratch(name: &str) -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("cadnn-telemetry-{}-{name}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&p);
    let _ = std::fs::remove_file(cadnn::obs::export::rotated_path(&p));
    p
}

/// All spans across every `spans` line in the telemetry file.
fn file_spans(lines: &[TelemetryLine]) -> Vec<Span> {
    lines
        .iter()
        .filter_map(|l| match l {
            TelemetryLine::Spans { spans, .. } => Some(spans.clone()),
            _ => None,
        })
        .flatten()
        .collect()
}

/// At `sample_rate = 1.0`, the telemetry file alone reconstructs every
/// request's lifecycle: a terminal `request` span with a non-zero trace
/// id per request, an `admit` span on the same trace, and exec +
/// kernel spans that inherited the trace through the thread-local
/// context. Also the shutdown-flush guarantee: all of this is recorded
/// *before* `Server::shutdown` returns, and the flusher's final drain —
/// which runs after the workers are joined — loses none of it.
#[test]
fn telemetry_file_reconstructs_full_request_lifecycles() {
    if !obs::COMPILED {
        return;
    }
    let _g = serialize();
    obs::reset();
    let path = scratch("lifecycle");
    let engine = Engine::native("lenet5").batch_sizes(&[1, 2, 4]).build().unwrap();
    let cfg = QueueConfig { max_batch: 4, max_wait_us: 1_000, ..QueueConfig::default() };
    let mut tcfg = TelemetryConfig::new(&path);
    tcfg.sample_rate = 1.0;
    // long period: the final shutdown flush must carry everything even
    // if no periodic flush ever ran
    tcfg.period_ms = 60_000;
    let server = Server::builder()
        .engine_with("m", &engine, cfg)
        .telemetry(tcfg)
        .build()
        .unwrap();
    let input_len = server.input_len("m").unwrap();

    let n = 8;
    let mut rxs = Vec::new();
    for _ in 0..n {
        rxs.push(server.submit(ServeRequest::new("m", vec![0.25f32; input_len])).unwrap());
    }
    let mut ids = Vec::new();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(resp.outcome.is_ok());
        ids.push(resp.id);
    }
    server.shutdown().unwrap();
    obs::disable();
    obs::reset();

    let (lines, malformed) = read_telemetry(&path).unwrap();
    assert_eq!(malformed, 0, "a clean shutdown writes whole lines only");
    let spans = file_spans(&lines);

    for id in &ids {
        let req: Vec<_> = spans
            .iter()
            .filter(|s| {
                s.cat == obs::CAT_SERVE
                    && s.name == "request"
                    && s.num_arg("id") == Some(*id as f64)
            })
            .collect();
        assert_eq!(req.len(), 1, "request {id}: exactly one terminal span in the file");
        let s = req[0];
        assert!(s.trace != 0, "request {id}: terminal span must carry a trace id");
        assert_eq!(s.str_arg("outcome"), Some("ok"));

        // the same trace joins admission to the terminal reply
        let trace = s.trace;
        assert!(
            spans
                .iter()
                .any(|x| x.trace == trace && x.cat == obs::CAT_SERVE && x.name == "admit"),
            "trace {trace}: admit span missing"
        );
    }
    // batch and exec spans are attributed to the *head* request's trace
    // (a batch serves many traces), so at least one request trace must
    // reconstruct all the way down into execution
    let full_lifecycles = spans
        .iter()
        .filter(|s| s.cat == obs::CAT_SERVE && s.name == "request")
        .filter(|s| {
            spans.iter().any(|x| x.trace == s.trace && x.cat == obs::CAT_SERVE && x.name == "batch")
                && spans.iter().any(|x| x.trace == s.trace && x.cat == obs::CAT_EXEC)
        })
        .count();
    assert!(
        full_lifecycles >= 1,
        "at least one trace must span admit → request → batch → exec"
    );
    // every distinct trace id is unique per request
    let mut traces: Vec<u64> = spans
        .iter()
        .filter(|s| s.cat == obs::CAT_SERVE && s.name == "request")
        .map(|s| s.trace)
        .collect();
    traces.sort_unstable();
    traces.dedup();
    assert_eq!(traces.len(), n, "one distinct trace per request");

    // execution inherited trace context: exec spans exist and every one
    // carries some admitted request's trace (batch heads), never 0
    let exec: Vec<_> = spans.iter().filter(|s| s.cat == obs::CAT_EXEC).collect();
    assert!(!exec.is_empty(), "exec spans must reach the telemetry file");
    assert!(exec.iter().all(|s| s.trace != 0), "exec spans inherit the head trace");
    // kernel-family spans ride the same context (lenet5's dense gemm
    // only fires above the parallel cutover, so tolerate absence, but
    // any present must be traced)
    assert!(spans.iter().filter(|s| s.cat == obs::CAT_KERNEL).all(|s| s.trace != 0));

    // snapshot lines carry the merged metrics the server reported
    let snap = lines.iter().rev().find_map(|l| match l {
        TelemetryLine::Snapshot { model, stats, .. } if model == "m" => Some(stats.clone()),
        _ => None,
    });
    let stats = snap.expect("final metrics snapshot line present");
    assert_eq!(stats.get("requests").and_then(|v| v.as_f64()), Some(n as f64));

    let _ = std::fs::remove_file(&path);
}

/// Export failure mode: an unwritable telemetry path warns once and
/// disables export — serving itself is completely unaffected.
#[test]
fn unwritable_telemetry_path_never_blocks_serving() {
    if !obs::COMPILED {
        return;
    }
    let _g = serialize();
    obs::reset();
    let engine = Engine::native("lenet5").batch_sizes(&[1, 2]).build().unwrap();
    let mut tcfg =
        TelemetryConfig::new("/nonexistent-dir-cadnn-telemetry/deep/t.jsonl");
    tcfg.period_ms = 10;
    let server = Server::builder()
        .engine_with("m", &engine, QueueConfig { max_batch: 2, ..QueueConfig::default() })
        .telemetry(tcfg)
        .build()
        .unwrap();
    let input_len = server.input_len("m").unwrap();
    for _ in 0..4 {
        let resp = server.infer(ServeRequest::new("m", vec![0.5f32; input_len])).unwrap();
        assert!(resp.outcome.is_ok(), "serving must survive a dead telemetry sink");
    }
    server.shutdown().unwrap();
    obs::disable();
    obs::reset();
}

/// With the recorder off and no telemetry configured, a served load
/// records zero spans — the always-on path costs nothing when it is
/// off.
#[test]
fn disabled_sampling_leaves_zero_spans() {
    if !obs::COMPILED {
        return;
    }
    let _g = serialize();
    obs::reset();
    obs::disable();
    let engine = Engine::native("lenet5").batch_sizes(&[1, 2]).build().unwrap();
    let server = Server::builder()
        .engine_with("m", &engine, QueueConfig { max_batch: 2, ..QueueConfig::default() })
        .build()
        .unwrap();
    let input_len = server.input_len("m").unwrap();
    for _ in 0..4 {
        let resp = server.infer(ServeRequest::new("m", vec![0.5f32; input_len])).unwrap();
        assert!(resp.outcome.is_ok());
    }
    server.shutdown().unwrap();
    assert!(obs::drain().is_empty(), "disabled recorder must stay empty under load");
    obs::reset();
}

// ---------------------------------------------------------------------
// simulator-driven sampler properties

/// Synthetic backend with an affine plan-cost model (the fleet-serving
/// test fixture): `cost_at(b) = overhead + per_image · b` plan units.
struct AffineBackend {
    batches: Vec<usize>,
    per_image: f64,
    overhead: f64,
}

impl Backend for AffineBackend {
    fn name(&self) -> &str {
        "affine"
    }
    fn input_shape(&self) -> &[usize] {
        &[2, 2, 1]
    }
    fn classes(&self) -> usize {
        4
    }
    fn batch_sizes(&self) -> Vec<usize> {
        self.batches.clone()
    }
    fn run_batch(&self, batch: usize, input: &[f32]) -> Result<Vec<f32>, CadnnError> {
        Ok(input[..batch * 4].to_vec())
    }
    fn plan_costs(&self) -> Vec<(usize, f64)> {
        self.batches
            .iter()
            .map(|&b| (b, self.overhead + self.per_image * b as f64))
            .collect()
    }
}

/// One seeded 2×-overload run on the virtual-clock simulator: returns
/// the drained spans plus the non-ok (shed / deadline-missed) request
/// ids. Request id == trace id in the sim, deterministically.
fn overload_run(seed: u64, n: u64) -> (Vec<Span>, Vec<u64>) {
    obs::reset();
    obs::enable();
    let mut sim = SimServer::new();
    let backend = AffineBackend { batches: vec![1, 2, 4, 8], per_image: 1_000.0, overhead: 100.0 };
    let cfg = QueueConfig { calibration: Some(1.0), ..QueueConfig::default() };
    sim.register("m", Box::new(backend), cfg).unwrap();
    // cheapest batch ≈ 1100µs/request; one arrival per ~550µs is 2×
    // capacity, with seeded jitter so every seed is a different trace
    let mut rng = Rng::new(seed);
    let mut at = 0u64;
    let rxs: Vec<_> = (0..n)
        .map(|_| {
            at += 300 + rng.below(500) as u64; // mean 550µs gap
            let deadline = 5_000 + rng.below(10_000) as u64;
            let req = ServeRequest::new("m", vec![0.5f32; 4]).deadline_us(deadline);
            sim.submit_at(at, req).unwrap()
        })
        .collect();
    sim.run();
    let mut non_ok = Vec::new();
    for rx in rxs {
        let resp = rx.try_recv().expect("every request is answered");
        if resp.outcome.is_err() {
            non_ok.push(resp.id);
        }
    }
    obs::disable();
    let spans = obs::drain();
    obs::reset();
    (spans, non_ok)
}

/// Kept trace-id set after streaming `spans` through a fresh sampler in
/// flush-sized chunks (mimicking the periodic flusher), including the
/// conservative shutdown flush.
fn sampled_traces(spans: &[Span], rate: f64) -> Vec<u64> {
    let mut sampler = Sampler::new(SampleConfig {
        rate,
        // disarm the p99 tail keeper: its decisions depend on drain
        // order, which wall-clock start stamps do not pin down — head
        // hash and outcome tail are the order-independent properties
        min_hist: u64::MAX,
        ..SampleConfig::default()
    });
    let mut kept = Vec::new();
    for chunk in spans.chunks(64) {
        kept.extend(sampler.filter(chunk.to_vec()));
    }
    kept.extend(sampler.finish());
    let mut traces: Vec<u64> = kept.iter().map(|s| s.trace).filter(|&t| t != 0).collect();
    traces.sort_unstable();
    traces.dedup();
    traces
}

/// 50-seed property: (a) identical sim runs produce identical sampling
/// decisions — trace ids come from the deterministic per-sim counter
/// and head sampling hashes only the trace id; (b) at head rate 0.0 the
/// tail keeper still retains **every** shed / deadline-missed trace of
/// a 2×-overloaded workload, and nothing else.
#[test]
fn fifty_seeds_sampling_is_deterministic_and_tail_captures_every_miss() {
    if !obs::COMPILED {
        return;
    }
    let _g = serialize();
    let mut any_shed = false;
    for seed in 0..50u64 {
        let (spans_a, non_ok_a) = overload_run(seed, 60);
        let (spans_b, non_ok_b) = overload_run(seed, 60);
        assert_eq!(non_ok_a, non_ok_b, "seed {seed}: sim outcomes must be identical");

        // (a) determinism of the sampler over the two identical runs
        let kept_a = sampled_traces(&spans_a, 0.25);
        let kept_b = sampled_traces(&spans_b, 0.25);
        assert_eq!(kept_a, kept_b, "seed {seed}: same run ⇒ same kept traces");

        // (b) tail-only sampling keeps exactly the non-ok traces
        let tail = sampled_traces(&spans_a, 0.0);
        let mut want = non_ok_a.clone();
        want.sort_unstable();
        assert_eq!(tail, want, "seed {seed}: tail keeper must capture every shed/miss");
        any_shed |= !want.is_empty();
    }
    assert!(any_shed, "the overload workload must actually shed somewhere");
}
