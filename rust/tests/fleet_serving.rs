//! Fleet-scale serving, proven on the deterministic virtual-clock
//! simulator (`serve::sim::SimServer`): admission control sheds at
//! enqueue exactly when the cost-model prediction says a deadline is
//! infeasible, admitted requests always finish within that prediction,
//! replica work stealing preserves FIFO prefixes, and the shed/served/
//! queue-miss counters exactly partition the offered load.
//!
//! Every assertion here is exact — no sleeps, no wall-clock tolerances.
//! The simulator prices batch execution at the same `plan units ×
//! us_per_unit` estimate the scheduler and admission controller use, and
//! all costs are chosen integral so the scheduler's EWMA sits at its
//! fixed point: estimates never drift, so `==` is sound.

use cadnn::api::Backend;
use cadnn::error::CadnnError;
use cadnn::serve::sim::{AdmitRecord, SimServer};
use cadnn::serve::{AdmissionConfig, AdmitDecision, QueueConfig, ServeError, ServeRequest};
use cadnn::util::prop::{check_n, CASES};
use cadnn::util::rng::Rng;
use cadnn::{prop_assert, prop_assert_eq};
use std::cell::Cell;

/// Synthetic backend with an affine plan-cost model:
/// `cost_at(b) = overhead + per_image · b` plan units.
struct AffineBackend {
    batches: Vec<usize>,
    per_image: f64,
    overhead: f64,
}

impl AffineBackend {
    fn new(batches: &[usize], per_image: f64, overhead: f64) -> AffineBackend {
        AffineBackend { batches: batches.to_vec(), per_image, overhead }
    }
}

impl Backend for AffineBackend {
    fn name(&self) -> &str {
        "affine"
    }
    fn input_shape(&self) -> &[usize] {
        &[2, 2, 1]
    }
    fn classes(&self) -> usize {
        4
    }
    fn batch_sizes(&self) -> Vec<usize> {
        self.batches.clone()
    }
    fn run_batch(&self, batch: usize, input: &[f32]) -> Result<Vec<f32>, CadnnError> {
        Ok(input[..batch * 4].to_vec())
    }
    fn plan_costs(&self) -> Vec<(usize, f64)> {
        self.batches
            .iter()
            .map(|&b| (b, self.overhead + self.per_image * b as f64))
            .collect()
    }
}

/// min/worst batch estimates in µs for an affine backend at `upu`.
fn estimates(b: &AffineBackend, upu: f64) -> (u64, u64) {
    let min_b = *b.batches.iter().min().unwrap() as f64;
    let max_b = *b.batches.iter().max().unwrap() as f64;
    let min = ((b.overhead + b.per_image * min_b) * upu).ceil() as u64;
    let worst = ((b.overhead + b.per_image * max_b) * upu).ceil() as u64;
    (min.max(1), worst)
}

fn predicted_of(rec: &AdmitRecord) -> u64 {
    match rec.decision {
        AdmitDecision::Admit { predicted_us, .. } => predicted_us,
        AdmitDecision::ShedDeadline { predicted_us } => predicted_us,
        AdmitDecision::Shed { predicted_us, .. } => predicted_us,
    }
}

/// The acceptance scenario from the issue: one model, offered load at 2×
/// the calibrated per-request capacity, 15ms deadlines. Admission sheds
/// the excess with early `ServeError::Deadline` answers at enqueue
/// (`waited_us == 0`), queue-expiry misses stay at exactly zero, and
/// every admitted request's measured latency is within the completion
/// estimate its own admission decision recorded — so the admitted p99 is
/// within the admission estimate by construction.
#[test]
fn overload_at_twice_capacity_sheds_early_and_admitted_p99_holds() {
    let mut sim = SimServer::new();
    let backend = AffineBackend::new(&[1, 2, 4, 8], 1_000.0, 100.0);
    let (min_est, worst) = estimates(&backend, 1.0);
    assert_eq!((min_est, worst), (1_100, 8_100));
    let cfg = QueueConfig { calibration: Some(1.0), ..QueueConfig::default() };
    sim.register("m", Box::new(backend), cfg).unwrap();

    // amortized capacity is one request per min_est = 1100µs; offer one
    // every 550µs = exactly 2× calibrated capacity
    let n = 300u64;
    let deadline_us = 15_000u64;
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let req = ServeRequest::new("m", vec![i as f32; 4]).deadline_us(deadline_us);
            sim.submit_at(i * 550, req).unwrap()
        })
        .collect();
    sim.run();

    let audit = sim.audit().to_vec();
    assert_eq!(audit.len() as u64, n, "every arrival gets an admission decision");
    let (mut ok, mut shed) = (0u64, 0u64);
    for (rx, rec) in rxs.iter().zip(&audit) {
        let resp = rx.try_recv().expect("every request is answered");
        match (&resp.outcome, &rec.decision) {
            (Ok(_), AdmitDecision::Admit { predicted_us, .. }) => {
                ok += 1;
                // the latency bound is per-request and exact: measured
                // completion never exceeds the admission estimate, so
                // p50 = p99 = max all sit within it
                assert!(
                    resp.latency_us <= *predicted_us as f64,
                    "admitted latency {} > predicted {}",
                    resp.latency_us,
                    predicted_us
                );
                assert!(*predicted_us <= deadline_us, "admit implies feasible");
            }
            (Err(ServeError::Deadline { deadline_us: d, waited_us }), dec) => {
                shed += 1;
                assert_eq!(*d, deadline_us);
                assert_eq!(*waited_us, 0, "shed at enqueue, before any queueing");
                assert_eq!(resp.batch, 0);
                assert!(
                    matches!(dec, AdmitDecision::ShedDeadline { .. }),
                    "early Deadline answers come only from admission: {dec:?}"
                );
                assert!(predicted_of(rec) > deadline_us, "shed implies infeasible");
            }
            (outcome, dec) => panic!("unexpected outcome {outcome:?} for decision {dec:?}"),
        }
    }

    let s = &sim.stats()["m"];
    assert!(shed > 0, "2× overload must shed");
    assert!(ok > 0, "admission keeps serving at capacity");
    assert_eq!(ok + shed, n, "shed + served exactly partition the offered load");
    assert_eq!(s.requests, ok);
    assert_eq!(s.shed_deadline, shed);
    assert_eq!((s.shed_quota, s.shed_backlog), (0, 0));
    assert_eq!(s.deadline_misses, 0, "admitted requests never expire in queue");
    assert_eq!(s.committed_us, 0, "every commitment released at reply");
}

/// Strictly-under-capacity traffic is never shed and never misses: with
/// arrival gaps ≥ one batching window plus the worst batch estimate, the
/// queue drains to empty between arrivals, so every prediction is the
/// empty-backlog `max_wait + worst` bound and every deadline ≥ that
/// bound is admitted and met.
#[test]
fn under_capacity_traffic_is_never_shed() {
    check_n("under-capacity no shed", CASES, |rng| {
        let (per_image, overhead) = (2 * rng.range(100, 900), 2 * rng.range(50, 400));
        let upu = [0.5, 1.0, 2.0][rng.below(3)];
        let backend = AffineBackend::new(&[1, 2, 4, 8], per_image as f64, overhead as f64);
        let (_, worst) = estimates(&backend, upu);
        let cfg = QueueConfig { calibration: Some(upu), ..QueueConfig::default() };
        let bound = cfg.max_wait_us + worst;
        let mut sim = SimServer::new();
        sim.register("m", Box::new(backend), cfg).unwrap();
        let n = (1 + rng.below(30)) as u64;
        let mut at = 0u64;
        let rxs: Vec<_> = (0..n)
            .map(|i| {
                let rx = sim
                    .submit_at(
                        at,
                        ServeRequest::new("m", vec![i as f32; 4])
                            .deadline_us(bound + rng.below(50_000) as u64),
                    )
                    .unwrap();
                at += bound + rng.below(5_000) as u64;
                rx
            })
            .collect();
        sim.run();
        for rx in &rxs {
            let resp = rx.try_recv().expect("answered");
            prop_assert!(resp.outcome.is_ok(), "under capacity, got {:?}", resp.outcome);
            prop_assert!(
                resp.latency_us <= bound as f64,
                "latency {} exceeds empty-backlog bound {bound}",
                resp.latency_us
            );
        }
        let s = &sim.stats()["m"];
        prop_assert_eq!(s.requests, n);
        prop_assert_eq!(s.shed_deadline + s.shed_quota + s.shed_backlog, 0);
        prop_assert_eq!(s.deadline_misses, 0);
        Ok(())
    });
}

/// Property (a) from the issue: **no admitted request ever misses a
/// deadline the admission controller called feasible**. Single replica,
/// integral costs (so estimates are exact), random load far past
/// saturation: every admitted request completes with latency ≤ the
/// `predicted_us` its own admission decision recorded, queue-expiry
/// misses are exactly zero, and the counters partition the offered load.
#[test]
fn prop_admitted_requests_meet_the_admission_prediction() {
    check_n("admitted never miss", 200, |rng| {
        let (per_image, overhead) = (2 * rng.range(100, 900), 2 * rng.range(50, 400));
        let upu = [0.5, 1.0, 2.0][rng.below(3)];
        let backend = AffineBackend::new(&[1, 2, 4, 8], per_image as f64, overhead as f64);
        let (min_est, worst) = estimates(&backend, upu);
        let cfg = QueueConfig {
            calibration: Some(upu),
            max_wait_us: [1_000, 2_000, 4_000][rng.below(3)],
            ..QueueConfig::default()
        };
        let mut sim = SimServer::new();
        sim.register("m", Box::new(backend), cfg).unwrap();
        let n = (10 + rng.below(40)) as u64;
        let mut at = 0u64;
        let rxs: Vec<_> = (0..n)
            .map(|i| {
                // mean gap ≈ min_est/2: ~2× overload, with bursts
                at += rng.below(min_est.max(2) as usize) as u64;
                let mut req = ServeRequest::new("m", vec![i as f32; 4]);
                if rng.below(4) > 0 {
                    // deadlines from hopeless to generous
                    req = req.deadline_us(cfg.max_wait_us + rng.below(4 * worst as usize) as u64);
                }
                sim.submit_at(at, req).unwrap()
            })
            .collect();
        sim.run();
        let audit = sim.audit().to_vec();
        prop_assert_eq!(audit.len() as u64, n);
        let mut served = 0u64;
        for (rx, rec) in rxs.iter().zip(&audit) {
            let resp = rx.try_recv().expect("answered");
            match rec.decision {
                AdmitDecision::Admit { predicted_us, .. } => {
                    served += 1;
                    prop_assert!(
                        resp.outcome.is_ok(),
                        "admitted id {} must be served, got {:?}",
                        rec.id,
                        resp.outcome
                    );
                    prop_assert!(
                        resp.latency_us <= predicted_us as f64,
                        "id {}: latency {} > predicted {}",
                        rec.id,
                        resp.latency_us,
                        predicted_us
                    );
                }
                AdmitDecision::ShedDeadline { .. } => {
                    prop_assert!(
                        matches!(
                            resp.outcome,
                            Err(ServeError::Deadline { waited_us: 0, .. })
                        ),
                        "shed id {} answered {:?}",
                        rec.id,
                        resp.outcome
                    );
                }
                AdmitDecision::Shed { .. } => {
                    prop_assert!(false, "no quota/backlog configured, got {:?}", rec.decision)
                }
            }
        }
        let s = &sim.stats()["m"];
        prop_assert_eq!(s.requests, served);
        prop_assert_eq!(s.requests + s.shed_deadline, n);
        prop_assert_eq!(s.deadline_misses, 0);
        prop_assert_eq!(s.committed_us, 0);
        Ok(())
    });
}

/// Property (b) from the issue: **work stealing never reorders a
/// replica's FIFO prefix**. With 2–3 replicas and bursty arrivals, the
/// requests a replica dispatched *and* executed itself (its FIFO prefix;
/// steals only ever remove the tail) execute in strictly increasing
/// submission order, and the partition invariant still holds.
#[test]
fn prop_work_stealing_preserves_fifo_prefixes() {
    let steals_seen = Cell::new(0u64);
    check_n("steal keeps FIFO prefix", 200, |rng| {
        let (per_image, overhead) = (2 * rng.range(100, 900), 2 * rng.range(50, 400));
        let backend = AffineBackend::new(&[1, 2, 4], per_image as f64, overhead as f64);
        let (min_est, _) = estimates(&backend, 1.0);
        let cfg = QueueConfig {
            calibration: Some(1.0),
            replicas: 2 + rng.below(2),
            max_batch: 4,
            ..QueueConfig::default()
        };
        let mut sim = SimServer::new();
        sim.register("m", Box::new(backend), cfg).unwrap();
        let n = (10 + rng.below(40)) as u64;
        let mut at = 0u64;
        let rxs: Vec<_> = (0..n)
            .map(|i| {
                // bursts of up to 6 at the same instant force imbalance,
                // long gaps let idle replicas steal
                if rng.below(6) == 0 {
                    at += min_est * rng.range(1, 4) as u64;
                }
                sim.submit_at(at, ServeRequest::new("m", vec![i as f32; 4])).unwrap()
            })
            .collect();
        sim.run();
        for rx in &rxs {
            prop_assert!(rx.try_recv().expect("answered").outcome.is_ok(), "no deadlines set");
        }
        // each replica's self-dispatched, self-executed stream is its
        // FIFO prefix: submission ids strictly increase
        for r in 0..cfg.replicas {
            let mut last = 0u64;
            for e in sim.exec_log().iter().filter(|e| e.dispatched == r && e.executed == r) {
                prop_assert!(
                    e.id > last,
                    "replica {r} executed id {} after {} — prefix reordered",
                    e.id,
                    last
                );
                last = e.id;
            }
        }
        let s = &sim.stats()["m"];
        steals_seen.set(steals_seen.get() + s.steals);
        prop_assert_eq!(s.requests, n);
        prop_assert_eq!(s.replicas, cfg.replicas as u64);
        prop_assert_eq!(sim.exec_log().len() as u64, n);
        prop_assert_eq!(s.committed_us, 0);
        Ok(())
    });
    assert!(steals_seen.get() > 0, "200 bursty cases must exercise work stealing");
}

/// Property (c) from the issue: **shed + served + queue-miss counts
/// exactly partition the offered load**, under random per-model quotas
/// and a random global backlog cap, across two models sharing the
/// budget. Commitments are always fully released.
#[test]
fn prop_counters_partition_offered_load_under_quotas() {
    check_n("counters partition load", 200, |rng| {
        let admission = AdmissionConfig {
            enabled: true,
            max_backlog_us: if rng.below(2) == 0 {
                Some(rng.range(2_000, 30_000) as u64)
            } else {
                None
            },
        };
        let mut sim = SimServer::with_admission(admission);
        let names = ["a", "b"];
        let mut min_ests = [0u64; 2];
        for (i, name) in names.iter().enumerate() {
            let (per_image, overhead) = (2 * rng.range(100, 900), 2 * rng.range(50, 400));
            let backend = AffineBackend::new(&[1, 2, 4, 8], per_image as f64, overhead as f64);
            min_ests[i] = estimates(&backend, 1.0).0;
            let cfg = QueueConfig {
                calibration: Some(1.0),
                quota_us: if rng.below(2) == 0 {
                    Some(rng.range(1_000, 20_000) as u64)
                } else {
                    None
                },
                replicas: 1 + rng.below(2),
                ..QueueConfig::default()
            };
            sim.register(*name, Box::new(backend), cfg).unwrap();
        }
        let mut offered = [0u64; 2];
        let mut at = 0u64;
        let n = 20 + rng.below(60);
        let rxs: Vec<_> = (0..n)
            .map(|i| {
                let m = rng.below(2);
                offered[m] += 1;
                at += rng.below(min_ests[m].max(2) as usize) as u64;
                let mut req = ServeRequest::new(names[m], vec![i as f32; 4]);
                if rng.below(3) == 0 {
                    req = req.deadline_us(rng.range(1_000, 60_000) as u64);
                }
                sim.submit_at(at, req).unwrap()
            })
            .collect();
        sim.run();
        for rx in &rxs {
            rx.try_recv().expect("every request is answered exactly once");
        }
        let stats = sim.stats();
        for (i, name) in names.iter().enumerate() {
            let s = &stats[*name];
            let answered = s.requests
                + s.shed_deadline
                + s.shed_quota
                + s.shed_backlog
                + s.deadline_misses
                + s.backend_errors;
            prop_assert!(
                answered == offered[i],
                "model {name}: served {} + shed ({},{},{}) + missed {} + errors {} != offered {}",
                s.requests,
                s.shed_deadline,
                s.shed_quota,
                s.shed_backlog,
                s.deadline_misses,
                s.backend_errors,
                offered[i]
            );
            prop_assert_eq!(s.committed_us, 0);
            if let (Some(q), Some(u)) = (s.quota_us, s.quota_utilization) {
                prop_assert!(q > 0 && u == 0.0, "drained quota shows zero utilization");
            }
        }
        Ok(())
    });
}

/// Disabling admission restores the pre-admission behavior: nothing is
/// shed at enqueue, infeasible requests expire in the queue instead, and
/// the taxonomy splits the two miss shapes (shed vs queue expiry).
#[test]
fn disabled_admission_shifts_sheds_into_queue_expiries() {
    let run = |enabled: bool| {
        let mut sim =
            SimServer::with_admission(AdmissionConfig { enabled, max_backlog_us: None });
        let backend = AffineBackend::new(&[1, 2, 4, 8], 1_000.0, 100.0);
        let cfg = QueueConfig { calibration: Some(1.0), ..QueueConfig::default() };
        sim.register("m", Box::new(backend), cfg).unwrap();
        for i in 0..40u64 {
            // 4× overload with a deadline only the first few can meet
            sim.submit_at(i * 275, ServeRequest::new("m", vec![0.0; 4]).deadline_us(12_000))
                .unwrap();
        }
        sim.run();
        sim.stats()["m"].clone()
    };
    let on = run(true);
    assert!(on.shed_deadline > 0, "admission sheds the infeasible tail");
    assert_eq!(on.deadline_misses, 0, "and nothing admitted ever expires");
    assert_eq!(on.requests + on.shed_deadline, 40);

    let off = run(false);
    assert_eq!(off.shed_total(), 0, "no admission, no sheds");
    assert!(off.deadline_misses > 0, "the same overload now dies in the queue");
    assert_eq!(off.requests + off.deadline_misses, 40);
}
