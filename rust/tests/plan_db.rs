//! Durability and determinism of the persistent plan database
//! (`planner::db` + `planner::search`), through the public API only:
//!
//! - corrupt input (truncation at every byte, junk-byte corpora,
//!   version bumps) loads as a cold database with a warning — never a
//!   panic, never a build failure (mirrors the `model_ir.rs` fuzz
//!   discipline);
//! - a warm database returns exactly the plan a cold search would have
//!   produced (200-seed property test);
//! - the tentpole acceptance criteria: a warm-database replan of
//!   ResNet-50 performs zero measurements and yields a bit-identical
//!   `ExecPlan`, and the searched plan's modeled cost never exceeds the
//!   heuristic plan's on any builtin model.

use cadnn::api::Engine;
use cadnn::compress::csr::CsrMatrix;
use cadnn::compress::profile::paper_profile;
use cadnn::exec::Personality;
use cadnn::front;
use cadnn::ir::ops::Op;
use cadnn::models;
use cadnn::planner::db::{
    spec_seed, CostTable, PlanDb, Provenance, SpecKey, StoredCandidate, TOP_K,
};
use cadnn::planner::search::search_layer;
use cadnn::planner::{plan_layer_valued, FormatPolicy, LayerPlan, PlanCache, ValuePolicy};
use cadnn::util::rng::Rng;

fn tmp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("cadnn_plandb_it_{tag}_{}.json", std::process::id()))
}

/// A small random CSR support (via dense round trip: sorted, unique
/// column indices per row come for free).
fn random_csr(rows: usize, cols: usize, density: f64, rng: &mut Rng) -> CsrMatrix {
    let mut dense = vec![0.0f32; rows * cols];
    for v in dense.iter_mut() {
        if rng.f64() < density {
            *v = rng.normal() as f32 * 0.5 + 0.01;
        }
    }
    // guarantee at least one stored value so the layer is plannable
    dense[0] = 1.0;
    CsrMatrix::from_dense(&dense, rows, cols)
}

/// Direct CSR synthesis for shapes too large to materialize densely
/// (vgg16's fc layers): `per_row` sorted unique columns per row, nnz
/// capped so no builtin layer costs minutes to price.
fn synth_csr(rows: usize, cols: usize, nnz_cap: usize, rng: &mut Rng) -> CsrMatrix {
    let per_row = (nnz_cap / rows.max(1)).clamp(1, cols);
    let stride = (cols / per_row).max(1);
    let mut row_ptr = Vec::with_capacity(rows + 1);
    let mut col_idx = Vec::with_capacity(rows * per_row);
    let mut values = Vec::with_capacity(rows * per_row);
    row_ptr.push(0u32);
    for _ in 0..rows {
        for j in 0..per_row {
            let c = (j * stride + rng.below(stride)).min(cols - 1);
            col_idx.push(c as u32);
            values.push(rng.normal() as f32 * 0.5 + 0.01);
        }
        row_ptr.push(col_idx.len() as u32);
    }
    CsrMatrix { rows, cols, row_ptr, col_idx, values }
}

/// A database with real searched content to corrupt: a few layer specs,
/// each holding its search's ranked candidates.
fn seeded_db_text() -> String {
    let mut db = PlanDb::in_memory();
    let mut cache = PlanCache::default();
    let mut rng = Rng::new(41);
    for i in 0..3u64 {
        let csr = random_csr(48, 32, 0.12, &mut rng);
        let hwio = [4, 3, 4, 32];
        let spec = SpecKey::from_layer(
            FormatPolicy::Auto,
            ValuePolicy::Auto,
            None,
            &csr,
            hwio,
            db.device_fp(),
        );
        let arts = cache.layer(&format!("l{i}"), &csr);
        let out = search_layer(
            FormatPolicy::Auto,
            ValuePolicy::Auto,
            None,
            &csr,
            64,
            hwio,
            &CostTable::builtin(),
            &[],
            false,
            spec.seed(),
            arts,
        );
        db.insert(spec, out.candidates, Provenance::Modeled);
    }
    assert_eq!(db.len(), 3);
    db.to_json().to_string_pretty()
}

/// Truncating the file at EVERY byte offset must yield a clean parse
/// error (or, for a pure trailing-whitespace cut, the full database) —
/// never a panic, never a partial load.
#[test]
fn truncation_at_every_byte_loads_cold_or_complete() {
    let text = seeded_db_text();
    let full = PlanDb::load_str(&text).expect("untruncated text loads");
    assert_eq!(full.len(), 3);
    for i in 0..text.len() {
        let Some(prefix) = text.get(..i) else { continue };
        match PlanDb::load_str(prefix) {
            Err(_) => {}
            Ok(db) => {
                assert!(
                    text[i..].trim().is_empty(),
                    "byte {i}/{}: truncated text parsed as a database",
                    text.len()
                );
                assert_eq!(db.len(), full.len());
            }
        }
    }
    // the same truncations through the file path degrade, never panic
    let path = tmp("trunc");
    for i in [0, 1, text.len() / 2, text.len() - 1] {
        std::fs::write(&path, &text.as_bytes()[..i]).unwrap();
        let db = PlanDb::open(&path);
        assert!(db.degraded().is_some(), "byte {i}: truncated file must degrade");
        assert!(db.is_empty(), "byte {i}: degraded database starts cold");
    }
    std::fs::remove_file(&path).ok();
}

/// Random junk bytes: the loader rejects them with an error; the file
/// path degrades cold with a warning — whatever the bytes contain.
#[test]
fn junk_bytes_degrade_to_cold() {
    let mut rng = Rng::new(7);
    let path = tmp("junk");
    for case in 0..64 {
        let len = rng.range(1, 512);
        let bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        let text = String::from_utf8_lossy(&bytes).into_owned();
        assert!(PlanDb::load_str(&text).is_err(), "case {case}: junk must not load");
        std::fs::write(&path, &bytes).unwrap();
        let db = PlanDb::open(&path);
        assert!(db.degraded().is_some(), "case {case}: junk file must degrade");
        assert!(db.is_empty());
    }
    std::fs::remove_file(&path).ok();
}

/// A future format version is not migrated — it degrades cold (old
/// binaries never misread new files).
#[test]
fn version_bump_invalidates_the_whole_file() {
    let text = seeded_db_text();
    assert!(text.contains("\"cadnn_plandb\": 1"), "version key missing from serialization");
    let bumped = text.replace("\"cadnn_plandb\": 1", "\"cadnn_plandb\": 2");
    let err = PlanDb::load_str(&bumped).unwrap_err();
    assert!(err.contains("version"), "{err}");
    let path = tmp("vbump");
    std::fs::write(&path, &bumped).unwrap();
    let db = PlanDb::open(&path);
    assert!(db.degraded().is_some() && db.is_empty());
    std::fs::remove_file(&path).ok();
}

/// Candidates beyond [`TOP_K`] are evicted from the tail: the ranked
/// order the search supplied is preserved, the overflow dropped — and
/// the order survives the JSON round trip.
#[test]
fn top_k_eviction_drops_the_tail_in_order() {
    let mut rng = Rng::new(11);
    let csr = random_csr(16, 16, 0.3, &mut rng);
    let mut db = PlanDb::in_memory();
    let spec = SpecKey::from_layer(
        FormatPolicy::Auto,
        ValuePolicy::Auto,
        None,
        &csr,
        [1, 1, 16, 16],
        db.device_fp(),
    );
    // 2*TOP_K candidates, distinct identities (cutover), ascending cost
    let cands: Vec<StoredCandidate> = (0..2 * TOP_K)
        .map(|i| {
            let mut plan = LayerPlan::csr();
            plan.parallel_cutover = 100 + i;
            StoredCandidate { plan, cost: 10.0 + i as f64, measured_us: None }
        })
        .collect();
    db.insert(spec, cands.clone(), Provenance::Modeled);
    let kept = db.seed_plans(&spec);
    assert_eq!(kept.len(), TOP_K, "eviction keeps exactly TOP_K");
    for (i, plan) in kept.iter().enumerate() {
        assert_eq!(plan.parallel_cutover, 100 + i, "rank {i} must keep supplied order");
    }
    let mut back = PlanDb::load_str(&db.to_json().to_string_pretty()).unwrap();
    assert_eq!(back.seed_plans(&spec), kept, "ranking survives the round trip");
    assert_eq!(back.best_plan(&spec).unwrap(), kept[0]);
}

/// 200-seed property: a warm database (after a full JSON round trip)
/// returns exactly the plan the cold search produced, over random
/// shapes, sparsities, policies, and value widths.
#[test]
fn warm_db_returns_the_cold_search_plan_200_seeds() {
    let policies =
        [FormatPolicy::Auto, FormatPolicy::Csr, FormatPolicy::Bsr, FormatPolicy::Pattern];
    let vpolicies = [ValuePolicy::Auto, ValuePolicy::F32, ValuePolicy::Q8, ValuePolicy::Q4];
    let mut db = PlanDb::in_memory();
    let mut cache = PlanCache::default();
    let mut cases = Vec::new();
    for seed in 0..200u64 {
        let mut rng = Rng::new(seed * 7919 + 1);
        let (kh, kw) = ([1usize, 3, 5][rng.below(3)], [1usize, 3][rng.below(2)]);
        let cin = rng.range(2, 12);
        let cout = rng.range(8, 48);
        let hwio = [kh, kw, cin, cout];
        let csr = random_csr(kh * kw * cin, cout, 0.05 + rng.f64() * 0.3, &mut rng);
        let m = rng.range(1, 256);
        let policy = policies[rng.below(4)];
        let vp = vpolicies[rng.below(4)];
        let declared = [None, Some(4u8), Some(8u8)][rng.below(3)];
        let spec = SpecKey::from_layer(policy, vp, declared, &csr, hwio, db.device_fp());
        let arts = cache.layer(&format!("case{seed}"), &csr);
        let out = search_layer(
            policy,
            vp,
            declared,
            &csr,
            m,
            hwio,
            &CostTable::builtin(),
            &[],
            false,
            spec.seed(),
            arts,
        );
        let best = out.best().expect("nonempty search").plan.clone();
        db.insert(spec, out.candidates, Provenance::Modeled);
        cases.push((spec, best));
    }
    // the round trip is the "next process": serialize, reload, look up
    let mut warm = PlanDb::load_str(&db.to_json().to_string_pretty()).unwrap();
    for (i, (spec, cold)) in cases.iter().enumerate() {
        let got = warm.best_plan(spec);
        assert_eq!(got.as_ref(), Some(cold), "seed {i}: warm lookup diverged from cold search");
    }
}

/// Acceptance: on every builtin model, for every prunable layer shape
/// (paper-profile sparsity, nnz capped at 2M for the vgg16 fc giants),
/// the searched plan's modeled cost is <= the heuristic plan's.
#[test]
fn searched_cost_never_exceeds_heuristic_on_every_builtin() {
    let mut cache = PlanCache::default();
    let mut rng = Rng::new(3);
    let mut checked = 0usize;
    for name in models::all_names() {
        let g = models::build(name, 1).unwrap();
        let profile = paper_profile(&g);
        for node in &g.nodes {
            let Some(&sparsity) = profile.layers.get(&node.name) else { continue };
            let (rows, cols, hwio, m) = match node.op {
                Op::Conv2d { kh, kw, cin, cout, .. } => {
                    let m = node.shape.0.get(1).copied().unwrap_or(1)
                        * node.shape.0.get(2).copied().unwrap_or(1);
                    (kh * kw * cin, cout, [kh, kw, cin, cout], m)
                }
                Op::FullyConnected { cin, cout, .. } => (cin, cout, [1, 1, cin, cout], 1),
                _ => continue,
            };
            let dense_nnz = ((rows * cols) as f64 * (1.0 - sparsity)).ceil() as usize;
            let csr = synth_csr(rows, cols, dense_nnz.clamp(1, 2_000_000), &mut rng);
            let key = format!("{name}/{}", node.name);
            let heuristic = {
                let arts = cache.layer(&key, &csr);
                plan_layer_valued(FormatPolicy::Auto, ValuePolicy::Auto, None, &csr, m, hwio, arts)
            };
            let arts = cache.layer(&key, &csr);
            let out = search_layer(
                FormatPolicy::Auto,
                ValuePolicy::Auto,
                None,
                &csr,
                m,
                hwio,
                &CostTable::builtin(),
                &[],
                false,
                spec_seed(FormatPolicy::Auto, ValuePolicy::Auto, None, &csr, hwio),
                arts,
            );
            let searched = out.best().expect("search never returns empty for nnz > 0");
            assert!(
                searched.cost <= heuristic.cost_per_row + 1e-9,
                "{key}: searched {:.3} (fmt {}) > heuristic {:.3} (fmt {})",
                searched.cost,
                searched.plan.format.label(),
                heuristic.cost_per_row,
                heuristic.format.label()
            );
            checked += 1;
        }
    }
    assert!(checked >= 50, "expected dozens of prunable layers, checked {checked}");
}

/// The tentpole acceptance test at full scale: plan-database semantics
/// through the engine API on the real ResNet-50 model file (modeled
/// search — the measured `--tune` variant of the same double-run is the
/// release-mode CI smoke, where kernel timing is affordable). The cold
/// build searches every pruned layer and persists; the warm rebuild
/// answers 100% from the database with zero searches, zero measurements,
/// and reproduces the `ExecPlan` bit-for-bit (JSON string equality).
#[test]
fn resnet50_warm_replan_zero_measurements_bit_identical() {
    let model = format!("{}/models/resnet50.cadnn", env!("CARGO_MANIFEST_DIR"));
    let parsed = front::parse_file(&model).expect("golden resnet50 model parses");
    let profile = paper_profile(&parsed.graph);
    let dbf = tmp("resnet50");
    std::fs::remove_file(&dbf).ok();
    let build = || {
        Engine::from_model_file(&model)
            .personality(Personality::CadnnSparse)
            .sparsity_profile(profile.clone())
            .batch_sizes(&[1])
            .plan_db(dbf.to_str().unwrap())
            .build()
            .unwrap()
    };
    let cold = build();
    let cs = cold.tune_stats().expect("native engines report tune stats");
    assert!(cs.searched > 0, "cold build must search: {cs:?}");
    assert_eq!(cs.measurements, 0, "modeled search must not measure: {cs:?}");
    let warm = build();
    std::fs::remove_file(&dbf).ok();
    let ws = warm.tune_stats().unwrap();
    assert_eq!(ws.measurements, 0, "warm replan must not measure: {ws:?}");
    assert_eq!(ws.searched, 0, "warm replan must not search: {ws:?}");
    assert_eq!(ws.db_hits, ws.requests, "100% database hits: {ws:?}");
    assert!(ws.requests > 0, "resnet50 must have pruned layers to plan");
    let a = cold.exec_plan().expect("pruned engine has a plan").to_json().to_string_pretty();
    let b = warm.exec_plan().unwrap().to_json().to_string_pretty();
    assert_eq!(a, b, "warm ExecPlan must be bit-identical to the cold run's");
}

/// The measured (`--tune`) half of the acceptance, on a model small
/// enough to time kernels in a debug-build test: the cold tuned build
/// measures the beam; the warm rebuild replays the *measured* winners
/// with zero measurements and a bit-identical `ExecPlan` — timing noise
/// only ever existed in the run that wrote the database.
#[test]
fn lenet5_measured_tune_warm_replay_is_bit_identical() {
    let g = models::build("lenet5", 1).expect("builtin lenet5");
    let profile = paper_profile(&g);
    let dbf = tmp("lenet5");
    std::fs::remove_file(&dbf).ok();
    let build = || {
        Engine::native("lenet5")
            .personality(Personality::CadnnSparse)
            .sparsity_profile(profile.clone())
            .batch_sizes(&[1])
            .tune_plans(true)
            .plan_db(dbf.to_str().unwrap())
            .build()
            .unwrap()
    };
    let cold = build();
    let cs = cold.tune_stats().unwrap();
    assert!(cs.searched > 0, "cold tuned build must search: {cs:?}");
    assert!(cs.measurements > 0, "tuning must measure kernels: {cs:?}");
    let warm = build();
    std::fs::remove_file(&dbf).ok();
    let ws = warm.tune_stats().unwrap();
    assert_eq!(ws.measurements, 0, "warm replay must not measure: {ws:?}");
    assert_eq!(ws.searched, 0, "warm replay must not search: {ws:?}");
    assert_eq!(ws.db_hits, ws.requests, "100% database hits: {ws:?}");
    let a = cold.exec_plan().unwrap().to_json().to_string_pretty();
    let b = warm.exec_plan().unwrap().to_json().to_string_pretty();
    assert_eq!(a, b, "warm ExecPlan must replay the measured winners bit-for-bit");
}
