//! Integration: the serving coordinator over the *native* backend — no
//! artifacts directory, no PJRT. The `Backend` trait is the seam: the
//! same queue/batcher/metrics path that serves AOT artifacts serves
//! `ModelInstance`s built in-process, and backend failures surface as
//! explicit error responses (distinct from shutdown, which closes the
//! reply channel).

use cadnn::api::{Backend, Engine};
use cadnn::compress::profile::paper_profile;
use cadnn::coordinator::{BatchPolicy, BatcherConfig, Coordinator, ServeError};
use cadnn::error::CadnnError;
use cadnn::exec::Personality;
use cadnn::models;
use cadnn::util::rng::Rng;

fn batcher() -> BatcherConfig {
    BatcherConfig { max_batch: 4, max_wait_us: 1_000, policy: BatchPolicy::PadToFit }
}

#[test]
fn coordinator_serves_native_engine_end_to_end() {
    let engine = Engine::native("lenet5").batch_sizes(&[1, 2, 4]).build().unwrap();
    let coord = Coordinator::serve_engine(&engine, batcher()).unwrap();
    assert_eq!(coord.input_len, 28 * 28);
    assert_eq!(coord.classes, 10);

    let mut rng = Rng::new(3);
    let n = 16;
    let mut rxs = Vec::new();
    for _ in 0..n {
        let mut img = vec![0.0f32; coord.input_len];
        rng.fill_normal(&mut img, 0.5);
        rxs.push(coord.submit(img).unwrap());
    }
    let mut ids = Vec::new();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        let logits = resp.logits().expect("native backend must not error");
        assert_eq!(logits.len(), 10);
        assert!(logits.iter().all(|v| v.is_finite()));
        let s: f32 = logits.iter().sum();
        assert!((s - 1.0).abs() < 1e-3, "softmax row sums to {s}");
        assert!(resp.batch >= 1 && resp.batch <= 4);
        ids.push(resp.id);
    }
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n, "every request answered exactly once");

    let m = &coord.metrics;
    assert_eq!(m.requests() as usize, n);
    assert_eq!(m.backend_errors(), 0);
    // a burst must produce some multi-request batches
    assert!((m.batches() as usize) < n, "no batching: {} batches / {n} requests", m.batches());
    coord.shutdown().unwrap();
}

#[test]
fn native_responses_match_direct_session_runs() {
    // what the coordinator serves must be exactly what a session computes
    let engine = Engine::native("lenet5").batch_sizes(&[1, 2]).build().unwrap();
    let mut session = engine.session();
    let img: Vec<f32> = (0..28 * 28).map(|i| ((i % 13) as f32) / 13.0).collect();
    let direct = session.run(&img).unwrap();

    let coord = Coordinator::serve_engine(&engine, batcher()).unwrap();
    let resp = coord.infer(img).unwrap();
    let served = resp.into_logits().unwrap();
    let d = direct
        .iter()
        .zip(&served)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(d < 1e-5, "served logits diverge from session: {d}");
    coord.shutdown().unwrap();
}

#[test]
fn sparse_native_engine_serves() {
    let g = models::build("lenet5", 1).unwrap();
    let engine = Engine::native("lenet5")
        .personality(Personality::CadnnSparse)
        .sparsity_profile(paper_profile(&g))
        .batch_sizes(&[1, 2])
        .build()
        .unwrap();
    let coord = Coordinator::serve_engine(&engine, batcher()).unwrap();
    let resp = coord.infer(vec![0.2f32; coord.input_len]).unwrap();
    assert_eq!(resp.into_logits().unwrap().len(), 10);
    coord.shutdown().unwrap();
}

/// A backend that always fails, to prove the error-response contract.
struct FailingBackend {
    shape: Vec<usize>,
}

impl Backend for FailingBackend {
    fn name(&self) -> &str {
        "failing"
    }
    fn input_shape(&self) -> &[usize] {
        &self.shape
    }
    fn classes(&self) -> usize {
        10
    }
    fn batch_sizes(&self) -> Vec<usize> {
        vec![1, 4]
    }
    fn run_batch(&self, _batch: usize, _input: &[f32]) -> Result<Vec<f32>, CadnnError> {
        Err(CadnnError::execution("injected failure"))
    }
}

#[test]
fn backend_errors_reach_clients_as_explicit_responses() {
    let coord = Coordinator::serve_with(
        || {
            let b: Box<dyn Backend> = Box::new(FailingBackend { shape: vec![4, 4, 1] });
            Ok(b)
        },
        batcher(),
    )
    .unwrap();
    let mut rxs = Vec::new();
    for _ in 0..3 {
        rxs.push(coord.submit(vec![0.5f32; 16]).unwrap());
    }
    for rx in rxs {
        // the channel must NOT close (that would mean shutdown); clients
        // get a typed backend-error outcome instead
        let resp = rx.recv().expect("reply channel closed on backend error");
        match resp.outcome {
            Err(ServeError::Backend(msg)) => {
                assert!(msg.contains("injected failure"), "{msg}");
            }
            Err(other) => panic!("expected Backend error, got {other:?}"),
            Ok(_) => panic!("failing backend produced logits"),
        }
    }
    let m = &coord.metrics;
    assert_eq!(m.backend_errors(), 3);
    assert_eq!(m.requests(), 0, "failed requests must not count as served");
    coord.shutdown().unwrap();
}

#[test]
fn coordinator_rejects_wrong_native_input_length() {
    let engine = Engine::native("lenet5").build().unwrap();
    let coord = Coordinator::serve_engine(&engine, batcher()).unwrap();
    assert!(coord.submit(vec![0.0; 3]).is_err());
    coord.shutdown().unwrap();
}

#[test]
fn engine_factory_failure_surfaces_at_start() {
    let result = Coordinator::serve_with(
        || Err(CadnnError::BackendUnavailable { backend: "test".into(), reason: "nope".into() }),
        batcher(),
    );
    let e = result.err().expect("factory failure must fail start");
    assert!(e.to_string().contains("nope"), "{e}");
}
