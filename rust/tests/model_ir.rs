//! End-to-end coverage of the textual model IR front-end through the
//! public API: the golden `models/*.cadnn` files are bit-identical to
//! what the canonical printer emits for the builtin builders, every
//! builtin round-trips through text, print→parse→print is a fixpoint on
//! randomly generated graphs, malformed input always yields a
//! positioned `CadnnError::Parse` (never a panic), and a `.cadnn` file
//! alone is a complete input to the compress → plan → serve pipeline.

use cadnn::api::Engine;
use cadnn::compress::profile::{PruneStructure, SparsityProfile};
use cadnn::error::CadnnError;
use cadnn::exec::Personality;
use cadnn::front;
use cadnn::ir::ops::{ActKind, Op, PoolKind};
use cadnn::ir::{Graph, Shape};
use cadnn::models;
use cadnn::planner::SparseFormat;
use cadnn::util::rng::Rng;

const GOLDEN: [&str; 4] = ["lenet5", "mobilenet_v1", "resnet50", "inception_v3"];

fn golden_path(name: &str) -> String {
    format!("{}/models/{name}.cadnn", env!("CARGO_MANIFEST_DIR"))
}

/// The checked-in `.cadnn` files ARE the printer's output for the
/// builtin builders — byte for byte. Regenerate with
/// `front::print(&models::build(name, 1).unwrap())` if an op's surface
/// syntax changes; any drift between builders, printer, and goldens
/// fails here first.
#[test]
fn golden_files_are_bit_identical_to_builders() {
    for name in GOLDEN {
        let g = models::build(name, 1).unwrap();
        let text = front::print(&g);
        let file = std::fs::read_to_string(golden_path(name))
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(text, file, "{name}: golden file drifted from printer output");
    }
}

/// Parsing a golden file reconstructs the builder's graph node-for-node
/// (names, ops, wiring, shapes — `Graph` equality is structural).
#[test]
fn golden_files_parse_back_to_the_builders() {
    for name in GOLDEN {
        let parsed = front::parse_file(&golden_path(name))
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(parsed.graph, models::build(name, 1).unwrap(), "{name}");
        assert!(parsed.profile.is_empty(), "{name}: goldens carry no hints");
    }
}

/// Every builtin — including the four without golden files — survives a
/// full print → parse round trip, and the reprint is a fixpoint.
#[test]
fn every_builtin_round_trips_through_text() {
    let all = models::EVAL_MODELS.iter().chain(models::COMPRESS_MODELS.iter());
    for name in all {
        let g = models::build(name, 1).unwrap();
        let text = front::print(&g);
        let parsed = front::parse(&text).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(parsed.graph, g, "{name}: round trip changed the graph");
        assert_eq!(front::print(&parsed.graph), text, "{name}: print not a fixpoint");
    }
}

/// Random chain CNN over the user-facing op vocabulary: conv blocks
/// (incl. asymmetric kernels, bias, grouped), depthwise blocks, pools,
/// residual adds, concat branches, and both flatten+fc and gap+fc
/// tails. Every graph it returns passes `Graph::validate`.
fn random_graph(case: u64, rng: &mut Rng) -> Graph {
    let h = [8usize, 10, 12, 16][rng.below(4)];
    let c0 = [2usize, 3, 4, 8][rng.below(4)];
    let mut g = Graph::new(&format!("rand{case}"), Shape::nhwc(1, h, h, c0));
    let mut x = 0usize;
    let mut cin = c0;
    let layers = rng.range(2, 7);
    for i in 0..layers {
        match rng.below(7) {
            // conv (+ optional bn+act), sometimes asymmetric / biased
            0 | 1 => {
                let cout = [4usize, 8, 12, 16][rng.below(4)];
                let (k, s, p): (usize, usize, usize) =
                    [(1, 1, 0), (3, 1, 1), (3, 2, 1), (5, 1, 2)][rng.below(4)];
                if g.node(x).shape.h() + 2 * p < k {
                    continue;
                }
                let op = match rng.below(3) {
                    0 => Op::conv(k, k, cin, cout, s, p),
                    1 => Op::conv_b(k, k, cin, cout, s, p),
                    _ => Op::conv_asym(1, k, cin, cout, s, 0, p),
                };
                let c = g.add(format!("l{i}_conv"), op, vec![x]);
                let b = g.add(format!("l{i}_bn"), Op::BatchNorm { c: cout }, vec![c]);
                let kind = [ActKind::Relu, ActKind::Relu6][rng.below(2)];
                x = g.add(format!("l{i}_act"), Op::Activation { kind }, vec![b]);
                cin = cout;
            }
            // depthwise block
            2 => {
                let stride = 1 + rng.below(2);
                let d = g.add(
                    format!("l{i}_dw"),
                    Op::DepthwiseConv2d { kh: 3, kw: 3, c: cin, stride, padding: 1 },
                    vec![x],
                );
                let b = g.add(format!("l{i}_dw_bn"), Op::BatchNorm { c: cin }, vec![d]);
                x = g.add(
                    format!("l{i}_dw_act"),
                    Op::Activation { kind: ActKind::Relu },
                    vec![b],
                );
            }
            // pool
            3 => {
                if g.node(x).shape.h() < 2 {
                    continue;
                }
                let kind = [PoolKind::Max, PoolKind::Avg][rng.below(2)];
                x = g.add(
                    format!("l{i}_pool"),
                    Op::Pool { kind, k: 2, stride: 2, padding: 0 },
                    vec![x],
                );
            }
            // residual 1x1 branch + add (shape-preserving)
            4 => {
                let c = g.add(format!("l{i}_res"), Op::conv(1, 1, cin, cin, 1, 0), vec![x]);
                let b = g.add(format!("l{i}_res_bn"), Op::BatchNorm { c: cin }, vec![c]);
                x = g.add(format!("l{i}_add"), Op::Add, vec![b, x]);
            }
            // two 1x1 branches concatenated on channels
            5 => {
                let (ca, cb) = ([4usize, 8][rng.below(2)], [4usize, 8][rng.below(2)]);
                let a = g.add(format!("l{i}_br_a"), Op::conv(1, 1, cin, ca, 1, 0), vec![x]);
                let b = g.add(format!("l{i}_br_b"), Op::conv(1, 1, cin, cb, 1, 0), vec![x]);
                x = g.add(format!("l{i}_cat"), Op::Concat, vec![a, b]);
                cin = ca + cb;
            }
            // identity — keeps chains of differing lengths in the pool
            _ => {
                x = g.add(format!("l{i}_id"), Op::Activation { kind: ActKind::None }, vec![x]);
            }
        }
    }
    let shape = g.node(x).shape.clone();
    let head = if rng.below(2) == 0 {
        let f = g.add("flatten", Op::Flatten, vec![x]);
        let flat = shape.h() * shape.w() * cin;
        g.add("fc", Op::FullyConnected { cin: flat, cout: 10, bias: rng.below(2) == 0 }, vec![f])
    } else {
        let gap = g.add("gap", Op::GlobalAvgPool, vec![x]);
        g.add("fc", Op::fc(cin, 10), vec![gap])
    };
    if rng.below(2) == 0 {
        g.add("sm", Op::Softmax, vec![head]);
    }
    g
}

/// Property: for ≥200 seeded random graphs, print → parse → print is a
/// fixpoint and parse reconstructs the graph exactly. Half the cases
/// also carry a sparsity profile through `print_with_hints` and require
/// it back intact (values, structures, quant bits).
#[test]
fn prop_print_parse_print_is_a_fixpoint() {
    let cases = 200u64;
    for case in 0..cases {
        let mut rng = Rng::new(0xBEEF ^ case.wrapping_mul(0x9E3779B97F4A7C15));
        let g = random_graph(case, &mut rng);
        g.validate().unwrap_or_else(|e| panic!("case {case}: generator bug: {e}"));

        let text = if case % 2 == 0 {
            front::print(&g)
        } else {
            let s = [0.5, 0.8, 0.9, 0.93][rng.below(4)];
            let mut profile = match rng.below(3) {
                0 => SparsityProfile::uniform(&g, s),
                1 => SparsityProfile::uniform_structured(
                    &g,
                    s,
                    PruneStructure::parse("block4x4").unwrap(),
                ),
                _ => SparsityProfile::uniform(&g, s).with_uniform_quant(4),
            };
            // profiles over graphs with no prunable layer print hint-free
            if profile.is_empty() {
                profile = SparsityProfile::default();
            }
            let text = front::print_with_hints(&g, &profile);
            let parsed = front::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
            assert_eq!(parsed.profile, profile, "case {case}: hints changed\n{text}");
            text
        };
        let parsed = front::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(parsed.graph, g, "case {case}: graph changed\n{text}");
        assert_eq!(front::print(&parsed.graph), front::print(&g), "case {case}: not a fixpoint");
    }
}

const TINY: &str = "model tiny\n\
                    input input [1,8,8,3]\n\
                    c1 = conv2d(input) k=3 cout=8 stride=1 pad=1 sparsity=0.5\n\
                    b1 = batchnorm(c1)\n\
                    r1 = relu(b1)\n\
                    p1 = maxpool(r1) k=2\n\
                    gap = global_avg_pool(p1)\n\
                    fc = dense(gap) cout=10 bias sparsity=0.9 prune=block4x4 quant=4\n\
                    out = softmax(fc)\n\
                    output out\n";

/// Malformed source of every kind yields a positioned `Parse` error with
/// the expected diagnostic — the same corpus the python reader pins
/// (`python/tests/test_cadnn_ir.py`), so the two front-ends reject
/// identically.
#[test]
fn malformed_input_yields_positioned_parse_errors() {
    let cases: [(&str, &str); 13] = [
        ("", "expected 'model"),
        ("model t\n", "expected 'input"),
        ("model t\ninput x [0]\n", "dimension must be"),
        ("model t\ninput x [1,4,4,2]\na = add(x, y)\n", "unknown input 'y'"),
        ("model t\ninput x [1,4,4,2]\nx = relu(x)\n", "duplicate node name"),
        ("model t\ninput x [1,4,4,2]\nc = conv2d(x) k=9 cout=4\n", "does not fit"),
        ("model t\ninput x [1,4,4,2]\nd = dense(x) cout=4\n", "rank-2"),
        ("model t\ninput x [1,4,4,2]\nr = relu(x) bogus=1\n", "unknown attribute"),
        ("model t\ninput x [1,4,4,2]\nr = relu(x) sparsity=0.5\n", "weight layers"),
        ("model t\ninput x [1,4,4,2]\noutput y\n", "unknown node"),
        ("model t\ninput x [1,4,4,2]\noutput x\nr = relu(x)\n", "last statement"),
        ("model t\ninput x [1,4,4,2]\nc = convv2d(x) k=3\n", "unknown op"),
        ("a @ b", "unexpected character"),
    ];
    for (src, frag) in cases {
        let err = front::parse(src).err().unwrap_or_else(|| panic!("accepted: {src:?}"));
        assert!(matches!(err, CadnnError::Parse { .. }), "{src:?}: {err}");
        let msg = err.to_string();
        assert!(msg.contains("parse error at"), "{src:?}: {msg}");
        assert!(msg.contains(frag), "{src:?}: missing {frag:?} in {msg}");
    }
}

/// Error positions are exact (1-based line and column of the offending
/// token), so editors can jump to them.
#[test]
fn error_positions_are_exact() {
    let err =
        front::parse("model t\ninput x [1,8,8,3]\nc = convv2d(x) k=3 cout=8\n").err().unwrap();
    match err {
        CadnnError::Parse { line, col, ref token, .. } => {
            assert_eq!((line, col, token.as_str()), (3, 5, "convv2d"), "{err}");
        }
        other => panic!("expected Parse, got {other}"),
    }
}

/// Truncating a valid model at EVERY byte offset either parses (the
/// optional-output grammar admits some prefixes) or returns `Parse` —
/// never a panic, never a different error kind.
#[test]
fn truncation_at_every_offset_never_panics() {
    for cut in 0..TINY.len() {
        match front::parse(&TINY[..cut]) {
            Ok(_) | Err(CadnnError::Parse { .. }) => {}
            Err(other) => panic!("cut at {cut}: unexpected error kind: {other}"),
        }
    }
}

/// A hinted `.cadnn` file alone drives the full pipeline: parse →
/// profile → plan (hinted layers leave Dense) → serve with the right
/// output arity. This is the acceptance path for user-defined models.
#[test]
fn cadnn_file_is_a_complete_pipeline_input() {
    let path = std::env::temp_dir().join(format!("cadnn_mir_{}.cadnn", std::process::id()));
    std::fs::write(&path, TINY).unwrap();
    let engine = Engine::from_model_file(path.to_str().unwrap())
        .personality(Personality::CadnnSparse)
        .build()
        .unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(engine.classes(), 10);
    let plan = engine.exec_plan().expect("inline hints must produce a plan");
    let fc = plan.get("fc").expect("hinted fc layer must be planned");
    assert_ne!(fc.format, SparseFormat::Dense, "90% sparse fc stayed dense: {fc:?}");
    let mut rng = Rng::new(42);
    let mut img = vec![0.0f32; engine.input_len()];
    rng.fill_normal(&mut img, 0.5);
    let out = engine.session().run(&img).unwrap();
    assert_eq!(out.len(), 10);
    let sum: f32 = out.iter().sum();
    assert!((sum - 1.0).abs() < 1e-4, "softmax output must normalize: {sum}");
}

/// An explicit profile whose names match nothing in the parsed file
/// fails the build loudly (every layer would silently plan Dense).
#[test]
fn mismatched_profile_on_model_file_is_config_error() {
    let path = std::env::temp_dir().join(format!("cadnn_mir_bad_{}.cadnn", std::process::id()));
    std::fs::write(&path, TINY).unwrap();
    let mut profile = SparsityProfile::default();
    profile.layers.insert("not_a_layer".into(), 0.9);
    let err = Engine::from_model_file(path.to_str().unwrap())
        .personality(Personality::CadnnSparse)
        .sparsity_profile(profile)
        .build()
        .err()
        .unwrap();
    std::fs::remove_file(&path).ok();
    assert!(matches!(err, CadnnError::Config { .. }), "{err}");
    assert!(err.to_string().contains("matches no prunable layer"), "{err}");
}
