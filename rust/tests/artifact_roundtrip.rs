//! Integration: every AOT artifact loads through PJRT and reproduces the
//! golden logits recorded by aot.py at lowering time — the end-to-end
//! proof that the three layers compose (Pallas kernel -> JAX model ->
//! HLO text -> Rust runtime).
//!
//! Requires `make artifacts`; tests no-op (with a loud message) otherwise.

use cadnn::runtime::Runtime;
use cadnn::util::json::Json;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("CADNN_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir} (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_entries_all_load_and_execute() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    for (name, variant) in rt.manifest.model_variants() {
        let n = rt.load(&name, &variant).unwrap();
        assert!(n >= 2, "{name}/{variant}: expected multiple batch variants");
        for batch in rt.batches(&name, &variant) {
            let model = rt.get(&name, &variant, batch).unwrap();
            let len: usize = model.entry.input_shape.iter().product();
            let out = model.run(&vec![0.1f32; len]).unwrap();
            assert_eq!(
                out.len(),
                batch * model.entry.classes,
                "{name}/{variant} b{batch} output length"
            );
            assert!(out.iter().all(|v| v.is_finite()), "{name}/{variant} non-finite");
        }
    }
}

#[test]
fn golden_logits_reproduced() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    for (name, variant) in rt.manifest.model_variants() {
        let golden_path = format!("{dir}/golden/{name}_{variant}.json");
        let Ok(text) = std::fs::read_to_string(&golden_path) else {
            panic!("missing golden file {golden_path}");
        };
        let g = Json::parse(&text).unwrap();
        let input = g.get("input").and_then(|v| v.as_f32_vec()).unwrap();
        let want = g.get("logits").and_then(|v| v.as_f32_vec()).unwrap();
        let ishape = g.get("input_shape").and_then(|v| v.as_usize_vec()).unwrap();
        let lshape = g.get("logits_shape").and_then(|v| v.as_usize_vec()).unwrap();
        let (gb, classes) = (ishape[0], lshape[1]);
        let per_image: usize = ishape.iter().skip(1).product();

        rt.load(&name, &variant).unwrap();
        // run the golden images through the batch-1 executable one by one
        let model = rt.get(&name, &variant, 1).unwrap();
        for i in 0..gb {
            let out = model.run(&input[i * per_image..(i + 1) * per_image]).unwrap();
            let expect = &want[i * classes..(i + 1) * classes];
            let max_err = out
                .iter()
                .zip(expect)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(
                max_err < 1e-3,
                "{name}/{variant} image {i}: max_err {max_err}"
            );
        }
    }
}

#[test]
fn batch_variants_agree_with_batch1() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    rt.load("lenet5", "dense").unwrap();
    let b1 = rt.get("lenet5", "dense", 1).unwrap();
    let batches = rt.batches("lenet5", "dense");
    let per_image = 28 * 28;
    // deterministic pseudo-images
    let img: Vec<f32> = (0..per_image).map(|i| ((i % 17) as f32) / 17.0).collect();
    let single = b1.run(&img).unwrap();
    for &b in batches.iter().filter(|&&b| b > 1) {
        let model = rt.get("lenet5", "dense", b).unwrap();
        let mut input = Vec::with_capacity(b * per_image);
        for _ in 0..b {
            input.extend_from_slice(&img);
        }
        let out = model.run(&input).unwrap();
        for row in 0..b {
            let got = &out[row * 10..(row + 1) * 10];
            let max_err = got
                .iter()
                .zip(&single)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(max_err < 1e-4, "b{b} row {row}: max_err {max_err}");
        }
    }
}

#[test]
fn sparse_artifact_advertises_compression() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let sparse: Vec<_> = rt
        .manifest
        .models
        .iter()
        .filter(|e| e.variant == "sparse")
        .collect();
    assert!(!sparse.is_empty());
    for e in sparse {
        assert!(e.compression_rate > 1.5, "{}: rate {}", e.name, e.compression_rate);
        assert!(e.accuracy > 0.35, "{}: acc {}", e.name, e.accuracy);
    }
}
