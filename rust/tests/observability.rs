//! Integration: the `cadnn::obs` recorder end to end — ring overflow
//! accounting, trace JSON round-trips through the actual serialized
//! text, histogram quantiles, cost residuals on a synthetic plan, and a
//! served workload where every request leaves a complete lifecycle span.
//!
//! The recorder is process-global, so every test that touches it holds
//! `LOCK` and starts from `obs::reset()` — spans left in pooled worker
//! threads by another test would otherwise leak into `drain()`.

use cadnn::api::Engine;
use cadnn::models;
use cadnn::obs::{self, trace, ArgValue, CostReport, Log2Hist, RING_CAPACITY};
use cadnn::serve::{QueueConfig, ServeRequest, Server};
use cadnn::util::json::Json;
use std::sync::Mutex;

static LOCK: Mutex<()> = Mutex::new(());

fn serialize() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[test]
fn ring_overflow_drops_oldest_and_counts() {
    if !obs::COMPILED {
        return;
    }
    let _g = serialize();
    obs::reset();
    obs::enable();
    let extra = 10;
    for i in 0..RING_CAPACITY + extra {
        obs::record_span(obs::CAT_EXEC, "n".into(), i as f64, 1.0, vec![]);
    }
    obs::disable();
    assert_eq!(obs::dropped_spans(), extra as u64);
    let spans = obs::drain();
    assert_eq!(spans.len(), RING_CAPACITY);
    // oldest `extra` spans were the ones evicted
    let min_start = spans.iter().map(|s| s.start_us).fold(f64::MAX, f64::min);
    assert_eq!(min_start, extra as f64);
    obs::reset();
    assert_eq!(obs::dropped_spans(), 0);
}

#[test]
fn recorded_spans_round_trip_through_trace_text() {
    if !obs::COMPILED {
        return;
    }
    let _g = serialize();
    obs::reset();
    obs::enable();
    obs::record_span(
        obs::CAT_EXEC,
        "conv1".into(),
        5.0,
        40.0,
        vec![
            ("op", ArgValue::Str("conv2d".into())),
            ("format", ArgValue::Str("csr".into())),
            ("m", ArgValue::Num(784.0)),
            ("pred_units", ArgValue::Num(1000.0)),
        ],
    );
    obs::record_span(
        obs::CAT_SERVE,
        "request".into(),
        0.0,
        100.0,
        vec![("outcome", ArgValue::Str("ok".into())), ("id", ArgValue::Num(3.0))],
    );
    obs::add(obs::Counter::CsrRows, 784);
    obs::disable();
    let spans = obs::drain();
    assert_eq!(spans.len(), 2);
    let doc = trace::chrome_trace(&spans, &obs::counters(), obs::dropped_spans());
    // through the serialized text — what `cadnn profile --trace` writes
    let text = doc.to_string_pretty();
    let parsed = Json::parse(&text).expect("trace output must be valid JSON");
    let back = trace::parse_chrome_trace(&parsed).expect("writer output must parse back");
    assert_eq!(back, spans);
    let counters = parsed.get("otherData").and_then(|o| o.get("counters")).unwrap();
    assert_eq!(counters.get("csr_rows").and_then(|v| v.as_f64()), Some(784.0));
    obs::reset();
}

#[test]
fn histogram_quantiles_pin_bucket_upper_edges() {
    // pure-value API, no global state: fine to run unserialized
    let h = Log2Hist::new();
    for v in 0..1000 {
        h.record(v as f64);
    }
    let s = h.snapshot().unwrap().summary();
    assert_eq!(s.count, 1000);
    // nearest-rank quantiles resolve to bucket upper edges, clamped to
    // the observed max
    assert_eq!(s.p50, 512.0);
    assert_eq!(s.p99, 999.0);
    assert_eq!(s.max, 999.0);

    let one = Log2Hist::new();
    one.record(3000.0);
    let s1 = one.snapshot().unwrap().summary();
    assert_eq!((s1.p50, s1.p99), (3000.0, 3000.0));
}

#[test]
fn residuals_on_a_synthetic_plan_recover_the_skew() {
    if !obs::COMPILED {
        return;
    }
    // two formats, one measured 2x the global fit, one measured at it —
    // entirely through public Span values, no recorder involvement
    let mk = |name: &str, format: &str, pred: f64, dur: f64| obs::Span {
        cat: obs::CAT_EXEC,
        name: name.to_string(),
        start_us: 0.0,
        dur_us: dur,
        tid: 1,
        trace: 0,
        args: vec![
            ("op", ArgValue::Str("fc".into())),
            ("format", ArgValue::Str(format.to_string())),
            ("pred_units", ArgValue::Num(pred)),
        ],
    };
    let spans = vec![
        mk("a", "csr", 1000.0, 2000.0),
        mk("b", "csr", 1000.0, 2000.0),
        mk("c", "dense", 1000.0, 1000.0),
        mk("d", "dense", 1000.0, 1000.0),
    ];
    let report = CostReport::from_spans(&spans);
    assert_eq!(report.spans, 4);
    // least-squares global fit: (2*2000 + 2*1000) / 4000 = 1.5 us/unit
    assert!((report.us_per_unit - 1.5).abs() < 1e-9, "{}", report.us_per_unit);
    let csr = report.groups.iter().find(|g| g.format == "csr").unwrap();
    let dense = report.groups.iter().find(|g| g.format == "dense").unwrap();
    assert!((csr.residual - 2.0 / 1.5).abs() < 1e-9);
    assert!((dense.residual - 1.0 / 1.5).abs() < 1e-9);
    // suggestions scale the constants by the residuals
    let sug = report.suggestions();
    let csr_sug = sug.iter().find(|(n, _, _)| *n == "COST_CSR_NNZ").unwrap();
    assert!((csr_sug.2 / csr_sug.1 - 2.0 / 1.5).abs() < 1e-9);
}

#[test]
fn served_requests_emit_complete_lifecycle_spans() {
    if !obs::COMPILED {
        return;
    }
    let _g = serialize();
    let engine = Engine::native("lenet5").batch_sizes(&[1, 2, 4]).build().unwrap();
    let nodes = models::build("lenet5", 1).unwrap().len() - 1; // node 0 is the input
    let cfg = QueueConfig { max_batch: 4, max_wait_us: 1_000, ..QueueConfig::default() };
    let server = Server::builder().engine_with("m", &engine, cfg).build().unwrap();
    let input_len = server.input_len("m").unwrap();

    obs::reset();
    obs::enable();
    let n = 8;
    let mut rxs = Vec::new();
    for _ in 0..n {
        let req = ServeRequest::new("m", vec![0.25f32; input_len]);
        rxs.push(server.submit(req).unwrap());
    }
    let mut ids = Vec::new();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(resp.outcome.is_ok());
        ids.push(resp.id);
    }
    let stats = server.stats();
    server.shutdown().unwrap();
    obs::disable();
    let spans = obs::drain();
    obs::reset();

    // every request: exactly one "request" span, outcome ok, with the
    // full lifecycle accounting attached
    for id in ids {
        let req_spans: Vec<_> = spans
            .iter()
            .filter(|s| {
                s.cat == obs::CAT_SERVE
                    && s.name == "request"
                    && s.num_arg("id") == Some(id as f64)
            })
            .collect();
        assert_eq!(req_spans.len(), 1, "request {id} must leave exactly one span");
        let s = req_spans[0];
        assert_eq!(s.str_arg("outcome"), Some("ok"));
        assert_eq!(s.str_arg("model"), Some("m"));
        assert!(s.num_arg("wait_us").is_some_and(|w| w >= 0.0));
        assert!(s.num_arg("exec_us").is_some_and(|e| e > 0.0));
        assert!(s.num_arg("batch").is_some_and(|b| b >= 1.0));
        assert!(s.dur_us >= 0.0);
    }
    // batches leave their own spans, and the executor traced each node
    // of each batch run
    let batches = spans
        .iter()
        .filter(|s| s.cat == obs::CAT_SERVE && s.name == "batch")
        .count();
    assert!(batches >= 1, "no batch spans recorded");
    let exec = spans.iter().filter(|s| s.cat == obs::CAT_EXEC).count();
    assert!(
        exec >= nodes * batches,
        "{exec} exec spans for {batches} batches over {nodes} nodes"
    );
    // the atomic metrics saw the same traffic, histograms included
    let m = &stats["m"];
    assert_eq!(m.requests as usize, n);
    let q = m.queue_wait.as_ref().expect("queue-wait summary present");
    assert_eq!(q.count, n);
    assert!(m.latency_hist.is_some() && m.queue_wait_hist.is_some());
}

/// Replica metrics aggregation rests on `HistSnapshot::merge` being an
/// order-insensitive bucket-wise fold. Pin it against the
/// single-recorder oracle: 500 random samples split round-robin across
/// three recorders merge — in every grouping and order — to exactly the
/// histogram one recorder sees: count, extrema, bucket contents, and
/// the quantiles snapshots report. (Means recombine count-weighted;
/// with non-power-of-two counts that recombination is exact up to f64
/// rounding, so it gets an epsilon while everything else gets `==`.)
#[test]
fn replica_hist_merge_matches_a_single_recorder_oracle() {
    use cadnn::util::rng::Rng;
    let mut rng = Rng::new(0x0b5);
    let oracle = Log2Hist::new();
    let parts = [Log2Hist::new(), Log2Hist::new(), Log2Hist::new()];
    for i in 0..500 {
        // spread over ~6 decades, fractional values included
        let v = rng.below(1_000_000) as f64 / 7.0;
        oracle.record(v);
        parts[i % 3].record(v);
    }
    let [a, b, c] = parts.map(|h| h.snapshot().unwrap());
    let want = oracle.snapshot().unwrap();
    let orders = [
        a.merge(&b).merge(&c),       // left fold
        a.merge(&b.merge(&c)),       // right fold (associativity)
        c.merge(&b).merge(&a),       // reversed (commutativity)
        b.merge(&c.merge(&a)),       // rotated
    ];
    for got in &orders {
        assert_eq!(got.count, want.count);
        assert_eq!(got.min_us, want.min_us);
        assert_eq!(got.max_us, want.max_us);
        assert_eq!(got.buckets, want.buckets, "bucket-wise merge must be exact");
        assert_eq!((got.p50(), got.p95(), got.p99()), (want.p50(), want.p95(), want.p99()));
        assert!((got.mean_us - want.mean_us).abs() <= 1e-9 * want.mean_us.abs());
    }
}

#[test]
fn disabled_recorder_records_nothing() {
    if !obs::COMPILED {
        return;
    }
    let _g = serialize();
    obs::reset();
    obs::disable();
    assert!(obs::timer().is_none());
    obs::record_span(obs::CAT_EXEC, "ghost".into(), 0.0, 1.0, vec![]);
    obs::add(obs::Counter::GemmRows, 99);
    assert!(obs::drain().is_empty());
    assert!(obs::counters().iter().all(|&(_, v)| v == 0));
    assert_eq!(obs::dropped_spans(), 0);
}
