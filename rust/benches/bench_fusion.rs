//! Fusion ablation (paper §4 "model computation fusion"): measured on the
//! real executor — the same model run by TFLite-like (unfused, direct),
//! an im2col-GEMM engine *without* fused epilogues, and CADNN (fused).
//! Also reports the graph-level effect of the passes.
//!
//! Run: cargo bench --bench bench_fusion

use cadnn::bench::print_table;
use cadnn::exec::{ModelInstance, Personality};
use cadnn::ir::Shape;
use cadnn::ir::{Graph, Op};
use cadnn::ir::ops::ActKind;
use cadnn::kernels::Tensor;
use cadnn::util::rng::Rng;
use cadnn::util::stats;

/// A MobileNet-ish tower at reduced resolution: the fusion targets
/// (conv+bn+relu, dw+bn+relu, 1x1 convs) at host-benchable sizes.
fn tower(batch: usize) -> Graph {
    let mut g = Graph::new("tower", Shape::nhwc(batch, 56, 56, 16));
    let mut x = 0;
    let mut cin = 16;
    for (i, (cout, stride)) in [(32usize, 1usize), (32, 2), (64, 1), (64, 2), (128, 1)]
        .iter()
        .enumerate()
    {
        let dw = g.add(
            format!("b{i}_dw"),
            Op::DepthwiseConv2d { kh: 3, kw: 3, c: cin, stride: *stride, padding: 1 },
            vec![x],
        );
        let dwbn = g.add(format!("b{i}_dw_bn"), Op::BatchNorm { c: cin }, vec![dw]);
        let dwact = g.add(
            format!("b{i}_dw_act"),
            Op::Activation { kind: ActKind::Relu },
            vec![dwbn],
        );
        let pw = g.add(format!("b{i}_pw"), Op::conv(1, 1, cin, *cout, 1, 0), vec![dwact]);
        let pwbn = g.add(format!("b{i}_pw_bn"), Op::BatchNorm { c: *cout }, vec![pw]);
        x = g.add(
            format!("b{i}_pw_act"),
            Op::Activation { kind: ActKind::Relu },
            vec![pwbn],
        );
        cin = *cout;
    }
    let gap = g.add("gap", Op::GlobalAvgPool, vec![x]);
    g.add("fc", Op::fc(cin, 10), vec![gap]);
    g
}

fn main() {
    let g = tower(1);
    let mut rng = Rng::new(3);
    let mut input = Tensor::zeros(&g.nodes[0].shape.0);
    rng.fill_normal(&mut input.data, 0.5);

    println!("== fusion ablation on a depthwise-separable tower (56x56x16 input) ==\n");

    // graph-level effect
    let fused_graph = Personality::CadnnDense.lower(&g);
    println!(
        "graph nodes: {} unfused -> {} fused (eliminated {} intermediate tensors)\n",
        g.len(),
        fused_graph.len(),
        g.len() - fused_graph.len()
    );

    let mut rows = Vec::new();
    let mut base_us = 0.0;
    for p in [Personality::TfLiteLike, Personality::TvmLike, Personality::CadnnDense] {
        let inst = ModelInstance::build(&g, p, None, None, 2 << 20).unwrap();
        let samples = stats::measure_adaptive_us(400_000.0, 12, || {
            let _ = inst.execute(&input).unwrap();
        });
        let s = stats::Summary::from(&samples).unwrap();
        if p == Personality::TfLiteLike {
            base_us = s.p50;
        }
        rows.push(vec![
            p.label().to_string(),
            format!("{:.0}", s.p50),
            format!("{:.0}", s.mean),
            format!("{:.2}x", base_us / s.p50),
        ]);
    }
    print_table(&["personality", "p50 us", "mean us", "speedup vs TFLite-like"], &rows);
    println!("\n(TVM-like = fusion+GEMM with default tiles; CADNN-D adds tuned tiles)");
}
