//! Serving bench: open-loop Poisson arrivals against the native sparse
//! lenet5 engine, sweeping offered load across three batch-selection
//! modes — the old greedy batcher, pad-to-fit, and the planner-informed
//! deadline-aware scheduler (`ExecPlan::cost_at` + online calibration).
//! Quantifies what plan-aware batching buys: p50/p99 latency, queue-wait
//! percentiles, batch utilization, and deadline misses (split by cause)
//! at each load. A second sweep serves two models together at 0.5×–2.0×
//! the calibrated capacity with admission control on/off, showing
//! overload turning queue-expiry misses into early sheds. Two final A/B
//! passes measure observability cost: the span-recorder overhead on the
//! exec hot path (obs enabled vs disabled), and the full telemetry
//! stack on the serving path (off vs head-1% sampling vs always-on).
//! No artifacts needed. Emits `BENCH_serving.json`. Run:
//! cargo bench --bench bench_serving

use cadnn::api::Engine;
use cadnn::bench::print_table;
use cadnn::compress::profile::paper_profile;
use cadnn::exec::Personality;
use cadnn::models;
use cadnn::planner::BatchCost;
use cadnn::serve::{
    AdmissionConfig, BatchPolicy, QueueConfig, ServeError, ServeRequest, Server, TelemetryConfig,
};
use cadnn::util::json::{obj, Json};
use cadnn::util::rng::Rng;

const DEADLINE_MS: u64 = 60;

struct RunResult {
    ok: usize,
    missed: usize,
    missed_queue: u64,
    missed_infeasible: u64,
    p50_ms: f64,
    p99_ms: f64,
    queue_p50_ms: f64,
    queue_p95_ms: f64,
    batch_util: f64,
    batches: u64,
}

fn run(engine: &Engine, cfg: QueueConfig, rps: f64, requests: usize) -> Option<RunResult> {
    let server = Server::builder().engine_with("m", engine, cfg).build().ok()?;
    let input_len = server.input_len("m")?;
    let mut rng = Rng::new(77);
    // open loop: arrivals follow the Poisson schedule regardless of
    // completions, so overload shows up as queueing (not back-pressure)
    let mut inflight = Vec::new();
    for _ in 0..requests {
        let mut img = vec![0.0f32; input_len];
        rng.fill_normal(&mut img, 0.5);
        let req = ServeRequest::new("m", img).deadline_ms(DEADLINE_MS);
        inflight.push(server.submit(req).ok()?);
        std::thread::sleep(std::time::Duration::from_secs_f64(rng.exp(rps)));
    }
    let (mut ok, mut missed) = (0usize, 0usize);
    for rx in inflight {
        match rx.recv() {
            Ok(resp) => match resp.outcome {
                Ok(_) => ok += 1,
                Err(ServeError::Deadline { .. }) => missed += 1,
                Err(_) => {}
            },
            Err(_) => {}
        }
    }
    let stats = server.stats();
    let s = &stats["m"];
    let (p50, p99) = s
        .latency
        .as_ref()
        .map(|l| (l.p50 / 1e3, l.p99 / 1e3))
        .unwrap_or((0.0, 0.0));
    let (q50, q95) = s
        .queue_wait
        .as_ref()
        .map(|q| (q.p50 / 1e3, q.p95 / 1e3))
        .unwrap_or((0.0, 0.0));
    let result = RunResult {
        ok,
        missed,
        missed_queue: s.deadline_misses_queue,
        missed_infeasible: s.deadline_misses_infeasible,
        p50_ms: p50,
        p99_ms: p99,
        queue_p50_ms: q50,
        queue_p95_ms: q95,
        batch_util: s.batch_utilization,
        batches: s.batches,
    };
    server.shutdown().ok()?;
    Some(result)
}

/// A/B the span recorder on the exec hot path: median single-inference
/// latency over direct session runs with obs disabled vs enabled.
/// Prints the delta and returns the JSON blob embedded in the report
/// (`Json::Null` when the `obs` feature is compiled out — overhead is
/// zero by construction, there is nothing to measure).
fn measure_obs_overhead(engine: &Engine) -> Json {
    if !cadnn::obs::COMPILED {
        println!("\nobs overhead: feature compiled out — recorder cost is exactly 0");
        return Json::Null;
    }
    const WARMUP: usize = 5;
    const ITERS: usize = 50;
    let mut session = engine.session();
    let img: Vec<f32> = (0..28 * 28).map(|i| ((i % 17) as f32) / 17.0).collect();
    let median_us = |session: &mut cadnn::api::Session| -> f64 {
        let mut samples: Vec<f64> = (0..ITERS)
            .map(|_| {
                let t0 = std::time::Instant::now();
                session.run(&img).expect("lenet5 session runs");
                t0.elapsed().as_secs_f64() * 1e6
            })
            .collect();
        samples.sort_by(|a, b| a.partial_cmp(b).expect("finite timings"));
        samples[ITERS / 2]
    };
    for _ in 0..WARMUP {
        session.run(&img).expect("lenet5 session runs");
    }
    cadnn::obs::disable();
    let off_us = median_us(&mut session);
    cadnn::obs::reset();
    cadnn::obs::enable();
    let on_us = median_us(&mut session);
    cadnn::obs::disable();
    cadnn::obs::reset();
    let pct = if off_us > 0.0 { (on_us / off_us - 1.0) * 100.0 } else { 0.0 };
    println!(
        "\nobs overhead: median inference {off_us:.1}us recorder-off vs {on_us:.1}us \
         recorder-on ({pct:+.2}%; target <2% enabled, 0 when compiled out)"
    );
    obj(vec![
        ("iters", Json::Num(ITERS as f64)),
        ("disabled_median_us", Json::Num(off_us)),
        ("enabled_median_us", Json::Num(on_us)),
        ("overhead_pct", Json::Num(pct)),
    ])
}

/// A/B the full always-on tracing stack on the serving path: mean
/// per-request closed-loop latency with telemetry off, head-sampled at
/// 1%, and always-on (rate 1.0). Each configuration serves the same
/// load; the telemetry sink is a temp file, removed afterwards. Returns
/// the JSON blob embedded in the report (`Json::Null` when the `obs`
/// feature is compiled out).
fn measure_telemetry_overhead(engine: &Engine) -> Json {
    if !cadnn::obs::COMPILED {
        println!("\ntelemetry overhead: obs feature compiled out — cost is exactly 0");
        return Json::Null;
    }
    const REQUESTS: usize = 64;
    let path = std::env::temp_dir()
        .join(format!("cadnn-bench-telemetry-{}.jsonl", std::process::id()));
    let mut run_cfg = |rate: Option<f64>| -> Option<f64> {
        cadnn::obs::disable();
        cadnn::obs::reset();
        let mut builder = Server::builder().engine_with("m", engine, QueueConfig::default());
        if let Some(r) = rate {
            let mut tcfg = TelemetryConfig::new(&path);
            tcfg.sample_rate = r;
            tcfg.period_ms = 50;
            builder = builder.telemetry(tcfg);
        }
        let server = builder.build().ok()?;
        let input_len = server.input_len("m")?;
        let mut rng = Rng::new(29);
        let t0 = std::time::Instant::now();
        for _ in 0..REQUESTS {
            let mut img = vec![0.0f32; input_len];
            rng.fill_normal(&mut img, 0.5);
            server.infer(ServeRequest::new("m", img)).ok()?;
        }
        let total_us = t0.elapsed().as_secs_f64() * 1e6;
        server.shutdown().ok()?;
        cadnn::obs::disable();
        cadnn::obs::reset();
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(cadnn::obs::export::rotated_path(&path));
        Some(total_us / REQUESTS as f64)
    };
    let (off, head, always) = match (run_cfg(None), run_cfg(Some(0.01)), run_cfg(Some(1.0))) {
        (Some(a), Some(b), Some(c)) => (a, b, c),
        _ => {
            eprintln!("telemetry overhead runs failed");
            return Json::Null;
        }
    };
    let pct = |on: f64| if off > 0.0 { (on / off - 1.0) * 100.0 } else { 0.0 };
    println!(
        "\ntelemetry overhead: per-request {off:.1}us off vs {head:.1}us head-1% \
         ({:+.2}%) vs {always:.1}us always-on ({:+.2}%)",
        pct(head),
        pct(always),
    );
    obj(vec![
        ("requests", Json::Num(REQUESTS as f64)),
        ("off_mean_us", Json::Num(off)),
        ("head_1pct_mean_us", Json::Num(head)),
        ("always_on_mean_us", Json::Num(always)),
        ("head_1pct_overhead_pct", Json::Num(pct(head))),
        ("always_on_overhead_pct", Json::Num(pct(always))),
    ])
}

/// Converge the serving-cost calibration (units → µs) with a short
/// closed-loop warm-up, so the overload sweep's capacity axis is in
/// calibrated units rather than guesses.
fn calibrate_upu(engine: &Engine) -> Option<f64> {
    let server = Server::builder().engine_with("m", engine, QueueConfig::default()).build().ok()?;
    let input_len = server.input_len("m")?;
    let mut rng = Rng::new(13);
    for _ in 0..8 {
        let mut img = vec![0.0f32; input_len];
        rng.fill_normal(&mut img, 0.5);
        server.infer(ServeRequest::new("m", img)).ok()?;
    }
    let upu = server.stats()["m"].us_per_unit;
    server.shutdown().ok()?;
    upu
}

/// Recover the affine batch cost model from the engine's plan-cost
/// samples (they are `cost_at(b)` evaluations, so two points determine
/// the line exactly).
fn affine_cost(engine: &Engine) -> Option<BatchCost> {
    let costs = engine.plan_costs();
    let (&(b0, c0), &(b1, c1)) = (costs.first()?, costs.last()?);
    if b1 == b0 {
        return None;
    }
    let per_image = (c1 - c0) / (b1 - b0) as f64;
    Some(BatchCost { per_image, overhead: c0 - per_image * b0 as f64 })
}

struct OverloadCell {
    model: String,
    ok: usize,
    missed: usize,
    shed: usize,
    shed_quota: u64,
    shed_deadline: u64,
    p99_ms: f64,
}

/// One overload cell: two models (same engine twice) served together at
/// `load_x ×` the calibrated full-batch capacity each, admission on or
/// off. Returns one result row per model.
fn overload_run(
    engine: &Engine,
    upu: f64,
    capacity_rps: f64,
    load_x: f64,
    admission: bool,
    requests: usize,
) -> Option<Vec<OverloadCell>> {
    let names = ["a", "b"];
    let cfg = QueueConfig { calibration: Some(upu), ..QueueConfig::default() };
    let mut builder = Server::builder()
        .admission(AdmissionConfig { enabled: admission, max_backlog_us: None });
    for n in names {
        builder = builder.engine_with(n, engine, cfg);
    }
    let server = builder.build().ok()?;
    let input_len = server.input_len("a")?;
    // each model is offered load_x × its own capacity; the joint stream
    // alternates, so it runs at twice that rate
    let rps = 2.0 * load_x * capacity_rps;
    let mut rng = Rng::new(101);
    let mut inflight: Vec<(usize, _)> = Vec::new();
    for i in 0..requests {
        let m = i % names.len();
        let mut img = vec![0.0f32; input_len];
        rng.fill_normal(&mut img, 0.5);
        let req = ServeRequest::new(names[m], img).deadline_ms(DEADLINE_MS);
        inflight.push((m, server.submit(req).ok()?));
        std::thread::sleep(std::time::Duration::from_secs_f64(rng.exp(rps)));
    }
    let mut per: Vec<(usize, usize, usize)> = vec![(0, 0, 0); names.len()];
    for (m, rx) in inflight {
        match rx.recv() {
            Ok(resp) => match resp.outcome {
                Ok(_) => per[m].0 += 1,
                Err(ServeError::Deadline { .. }) => per[m].1 += 1,
                Err(ServeError::Shed { .. }) => per[m].2 += 1,
                Err(_) => {}
            },
            Err(_) => {}
        }
    }
    let stats = server.stats();
    let cells = names
        .iter()
        .zip(per)
        .map(|(n, (ok, missed, shed))| {
            let s = &stats[*n];
            OverloadCell {
                model: n.to_string(),
                ok,
                missed,
                shed,
                shed_quota: s.shed_quota,
                shed_deadline: s.shed_deadline,
                p99_ms: s.latency.as_ref().map(|l| l.p99 / 1e3).unwrap_or(0.0),
            }
        })
        .collect();
    server.shutdown().ok()?;
    Some(cells)
}

fn main() {
    let g = models::build("lenet5", 1).expect("lenet5 exists");
    let engine = Engine::native("lenet5")
        .personality(Personality::CadnnSparse)
        .sparsity_profile(paper_profile(&g))
        .batch_sizes(&[1, 2, 4, 8])
        .build()
        .expect("native sparse lenet5 builds");
    assert!(
        !engine.plan_costs().is_empty(),
        "sparse engine must expose plan costs for the planned mode"
    );

    let modes: [(&str, QueueConfig); 3] = [
        (
            "greedy",
            QueueConfig { fallback: BatchPolicy::Greedy, planned: false, ..QueueConfig::default() },
        ),
        (
            "padtofit",
            QueueConfig { fallback: BatchPolicy::PadToFit, planned: false, ..QueueConfig::default() },
        ),
        ("planned", QueueConfig { planned: true, ..QueueConfig::default() }),
    ];

    println!(
        "== serving bench (native sparse lenet5, open-loop Poisson, deadline {DEADLINE_MS}ms) ==\n"
    );
    let requests = 60;
    let mut rows = Vec::new();
    let mut report = Vec::new();
    for rps in [50.0, 200.0, 600.0] {
        for (mode, cfg) in &modes {
            let Some(r) = run(&engine, *cfg, rps, requests) else {
                eprintln!("run failed: {mode} @ {rps}");
                continue;
            };
            rows.push(vec![
                mode.to_string(),
                format!("{rps:.0}"),
                format!("{}", r.ok),
                format!("{} ({}/{})", r.missed, r.missed_queue, r.missed_infeasible),
                format!("{:.1}", r.p50_ms),
                format!("{:.1}", r.p99_ms),
                format!("{:.1}", r.queue_p50_ms),
                format!("{:.1}", r.queue_p95_ms),
                format!("{:.0}%", r.batch_util * 100.0),
                format!("{}", r.batches),
            ]);
            report.push(obj(vec![
                ("mode", Json::Str(mode.to_string())),
                ("offered_rps", Json::Num(rps)),
                ("requests", Json::Num(requests as f64)),
                ("ok", Json::Num(r.ok as f64)),
                ("deadline_missed", Json::Num(r.missed as f64)),
                ("deadline_missed_queue", Json::Num(r.missed_queue as f64)),
                ("deadline_missed_infeasible", Json::Num(r.missed_infeasible as f64)),
                ("p50_ms", Json::Num(r.p50_ms)),
                ("p99_ms", Json::Num(r.p99_ms)),
                ("queue_wait_p50_ms", Json::Num(r.queue_p50_ms)),
                ("queue_wait_p95_ms", Json::Num(r.queue_p95_ms)),
                ("batch_utilization", Json::Num(r.batch_util)),
                ("batches", Json::Num(r.batches as f64)),
            ]));
        }
    }
    print_table(
        &[
            "mode",
            "offered rps",
            "ok",
            "missed (q/inf)",
            "p50 ms",
            "p99 ms",
            "qwait p50",
            "qwait p95",
            "batch util",
            "batches",
        ],
        &rows,
    );
    // multi-model overload sweep: two models served together, offered
    // load at {0.5, 1.0, 2.0}× the calibrated full-batch capacity each,
    // with and without the admission controller
    let mut overload_rows = Vec::new();
    match (calibrate_upu(&engine), affine_cost(&engine)) {
        (Some(upu), Some(cost)) => {
            let capacity = cost.capacity_rps(8, upu);
            println!(
                "\n== overload sweep (2 models, calibrated capacity {capacity:.0} req/s \
                 per model, deadline {DEADLINE_MS}ms) ==\n"
            );
            let mut table = Vec::new();
            for load_x in [0.5, 1.0, 2.0] {
                for admission in [true, false] {
                    let Some(cells) =
                        overload_run(&engine, upu, capacity, load_x, admission, requests)
                    else {
                        eprintln!("overload run failed: {load_x}x admission={admission}");
                        continue;
                    };
                    for c in cells {
                        table.push(vec![
                            format!("{load_x:.1}x"),
                            if admission { "on" } else { "off" }.to_string(),
                            c.model.clone(),
                            format!("{}", c.ok),
                            format!("{}", c.missed),
                            format!("{}", c.shed),
                            format!("{:.1}", c.p99_ms),
                        ]);
                        overload_rows.push(obj(vec![
                            ("load_x", Json::Num(load_x)),
                            ("admission", Json::Bool(admission)),
                            ("model", Json::Str(c.model)),
                            ("requests_offered", Json::Num((requests / 2) as f64)),
                            ("ok", Json::Num(c.ok as f64)),
                            ("deadline_missed", Json::Num(c.missed as f64)),
                            ("shed", Json::Num(c.shed as f64)),
                            ("shed_deadline", Json::Num(c.shed_deadline as f64)),
                            ("shed_quota", Json::Num(c.shed_quota as f64)),
                            ("p99_ms", Json::Num(c.p99_ms)),
                        ]));
                    }
                }
            }
            print_table(
                &["offered", "admission", "model", "ok", "missed", "shed", "p99 ms"],
                &table,
            );
            println!(
                "(with admission on, overload turns queue-expiry misses into early sheds \
                 and the admitted p99 stays near the feasible bound)"
            );
        }
        _ => eprintln!("overload sweep skipped: engine did not calibrate"),
    }

    let obs_overhead = measure_obs_overhead(&engine);
    let telemetry_overhead = measure_telemetry_overhead(&engine);
    let out = Json::Obj(vec![
        ("bench".to_string(), Json::Str("serving".to_string())),
        ("deadline_ms".to_string(), Json::Num(DEADLINE_MS as f64)),
        ("rows".to_string(), Json::Arr(report)),
        ("overload_rows".to_string(), Json::Arr(overload_rows)),
        ("obs_overhead".to_string(), obs_overhead),
        ("telemetry_overhead".to_string(), telemetry_overhead),
    ]);
    let path = "BENCH_serving.json";
    match std::fs::write(path, out.to_string_pretty()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
    println!(
        "(planned = scheduler on ExecPlan::cost_at with online µs calibration; \
         greedy/padtofit = the pre-planner policy batcher)"
    );
}
