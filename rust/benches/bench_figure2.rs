//! Figure 2 bench: host-calibrated projection of all 7 series x 4 models,
//! plus the paper-vs-ours headline summary. (criterion is unavailable
//! offline; this is a harness=false bench using the shared stats module.)
//!
//! Run: cargo bench --bench bench_figure2

use cadnn::bench::{figure2, print_table};
use cadnn::costmodel::calibrate;
use cadnn::models;

fn print_rows(rows: &[figure2::Figure2Row]) {
    let mut table = Vec::new();
    for m in models::EVAL_MODELS {
        let mut row = vec![m.to_string()];
        for s in figure2::SERIES {
            row.push(
                rows.iter()
                    .find(|r| r.model == m && r.series == s)
                    .map(|r| format!("{:.1}", r.latency_ms))
                    .unwrap_or_default(),
            );
        }
        table.push(row);
    }
    let mut headers = vec!["model (ms)"];
    headers.extend(figure2::SERIES);
    print_table(&headers, &table);
}

fn main() {
    // Reference projection first: deterministic nominal ratios (the
    // numbers EXPERIMENTS.md quotes), then the live host calibration.
    println!("== bench_figure2: nominal-calibration projection (reference) ==\n");
    let nominal_rows = figure2::figure2(&calibrate::CalibrationTable::nominal(), 1.25);
    print_rows(&nominal_rows);
    let hn = figure2::headline(&nominal_rows);
    println!(
        "\nnominal headline: resnet50 SC {:.1} / SG {:.1} ms; vs TFLite {:.1}x, vs TVM {:.1}x\n",
        hn.resnet50_sc_ms, hn.resnet50_sg_ms, hn.max_speedup_vs_tflite, hn.max_speedup_vs_tvm
    );

    println!("== bench_figure2: host-calibrated device projection ==\n");
    let cores = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    if cores == 1 {
        println!(
            "NOTE: single-core host — the measured 'peak' proxy equals the\n\
             single-thread blocked GEMM, so the blocked/peak ratio saturates\n\
             at 1.0 and dense series are flattered vs sparse. The nominal\n\
             table above is the calibration-shape-corrected reference.\n"
        );
    }
    let calib = calibrate::measure_host();
    println!(
        "host: peak {:.1} GFLOPS, bw {:.1} GB/s; ratios naive={:.3} blocked={:.3} csr={:.3}\n",
        calib.host_peak_gflops,
        calib.host_bw_gbps,
        calib.direct_conv.compute,
        calib.gemm.compute,
        calib.csr_gemm.compute
    );
    // measured tuning uplift from a representative shape
    let t = cadnn::tuner::tune(784, 576, 128, 2 << 20, 7);
    let uplift = t.speedup_vs_default().clamp(1.0, 2.0);
    println!(
        "tuning uplift (measured): {:.2}x (default {:.0}us -> tuned {:.0}us)\n",
        uplift, t.default_us, t.best_us
    );

    let rows = figure2::figure2(&calib, uplift);
    print_rows(&rows);

    let h = figure2::headline(&rows);
    println!("\n== headline vs paper ==");
    println!("resnet50     CADNN-SC {:7.1} ms   (paper ~26 ms)", h.resnet50_sc_ms);
    println!("resnet50     CADNN-SG {:7.1} ms   (paper ~21 ms)", h.resnet50_sg_ms);
    println!("inception_v3 best     {:7.1} ms   (paper ~35 ms)", h.inception_best_ms);
    println!("max speedup vs TFLite  {:6.1}x    (paper: up to 8.8x)", h.max_speedup_vs_tflite);
    println!("max speedup vs TVM     {:6.1}x    (paper: up to 6.4x)", h.max_speedup_vs_tvm);

    // per-model speedup table (who wins, by what factor)
    println!("\n== speedups (TFLITE-DC / CADNN-SC and TVM-DC / CADNN-SC) ==");
    let get = |m: &str, s: &str| {
        rows.iter().find(|r| r.model == m && r.series == s).unwrap().latency_ms
    };
    let mut sp = Vec::new();
    for m in models::EVAL_MODELS {
        sp.push(vec![
            m.to_string(),
            format!("{:.1}x", get(m, "TFLITE-DC") / get(m, "CADNN-SC")),
            format!("{:.1}x", get(m, "TVM-DC") / get(m, "CADNN-SC")),
            format!("{:.1}x", get(m, "TVM-DG") / get(m, "CADNN-SG")),
        ]);
    }
    print_table(&["model", "vs TFLite(CPU)", "vs TVM(CPU)", "vs TVM(GPU)"], &sp);
}
