//! Table 2 bench target: model size / layer accounting vs the paper, and
//! §3 compression-rate pins — a fast, fully deterministic table.
//!
//! Run: cargo bench --bench bench_table2

use cadnn::bench::{print_table, table2};
use cadnn::compress::profile::paper_profile;
use cadnn::compress::size;
use cadnn::models;

fn main() {
    println!("== Table 2 ==\n");
    let rows: Vec<Vec<String>> = table2::table2()
        .iter()
        .map(|r| {
            vec![
                r.model.to_string(),
                format!("{:.1}", r.size_mb),
                format!("{:.1}", r.paper_size_mb),
                format!("{:+.1}%", 100.0 * (r.size_mb - r.paper_size_mb) / r.paper_size_mb),
                format!("{}", r.weight_layers),
                format!("{}", r.compute_layers),
                format!("{}", r.paper_layers),
            ]
        })
        .collect();
    print_table(
        &["model", "size MB", "paper MB", "delta", "w-layers", "c-layers", "paper layers"],
        &rows,
    );

    println!("\n== §3 pruning-rate pins ==\n");
    let mut rows = Vec::new();
    for (name, claim) in [
        ("lenet5", 348.0),
        ("alexnet", 36.0),
        ("vgg16", 34.0),
        ("resnet18", 8.0),
        ("resnet50", 9.2),
    ] {
        let g = models::build(name, 1).unwrap();
        let r = size::report(&g, &paper_profile(&g));
        rows.push(vec![
            name.to_string(),
            format!("{:.1}x", r.compression_rate),
            format!("{claim}x"),
            format!("{:+.1}%", 100.0 * (r.compression_rate - claim) / claim),
            format!("{:.0}x", r.storage_reduction_no_idx()),
        ]);
    }
    print_table(&["model", "ours", "paper", "delta", "4bit storage"], &rows);
}
