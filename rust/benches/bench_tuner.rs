//! Tuner ablation (paper §4.3 "optimization parameters selection"):
//! default vs tuned tiles on the Figure-2 models' GEMM shapes, plus the
//! pruned-search-vs-space statistics that justify the knowledge-based
//! pruning rules.
//!
//! Run: cargo bench --bench bench_tuner

use cadnn::bench::print_table;
use cadnn::exec::Personality;
use cadnn::models;
use cadnn::passes::layout;
use cadnn::tuner;

fn main() {
    println!("== optimization-parameter selection ablation ==\n");
    let mut all_rows = Vec::new();
    let mut geo = 1.0f64;
    let mut count = 0usize;
    for model in ["resnet50", "mobilenet_v1"] {
        let g = models::build(model, 1).unwrap();
        let lowered = Personality::CadnnDense.lower(&g);
        let plan = layout::plan(&lowered);
        let mut shapes: Vec<(usize, usize, usize)> = plan
            .per_node
            .values()
            .map(|i| (i.gemm_m.min(3136), i.gemm_k, i.gemm_n))
            .collect();
        shapes.sort();
        shapes.dedup();
        shapes.sort_by_key(|&(m, k, n)| std::cmp::Reverse(m * k * n));
        shapes.truncate(4);
        for (m, k, n) in shapes {
            let r = tuner::tune(m, k, n, 2 << 20, 7);
            geo *= r.speedup_vs_default();
            count += 1;
            all_rows.push(vec![
                model.to_string(),
                format!("{m}x{k}x{n}"),
                format!("{:.0}", r.default_us),
                format!("{:.0}", r.best_us),
                format!("{:.2}x", r.speedup_vs_default()),
                format!("mc{} nc{} kc{} u{}", r.best.mc, r.best.nc, r.best.kc, r.best.unroll),
                format!("{}", r.evaluated),
                format!("{}", r.pruned),
            ]);
        }
    }
    print_table(
        &["model", "shape", "default us", "tuned us", "speedup", "best", "evals", "pruned"],
        &all_rows,
    );
    println!(
        "\ngeometric-mean speedup {:.2}x over {} shapes — the measured uplift used in Figure 2",
        geo.powf(1.0 / count.max(1) as f64),
        count
    );

    // pruning-rule effectiveness: candidates vs full grid
    let (cands, pruned) = tuner::candidates(784, 576, 128, 2 << 20);
    println!(
        "\nsearch-space pruning (784x576x128): {} legal / {} pruned ({}% of the grid eliminated)",
        cands.len(),
        pruned,
        100 * pruned / (cands.len() + pruned)
    );
}
