//! Kernel microbenchmarks: dense GEMM schedules (naive / blocked /
//! parallel) and CSR sparse GEMM across sparsity levels, on
//! ResNet-50-representative shapes. Regenerates the efficiency ratios
//! behind the Figure 2 projection and the sparse-crossover analysis.
//!
//! Run: cargo bench --bench bench_kernels

use cadnn::bench::print_table;
use cadnn::compress::csr::CsrMatrix;
use cadnn::kernels::gemm::{gemm_blocked, gemm_naive, gemm_parallel};
use cadnn::kernels::sparse::csr_gemm;
use cadnn::kernels::Epilogue;
use cadnn::passes::layout::TileConfig;
use cadnn::util::rng::Rng;
use cadnn::util::stats;

fn gflops(flops: u64, us: f64) -> f64 {
    flops as f64 / us / 1e3
}

fn main() {
    let mut rng = Rng::new(11);
    println!("== dense GEMM schedules ==\n");
    let mut rows = Vec::new();
    for (m, k, n) in [(784usize, 576usize, 128usize), (3136, 64, 256), (196, 1152, 256)] {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut c = vec![0.0f32; m * n];
        let flops = 2 * (m * k * n) as u64;
        let t_naive = stats::Summary::from(&stats::measure_adaptive_us(150_000.0, 8, || {
            gemm_naive(&a, &b, &mut c, m, k, n)
        }))
        .unwrap()
        .p50;
        let t_blocked = stats::Summary::from(&stats::measure_adaptive_us(150_000.0, 10, || {
            gemm_blocked(&a, &b, &mut c, m, k, n, &TileConfig::DEFAULT, &Epilogue::None)
        }))
        .unwrap()
        .p50;
        let t_par = stats::Summary::from(&stats::measure_adaptive_us(150_000.0, 10, || {
            gemm_parallel(&a, &b, &mut c, m, k, n, &TileConfig::DEFAULT, &Epilogue::None)
        }))
        .unwrap()
        .p50;
        rows.push(vec![
            format!("{m}x{k}x{n}"),
            format!("{:.0} ({:.1})", t_naive, gflops(flops, t_naive)),
            format!("{:.0} ({:.1})", t_blocked, gflops(flops, t_blocked)),
            format!("{:.0} ({:.1})", t_par, gflops(flops, t_par)),
            format!("{:.1}x", t_naive / t_blocked),
        ]);
    }
    print_table(
        &["shape", "naive us (GF/s)", "blocked us (GF/s)", "parallel us (GF/s)", "blk/naive"],
        &rows,
    );

    println!("\n== CSR sparse GEMM vs sparsity (784x576x128) ==\n");
    let (m, k, n) = (784usize, 576usize, 128usize);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    let dense_b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
    let mut c = vec![0.0f32; m * n];
    let t_dense = stats::Summary::from(&stats::measure_adaptive_us(150_000.0, 10, || {
        gemm_blocked(&a, &dense_b, &mut c, m, k, n, &TileConfig::DEFAULT, &Epilogue::None)
    }))
    .unwrap()
    .p50;
    let mut rows = Vec::new();
    for sparsity in [0.5, 0.7, 0.8, 0.9, 0.95, 0.99] {
        let mut w = dense_b.clone();
        for v in w.iter_mut() {
            if rng.f64() < sparsity {
                *v = 0.0;
            }
        }
        let csr = CsrMatrix::from_dense(&w, k, n);
        let t_csr = stats::Summary::from(&stats::measure_adaptive_us(120_000.0, 10, || {
            csr_gemm(&a, &csr, &mut c, m, &Epilogue::None)
        }))
        .unwrap()
        .p50;
        rows.push(vec![
            format!("{:.0}%", sparsity * 100.0),
            format!("{}", csr.nnz()),
            format!("{:.0}", t_csr),
            format!("{:.0}", t_dense),
            format!("{:.2}x", t_dense / t_csr),
        ]);
    }
    print_table(
        &["sparsity", "nnz", "csr us", "dense us", "speedup"],
        &rows,
    );
    println!("\n(crossover: CSR beats blocked-dense once sparsity exceeds the row above 1.0x)");
}
