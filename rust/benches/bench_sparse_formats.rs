//! Sparse-format sweep: density x structure x format over
//! ResNet-50-shaped GEMM layers, with the planner's Auto choice recorded
//! next to the measured winner. Structures cover scattered magnitude
//! pruning (`random`), block-pattern ADMM (`block4x4`), and PatDNN
//! pattern pruning (`pattern4` — 4-entry patterns from an 8-pattern
//! library + connectivity pruning, 3x3 shapes only). Emits
//! `BENCH_sparse_formats.json` so the perf trajectory of the format
//! subsystem is recorded run over run.
//!
//! Run: cargo bench --bench bench_sparse_formats

use cadnn::bench::print_table;
use cadnn::compress::bsr::BsrMatrix;
use cadnn::compress::csr::CsrMatrix;
use cadnn::compress::pattern::{prune_patterns, PatternMatrix};
use cadnn::compress::qsparse::{QBsr, QCsr, QPattern};
use cadnn::compress::reorder;
use cadnn::kernels::bsr::bsr_gemm;
use cadnn::kernels::gemm::gemm_blocked;
use cadnn::kernels::lut::{qbsr_gemm, qcsr_gemm, qpattern_gemm};
use cadnn::kernels::pattern::pattern_gemm;
use cadnn::kernels::sparse::{csr_gemm, csr_gemm_parallel};
use cadnn::kernels::Epilogue;
use cadnn::passes::layout::TileConfig;
use cadnn::planner::db::{CostTable, PlanDb, Provenance, SpecKey};
use cadnn::planner::search::search_layer;
use cadnn::planner::{choose, plan_layer_valued, FormatPolicy, PlanCache, ValuePolicy};
use cadnn::util::json::{obj, Json};
use cadnn::util::rng::Rng;
use cadnn::util::stats;

/// (m, hwio, label): im2col GEMM shapes of representative ResNet-50
/// convolutions at 224x224 (m = output pixels, hwio = [kh, kw, cin,
/// cout] so the planner sees the same spatial-vs-GEMM margin the real
/// executor applies; k = kh*kw*cin, n = cout).
const SHAPES: [(usize, [usize; 4], &str); 4] = [
    (3136, [3, 3, 64, 64], "res2_3x3"),
    (3136, [1, 1, 64, 256], "res2_1x1"),
    (784, [3, 3, 128, 128], "res3_3x3"),
    (196, [3, 3, 256, 256], "res4_3x3"),
];

const DENSITIES: [f64; 4] = [0.1, 0.2, 0.3, 0.5];

fn random_weights(rng: &mut Rng, k: usize, n: usize, density: f64) -> Vec<f32> {
    let mut dense = vec![0.0f32; k * n];
    for v in dense.iter_mut() {
        if rng.f64() < density {
            *v = rng.normal() as f32;
        }
    }
    dense
}

/// Structured pruning: whole 4x4 blocks survive or die (the ADMM
/// block-pattern regime BSR exists for).
fn block_weights(rng: &mut Rng, k: usize, n: usize, density: f64) -> Vec<f32> {
    let mut dense = vec![0.0f32; k * n];
    for b in 0..k.div_ceil(4) {
        for j in 0..n.div_ceil(4) {
            if rng.f64() >= density {
                continue;
            }
            for p in 0..(k - b * 4).min(4) {
                for x in 0..(n - j * 4).min(4) {
                    dense[(b * 4 + p) * n + j * 4 + x] = rng.normal() as f32;
                }
            }
        }
    }
    dense
}

fn measure(mut f: impl FnMut()) -> f64 {
    let samples = stats::measure_adaptive_us(25_000.0, 5, || f());
    stats::Summary::from(&samples).unwrap().p50
}

/// PatDNN pattern pruning: 4-entry patterns from an 8-pattern library +
/// connectivity pruning, applied to an initially dense matrix.
fn pattern_weights(rng: &mut Rng, hwio: [usize; 4], density: f64) -> Vec<f32> {
    let (k, n) = (hwio[0] * hwio[1] * hwio[2], hwio[3]);
    let mut dense = vec![0.0f32; k * n];
    rng.fill_normal(&mut dense, 0.5);
    prune_patterns(&mut dense, hwio[0], hwio[1], hwio[2], hwio[3], 1.0 - density, 4, 8);
    dense
}

/// A/B the kernel counter hooks (rows/nnz/panel dispatch) on the
/// instrumented CSR entry point: p50 over the largest sweep shape with
/// the recorder off vs on. Returns the JSON blob embedded in the report
/// (`Json::Null` when the `obs` feature is compiled out — the hooks are
/// `if false` branches and cost exactly 0).
fn measure_obs_overhead(rng: &mut Rng) -> Json {
    if !cadnn::obs::COMPILED {
        println!("\nobs overhead: feature compiled out — counter cost is exactly 0");
        return Json::Null;
    }
    let (m, hwio) = (3136usize, [3usize, 3, 64, 64]);
    let (k, n) = (hwio[0] * hwio[1] * hwio[2], hwio[3]);
    let dense = random_weights(rng, k, n, 0.2);
    let csr = CsrMatrix::from_dense(&dense, k, n);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    let mut c = vec![0.0f32; m * n];
    cadnn::obs::disable();
    let off = measure(|| csr_gemm_parallel(&a, &csr, &mut c, m, &Epilogue::None));
    cadnn::obs::reset();
    cadnn::obs::enable();
    let on = measure(|| csr_gemm_parallel(&a, &csr, &mut c, m, &Epilogue::None));
    cadnn::obs::disable();
    cadnn::obs::reset();
    let pct = if off > 0.0 { (on / off - 1.0) * 100.0 } else { 0.0 };
    println!(
        "\nobs overhead: csr_gemm_parallel res2_3x3 @20% p50 {off:.1}us recorder-off vs \
         {on:.1}us recorder-on ({pct:+.2}%; target <2% enabled, 0 when compiled out)"
    );
    obj(vec![
        ("kernel", Json::Str("csr_gemm_parallel".to_string())),
        ("shape", Json::Str(format!("{m}x{k}x{n}"))),
        ("density", Json::Num(0.2)),
        ("disabled_p50_us", Json::Num(off)),
        ("enabled_p50_us", Json::Num(on)),
        ("overhead_pct", Json::Num(pct)),
    ])
}

/// Tuned (beam-searched) vs heuristic modeled cost, and warm-vs-cold
/// plan wall time through the plan database, over the sweep shapes at
/// 20% random density. The warm column is the `plan --tune --plan-db`
/// replay path: every spec answered by a JSON-round-tripped database,
/// zero searches, zero measurements.
fn measure_plan_db(rng: &mut Rng) -> Json {
    let table = CostTable::builtin();
    let mut cache = PlanCache::default();
    let mut db = PlanDb::in_memory();
    let mut rows = Vec::new();
    let mut report = Vec::new();
    let mut specs = Vec::new();
    for (m, hwio, label) in SHAPES {
        let (k, n) = (hwio[0] * hwio[1] * hwio[2], hwio[3]);
        let dense = random_weights(rng, k, n, 0.2);
        let csr = CsrMatrix::from_dense(&dense, k, n);
        let t0 = std::time::Instant::now();
        let heuristic = {
            let arts = cache.layer(label, &csr);
            plan_layer_valued(FormatPolicy::Auto, ValuePolicy::Auto, None, &csr, m, hwio, arts)
        };
        let heur_us = t0.elapsed().as_secs_f64() * 1e6;
        let spec = SpecKey::from_layer(
            FormatPolicy::Auto,
            ValuePolicy::Auto,
            None,
            &csr,
            hwio,
            db.device_fp(),
        );
        let arts = cache.layer(label, &csr);
        let t1 = std::time::Instant::now();
        let out = search_layer(
            FormatPolicy::Auto,
            ValuePolicy::Auto,
            None,
            &csr,
            m,
            hwio,
            &table,
            &[],
            false,
            spec.seed(),
            arts,
        );
        let cold_us = t1.elapsed().as_secs_f64() * 1e6;
        let tuned = out.best().expect("nonempty search").clone();
        db.insert(spec, out.candidates, Provenance::Modeled);
        specs.push((spec, label, heuristic, tuned, heur_us, cold_us));
    }
    // warm replay: the round-tripped database answers every spec
    let mut warm_db =
        PlanDb::load_str(&db.to_json().to_string_pretty()).expect("fresh database round-trips");
    for (spec, label, heuristic, tuned, heur_us, cold_us) in specs {
        let t2 = std::time::Instant::now();
        let hit = warm_db.best_plan(&spec).expect("warm database answers its own spec");
        let warm_us = t2.elapsed().as_secs_f64() * 1e6;
        assert_eq!(hit, tuned.plan, "warm lookup must replay the cold search");
        let ratio = if heuristic.cost_per_row > 0.0 {
            tuned.cost / heuristic.cost_per_row
        } else {
            1.0
        };
        rows.push(vec![
            label.to_string(),
            format!("{:.0}", heuristic.cost_per_row),
            heuristic.format.label().to_string(),
            format!("{:.0}", tuned.cost),
            tuned.plan.format.label().to_string(),
            format!("{ratio:.3}"),
            format!("{cold_us:.0}"),
            format!("{warm_us:.1}"),
        ]);
        report.push(obj(vec![
            ("layer", Json::Str(label.to_string())),
            ("density", Json::Num(0.2)),
            ("heuristic_cost", Json::Num(heuristic.cost_per_row)),
            ("heuristic_format", Json::Str(heuristic.format.label().to_string())),
            ("tuned_cost", Json::Num(tuned.cost)),
            ("tuned_format", Json::Str(tuned.plan.format.label().to_string())),
            ("tuned_over_heuristic", Json::Num(ratio)),
            ("heuristic_plan_us", Json::Num(heur_us)),
            ("cold_plan_us", Json::Num(cold_us)),
            ("warm_plan_us", Json::Num(warm_us)),
        ]));
    }
    println!("\n== plan search vs heuristic, cold vs warm plan time (modeled cost units) ==\n");
    print_table(
        &[
            "layer", "heur_cost", "heur_fmt", "tuned_cost", "tuned_fmt", "tuned/heur", "cold_us",
            "warm_us",
        ],
        &rows,
    );
    Json::Arr(report)
}

fn main() {
    let mut rng = Rng::new(17);
    let mut report: Vec<Json> = Vec::new();
    let mut rows = Vec::new();
    for (m, hwio, label) in SHAPES {
        let (k, n) = (hwio[0] * hwio[1] * hwio[2], hwio[3]);
        let spatial = hwio[0] * hwio[1] > 1;
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let mut c = vec![0.0f32; m * n];
        for structure in ["random", "block4x4", "pattern4"] {
            for density in DENSITIES {
                if structure == "pattern4" && (!spatial || density > 4.0 / 9.0) {
                    // pattern pruning needs spatial kernels and cannot
                    // express densities above entries/positions
                    continue;
                }
                let dense = match structure {
                    "random" => random_weights(&mut rng, k, n, density),
                    "block4x4" => block_weights(&mut rng, k, n, density),
                    _ => pattern_weights(&mut rng, hwio, density),
                };
                let csr = CsrMatrix::from_dense(&dense, k, n);
                let bsr41 = BsrMatrix::from_dense(&dense, k, n, 4, 1);
                let bsr44 = BsrMatrix::from_dense(&dense, k, n, 4, 4);
                let perm = reorder::cluster_columns(&dense, k, n, 4);
                let reordered = reorder::permute_cols(&dense, k, n, &perm);
                let bsr44r = BsrMatrix::from_dense(&reordered, k, n, 4, 4);

                let t_dense = measure(|| {
                    gemm_blocked(&a, &dense, &mut c, m, k, n, &TileConfig::DEFAULT, &Epilogue::None)
                });
                let t_csr = measure(|| csr_gemm(&a, &csr, &mut c, m, &Epilogue::None));
                let t_b41 = measure(|| bsr_gemm(&a, &bsr41, &mut c, m, &Epilogue::None));
                let t_b44 = measure(|| bsr_gemm(&a, &bsr44, &mut c, m, &Epilogue::None));
                let t_b44r = measure(|| bsr_gemm(&a, &bsr44r, &mut c, m, &Epilogue::None));
                // the value_bits axis: same formats, codebook-packed
                // values through the LUT kernels (feeds COST_LUT_Q8/Q4)
                let qcsr8 = QCsr::from_csr(&csr, 8);
                let qcsr4 = QCsr::from_csr(&csr, 4);
                let t_csr_q8 = measure(|| qcsr_gemm(&a, &qcsr8, &mut c, m, &Epilogue::None));
                let t_csr_q4 = measure(|| qcsr_gemm(&a, &qcsr4, &mut c, m, &Epilogue::None));
                let qb44 = QBsr::from_bsr(&bsr44, 8);
                let t_b44_q8 = measure(|| qbsr_gemm(&a, &qb44, &mut c, m, &Epilogue::None));
                let (t_pat, t_pat_q4, pat_kernels) = if spatial {
                    let pat = PatternMatrix::from_dense(&dense, hwio[0], hwio[1], hwio[2], n);
                    let qpat4 = QPattern::from_pattern(&pat, 4);
                    (
                        measure(|| pattern_gemm(&a, &pat, &mut c, m, &Epilogue::None)),
                        measure(|| qpattern_gemm(&a, &qpat4, &mut c, m, &Epilogue::None)),
                        pat.kernels(),
                    )
                } else {
                    (f64::NAN, f64::NAN, 0)
                };

                let auto = choose(FormatPolicy::Auto, &csr, m, hwio);
                let mut times = vec![
                    ("dense", t_dense),
                    ("csr", t_csr),
                    ("bsr4x1", t_b41),
                    ("bsr4x4", t_b44),
                    ("bsr4x4+reorder", t_b44r),
                    ("csr+q8", t_csr_q8),
                    ("csr+q4", t_csr_q4),
                    ("bsr4x4+q8", t_b44_q8),
                ];
                if spatial {
                    times.push(("pattern", t_pat));
                    times.push(("pattern+q4", t_pat_q4));
                }
                let winner = times
                    .iter()
                    .min_by(|x, y| x.1.partial_cmp(&y.1).unwrap())
                    .unwrap()
                    .0;
                rows.push(vec![
                    label.to_string(),
                    structure.to_string(),
                    format!("{:.0}%", density * 100.0),
                    format!("{t_dense:.0}"),
                    format!("{t_csr:.0}"),
                    format!("{t_b41:.0}"),
                    format!("{t_b44:.0}"),
                    format!("{t_b44r:.0}"),
                    format!("{t_csr_q4:.0}"),
                    if spatial { format!("{t_pat:.0}") } else { "-".to_string() },
                    if spatial { format!("{t_pat_q4:.0}") } else { "-".to_string() },
                    winner.to_string(),
                    auto.format.label(),
                ]);
                report.push(obj(vec![
                    ("shape", Json::Str(format!("{m}x{k}x{n}"))),
                    ("layer", Json::Str(label.to_string())),
                    ("structure", Json::Str(structure.to_string())),
                    ("density", Json::Num(density)),
                    ("fill_bsr4x1", Json::Num(bsr41.fill_ratio())),
                    ("fill_bsr4x4", Json::Num(bsr44.fill_ratio())),
                    ("fill_bsr4x4_reordered", Json::Num(bsr44r.fill_ratio())),
                    ("pattern_kernels", Json::Num(pat_kernels as f64)),
                    (
                        "us",
                        obj(times.iter().map(|(f, t)| (*f, Json::Num(*t))).collect()),
                    ),
                    ("winner", Json::Str(winner.to_string())),
                    ("auto_choice", Json::Str(auto.format.label())),
                    ("auto_reorder", Json::Bool(auto.reorder)),
                ]));
            }
        }
    }
    println!("== sparse formats on ResNet-50 GEMM shapes (us, serial kernels) ==\n");
    print_table(
        &[
            "layer", "structure", "density", "dense", "csr", "bsr4x1", "bsr4x4", "bsr4x4+r",
            "csr_q4", "pattern", "pat_q4", "winner", "auto",
        ],
        &rows,
    );
    let plan_db = measure_plan_db(&mut rng);
    let obs_overhead = measure_obs_overhead(&mut rng);
    let out = Json::Obj(vec![
        ("bench".to_string(), Json::Str("sparse_formats".to_string())),
        ("rows".to_string(), Json::Arr(report)),
        ("plan_db".to_string(), plan_db),
        ("obs_overhead".to_string(), obs_overhead),
    ]);
    let path = "BENCH_sparse_formats.json";
    match std::fs::write(path, out.to_string_pretty()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
    println!(
        "(planner cost constants live in cadnn::planner; retune them against the \
         'winner' column when kernels change)"
    );
}
