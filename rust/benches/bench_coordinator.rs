//! Serving-coordinator bench: throughput/latency across offered load and
//! batch policies over the real PJRT artifacts. Quantifies coordinator
//! overhead (the §Perf L3 target: overhead << execution time).
//!
//! Requires `make artifacts`. Run: cargo bench --bench bench_coordinator

use cadnn::bench::print_table;
use cadnn::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig};
use cadnn::util::rng::Rng;

fn run(variant: &str, rps: f64, requests: usize, policy: BatchPolicy) -> Option<Vec<String>> {
    let cfg = CoordinatorConfig {
        artifacts_dir: "artifacts".into(),
        model: "lenet5".into(),
        variant: variant.into(),
        max_batch: 8,
        max_wait_us: 2_000,
        policy,
    };
    let coord = Coordinator::start(cfg).ok()?;
    let mut rng = Rng::new(77);
    let mut rxs = Vec::new();
    for _ in 0..requests {
        let mut img = vec![0.0f32; coord.input_len];
        rng.fill_normal(&mut img, 0.5);
        rxs.push(coord.submit(img).ok()?);
        std::thread::sleep(std::time::Duration::from_secs_f64(rng.exp(rps)));
    }
    for rx in rxs {
        let _ = rx.recv();
    }
    let m = &coord.metrics;
    let lat = m.latency_summary()?;
    let exec = m.exec_summary()?;
    let row = vec![
        variant.to_string(),
        format!("{rps:.0}"),
        format!("{:?}", policy),
        format!("{:.1}", m.throughput_rps()),
        format!("{:.1}", lat.p50 / 1e3),
        format!("{:.1}", lat.p99 / 1e3),
        format!("{:.0}%", m.batch_utilization() * 100.0),
        format!("{:.1}", exec.p50 / 1e3),
    ];
    coord.shutdown().ok()?;
    Some(row)
}

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("bench_coordinator: artifacts/ missing — run `make artifacts` first");
        return;
    }
    println!("== coordinator serving bench (lenet5, Poisson arrivals) ==\n");
    let mut rows = Vec::new();
    for variant in ["dense", "sparse"] {
        for rps in [30.0, 120.0, 400.0] {
            for policy in [BatchPolicy::PadToFit, BatchPolicy::Greedy] {
                if let Some(r) = run(variant, rps, 60, policy) {
                    rows.push(r);
                }
            }
        }
    }
    print_table(
        &["variant", "offered rps", "policy", "achieved rps", "p50 ms", "p99 ms", "batch util", "exec p50 ms"],
        &rows,
    );
    println!("\n(p50 - exec p50 gap at low load ~= coordinator overhead + batching wait)");
}
