//! artifacts/manifest.json schema (written by python/compile/aot.py).

use crate::util::json::Json;
use anyhow::{anyhow, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct ManifestEntry {
    pub name: String,
    pub variant: String,
    pub batch: usize,
    pub path: String,
    pub input_shape: Vec<usize>,
    pub classes: usize,
    pub accuracy: f64,
    pub compression_rate: f64,
}

#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub models: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let format = j.get("format").and_then(|v| v.as_usize()).unwrap_or(0);
        if format != 1 {
            return Err(anyhow!("unsupported manifest format {format}"));
        }
        let mut models = Vec::new();
        for m in j
            .get("models")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("manifest: missing models"))?
        {
            models.push(ManifestEntry {
                name: m
                    .get("name")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("entry missing name"))?
                    .to_string(),
                variant: m
                    .get("variant")
                    .and_then(|v| v.as_str())
                    .unwrap_or("dense")
                    .to_string(),
                batch: m
                    .get("batch")
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| anyhow!("entry missing batch"))?,
                path: m
                    .get("path")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("entry missing path"))?
                    .to_string(),
                input_shape: m
                    .get("input_shape")
                    .and_then(|v| v.as_usize_vec())
                    .ok_or_else(|| anyhow!("entry missing input_shape"))?,
                classes: m.get("classes").and_then(|v| v.as_usize()).unwrap_or(0),
                accuracy: m.get("accuracy").and_then(|v| v.as_f64()).unwrap_or(0.0),
                compression_rate: m
                    .get("compression_rate")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(1.0),
            });
        }
        Ok(Manifest { models })
    }

    /// Distinct (name, variant) pairs.
    pub fn model_variants(&self) -> Vec<(String, String)> {
        let mut out: Vec<(String, String)> = self
            .models
            .iter()
            .map(|e| (e.name.clone(), e.variant.clone()))
            .collect();
        out.sort();
        out.dedup();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "models": [
        {"name": "lenet5", "variant": "dense", "batch": 1,
         "path": "lenet5_dense_b1.hlo.txt",
         "input_shape": [1, 28, 28, 1], "classes": 10,
         "accuracy": 0.99, "compression_rate": 1.0},
        {"name": "lenet5", "variant": "sparse", "batch": 4,
         "path": "lenet5_sparse_b4.hlo.txt",
         "input_shape": [4, 28, 28, 1], "classes": 10,
         "accuracy": 0.97, "compression_rate": 2.5}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.models.len(), 2);
        assert_eq!(m.models[0].name, "lenet5");
        assert_eq!(m.models[1].batch, 4);
        assert_eq!(m.models[1].input_shape, vec![4, 28, 28, 1]);
        assert!(m.models[1].compression_rate > 2.0);
    }

    #[test]
    fn model_variants_deduped() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(
            m.model_variants(),
            vec![
                ("lenet5".to_string(), "dense".to_string()),
                ("lenet5".to_string(), "sparse".to_string())
            ]
        );
    }

    #[test]
    fn rejects_bad_format() {
        assert!(Manifest::parse(r#"{"format": 9, "models": []}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn rejects_missing_or_wrong_format_field() {
        // absent format -> treated as 0 -> unsupported
        let e = Manifest::parse(r#"{"models": []}"#).err().unwrap();
        assert!(e.to_string().contains("unsupported manifest format"), "{e}");
        // non-numeric format -> same rejection
        let e = Manifest::parse(r#"{"format": "one", "models": []}"#).err().unwrap();
        assert!(e.to_string().contains("unsupported manifest format"), "{e}");
    }

    #[test]
    fn rejects_missing_models_list() {
        let e = Manifest::parse(r#"{"format": 1}"#).err().unwrap();
        assert!(e.to_string().contains("missing models"), "{e}");
        // models present but not an array
        let e = Manifest::parse(r#"{"format": 1, "models": 3}"#).err().unwrap();
        assert!(e.to_string().contains("missing models"), "{e}");
    }

    #[test]
    fn empty_models_list_parses_to_empty_manifest() {
        let m = Manifest::parse(r#"{"format": 1, "models": []}"#).unwrap();
        assert!(m.models.is_empty());
        assert!(m.model_variants().is_empty());
    }

    #[test]
    fn rejects_entries_missing_required_fields() {
        // each required field, dropped one at a time
        let full = r#"{"name": "m", "batch": 1, "path": "p", "input_shape": [1, 2]}"#;
        assert!(Manifest::parse(&wrap(full)).is_ok());
        for (missing, entry) in [
            ("name", r#"{"batch": 1, "path": "p", "input_shape": [1, 2]}"#),
            ("batch", r#"{"name": "m", "path": "p", "input_shape": [1, 2]}"#),
            ("path", r#"{"name": "m", "batch": 1, "input_shape": [1, 2]}"#),
            ("input_shape", r#"{"name": "m", "batch": 1, "path": "p"}"#),
        ] {
            let e = Manifest::parse(&wrap(entry)).err()
                .unwrap_or_else(|| panic!("entry without {missing} must be rejected"));
            assert!(e.to_string().contains(missing), "{missing}: {e}");
        }
    }

    #[test]
    fn optional_fields_get_defaults() {
        let m = wrap(r#"{"name": "m", "batch": 2, "path": "p", "input_shape": [2, 4]}"#);
        let m = Manifest::parse(&m).unwrap();
        let e = &m.models[0];
        assert_eq!(e.variant, "dense");
        assert_eq!(e.classes, 0);
        assert_eq!(e.accuracy, 0.0);
        assert_eq!(e.compression_rate, 1.0);
    }

    fn wrap(entry: &str) -> String {
        format!(r#"{{"format": 1, "models": [{entry}]}}"#)
    }
}
