//! artifacts/manifest.json schema (written by python/compile/aot.py).
//!
//! Format 1 entries may carry an optional `exec_plan` — the per-layer
//! sparse-format decisions a [`crate::planner::ExecPlan`] serializes —
//! so a deployed artifact pins the formats it was validated with.
//! Manifests written before the planner existed (or with a malformed
//! plan) simply load with `exec_plan: None` and the runtime replans.

use crate::planner::ExecPlan;
use crate::util::json::{obj, Json};
use anyhow::{anyhow, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct ManifestEntry {
    pub name: String,
    pub variant: String,
    pub batch: usize,
    pub path: String,
    pub input_shape: Vec<usize>,
    pub classes: usize,
    pub accuracy: f64,
    pub compression_rate: f64,
    /// Planned per-layer formats; `None` for old manifests (pre-planner)
    /// or dense variants.
    pub exec_plan: Option<ExecPlan>,
    /// Converged serving-cost calibration (µs per plan cost unit) from a
    /// previous serving run of this entry — `serve::Scheduler`s seeded
    /// with it are deadline-accurate from their first batch instead of
    /// re-learning the scale online. `None` for old manifests or entries
    /// never served.
    pub us_per_unit: Option<f64>,
    /// The plan-database device generation (`PlanDb::device_fp`, see
    /// `docs/PLANDB.md`) this entry's `exec_plan` was searched under.
    /// Serialized as a 16-hex-digit string; `None` for old manifests or
    /// heuristic (non-database) plans. A deployment can compare it
    /// against its database's current generation to detect a plan that
    /// predates a recalibration.
    pub plan_generation: Option<u64>,
}

#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub models: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let format = j.get("format").and_then(|v| v.as_usize()).unwrap_or(0);
        if format != 1 {
            return Err(anyhow!("unsupported manifest format {format}"));
        }
        let mut models = Vec::new();
        for m in j
            .get("models")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("manifest: missing models"))?
        {
            models.push(ManifestEntry {
                name: m
                    .get("name")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("entry missing name"))?
                    .to_string(),
                variant: m
                    .get("variant")
                    .and_then(|v| v.as_str())
                    .unwrap_or("dense")
                    .to_string(),
                batch: m
                    .get("batch")
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| anyhow!("entry missing batch"))?,
                path: m
                    .get("path")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("entry missing path"))?
                    .to_string(),
                input_shape: m
                    .get("input_shape")
                    .and_then(|v| v.as_usize_vec())
                    .ok_or_else(|| anyhow!("entry missing input_shape"))?,
                classes: m.get("classes").and_then(|v| v.as_usize()).unwrap_or(0),
                accuracy: m.get("accuracy").and_then(|v| v.as_f64()).unwrap_or(0.0),
                compression_rate: m
                    .get("compression_rate")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(1.0),
                exec_plan: m.get("exec_plan").and_then(ExecPlan::from_json),
                us_per_unit: m
                    .get("us_per_unit")
                    .and_then(|v| v.as_f64())
                    .filter(|u| u.is_finite() && *u > 0.0),
                plan_generation: m
                    .get("plan_generation")
                    .and_then(|v| v.as_str())
                    .and_then(|s| {
                        if s.len() == 16 { u64::from_str_radix(s, 16).ok() } else { None }
                    }),
            });
        }
        Ok(Manifest { models })
    }

    /// Serialize back to the format-1 JSON [`Manifest::parse`] accepts
    /// (entries with a plan carry `exec_plan`; entries without omit it).
    pub fn to_json(&self) -> Json {
        let models: Vec<Json> = self
            .models
            .iter()
            .map(|e| {
                let mut kv = vec![
                    ("name", Json::Str(e.name.clone())),
                    ("variant", Json::Str(e.variant.clone())),
                    ("batch", Json::Num(e.batch as f64)),
                    ("path", Json::Str(e.path.clone())),
                    (
                        "input_shape",
                        Json::Arr(e.input_shape.iter().map(|&v| Json::Num(v as f64)).collect()),
                    ),
                    ("classes", Json::Num(e.classes as f64)),
                    ("accuracy", Json::Num(e.accuracy)),
                    ("compression_rate", Json::Num(e.compression_rate)),
                ];
                if let Some(plan) = &e.exec_plan {
                    kv.push(("exec_plan", plan.to_json()));
                }
                if let Some(u) = e.us_per_unit {
                    kv.push(("us_per_unit", Json::Num(u)));
                }
                if let Some(g) = e.plan_generation {
                    kv.push(("plan_generation", Json::Str(format!("{g:016x}"))));
                }
                obj(kv)
            })
            .collect();
        obj(vec![("format", Json::Num(1.0)), ("models", Json::Arr(models))])
    }

    /// Record a converged serving-cost calibration (µs per plan cost
    /// unit, from `serve::Scheduler::us_per_unit` /
    /// `MetricsSnapshot::us_per_unit`) on every batch variant of
    /// (model, variant), so the next process seeds its schedulers
    /// deadline-accurate. Returns how many entries were updated
    /// (0 for unknown models or a non-positive calibration).
    pub fn record_calibration(&mut self, name: &str, variant: &str, us_per_unit: f64) -> usize {
        if !us_per_unit.is_finite() || us_per_unit <= 0.0 {
            return 0;
        }
        let mut n = 0;
        for e in self.models.iter_mut() {
            if e.name == name && e.variant == variant {
                e.us_per_unit = Some(us_per_unit);
                n += 1;
            }
        }
        n
    }

    /// Stamp the plan-database device generation onto every batch
    /// variant of (model, variant) whose entry carries an `exec_plan`,
    /// so a deployment can tell whether the pinned plans predate a
    /// later `cadnn calibrate --apply-db` recalibration. Returns how
    /// many entries were updated (planless entries are skipped — a
    /// generation without a plan is meaningless).
    pub fn record_plan_generation(&mut self, name: &str, variant: &str, gen: u64) -> usize {
        let mut n = 0;
        for e in self.models.iter_mut() {
            if e.name == name && e.variant == variant && e.exec_plan.is_some() {
                e.plan_generation = Some(gen);
                n += 1;
            }
        }
        n
    }

    /// Distinct (name, variant) pairs.
    pub fn model_variants(&self) -> Vec<(String, String)> {
        let mut out: Vec<(String, String)> = self
            .models
            .iter()
            .map(|e| (e.name.clone(), e.variant.clone()))
            .collect();
        out.sort();
        out.dedup();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "models": [
        {"name": "lenet5", "variant": "dense", "batch": 1,
         "path": "lenet5_dense_b1.hlo.txt",
         "input_shape": [1, 28, 28, 1], "classes": 10,
         "accuracy": 0.99, "compression_rate": 1.0},
        {"name": "lenet5", "variant": "sparse", "batch": 4,
         "path": "lenet5_sparse_b4.hlo.txt",
         "input_shape": [4, 28, 28, 1], "classes": 10,
         "accuracy": 0.97, "compression_rate": 2.5}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.models.len(), 2);
        assert_eq!(m.models[0].name, "lenet5");
        assert_eq!(m.models[1].batch, 4);
        assert_eq!(m.models[1].input_shape, vec![4, 28, 28, 1]);
        assert!(m.models[1].compression_rate > 2.0);
    }

    #[test]
    fn model_variants_deduped() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(
            m.model_variants(),
            vec![
                ("lenet5".to_string(), "dense".to_string()),
                ("lenet5".to_string(), "sparse".to_string())
            ]
        );
    }

    #[test]
    fn rejects_bad_format() {
        assert!(Manifest::parse(r#"{"format": 9, "models": []}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn rejects_missing_or_wrong_format_field() {
        // absent format -> treated as 0 -> unsupported
        let e = Manifest::parse(r#"{"models": []}"#).err().unwrap();
        assert!(e.to_string().contains("unsupported manifest format"), "{e}");
        // non-numeric format -> same rejection
        let e = Manifest::parse(r#"{"format": "one", "models": []}"#).err().unwrap();
        assert!(e.to_string().contains("unsupported manifest format"), "{e}");
    }

    #[test]
    fn rejects_missing_models_list() {
        let e = Manifest::parse(r#"{"format": 1}"#).err().unwrap();
        assert!(e.to_string().contains("missing models"), "{e}");
        // models present but not an array
        let e = Manifest::parse(r#"{"format": 1, "models": 3}"#).err().unwrap();
        assert!(e.to_string().contains("missing models"), "{e}");
    }

    #[test]
    fn empty_models_list_parses_to_empty_manifest() {
        let m = Manifest::parse(r#"{"format": 1, "models": []}"#).unwrap();
        assert!(m.models.is_empty());
        assert!(m.model_variants().is_empty());
    }

    #[test]
    fn rejects_entries_missing_required_fields() {
        // each required field, dropped one at a time
        let full = r#"{"name": "m", "batch": 1, "path": "p", "input_shape": [1, 2]}"#;
        assert!(Manifest::parse(&wrap(full)).is_ok());
        for (missing, entry) in [
            ("name", r#"{"batch": 1, "path": "p", "input_shape": [1, 2]}"#),
            ("batch", r#"{"name": "m", "path": "p", "input_shape": [1, 2]}"#),
            ("path", r#"{"name": "m", "batch": 1, "input_shape": [1, 2]}"#),
            ("input_shape", r#"{"name": "m", "batch": 1, "path": "p"}"#),
        ] {
            let e = Manifest::parse(&wrap(entry)).err()
                .unwrap_or_else(|| panic!("entry without {missing} must be rejected"));
            assert!(e.to_string().contains(missing), "{missing}: {e}");
        }
    }

    #[test]
    fn old_manifest_without_plan_still_loads() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.models.iter().all(|e| e.exec_plan.is_none()));
    }

    #[test]
    fn exec_plan_round_trips_through_json() {
        use crate::compress::qsparse::ValueBits;
        use crate::planner::{LayerPlan, SparseFormat};
        let mut plan = ExecPlan::default();
        plan.layers.insert("c1".into(), LayerPlan::csr());
        plan.layers.insert(
            "f1".into(),
            LayerPlan {
                format: SparseFormat::Bsr { br: 4, bc: 4 },
                value_bits: ValueBits::Q8,
                reorder: true,
                parallel_cutover: 192,
                cost_per_row: 57.6,
                rows_per_image: 196,
            },
        );
        let mut m = Manifest::parse(SAMPLE).unwrap();
        m.models[1].exec_plan = Some(plan.clone());
        let text = m.to_json().to_string_pretty();
        let back = Manifest::parse(&text).unwrap();
        assert_eq!(back.models, m.models);
        assert_eq!(back.models[1].exec_plan.as_ref(), Some(&plan));
        assert!(back.models[0].exec_plan.is_none());
    }

    /// The serving-cost calibration satellite: `us_per_unit` round-trips
    /// next to `exec_plan`, old manifests load without it, and junk
    /// values are dropped rather than poisoning fresh schedulers.
    #[test]
    fn us_per_unit_roundtrip_and_fallback() {
        let mut m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.models.iter().all(|e| e.us_per_unit.is_none()), "old manifests: None");
        assert_eq!(m.record_calibration("lenet5", "sparse", 0.37), 1);
        assert_eq!(m.record_calibration("lenet5", "nope", 0.37), 0);
        assert_eq!(m.record_calibration("lenet5", "dense", -1.0), 0, "junk rejected");
        let text = m.to_json().to_string_pretty();
        let back = Manifest::parse(&text).unwrap();
        assert_eq!(back.models[1].us_per_unit, Some(0.37));
        assert_eq!(back.models[0].us_per_unit, None);
        // junk in the file is filtered at parse time
        let entry = r#"{"name": "m", "batch": 1, "path": "p", "input_shape": [1, 2],
                        "us_per_unit": -3.0}"#;
        let m = Manifest::parse(&wrap(entry)).unwrap();
        assert_eq!(m.models[0].us_per_unit, None);
    }

    /// `plan_generation` rides next to `exec_plan` as a 16-hex-digit
    /// string: it round-trips, only attaches to planned entries, old
    /// manifests load without it, and malformed values degrade to None.
    #[test]
    fn plan_generation_roundtrip_and_degrade() {
        use crate::planner::LayerPlan;
        let mut m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.models.iter().all(|e| e.plan_generation.is_none()), "old manifests: None");
        // planless entries refuse the stamp
        assert_eq!(m.record_plan_generation("lenet5", "sparse", 0xabcd), 0);
        let mut plan = ExecPlan::default();
        plan.layers.insert("c1".into(), LayerPlan::csr());
        m.models[1].exec_plan = Some(plan);
        assert_eq!(m.record_plan_generation("lenet5", "sparse", 0xabcd), 1);
        let text = m.to_json().to_string_pretty();
        assert!(text.contains("\"000000000000abcd\""), "hex-string encoding: {text}");
        let back = Manifest::parse(&text).unwrap();
        assert_eq!(back.models[1].plan_generation, Some(0xabcd));
        assert_eq!(back.models[0].plan_generation, None);
        // wrong width / non-hex / non-string values all degrade to None
        for junk in [r#""abcd""#, r#""zzzzzzzzzzzzzzzz""#, "12"] {
            let entry = format!(
                r#"{{"name": "m", "batch": 1, "path": "p", "input_shape": [1, 2],
                    "plan_generation": {junk}}}"#
            );
            let m = Manifest::parse(&wrap(&entry)).unwrap();
            assert_eq!(m.models[0].plan_generation, None, "junk {junk} must degrade");
        }
    }

    #[test]
    fn malformed_plan_degrades_to_none() {
        // an unknown format label must not fail the whole manifest — the
        // entry loads planless and the runtime replans
        let entry = r#"{"name": "m", "batch": 1, "path": "p", "input_shape": [1, 2],
                        "exec_plan": {"layers": {"c1": {"format": "coo"}}}}"#;
        let m = Manifest::parse(&wrap(entry)).unwrap();
        assert!(m.models[0].exec_plan.is_none());
    }

    #[test]
    fn optional_fields_get_defaults() {
        let m = wrap(r#"{"name": "m", "batch": 2, "path": "p", "input_shape": [2, 4]}"#);
        let m = Manifest::parse(&m).unwrap();
        let e = &m.models[0];
        assert_eq!(e.variant, "dense");
        assert_eq!(e.classes, 0);
        assert_eq!(e.accuracy, 0.0);
        assert_eq!(e.compression_rate, 1.0);
    }

    fn wrap(entry: &str) -> String {
        format!(r#"{{"format": 1, "models": [{entry}]}}"#)
    }
}
