//! PJRT runtime: load AOT HLO-text artifacts (built by `make artifacts`)
//! and execute them from the Rust request path. Python never runs here.
//!
//! One compiled executable per (model, variant, batch) — PJRT programs
//! are shape-static, so the coordinator's dynamic batcher picks among
//! batch variants (manifest-driven).
//!
//! Serving code should not use this module directly: wrap it in
//! [`crate::api::ArtifactBackend`] (or `Engine::artifacts`), which
//! normalizes errors to [`crate::error::CadnnError`] and plugs into the
//! coordinator. Note the in-tree `xla` crate is an offline stub that
//! fails at `Runtime::open`; swap in the real binding to execute
//! artifacts.

pub mod manifest;

pub use manifest::{Manifest, ManifestEntry};

use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// A compiled (model, variant, batch) program.
pub struct LoadedModel {
    pub entry: ManifestEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedModel {
    /// Execute on a flat f32 input of `entry.input_shape`; returns logits
    /// (batch * classes).
    pub fn run(&self, input: &[f32]) -> Result<Vec<f32>> {
        let want: usize = self.entry.input_shape.iter().product();
        if input.len() != want {
            return Err(anyhow!(
                "input length {} != expected {} for {}",
                input.len(),
                want,
                self.entry.path
            ));
        }
        let dims: Vec<i64> = self.entry.input_shape.iter().map(|&d| d as i64).collect();
        let x = xla::Literal::vec1(input).reshape(&dims)?;
        let result = self.exe.execute::<xla::Literal>(&[x])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True -> 1-tuple
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// PJRT CPU client + model registry.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    pub manifest: Manifest,
    /// (name, variant) -> batch-ascending loaded models.
    models: BTreeMap<(String, String), Vec<LoadedModel>>,
}

impl Runtime {
    /// Create a CPU runtime over an artifacts directory (reads
    /// manifest.json; compiles nothing yet).
    pub fn open(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let manifest = Manifest::parse(&text)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, artifacts_dir: dir, manifest, models: BTreeMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile every batch variant of (model, variant). Idempotent.
    pub fn load(&mut self, name: &str, variant: &str) -> Result<usize> {
        let key = (name.to_string(), variant.to_string());
        if self.models.contains_key(&key) {
            return Ok(self.models[&key].len());
        }
        let mut loaded = Vec::new();
        let mut entries: Vec<ManifestEntry> = self
            .manifest
            .models
            .iter()
            .filter(|e| e.name == name && e.variant == variant)
            .cloned()
            .collect();
        entries.sort_by_key(|e| e.batch);
        if entries.is_empty() {
            return Err(anyhow!("no manifest entries for {name}/{variant}"));
        }
        for entry in entries {
            let path = self.artifacts_dir.join(&entry.path);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            loaded.push(LoadedModel { entry, exe });
        }
        let n = loaded.len();
        self.models.insert(key, loaded);
        Ok(n)
    }

    /// Available batch sizes for a loaded (model, variant).
    pub fn batches(&self, name: &str, variant: &str) -> Vec<usize> {
        self.models
            .get(&(name.to_string(), variant.to_string()))
            .map(|v| v.iter().map(|m| m.entry.batch).collect())
            .unwrap_or_default()
    }

    /// Fetch the loaded model with exactly this batch.
    pub fn get(&self, name: &str, variant: &str, batch: usize) -> Option<&LoadedModel> {
        self.models
            .get(&(name.to_string(), variant.to_string()))?
            .iter()
            .find(|m| m.entry.batch == batch)
    }
}

#[cfg(test)]
mod tests {
    // PJRT-dependent integration tests live in rust/tests/ (they need
    // built artifacts); here only pure helpers are covered via manifest.rs.
}
