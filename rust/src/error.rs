//! Typed errors for the whole crate.
//!
//! `CadnnError` replaces the ad-hoc `Result<T, String>` plumbing that the
//! seed used in `ir`, `exec`, and `compress`. It is a hand-rolled
//! `thiserror`-style enum (no new dependencies): every variant carries the
//! data a caller needs to react programmatically, `Display` renders a
//! human-readable message, and the `std::error::Error` impl lets `anyhow`
//! layers (the CLI, examples, coordinator plumbing) consume it with `?`.

use std::fmt;

/// Every way the CADNN stack can fail, from graph construction through
/// backend execution.
#[derive(Debug, Clone, PartialEq)]
pub enum CadnnError {
    /// A graph failed structural validation.
    InvalidGraph { graph: String, reason: String },
    /// A node uses an op (or op configuration) the native executor cannot run.
    UnsupportedOp { node: String, reason: String },
    /// An executable node has no generated weights (internal invariant).
    MissingWeights { node: String },
    /// Input tensor shape does not match the model's input shape.
    InputShape { expected: Vec<usize>, got: Vec<usize> },
    /// Flat input buffer has the wrong length (or is otherwise malformed).
    InvalidInput { reason: String },
    /// `models::build` does not know this model name.
    UnknownModel { name: String },
    /// The requested batch size has no compiled/built variant.
    BatchUnavailable { batch: usize, available: Vec<usize> },
    /// A backend could not be constructed (e.g. PJRT missing, artifacts absent).
    BackendUnavailable { backend: String, reason: String },
    /// A CSR matrix failed structural validation.
    InvalidCsr { reason: String },
    /// artifacts/manifest.json is malformed.
    Manifest { reason: String },
    /// A forward pass failed mid-execution.
    Execution { reason: String },
    /// Builder/config misuse (e.g. batch variants on a fixed graph source).
    Config { reason: String },
    /// A textual model (`.cadnn`, see `docs/MODEL_FORMAT.md`) failed to
    /// parse. Carries the 1-based source position and the offending
    /// token so front-end diagnostics stay actionable.
    Parse { line: usize, col: usize, token: String, reason: String },
}

impl CadnnError {
    /// Shorthand for [`CadnnError::Execution`].
    pub fn execution(reason: impl Into<String>) -> CadnnError {
        CadnnError::Execution { reason: reason.into() }
    }

    /// Shorthand for [`CadnnError::Config`].
    pub fn config(reason: impl Into<String>) -> CadnnError {
        CadnnError::Config { reason: reason.into() }
    }

    /// Shorthand for [`CadnnError::Parse`].
    pub fn parse(
        line: usize,
        col: usize,
        token: impl Into<String>,
        reason: impl Into<String>,
    ) -> CadnnError {
        CadnnError::Parse { line, col, token: token.into(), reason: reason.into() }
    }
}

impl fmt::Display for CadnnError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CadnnError::InvalidGraph { graph, reason } => {
                write!(f, "invalid graph '{graph}': {reason}")
            }
            CadnnError::UnsupportedOp { node, reason } => {
                write!(f, "unsupported op at node '{node}': {reason}")
            }
            CadnnError::MissingWeights { node } => {
                write!(f, "missing weights for node '{node}'")
            }
            CadnnError::InputShape { expected, got } => {
                write!(f, "input shape {got:?} != model input {expected:?}")
            }
            CadnnError::InvalidInput { reason } => write!(f, "invalid input: {reason}"),
            CadnnError::UnknownModel { name } => write!(f, "unknown model '{name}'"),
            CadnnError::BatchUnavailable { batch, available } => {
                write!(f, "batch {batch} unavailable (have {available:?})")
            }
            CadnnError::BackendUnavailable { backend, reason } => {
                write!(f, "backend '{backend}' unavailable: {reason}")
            }
            CadnnError::InvalidCsr { reason } => write!(f, "invalid CSR matrix: {reason}"),
            CadnnError::Manifest { reason } => write!(f, "manifest: {reason}"),
            CadnnError::Execution { reason } => write!(f, "execution failed: {reason}"),
            CadnnError::Config { reason } => write!(f, "invalid configuration: {reason}"),
            CadnnError::Parse { line, col, token, reason } => {
                write!(f, "parse error at {line}:{col} near '{token}': {reason}")
            }
        }
    }
}

impl std::error::Error for CadnnError {}

/// Lets property-test closures (`Result<(), String>`) use `?` on fallible
/// CADNN calls.
impl From<CadnnError> for String {
    fn from(e: CadnnError) -> String {
        e.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_informative() {
        let e = CadnnError::BatchUnavailable { batch: 3, available: vec![1, 2, 4] };
        let s = e.to_string();
        assert!(s.contains('3') && s.contains("[1, 2, 4]"), "{s}");
    }

    #[test]
    fn anyhow_consumes_cadnn_errors() {
        fn fails() -> anyhow::Result<()> {
            Err(CadnnError::UnknownModel { name: "nope".into() })?;
            Ok(())
        }
        let e = fails().unwrap_err();
        assert!(e.to_string().contains("unknown model 'nope'"));
    }

    #[test]
    fn parse_errors_carry_position() {
        let e = CadnnError::parse(3, 14, "convv2d", "unknown op");
        assert_eq!(e.to_string(), "parse error at 3:14 near 'convv2d': unknown op");
        match e {
            CadnnError::Parse { line, col, .. } => assert_eq!((line, col), (3, 14)),
            other => panic!("expected Parse, got {other:?}"),
        }
    }

    #[test]
    fn string_conversion_for_prop_closures() {
        let s: String = CadnnError::execution("boom").into();
        assert_eq!(s, "execution failed: boom");
    }
}
