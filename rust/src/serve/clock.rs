//! Time as a seam: the serving layer reads *microseconds since server
//! start* through a [`Clock`] trait instead of calling
//! [`std::time::Instant::now`] directly. Production servers run on
//! [`SystemClock`]; tests and the deterministic discrete-event harness
//! ([`crate::serve::sim::SimServer`]) inject a [`VirtualClock`] they
//! advance by hand, so deadline expiry, batching windows, admission
//! predictions, and throughput windows are all reproducible — no
//! sleeps, no wall-clock tolerances.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Monotonic microsecond source for the serving layer. Implementations
/// must be cheap (called on every submit/flush) and monotonic per
/// instance; absolute zero is the clock's own epoch, not Unix time.
pub trait Clock: Send + Sync + std::fmt::Debug {
    /// Microseconds elapsed since this clock's epoch.
    fn now_us(&self) -> u64;
}

/// Shared handle servers and workers thread around.
pub type SharedClock = Arc<dyn Clock>;

/// Wall-clock time, epoch = construction. The default for real servers.
#[derive(Debug)]
pub struct SystemClock {
    epoch: Instant,
}

impl SystemClock {
    /// A system clock whose zero is "now".
    pub fn new() -> SystemClock {
        SystemClock { epoch: Instant::now() }
    }
}

impl Default for SystemClock {
    fn default() -> SystemClock {
        SystemClock::new()
    }
}

impl Clock for SystemClock {
    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }
}

/// A fresh [`SystemClock`] behind the shared handle.
pub fn system() -> SharedClock {
    Arc::new(SystemClock::new())
}

/// Hand-advanced clock for deterministic tests. Cloning shares the
/// underlying counter, so a test can hold one handle while the server
/// under test reads another. Time never moves unless the test moves it.
#[derive(Debug, Clone, Default)]
pub struct VirtualClock {
    us: Arc<AtomicU64>,
}

impl VirtualClock {
    /// A virtual clock at t = 0 µs.
    pub fn new() -> VirtualClock {
        VirtualClock::default()
    }

    /// Move time forward by `us` microseconds; returns the new time.
    pub fn advance(&self, us: u64) -> u64 {
        self.us.fetch_add(us, Ordering::SeqCst) + us
    }

    /// Jump to an absolute time. Monotonicity is the caller's contract:
    /// the discrete-event harness only ever sets nondecreasing values.
    pub fn set_us(&self, us: u64) {
        self.us.store(us, Ordering::SeqCst);
    }

    /// Shared-handle form of this clock.
    pub fn shared(&self) -> SharedClock {
        Arc::new(self.clone())
    }
}

impl Clock for VirtualClock {
    fn now_us(&self) -> u64 {
        self.us.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_is_shared_across_clones() {
        let c = VirtualClock::new();
        let view: SharedClock = c.shared();
        assert_eq!(view.now_us(), 0);
        assert_eq!(c.advance(250), 250);
        assert_eq!(view.now_us(), 250);
        c.set_us(1_000_000);
        assert_eq!(view.now_us(), 1_000_000);
    }

    #[test]
    fn system_clock_is_monotonic_from_its_own_epoch() {
        let c = SystemClock::new();
        let a = c.now_us();
        let b = c.now_us();
        assert!(b >= a);
    }
}
