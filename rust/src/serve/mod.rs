//! Multi-model serving: named engines behind one [`Server`], a request
//! queue per model replica, and a planner-informed, deadline-aware
//! dynamic batcher with fleet-scale admission control.
//!
//! The paper's real-time claim (26 ms ResNet-50) is a statement about
//! *latency under load*, so the serving layer must understand what a
//! batch costs before it commits to one. This module closes that loop
//! twice: per batch, every registered model carries its
//! [`crate::planner::ExecPlan`], the plan prices each batch variant
//! ([`crate::planner::ExecPlan::cost_at`]), and the [`Scheduler`] picks
//! the batch that maximizes throughput *subject to the tightest pending
//! request's deadline*; per request, the same price × calibration feeds
//! a global [`admission`] controller that refuses work **at enqueue**
//! when the committed backlog says a deadline cannot be met (or a
//! model's quota / the server-wide backlog budget is full) — graceful
//! shedding instead of queueing to death.
//!
//! One logical model may be backed by `N` worker **replicas**
//! ([`QueueConfig::replicas`]) sharing the engine's `PlanCache`d build:
//! submits go to the shortest replica queue, and an idle replica steals
//! the tail half of the longest sibling queue, so a burst dispatched to
//! one queue cannot strand work while other replicas sit idle.
//!
//! ```ignore
//! use cadnn::serve::{AdmissionConfig, QueueConfig, ServeRequest, Server};
//!
//! let server = Server::builder()
//!     .engine("resnet50", &resnet)            // default queue config
//!     .engine_with(
//!         "lenet5",
//!         &lenet,
//!         QueueConfig { replicas: 2, quota_us: Some(50_000), ..QueueConfig::default() },
//!     )
//!     .admission(AdmissionConfig::default())
//!     .build()?;
//!
//! let resp = server.infer(
//!     ServeRequest::new("resnet50", image).deadline_ms(30).topk(5),
//! )?;
//! match resp.outcome {
//!     Ok(logits) => println!("top-1 {:?}", resp.topk),
//!     Err(e) => eprintln!("{e}"),             // Deadline | Shed | Backend
//! }
//! let stats = server.stats();                 // merged per-model snapshots
//! server.shutdown()?;
//! ```
//!
//! All deadline math runs on microseconds from an injectable
//! [`clock::Clock`], and the batching/stealing/shedding pipeline is
//! factored into pure helpers shared with [`sim::SimServer`], a
//! single-threaded discrete-event harness on a [`clock::VirtualClock`] —
//! overload behavior is tested deterministically, with exact
//! assertions and zero sleeps. Request lifecycle, deadline semantics,
//! the shed taxonomy, and the cost model are documented in
//! `docs/SERVING.md`. The old single-model
//! [`crate::coordinator::Coordinator`] remains as a thin deprecated shim
//! over this module.

pub mod admission;
pub mod clock;
pub mod metrics;
pub mod registry;
pub mod scheduler;
pub mod sim;

pub use admission::{AdmissionConfig, AdmitDecision, ShedCause};
pub use clock::{Clock, SharedClock, SystemClock, VirtualClock};
pub use metrics::{Metrics, MetricsSnapshot};
pub use registry::{ModelEntry, Registry};
pub use scheduler::{pick_batch, BatchPolicy, Scheduler};
pub use sim::SimServer;

use crate::api::Backend;
use crate::error::CadnnError;
use crate::obs::{self, ArgValue};
use crate::planner::ExecPlan;
use admission::ModelAdmission;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// Per-model queue/batcher knobs.
#[derive(Debug, Clone, Copy)]
pub struct QueueConfig {
    /// Queue depth considered per batch decision.
    pub max_batch: usize,
    /// Batching window: how long the worker waits for co-riders after
    /// the first queued request (a pending deadline shortens the wait).
    pub max_wait_us: u64,
    /// Policy used while no cost model / calibration is available (and
    /// always, when `planned` is off).
    pub fallback: BatchPolicy,
    /// Use the planner cost model for batch-size choice when the backend
    /// provides one. Off = always the plain `fallback` policy (the
    /// pre-planner behavior, kept for A/B benchmarking). Also disables
    /// admission pricing for this model (no cost model ⇒ unpriced).
    pub planned: bool,
    /// Seed the scheduler's units→µs scale (µs per plan cost unit) so a
    /// fresh process is deadline-accurate from its first batch. `None`
    /// falls back to the backend's persisted calibration
    /// ([`crate::api::Backend::calibration`], e.g. the artifact
    /// manifest's `us_per_unit`), then to online learning. Ignored when
    /// `planned` is off.
    pub calibration: Option<f64>,
    /// Worker replicas backing this logical model (min 1). Values > 1
    /// require an engine-registered model: each replica clones the
    /// [`crate::api::Engine`], sharing its built instances.
    pub replicas: usize,
    /// Per-model committed-work quota in µs: when the model's admitted
    /// outstanding cost would exceed this, new requests are shed with
    /// [`ServeError::Shed`] (`cause: Quota`). At least one outstanding
    /// request is always admitted. `None` = unlimited.
    pub quota_us: Option<u64>,
}

impl Default for QueueConfig {
    fn default() -> Self {
        QueueConfig {
            max_batch: 8,
            max_wait_us: 2_000,
            fallback: BatchPolicy::PadToFit,
            planned: true,
            calibration: None,
            replicas: 1,
            quota_us: None,
        }
    }
}

/// One inference request: which model, the image, and per-request
/// options (deadline, top-k).
#[derive(Debug, Clone)]
pub struct ServeRequest {
    /// Registry name of the target model.
    pub model: String,
    /// Flat NHWC image (`input_len` floats of the target model).
    pub input: Vec<f32>,
    /// Answer-by budget relative to submission. A request still queued
    /// when its deadline passes is answered with
    /// [`ServeError::Deadline`] instead of being executed; the scheduler
    /// also avoids batch sizes whose estimated run time would blow the
    /// tightest queued deadline, and the admission controller sheds the
    /// request up front when its completion prediction already exceeds
    /// the budget.
    pub deadline_us: Option<u64>,
    /// Attach the top-k (class, logit) pairs to the response.
    pub topk: Option<usize>,
}

impl ServeRequest {
    pub fn new(model: impl Into<String>, input: Vec<f32>) -> ServeRequest {
        ServeRequest { model: model.into(), input, deadline_us: None, topk: None }
    }

    pub fn deadline_us(mut self, us: u64) -> ServeRequest {
        self.deadline_us = Some(us);
        self
    }

    pub fn deadline_ms(self, ms: u64) -> ServeRequest {
        self.deadline_us(ms.saturating_mul(1_000))
    }

    pub fn topk(mut self, k: usize) -> ServeRequest {
        self.topk = Some(k);
        self
    }
}

/// Why a request failed while the server stayed alive. (Shutdown is
/// signalled differently: the reply channel closes.)
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The backend rejected or failed the batch this request rode in.
    Backend(String),
    /// The request's deadline cannot be (or was not) met; it was never
    /// executed. `waited_us == 0` means the admission controller shed it
    /// at enqueue (predicted completion past the budget); `waited_us > 0`
    /// means it expired in queue. (A request that *starts* executing is
    /// always answered with its logits — clients can compare
    /// `latency_us` against their budget for the overran-while-running
    /// case.)
    Deadline {
        /// The request's deadline budget.
        deadline_us: u64,
        /// How long it had been queued when the miss was detected.
        waited_us: u64,
    },
    /// Refused at enqueue by quota/backlog accounting — the model's
    /// `quota_us` or the server's `max_backlog_us` committed-work budget
    /// was full. Never executed, never queued.
    Shed {
        /// Which budget refused it.
        cause: ShedCause,
        /// The admission controller's completion estimate at refusal.
        predicted_us: u64,
    },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Backend(msg) => write!(f, "backend error: {msg}"),
            ServeError::Deadline { deadline_us, waited_us } => write!(
                f,
                "deadline missed: budget {deadline_us}µs, waited {waited_us}µs"
            ),
            ServeError::Shed { cause, predicted_us } => write!(
                f,
                "shed ({cause}): predicted completion {predicted_us}µs"
            ),
        }
    }
}

impl std::error::Error for ServeError {}

/// One request's answer.
#[derive(Debug, Clone)]
pub struct ServeResponse {
    pub id: u64,
    /// Which registered model served (or expired) this request.
    pub model: String,
    /// Logits on success, or an explicit serve error.
    pub outcome: Result<Vec<f32>, ServeError>,
    /// (class, logit) pairs, descending — present iff the request asked
    /// for top-k and succeeded.
    pub topk: Option<Vec<(usize, f32)>>,
    /// end-to-end latency (enqueue -> reply), microseconds
    pub latency_us: f64,
    /// batch this request rode in (0 for requests never executed)
    pub batch: usize,
}

impl ServeResponse {
    /// Logits, if the request succeeded.
    pub fn logits(&self) -> Option<&[f32]> {
        self.outcome.as_ref().ok().map(|v| v.as_slice())
    }

    /// Consume into logits or the serve error.
    pub fn into_logits(self) -> Result<Vec<f32>, ServeError> {
        self.outcome
    }
}

/// Queued request, inside a replica queue. All times are µs on the
/// server's [`Clock`].
pub(crate) struct Pending {
    pub(crate) id: u64,
    pub(crate) input: Vec<f32>,
    pub(crate) enqueued_us: u64,
    pub(crate) deadline_at_us: Option<u64>,
    pub(crate) deadline_us: Option<u64>,
    /// Commitment charged at admission; released at the terminal reply.
    pub(crate) cost_us: u64,
    /// Request trace id ([`obs::next_trace_id`]); 0 = untraced. Carried
    /// through queue → batch → execution so every span the request
    /// touches shares one id.
    pub(crate) trace: u64,
    pub(crate) topk: Option<usize>,
    pub(crate) reply: Sender<ServeResponse>,
}

/// What a worker reports back once its backend is up.
struct ReadyInfo {
    input_shape: Vec<usize>,
    classes: usize,
    batch_sizes: Vec<usize>,
    plan: Option<ExecPlan>,
    plan_costs: Vec<(usize, f64)>,
}

/// One replica's FIFO queue + its worker's wakeup channel.
struct ReplicaQueue {
    q: Mutex<VecDeque<Pending>>,
    cv: Condvar,
    /// Mirror of `q.len()` for lock-free dispatch/steal victim choice.
    depth: AtomicU64,
}

impl ReplicaQueue {
    fn new() -> ReplicaQueue {
        ReplicaQueue {
            q: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            depth: AtomicU64::new(0),
        }
    }

    fn lock(&self) -> MutexGuard<'_, VecDeque<Pending>> {
        self.q.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// One logical model's replica set.
struct Shard {
    replicas: Vec<Arc<ReplicaQueue>>,
    shutdown: AtomicBool,
}

impl Shard {
    fn new(n: usize) -> Shard {
        Shard {
            replicas: (0..n.max(1)).map(|_| Arc::new(ReplicaQueue::new())).collect(),
            shutdown: AtomicBool::new(false),
        }
    }

    fn signal_shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
        for rq in &self.replicas {
            rq.cv.notify_all();
        }
    }
}

struct ModelHandle {
    shard: Arc<Shard>,
    workers: Vec<std::thread::JoinHandle<Result<(), CadnnError>>>,
    /// One metrics recorder per replica (index-aligned with the shard).
    metrics: Vec<Arc<Metrics>>,
    admission: Arc<ModelAdmission>,
    input_len: usize,
}

type BackendFactory = Box<dyn FnOnce() -> Result<Box<dyn Backend>, CadnnError> + Send>;

struct ModelSpec {
    name: String,
    factory: BackendFactory,
    cfg: QueueConfig,
    engine: Option<crate::api::Engine>,
}

/// Configure a [`Server`]: register models, then `build` to spawn one
/// worker (queue + scheduler + metrics) per model replica.
#[derive(Default)]
pub struct ServerBuilder {
    specs: Vec<ModelSpec>,
    clock: Option<SharedClock>,
    admission: AdmissionConfig,
    telemetry: Option<TelemetryConfig>,
}

/// Live telemetry export knobs (see [`crate::obs::export`]): where the
/// JSONL stream goes, how spans are sampled, how often the background
/// flusher wakes.
#[derive(Debug, Clone)]
pub struct TelemetryConfig {
    /// Telemetry JSONL destination (line-appended, rotated at
    /// `max_bytes` to `<path>.1`).
    pub path: std::path::PathBuf,
    /// Head sampling rate in `[0, 1]` ([`crate::obs::SampleConfig::rate`]);
    /// tail-kept traces (sheds, deadline misses, errors, p99 stragglers)
    /// survive regardless.
    pub sample_rate: f64,
    /// Flush period. The flusher also drains once more at shutdown, so
    /// short-lived servers still emit their final snapshot.
    pub period_ms: u64,
    /// Rotation cap per telemetry file generation.
    pub max_bytes: u64,
}

impl TelemetryConfig {
    pub fn new(path: impl Into<std::path::PathBuf>) -> TelemetryConfig {
        TelemetryConfig {
            path: path.into(),
            sample_rate: obs::SampleConfig::default().rate,
            period_ms: 500,
            max_bytes: obs::export::DEFAULT_MAX_BYTES,
        }
    }
}

impl ServerBuilder {
    /// Register an engine under `name` with the default [`QueueConfig`].
    pub fn engine(self, name: impl Into<String>, engine: &crate::api::Engine) -> ServerBuilder {
        self.engine_with(name, engine, QueueConfig::default())
    }

    /// Register an engine under `name` with explicit queue knobs.
    pub fn engine_with(
        mut self,
        name: impl Into<String>,
        engine: &crate::api::Engine,
        cfg: QueueConfig,
    ) -> ServerBuilder {
        let e = engine.clone();
        let for_worker = e.clone();
        self.specs.push(ModelSpec {
            name: name.into(),
            factory: Box::new(move || Ok(Box::new(for_worker) as Box<dyn Backend>)),
            cfg,
            engine: Some(e),
        });
        self
    }

    /// Register a backend built *inside* the worker thread (required for
    /// backends whose handles are not `Send`, e.g. real PJRT). Limited
    /// to `replicas == 1`: the factory runs once, so there is nothing to
    /// clone a second replica from.
    pub fn backend_with<F>(
        mut self,
        name: impl Into<String>,
        factory: F,
        cfg: QueueConfig,
    ) -> ServerBuilder
    where
        F: FnOnce() -> Result<Box<dyn Backend>, CadnnError> + Send + 'static,
    {
        self.specs.push(ModelSpec {
            name: name.into(),
            factory: Box::new(factory),
            cfg,
            engine: None,
        });
        self
    }

    /// Server-wide admission policy (default: enabled, no global
    /// backlog cap).
    pub fn admission(mut self, cfg: AdmissionConfig) -> ServerBuilder {
        self.admission = cfg;
        self
    }

    /// Enable always-on production tracing: turns the span recorder on
    /// and spawns a background flusher that samples traces
    /// ([`crate::obs::Sampler`]), watches cost-model drift
    /// ([`crate::obs::DriftWatchdog`]), and appends JSONL telemetry to
    /// `cfg.path`. Export failures degrade to a warning — they never
    /// block or fail serving.
    pub fn telemetry(mut self, cfg: TelemetryConfig) -> ServerBuilder {
        self.telemetry = Some(cfg);
        self
    }

    /// Inject the time source every queue/deadline/admission decision
    /// reads (default: a fresh [`SystemClock`]). Threaded workers poll
    /// in bounded slices, so a frozen [`VirtualClock`] cannot hang them —
    /// but for fully deterministic virtual-time tests prefer
    /// [`sim::SimServer`], which shares this module's pipeline helpers.
    pub fn clock(mut self, clock: SharedClock) -> ServerBuilder {
        self.clock = Some(clock);
        self
    }

    /// Spawn every model's replica workers and wait until each backend
    /// is up (so client latency measurements see steady state and load
    /// errors surface here).
    pub fn build(self) -> Result<Server, CadnnError> {
        if self.specs.is_empty() {
            return Err(CadnnError::config("no models registered"));
        }
        let clock = self.clock.unwrap_or_else(clock::system);
        if self.telemetry.is_some() {
            // telemetry implies tracing: spans must exist to be sampled
            obs::enable();
        }
        let global_committed = Arc::new(AtomicU64::new(0));
        let mut handles: BTreeMap<String, ModelHandle> = BTreeMap::new();
        let mut registry = Registry::default();
        let mut flusher_sources: Vec<FlusherSource> = Vec::new();
        // On any failure, tear down everything spawned so far: signal
        // every shard, then join — condvar workers never exit on their
        // own (there is no channel whose closure could stop them).
        let fail = |handles: &mut BTreeMap<String, ModelHandle>, e: CadnnError| {
            shutdown_handles(handles);
            Err(e)
        };
        for spec in self.specs {
            if handles.contains_key(&spec.name) {
                return fail(
                    &mut handles,
                    CadnnError::config(format!("model '{}' registered twice", spec.name)),
                );
            }
            let replicas = spec.cfg.replicas.max(1);
            if replicas > 1 && spec.engine.is_none() {
                return fail(
                    &mut handles,
                    CadnnError::config(format!(
                        "model '{}': replicas > 1 requires an engine-registered model \
                         (a backend factory runs once and cannot be cloned)",
                        spec.name
                    )),
                );
            }
            let shard = Arc::new(Shard::new(replicas));
            let metrics: Vec<Arc<Metrics>> = (0..replicas)
                .map(|_| Arc::new(Metrics::with_clock(Arc::clone(&clock))))
                .collect();
            let adm = Arc::new(ModelAdmission::new(
                self.admission,
                replicas,
                spec.cfg.max_wait_us,
                spec.cfg.quota_us,
                Arc::clone(&metrics[0]),
                Arc::clone(&global_committed),
            ));
            let mut factories: Vec<BackendFactory> = vec![spec.factory];
            for _ in 1..replicas {
                let e = spec.engine.clone().expect("checked above: replicas > 1 has an engine");
                factories.push(Box::new(move || Ok(Box::new(e) as Box<dyn Backend>)));
            }
            flusher_sources.push(FlusherSource {
                model: spec.name.clone(),
                metrics: metrics.clone(),
                admission: Arc::clone(&adm),
            });
            let (ready_tx, ready_rx) = channel::<Result<ReadyInfo, CadnnError>>();
            let mut workers = Vec::with_capacity(replicas);
            for (r, factory) in factories.into_iter().enumerate() {
                let ctx = WorkerCtx {
                    model: spec.name.clone(),
                    replica: r,
                    cfg: spec.cfg,
                    shard: Arc::clone(&shard),
                    metrics: Arc::clone(&metrics[r]),
                    clock: Arc::clone(&clock),
                    admission: Arc::clone(&adm),
                };
                let ready = ready_tx.clone();
                let w = std::thread::Builder::new()
                    .name(format!("cadnn-serve-{}-{r}", spec.name))
                    .spawn(move || worker_loop(ctx, factory, ready));
                match w {
                    Ok(w) => workers.push(w),
                    Err(e) => {
                        shard.signal_shutdown();
                        for w in workers {
                            let _ = w.join();
                        }
                        return fail(
                            &mut handles,
                            CadnnError::execution(format!("spawn failed: {e}")),
                        );
                    }
                }
            }
            drop(ready_tx);
            let mut info: Option<ReadyInfo> = None;
            let mut first_err: Option<CadnnError> = None;
            for _ in 0..replicas {
                match ready_rx.recv() {
                    Ok(Ok(i)) => info = info.or(Some(i)),
                    Ok(Err(e)) => first_err = first_err.or(Some(e)),
                    Err(_) => {
                        first_err = first_err.or(Some(CadnnError::execution(format!(
                            "serve worker for '{}' died during startup",
                            spec.name
                        ))))
                    }
                }
            }
            let handle = ModelHandle { shard, workers, metrics, admission: adm, input_len: 0 };
            if let Some(e) = first_err {
                handle.shard.signal_shutdown();
                for w in handle.workers {
                    let _ = w.join();
                }
                return fail(&mut handles, e);
            }
            let info = info.expect("no error implies every replica reported ready");
            handle.admission.set_pricing(&info.plan_costs);
            // how the engine's plan was obtained (memo / database hits,
            // searches, measurements) — captured before the engine moves
            // into the entry
            let plan_tuning = spec.engine.as_ref().and_then(|e| e.tune_stats());
            let entry = ModelEntry {
                name: spec.name.clone(),
                engine: spec.engine,
                plan: info.plan,
                plan_costs: info.plan_costs,
                plan_tuning,
                input_shape: info.input_shape,
                classes: info.classes,
                batch_sizes: info.batch_sizes,
                replicas,
            };
            let input_len = entry.input_len();
            registry.insert(entry);
            handles.insert(spec.name, ModelHandle { input_len, ..handle });
        }
        let telemetry = self
            .telemetry
            .map(|cfg| TelemetryFlusher::spawn(cfg, flusher_sources));
        Ok(Server { handles, registry, next_id: AtomicU64::new(1), clock, telemetry })
    }
}

/// What the telemetry flusher reads per model: replica metrics to merge
/// and the admission state to stamp on top — the same inputs as
/// [`Server::stats`].
struct FlusherSource {
    model: String,
    metrics: Vec<Arc<Metrics>>,
    admission: Arc<ModelAdmission>,
}

/// Background telemetry thread: periodically drains the span recorder,
/// streams spans through the drift watchdog and the sampler, and
/// appends JSONL lines ([`crate::obs::export`]). Runs entirely off the
/// request path — workers only ever touch their lock-free span rings.
struct TelemetryFlusher {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

/// Shutdown-responsiveness slice for the flusher's sleep.
const FLUSHER_POLL: Duration = Duration::from_millis(10);

impl TelemetryFlusher {
    fn spawn(cfg: TelemetryConfig, sources: Vec<FlusherSource>) -> TelemetryFlusher {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let thread = std::thread::Builder::new()
            .name("cadnn-telemetry".to_string())
            .spawn(move || flusher_loop(cfg, sources, flag));
        let thread = match thread {
            Ok(t) => Some(t),
            Err(e) => {
                crate::util::log::log(
                    crate::util::log::Level::Warn,
                    "obs::export",
                    format_args!("telemetry flusher spawn failed: {e} — telemetry disabled"),
                );
                None
            }
        };
        TelemetryFlusher { stop, thread }
    }

    /// Idempotent: the thread handle is taken on the first call.
    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn flusher_loop(cfg: TelemetryConfig, sources: Vec<FlusherSource>, stop: Arc<AtomicBool>) {
    use crate::obs::export;
    let mut writer = export::TelemetryWriter::open(&cfg.path, cfg.max_bytes);
    let mut sampler = obs::Sampler::new(obs::SampleConfig {
        rate: cfg.sample_rate,
        ..obs::SampleConfig::default()
    });
    let mut drift = obs::DriftWatchdog::new(obs::DriftConfig::default());
    loop {
        // read the flag BEFORE draining: spans recorded before a
        // shutdown signal are guaranteed to be in this final drain
        // (workers are joined before the flusher is stopped)
        let stopping = stop.load(Ordering::Acquire);
        let spans = obs::drain();
        let at_us = obs::now_us();
        for ev in drift.observe(&spans) {
            writer.write_line(&ev.to_json());
        }
        let mut kept = sampler.filter(spans);
        if stopping {
            // undecided traces are conservatively kept at shutdown
            kept.extend(sampler.finish());
        }
        if !kept.is_empty() {
            let dropped = obs::dropped_spans() + sampler.dropped_spans();
            writer.write_line(&export::spans_line(at_us, &kept, dropped));
        }
        let counters = obs::counters();
        for s in &sources {
            let merged = MetricsSnapshot::merge_all(s.metrics.iter().map(|m| m.snapshot()))
                .unwrap_or_default();
            let snap = stamp_admission(merged, &s.admission);
            writer.write_line(&export::snapshot_line(at_us, &s.model, snap.to_json(), &counters));
        }
        if stopping {
            return;
        }
        let mut left = Duration::from_millis(cfg.period_ms.max(1));
        while !stop.load(Ordering::Acquire) && left > Duration::ZERO {
            let slice = left.min(FLUSHER_POLL);
            std::thread::sleep(slice);
            left -= slice;
        }
    }
}

/// Signal + join every handle's workers (build-failure path, shutdown,
/// and Drop all funnel here). Idempotent: joined workers are drained.
fn shutdown_handles(handles: &mut BTreeMap<String, ModelHandle>) -> Result<(), CadnnError> {
    for h in handles.values() {
        h.shard.signal_shutdown();
    }
    let mut result = Ok(());
    for (name, h) in handles.iter_mut() {
        for w in h.workers.drain(..) {
            match w.join() {
                Ok(r) => {
                    if result.is_ok() {
                        if let Err(e) = r {
                            result = Err(e);
                        }
                    }
                }
                Err(_) => {
                    if result.is_ok() {
                        result =
                            Err(CadnnError::execution(format!("worker for '{name}' panicked")));
                    }
                }
            }
        }
    }
    result
}

/// Multi-model serving front: owns the [`Registry`] and one worker
/// (queue → scheduler → backend) per registered model replica.
pub struct Server {
    handles: BTreeMap<String, ModelHandle>,
    registry: Registry,
    next_id: AtomicU64,
    clock: SharedClock,
    telemetry: Option<TelemetryFlusher>,
}

impl Server {
    pub fn builder() -> ServerBuilder {
        ServerBuilder::default()
    }

    /// What is being served: names, plans, batch variants, costs.
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Registered model names, sorted.
    pub fn models(&self) -> Vec<&str> {
        self.registry.names()
    }

    /// Flat floats per image for one model.
    pub fn input_len(&self, model: &str) -> Option<usize> {
        self.handles.get(model).map(|h| h.input_len)
    }

    /// Logits per image for one model.
    pub fn classes(&self, model: &str) -> Option<usize> {
        self.registry.get(model).map(|e| e.classes)
    }

    /// One model's live metrics handle — **replica 0's** recorder (exact
    /// for single-replica models; the shim and the CLI report off this).
    /// Lock-free: recording and reading both take `&self`, so holding
    /// this never contends with the worker; prefer [`Server::stats`] for
    /// point-in-time reads merged across replicas.
    pub fn metrics(&self, model: &str) -> Option<Arc<Metrics>> {
        self.handles.get(model).map(|h| h.metrics[0].clone())
    }

    /// One model's admission state: committed work, quota, shed counts.
    pub fn admission(&self, model: &str) -> Option<&ModelAdmission> {
        self.handles.get(model).map(|h| h.admission.as_ref())
    }

    /// Per-replica raw snapshots for one model (index = replica).
    pub fn replica_stats(&self, model: &str) -> Option<Vec<MetricsSnapshot>> {
        self.handles
            .get(model)
            .map(|h| h.metrics.iter().map(|m| m.snapshot()).collect())
    }

    /// Point-in-time per-model metrics snapshots: replica recorders
    /// merged (histogram buckets added, rates recomputed), admission
    /// accounting (shed splits, quota utilization) stamped on top.
    pub fn stats(&self) -> BTreeMap<String, MetricsSnapshot> {
        self.handles
            .iter()
            .map(|(name, h)| {
                let merged = MetricsSnapshot::merge_all(h.metrics.iter().map(|m| m.snapshot()))
                    .unwrap_or_default();
                (name.clone(), stamp_admission(merged, &h.admission))
            })
            .collect()
    }

    /// Submit one request; returns a receiver for its response. Routing
    /// and input-length errors surface synchronously; admission sheds,
    /// deadline misses, and backend failures arrive as explicit response
    /// outcomes.
    pub fn submit(&self, req: ServeRequest) -> Result<Receiver<ServeResponse>, CadnnError> {
        let handle = self
            .handles
            .get(&req.model)
            .ok_or_else(|| CadnnError::UnknownModel { name: req.model.clone() })?;
        if req.input.len() != handle.input_len {
            return Err(CadnnError::InvalidInput {
                reason: format!(
                    "input length {} != expected {} for model '{}'",
                    req.input.len(),
                    handle.input_len,
                    req.model
                ),
            });
        }
        let (rtx, rrx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        // mint the trace id at the front door: every span this request
        // touches (admit, queue expiry, batch, exec, kernels, reply)
        // carries it, so a sampled trace reconstructs the full lifecycle
        let trace = if obs::on() { obs::next_trace_id() } else { 0 };
        let cost_us = match handle.admission.admit(req.deadline_us) {
            AdmitDecision::Admit { cost_us, predicted_us } => {
                if obs::on() {
                    let _tg = obs::with_trace(trace);
                    obs::record_span(
                        obs::CAT_SERVE,
                        "admit".to_string(),
                        obs::now_us(),
                        0.0,
                        vec![
                            ("model", ArgValue::Str(req.model.clone())),
                            ("id", ArgValue::Num(id as f64)),
                            ("predicted_us", ArgValue::Num(predicted_us as f64)),
                        ],
                    );
                }
                cost_us
            }
            decision => {
                let _ = rtx.send(shed_response(&req.model, id, trace, req.deadline_us, decision));
                return Ok(rrx);
            }
        };
        let enqueued_us = self.clock.now_us();
        let pending = Pending {
            id,
            input: req.input,
            enqueued_us,
            deadline_at_us: req.deadline_us.map(|us| enqueued_us.saturating_add(us)),
            deadline_us: req.deadline_us,
            cost_us,
            trace,
            topk: req.topk,
            reply: rtx,
        };
        // dispatch to the shortest replica queue (ties: lowest index)
        let shard = &handle.shard;
        let r = (0..shard.replicas.len())
            .min_by_key(|&i| shard.replicas[i].depth.load(Ordering::Acquire))
            .unwrap_or(0);
        let rq = &shard.replicas[r];
        {
            let mut q = rq.lock();
            q.push_back(pending);
            rq.depth.store(q.len() as u64, Ordering::Release);
        }
        rq.cv.notify_one();
        Ok(rrx)
    }

    /// Submit and wait (convenience).
    pub fn infer(&self, req: ServeRequest) -> Result<ServeResponse, CadnnError> {
        let rx = self.submit(req)?;
        rx.recv()
            .map_err(|_| CadnnError::execution("server dropped request"))
    }

    /// Stop every worker, draining queued requests first. All workers
    /// are signalled before any is joined, so the total shutdown time is
    /// the slowest model's drain, not the sum of all drains.
    pub fn shutdown(mut self) -> Result<(), CadnnError> {
        // workers first: once they are joined, every span they recorded
        // is in the rings, so the flusher's final drain misses nothing
        let result = shutdown_handles(&mut self.handles);
        if let Some(f) = self.telemetry.as_mut() {
            f.stop_and_join();
        }
        result
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = shutdown_handles(&mut self.handles);
        if let Some(f) = self.telemetry.as_mut() {
            f.stop_and_join();
        }
    }
}

/// Stamp one model's admission accounting onto its merged snapshot.
pub(crate) fn stamp_admission(mut snap: MetricsSnapshot, adm: &ModelAdmission) -> MetricsSnapshot {
    let (sd, sq, sb) = adm.shed_counts();
    snap.shed_deadline = sd;
    snap.shed_quota = sq;
    snap.shed_backlog = sb;
    snap.committed_us = adm.committed_us();
    snap.quota_us = adm.quota_us();
    snap.quota_utilization = adm
        .quota_us()
        .map(|q| if q == 0 { 0.0 } else { snap.committed_us as f64 / q as f64 });
    snap
}

/// The immediate reply for a request refused at enqueue, plus its
/// shed-decision span.
pub(crate) fn shed_response(
    model: &str,
    id: u64,
    trace: u64,
    deadline_us: Option<u64>,
    decision: AdmitDecision,
) -> ServeResponse {
    let (outcome, cause, predicted_us) = match decision {
        AdmitDecision::ShedDeadline { predicted_us } => (
            Err(ServeError::Deadline { deadline_us: deadline_us.unwrap_or(0), waited_us: 0 }),
            "deadline",
            predicted_us,
        ),
        AdmitDecision::Shed { cause, predicted_us } => {
            (Err(ServeError::Shed { cause, predicted_us }), match cause {
                ShedCause::Quota => "quota",
                ShedCause::Backlog => "backlog",
            }, predicted_us)
        }
        AdmitDecision::Admit { .. } => unreachable!("admitted requests are not shed replies"),
    };
    if obs::on() {
        let _tg = obs::with_trace(trace);
        obs::record_span(
            obs::CAT_SERVE,
            "request".to_string(),
            obs::now_us(),
            0.0,
            vec![
                ("model", ArgValue::Str(model.to_string())),
                ("id", ArgValue::Num(id as f64)),
                ("outcome", ArgValue::Str("shed".to_string())),
                ("cause", ArgValue::Str(cause.to_string())),
                ("predicted_us", ArgValue::Num(predicted_us as f64)),
            ],
        );
    }
    ServeResponse { id, model: model.to_string(), outcome, topk: None, latency_us: 0.0, batch: 0 }
}

/// Everything a replica worker thread needs, bundled.
struct WorkerCtx {
    model: String,
    replica: usize,
    cfg: QueueConfig,
    shard: Arc<Shard>,
    metrics: Arc<Metrics>,
    clock: SharedClock,
    admission: Arc<ModelAdmission>,
}

/// Threaded workers poll in bounded slices instead of waiting the full
/// batching window: keeps them responsive to steal opportunities and
/// shutdown, and keeps a frozen [`VirtualClock`] from hanging them.
const WORKER_POLL: Duration = Duration::from_millis(5);

fn worker_loop(
    ctx: WorkerCtx,
    factory: BackendFactory,
    ready: Sender<Result<ReadyInfo, CadnnError>>,
) -> Result<(), CadnnError> {
    // Backend objects are created inside the worker thread (no Send bound
    // on the backend itself, only on the factory).
    let backend = match factory() {
        Ok(b) => b,
        Err(e) => {
            let msg = e.to_string();
            let _ = ready.send(Err(e));
            return Err(CadnnError::execution(format!("backend init failed: {msg}")));
        }
    };
    let batches = backend.batch_sizes();
    if batches.is_empty() {
        let err = CadnnError::config("backend reports no batch variants");
        let _ = ready.send(Err(err.clone()));
        return Err(err);
    }
    let input_shape = backend.input_shape().to_vec();
    let per_image: usize = input_shape.iter().product();
    let classes = backend.classes();
    let plan_costs = if ctx.cfg.planned { backend.plan_costs() } else { Vec::new() };
    let mut sched = Scheduler::new(batches.clone(), plan_costs.clone(), ctx.cfg.fallback);
    if ctx.cfg.planned {
        // seed the units→µs scale: explicit config first, then the
        // backend's persisted calibration (artifact manifest) — a seeded
        // scheduler is deadline-accurate before its first observation,
        // and a seeded replica-0 recorder activates admission pricing
        // before the first batch
        if let Some(c) = ctx.cfg.calibration.or_else(|| backend.calibration()) {
            sched.calibrate(c);
        }
    }
    ctx.metrics.record_calibration(sched.us_per_unit());
    let _ = ready.send(Ok(ReadyInfo {
        input_shape,
        classes,
        batch_sizes: batches,
        plan: backend.exec_plan(),
        plan_costs,
    }));
    let backend = backend.as_ref();
    let rq = Arc::clone(&ctx.shard.replicas[ctx.replica]);

    loop {
        // --- acquire: own queue first, then steal, then sleep ---
        let mut guard = rq.lock();
        loop {
            if !guard.is_empty() {
                break;
            }
            if ctx.shard.shutdown.load(Ordering::Acquire) {
                return Ok(());
            }
            drop(guard);
            if try_steal(&ctx.shard, ctx.replica, &ctx.metrics) {
                guard = rq.lock();
                continue;
            }
            guard = rq.lock();
            if guard.is_empty() && !ctx.shard.shutdown.load(Ordering::Acquire) {
                guard = rq
                    .cv
                    .wait_timeout(guard, WORKER_POLL)
                    .unwrap_or_else(|e| e.into_inner())
                    .0;
            }
        }
        // --- batching window: wait for co-riders until the formation
        // deadline (head arrival + max_wait, clipped by any pending
        // request deadline), or until the queue fills ---
        while !ctx.shard.shutdown.load(Ordering::Acquire) && guard.len() < ctx.cfg.max_batch {
            let due = formation_due_us(&guard, &ctx.cfg);
            let now = ctx.clock.now_us();
            if now >= due {
                break;
            }
            let wait = Duration::from_micros(due - now).min(WORKER_POLL);
            guard = rq.cv.wait_timeout(guard, wait).unwrap_or_else(|e| e.into_inner()).0;
            if guard.is_empty() {
                // a sibling stole everything while we waited
                break;
            }
        }
        drop(guard);
        flush_replica(&ctx, backend, &rq, &mut sched, per_image, classes);
    }
}

/// Steal the tail half of the deepest sibling queue (≥ 2 entries) into
/// our own. Taking from the *tail* preserves the victim's FIFO prefix —
/// the requests it already owes answers to stay in order — and the
/// stolen block itself stays in arrival order at the thief. Locks are
/// taken one at a time (victim, then own), so two thieves can never
/// deadlock.
fn try_steal(shard: &Shard, me: usize, metrics: &Metrics) -> bool {
    let victim = (0..shard.replicas.len())
        .filter(|&i| i != me)
        .max_by_key(|&i| shard.replicas[i].depth.load(Ordering::Acquire));
    let Some(victim) = victim else { return false };
    if shard.replicas[victim].depth.load(Ordering::Acquire) < 2 {
        return false;
    }
    let vq = &shard.replicas[victim];
    let stolen: Vec<Pending> = {
        let mut q = vq.lock();
        if q.len() < 2 {
            return false;
        }
        let keep = q.len() - q.len() / 2;
        let stolen = q.split_off(keep);
        vq.depth.store(q.len() as u64, Ordering::Release);
        stolen.into()
    };
    let rq = &shard.replicas[me];
    {
        let mut q = rq.lock();
        q.extend(stolen);
        rq.depth.store(q.len() as u64, Ordering::Release);
    }
    metrics.record_steal();
    obs::add(obs::Counter::ServeSteals, 1);
    true
}

/// Drain one replica's queue: expire, plan, execute, reply — until the
/// queue is empty. The queue lock is never held across `run_batch`, so
/// submits and thieves proceed while a batch executes.
fn flush_replica(
    ctx: &WorkerCtx,
    backend: &dyn Backend,
    rq: &ReplicaQueue,
    sched: &mut Scheduler,
    per_image: usize,
    classes: usize,
) {
    loop {
        let mut q = rq.lock();
        ctx.metrics.set_queue_depth(q.len());
        let now = ctx.clock.now_us();
        expire_queue(&ctx.model, &mut q, &ctx.metrics, sched.min_est_us(), now, &ctx.admission);
        rq.depth.store(q.len() as u64, Ordering::Release);
        if q.is_empty() {
            ctx.metrics.set_queue_depth(0);
            return;
        }
        let b = plan_batch(&q, &ctx.cfg, sched, now);
        let take = b.min(q.len());
        let batch: Vec<Pending> = q.drain(..take).collect();
        rq.depth.store(q.len() as u64, Ordering::Release);
        ctx.metrics.set_queue_depth(q.len());
        drop(q);
        let input = gather_input(&batch, b, per_image);
        let formed_at_us = ctx.clock.now_us();
        // exec/kernel spans recorded inside run_batch inherit the head
        // request's trace via the thread-local trace context
        let result = {
            let _tg = obs::with_trace(batch.first().map(|r| r.trace).unwrap_or(0));
            backend.run_batch(b, &input)
        };
        let exec_us = ctx.clock.now_us().saturating_sub(formed_at_us).max(1);
        if result.is_ok() {
            sched.observe(b, exec_us as f64);
            ctx.metrics.record_calibration(sched.us_per_unit());
        }
        complete_batch(
            &ctx.model,
            result,
            batch,
            b,
            formed_at_us,
            exec_us,
            classes,
            &ctx.metrics,
            &ctx.admission,
        );
    }
}

/// Absolute µs time at which the queue's next batch should form: the
/// head-of-line arrival plus the batching window, clipped by the
/// earliest pending deadline; immediately (0) once the queue can fill a
/// `max_batch`.
pub(crate) fn formation_due_us(queue: &VecDeque<Pending>, cfg: &QueueConfig) -> u64 {
    if queue.len() >= cfg.max_batch {
        return 0;
    }
    let Some(head) = queue.front() else { return 0 };
    let mut due = head.enqueued_us.saturating_add(cfg.max_wait_us);
    if let Some(d) = queue.iter().filter_map(|r| r.deadline_at_us).min() {
        due = due.min(d);
    }
    due
}

/// Answer every queued request whose deadline already passed with an
/// explicit [`ServeError::Deadline`] — they are never executed. Each
/// miss is attributed to a cause: *infeasible on arrival* when the
/// request's whole deadline budget was below the cheapest batch's
/// estimated exec time (`min_est_us` — no admission decision could have
/// saved it), else *expired in queue* (it waited too long behind other
/// work). Expired commitments are released.
pub(crate) fn expire_queue(
    model: &str,
    queue: &mut VecDeque<Pending>,
    metrics: &Metrics,
    min_est_us: Option<f64>,
    now_us: u64,
    admission: &ModelAdmission,
) {
    if !queue.iter().any(|r| r.deadline_at_us.is_some_and(|d| d <= now_us)) {
        return;
    }
    let mut keep = VecDeque::with_capacity(queue.len());
    while let Some(r) = queue.pop_front() {
        if !r.deadline_at_us.is_some_and(|d| d <= now_us) {
            keep.push_back(r);
            continue;
        }
        let waited_us = now_us.saturating_sub(r.enqueued_us) as f64;
        let budget_us = r.deadline_us.unwrap_or(0) as f64;
        let infeasible = min_est_us.is_some_and(|e| budget_us < e);
        metrics.record_deadline_miss(infeasible);
        admission.release(r.cost_us);
        if obs::on() {
            let _tg = obs::with_trace(r.trace);
            obs::record_span(
                obs::CAT_SERVE,
                "request".to_string(),
                obs::now_us() - waited_us,
                waited_us,
                vec![
                    ("model", ArgValue::Str(model.to_string())),
                    ("id", ArgValue::Num(r.id as f64)),
                    ("wait_us", ArgValue::Num(waited_us)),
                    ("slack_us", ArgValue::Num(budget_us - waited_us)),
                    ("outcome", ArgValue::Str("deadline".to_string())),
                    (
                        "cause",
                        ArgValue::Str(
                            if infeasible { "infeasible" } else { "queue" }.to_string(),
                        ),
                    ),
                ],
            );
        }
        let _ = r.reply.send(ServeResponse {
            id: r.id,
            model: model.to_string(),
            outcome: Err(ServeError::Deadline {
                deadline_us: r.deadline_us.unwrap_or(0),
                waited_us: waited_us as u64,
            }),
            topk: None,
            latency_us: waited_us,
            batch: 0,
        });
    }
    *queue = keep;
}

/// Pick the batch size for the queue's FIFO prefix: per-prefix deadline
/// slack feeds the scheduler, because a batch of size `b` serves the
/// first `min(b, horizon)` requests — an urgent request deeper in the
/// queue is not helped by shrinking a batch that won't include it.
pub(crate) fn plan_batch(
    queue: &VecDeque<Pending>,
    cfg: &QueueConfig,
    sched: &mut Scheduler,
    now_us: u64,
) -> usize {
    let horizon = queue.len().min(cfg.max_batch).max(1);
    let mut prefix_slack: Vec<Option<f64>> = Vec::with_capacity(horizon);
    let mut tightest: Option<f64> = None;
    for r in queue.iter().take(horizon) {
        if let Some(d) = r.deadline_at_us {
            let s = d.saturating_sub(now_us) as f64;
            tightest = Some(tightest.map_or(s, |t: f64| t.min(s)));
        }
        prefix_slack.push(tightest);
    }
    sched.pick_with(horizon, |b| prefix_slack[b.min(horizon) - 1])
}

/// Pack the batch's inputs into one flat buffer (padding slots stay 0).
pub(crate) fn gather_input(batch: &[Pending], b: usize, per_image: usize) -> Vec<f32> {
    let mut input = vec![0.0f32; b * per_image];
    for (i, r) in batch.iter().enumerate() {
        input[i * per_image..(i + 1) * per_image].copy_from_slice(&r.input);
    }
    input
}

/// Account for and answer one executed (or failed) batch: queue-wait and
/// latency histograms, spans, top-k, commitment release, replies. Shared
/// verbatim by the threaded workers and the discrete-event sim, so the
/// deterministic tests exercise the same accounting the real server
/// runs.
#[allow(clippy::too_many_arguments)]
pub(crate) fn complete_batch(
    model: &str,
    result: Result<Vec<f32>, CadnnError>,
    batch: Vec<Pending>,
    b: usize,
    formed_at_us: u64,
    exec_us: u64,
    classes: usize,
    metrics: &Metrics,
    admission: &ModelAdmission,
) {
    let take = batch.len();
    let reply_at_us = formed_at_us.saturating_add(exec_us);
    let request_span = |r: &Pending, wait_us: f64, latency_us: f64, out: &str| {
        let mut args = vec![
            ("model", ArgValue::Str(model.to_string())),
            ("id", ArgValue::Num(r.id as f64)),
            ("batch", ArgValue::Num(b as f64)),
            ("wait_us", ArgValue::Num(wait_us)),
            ("exec_us", ArgValue::Num(exec_us as f64)),
            ("outcome", ArgValue::Str(out.to_string())),
        ];
        if let Some(d) = r.deadline_us {
            args.push(("slack_us", ArgValue::Num(d as f64 - latency_us)));
        }
        let _tg = obs::with_trace(r.trace);
        obs::record_span(
            obs::CAT_SERVE,
            "request".to_string(),
            obs::now_us() - latency_us,
            latency_us,
            args,
        );
    };
    // the batch span is attributed to the head request's trace (a batch
    // serves many traces; the head is the one that formed it)
    let head_trace = batch.first().map(|r| r.trace).unwrap_or(0);
    match result {
        Ok(out) => {
            metrics.record_batch(b, take, exec_us as f64);
            if obs::on() {
                let _tg = obs::with_trace(head_trace);
                obs::record_span(
                    obs::CAT_SERVE,
                    "batch".to_string(),
                    obs::now_us() - exec_us as f64,
                    exec_us as f64,
                    vec![
                        ("model", ArgValue::Str(model.to_string())),
                        ("batch", ArgValue::Num(b as f64)),
                        ("used", ArgValue::Num(take as f64)),
                    ],
                );
            }
            for (i, r) in batch.into_iter().enumerate() {
                let wait_us = formed_at_us.saturating_sub(r.enqueued_us) as f64;
                metrics.record_queue_wait(wait_us);
                let latency_us = reply_at_us.saturating_sub(r.enqueued_us) as f64;
                metrics.record_request(latency_us);
                if obs::on() {
                    request_span(&r, wait_us, latency_us, "ok");
                }
                let logits = out[i * classes..(i + 1) * classes].to_vec();
                let topk = r.topk.map(|k| topk_of(&logits, k));
                admission.release(r.cost_us);
                let _ = r.reply.send(ServeResponse {
                    id: r.id,
                    model: model.to_string(),
                    outcome: Ok(logits),
                    topk,
                    latency_us,
                    batch: b,
                });
            }
        }
        Err(e) => {
            crate::util::log::log(
                crate::util::log::Level::Error,
                "serve",
                format_args!("{model}: execute failed: {e}"),
            );
            // answer the affected requests with an explicit backend
            // error so clients can distinguish this from shutdown
            // (where the reply channel just closes)
            let err = ServeError::Backend(e.to_string());
            metrics.record_errors(take as u64);
            for r in batch {
                let wait_us = formed_at_us.saturating_sub(r.enqueued_us) as f64;
                metrics.record_queue_wait(wait_us);
                let latency_us = reply_at_us.saturating_sub(r.enqueued_us) as f64;
                if obs::on() {
                    request_span(&r, wait_us, latency_us, "error");
                }
                admission.release(r.cost_us);
                let _ = r.reply.send(ServeResponse {
                    id: r.id,
                    model: model.to_string(),
                    outcome: Err(err.clone()),
                    topk: None,
                    latency_us,
                    batch: b,
                });
            }
        }
    }
}

/// (class, logit) pairs sorted by descending logit, ties by class.
pub(crate) fn topk_of(logits: &[f32], k: usize) -> Vec<(usize, f32)> {
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_by(|&a, &b| {
        logits[b]
            .partial_cmp(&logits[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx.into_iter().take(k).map(|i| (i, logits[i])).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_builder_composes_options() {
        let r = ServeRequest::new("m", vec![0.0; 4]).deadline_ms(30).topk(5);
        assert_eq!(r.model, "m");
        assert_eq!(r.deadline_us, Some(30_000));
        assert_eq!(r.topk, Some(5));
        let plain = ServeRequest::new("m", vec![0.0; 4]);
        assert_eq!(plain.deadline_us, None);
        assert_eq!(plain.topk, None);
    }

    #[test]
    fn topk_sorts_descending_with_stable_ties() {
        let logits = [0.1f32, 0.7, 0.7, 0.05, 0.9];
        let t = topk_of(&logits, 3);
        assert_eq!(t[0], (4, 0.9));
        assert_eq!(t[1], (1, 0.7), "ties break by class index");
        assert_eq!(t[2], (2, 0.7));
        assert_eq!(topk_of(&logits, 99).len(), logits.len());
    }

    #[test]
    fn serve_error_displays() {
        let d = ServeError::Deadline { deadline_us: 5_000, waited_us: 7_500 };
        let s = d.to_string();
        assert!(s.contains("5000") && s.contains("7500"), "{s}");
        assert!(ServeError::Backend("boom".into()).to_string().contains("boom"));
        let shed = ServeError::Shed { cause: ShedCause::Quota, predicted_us: 12_000 };
        let s = shed.to_string();
        assert!(s.contains("quota") && s.contains("12000"), "{s}");
    }

    #[test]
    fn empty_builder_is_a_config_error() {
        let err = Server::builder().build().err().unwrap();
        assert!(matches!(err, CadnnError::Config { .. }), "{err}");
    }

    fn pending(id: u64, enqueued_us: u64, deadline_at_us: Option<u64>) -> Pending {
        let (tx, _rx) = channel();
        Pending {
            id,
            input: Vec::new(),
            enqueued_us,
            deadline_at_us,
            deadline_us: deadline_at_us.map(|d| d - enqueued_us),
            cost_us: 0,
            trace: 0,
            topk: None,
            reply: tx,
        }
    }

    #[test]
    fn formation_due_tracks_head_window_deadlines_and_fill() {
        let cfg = QueueConfig { max_batch: 2, max_wait_us: 1_000, ..QueueConfig::default() };
        let mut q: VecDeque<Pending> = VecDeque::new();
        q.push_back(pending(1, 100, None));
        assert_eq!(formation_due_us(&q, &cfg), 1_100, "head arrival + window");
        q[0].deadline_at_us = Some(700);
        assert_eq!(formation_due_us(&q, &cfg), 700, "a pending deadline clips the window");
        q.push_back(pending(2, 150, None));
        assert_eq!(formation_due_us(&q, &cfg), 0, "a full queue forms immediately");
    }

    #[test]
    fn stealing_takes_the_tail_half_and_preserves_order() {
        let shard = Shard::new(2);
        {
            let mut q = shard.replicas[0].lock();
            for id in 1..=5 {
                q.push_back(pending(id, id * 10, None));
            }
            shard.replicas[0].depth.store(5, Ordering::Release);
        }
        let metrics = Metrics::new();
        assert!(try_steal(&shard, 1, &metrics));
        let victim: Vec<u64> = shard.replicas[0].lock().iter().map(|r| r.id).collect();
        let thief: Vec<u64> = shard.replicas[1].lock().iter().map(|r| r.id).collect();
        assert_eq!(victim, vec![1, 2, 3], "victim keeps its FIFO prefix");
        assert_eq!(thief, vec![4, 5], "stolen tail stays in arrival order");
        assert_eq!(shard.replicas[0].depth.load(Ordering::Acquire), 3);
        assert_eq!(shard.replicas[1].depth.load(Ordering::Acquire), 2);
        assert_eq!(metrics.snapshot().steals, 1);
        // nothing left worth stealing (victim depth < 2 after a re-steal
        // from the other side leaves 1)
        assert!(!try_steal(&shard, 0, &metrics) || shard.replicas[1].lock().len() <= 1);
    }
}
