//! Multi-model serving: named engines behind one [`Server`], a request
//! queue per model, and a planner-informed, deadline-aware dynamic
//! batcher.
//!
//! The paper's real-time claim (26 ms ResNet-50) is a statement about
//! *latency under load*, so the serving layer must understand what a
//! batch costs before it commits to one. This module closes that loop:
//! every registered model carries its [`crate::planner::ExecPlan`], the
//! plan prices each batch variant
//! ([`crate::planner::ExecPlan::cost_at`]), and the [`Scheduler`] picks
//! the batch that maximizes throughput *subject to the tightest pending
//! request's deadline* — instead of greedily filling to `max_batch`.
//!
//! ```ignore
//! use cadnn::serve::{QueueConfig, ServeRequest, Server};
//!
//! let server = Server::builder()
//!     .engine("resnet50", &resnet)            // default queue config
//!     .engine_with("lenet5", &lenet, QueueConfig::default())
//!     .build()?;
//!
//! let resp = server.infer(
//!     ServeRequest::new("resnet50", image).deadline_ms(30).topk(5),
//! )?;
//! match resp.outcome {
//!     Ok(logits) => println!("top-1 {:?}", resp.topk),
//!     Err(e) => eprintln!("{e}"),             // Deadline | Backend
//! }
//! let stats = server.stats();                 // per-model snapshots
//! server.shutdown()?;
//! ```
//!
//! Request lifecycle, deadline semantics, and the cost model are
//! documented in `docs/SERVING.md`. The old single-model
//! [`crate::coordinator::Coordinator`] remains as a thin deprecated shim
//! over this module.

pub mod metrics;
pub mod registry;
pub mod scheduler;

pub use metrics::{Metrics, MetricsSnapshot};
pub use registry::{ModelEntry, Registry};
pub use scheduler::{pick_batch, BatchPolicy, Scheduler};

use crate::api::Backend;
use crate::error::CadnnError;
use crate::obs::{self, ArgValue};
use crate::planner::ExecPlan;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Per-model queue/batcher knobs.
#[derive(Debug, Clone, Copy)]
pub struct QueueConfig {
    /// Queue depth considered per batch decision.
    pub max_batch: usize,
    /// Batching window: how long the worker waits for co-riders after
    /// the first queued request (a pending deadline shortens the wait).
    pub max_wait_us: u64,
    /// Policy used while no cost model / calibration is available (and
    /// always, when `planned` is off).
    pub fallback: BatchPolicy,
    /// Use the planner cost model for batch-size choice when the backend
    /// provides one. Off = always the plain `fallback` policy (the
    /// pre-planner behavior, kept for A/B benchmarking).
    pub planned: bool,
    /// Seed the scheduler's units→µs scale (µs per plan cost unit) so a
    /// fresh process is deadline-accurate from its first batch. `None`
    /// falls back to the backend's persisted calibration
    /// ([`crate::api::Backend::calibration`], e.g. the artifact
    /// manifest's `us_per_unit`), then to online learning. Ignored when
    /// `planned` is off.
    pub calibration: Option<f64>,
}

impl Default for QueueConfig {
    fn default() -> Self {
        QueueConfig {
            max_batch: 8,
            max_wait_us: 2_000,
            fallback: BatchPolicy::PadToFit,
            planned: true,
            calibration: None,
        }
    }
}

/// One inference request: which model, the image, and per-request
/// options (deadline, top-k).
#[derive(Debug, Clone)]
pub struct ServeRequest {
    /// Registry name of the target model.
    pub model: String,
    /// Flat NHWC image (`input_len` floats of the target model).
    pub input: Vec<f32>,
    /// Answer-by budget relative to submission. A request still queued
    /// when its deadline passes is answered with
    /// [`ServeError::Deadline`] instead of being executed; the scheduler
    /// also avoids batch sizes whose estimated run time would blow the
    /// tightest queued deadline.
    pub deadline_us: Option<u64>,
    /// Attach the top-k (class, logit) pairs to the response.
    pub topk: Option<usize>,
}

impl ServeRequest {
    pub fn new(model: impl Into<String>, input: Vec<f32>) -> ServeRequest {
        ServeRequest { model: model.into(), input, deadline_us: None, topk: None }
    }

    pub fn deadline_us(mut self, us: u64) -> ServeRequest {
        self.deadline_us = Some(us);
        self
    }

    pub fn deadline_ms(self, ms: u64) -> ServeRequest {
        self.deadline_us(ms.saturating_mul(1_000))
    }

    pub fn topk(mut self, k: usize) -> ServeRequest {
        self.topk = Some(k);
        self
    }
}

/// Why a request failed while the server stayed alive. (Shutdown is
/// signalled differently: the reply channel closes.)
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The backend rejected or failed the batch this request rode in.
    Backend(String),
    /// The request's deadline passed while it was queued; it was never
    /// executed. (A request that *starts* executing is always answered
    /// with its logits — clients can compare `latency_us` against their
    /// budget for the overran-while-running case.)
    Deadline {
        /// The request's deadline budget.
        deadline_us: u64,
        /// How long it had been queued when the miss was detected.
        waited_us: u64,
    },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Backend(msg) => write!(f, "backend error: {msg}"),
            ServeError::Deadline { deadline_us, waited_us } => write!(
                f,
                "deadline missed: budget {deadline_us}µs, waited {waited_us}µs"
            ),
        }
    }
}

impl std::error::Error for ServeError {}

/// One request's answer.
#[derive(Debug, Clone)]
pub struct ServeResponse {
    pub id: u64,
    /// Which registered model served (or expired) this request.
    pub model: String,
    /// Logits on success, or an explicit serve error.
    pub outcome: Result<Vec<f32>, ServeError>,
    /// (class, logit) pairs, descending — present iff the request asked
    /// for top-k and succeeded.
    pub topk: Option<Vec<(usize, f32)>>,
    /// end-to-end latency (enqueue -> reply), microseconds
    pub latency_us: f64,
    /// batch this request rode in (0 for requests never executed)
    pub batch: usize,
}

impl ServeResponse {
    /// Logits, if the request succeeded.
    pub fn logits(&self) -> Option<&[f32]> {
        self.outcome.as_ref().ok().map(|v| v.as_slice())
    }

    /// Consume into logits or the serve error.
    pub fn into_logits(self) -> Result<Vec<f32>, ServeError> {
        self.outcome
    }
}

/// Queued request, inside the worker.
struct Pending {
    id: u64,
    input: Vec<f32>,
    enqueued: Instant,
    deadline: Option<Instant>,
    deadline_us: Option<u64>,
    topk: Option<usize>,
    reply: Sender<ServeResponse>,
}

enum Msg {
    Req(Pending),
    Shutdown,
}

/// What a worker reports back once its backend is up.
struct ReadyInfo {
    input_shape: Vec<usize>,
    classes: usize,
    batch_sizes: Vec<usize>,
    plan: Option<ExecPlan>,
    plan_costs: Vec<(usize, f64)>,
}

struct ModelHandle {
    tx: Sender<Msg>,
    worker: Option<std::thread::JoinHandle<Result<(), CadnnError>>>,
    metrics: Arc<Metrics>,
    input_len: usize,
}

type BackendFactory = Box<dyn FnOnce() -> Result<Box<dyn Backend>, CadnnError> + Send>;

struct ModelSpec {
    name: String,
    factory: BackendFactory,
    cfg: QueueConfig,
    engine: Option<crate::api::Engine>,
}

/// Configure a [`Server`]: register models, then `build` to spawn one
/// worker (queue + scheduler + metrics) per model.
#[derive(Default)]
pub struct ServerBuilder {
    specs: Vec<ModelSpec>,
}

impl ServerBuilder {
    /// Register an engine under `name` with the default [`QueueConfig`].
    pub fn engine(self, name: impl Into<String>, engine: &crate::api::Engine) -> ServerBuilder {
        self.engine_with(name, engine, QueueConfig::default())
    }

    /// Register an engine under `name` with explicit queue knobs.
    pub fn engine_with(
        mut self,
        name: impl Into<String>,
        engine: &crate::api::Engine,
        cfg: QueueConfig,
    ) -> ServerBuilder {
        let e = engine.clone();
        let for_worker = e.clone();
        self.specs.push(ModelSpec {
            name: name.into(),
            factory: Box::new(move || Ok(Box::new(for_worker) as Box<dyn Backend>)),
            cfg,
            engine: Some(e),
        });
        self
    }

    /// Register a backend built *inside* the worker thread (required for
    /// backends whose handles are not `Send`, e.g. real PJRT).
    pub fn backend_with<F>(mut self, name: impl Into<String>, factory: F, cfg: QueueConfig) -> ServerBuilder
    where
        F: FnOnce() -> Result<Box<dyn Backend>, CadnnError> + Send + 'static,
    {
        self.specs.push(ModelSpec {
            name: name.into(),
            factory: Box::new(factory),
            cfg,
            engine: None,
        });
        self
    }

    /// Spawn every model's worker and wait until each backend is up (so
    /// client latency measurements see steady state and load errors
    /// surface here).
    pub fn build(self) -> Result<Server, CadnnError> {
        if self.specs.is_empty() {
            return Err(CadnnError::config("no models registered"));
        }
        let mut handles = BTreeMap::new();
        let mut registry = Registry::default();
        for spec in self.specs {
            if handles.contains_key(&spec.name) {
                return Err(CadnnError::config(format!(
                    "model '{}' registered twice",
                    spec.name
                )));
            }
            let (tx, rx) = channel::<Msg>();
            let metrics = Arc::new(Metrics::new());
            let m2 = metrics.clone();
            let (ready_tx, ready_rx) = channel::<Result<ReadyInfo, CadnnError>>();
            let name = spec.name.clone();
            let cfg = spec.cfg;
            let factory = spec.factory;
            let worker = std::thread::Builder::new()
                .name(format!("cadnn-serve-{name}"))
                .spawn(move || worker_loop(name, factory, cfg, rx, m2, ready_tx))
                .map_err(|e| CadnnError::execution(format!("spawn failed: {e}")))?;
            let info = match ready_rx.recv() {
                Ok(Ok(info)) => info,
                Ok(Err(e)) => {
                    let _ = worker.join();
                    return Err(e);
                }
                Err(_) => {
                    let _ = worker.join();
                    return Err(CadnnError::execution(format!(
                        "serve worker for '{}' died during startup",
                        spec.name
                    )));
                }
            };
            let entry = ModelEntry {
                name: spec.name.clone(),
                engine: spec.engine,
                plan: info.plan,
                plan_costs: info.plan_costs,
                input_shape: info.input_shape,
                classes: info.classes,
                batch_sizes: info.batch_sizes,
            };
            let input_len = entry.input_len();
            registry.insert(entry);
            handles.insert(
                spec.name,
                ModelHandle { tx, worker: Some(worker), metrics, input_len },
            );
        }
        Ok(Server { handles, registry, next_id: AtomicU64::new(1) })
    }
}

/// Multi-model serving front: owns the [`Registry`] and one worker
/// (queue → scheduler → backend) per registered model.
pub struct Server {
    handles: BTreeMap<String, ModelHandle>,
    registry: Registry,
    next_id: AtomicU64,
}

impl Server {
    pub fn builder() -> ServerBuilder {
        ServerBuilder::default()
    }

    /// What is being served: names, plans, batch variants, costs.
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Registered model names, sorted.
    pub fn models(&self) -> Vec<&str> {
        self.registry.names()
    }

    /// Flat floats per image for one model.
    pub fn input_len(&self, model: &str) -> Option<usize> {
        self.handles.get(model).map(|h| h.input_len)
    }

    /// Logits per image for one model.
    pub fn classes(&self, model: &str) -> Option<usize> {
        self.registry.get(model).map(|e| e.classes)
    }

    /// One model's live metrics handle (the shim and the CLI report off
    /// this). Lock-free: recording and reading both take `&self`, so
    /// holding this never contends with the worker; prefer
    /// [`Server::stats`] for point-in-time reads.
    pub fn metrics(&self, model: &str) -> Option<Arc<Metrics>> {
        self.handles.get(model).map(|h| h.metrics.clone())
    }

    /// Point-in-time per-model metrics snapshots.
    pub fn stats(&self) -> BTreeMap<String, MetricsSnapshot> {
        self.handles
            .iter()
            .map(|(name, h)| (name.clone(), h.metrics.snapshot()))
            .collect()
    }

    /// Submit one request; returns a receiver for its response. Routing
    /// and input-length errors surface synchronously; deadline misses
    /// and backend failures arrive as explicit response outcomes.
    pub fn submit(&self, req: ServeRequest) -> Result<Receiver<ServeResponse>, CadnnError> {
        let handle = self
            .handles
            .get(&req.model)
            .ok_or_else(|| CadnnError::UnknownModel { name: req.model.clone() })?;
        if req.input.len() != handle.input_len {
            return Err(CadnnError::InvalidInput {
                reason: format!(
                    "input length {} != expected {} for model '{}'",
                    req.input.len(),
                    handle.input_len,
                    req.model
                ),
            });
        }
        let (rtx, rrx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let enqueued = Instant::now();
        let pending = Pending {
            id,
            input: req.input,
            enqueued,
            deadline: req.deadline_us.map(|us| enqueued + Duration::from_micros(us)),
            deadline_us: req.deadline_us,
            topk: req.topk,
            reply: rtx,
        };
        handle
            .tx
            .send(Msg::Req(pending))
            .map_err(|_| CadnnError::execution(format!("model '{}' stopped", req.model)))?;
        Ok(rrx)
    }

    /// Submit and wait (convenience).
    pub fn infer(&self, req: ServeRequest) -> Result<ServeResponse, CadnnError> {
        let rx = self.submit(req)?;
        rx.recv()
            .map_err(|_| CadnnError::execution("server dropped request"))
    }

    /// Stop every worker, draining queued requests first. All workers
    /// are signalled before any is joined, so the total shutdown time is
    /// the slowest model's drain, not the sum of all drains.
    pub fn shutdown(mut self) -> Result<(), CadnnError> {
        for h in self.handles.values() {
            let _ = h.tx.send(Msg::Shutdown);
        }
        let mut result = Ok(());
        for (name, h) in self.handles.iter_mut() {
            if let Some(w) = h.worker.take() {
                match w.join() {
                    Ok(r) => {
                        if result.is_ok() {
                            if let Err(e) = r {
                                result = Err(e);
                            }
                        }
                    }
                    Err(_) => {
                        if result.is_ok() {
                            result = Err(CadnnError::execution(format!(
                                "worker for '{name}' panicked"
                            )));
                        }
                    }
                }
            }
        }
        result
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        for h in self.handles.values() {
            let _ = h.tx.send(Msg::Shutdown);
        }
        for h in self.handles.values_mut() {
            if let Some(w) = h.worker.take() {
                let _ = w.join();
            }
        }
    }
}

fn worker_loop(
    model: String,
    factory: BackendFactory,
    cfg: QueueConfig,
    rx: Receiver<Msg>,
    metrics: Arc<Metrics>,
    ready: Sender<Result<ReadyInfo, CadnnError>>,
) -> Result<(), CadnnError> {
    // Backend objects are created inside the worker thread (no Send bound
    // on the backend itself, only on the factory).
    let backend = match factory() {
        Ok(b) => b,
        Err(e) => {
            let msg = e.to_string();
            let _ = ready.send(Err(e));
            return Err(CadnnError::execution(format!("backend init failed: {msg}")));
        }
    };
    let batches = backend.batch_sizes();
    if batches.is_empty() {
        let err = CadnnError::config("backend reports no batch variants");
        let _ = ready.send(Err(err.clone()));
        return Err(err);
    }
    let input_shape = backend.input_shape().to_vec();
    let per_image: usize = input_shape.iter().product();
    let classes = backend.classes();
    let plan_costs = if cfg.planned { backend.plan_costs() } else { Vec::new() };
    let mut sched = Scheduler::new(batches.clone(), plan_costs.clone(), cfg.fallback);
    if cfg.planned {
        // seed the units→µs scale: explicit config first, then the
        // backend's persisted calibration (artifact manifest) — a seeded
        // scheduler is deadline-accurate before its first observation
        if let Some(c) = cfg.calibration.or_else(|| backend.calibration()) {
            sched.calibrate(c);
        }
    }
    metrics.record_calibration(sched.us_per_unit());
    let _ = ready.send(Ok(ReadyInfo {
        input_shape,
        classes,
        batch_sizes: batches,
        plan: backend.exec_plan(),
        plan_costs,
    }));
    let backend = backend.as_ref();

    let mut queue: Vec<Pending> = Vec::new();
    loop {
        // fill the queue: block for the first request, then drain the
        // burst that arrived while the previous batch executed
        if queue.is_empty() {
            match rx.recv() {
                Ok(Msg::Req(r)) => queue.push(r),
                Ok(Msg::Shutdown) | Err(_) => return Ok(()),
            }
        }
        while queue.len() < cfg.max_batch {
            match rx.try_recv() {
                Ok(Msg::Req(r)) => queue.push(r),
                Ok(Msg::Shutdown) => {
                    flush(&model, backend, &cfg, &mut sched, &mut queue, per_image, classes, &metrics);
                    return Ok(());
                }
                Err(_) => break,
            }
        }
        // batching window: wait for co-riders up to max_wait_us past the
        // head-of-line arrival — but never past a pending deadline
        let mut wait_until = queue[0].enqueued + Duration::from_micros(cfg.max_wait_us);
        if let Some(d) = queue.iter().filter_map(|r| r.deadline).min() {
            wait_until = wait_until.min(d);
        }
        while queue.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= wait_until {
                break;
            }
            match rx.recv_timeout(wait_until - now) {
                Ok(Msg::Req(r)) => {
                    if let Some(d) = r.deadline {
                        wait_until = wait_until.min(d);
                    }
                    queue.push(r);
                }
                Ok(Msg::Shutdown) => {
                    flush(&model, backend, &cfg, &mut sched, &mut queue, per_image, classes, &metrics);
                    return Ok(());
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                Err(_) => {
                    flush(&model, backend, &cfg, &mut sched, &mut queue, per_image, classes, &metrics);
                    return Ok(());
                }
            }
        }
        flush(&model, backend, &cfg, &mut sched, &mut queue, per_image, classes, &metrics);
    }
}

/// Answer every queued request whose deadline already passed with an
/// explicit [`ServeError::Deadline`] — they are never executed. Each
/// miss is attributed to a cause: *infeasible on arrival* when the
/// request's whole deadline budget was below the cheapest batch's
/// estimated exec time (`min_est_us` — no admission decision could have
/// saved it), else *expired in queue* (it waited too long behind other
/// work).
fn expire(model: &str, queue: &mut Vec<Pending>, metrics: &Metrics, min_est_us: Option<f64>) {
    let now = Instant::now();
    if !queue.iter().any(|r| r.deadline.is_some_and(|d| d <= now)) {
        return;
    }
    let (expired, keep): (Vec<Pending>, Vec<Pending>) = queue
        .drain(..)
        .partition(|r| r.deadline.is_some_and(|d| d <= now));
    *queue = keep;
    for r in expired {
        let waited_us = r.enqueued.elapsed().as_secs_f64() * 1e6;
        let budget_us = r.deadline_us.unwrap_or(0) as f64;
        let infeasible = min_est_us.is_some_and(|e| budget_us < e);
        metrics.record_deadline_miss(infeasible);
        if obs::on() {
            obs::record_span(
                obs::CAT_SERVE,
                "request".to_string(),
                obs::at_us(r.enqueued),
                waited_us,
                vec![
                    ("model", ArgValue::Str(model.to_string())),
                    ("id", ArgValue::Num(r.id as f64)),
                    ("wait_us", ArgValue::Num(waited_us)),
                    ("slack_us", ArgValue::Num(budget_us - waited_us)),
                    ("outcome", ArgValue::Str("deadline".to_string())),
                    (
                        "cause",
                        ArgValue::Str(
                            if infeasible { "infeasible" } else { "queue" }.to_string(),
                        ),
                    ),
                ],
            );
        }
        let _ = r.reply.send(ServeResponse {
            id: r.id,
            model: model.to_string(),
            outcome: Err(ServeError::Deadline {
                deadline_us: r.deadline_us.unwrap_or(0),
                waited_us: waited_us as u64,
            }),
            topk: None,
            latency_us: waited_us,
            batch: 0,
        });
    }
}

/// (class, logit) pairs sorted by descending logit, ties by class.
fn topk_of(logits: &[f32], k: usize) -> Vec<(usize, f32)> {
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_by(|&a, &b| {
        logits[b]
            .partial_cmp(&logits[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx.into_iter().take(k).map(|i| (i, logits[i])).collect()
}

/// Execute and reply to as many queued requests as scheduled batches
/// allow, expiring dead requests between rounds. Emits one `serve`
/// "request" span per reply and one "batch" span per executed batch
/// when the obs recorder is on.
#[allow(clippy::too_many_arguments)]
fn flush(
    model: &str,
    backend: &dyn Backend,
    cfg: &QueueConfig,
    sched: &mut Scheduler,
    queue: &mut Vec<Pending>,
    per_image: usize,
    classes: usize,
    metrics: &Metrics,
) {
    while !queue.is_empty() {
        metrics.set_queue_depth(queue.len());
        expire(model, queue, metrics, sched.min_est_us());
        if queue.is_empty() {
            break;
        }
        // per-prefix deadline slack: a batch of size b serves the first
        // min(b, horizon) FIFO requests, so only their deadlines
        // constrain it — an urgent request deeper in the queue is not
        // helped by shrinking a batch that won't include it
        let now = Instant::now();
        let horizon = queue.len().min(cfg.max_batch);
        let mut prefix_slack: Vec<Option<f64>> = Vec::with_capacity(horizon);
        let mut tightest: Option<f64> = None;
        for r in queue.iter().take(horizon) {
            if let Some(d) = r.deadline {
                let s = d.saturating_duration_since(now).as_secs_f64() * 1e6;
                tightest = Some(tightest.map_or(s, |t: f64| t.min(s)));
            }
            prefix_slack.push(tightest);
        }
        let b = sched.pick_with(horizon, |b| prefix_slack[b.min(horizon) - 1]);
        let take = b.min(queue.len());
        let mut input = vec![0.0f32; b * per_image];
        for (i, r) in queue.iter().take(take).enumerate() {
            input[i * per_image..(i + 1) * per_image].copy_from_slice(&r.input);
        }
        // batch formed: the prefix's queue wait ends here, whatever the
        // execution outcome
        let t0 = Instant::now();
        let waits_us: Vec<f64> = queue
            .iter()
            .take(take)
            .map(|r| t0.duration_since(r.enqueued).as_secs_f64() * 1e6)
            .collect();
        for &w in &waits_us {
            metrics.record_queue_wait(w);
        }
        let request_span = |r: &Pending, i: usize, latency_us: f64, exec_us: f64, out: &str| {
            let mut args = vec![
                ("model", ArgValue::Str(model.to_string())),
                ("id", ArgValue::Num(r.id as f64)),
                ("batch", ArgValue::Num(b as f64)),
                ("wait_us", ArgValue::Num(waits_us[i])),
                ("exec_us", ArgValue::Num(exec_us)),
                ("outcome", ArgValue::Str(out.to_string())),
            ];
            if let Some(d) = r.deadline_us {
                args.push(("slack_us", ArgValue::Num(d as f64 - latency_us)));
            }
            obs::record_span(
                obs::CAT_SERVE,
                "request".to_string(),
                obs::at_us(r.enqueued),
                latency_us,
                args,
            );
        };
        let out = match backend.run_batch(b, &input) {
            Ok(o) => o,
            Err(e) => {
                crate::util::log::log(
                    crate::util::log::Level::Error,
                    "serve",
                    format_args!("{model}: execute failed: {e}"),
                );
                // answer the affected requests with an explicit backend
                // error so clients can distinguish this from shutdown
                // (where the reply channel just closes)
                let err = ServeError::Backend(e.to_string());
                let exec_us = t0.elapsed().as_secs_f64() * 1e6;
                metrics.record_errors(take as u64);
                for (i, r) in queue.drain(..take).enumerate() {
                    let latency_us = r.enqueued.elapsed().as_secs_f64() * 1e6;
                    if obs::on() {
                        request_span(&r, i, latency_us, exec_us, "error");
                    }
                    let _ = r.reply.send(ServeResponse {
                        id: r.id,
                        model: model.to_string(),
                        outcome: Err(err.clone()),
                        topk: None,
                        latency_us,
                        batch: b,
                    });
                }
                continue;
            }
        };
        let exec_us = t0.elapsed().as_secs_f64() * 1e6;
        sched.observe(b, exec_us);
        metrics.record_calibration(sched.us_per_unit());
        metrics.record_batch(b, take, exec_us);
        if obs::on() {
            obs::record_span(
                obs::CAT_SERVE,
                "batch".to_string(),
                obs::at_us(t0),
                exec_us,
                vec![
                    ("model", ArgValue::Str(model.to_string())),
                    ("batch", ArgValue::Num(b as f64)),
                    ("used", ArgValue::Num(take as f64)),
                ],
            );
        }
        for (i, r) in queue.drain(..take).enumerate() {
            let latency_us = r.enqueued.elapsed().as_secs_f64() * 1e6;
            metrics.record_request(latency_us);
            if obs::on() {
                request_span(&r, i, latency_us, exec_us, "ok");
            }
            let logits = out[i * classes..(i + 1) * classes].to_vec();
            let topk = r.topk.map(|k| topk_of(&logits, k));
            let _ = r.reply.send(ServeResponse {
                id: r.id,
                model: model.to_string(),
                outcome: Ok(logits),
                topk,
                latency_us,
                batch: b,
            });
        }
    }
    metrics.set_queue_depth(queue.len());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_builder_composes_options() {
        let r = ServeRequest::new("m", vec![0.0; 4]).deadline_ms(30).topk(5);
        assert_eq!(r.model, "m");
        assert_eq!(r.deadline_us, Some(30_000));
        assert_eq!(r.topk, Some(5));
        let plain = ServeRequest::new("m", vec![0.0; 4]);
        assert_eq!(plain.deadline_us, None);
        assert_eq!(plain.topk, None);
    }

    #[test]
    fn topk_sorts_descending_with_stable_ties() {
        let logits = [0.1f32, 0.7, 0.7, 0.05, 0.9];
        let t = topk_of(&logits, 3);
        assert_eq!(t[0], (4, 0.9));
        assert_eq!(t[1], (1, 0.7), "ties break by class index");
        assert_eq!(t[2], (2, 0.7));
        assert_eq!(topk_of(&logits, 99).len(), logits.len());
    }

    #[test]
    fn serve_error_displays() {
        let d = ServeError::Deadline { deadline_us: 5_000, waited_us: 7_500 };
        let s = d.to_string();
        assert!(s.contains("5000") && s.contains("7500"), "{s}");
        assert!(ServeError::Backend("boom".into()).to_string().contains("boom"));
    }

    #[test]
    fn empty_builder_is_a_config_error() {
        let err = Server::builder().build().err().unwrap();
        assert!(matches!(err, CadnnError::Config { .. }), "{err}");
    }
}
