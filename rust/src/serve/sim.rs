//! Deterministic serving simulator: the server's batching, admission,
//! expiry, replica-dispatch, and work-stealing pipeline driven by a
//! single-threaded discrete-event loop on a [`VirtualClock`].
//!
//! [`SimServer`] reuses the *exact* production helpers —
//! [`super::expire_queue`], [`super::formation_due_us`],
//! [`super::plan_batch`], [`super::gather_input`],
//! [`super::complete_batch`], and the real [`ModelAdmission`] /
//! [`Scheduler`] / [`Metrics`] objects — so what the tests prove about
//! shedding taxonomy, deadline math, and metric partitions is a
//! statement about the served code path, not a model of it. Only the
//! threads and the wall clock are replaced: arrivals, batching-window
//! expirations, and batch completions are heap-ordered events, batch
//! execution time comes from an injectable cost function (defaulting to
//! plan units × calibration, the same estimate the scheduler and the
//! admission controller price with), and ties break on submission
//! order — every run is bit-for-bit reproducible, with zero sleeps.
//!
//! ```ignore
//! let mut sim = SimServer::new();
//! sim.register("m", Box::new(backend), QueueConfig::default())?;
//! let rx = sim.submit_at(0, ServeRequest::new("m", img).deadline_ms(10))?;
//! sim.run(); // drain every event; virtual time advances as needed
//! let resp = rx.try_recv().unwrap();
//! let stats = sim.stats();
//! ```

use super::admission::{AdmitDecision, ModelAdmission};
use super::clock::{Clock, VirtualClock};
use super::metrics::{Metrics, MetricsSnapshot};
use super::{
    complete_batch, expire_queue, formation_due_us, gather_input, plan_batch, shed_response,
    stamp_admission, AdmissionConfig, Pending, QueueConfig, Scheduler, ServeRequest,
    ServeResponse,
};
use crate::api::Backend;
use crate::error::CadnnError;
use crate::obs;
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};
use std::sync::atomic::AtomicU64;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// One admission decision, as the simulator saw it (audit trail for
/// exact-assertion tests: the recorded `predicted_us` of an `Admit` is
/// the bound the request's measured latency must stay within).
#[derive(Debug, Clone, PartialEq)]
pub struct AdmitRecord {
    pub id: u64,
    pub model: String,
    /// Virtual time of the admission decision.
    pub at_us: u64,
    pub decision: AdmitDecision,
}

/// One executed request, as the simulator formed its batch (audit trail
/// for FIFO/work-stealing properties).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExecRecord {
    pub id: u64,
    pub model: String,
    /// Replica the dispatcher originally queued the request on.
    pub dispatched: usize,
    /// Replica that actually executed it (differs after a steal).
    pub executed: usize,
    /// Virtual time the batch formed.
    pub formed_at_us: u64,
    /// Batch variant it rode in.
    pub batch: usize,
}

struct Submission {
    id: u64,
    /// Deterministic trace id (== `id`): the sim never consults the
    /// process-global trace counter, so identical runs produce identical
    /// trace ids and identical sampling decisions.
    trace: u64,
    model: String,
    input: Vec<f32>,
    deadline_us: Option<u64>,
    topk: Option<usize>,
    reply: Sender<ServeResponse>,
}

enum EvKind {
    Arrival(Submission),
    Wake {
        model: String,
        replica: usize,
    },
    Complete {
        model: String,
        replica: usize,
        b: usize,
        formed_at_us: u64,
        exec_us: u64,
        result: Result<Vec<f32>, CadnnError>,
        batch: Vec<Pending>,
    },
}

struct Ev {
    at: u64,
    seq: u64,
    kind: EvKind,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Ev) -> bool {
        (self.at, self.seq) == (other.at, other.seq)
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Ev) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Ev) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// Min-heap of events, tie-broken by insertion order (determinism).
#[derive(Default)]
struct EventQueue {
    heap: BinaryHeap<Reverse<Ev>>,
    seq: u64,
}

impl EventQueue {
    fn push(&mut self, at: u64, kind: EvKind) {
        self.seq += 1;
        self.heap.push(Reverse(Ev { at, seq: self.seq, kind }));
    }

    fn pop(&mut self) -> Option<Ev> {
        self.heap.pop().map(|Reverse(e)| e)
    }
}

/// Exec-time model for one simulated backend: µs for one run of batch
/// `b`.
pub type CostFn = Box<dyn Fn(usize) -> u64>;

struct SimReplica {
    queue: VecDeque<Pending>,
    sched: Scheduler,
    metrics: Arc<Metrics>,
    busy: bool,
}

struct SimModel {
    cfg: QueueConfig,
    backend: Box<dyn Backend>,
    cost_fn: CostFn,
    per_image: usize,
    classes: usize,
    admission: Arc<ModelAdmission>,
    replicas: Vec<SimReplica>,
}

/// Single-threaded discrete-event twin of [`super::Server`]. See the
/// module docs; API mirrors the server where it can
/// ([`SimServer::submit_at`] ≈ `Server::submit` with an explicit
/// arrival time, [`SimServer::stats`] = merged + admission-stamped
/// snapshots).
#[derive(Default)]
pub struct SimServer {
    clock: VirtualClock,
    admission_cfg: AdmissionConfig,
    global_committed: Arc<AtomicU64>,
    models: BTreeMap<String, SimModel>,
    events: EventQueue,
    next_id: u64,
    dispatched: BTreeMap<u64, usize>,
    audit: Vec<AdmitRecord>,
    exec_log: Vec<ExecRecord>,
}

impl SimServer {
    /// A simulator with default admission (enabled, no global backlog
    /// cap) at virtual t = 0.
    pub fn new() -> SimServer {
        SimServer::default()
    }

    /// A simulator with an explicit server-wide admission policy.
    pub fn with_admission(cfg: AdmissionConfig) -> SimServer {
        SimServer { admission_cfg: cfg, ..SimServer::default() }
    }

    /// The virtual clock every queue/deadline/metrics decision reads.
    /// `run` advances it; tests only read it.
    pub fn clock(&self) -> &VirtualClock {
        &self.clock
    }

    /// Current virtual time, µs.
    pub fn now_us(&self) -> u64 {
        self.clock.now_us()
    }

    /// Register a model whose batch exec time is *priced like the
    /// scheduler prices it*: plan cost units × the seeded calibration
    /// (`cfg.calibration`, else the backend's persisted one). With exact
    /// costs the scheduler's EWMA sits at its fixed point, so estimates
    /// never drift mid-test — the foundation for exact assertions.
    /// Models without costs or calibration execute in a nominal 1000 µs
    /// per batch.
    pub fn register(
        &mut self,
        name: impl Into<String>,
        backend: Box<dyn Backend>,
        cfg: QueueConfig,
    ) -> Result<(), CadnnError> {
        let costs = if cfg.planned { backend.plan_costs() } else { Vec::new() };
        let cal = cfg.calibration.or_else(|| backend.calibration());
        let cost_fn: CostFn = match cal {
            Some(c) if !costs.is_empty() => {
                let costs = costs.clone();
                Box::new(move |b| {
                    costs
                        .iter()
                        .find(|&&(bb, _)| bb == b)
                        .map(|&(_, units)| (units * c).ceil() as u64)
                        .unwrap_or(1_000)
                })
            }
            _ => Box::new(|_| 1_000),
        };
        self.register_with_cost(name, backend, cfg, cost_fn)
    }

    /// Register a model with an explicit exec-time model (µs per batch
    /// run). The backend still produces the actual logits; `cost` only
    /// decides how much virtual time each batch consumes.
    pub fn register_with_cost(
        &mut self,
        name: impl Into<String>,
        backend: Box<dyn Backend>,
        cfg: QueueConfig,
        cost: CostFn,
    ) -> Result<(), CadnnError> {
        let name = name.into();
        if self.models.contains_key(&name) {
            return Err(CadnnError::config(format!("model '{name}' registered twice")));
        }
        let batches = backend.batch_sizes();
        if batches.is_empty() {
            return Err(CadnnError::config("backend reports no batch variants"));
        }
        let per_image: usize = backend.input_shape().iter().product();
        let classes = backend.classes();
        let plan_costs = if cfg.planned { backend.plan_costs() } else { Vec::new() };
        let n = cfg.replicas.max(1);
        let replicas: Vec<SimReplica> = (0..n)
            .map(|_| {
                let mut sched =
                    Scheduler::new(batches.clone(), plan_costs.clone(), cfg.fallback);
                if cfg.planned {
                    if let Some(c) = cfg.calibration.or_else(|| backend.calibration()) {
                        sched.calibrate(c);
                    }
                }
                let metrics = Arc::new(Metrics::with_clock(self.clock.shared()));
                metrics.record_calibration(sched.us_per_unit());
                SimReplica { queue: VecDeque::new(), sched, metrics, busy: false }
            })
            .collect();
        let admission = Arc::new(ModelAdmission::new(
            self.admission_cfg,
            n,
            cfg.max_wait_us,
            cfg.quota_us,
            Arc::clone(&replicas[0].metrics),
            Arc::clone(&self.global_committed),
        ));
        admission.set_pricing(&plan_costs);
        self.models.insert(
            name,
            SimModel { cfg, backend, cost_fn: cost, per_image, classes, admission, replicas },
        );
        Ok(())
    }

    /// Schedule one request to arrive at virtual time `at_us`. Routing
    /// and input-length errors surface synchronously (same contract as
    /// `Server::submit`); the admission decision happens at *arrival*
    /// processing, in event order. The reply lands in the returned
    /// receiver during [`SimServer::run`].
    pub fn submit_at(
        &mut self,
        at_us: u64,
        req: ServeRequest,
    ) -> Result<Receiver<ServeResponse>, CadnnError> {
        let model = self
            .models
            .get(&req.model)
            .ok_or_else(|| CadnnError::UnknownModel { name: req.model.clone() })?;
        if req.input.len() != model.per_image {
            return Err(CadnnError::InvalidInput {
                reason: format!(
                    "input length {} != expected {} for model '{}'",
                    req.input.len(),
                    model.per_image,
                    req.model
                ),
            });
        }
        let (rtx, rrx) = channel();
        self.next_id += 1;
        self.events.push(
            at_us,
            EvKind::Arrival(Submission {
                id: self.next_id,
                trace: self.next_id,
                model: req.model,
                input: req.input,
                deadline_us: req.deadline_us,
                topk: req.topk,
                reply: rtx,
            }),
        );
        Ok(rrx)
    }

    /// Drain every event, advancing virtual time to each event's stamp.
    /// Returns the final virtual time. Deterministic: identical
    /// registrations + submissions ⇒ identical replies, metrics, and
    /// audit trails.
    pub fn run(&mut self) -> u64 {
        while let Some(ev) = self.events.pop() {
            // monotonic guard: an event scheduled "now" during handling
            // can never move time backward
            if ev.at > self.clock.now_us() {
                self.clock.set_us(ev.at);
            }
            match ev.kind {
                EvKind::Arrival(sub) => self.handle_arrival(sub),
                EvKind::Wake { model, replica } => self.handle_wake(&model, replica),
                EvKind::Complete { model, replica, b, formed_at_us, exec_us, result, batch } => {
                    self.handle_complete(&model, replica, b, formed_at_us, exec_us, result, batch)
                }
            }
        }
        self.clock.now_us()
    }

    /// Every admission decision made so far, in decision order.
    pub fn audit(&self) -> &[AdmitRecord] {
        &self.audit
    }

    /// Every executed request so far, in batch-formation order.
    pub fn exec_log(&self) -> &[ExecRecord] {
        &self.exec_log
    }

    /// Per-model snapshots: replica recorders merged, admission
    /// accounting stamped — the same shape `Server::stats` returns.
    pub fn stats(&self) -> BTreeMap<String, MetricsSnapshot> {
        self.models
            .iter()
            .map(|(name, m)| {
                let merged =
                    MetricsSnapshot::merge_all(m.replicas.iter().map(|r| r.metrics.snapshot()))
                        .unwrap_or_default();
                (name.clone(), stamp_admission(merged, &m.admission))
            })
            .collect()
    }

    /// Per-replica raw snapshots for one model (index = replica).
    pub fn replica_stats(&self, model: &str) -> Option<Vec<MetricsSnapshot>> {
        self.models
            .get(model)
            .map(|m| m.replicas.iter().map(|r| r.metrics.snapshot()).collect())
    }

    /// One model's admission state (committed work, shed counts).
    pub fn admission(&self, model: &str) -> Option<&ModelAdmission> {
        self.models.get(model).map(|m| m.admission.as_ref())
    }

    fn handle_arrival(&mut self, sub: Submission) {
        let now = self.clock.now_us();
        let Some(model) = self.models.get_mut(&sub.model) else { return };
        let decision = model.admission.admit(sub.deadline_us);
        self.audit.push(AdmitRecord {
            id: sub.id,
            model: sub.model.clone(),
            at_us: now,
            decision,
        });
        let cost_us = match decision {
            AdmitDecision::Admit { cost_us, .. } => cost_us,
            refused => {
                let _ = sub
                    .reply
                    .send(shed_response(&sub.model, sub.id, sub.trace, sub.deadline_us, refused));
                return;
            }
        };
        // shortest replica queue, ties to the lowest index — same
        // dispatch rule as the threaded server
        let r = (0..model.replicas.len())
            .min_by_key(|&i| model.replicas[i].queue.len())
            .unwrap_or(0);
        self.dispatched.insert(sub.id, r);
        let rep = &mut model.replicas[r];
        rep.queue.push_back(Pending {
            id: sub.id,
            input: sub.input,
            enqueued_us: now,
            deadline_at_us: sub.deadline_us.map(|d| now.saturating_add(d)),
            deadline_us: sub.deadline_us,
            cost_us,
            trace: sub.trace,
            topk: sub.topk,
            reply: sub.reply,
        });
        rep.metrics.set_queue_depth(rep.queue.len());
        if !rep.busy {
            self.events.push(now, EvKind::Wake { model: sub.model, replica: r });
        }
    }

    fn handle_wake(&mut self, name: &str, r: usize) {
        let now = self.clock.now_us();
        let Some(model) = self.models.get_mut(name) else { return };
        if model.replicas[r].busy {
            return; // Complete will re-wake
        }
        loop {
            {
                let rep = &mut model.replicas[r];
                let min_est = rep.sched.min_est_us();
                expire_queue(name, &mut rep.queue, &rep.metrics, min_est, now, &model.admission);
                rep.metrics.set_queue_depth(rep.queue.len());
            }
            if model.replicas[r].queue.is_empty() {
                if !sim_steal(model, r) {
                    return;
                }
                continue; // stolen work may itself be expired
            }
            let due = formation_due_us(&model.replicas[r].queue, &model.cfg);
            if now < due {
                self.events
                    .push(due, EvKind::Wake { model: name.to_string(), replica: r });
                return;
            }
            let (b, batch, input) = {
                let rep = &mut model.replicas[r];
                let b = plan_batch(&rep.queue, &model.cfg, &mut rep.sched, now);
                let take = b.min(rep.queue.len());
                let batch: Vec<Pending> = rep.queue.drain(..take).collect();
                rep.metrics.set_queue_depth(rep.queue.len());
                let input = gather_input(&batch, b, model.per_image);
                (b, batch, input)
            };
            // same trace propagation as the threaded worker: exec spans
            // recorded inside run_batch carry the head request's trace
            let result = {
                let _tg = crate::obs::with_trace(batch.first().map(|p| p.trace).unwrap_or(0));
                model.backend.run_batch(b, &input)
            };
            let exec_us = (model.cost_fn)(b).max(1);
            for p in &batch {
                self.exec_log.push(ExecRecord {
                    id: p.id,
                    model: name.to_string(),
                    dispatched: self.dispatched.get(&p.id).copied().unwrap_or(r),
                    executed: r,
                    formed_at_us: now,
                    batch: b,
                });
            }
            model.replicas[r].busy = true;
            self.events.push(
                now.saturating_add(exec_us),
                EvKind::Complete {
                    model: name.to_string(),
                    replica: r,
                    b,
                    formed_at_us: now,
                    exec_us,
                    result,
                    batch,
                },
            );
            return;
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn handle_complete(
        &mut self,
        name: &str,
        r: usize,
        b: usize,
        formed_at_us: u64,
        exec_us: u64,
        result: Result<Vec<f32>, CadnnError>,
        batch: Vec<Pending>,
    ) {
        let Some(model) = self.models.get_mut(name) else { return };
        let rep = &mut model.replicas[r];
        rep.busy = false;
        if result.is_ok() {
            rep.sched.observe(b, exec_us as f64);
            rep.metrics.record_calibration(rep.sched.us_per_unit());
        }
        complete_batch(
            name,
            result,
            batch,
            b,
            formed_at_us,
            exec_us,
            model.classes,
            &rep.metrics,
            &model.admission,
        );
        let now = self.clock.now_us();
        self.events.push(now, EvKind::Wake { model: name.to_string(), replica: r });
    }
}

/// Same stealing rule as the threaded [`super::try_steal`]: take the
/// tail half of the deepest sibling queue (≥ 2 entries); the victim's
/// FIFO prefix and the stolen block's internal order are preserved.
fn sim_steal(model: &mut SimModel, me: usize) -> bool {
    let victim = (0..model.replicas.len())
        .filter(|&i| i != me)
        .max_by_key(|&i| model.replicas[i].queue.len());
    let Some(victim) = victim else { return false };
    if model.replicas[victim].queue.len() < 2 {
        return false;
    }
    let stolen = {
        let vq = &mut model.replicas[victim].queue;
        let keep = vq.len() - vq.len() / 2;
        let stolen = vq.split_off(keep);
        model.replicas[victim].metrics.set_queue_depth(model.replicas[victim].queue.len());
        stolen
    };
    let rep = &mut model.replicas[me];
    rep.queue.extend(stolen);
    rep.metrics.set_queue_depth(rep.queue.len());
    rep.metrics.record_steal();
    obs::add(obs::Counter::ServeSteals, 1);
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::ServeError;

    /// Synthetic backend: identity-ish logits, affine plan costs.
    struct CostBackend {
        batches: Vec<usize>,
    }

    impl Backend for CostBackend {
        fn name(&self) -> &str {
            "cost-backend"
        }
        fn input_shape(&self) -> &[usize] {
            &[2, 2, 1]
        }
        fn classes(&self) -> usize {
            4
        }
        fn batch_sizes(&self) -> Vec<usize> {
            self.batches.clone()
        }
        fn run_batch(&self, batch: usize, input: &[f32]) -> Result<Vec<f32>, CadnnError> {
            // logits = the image itself (4 values in, 4 classes out)
            Ok(input[..batch * 4].to_vec())
        }
        fn plan_costs(&self) -> Vec<(usize, f64)> {
            self.batches.iter().map(|&b| (b, 100.0 + 1_000.0 * b as f64)).collect()
        }
    }

    fn cfg() -> QueueConfig {
        QueueConfig { calibration: Some(1.0), ..QueueConfig::default() }
    }

    #[test]
    fn two_arrivals_in_one_window_ride_one_batch() {
        let mut sim = SimServer::new();
        sim.register("m", Box::new(CostBackend { batches: vec![1, 2, 4, 8] }), cfg())
            .unwrap();
        let a = sim.submit_at(0, ServeRequest::new("m", vec![1.0; 4])).unwrap();
        let b = sim.submit_at(500, ServeRequest::new("m", vec![2.0; 4]).topk(1)).unwrap();
        sim.run();
        let ra = a.try_recv().unwrap();
        let rb = b.try_recv().unwrap();
        assert_eq!(ra.batch, 2, "window held the batch until the co-rider arrived");
        assert_eq!(rb.batch, 2);
        // batch formed at the head's window expiry (t = 0 + 2000µs),
        // exec = 100 + 1000·2 = 2100µs
        assert_eq!(ra.latency_us, 4_100.0);
        assert_eq!(rb.latency_us, 3_600.0);
        assert_eq!(ra.logits().unwrap(), &[1.0; 4]);
        assert_eq!(rb.topk.as_ref().unwrap()[0], (0, 2.0));
        let s = &sim.stats()["m"];
        assert_eq!(s.requests, 2);
        assert_eq!(s.batches, 1);
        assert_eq!(s.committed_us, 0, "commitments fully released");
    }

    #[test]
    fn queued_deadline_expiry_is_exact_and_attributed() {
        let mut sim = SimServer::new();
        // quota admits only ~one outstanding request; the second is shed
        let mut c = cfg();
        c.max_batch = 1;
        sim.register("m", Box::new(CostBackend { batches: vec![1] }), c).unwrap();
        // batch of 1 costs 1100µs; deadline 40_000µs is feasible for the
        // first two, but the third arrives behind 2 queued batches and a
        // deadline the admission estimate says it cannot make
        let a = sim
            .submit_at(0, ServeRequest::new("m", vec![0.0; 4]).deadline_us(40_000))
            .unwrap();
        let b = sim
            .submit_at(10, ServeRequest::new("m", vec![0.0; 4]).deadline_us(40_000))
            .unwrap();
        let c2 = sim
            .submit_at(20, ServeRequest::new("m", vec![0.0; 4]).deadline_us(1_000))
            .unwrap();
        sim.run();
        assert!(a.try_recv().unwrap().outcome.is_ok());
        assert!(b.try_recv().unwrap().outcome.is_ok());
        let shed = c2.try_recv().unwrap();
        assert_eq!(
            shed.outcome,
            Err(ServeError::Deadline { deadline_us: 1_000, waited_us: 0 }),
            "predicted completion exceeds the 1ms budget: shed at enqueue"
        );
        let s = &sim.stats()["m"];
        assert_eq!(s.shed_deadline, 1);
        assert_eq!(s.deadline_misses_queue, 0);
        assert_eq!(s.requests, 2);
    }

    #[test]
    fn replicas_share_a_burst_and_audit_records_the_dispatch() {
        let mut sim = SimServer::new();
        let mut c = cfg();
        c.replicas = 2;
        c.max_batch = 2;
        sim.register("m", Box::new(CostBackend { batches: vec![1, 2] }), c).unwrap();
        let rxs: Vec<_> = (0..6)
            .map(|_| sim.submit_at(0, ServeRequest::new("m", vec![0.0; 4])).unwrap())
            .collect();
        sim.run();
        for rx in rxs {
            assert!(rx.try_recv().unwrap().outcome.is_ok());
        }
        let used: std::collections::BTreeSet<usize> =
            sim.exec_log().iter().map(|e| e.executed).collect();
        assert_eq!(used.len(), 2, "both replicas executed work");
        assert_eq!(sim.exec_log().len(), 6);
        let s = &sim.stats()["m"];
        assert_eq!(s.requests, 6);
        assert_eq!(s.replicas, 2);
    }

    #[test]
    fn identical_runs_are_bit_identical() {
        let run_once = || {
            let mut sim = SimServer::new();
            let mut c = cfg();
            c.replicas = 2;
            sim.register("m", Box::new(CostBackend { batches: vec![1, 2, 4, 8] }), c)
                .unwrap();
            let rxs: Vec<_> = (0..40)
                .map(|i| {
                    sim.submit_at(
                        i * 300,
                        ServeRequest::new("m", vec![i as f32; 4]).deadline_us(20_000),
                    )
                    .unwrap()
                })
                .collect();
            let end = sim.run();
            let outcomes: Vec<String> = rxs
                .iter()
                .map(|rx| format!("{:?}", rx.try_recv().map(|r| (r.id, r.latency_us, r.batch))))
                .collect();
            let log: Vec<ExecRecord> = sim.exec_log().to_vec();
            (end, outcomes, log)
        };
        assert_eq!(run_once(), run_once());
    }
}
