//! Batch-size selection among the compiled (shape-static) batch
//! variants: the legacy policy-driven [`pick_batch`] and the
//! planner-informed, deadline-aware [`Scheduler`].
//!
//! The scheduler closes the loop the ROADMAP called "planner-aware
//! batching": the per-layer format planner already prices every pruned
//! layer ([`crate::planner::ExecPlan::cost_at`]), so the batch-size
//! choice can trade throughput (larger batches amortize the dispatch
//! overhead) against each pending request's deadline (larger batches run
//! longer) on the *same* cost model that chose the kernels. The abstract
//! cost units are mapped to microseconds online, from the exec times the
//! worker observes ([`Scheduler::observe`]), so no device-specific
//! calibration table is needed.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchPolicy {
    /// Smallest compiled batch >= pending (pads the remainder). Wastes
    /// some compute, minimizes queue latency.
    PadToFit,
    /// Largest compiled batch <= pending (runs multiple rounds). No
    /// padding waste, but the tail waits.
    Greedy,
}

/// Choose the compiled batch for `pending` requests from `available`
/// (ascending batch sizes, non-empty) — the pre-planner policy rule,
/// still the fallback whenever no cost model is available.
pub fn pick_batch(pending: usize, available: &[usize], policy: BatchPolicy) -> usize {
    debug_assert!(!available.is_empty());
    debug_assert!(available.windows(2).all(|w| w[0] < w[1]), "must be ascending");
    let pending = pending.max(1);
    match policy {
        BatchPolicy::PadToFit => available
            .iter()
            .copied()
            .find(|&b| b >= pending)
            .unwrap_or(*available.last().unwrap()),
        BatchPolicy::Greedy => available
            .iter()
            .copied()
            .rev()
            .find(|&b| b <= pending)
            .unwrap_or(available[0]),
    }
}

/// Smoothing factor for the exec-time observations (higher = newer
/// observations dominate faster).
const EWMA_ALPHA: f64 = 0.3;

/// Planner-informed, deadline-aware batch-size chooser.
///
/// Construction takes the backend's batch variants and their plan costs
/// (`(batch, units)` pairs from [`crate::api::Backend::plan_costs`] —
/// i.e. `ExecPlan::cost_at(b)` per variant). Until a units→µs scale
/// exists (first [`Scheduler::observe`] or an explicit
/// [`Scheduler::calibrate`]), or when the cost model doesn't cover every
/// variant, [`Scheduler::pick`] falls back to the plain policy rule.
///
/// Once estimable, `pick` maximizes throughput — served images per
/// estimated microsecond — over the variants whose estimated run time
/// fits the tightest pending deadline's slack. When no variant fits, it
/// picks the cheapest one so the queue still drains (the expired
/// requests are answered with an explicit deadline miss by the worker).
#[derive(Debug)]
pub struct Scheduler {
    available: Vec<usize>,
    /// batch -> plan cost units.
    units: BTreeMap<usize, f64>,
    fallback: BatchPolicy,
    /// batch -> EWMA of observed exec µs (trusted over the prior).
    observed: BTreeMap<usize, f64>,
    /// EWMA of observed µs per cost unit (scales the prior to batches
    /// not yet observed).
    us_per_unit: Option<f64>,
}

impl Scheduler {
    /// `available` must be ascending (the backend contract).
    pub fn new(
        available: Vec<usize>,
        plan_costs: Vec<(usize, f64)>,
        fallback: BatchPolicy,
    ) -> Scheduler {
        let units = plan_costs.into_iter().filter(|(_, u)| *u > 0.0).collect();
        Scheduler {
            available,
            units,
            fallback,
            observed: BTreeMap::new(),
            us_per_unit: None,
        }
    }

    /// True when every available batch variant has a cost-model entry —
    /// the precondition for planner-driven picks.
    pub fn planned(&self) -> bool {
        !self.available.is_empty() && self.available.iter().all(|b| self.units.contains_key(b))
    }

    /// Seed the units→µs scale directly (a persisted calibration from
    /// the artifact manifest, tests, benches, or a known device
    /// profile); observations keep refining it.
    pub fn calibrate(&mut self, us_per_unit: f64) {
        if us_per_unit > 0.0 {
            self.us_per_unit = Some(us_per_unit);
        }
    }

    /// The current units→µs scale (EWMA-converged over observations, or
    /// the seeded value before any) — what gets persisted into the
    /// artifact manifest (`Manifest::record_calibration`) so the next
    /// process is deadline-accurate from its first batch.
    pub fn us_per_unit(&self) -> Option<f64> {
        self.us_per_unit
    }

    /// Feed back one executed batch's wall-clock time. Updates the
    /// per-batch estimate and the units→µs scale.
    pub fn observe(&mut self, batch: usize, exec_us: f64) {
        if !exec_us.is_finite() || exec_us <= 0.0 {
            return;
        }
        let e = self.observed.entry(batch).or_insert(exec_us);
        *e += EWMA_ALPHA * (exec_us - *e);
        if let Some(&u) = self.units.get(&batch) {
            if u > 0.0 {
                let sample = exec_us / u;
                let s = self.us_per_unit.get_or_insert(sample);
                *s += EWMA_ALPHA * (sample - *s);
            }
        }
    }

    /// Estimated wall-clock µs for one run of `batch`: the observed EWMA
    /// when this batch has run before, otherwise the plan cost scaled by
    /// the calibrated units→µs rate. `None` when neither exists.
    pub fn est_us(&self, batch: usize) -> Option<f64> {
        if let Some(&o) = self.observed.get(&batch) {
            return Some(o);
        }
        match (self.us_per_unit, self.units.get(&batch)) {
            (Some(upu), Some(&u)) => Some(upu * u),
            _ => None,
        }
    }

    /// Estimated exec µs of the *cheapest* available batch — the bar a
    /// request's whole deadline budget must clear to be servable at all.
    /// A fresh request whose budget is below this was infeasible on
    /// arrival (the metrics' deadline-miss cause split); one above it
    /// that still expires died waiting in the queue. `None` until the
    /// scheduler can estimate (uncalibrated or unplanned).
    pub fn min_est_us(&self) -> Option<f64> {
        self.available
            .iter()
            .filter_map(|&b| self.est_us(b))
            .min_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal))
    }

    /// Choose the batch for `pending` queued requests. `slack_us` is the
    /// tightest pending deadline's remaining time (`None` when no queued
    /// request carries a deadline).
    pub fn pick(&self, pending: usize, slack_us: Option<f64>) -> usize {
        self.pick_with(pending, |_| slack_us)
    }

    /// Generalized [`Scheduler::pick`]: `slack_of(b)` is the tightest
    /// deadline slack among the requests that would actually ride a
    /// batch of size `b` (the FIFO prefix the worker will take) — `None`
    /// when none of those requests carries a deadline. A tight deadline
    /// *behind* the batch boundary must not shrink the batch: the
    /// urgent request isn't served by it either way, and a bigger batch
    /// drains the queue toward it faster.
    ///
    /// The policy fallback applies whenever the scheduler was built
    /// without a full cost model ([`Scheduler::planned`] is false —
    /// including `QueueConfig { planned: false }`, which passes no
    /// costs) or the units→µs scale is not yet known; exec-time
    /// observations alone never flip a policy-only scheduler into
    /// planner mode.
    pub fn pick_with(
        &self,
        pending: usize,
        slack_of: impl Fn(usize) -> Option<f64>,
    ) -> usize {
        let pending = pending.max(1);
        if !self.planned() {
            return pick_batch(pending, &self.available, self.fallback);
        }
        let ests: Vec<(usize, f64)> = self
            .available
            .iter()
            .filter_map(|&b| self.est_us(b).map(|e| (b, e.max(1e-9))))
            .collect();
        if ests.len() != self.available.len() {
            // not yet calibrated: plain policy
            return pick_batch(pending, &self.available, self.fallback);
        }
        let feasible: Vec<(usize, f64)> = ests
            .iter()
            .copied()
            .filter(|&(b, e)| slack_of(b).is_none_or(|s| e <= s))
            .collect();
        if feasible.is_empty() {
            // nothing fits its riders' tightest deadline: run the
            // cheapest batch so the queue drains (the worker answers
            // expired requests with an explicit deadline miss)
            return ests
                .iter()
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .map(|&(b, _)| b)
                .unwrap();
        }
        feasible
            .iter()
            .max_by(|a, b| {
                let ta = pending.min(a.0) as f64 / a.1;
                let tb = pending.min(b.0) as f64 / b.1;
                // higher throughput wins; ties go to the smaller batch
                // (lower latency, less padding)
                ta.partial_cmp(&tb).unwrap().then(b.0.cmp(&a.0))
            })
            .map(|&(b, _)| b)
            .unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::BatchCost;
    use crate::prop_assert;
    use crate::util::prop;
    use crate::util::rng::Rng;

    const AVAIL: [usize; 3] = [1, 4, 8];

    #[test]
    fn pad_to_fit_picks_smallest_covering() {
        assert_eq!(pick_batch(1, &AVAIL, BatchPolicy::PadToFit), 1);
        assert_eq!(pick_batch(2, &AVAIL, BatchPolicy::PadToFit), 4);
        assert_eq!(pick_batch(4, &AVAIL, BatchPolicy::PadToFit), 4);
        assert_eq!(pick_batch(5, &AVAIL, BatchPolicy::PadToFit), 8);
        assert_eq!(pick_batch(50, &AVAIL, BatchPolicy::PadToFit), 8);
    }

    #[test]
    fn greedy_picks_largest_fitting() {
        assert_eq!(pick_batch(1, &AVAIL, BatchPolicy::Greedy), 1);
        assert_eq!(pick_batch(3, &AVAIL, BatchPolicy::Greedy), 1);
        assert_eq!(pick_batch(4, &AVAIL, BatchPolicy::Greedy), 4);
        assert_eq!(pick_batch(7, &AVAIL, BatchPolicy::Greedy), 4);
        assert_eq!(pick_batch(9, &AVAIL, BatchPolicy::Greedy), 8);
    }

    #[test]
    fn zero_pending_treated_as_one() {
        assert_eq!(pick_batch(0, &AVAIL, BatchPolicy::PadToFit), 1);
        assert_eq!(pick_batch(0, &AVAIL, BatchPolicy::Greedy), 1);
    }

    #[test]
    fn non_contiguous_batch_sets() {
        // gaps and a floor above 1 — e.g. a manifest compiled at [2, 3, 7]
        let avail = [2usize, 3, 7];
        // PadToFit: smallest covering, or the largest when none covers
        assert_eq!(pick_batch(1, &avail, BatchPolicy::PadToFit), 2);
        assert_eq!(pick_batch(2, &avail, BatchPolicy::PadToFit), 2);
        assert_eq!(pick_batch(3, &avail, BatchPolicy::PadToFit), 3);
        assert_eq!(pick_batch(4, &avail, BatchPolicy::PadToFit), 7);
        assert_eq!(pick_batch(6, &avail, BatchPolicy::PadToFit), 7);
        assert_eq!(pick_batch(7, &avail, BatchPolicy::PadToFit), 7);
        assert_eq!(pick_batch(100, &avail, BatchPolicy::PadToFit), 7);
        // Greedy: largest fitting, or the smallest when none fits
        assert_eq!(pick_batch(1, &avail, BatchPolicy::Greedy), 2);
        assert_eq!(pick_batch(2, &avail, BatchPolicy::Greedy), 2);
        assert_eq!(pick_batch(4, &avail, BatchPolicy::Greedy), 3);
        assert_eq!(pick_batch(6, &avail, BatchPolicy::Greedy), 3);
        assert_eq!(pick_batch(7, &avail, BatchPolicy::Greedy), 7);
        assert_eq!(pick_batch(9, &avail, BatchPolicy::Greedy), 7);
    }

    #[test]
    fn singleton_batch_set() {
        for pending in [0usize, 1, 5, 40] {
            assert_eq!(pick_batch(pending, &[4], BatchPolicy::PadToFit), 4);
            assert_eq!(pick_batch(pending, &[4], BatchPolicy::Greedy), 4);
        }
    }

    #[test]
    fn prop_pick_batch_invariants() {
        prop::check("pick_batch invariants", |rng: &mut Rng| {
            // random ascending available set
            let mut avail = vec![1usize];
            let mut v = 1;
            for _ in 0..rng.range(0, 4) {
                v *= rng.range(2, 4);
                avail.push(v);
            }
            let pending = rng.range(0, 40);
            for policy in [BatchPolicy::PadToFit, BatchPolicy::Greedy] {
                let b = pick_batch(pending, &avail, policy);
                prop_assert!(avail.contains(&b), "picked {} not available", b);
                // progress guarantee: the flush loop always drains >= 1
                prop_assert!(b >= 1, "no progress");
                if policy == BatchPolicy::PadToFit && pending.max(1) <= *avail.last().unwrap() {
                    prop_assert!(
                        b >= pending.max(1),
                        "pad-to-fit must cover pending: {} < {}",
                        b,
                        pending
                    );
                }
                if policy == BatchPolicy::Greedy && pending >= 1 {
                    prop_assert!(
                        b <= pending.max(1) || b == avail[0],
                        "greedy overshoot: {} > {}",
                        b,
                        pending
                    );
                }
            }
            Ok(())
        });
    }

    // -----------------------------------------------------------------
    // Scheduler
    // -----------------------------------------------------------------

    fn affine_costs(avail: &[usize], overhead: f64, per_image: f64) -> Vec<(usize, f64)> {
        let c = BatchCost { per_image, overhead };
        avail.iter().map(|&b| (b, c.cost_at(b))).collect()
    }

    /// The acceptance demonstration: under a tight pending deadline the
    /// planner-informed scheduler picks a *smaller* batch than the greedy
    /// policy, and one whose estimated run time fits the slack.
    #[test]
    fn deadline_picks_smaller_batch_than_greedy() {
        let avail = vec![1usize, 2, 4, 8];
        let costs = affine_costs(&avail, 1000.0, 1000.0); // est(b) = 1000 + 1000b
        let mut s = Scheduler::new(avail.clone(), costs, BatchPolicy::Greedy);
        s.calibrate(1.0); // 1 unit = 1 µs
        let greedy = pick_batch(8, &avail, BatchPolicy::Greedy);
        assert_eq!(greedy, 8);
        // slack 6000µs: batch 8 (est 9000µs) would blow the deadline
        let picked = s.pick(8, Some(6_000.0));
        assert!(picked < greedy, "scheduler must back off from greedy {greedy}");
        assert_eq!(picked, 4, "best-throughput feasible batch");
        assert!(s.est_us(picked).unwrap() <= 6_000.0);
        // without deadline pressure, throughput wins: overhead amortizes
        assert_eq!(s.pick(8, None), 8);
        // pad-to-fit would also have overshot the deadline
        assert_eq!(pick_batch(8, &avail, BatchPolicy::PadToFit), 8);
    }

    #[test]
    fn uncalibrated_scheduler_falls_back_to_policy() {
        let avail = vec![1usize, 4, 8];
        let costs = affine_costs(&avail, 500.0, 200.0);
        let s = Scheduler::new(avail.clone(), costs, BatchPolicy::PadToFit);
        // no observation, no calibration -> plain policy
        assert_eq!(s.pick(3, Some(1.0)), 4);
        let none = Scheduler::new(avail, Vec::new(), BatchPolicy::Greedy);
        assert_eq!(none.pick(7, Some(1.0)), 4);
    }

    #[test]
    fn observations_override_the_prior() {
        let avail = vec![1usize, 4];
        let costs = affine_costs(&avail, 100.0, 100.0);
        let mut s = Scheduler::new(avail, costs, BatchPolicy::PadToFit);
        s.observe(4, 10_000.0);
        // batch 4 estimated from observation; batch 1 scaled from the
        // calibration the observation induced
        let e4 = s.est_us(4).unwrap();
        assert!((e4 - 10_000.0).abs() < 1e-6);
        let e1 = s.est_us(1).unwrap();
        assert!(e1 > 0.0 && e1 < e4, "batch 1 prior must be cheaper: {e1} vs {e4}");
        // repeated observations converge the EWMA
        for _ in 0..50 {
            s.observe(4, 2_000.0);
        }
        assert!((s.est_us(4).unwrap() - 2_000.0).abs() < 50.0);
    }

    #[test]
    fn nothing_feasible_picks_cheapest_and_still_drains() {
        let avail = vec![2usize, 4, 8];
        let costs = affine_costs(&avail, 1000.0, 1000.0);
        let mut s = Scheduler::new(avail.clone(), costs, BatchPolicy::Greedy);
        s.calibrate(1.0);
        // slack below the cheapest batch's estimate: progress over purity
        let b = s.pick(8, Some(10.0));
        assert_eq!(b, 2, "cheapest available batch drains the queue");
    }

    /// The satellite property: whenever *some* available batch fits the
    /// tightest pending deadline, the scheduler never picks one whose
    /// estimated cost exceeds it (and always picks an available batch).
    #[test]
    fn prop_scheduler_respects_tightest_deadline() {
        prop::check("scheduler deadline feasibility", |rng: &mut Rng| {
            let mut avail = vec![rng.range(1, 3)];
            for _ in 0..rng.range(1, 4) {
                let next = avail.last().unwrap() * rng.range(2, 4);
                avail.push(next);
            }
            let overhead = rng.range(0, 2000) as f64;
            let per_image = rng.range(1, 3000) as f64;
            let mut s = Scheduler::new(
                avail.clone(),
                affine_costs(&avail, overhead, per_image),
                BatchPolicy::PadToFit,
            );
            s.calibrate(0.25 + rng.f64());
            let pending = rng.range(1, 40);
            let slack = rng.range(1, 40_000) as f64;
            let picked = s.pick(pending, Some(slack));
            prop_assert!(avail.contains(&picked), "picked {} not available", picked);
            let est = s.est_us(picked).unwrap();
            let any_fits = avail.iter().any(|&b| s.est_us(b).unwrap() <= slack);
            if any_fits {
                prop_assert!(
                    est <= slack,
                    "picked batch {} est {:.0}µs exceeds tightest deadline slack {:.0}µs",
                    picked,
                    est,
                    slack
                );
            } else {
                let cheapest = avail
                    .iter()
                    .map(|&b| s.est_us(b).unwrap())
                    .fold(f64::INFINITY, f64::min);
                prop_assert!(
                    est <= cheapest + 1e-9,
                    "infeasible case must pick the cheapest batch"
                );
            }
            // and with no deadline, the pick is still an available batch
            let free = s.pick(pending, None);
            prop_assert!(avail.contains(&free), "picked {} not available", free);
            Ok(())
        });
    }

    /// The invariant the admission controller's per-request charge
    /// rests on (`admission.rs` commits `min_units × us_per_unit` per
    /// admitted request): a planned, calibrated scheduler never spends
    /// more than the cheapest batch estimate per *served* request —
    /// `est(picked) <= min(pending, picked) × min_est_us`, for every
    /// pending count and every deadline-slack shape. Amortized over any
    /// sequence of picks, the backlog therefore drains at least one
    /// committed charge per served request, which is what makes
    /// `predicted = committed/replicas + max_wait + worst_batch` an
    /// upper bound.
    #[test]
    fn prop_pick_amortized_cost_bounded_by_min_est() {
        prop::check_n("admission amortized cost bound", 200, |rng: &mut Rng| {
            let mut avail = vec![rng.range(1, 3)];
            for _ in 0..rng.range(1, 4) {
                let next = avail.last().unwrap() * rng.range(2, 4);
                avail.push(next);
            }
            let overhead = rng.range(0, 5_000) as f64;
            let per_image = rng.range(1, 3_000) as f64;
            let mut s = Scheduler::new(
                avail.clone(),
                affine_costs(&avail, overhead, per_image),
                BatchPolicy::PadToFit,
            );
            s.calibrate(0.25 + 2.0 * rng.f64());
            // a few observations at the true cost keep the EWMA at its
            // fixed point but exercise the observed-estimate path too
            for _ in 0..rng.range(0, 3) {
                let b = avail[rng.below(avail.len() as u64) as usize];
                s.observe(b, s.est_us(b).unwrap());
            }
            let min_est = s.min_est_us().unwrap();
            let pending = rng.range(1, 64);
            // three slack shapes: none, uniform, random per-prefix
            let uniform = rng.range(1, 30_000) as f64;
            let per_prefix: Vec<Option<f64>> = (0..avail.len())
                .map(|_| (rng.f64() < 0.7).then(|| rng.range(1, 30_000) as f64))
                .collect();
            let shapes: [Box<dyn Fn(usize) -> Option<f64>>; 3] = [
                Box::new(|_| None),
                Box::new(move |_| Some(uniform)),
                Box::new(move |b| per_prefix[b.saturating_sub(1).min(per_prefix.len() - 1)]),
            ];
            for slack_of in shapes {
                let picked = s.pick_with(pending, &slack_of);
                let served = pending.min(picked) as f64;
                let est = s.est_us(picked).unwrap();
                prop_assert!(
                    est <= served * min_est + 1e-6,
                    "batch {} est {:.1}µs exceeds {} served × min_est {:.1}µs",
                    picked,
                    est,
                    served,
                    min_est
                );
            }
            Ok(())
        });
    }

    /// `QueueConfig { planned: false }` builds the scheduler with no
    /// cost units; exec-time observations must never flip it into
    /// planner mode — the policy stays in charge forever (that's what
    /// bench_serving's greedy/padtofit baselines rely on).
    #[test]
    fn policy_mode_survives_observations() {
        let avail = vec![1usize, 4, 8];
        let mut s = Scheduler::new(avail.clone(), Vec::new(), BatchPolicy::Greedy);
        for &b in &avail {
            s.observe(b, 1_000.0 * b as f64);
        }
        assert!(!s.planned());
        // Greedy(3) = 1 even though the observed estimates would argue
        // for a different batch under throughput/deadline reasoning
        assert_eq!(s.pick(3, Some(10.0)), 1);
        assert_eq!(s.pick(3, None), 1);
    }

    /// A tight deadline *behind* the batch boundary must not shrink the
    /// batch — only the deadlines of the requests that would ride it
    /// (the FIFO prefix) constrain the choice.
    #[test]
    fn prefix_slack_ignores_deadlines_beyond_the_batch() {
        let avail = vec![1usize, 2, 4, 8];
        let costs = affine_costs(&avail, 1000.0, 1000.0); // est(b) = 1000 + 1000b
        let mut s = Scheduler::new(avail, costs, BatchPolicy::Greedy);
        s.calibrate(1.0);
        // 8 pending; only request #8 has a deadline (slack 3500µs).
        // est(8)=9000 blows it, but batches 1/2/4 don't serve #8 at all:
        let slack_of = |b: usize| if b >= 8 { Some(3_500.0) } else { None };
        let picked = s.pick_with(8, slack_of);
        assert_eq!(picked, 4, "free prefix must keep the throughput batch");
        // uniform slack (the degenerate pick()) would have collapsed to 2
        assert_eq!(s.pick(8, Some(3_500.0)), 2);
    }

    #[test]
    fn min_est_tracks_cheapest_batch() {
        let avail = vec![1usize, 4, 8];
        let mut s = Scheduler::new(avail.clone(), affine_costs(&avail, 1000.0, 1000.0),
            BatchPolicy::Greedy);
        assert_eq!(s.min_est_us(), None, "uncalibrated: no estimate");
        s.calibrate(1.0); // est(b) = 1000 + 1000b
        assert_eq!(s.min_est_us(), Some(2_000.0));
        // an observation that makes a bigger batch cheaper wins the min
        s.observe(8, 500.0);
        assert_eq!(s.min_est_us(), Some(500.0));
    }

    #[test]
    fn planned_requires_full_coverage() {
        let avail = vec![1usize, 2, 4];
        let full = Scheduler::new(avail.clone(), affine_costs(&avail, 10.0, 10.0),
            BatchPolicy::Greedy);
        assert!(full.planned());
        let partial = Scheduler::new(avail.clone(), vec![(1, 20.0)], BatchPolicy::Greedy);
        assert!(!partial.planned());
        let empty = Scheduler::new(avail, Vec::new(), BatchPolicy::Greedy);
        assert!(!empty.planned());
    }
}
