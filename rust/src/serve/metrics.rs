//! Serving metrics: per-request latency percentiles, batch utilization,
//! throughput, deadline misses — recorded per model, snapshotable for
//! [`crate::serve::Server::stats`].

use crate::util::stats::{Recorder, Summary};
use std::time::Instant;

#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    latency: Recorder,
    /// exec time per batch run
    exec: Recorder,
    pub requests: u64,
    pub batches: u64,
    /// sum over runs of (used slots) and (total slots) — padding waste.
    pub used_slots: u64,
    pub total_slots: u64,
    /// requests answered with a backend-error outcome.
    pub backend_errors: u64,
    /// requests answered with a deadline-miss outcome (never executed).
    pub deadline_misses: u64,
    /// The scheduler's current units→µs calibration (seeded at startup
    /// from a persisted manifest value, refined per executed batch) —
    /// surfaced so callers can persist it back
    /// (`runtime::Manifest::record_calibration`).
    pub us_per_unit: Option<f64>,
}

/// Plain-data view of one model's [`Metrics`] at a point in time — what
/// [`crate::serve::Server::stats`] hands out per model, safe to hold
/// without keeping the metrics mutex.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub backend_errors: u64,
    pub deadline_misses: u64,
    /// Fraction of executed batch slots carrying real requests
    /// (0.0 when nothing executed yet).
    pub batch_utilization: f64,
    /// Served requests per second over the window since metrics start
    /// (0.0 when nothing served or the window has zero width).
    pub throughput_rps: f64,
    pub latency: Option<Summary>,
    pub exec: Option<Summary>,
    /// Scheduler units→µs calibration at snapshot time (persistable).
    pub us_per_unit: Option<f64>,
}

impl Metrics {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            latency: Recorder::new(),
            exec: Recorder::new(),
            requests: 0,
            batches: 0,
            used_slots: 0,
            total_slots: 0,
            backend_errors: 0,
            deadline_misses: 0,
            us_per_unit: None,
        }
    }

    /// Publish the scheduler's current units→µs calibration (the worker
    /// calls this at startup with the seeded value and after each
    /// observed batch).
    pub fn record_calibration(&mut self, us_per_unit: Option<f64>) {
        self.us_per_unit = us_per_unit;
    }

    pub fn record_request(&mut self, latency_us: f64) {
        self.latency.record(latency_us);
        self.requests += 1;
    }

    pub fn record_batch(&mut self, batch: usize, used: usize, exec_us: f64) {
        self.batches += 1;
        self.used_slots += used as u64;
        self.total_slots += batch as u64;
        self.exec.record(exec_us);
    }

    /// Count requests that received an explicit backend-error response.
    pub fn record_errors(&mut self, n: u64) {
        self.backend_errors += n;
    }

    /// Count requests answered with `ServeError::Deadline` (expired in
    /// the queue, never executed).
    pub fn record_deadline_misses(&mut self, n: u64) {
        self.deadline_misses += n;
    }

    pub fn latency_summary(&self) -> Option<Summary> {
        self.latency.summary()
    }

    pub fn exec_summary(&self) -> Option<Summary> {
        self.exec.summary()
    }

    /// Requests per second since start. 0.0 when nothing has been served
    /// yet or the elapsed window has zero width (coarse clocks right
    /// after startup) — never a division-blowup artifact.
    pub fn throughput_rps(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if self.requests == 0 || secs <= 0.0 {
            return 0.0;
        }
        self.requests as f64 / secs
    }

    /// Fraction of executed batch slots carrying real requests. 0.0
    /// before the first batch executes: an idle model reports no
    /// utilization rather than a fake-perfect 100%.
    pub fn batch_utilization(&self) -> f64 {
        if self.total_slots == 0 {
            return 0.0;
        }
        self.used_slots as f64 / self.total_slots as f64
    }

    /// Freeze the current counters into a plain-data snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests,
            batches: self.batches,
            backend_errors: self.backend_errors,
            deadline_misses: self.deadline_misses,
            batch_utilization: self.batch_utilization(),
            throughput_rps: self.throughput_rps(),
            latency: self.latency_summary(),
            exec: self.exec_summary(),
            us_per_unit: self.us_per_unit,
        }
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "requests={} batches={} errors={} deadline_misses={} \
             throughput={:.1} req/s batch_util={:.0}%\n",
            self.requests,
            self.batches,
            self.backend_errors,
            self.deadline_misses,
            self.throughput_rps(),
            self.batch_utilization() * 100.0
        ));
        if let Some(s) = self.latency_summary() {
            out.push_str(&format!(
                "latency  p50={:.1}ms p95={:.1}ms p99={:.1}ms max={:.1}ms\n",
                s.p50 / 1e3,
                s.p95 / 1e3,
                s.p99 / 1e3,
                s.max / 1e3
            ));
        }
        if let Some(s) = self.exec_summary() {
            out.push_str(&format!(
                "exec     p50={:.1}ms mean={:.1}ms\n",
                s.p50 / 1e3,
                s.mean / 1e3
            ));
        }
        if let Some(u) = self.us_per_unit {
            out.push_str(&format!("calib    us_per_unit={u:.4}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = Metrics::new();
        m.record_request(1000.0);
        m.record_request(3000.0);
        m.record_batch(4, 2, 500.0);
        m.record_deadline_misses(1);
        assert_eq!(m.requests, 2);
        assert_eq!(m.batches, 1);
        assert_eq!(m.batch_utilization(), 0.5);
        let s = m.latency_summary().unwrap();
        assert_eq!(s.count, 2);
        let rpt = m.report();
        assert!(rpt.contains("requests=2"));
        assert!(rpt.contains("deadline_misses=1"));
        assert!(rpt.contains("latency"));
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert!(m.latency_summary().is_none());
        // no batches executed: no utilization to report (not fake 100%)
        assert_eq!(m.batch_utilization(), 0.0);
        // no requests served: zero throughput even on a zero-width
        // elapsed window (no 1e9-req/s division artifacts)
        assert_eq!(m.throughput_rps(), 0.0);
        assert!(m.report().contains("requests=0"));
    }

    #[test]
    fn snapshot_freezes_counters() {
        let mut m = Metrics::new();
        m.record_request(2000.0);
        m.record_batch(2, 2, 800.0);
        m.record_errors(3);
        let s = m.snapshot();
        assert_eq!(s.requests, 1);
        assert_eq!(s.batches, 1);
        assert_eq!(s.backend_errors, 3);
        assert_eq!(s.deadline_misses, 0);
        assert_eq!(s.batch_utilization, 1.0);
        assert_eq!(s.latency.as_ref().unwrap().count, 1);
        // the snapshot is detached: later recording doesn't change it
        m.record_errors(1);
        assert_eq!(s.backend_errors, 3);
    }
}
