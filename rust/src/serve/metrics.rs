//! Serving metrics: per-request latency histograms, queue-wait
//! distribution, batch utilization, throughput, deadline misses broken
//! down by cause — recorded per model, snapshotable for
//! [`crate::serve::Server::stats`].
//!
//! Everything here is lock-free: counters are relaxed atomics and the
//! latency distributions are [`Log2Hist`]s, so the serve worker records
//! with `&self` while `stats()` readers snapshot concurrently — no
//! `Mutex<Metrics>` on the hot path (the pre-obs design). The scalar
//! `latency` / `exec` [`Summary`]s in [`MetricsSnapshot`] are preserved
//! for API compatibility, now derived from the histograms (exact
//! count / mean / min / max, bucket-walk percentiles — see
//! `docs/OBSERVABILITY.md` for the error bound).

use crate::obs::{HistSnapshot, Log2Hist};
use crate::util::stats::Summary;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Lock-free per-model serving metrics; all recording takes `&self`.
#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    /// End-to-end request latency (enqueue → reply), µs.
    latency: Log2Hist,
    /// Exec time per batch run, µs.
    exec: Log2Hist,
    /// Queue wait (enqueue → batch formed), µs.
    queue_wait: Log2Hist,
    requests: AtomicU64,
    batches: AtomicU64,
    /// sum over runs of (used slots) and (total slots) — padding waste.
    used_slots: AtomicU64,
    total_slots: AtomicU64,
    /// requests answered with a backend-error outcome.
    backend_errors: AtomicU64,
    /// deadline misses by cause: expired while queued vs infeasible the
    /// moment they arrived (budget below the smallest batch's estimate).
    deadline_misses_queue: AtomicU64,
    deadline_misses_infeasible: AtomicU64,
    /// Current queue depth gauge (set by the worker each loop).
    queue_depth: AtomicU64,
    /// Scheduler units→µs calibration as f64 bits; 0 = unset (`None`).
    /// Seeded from a persisted manifest value, refined per batch.
    us_per_unit_bits: AtomicU64,
}

/// Plain-data view of one model's [`Metrics`] at a point in time — what
/// [`crate::serve::Server::stats`] hands out per model, safe to hold
/// indefinitely (the live metrics keep moving underneath).
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub backend_errors: u64,
    /// Total deadline misses (both causes) — the pre-obs field.
    pub deadline_misses: u64,
    /// ... broken down: expired while waiting in the queue,
    pub deadline_misses_queue: u64,
    /// ... vs infeasible on arrival (budget can't fit any batch).
    pub deadline_misses_infeasible: u64,
    /// Queue depth at snapshot time (requests waiting, gauge).
    pub queue_depth: u64,
    /// Fraction of executed batch slots carrying real requests
    /// (0.0 when nothing executed yet).
    pub batch_utilization: f64,
    /// Served requests per second over the window since metrics start
    /// (0.0 when nothing served or the window has zero width).
    pub throughput_rps: f64,
    pub latency: Option<Summary>,
    pub exec: Option<Summary>,
    /// Enqueue → batch-formed wait distribution.
    pub queue_wait: Option<Summary>,
    /// Full log₂ bucket histograms behind the summaries above.
    pub latency_hist: Option<HistSnapshot>,
    pub exec_hist: Option<HistSnapshot>,
    pub queue_wait_hist: Option<HistSnapshot>,
    /// Scheduler units→µs calibration at snapshot time (persistable).
    pub us_per_unit: Option<f64>,
}

impl Metrics {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            latency: Log2Hist::new(),
            exec: Log2Hist::new(),
            queue_wait: Log2Hist::new(),
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            used_slots: AtomicU64::new(0),
            total_slots: AtomicU64::new(0),
            backend_errors: AtomicU64::new(0),
            deadline_misses_queue: AtomicU64::new(0),
            deadline_misses_infeasible: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            us_per_unit_bits: AtomicU64::new(0),
        }
    }

    /// Publish the scheduler's current units→µs calibration (the worker
    /// calls this at startup with the seeded value and after each
    /// observed batch).
    pub fn record_calibration(&self, us_per_unit: Option<f64>) {
        let bits = match us_per_unit {
            Some(v) if v.is_finite() && v > 0.0 => v.to_bits(),
            _ => 0,
        };
        self.us_per_unit_bits.store(bits, Ordering::Relaxed);
    }

    pub fn record_request(&self, latency_us: f64) {
        self.latency.record(latency_us);
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Record how long a request sat queued before its batch formed.
    pub fn record_queue_wait(&self, wait_us: f64) {
        self.queue_wait.record(wait_us);
    }

    pub fn record_batch(&self, batch: usize, used: usize, exec_us: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.used_slots.fetch_add(used as u64, Ordering::Relaxed);
        self.total_slots.fetch_add(batch as u64, Ordering::Relaxed);
        self.exec.record(exec_us);
    }

    /// Count requests that received an explicit backend-error response.
    pub fn record_errors(&self, n: u64) {
        self.backend_errors.fetch_add(n, Ordering::Relaxed);
    }

    /// Count one request answered with `ServeError::Deadline`, by cause:
    /// `infeasible` means the deadline budget was already below the
    /// smallest batch's estimated exec time when the worker first saw
    /// the request — it never had a chance; `false` means it expired
    /// while waiting in the queue.
    pub fn record_deadline_miss(&self, infeasible: bool) {
        if infeasible {
            self.deadline_misses_infeasible.fetch_add(1, Ordering::Relaxed);
        } else {
            self.deadline_misses_queue.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Count `n` queue-expired deadline misses (compatibility shim for
    /// callers without cause information).
    pub fn record_deadline_misses(&self, n: u64) {
        self.deadline_misses_queue.fetch_add(n, Ordering::Relaxed);
    }

    /// Update the queue-depth gauge (worker, once per loop).
    pub fn set_queue_depth(&self, depth: usize) {
        self.queue_depth.store(depth as u64, Ordering::Relaxed);
    }

    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    pub fn backend_errors(&self) -> u64 {
        self.backend_errors.load(Ordering::Relaxed)
    }

    /// Total deadline misses across both causes.
    pub fn deadline_misses(&self) -> u64 {
        self.deadline_misses_queue() + self.deadline_misses_infeasible()
    }

    pub fn deadline_misses_queue(&self) -> u64 {
        self.deadline_misses_queue.load(Ordering::Relaxed)
    }

    pub fn deadline_misses_infeasible(&self) -> u64 {
        self.deadline_misses_infeasible.load(Ordering::Relaxed)
    }

    pub fn queue_depth(&self) -> u64 {
        self.queue_depth.load(Ordering::Relaxed)
    }

    pub fn us_per_unit(&self) -> Option<f64> {
        match self.us_per_unit_bits.load(Ordering::Relaxed) {
            0 => None,
            bits => Some(f64::from_bits(bits)),
        }
    }

    pub fn latency_summary(&self) -> Option<Summary> {
        self.latency.snapshot().map(|h| h.summary())
    }

    pub fn exec_summary(&self) -> Option<Summary> {
        self.exec.snapshot().map(|h| h.summary())
    }

    pub fn queue_wait_summary(&self) -> Option<Summary> {
        self.queue_wait.snapshot().map(|h| h.summary())
    }

    /// Requests per second since start. 0.0 when nothing has been served
    /// yet or the elapsed window has zero width (coarse clocks right
    /// after startup) — never a division-blowup artifact.
    pub fn throughput_rps(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        let requests = self.requests();
        if requests == 0 || secs <= 0.0 {
            return 0.0;
        }
        requests as f64 / secs
    }

    /// Fraction of executed batch slots carrying real requests. 0.0
    /// before the first batch executes: an idle model reports no
    /// utilization rather than a fake-perfect 100%.
    pub fn batch_utilization(&self) -> f64 {
        let total = self.total_slots.load(Ordering::Relaxed);
        if total == 0 {
            return 0.0;
        }
        self.used_slots.load(Ordering::Relaxed) as f64 / total as f64
    }

    /// Freeze the current counters into a plain-data snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let latency_hist = self.latency.snapshot();
        let exec_hist = self.exec.snapshot();
        let queue_wait_hist = self.queue_wait.snapshot();
        MetricsSnapshot {
            requests: self.requests(),
            batches: self.batches(),
            backend_errors: self.backend_errors(),
            deadline_misses: self.deadline_misses(),
            deadline_misses_queue: self.deadline_misses_queue(),
            deadline_misses_infeasible: self.deadline_misses_infeasible(),
            queue_depth: self.queue_depth(),
            batch_utilization: self.batch_utilization(),
            throughput_rps: self.throughput_rps(),
            latency: latency_hist.as_ref().map(|h| h.summary()),
            exec: exec_hist.as_ref().map(|h| h.summary()),
            queue_wait: queue_wait_hist.as_ref().map(|h| h.summary()),
            latency_hist,
            exec_hist,
            queue_wait_hist,
            us_per_unit: self.us_per_unit(),
        }
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "requests={} batches={} errors={} deadline_misses={} \
             (queue={} infeasible={}) queue_depth={} \
             throughput={:.1} req/s batch_util={:.0}%\n",
            self.requests(),
            self.batches(),
            self.backend_errors(),
            self.deadline_misses(),
            self.deadline_misses_queue(),
            self.deadline_misses_infeasible(),
            self.queue_depth(),
            self.throughput_rps(),
            self.batch_utilization() * 100.0
        ));
        if let Some(s) = self.latency_summary() {
            out.push_str(&format!(
                "latency  p50={:.1}ms p95={:.1}ms p99={:.1}ms max={:.1}ms\n",
                s.p50 / 1e3,
                s.p95 / 1e3,
                s.p99 / 1e3,
                s.max / 1e3
            ));
        }
        if let Some(s) = self.queue_wait_summary() {
            out.push_str(&format!(
                "queue    p50={:.1}ms p95={:.1}ms p99={:.1}ms max={:.1}ms\n",
                s.p50 / 1e3,
                s.p95 / 1e3,
                s.p99 / 1e3,
                s.max / 1e3
            ));
        }
        if let Some(s) = self.exec_summary() {
            out.push_str(&format!(
                "exec     p50={:.1}ms mean={:.1}ms\n",
                s.p50 / 1e3,
                s.mean / 1e3
            ));
        }
        if let Some(u) = self.us_per_unit() {
            out.push_str(&format!("calib    us_per_unit={u:.4}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let m = Metrics::new();
        m.record_request(1000.0);
        m.record_request(3000.0);
        m.record_batch(4, 2, 500.0);
        m.record_deadline_misses(1);
        assert_eq!(m.requests(), 2);
        assert_eq!(m.batches(), 1);
        assert_eq!(m.batch_utilization(), 0.5);
        let s = m.latency_summary().unwrap();
        assert_eq!(s.count, 2);
        let rpt = m.report();
        assert!(rpt.contains("requests=2"));
        assert!(rpt.contains("deadline_misses=1"));
        assert!(rpt.contains("latency"));
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert!(m.latency_summary().is_none());
        // no batches executed: no utilization to report (not fake 100%)
        assert_eq!(m.batch_utilization(), 0.0);
        // no requests served: zero throughput even on a zero-width
        // elapsed window (no 1e9-req/s division artifacts)
        assert_eq!(m.throughput_rps(), 0.0);
        assert!(m.report().contains("requests=0"));
    }

    #[test]
    fn snapshot_freezes_counters() {
        let m = Metrics::new();
        m.record_request(2000.0);
        m.record_batch(2, 2, 800.0);
        m.record_errors(3);
        let s = m.snapshot();
        assert_eq!(s.requests, 1);
        assert_eq!(s.batches, 1);
        assert_eq!(s.backend_errors, 3);
        assert_eq!(s.deadline_misses, 0);
        assert_eq!(s.batch_utilization, 1.0);
        assert_eq!(s.latency.as_ref().unwrap().count, 1);
        // the snapshot is detached: later recording doesn't change it
        m.record_errors(1);
        assert_eq!(s.backend_errors, 3);
    }

    #[test]
    fn deadline_misses_split_by_cause() {
        let m = Metrics::new();
        m.record_deadline_miss(false);
        m.record_deadline_miss(false);
        m.record_deadline_miss(true);
        assert_eq!(m.deadline_misses(), 3);
        assert_eq!(m.deadline_misses_queue(), 2);
        assert_eq!(m.deadline_misses_infeasible(), 1);
        let rpt = m.report();
        assert!(rpt.contains("deadline_misses=3"));
        assert!(rpt.contains("queue=2"));
        assert!(rpt.contains("infeasible=1"));
        let s = m.snapshot();
        assert_eq!(s.deadline_misses_queue, 2);
        assert_eq!(s.deadline_misses_infeasible, 1);
    }

    #[test]
    fn queue_wait_and_hists_surface_in_snapshot() {
        let m = Metrics::new();
        m.record_request(4000.0);
        m.record_queue_wait(1500.0);
        m.set_queue_depth(7);
        let s = m.snapshot();
        assert_eq!(s.queue_depth, 7);
        assert_eq!(s.queue_wait.as_ref().unwrap().count, 1);
        // single-sample percentiles are exact (min==max clamp)
        assert_eq!(s.queue_wait.as_ref().unwrap().p99, 1500.0);
        assert_eq!(s.latency_hist.as_ref().unwrap().p99(), 4000.0);
        assert!(s.exec_hist.is_none());
        assert!(m.report().contains("queue "));
    }

    #[test]
    fn calibration_round_trips_through_bits() {
        let m = Metrics::new();
        assert_eq!(m.us_per_unit(), None);
        m.record_calibration(Some(0.0123));
        assert_eq!(m.us_per_unit(), Some(0.0123));
        m.record_calibration(None);
        assert_eq!(m.us_per_unit(), None);
    }
}
