//! Serving metrics: per-request latency histograms, queue-wait
//! distribution, batch utilization, throughput, deadline misses broken
//! down by cause, admission shed counts, replica steals — recorded per
//! model replica, snapshotable and **mergeable** for
//! [`crate::serve::Server::stats`].
//!
//! Everything here is lock-free: counters are relaxed atomics and the
//! latency distributions are [`Log2Hist`]s, so the serve worker records
//! with `&self` while `stats()` readers snapshot concurrently — no
//! `Mutex<Metrics>` on the hot path (the pre-obs design). The scalar
//! `latency` / `exec` [`Summary`]s in [`MetricsSnapshot`] are preserved
//! for API compatibility, now derived from the histograms (exact
//! count / mean / min / max, bucket-walk percentiles — see
//! `docs/OBSERVABILITY.md` for the error bound).
//!
//! With replica sharding one logical model has one recorder per
//! replica; [`MetricsSnapshot::merge`] combines them exactly
//! (histograms add bucket-wise via [`HistSnapshot::merge`] —
//! associative and commutative, pinned against a single-recorder
//! oracle in `rust/tests/observability.rs`). Time comes from the
//! server's injectable [`Clock`], so virtual-clock tests get
//! deterministic throughput windows too.

use super::clock::{self, Clock, SharedClock};
use crate::obs::{HistSnapshot, Log2Hist};
use crate::util::stats::Summary;
use std::sync::atomic::{AtomicU64, Ordering};

/// Lock-free per-model-replica serving metrics; all recording takes
/// `&self`.
#[derive(Debug)]
pub struct Metrics {
    clock: SharedClock,
    /// Clock reading at construction — the throughput window's origin.
    started_us: u64,
    /// End-to-end request latency (enqueue → reply), µs.
    latency: Log2Hist,
    /// Exec time per batch run, µs.
    exec: Log2Hist,
    /// Queue wait (enqueue → batch formed), µs.
    queue_wait: Log2Hist,
    requests: AtomicU64,
    batches: AtomicU64,
    /// sum over runs of (used slots) and (total slots) — padding waste.
    used_slots: AtomicU64,
    total_slots: AtomicU64,
    /// requests answered with a backend-error outcome.
    backend_errors: AtomicU64,
    /// deadline misses by cause: expired while queued vs infeasible the
    /// moment they arrived (budget below the smallest batch's estimate).
    deadline_misses_queue: AtomicU64,
    deadline_misses_infeasible: AtomicU64,
    /// Queue-tail steals this replica performed (as the thief).
    steals: AtomicU64,
    /// Current queue depth gauge (set by the worker each loop).
    queue_depth: AtomicU64,
    /// Scheduler units→µs calibration as f64 bits; 0 = unset (`None`).
    /// Seeded from a persisted manifest value, refined per batch.
    us_per_unit_bits: AtomicU64,
}

/// Plain-data view of one model's [`Metrics`] at a point in time — what
/// [`crate::serve::Server::stats`] hands out per model, safe to hold
/// indefinitely (the live metrics keep moving underneath).
///
/// The `shed_*`, `committed_us`, `quota_us`, and `quota_utilization`
/// fields live on the admission controller, not the per-replica
/// recorders; `Server::stats` stamps them onto the merged snapshot
/// (raw [`Metrics::snapshot`]s report them as zero / `None`).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub backend_errors: u64,
    /// Total deadline misses (both causes) — the pre-obs field. Does
    /// NOT include admission sheds: those requests never queued.
    pub deadline_misses: u64,
    /// ... broken down: expired while waiting in the queue,
    pub deadline_misses_queue: u64,
    /// ... vs infeasible on arrival (budget can't fit any batch).
    pub deadline_misses_infeasible: u64,
    /// Requests refused at enqueue because the admission prediction said
    /// the deadline could not be met (answered `ServeError::Deadline`
    /// with `waited_us == 0`).
    pub shed_deadline: u64,
    /// Requests refused at enqueue by the model's `quota_us` budget.
    pub shed_quota: u64,
    /// Requests refused at enqueue by the global `max_backlog_us` budget.
    pub shed_backlog: u64,
    /// Outstanding admitted-but-unanswered committed work, µs.
    pub committed_us: u64,
    /// The model's configured committed-work quota, if any.
    pub quota_us: Option<u64>,
    /// `committed_us / quota_us` at snapshot time (`None` without a
    /// quota).
    pub quota_utilization: Option<f64>,
    /// Worker replicas merged into this snapshot (1 for a raw
    /// single-recorder snapshot).
    pub replicas: u64,
    /// Queue-tail steals between replicas (thief-side count).
    pub steals: u64,
    /// Queue depth at snapshot time (requests waiting, gauge; summed
    /// across replicas in a merged snapshot).
    pub queue_depth: u64,
    /// Raw slot accounting behind `batch_utilization` (kept so merges
    /// can recompute the ratio exactly).
    pub used_slots: u64,
    pub total_slots: u64,
    /// Fraction of executed batch slots carrying real requests
    /// (0.0 when nothing executed yet).
    pub batch_utilization: f64,
    /// Seconds covered by this snapshot's throughput window (clock time
    /// since metrics start; max across replicas in a merged snapshot).
    pub window_s: f64,
    /// Served requests per second over the window since metrics start
    /// (0.0 when nothing served or the window has zero width).
    pub throughput_rps: f64,
    pub latency: Option<Summary>,
    pub exec: Option<Summary>,
    /// Enqueue → batch-formed wait distribution.
    pub queue_wait: Option<Summary>,
    /// Full log₂ bucket histograms behind the summaries above.
    pub latency_hist: Option<HistSnapshot>,
    pub exec_hist: Option<HistSnapshot>,
    pub queue_wait_hist: Option<HistSnapshot>,
    /// Scheduler units→µs calibration at snapshot time (persistable).
    pub us_per_unit: Option<f64>,
}

impl Metrics {
    /// A recorder on the wall clock (its own epoch).
    #[allow(clippy::new_without_default)]
    pub fn new() -> Metrics {
        Metrics::with_clock(clock::system())
    }

    /// A recorder whose throughput window runs on an injected clock —
    /// the server passes its own, so virtual-clock tests see
    /// deterministic windows.
    pub fn with_clock(clock: SharedClock) -> Metrics {
        let started_us = clock.now_us();
        Metrics {
            clock,
            started_us,
            latency: Log2Hist::new(),
            exec: Log2Hist::new(),
            queue_wait: Log2Hist::new(),
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            used_slots: AtomicU64::new(0),
            total_slots: AtomicU64::new(0),
            backend_errors: AtomicU64::new(0),
            deadline_misses_queue: AtomicU64::new(0),
            deadline_misses_infeasible: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            us_per_unit_bits: AtomicU64::new(0),
        }
    }

    /// Publish the scheduler's current units→µs calibration (the worker
    /// calls this at startup with the seeded value and after each
    /// observed batch).
    pub fn record_calibration(&self, us_per_unit: Option<f64>) {
        let bits = match us_per_unit {
            Some(v) if v.is_finite() && v > 0.0 => v.to_bits(),
            _ => 0,
        };
        self.us_per_unit_bits.store(bits, Ordering::Relaxed);
    }

    pub fn record_request(&self, latency_us: f64) {
        self.latency.record(latency_us);
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Record how long a request sat queued before its batch formed.
    pub fn record_queue_wait(&self, wait_us: f64) {
        self.queue_wait.record(wait_us);
    }

    pub fn record_batch(&self, batch: usize, used: usize, exec_us: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.used_slots.fetch_add(used as u64, Ordering::Relaxed);
        self.total_slots.fetch_add(batch as u64, Ordering::Relaxed);
        self.exec.record(exec_us);
    }

    /// Count requests that received an explicit backend-error response.
    pub fn record_errors(&self, n: u64) {
        self.backend_errors.fetch_add(n, Ordering::Relaxed);
    }

    /// Count one request answered with `ServeError::Deadline`, by cause:
    /// `infeasible` means the deadline budget was already below the
    /// smallest batch's estimated exec time when the worker first saw
    /// the request — it never had a chance; `false` means it expired
    /// while waiting in the queue.
    pub fn record_deadline_miss(&self, infeasible: bool) {
        if infeasible {
            self.deadline_misses_infeasible.fetch_add(1, Ordering::Relaxed);
        } else {
            self.deadline_misses_queue.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Count `n` queue-expired deadline misses (compatibility shim for
    /// callers without cause information).
    pub fn record_deadline_misses(&self, n: u64) {
        self.deadline_misses_queue.fetch_add(n, Ordering::Relaxed);
    }

    /// Count one queue-tail steal this replica performed as the thief.
    pub fn record_steal(&self) {
        self.steals.fetch_add(1, Ordering::Relaxed);
    }

    /// Update the queue-depth gauge (worker, once per loop).
    pub fn set_queue_depth(&self, depth: usize) {
        self.queue_depth.store(depth as u64, Ordering::Relaxed);
    }

    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    pub fn backend_errors(&self) -> u64 {
        self.backend_errors.load(Ordering::Relaxed)
    }

    /// Total deadline misses across both causes.
    pub fn deadline_misses(&self) -> u64 {
        self.deadline_misses_queue() + self.deadline_misses_infeasible()
    }

    pub fn deadline_misses_queue(&self) -> u64 {
        self.deadline_misses_queue.load(Ordering::Relaxed)
    }

    pub fn deadline_misses_infeasible(&self) -> u64 {
        self.deadline_misses_infeasible.load(Ordering::Relaxed)
    }

    pub fn steals(&self) -> u64 {
        self.steals.load(Ordering::Relaxed)
    }

    pub fn queue_depth(&self) -> u64 {
        self.queue_depth.load(Ordering::Relaxed)
    }

    pub fn us_per_unit(&self) -> Option<f64> {
        match self.us_per_unit_bits.load(Ordering::Relaxed) {
            0 => None,
            bits => Some(f64::from_bits(bits)),
        }
    }

    pub fn latency_summary(&self) -> Option<Summary> {
        self.latency.snapshot().map(|h| h.summary())
    }

    pub fn exec_summary(&self) -> Option<Summary> {
        self.exec.snapshot().map(|h| h.summary())
    }

    pub fn queue_wait_summary(&self) -> Option<Summary> {
        self.queue_wait.snapshot().map(|h| h.summary())
    }

    /// Seconds since this recorder was constructed, on its clock.
    pub fn window_s(&self) -> f64 {
        self.clock.now_us().saturating_sub(self.started_us) as f64 / 1e6
    }

    /// Requests per second since start. 0.0 when nothing has been served
    /// yet or the elapsed window has zero width (coarse or frozen clocks
    /// right after startup) — never a division-blowup artifact.
    pub fn throughput_rps(&self) -> f64 {
        let secs = self.window_s();
        let requests = self.requests();
        if requests == 0 || secs <= 0.0 {
            return 0.0;
        }
        requests as f64 / secs
    }

    /// Fraction of executed batch slots carrying real requests. 0.0
    /// before the first batch executes: an idle model reports no
    /// utilization rather than a fake-perfect 100%.
    pub fn batch_utilization(&self) -> f64 {
        let total = self.total_slots.load(Ordering::Relaxed);
        if total == 0 {
            return 0.0;
        }
        self.used_slots.load(Ordering::Relaxed) as f64 / total as f64
    }

    /// Freeze the current counters into a plain-data snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let latency_hist = self.latency.snapshot();
        let exec_hist = self.exec.snapshot();
        let queue_wait_hist = self.queue_wait.snapshot();
        MetricsSnapshot {
            requests: self.requests(),
            batches: self.batches(),
            backend_errors: self.backend_errors(),
            deadline_misses: self.deadline_misses(),
            deadline_misses_queue: self.deadline_misses_queue(),
            deadline_misses_infeasible: self.deadline_misses_infeasible(),
            shed_deadline: 0,
            shed_quota: 0,
            shed_backlog: 0,
            committed_us: 0,
            quota_us: None,
            quota_utilization: None,
            replicas: 1,
            steals: self.steals(),
            queue_depth: self.queue_depth(),
            used_slots: self.used_slots.load(Ordering::Relaxed),
            total_slots: self.total_slots.load(Ordering::Relaxed),
            batch_utilization: self.batch_utilization(),
            window_s: self.window_s(),
            throughput_rps: self.throughput_rps(),
            latency: latency_hist.as_ref().map(|h| h.summary()),
            exec: exec_hist.as_ref().map(|h| h.summary()),
            queue_wait: queue_wait_hist.as_ref().map(|h| h.summary()),
            latency_hist,
            exec_hist,
            queue_wait_hist,
            us_per_unit: self.us_per_unit(),
        }
    }

    pub fn report(&self) -> String {
        self.snapshot().report()
    }
}

fn merge_hists(
    a: Option<HistSnapshot>,
    b: Option<HistSnapshot>,
) -> Option<HistSnapshot> {
    match (a, b) {
        (Some(x), Some(y)) => Some(x.merge(&y)),
        (x, y) => x.or(y),
    }
}

impl MetricsSnapshot {
    /// Requests refused at enqueue, across all three shed causes.
    pub fn shed_total(&self) -> u64 {
        self.shed_deadline + self.shed_quota + self.shed_backlog
    }

    /// Combine two snapshots as if one recorder had seen both replicas'
    /// traffic: counts add, histograms merge bucket-wise (exactly —
    /// see [`HistSnapshot::merge`]), summaries and ratios are recomputed
    /// from the merged data, the throughput window is the longest of the
    /// two, and the calibration keeps the first present value (replicas
    /// of one model converge to the same scale). Associative and
    /// commutative.
    pub fn merge(&self, other: &MetricsSnapshot) -> MetricsSnapshot {
        let latency_hist = merge_hists(self.latency_hist.clone(), other.latency_hist.clone());
        let exec_hist = merge_hists(self.exec_hist.clone(), other.exec_hist.clone());
        let queue_wait_hist =
            merge_hists(self.queue_wait_hist.clone(), other.queue_wait_hist.clone());
        let requests = self.requests + other.requests;
        let used_slots = self.used_slots + other.used_slots;
        let total_slots = self.total_slots + other.total_slots;
        let window_s = self.window_s.max(other.window_s);
        let committed_us = self.committed_us + other.committed_us;
        let quota_us = self.quota_us.or(other.quota_us);
        MetricsSnapshot {
            requests,
            batches: self.batches + other.batches,
            backend_errors: self.backend_errors + other.backend_errors,
            deadline_misses: self.deadline_misses + other.deadline_misses,
            deadline_misses_queue: self.deadline_misses_queue + other.deadline_misses_queue,
            deadline_misses_infeasible: self.deadline_misses_infeasible
                + other.deadline_misses_infeasible,
            shed_deadline: self.shed_deadline + other.shed_deadline,
            shed_quota: self.shed_quota + other.shed_quota,
            shed_backlog: self.shed_backlog + other.shed_backlog,
            committed_us,
            quota_us,
            quota_utilization: quota_us
                .map(|q| if q == 0 { 0.0 } else { committed_us as f64 / q as f64 }),
            replicas: self.replicas + other.replicas,
            steals: self.steals + other.steals,
            queue_depth: self.queue_depth + other.queue_depth,
            used_slots,
            total_slots,
            batch_utilization: if total_slots == 0 {
                0.0
            } else {
                used_slots as f64 / total_slots as f64
            },
            window_s,
            throughput_rps: if requests == 0 || window_s <= 0.0 {
                0.0
            } else {
                requests as f64 / window_s
            },
            latency: latency_hist.as_ref().map(|h| h.summary()),
            exec: exec_hist.as_ref().map(|h| h.summary()),
            queue_wait: queue_wait_hist.as_ref().map(|h| h.summary()),
            latency_hist,
            exec_hist,
            queue_wait_hist,
            us_per_unit: self.us_per_unit.or(other.us_per_unit),
        }
    }

    /// Fold any number of snapshots with [`MetricsSnapshot::merge`];
    /// `None` for an empty iterator.
    pub fn merge_all(snaps: impl IntoIterator<Item = MetricsSnapshot>) -> Option<MetricsSnapshot> {
        snaps.into_iter().reduce(|a, b| a.merge(&b))
    }

    /// JSON rendering for the telemetry stream (`--telemetry-out`
    /// snapshot lines). Counters always; optional summaries become
    /// nested objects or are omitted; histograms reuse
    /// [`HistSnapshot::to_json`].
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let num = |v: u64| Json::Num(v as f64);
        let mut kv = vec![
            ("requests".to_string(), num(self.requests)),
            ("batches".to_string(), num(self.batches)),
            ("backend_errors".to_string(), num(self.backend_errors)),
            ("deadline_misses".to_string(), num(self.deadline_misses)),
            ("deadline_misses_queue".to_string(), num(self.deadline_misses_queue)),
            (
                "deadline_misses_infeasible".to_string(),
                num(self.deadline_misses_infeasible),
            ),
            ("shed_deadline".to_string(), num(self.shed_deadline)),
            ("shed_quota".to_string(), num(self.shed_quota)),
            ("shed_backlog".to_string(), num(self.shed_backlog)),
            ("shed_total".to_string(), num(self.shed_total())),
            ("committed_us".to_string(), num(self.committed_us)),
            ("replicas".to_string(), num(self.replicas)),
            ("steals".to_string(), num(self.steals)),
            ("queue_depth".to_string(), num(self.queue_depth)),
            ("used_slots".to_string(), num(self.used_slots)),
            ("total_slots".to_string(), num(self.total_slots)),
            ("batch_utilization".to_string(), Json::Num(self.batch_utilization)),
            ("window_s".to_string(), Json::Num(self.window_s)),
            ("throughput_rps".to_string(), Json::Num(self.throughput_rps)),
        ];
        if let Some(q) = self.quota_us {
            kv.push(("quota_us".to_string(), num(q)));
        }
        if let Some(u) = self.quota_utilization {
            kv.push(("quota_utilization".to_string(), Json::Num(u)));
        }
        if let Some(u) = self.us_per_unit {
            kv.push(("us_per_unit".to_string(), Json::Num(u)));
        }
        for (key, hist) in [
            ("latency", &self.latency_hist),
            ("exec", &self.exec_hist),
            ("queue_wait", &self.queue_wait_hist),
        ] {
            if let Some(h) = hist {
                kv.push((key.to_string(), h.to_json()));
            }
        }
        Json::Obj(kv)
    }

    /// Human-readable multi-line report (the `cadnn serve` stats block).
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "requests={} batches={} errors={} deadline_misses={} \
             (queue={} infeasible={}) queue_depth={} \
             throughput={:.1} req/s batch_util={:.0}%\n",
            self.requests,
            self.batches,
            self.backend_errors,
            self.deadline_misses,
            self.deadline_misses_queue,
            self.deadline_misses_infeasible,
            self.queue_depth,
            self.throughput_rps,
            self.batch_utilization * 100.0
        ));
        if self.shed_total() > 0 || self.quota_us.is_some() {
            out.push_str(&format!(
                "shed={} (deadline={} quota={} backlog={}) committed={}us",
                self.shed_total(),
                self.shed_deadline,
                self.shed_quota,
                self.shed_backlog,
                self.committed_us
            ));
            if let (Some(q), Some(u)) = (self.quota_us, self.quota_utilization) {
                out.push_str(&format!(" quota={q}us quota_util={:.0}%", u * 100.0));
            }
            out.push('\n');
        }
        if self.replicas > 1 || self.steals > 0 {
            out.push_str(&format!(
                "replicas={} steals={}\n",
                self.replicas, self.steals
            ));
        }
        if let Some(s) = &self.latency {
            out.push_str(&format!(
                "latency  p50={:.1}ms p95={:.1}ms p99={:.1}ms max={:.1}ms\n",
                s.p50 / 1e3,
                s.p95 / 1e3,
                s.p99 / 1e3,
                s.max / 1e3
            ));
        }
        if let Some(s) = &self.queue_wait {
            out.push_str(&format!(
                "queue    p50={:.1}ms p95={:.1}ms p99={:.1}ms max={:.1}ms\n",
                s.p50 / 1e3,
                s.p95 / 1e3,
                s.p99 / 1e3,
                s.max / 1e3
            ));
        }
        if let Some(s) = &self.exec {
            out.push_str(&format!(
                "exec     p50={:.1}ms mean={:.1}ms\n",
                s.p50 / 1e3,
                s.mean / 1e3
            ));
        }
        if let Some(u) = self.us_per_unit {
            out.push_str(&format!("calib    us_per_unit={u:.4}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::clock::VirtualClock;

    #[test]
    fn records_and_reports() {
        let m = Metrics::new();
        m.record_request(1000.0);
        m.record_request(3000.0);
        m.record_batch(4, 2, 500.0);
        m.record_deadline_misses(1);
        assert_eq!(m.requests(), 2);
        assert_eq!(m.batches(), 1);
        assert_eq!(m.batch_utilization(), 0.5);
        let s = m.latency_summary().unwrap();
        assert_eq!(s.count, 2);
        let rpt = m.report();
        assert!(rpt.contains("requests=2"));
        assert!(rpt.contains("deadline_misses=1"));
        assert!(rpt.contains("latency"));
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert!(m.latency_summary().is_none());
        // no batches executed: no utilization to report (not fake 100%)
        assert_eq!(m.batch_utilization(), 0.0);
        // no requests served: zero throughput even on a zero-width
        // elapsed window (no 1e9-req/s division artifacts)
        assert_eq!(m.throughput_rps(), 0.0);
        assert!(m.report().contains("requests=0"));
    }

    #[test]
    fn snapshot_freezes_counters() {
        let m = Metrics::new();
        m.record_request(2000.0);
        m.record_batch(2, 2, 800.0);
        m.record_errors(3);
        let s = m.snapshot();
        assert_eq!(s.requests, 1);
        assert_eq!(s.batches, 1);
        assert_eq!(s.backend_errors, 3);
        assert_eq!(s.deadline_misses, 0);
        assert_eq!(s.batch_utilization, 1.0);
        assert_eq!(s.replicas, 1);
        assert_eq!(s.latency.as_ref().unwrap().count, 1);
        // the snapshot is detached: later recording doesn't change it
        m.record_errors(1);
        assert_eq!(s.backend_errors, 3);
    }

    #[test]
    fn deadline_misses_split_by_cause() {
        let m = Metrics::new();
        m.record_deadline_miss(false);
        m.record_deadline_miss(false);
        m.record_deadline_miss(true);
        assert_eq!(m.deadline_misses(), 3);
        assert_eq!(m.deadline_misses_queue(), 2);
        assert_eq!(m.deadline_misses_infeasible(), 1);
        let rpt = m.report();
        assert!(rpt.contains("deadline_misses=3"));
        assert!(rpt.contains("queue=2"));
        assert!(rpt.contains("infeasible=1"));
        let s = m.snapshot();
        assert_eq!(s.deadline_misses_queue, 2);
        assert_eq!(s.deadline_misses_infeasible, 1);
    }

    #[test]
    fn queue_wait_and_hists_surface_in_snapshot() {
        let m = Metrics::new();
        m.record_request(4000.0);
        m.record_queue_wait(1500.0);
        m.set_queue_depth(7);
        let s = m.snapshot();
        assert_eq!(s.queue_depth, 7);
        assert_eq!(s.queue_wait.as_ref().unwrap().count, 1);
        // single-sample percentiles are exact (min==max clamp)
        assert_eq!(s.queue_wait.as_ref().unwrap().p99, 1500.0);
        assert_eq!(s.latency_hist.as_ref().unwrap().p99(), 4000.0);
        assert!(s.exec_hist.is_none());
        assert!(m.report().contains("queue "));
    }

    #[test]
    fn calibration_round_trips_through_bits() {
        let m = Metrics::new();
        assert_eq!(m.us_per_unit(), None);
        m.record_calibration(Some(0.0123));
        assert_eq!(m.us_per_unit(), Some(0.0123));
        m.record_calibration(None);
        assert_eq!(m.us_per_unit(), None);
    }

    #[test]
    fn virtual_clock_drives_the_throughput_window() {
        let clock = VirtualClock::new();
        let m = Metrics::with_clock(clock.shared());
        m.record_request(100.0);
        assert_eq!(m.throughput_rps(), 0.0, "frozen clock: zero-width window");
        clock.advance(2_000_000);
        assert_eq!(m.window_s(), 2.0);
        assert_eq!(m.throughput_rps(), 0.5, "1 request over exactly 2 virtual seconds");
    }

    #[test]
    fn merged_snapshot_adds_counts_and_recomputes_ratios() {
        let clock = VirtualClock::new();
        let (a, b) = (
            Metrics::with_clock(clock.shared()),
            Metrics::with_clock(clock.shared()),
        );
        a.record_request(1_000.0);
        a.record_batch(4, 2, 500.0);
        a.record_steal();
        b.record_request(3_000.0);
        b.record_request(5_000.0);
        b.record_batch(4, 4, 700.0);
        b.record_deadline_miss(false);
        clock.advance(1_000_000);
        let m = a.snapshot().merge(&b.snapshot());
        assert_eq!(m.requests, 3);
        assert_eq!(m.batches, 2);
        assert_eq!(m.deadline_misses_queue, 1);
        assert_eq!(m.replicas, 2);
        assert_eq!(m.steals, 1);
        assert_eq!(m.batch_utilization, 6.0 / 8.0);
        assert_eq!(m.window_s, 1.0);
        assert_eq!(m.throughput_rps, 3.0);
        assert_eq!(m.latency.as_ref().unwrap().count, 3);
        assert_eq!(m.latency.as_ref().unwrap().min, 1_000.0);
        assert_eq!(m.latency.as_ref().unwrap().max, 5_000.0);
        // merge is commutative (field for field)
        assert_eq!(m, b.snapshot().merge(&a.snapshot()));
    }

    #[test]
    fn snapshot_to_json_carries_sheds_and_hists() {
        let m = Metrics::new();
        m.record_request(2000.0);
        m.record_batch(2, 2, 800.0);
        let mut s = m.snapshot();
        s.shed_quota = 4;
        s.quota_us = Some(10_000);
        let j = s.to_json();
        // through the serialized compact text (the telemetry line shape)
        let text = j.to_string_compact();
        assert!(!text.contains('\n'));
        let back = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(back.get("requests").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(back.get("shed_quota").and_then(|v| v.as_f64()), Some(4.0));
        assert_eq!(back.get("shed_total").and_then(|v| v.as_f64()), Some(4.0));
        assert_eq!(back.get("quota_us").and_then(|v| v.as_f64()), Some(10_000.0));
        assert!(back.get("latency").and_then(|h| h.get("p99_us")).is_some());
        assert!(back.get("queue_wait").is_none(), "empty hists omitted");
    }

    #[test]
    fn merge_all_folds_and_report_shows_sheds() {
        assert!(MetricsSnapshot::merge_all(Vec::new()).is_none());
        let m = Metrics::new();
        m.record_request(100.0);
        let mut s = MetricsSnapshot::merge_all([m.snapshot()]).unwrap();
        s.shed_deadline = 2;
        s.shed_quota = 1;
        s.quota_us = Some(10_000);
        s.committed_us = 2_500;
        s.quota_utilization = Some(0.25);
        assert_eq!(s.shed_total(), 3);
        let rpt = s.report();
        assert!(rpt.contains("shed=3"), "{rpt}");
        assert!(rpt.contains("deadline=2"), "{rpt}");
        assert!(rpt.contains("quota_util=25%"), "{rpt}");
    }
}
