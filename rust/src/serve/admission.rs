//! Global admission control: decide **at enqueue** whether a request
//! can be served, instead of letting it queue to death.
//!
//! The price signal is the planner's cost model: each model exposes
//! `(batch, plan cost units)` via [`crate::api::Backend::plan_costs`],
//! and the serving scheduler calibrates µs-per-unit online
//! ([`crate::serve::Scheduler::us_per_unit`], mirrored into
//! [`crate::serve::Metrics`]). Admission multiplies the two:
//!
//! - every admitted request **commits** `min_units × us_per_unit` —
//!   an upper bound on its amortized drain cost, because the
//!   throughput-argmax scheduler never spends more than the cheapest
//!   batch estimate per served request (see `fleet_serving` property
//!   tests, which assert this bound over 200 random workloads);
//! - a request's **predicted completion** is
//!   `committed / replicas + max_wait_us + worst_batch_us`: the
//!   committed backlog drains ahead of it, at most one batching window
//!   of idleness can pass once it is queued, and its own batch costs at
//!   most the largest batch estimate.
//!
//! Three shed classes, checked in order:
//!
//! 1. **Quota** — the model's committed backlog would exceed its
//!    configured `quota_us` ([`crate::serve::QueueConfig::quota_us`]).
//!    Answered as [`crate::serve::ServeError::Shed`].
//! 2. **Backlog** — the *global* committed backlog across all models
//!    would exceed [`AdmissionConfig::max_backlog_us`]. Also
//!    [`crate::serve::ServeError::Shed`].
//! 3. **Deadline** — the request carries a deadline the prediction says
//!    it cannot meet. Answered as an early
//!    [`crate::serve::ServeError::Deadline`] with `waited_us = 0`, the
//!    same type a queue expiry produces — clients handle one miss shape,
//!    but metrics split the counts ([`shed-vs-miss taxonomy`][tax]).
//!
//! Both checks keep a progress guarantee: with zero outstanding work a
//! request is never quota- or backlog-shed, so tiny quotas throttle
//! concurrency rather than deadlock a tenant. Models without plan costs
//! or calibration are unpriced: always admitted, commitment zero —
//! admission is strictly opt-in via the cost model.
//!
//! [tax]: ../../docs/SERVING.md

use super::metrics::Metrics;
use crate::obs;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

/// Server-wide admission policy knobs ([`crate::serve::ServerBuilder::admission`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdmissionConfig {
    /// Master switch. Off ⇒ every request is admitted with zero
    /// commitment (the pre-admission behavior, bit for bit).
    pub enabled: bool,
    /// Global committed-work ceiling in µs across **all** models;
    /// `None` = unbounded. The shared-CPU analogue of a per-model quota.
    pub max_backlog_us: Option<u64>,
}

impl Default for AdmissionConfig {
    fn default() -> AdmissionConfig {
        AdmissionConfig { enabled: true, max_backlog_us: None }
    }
}

/// Why a request was refused by quota/backlog accounting (deadline
/// sheds surface as [`crate::serve::ServeError::Deadline`] instead).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedCause {
    /// The model's `quota_us` committed-work budget was full.
    Quota,
    /// The server-wide `max_backlog_us` budget was full.
    Backlog,
}

impl std::fmt::Display for ShedCause {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShedCause::Quota => write!(f, "quota"),
            ShedCause::Backlog => write!(f, "backlog"),
        }
    }
}

/// Outcome of one admission check.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AdmitDecision {
    /// Proceed. `cost_us` was committed against the model and global
    /// budgets and must be released exactly once at the terminal reply;
    /// `predicted_us` is the completion estimate the decision used
    /// (0 = unpriced).
    Admit { cost_us: u64, predicted_us: u64 },
    /// Refuse: the deadline cannot be met. Answer
    /// [`crate::serve::ServeError::Deadline`] with `waited_us = 0`.
    ShedDeadline { predicted_us: u64 },
    /// Refuse: quota or global backlog. Answer
    /// [`crate::serve::ServeError::Shed`].
    Shed { cause: ShedCause, predicted_us: u64 },
}

/// Plan-derived price list, fixed once the backend is built.
#[derive(Debug, Clone, Copy)]
struct Pricing {
    /// Cheapest batch estimate in plan units — the per-request charge.
    min_units: f64,
    /// Costliest batch estimate in plan units — the own-batch term of
    /// the completion prediction.
    max_units: f64,
}

/// Per-model admission state. Shared between the submit path (admit)
/// and every replica worker (release at terminal reply).
#[derive(Debug)]
pub struct ModelAdmission {
    cfg: AdmissionConfig,
    replicas: u64,
    max_wait_us: u64,
    quota_us: Option<u64>,
    /// Filled by the first replica whose backend reports plan costs;
    /// until then the model is unpriced.
    pricing: OnceLock<Pricing>,
    /// Replica-0 metrics — the live µs-per-unit source (seeded at
    /// startup when a calibration is persisted or configured).
    calibration: Arc<Metrics>,
    committed_us: AtomicU64,
    global_committed_us: Arc<AtomicU64>,
    shed_deadline: AtomicU64,
    shed_quota: AtomicU64,
    shed_backlog: AtomicU64,
}

impl ModelAdmission {
    pub(crate) fn new(
        cfg: AdmissionConfig,
        replicas: usize,
        max_wait_us: u64,
        quota_us: Option<u64>,
        calibration: Arc<Metrics>,
        global_committed_us: Arc<AtomicU64>,
    ) -> ModelAdmission {
        ModelAdmission {
            cfg,
            replicas: replicas.max(1) as u64,
            max_wait_us,
            quota_us,
            pricing: OnceLock::new(),
            calibration,
            committed_us: AtomicU64::new(0),
            global_committed_us,
            shed_deadline: AtomicU64::new(0),
            shed_quota: AtomicU64::new(0),
            shed_backlog: AtomicU64::new(0),
        }
    }

    /// Install the plan-unit price list (first writer wins; replicas all
    /// report the same plan). Empty cost lists leave the model unpriced.
    pub(crate) fn set_pricing(&self, plan_costs: &[(usize, f64)]) {
        let units: Vec<f64> = plan_costs.iter().map(|&(_, u)| u).filter(|u| *u > 0.0).collect();
        let (Some(&min), Some(&max)) = (
            units.iter().min_by(|a, b| a.total_cmp(b)),
            units.iter().max_by(|a, b| a.total_cmp(b)),
        ) else {
            return;
        };
        let _ = self.pricing.set(Pricing { min_units: min, max_units: max });
    }

    /// Decide one request. On `Admit` the returned `cost_us` is already
    /// committed; the caller must [`ModelAdmission::release`] it at the
    /// terminal reply (success, backend error, or queue expiry).
    pub(crate) fn admit(&self, deadline_us: Option<u64>) -> AdmitDecision {
        let unpriced = AdmitDecision::Admit { cost_us: 0, predicted_us: 0 };
        if !self.cfg.enabled {
            return unpriced;
        }
        let Some(p) = self.pricing.get() else { return unpriced };
        let Some(upu) = self.calibration.us_per_unit() else { return unpriced };
        // ceil keeps the charge an upper bound; max(1) keeps commitment
        // visible even for absurdly cheap plans
        let est_us = ((p.min_units * upu).ceil() as u64).max(1);
        let worst_us = (p.max_units * upu).ceil() as u64;
        let committed = self.committed_us.load(Ordering::Relaxed);
        let predicted_us = committed / self.replicas + self.max_wait_us + worst_us;
        if let Some(quota) = self.quota_us {
            if committed > 0 && committed.saturating_add(est_us) > quota {
                self.shed_quota.fetch_add(1, Ordering::Relaxed);
                obs::add(obs::Counter::ServeShedQuota, 1);
                return AdmitDecision::Shed { cause: ShedCause::Quota, predicted_us };
            }
        }
        if let Some(max_backlog) = self.cfg.max_backlog_us {
            let global = self.global_committed_us.load(Ordering::Relaxed);
            if global > 0 && global.saturating_add(est_us) > max_backlog {
                self.shed_backlog.fetch_add(1, Ordering::Relaxed);
                obs::add(obs::Counter::ServeShedBacklog, 1);
                return AdmitDecision::Shed { cause: ShedCause::Backlog, predicted_us };
            }
        }
        if let Some(budget) = deadline_us {
            if budget < predicted_us {
                self.shed_deadline.fetch_add(1, Ordering::Relaxed);
                obs::add(obs::Counter::ServeShedDeadline, 1);
                return AdmitDecision::ShedDeadline { predicted_us };
            }
        }
        self.committed_us.fetch_add(est_us, Ordering::Relaxed);
        self.global_committed_us.fetch_add(est_us, Ordering::Relaxed);
        AdmitDecision::Admit { cost_us: est_us, predicted_us }
    }

    /// Return an admitted request's commitment. `cost_us == 0`
    /// (unpriced admit) is a no-op.
    pub(crate) fn release(&self, cost_us: u64) {
        if cost_us > 0 {
            self.committed_us.fetch_sub(cost_us, Ordering::Relaxed);
            self.global_committed_us.fetch_sub(cost_us, Ordering::Relaxed);
        }
    }

    /// Outstanding committed work for this model, µs.
    pub fn committed_us(&self) -> u64 {
        self.committed_us.load(Ordering::Relaxed)
    }

    /// Configured per-model quota, if any.
    pub fn quota_us(&self) -> Option<u64> {
        self.quota_us
    }

    /// Replica count this model's prediction divides backlog by.
    pub fn replicas(&self) -> u64 {
        self.replicas
    }

    /// `(deadline, quota, backlog)` shed counts since start.
    pub fn shed_counts(&self) -> (u64, u64, u64) {
        (
            self.shed_deadline.load(Ordering::Relaxed),
            self.shed_quota.load(Ordering::Relaxed),
            self.shed_backlog.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(
        cfg: AdmissionConfig,
        replicas: usize,
        quota_us: Option<u64>,
        upu: Option<f64>,
    ) -> ModelAdmission {
        let metrics = Arc::new(Metrics::new());
        metrics.record_calibration(upu);
        let m = ModelAdmission::new(
            cfg,
            replicas,
            2_000,
            quota_us,
            metrics,
            Arc::new(AtomicU64::new(0)),
        );
        m.set_pricing(&[(1, 1_100.0), (4, 4_100.0), (8, 8_100.0)]);
        m
    }

    #[test]
    fn unpriced_uncalibrated_or_disabled_admits_everything() {
        let free = AdmitDecision::Admit { cost_us: 0, predicted_us: 0 };
        let off = model(AdmissionConfig { enabled: false, ..Default::default() }, 1, Some(1), None);
        assert_eq!(off.admit(Some(1)), free);
        let uncal = model(AdmissionConfig::default(), 1, Some(1), None);
        assert_eq!(uncal.admit(Some(1)), free);
        let metrics = Arc::new(Metrics::new());
        metrics.record_calibration(Some(1.0));
        // calibrated but no pricing installed: still unpriced
        let unpriced = ModelAdmission::new(
            AdmissionConfig::default(),
            1,
            2_000,
            Some(1),
            metrics,
            Arc::new(AtomicU64::new(0)),
        );
        assert_eq!(unpriced.admit(Some(1)), free);
        assert_eq!(unpriced.committed_us(), 0);
    }

    #[test]
    fn deadline_shed_fires_exactly_at_the_prediction() {
        let m = model(AdmissionConfig::default(), 1, None, Some(1.0));
        // empty backlog: predicted = 0 + 2_000 + 8_100
        assert_eq!(m.admit(Some(10_099)), AdmitDecision::ShedDeadline { predicted_us: 10_100 });
        assert_eq!(
            m.admit(Some(10_100)),
            AdmitDecision::Admit { cost_us: 1_100, predicted_us: 10_100 }
        );
        // backlog of one committed request shifts the prediction
        assert_eq!(
            m.admit(Some(11_199)),
            AdmitDecision::ShedDeadline { predicted_us: 11_200 }
        );
        assert_eq!(m.shed_counts(), (2, 0, 0));
        m.release(1_100);
        assert_eq!(m.committed_us(), 0);
    }

    #[test]
    fn quota_always_admits_the_first_outstanding_request() {
        let m = model(AdmissionConfig::default(), 1, Some(1), Some(1.0));
        let first = m.admit(None);
        assert!(matches!(first, AdmitDecision::Admit { cost_us: 1_100, .. }), "{first:?}");
        assert!(matches!(
            m.admit(None),
            AdmitDecision::Shed { cause: ShedCause::Quota, .. }
        ));
        m.release(1_100);
        assert!(matches!(m.admit(None), AdmitDecision::Admit { .. }));
        assert_eq!(m.shed_counts(), (0, 1, 0));
    }

    #[test]
    fn global_backlog_spans_models() {
        let global = Arc::new(AtomicU64::new(0));
        let cfg = AdmissionConfig { enabled: true, max_backlog_us: Some(2_000) };
        let mk = || {
            let metrics = Arc::new(Metrics::new());
            metrics.record_calibration(Some(1.0));
            let m = ModelAdmission::new(cfg, 1, 2_000, None, metrics, Arc::clone(&global));
            m.set_pricing(&[(1, 1_100.0)]);
            m
        };
        let (a, b) = (mk(), mk());
        assert!(matches!(a.admit(None), AdmitDecision::Admit { cost_us: 1_100, .. }));
        // b's own backlog is empty, but the shared budget is charged
        assert!(matches!(
            b.admit(None),
            AdmitDecision::Shed { cause: ShedCause::Backlog, .. }
        ));
        a.release(1_100);
        assert!(matches!(b.admit(None), AdmitDecision::Admit { .. }));
        assert_eq!(b.shed_counts(), (0, 0, 1));
    }

    #[test]
    fn replicas_divide_the_backlog_prediction() {
        let m = model(AdmissionConfig::default(), 2, None, Some(1.0));
        for _ in 0..2 {
            assert!(matches!(m.admit(None), AdmitDecision::Admit { .. }));
        }
        // committed 2_200 over 2 replicas: predicted = 1_100 + 2_000 + 8_100
        assert_eq!(
            m.admit(Some(11_200)),
            AdmitDecision::Admit { cost_us: 1_100, predicted_us: 11_200 }
        );
    }
}
