//! The server's named model registry: what is being served, under which
//! plan, at which batch variants and costs.

use crate::api::Engine;
use crate::planner::db::TuneStats;
use crate::planner::ExecPlan;
use std::collections::BTreeMap;

/// One registered model, as the [`crate::serve::Server`] sees it after
/// its worker came up: identity, geometry, the execution plan behind the
/// backend (when known), and the per-batch-variant plan costs the
/// scheduler runs on.
#[derive(Clone)]
pub struct ModelEntry {
    /// Registry name (the routing key in
    /// [`crate::serve::ServeRequest::model`]).
    pub name: String,
    /// The engine behind this entry, when it was registered as one
    /// (`None` for opaque factory-built backends, whose handles live
    /// inside the worker thread).
    pub engine: Option<Engine>,
    /// The per-layer execution plan the backend reported, when known.
    pub plan: Option<ExecPlan>,
    /// (batch size, plan cost units) per batch variant —
    /// `ExecPlan::cost_at(b)` evaluated per variant; empty when the
    /// backend has no cost model (nothing pruned, or planning disabled).
    pub plan_costs: Vec<(usize, f64)>,
    /// How the plan was obtained at model load: build-time planning
    /// counters (in-process memo hits, plan-database hits, cold
    /// searches, kernel measurements — see
    /// [`crate::planner::db::TuneStats`]). `None` for opaque factory
    /// backends and artifact engines, whose plans predate the server.
    pub plan_tuning: Option<TuneStats>,
    /// Per-image input shape (batch axis excluded).
    pub input_shape: Vec<usize>,
    /// Logits per image.
    pub classes: usize,
    /// Ascending executable batch sizes.
    pub batch_sizes: Vec<usize>,
    /// Worker replicas backing this entry (≥ 1).
    pub replicas: usize,
}

impl ModelEntry {
    /// Flat floats per image.
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }
}

/// Named, inspectable collection of the server's [`ModelEntry`]s.
#[derive(Clone, Default)]
pub struct Registry {
    entries: BTreeMap<String, ModelEntry>,
}

impl Registry {
    pub(crate) fn insert(&mut self, entry: ModelEntry) {
        self.entries.insert(entry.name.clone(), entry);
    }

    pub fn get(&self, name: &str) -> Option<&ModelEntry> {
        self.entries.get(name)
    }

    /// Registered model names, sorted.
    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(String::as_str).collect()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &ModelEntry)> {
        self.entries.iter()
    }
}
