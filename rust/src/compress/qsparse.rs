//! Quantized sparse value stores: codebook-packed payloads for the
//! CSR / BSR / Pattern formats (paper §3, quantization stacked on
//! sparsity).
//!
//! `compress::quant` quantizes a tensor to symmetric uniform levels but
//! leaves the result as a dead-end `i8` array; every sparse payload in
//! the format subsystem still ships f32 values, so the storage win the
//! paper claims from *unified* prune+quantize never compounds with the
//! formats. This module closes that gap:
//!
//! - [`QuantizedValues`] — a codebook (`<= 2^bits` f32 entries, entry 0
//!   pinned to 0.0) plus bit-packed per-value indices (two per byte at
//!   4 bits). The codebook is fitted with deterministic 1-D k-means
//!   (Lloyd) seeded from the *uniform symmetric grid* `compress::quant`
//!   uses, so the fit subsumes the uniform quantizer under the sparse
//!   payloads' support constraint: no nonzero value may land on the
//!   zero entry (unlike `QuantizedTensor`, which snaps small weights to
//!   level 0 and silently changes the support), and within that
//!   constraint the reconstruction error is never worse than the
//!   uniform grid's (property-tested).
//! - [`QCsr`] / [`QBsr`] / [`QPattern`] — the three sparse formats with
//!   their f32 value arrays replaced by a `QuantizedValues` store. The
//!   structural arrays (pointers, indices, pattern table) are unchanged,
//!   so the LUT micro-kernels ([`crate::kernels::lut`]) walk the exact
//!   same loops as the f32 kernels and gather `codebook[idx]` instead of
//!   loading a float — no intermediate dense buffer, bit-identical to
//!   dequantize-then-execute.
//! - [`QSparseMatrix`] — the payload enum the executor dispatches on.
//!
//! Disk accounting (`disk_bytes` / `bytes_on_disk_idx16`) always charges
//! the codebook next to the packed indices: it is part of the layer's
//! payload, not free metadata. The index round-trip is lossless
//! (`pack`/`index` are exact inverses); the only lossy step is the value
//! → codebook-entry snap, bounded by [`QuantizedValues::error_bound`].

use crate::compress::bsr::BsrMatrix;
use crate::compress::csr::CsrMatrix;
use crate::compress::pattern::PatternMatrix;

/// How a sparse payload's values are stored: raw f32, or packed indices
/// into an 8-bit / 4-bit codebook. This is the *per-layer decision* the
/// planner records in `LayerPlan::value_bits` and the manifest
/// serializes; [`crate::planner::ValuePolicy`] is the user-facing knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ValueBits {
    /// Raw f32 values (the pre-quantization baseline).
    #[default]
    F32,
    /// 8-bit codebook indices (<= 256 entries).
    Q8,
    /// 4-bit codebook indices (<= 16 entries), two per byte.
    Q4,
}

impl ValueBits {
    /// Bits per stored value (32 / 8 / 4) — the manifest encoding.
    pub fn bits(&self) -> usize {
        match self {
            ValueBits::F32 => 32,
            ValueBits::Q8 => 8,
            ValueBits::Q4 => 4,
        }
    }

    /// Inverse of [`ValueBits::bits`].
    pub fn from_bits(bits: usize) -> Option<ValueBits> {
        match bits {
            32 => Some(ValueBits::F32),
            8 => Some(ValueBits::Q8),
            4 => Some(ValueBits::Q4),
            _ => None,
        }
    }

    /// Stable textual name (`f32`, `q8`, `q4`).
    pub fn label(&self) -> &'static str {
        match self {
            ValueBits::F32 => "f32",
            ValueBits::Q8 => "q8",
            ValueBits::Q4 => "q4",
        }
    }

    pub fn quantized(&self) -> bool {
        *self != ValueBits::F32
    }
}

/// Lloyd iterations for the codebook fit. 1-D k-means on sorted data
/// converges in a handful of passes; a fixed count keeps the fit
/// deterministic and cheap (O(iters * n log k)).
const FIT_ITERS: usize = 10;

/// Codebook-quantized value array: `codebook[indices[i]]` reconstructs
/// value `i`. Entry 0 of the codebook is pinned to exactly 0.0 and only
/// exact-zero inputs map to it, so a pruning support (and BSR padding)
/// survives quantization bit-for-bit — matching `compress::quant`'s
/// zero-preservation contract.
///
/// # Examples
///
/// ```
/// use cadnn::compress::qsparse::QuantizedValues;
///
/// let vals = [0.0f32, 0.5, -0.25, 0.5, 0.0];
/// let q = QuantizedValues::fit(&vals, 4);
/// assert_eq!(q.len(), 5);
/// assert_eq!(q.codebook[0], 0.0);
/// // three distinct values -> lossless reconstruction
/// assert_eq!(q.dequantize(), vals);
/// assert_eq!(q.error_bound(), 0.0);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct QuantizedValues {
    /// 4 or 8.
    pub bits: u8,
    /// Reconstruction table; `codebook[0] == 0.0`, nonzero entries
    /// ascending. Length `<= 1 << bits`.
    pub codebook: Vec<f32>,
    /// Bit-packed indices, `bits` per value (4-bit: low nibble first).
    pub packed: Vec<u8>,
    /// Stored value count (the packed array rounds up to whole bytes).
    len: usize,
    /// Max |v - codebook[index(v)]| over the fitted values.
    max_err: f32,
}

impl QuantizedValues {
    /// Fit a codebook to `values` and pack their indices. `bits` must be
    /// 4 or 8. Nonzero centroids are 1-D k-means (Lloyd) seeded from the
    /// uniform symmetric levels of [`crate::compress::quant`] — the fit
    /// starts at the uniform quantizer and only improves, so this
    /// subsumes `QuantizedTensor` for codebook purposes.
    pub fn fit(values: &[f32], bits: u8) -> QuantizedValues {
        assert!(bits == 4 || bits == 8, "codebook payloads support 4 or 8 bits");
        let nonzero: Vec<f32> = values.iter().copied().filter(|v| *v != 0.0).collect();
        let centers = fit_centers(&nonzero, bits);
        let mut codebook = Vec::with_capacity(centers.len() + 1);
        codebook.push(0.0f32);
        codebook.extend_from_slice(&centers);
        let mut packed = vec![0u8; (values.len() * bits as usize).div_ceil(8)];
        let mut max_err = 0.0f32;
        for (i, &v) in values.iter().enumerate() {
            let idx = if v == 0.0 { 0 } else { 1 + nearest(&centers, v) };
            let err = (v - codebook[idx]).abs();
            if err > max_err {
                max_err = err;
            }
            match bits {
                8 => packed[i] = idx as u8,
                _ => packed[i >> 1] |= (idx as u8) << ((i & 1) << 2),
            }
        }
        QuantizedValues { bits, codebook, packed, len: values.len(), max_err }
    }

    /// Stored values.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Codebook index of value `i` (lossless: exactly what `fit` packed).
    #[inline(always)]
    pub fn index(&self, i: usize) -> usize {
        debug_assert!(i < self.len);
        match self.bits {
            8 => self.packed[i] as usize,
            _ => ((self.packed[i >> 1] >> ((i & 1) << 2)) & 0xF) as usize,
        }
    }

    /// All indices, unpacked (tests and re-encoders).
    pub fn unpack_indices(&self) -> Vec<u16> {
        (0..self.len).map(|i| self.index(i) as u16).collect()
    }

    /// Reconstructed f32 values (`codebook[index(i)]` per value) — what
    /// every LUT kernel computes with, gathered lazily instead.
    pub fn dequantize(&self) -> Vec<f32> {
        (0..self.len).map(|i| self.codebook[self.index(i)]).collect()
    }

    /// Max absolute reconstruction error over the fitted values. 0.0
    /// when the distinct nonzero values fit the codebook (lossless).
    pub fn error_bound(&self) -> f32 {
        self.max_err
    }

    /// On-disk bytes: packed indices **plus the codebook** (f32 entries)
    /// plus one length byte for the codebook — the codebook is part of
    /// the payload, not free metadata.
    pub fn disk_bytes(&self) -> usize {
        self.packed.len() + self.codebook.len() * 4 + 1
    }

    /// Sum of squared reconstruction errors (fit-quality accounting; the
    /// uniform-seeding property test pins k-means <= uniform on this).
    pub fn sse(&self, values: &[f32]) -> f64 {
        assert_eq!(values.len(), self.len);
        values
            .iter()
            .enumerate()
            .map(|(i, &v)| {
                let d = (v - self.codebook[self.index(i)]) as f64;
                d * d
            })
            .sum()
    }
}

/// Nearest center to `v` among ascending `centers` (ties to the lower
/// index). Binary search + one neighbor comparison.
#[inline]
fn nearest(centers: &[f32], v: f32) -> usize {
    debug_assert!(!centers.is_empty());
    let p = centers.partition_point(|&c| c < v);
    if p == 0 {
        return 0;
    }
    if p == centers.len() {
        return centers.len() - 1;
    }
    // centers[p-1] < v <= centers[p]; lower index wins exact ties
    if (v - centers[p - 1]).abs() <= (centers[p] - v).abs() {
        p - 1
    } else {
        p
    }
}

/// Deterministic 1-D k-means over the nonzero values, seeded with the
/// exact uniform symmetric grid `compress::quant` rounds to
/// (`2^(bits-1)-1` levels per side at step `amax/n`), refined with
/// [`FIT_ITERS`] Lloyd passes — each pass only lowers the squared
/// reconstruction error, so the fit subsumes the uniform quantizer.
/// Returns ascending, deduplicated, nonzero centers (empty for no data;
/// the distinct values themselves when they fit the budget).
fn fit_centers(nonzero: &[f32], bits: u8) -> Vec<f32> {
    if nonzero.is_empty() {
        return Vec::new();
    }
    let mut sorted = nonzero.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut distinct = sorted.clone();
    distinct.dedup();
    let budget = (1usize << bits) - 1; // entry 0 of the codebook is the zero
    if distinct.len() <= budget {
        return distinct; // lossless: every distinct value is a center
    }
    // quant.rs seed: levels i * (amax / n), i in -n..=n without 0 —
    // 2n <= budget centers
    let n = (1i32 << (bits - 1)) - 1;
    let amax = sorted.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-8);
    let step = (amax / n as f32) as f64;
    let centers_seed: Vec<f64> =
        (-n..=n).filter(|&i| i != 0).map(|i| i as f64 * step).collect();
    let mut centers = centers_seed;
    // Lloyd on sorted data: clusters are contiguous ranges split at the
    // midpoints between adjacent centers
    let s64: Vec<f64> = sorted.iter().map(|&v| v as f64).collect();
    let mut prefix = vec![0.0f64; s64.len() + 1];
    for (i, &v) in s64.iter().enumerate() {
        prefix[i + 1] = prefix[i] + v;
    }
    for _ in 0..FIT_ITERS {
        let mut bounds = Vec::with_capacity(centers.len() + 1);
        bounds.push(0usize);
        for w in centers.windows(2) {
            let mid = (w[0] + w[1]) / 2.0;
            bounds.push(s64.partition_point(|&v| v <= mid));
        }
        bounds.push(s64.len());
        let mut moved = false;
        for (j, c) in centers.iter_mut().enumerate() {
            let (a, b) = (bounds[j], bounds[j + 1]);
            if a < b {
                let mean = (prefix[b] - prefix[a]) / (b - a) as f64;
                if mean != *c {
                    moved = true;
                }
                *c = mean;
            }
        }
        if !moved {
            break;
        }
    }
    let mut out: Vec<f32> = centers.iter().map(|&c| c as f32).collect();
    // zero is reserved for the pruning support: a symmetric cluster can
    // average to exactly 0.0 — snap it to its nearest actual value
    for c in out.iter_mut() {
        if *c == 0.0 {
            let i = sorted.partition_point(|&v| v < 0.0);
            *c = if i < sorted.len() && (i == 0 || sorted[i].abs() <= sorted[i - 1].abs()) {
                sorted[i]
            } else {
                sorted[i - 1]
            };
        }
    }
    out.sort_by(|a, b| a.partial_cmp(b).unwrap());
    out.dedup();
    out
}

/// CSR structure with a codebook-packed value store (see [`CsrMatrix`]
/// for the layout contract).
#[derive(Debug, Clone, PartialEq)]
pub struct QCsr {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub values: QuantizedValues,
}

impl QCsr {
    /// Quantize a CSR payload's values to a `bits`-bit codebook; the
    /// structure arrays are copied unchanged.
    pub fn from_csr(csr: &CsrMatrix, bits: u8) -> QCsr {
        QCsr {
            rows: csr.rows,
            cols: csr.cols,
            row_ptr: csr.row_ptr.clone(),
            col_idx: csr.col_idx.clone(),
            values: QuantizedValues::fit(&csr.values, bits),
        }
    }

    /// Dequantize back to an f32 CSR matrix — the reference the LUT
    /// kernel must match bit-for-bit.
    pub fn to_csr(&self) -> CsrMatrix {
        CsrMatrix {
            rows: self.rows,
            cols: self.cols,
            row_ptr: self.row_ptr.clone(),
            col_idx: self.col_idx.clone(),
            values: self.values.dequantize(),
        }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// On-disk bytes: CSR structure at 16-bit column indices plus the
    /// packed values **and codebook**.
    pub fn bytes_on_disk_idx16(&self) -> usize {
        self.row_ptr.len() * 4 + self.col_idx.len() * 2 + self.values.disk_bytes()
    }
}

/// BSR structure with a codebook-packed value store. Padding zeros pack
/// as index 0 and reconstruct to exactly 0.0, so fill accounting and the
/// kernels' zero-skips are unaffected.
#[derive(Debug, Clone, PartialEq)]
pub struct QBsr {
    pub rows: usize,
    pub cols: usize,
    pub br: usize,
    pub bc: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub values: QuantizedValues,
}

impl QBsr {
    pub fn from_bsr(bsr: &BsrMatrix, bits: u8) -> QBsr {
        QBsr {
            rows: bsr.rows,
            cols: bsr.cols,
            br: bsr.br,
            bc: bsr.bc,
            row_ptr: bsr.row_ptr.clone(),
            col_idx: bsr.col_idx.clone(),
            values: QuantizedValues::fit(&bsr.values, bits),
        }
    }

    pub fn to_bsr(&self) -> BsrMatrix {
        BsrMatrix::from_parts(
            self.rows,
            self.cols,
            self.br,
            self.bc,
            self.row_ptr.clone(),
            self.col_idx.clone(),
            self.values.dequantize(),
        )
    }

    pub fn block_rows(&self) -> usize {
        self.row_ptr.len() - 1
    }

    pub fn bytes_on_disk_idx16(&self) -> usize {
        self.row_ptr.len() * 4 + self.col_idx.len() * 2 + self.values.disk_bytes()
    }
}

/// Pattern structure with a codebook-packed value store (see
/// [`PatternMatrix`] for the layout contract). This is the friendliest
/// pairing: per-kernel value runs are contiguous, so 4-bit packing never
/// straddles a kernel on the canonical even-entry patterns.
#[derive(Debug, Clone, PartialEq)]
pub struct QPattern {
    pub rows: usize,
    pub cols: usize,
    pub kh: usize,
    pub kw: usize,
    pub cin: usize,
    pub kernel_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub pat_idx: Vec<u16>,
    pub val_ptr: Vec<u32>,
    pub pat_ptr: Vec<u32>,
    pub pat_pos: Vec<u8>,
    pub values: QuantizedValues,
}

impl QPattern {
    pub fn from_pattern(pat: &PatternMatrix, bits: u8) -> QPattern {
        QPattern {
            rows: pat.rows,
            cols: pat.cols,
            kh: pat.kh,
            kw: pat.kw,
            cin: pat.cin,
            kernel_ptr: pat.kernel_ptr.clone(),
            col_idx: pat.col_idx.clone(),
            pat_idx: pat.pat_idx.clone(),
            val_ptr: pat.val_ptr.clone(),
            pat_ptr: pat.pat_ptr.clone(),
            pat_pos: pat.pat_pos.clone(),
            values: QuantizedValues::fit(&pat.values, bits),
        }
    }

    /// Dequantize back to an f32 pattern matrix. NOTE: quantization can
    /// snap two distinct values to one codebook entry but never a
    /// nonzero to zero (entry 0 is reserved for exact zeros), so the
    /// reconstruction still passes `PatternMatrix::validate`.
    pub fn to_pattern(&self) -> PatternMatrix {
        PatternMatrix {
            rows: self.rows,
            cols: self.cols,
            kh: self.kh,
            kw: self.kw,
            cin: self.cin,
            kernel_ptr: self.kernel_ptr.clone(),
            col_idx: self.col_idx.clone(),
            pat_idx: self.pat_idx.clone(),
            val_ptr: self.val_ptr.clone(),
            pat_ptr: self.pat_ptr.clone(),
            pat_pos: self.pat_pos.clone(),
            values: self.values.dequantize(),
        }
    }

    pub fn kernels(&self) -> usize {
        self.col_idx.len()
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// On-disk bytes mirroring `PatternMatrix::bytes_on_disk_idx16`
    /// (16-bit column indices, 1-byte pattern ids while the table stays
    /// within 256 patterns, the shared table itself) with the value
    /// payload replaced by packed indices **plus the codebook**.
    pub fn bytes_on_disk_idx16(&self) -> usize {
        let id_bytes = if self.pat_ptr.len() - 1 <= 256 { 1 } else { 2 };
        self.kernel_ptr.len() * 4
            + self.col_idx.len() * 2
            + self.pat_idx.len() * id_bytes
            + self.pat_pos.len()
            + self.pat_ptr.len() * 2
            + self.values.disk_bytes()
    }
}

/// The quantized payload the executor dispatches on — one variant per
/// sparse format (dense layers never quantize: the blocked GEMM has no
/// LUT path and shallow pruning is not where storage hurts).
#[derive(Debug, Clone, PartialEq)]
pub enum QSparseMatrix {
    Csr(QCsr),
    Bsr(QBsr),
    Pattern(QPattern),
}

impl QSparseMatrix {
    pub fn rows(&self) -> usize {
        match self {
            QSparseMatrix::Csr(q) => q.rows,
            QSparseMatrix::Bsr(q) => q.rows,
            QSparseMatrix::Pattern(q) => q.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            QSparseMatrix::Csr(q) => q.cols,
            QSparseMatrix::Bsr(q) => q.cols,
            QSparseMatrix::Pattern(q) => q.cols,
        }
    }

    /// The value store behind this payload.
    pub fn values(&self) -> &QuantizedValues {
        match self {
            QSparseMatrix::Csr(q) => &q.values,
            QSparseMatrix::Bsr(q) => &q.values,
            QSparseMatrix::Pattern(q) => &q.values,
        }
    }

    pub fn bytes_on_disk_idx16(&self) -> usize {
        match self {
            QSparseMatrix::Csr(q) => q.bytes_on_disk_idx16(),
            QSparseMatrix::Bsr(q) => q.bytes_on_disk_idx16(),
            QSparseMatrix::Pattern(q) => q.bytes_on_disk_idx16(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::pattern::prune_patterns;
    use crate::prop_assert;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_sparse(rng: &mut Rng, len: usize, density: f64) -> Vec<f32> {
        let mut dense = vec![0.0f32; len];
        for v in dense.iter_mut() {
            if rng.f64() < density {
                *v = rng.normal() as f32;
            }
        }
        dense
    }

    #[test]
    fn value_bits_roundtrip() {
        for vb in [ValueBits::F32, ValueBits::Q8, ValueBits::Q4] {
            assert_eq!(ValueBits::from_bits(vb.bits()), Some(vb));
        }
        assert_eq!(ValueBits::from_bits(16), None);
        assert!(ValueBits::Q4.quantized());
        assert!(!ValueBits::F32.quantized());
    }

    /// The index path is lossless: pack -> unpack reproduces exactly the
    /// index every value was assigned, for both widths, any length
    /// (including odd lengths straddling 4-bit byte boundaries).
    #[test]
    fn prop_pack_unpack_lossless() {
        prop::check("qsparse pack/unpack", |rng: &mut Rng| {
            let n = rng.range(0, 600);
            let bits = [4u8, 8][rng.below(2)];
            let vals = random_sparse(rng, n, rng.f64());
            let q = QuantizedValues::fit(&vals, bits);
            prop_assert!(q.len() == n, "len");
            prop_assert!(
                q.packed.len() == (n * bits as usize).div_ceil(8),
                "packed bytes {} for {} x {}",
                q.packed.len(),
                n,
                bits
            );
            let idx = q.unpack_indices();
            // re-derive each index independently and compare
            for (i, &ix) in idx.iter().enumerate() {
                prop_assert!(q.index(i) == ix as usize, "index {i}");
                prop_assert!((ix as usize) < q.codebook.len(), "index {i} out of range");
            }
            // zeros (and only zeros) land on the reserved entry 0
            for (i, &v) in vals.iter().enumerate() {
                if v == 0.0 {
                    prop_assert!(q.index(i) == 0, "zero must map to entry 0");
                } else {
                    prop_assert!(q.index(i) != 0, "nonzero mapped to zero entry");
                    prop_assert!(q.codebook[q.index(i)] != 0.0, "nonzero reconstructs to 0");
                }
            }
            // dequantize matches codebook gather and the error bound
            let back = q.dequantize();
            for (a, b) in vals.iter().zip(&back) {
                prop_assert!(
                    (a - b).abs() <= q.error_bound() + 1e-7,
                    "err {} > bound {}",
                    (a - b).abs(),
                    q.error_bound()
                );
            }
            Ok(())
        });
    }

    /// Few distinct values fit the codebook exactly: reconstruction is
    /// lossless and the bound is zero.
    #[test]
    fn lossless_when_distinct_values_fit() {
        let vals = [0.0f32, 1.5, -2.0, 1.5, 0.0, -2.0, 3.25];
        for bits in [4u8, 8] {
            let q = QuantizedValues::fit(&vals, bits);
            assert_eq!(q.dequantize(), vals);
            assert_eq!(q.error_bound(), 0.0);
        }
    }

    /// The k-means fit subsumes the uniform quantizer under the same
    /// support constraint: seeded from `compress::quant`'s symmetric
    /// grid, its SSE is never worse than assigning each nonzero value to
    /// its nearest NONZERO uniform level. (The unconstrained
    /// `QuantizedTensor` may snap small nonzeros to level 0 — cheaper in
    /// SSE but it silently changes the support, which sparse payloads
    /// must never do; that is exactly the constraint this fit adds.)
    #[test]
    fn prop_kmeans_no_worse_than_support_preserving_uniform() {
        prop::check_n("kmeans vs uniform", 40, |rng: &mut Rng| {
            let n = rng.range(20, 400);
            let bits = [4u8, 8][rng.below(2)];
            let vals = random_sparse(rng, n, 0.7);
            let q = QuantizedValues::fit(&vals, bits);
            // support-preserving uniform baseline: nearest nonzero level
            let amax = vals.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-8);
            let levels = (1i32 << (bits - 1)) - 1;
            let step = amax / levels as f32;
            let uni_sse: f64 = vals
                .iter()
                .filter(|v| **v != 0.0)
                .map(|&v| {
                    let mut lvl = ((v / step).round() as i32).clamp(-levels, levels);
                    if lvl == 0 {
                        lvl = if v > 0.0 { 1 } else { -1 };
                    }
                    let d = (v - lvl as f32 * step) as f64;
                    d * d
                })
                .sum();
            let sse = q.sse(&vals);
            prop_assert!(
                sse <= uni_sse * (1.0 + 1e-4) + 1e-6,
                "kmeans sse {} worse than support-preserving uniform {}",
                sse,
                uni_sse
            );
            Ok(())
        });
    }

    #[test]
    fn codebook_size_respects_bits() {
        let mut rng = Rng::new(5);
        let vals = random_sparse(&mut rng, 4000, 0.9);
        let q4 = QuantizedValues::fit(&vals, 4);
        assert!(q4.codebook.len() <= 16, "{}", q4.codebook.len());
        assert!(q4.codebook.len() > 8, "fit should use the budget");
        let q8 = QuantizedValues::fit(&vals, 8);
        assert!(q8.codebook.len() <= 256);
        assert!(
            q8.error_bound() <= q4.error_bound(),
            "more levels cannot hurt: {} vs {}",
            q8.error_bound(),
            q4.error_bound()
        );
    }

    #[test]
    fn empty_and_all_zero_values() {
        let q = QuantizedValues::fit(&[], 4);
        assert_eq!(q.len(), 0);
        assert_eq!(q.codebook, vec![0.0]);
        assert!(q.dequantize().is_empty());
        let qz = QuantizedValues::fit(&[0.0; 7], 8);
        assert_eq!(qz.dequantize(), vec![0.0; 7]);
        assert_eq!(qz.error_bound(), 0.0);
    }

    /// Structure arrays survive quantization untouched for all three
    /// formats; dequantization reproduces a valid matrix whose support
    /// is exactly the original's.
    #[test]
    fn prop_wrappers_preserve_structure() {
        prop::check_n("qsparse wrappers", 40, |rng: &mut Rng| {
            let kh = [2usize, 3][rng.below(2)];
            let kw = [2usize, 3][rng.below(2)];
            let cin = rng.range(1, 6);
            let cols = rng.range(1, 12);
            let k = kh * kw * cin;
            let bits = [4u8, 8][rng.below(2)];
            let dense = random_sparse(rng, k * cols, rng.f64());

            let csr = CsrMatrix::from_dense(&dense, k, cols);
            let qcsr = QCsr::from_csr(&csr, bits);
            let back = qcsr.to_csr();
            back.validate()?;
            prop_assert!(back.row_ptr == csr.row_ptr, "csr row_ptr");
            prop_assert!(back.col_idx == csr.col_idx, "csr col_idx");
            prop_assert!(qcsr.nnz() == csr.nnz(), "csr nnz");

            let bsr = BsrMatrix::from_dense(&dense, k, cols, 4, 4);
            let qbsr = QBsr::from_bsr(&bsr, bits);
            let bback = qbsr.to_bsr();
            bback.validate()?;
            prop_assert!(bback.row_ptr == bsr.row_ptr, "bsr row_ptr");
            prop_assert!(bback.nnz() == bsr.nnz(), "bsr nnz survives padding-zero packing");

            let pat = PatternMatrix::from_dense(&dense, kh, kw, cin, cols);
            let qpat = QPattern::from_pattern(&pat, bits);
            let pback = qpat.to_pattern();
            pback.validate()?;
            prop_assert!(pback.pat_idx == pat.pat_idx, "pattern ids");
            prop_assert!(pback.val_ptr == pat.val_ptr, "pattern val_ptr");
            Ok(())
        });
    }

    /// The §3 compounding claim at the payload level: a q4 pattern
    /// payload, codebook charged, lands under 40% of the f32 pattern
    /// payload on a pattern-pruned layer.
    #[test]
    fn q4_pattern_payload_under_40_percent_of_f32() {
        let (kh, kw, cin, cols) = (3usize, 3usize, 16usize, 64usize);
        let mut rng = Rng::new(7);
        let mut mat = vec![0.0f32; kh * kw * cin * cols];
        rng.fill_normal(&mut mat, 0.5);
        prune_patterns(&mut mat, kh, kw, cin, cols, 0.8, 4, 8);
        let pat = PatternMatrix::from_dense(&mat, kh, kw, cin, cols);
        let qpat = QPattern::from_pattern(&pat, 4);
        let f32_bytes = pat.bytes_on_disk_idx16(32);
        let q4_bytes = qpat.bytes_on_disk_idx16();
        assert!(
            (q4_bytes as f64) < 0.4 * f32_bytes as f64,
            "q4 {} vs f32 {} ({:.1}%)",
            q4_bytes,
            f32_bytes,
            100.0 * q4_bytes as f64 / f32_bytes as f64
        );
    }

    #[test]
    fn disk_bytes_charge_the_codebook() {
        let vals = vec![1.0f32; 100];
        let q = QuantizedValues::fit(&vals, 4);
        // codebook [0.0, 1.0]: 2 entries * 4 bytes + 1 length byte;
        // packed: 100 * 4 bits = 50 bytes
        assert_eq!(q.codebook.len(), 2);
        assert_eq!(q.disk_bytes(), 50 + 8 + 1);
    }
}
