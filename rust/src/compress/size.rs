//! Storage accounting: regenerates Table 2's Size(M) column and the §3
//! compression / storage-reduction claims from the IR graphs + profiles.

use super::profile::SparsityProfile;
use crate::ir::Graph;

#[derive(Debug, Clone)]
pub struct SizeReport {
    pub model: String,
    pub params: usize,
    pub weights: usize,
    pub dense_mb: f64,
    pub nnz: usize,
    pub compression_rate: f64,
    /// CSR-ish on-disk bytes: values f32 + 16-bit indices.
    pub sparse_bytes_idx16: usize,
    /// 4-bit quantized values, no indices (the paper's 3,438x convention).
    pub quant4_bytes_no_idx: usize,
    /// 4-bit quantized + 16-bit indices.
    pub quant4_bytes_idx16: usize,
}

impl SizeReport {
    pub fn storage_reduction_no_idx(&self) -> f64 {
        (self.weights * 4) as f64 / self.quant4_bytes_no_idx.max(1) as f64
    }
    pub fn storage_reduction_idx16(&self) -> f64 {
        (self.weights * 4) as f64 / self.quant4_bytes_idx16.max(1) as f64
    }
}

/// Account a graph under a sparsity profile (+4-bit quantization).
pub fn report(graph: &Graph, profile: &SparsityProfile) -> SizeReport {
    let weights = graph.weight_count();
    let nnz = profile.nnz(graph);
    SizeReport {
        model: graph.name.clone(),
        params: graph.param_count(),
        weights,
        dense_mb: graph.size_mb(),
        nnz,
        compression_rate: weights as f64 / nnz.max(1) as f64,
        sparse_bytes_idx16: nnz * 4 + nnz * 2,
        quant4_bytes_no_idx: (nnz * 4).div_ceil(8),
        quant4_bytes_idx16: (nnz * 4).div_ceil(8) + nnz * 2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::profile::paper_profile;
    use crate::models;

    #[test]
    fn lenet5_storage_reduction_two_orders() {
        // §3: "reduction of up to 3,438x in weight storage (LeNet-5, not
        // accounting for indices)" — dense f32 vs 4-bit on the surviving
        // weights. With our 348x profile: 348 * 8 = 2,784x; the paper's
        // 3,438x uses its slightly higher rate + 3-bit fc. Same order.
        let g = models::build("lenet5", 1).unwrap();
        let r = report(&g, &paper_profile(&g));
        let red = r.storage_reduction_no_idx();
        assert!(red > 2000.0, "storage reduction {red}");
        assert!(red < 5000.0);
    }

    #[test]
    fn table2_sizes() {
        for (model, mb) in [
            ("mobilenet_v1", 17.1),
            ("mobilenet_v2", 14.1),
            ("inception_v3", 95.4),
            ("resnet50", 102.4),
        ] {
            let g = models::build(model, 1).unwrap();
            let r = report(&g, &SparsityProfile::default());
            assert!((r.dense_mb - mb).abs() / mb < 0.02, "{model}: {}", r.dense_mb);
        }
    }

    #[test]
    fn sparse_smaller_than_dense_above_breakeven() {
        // CSR(f32+idx16) pays 1.5x per nnz: wins iff sparsity > 1/3.
        let g = models::build("alexnet", 1).unwrap();
        let r = report(&g, &paper_profile(&g));
        assert!(r.sparse_bytes_idx16 < r.weights * 4);
    }

    #[test]
    fn rate_consistency() {
        let g = models::build("vgg16", 1).unwrap();
        let p = paper_profile(&g);
        let r = report(&g, &p);
        assert!((r.compression_rate - p.overall_rate(&g)).abs() < 0.5);
    }
}
