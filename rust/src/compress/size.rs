//! Storage accounting: regenerates Table 2's Size(M) column and the §3
//! compression / storage-reduction claims from the IR graphs + profiles,
//! plus per-matrix format comparisons (CSR vs BSR padding overhead).

use super::bsr::BsrMatrix;
use super::csr::CsrMatrix;
use super::pattern::PatternMatrix;
use super::profile::SparsityProfile;
use super::qsparse::{QBsr, QCsr, QPattern, ValueBits};
use crate::ir::Graph;

#[derive(Debug, Clone)]
pub struct SizeReport {
    pub model: String,
    pub params: usize,
    pub weights: usize,
    pub dense_mb: f64,
    pub nnz: usize,
    pub compression_rate: f64,
    /// CSR-ish on-disk bytes: values f32 + 16-bit indices.
    pub sparse_bytes_idx16: usize,
    /// 4-bit quantized values, no indices (the paper's 3,438x convention).
    pub quant4_bytes_no_idx: usize,
    /// 4-bit quantized + 16-bit indices.
    pub quant4_bytes_idx16: usize,
}

impl SizeReport {
    pub fn storage_reduction_no_idx(&self) -> f64 {
        (self.weights * 4) as f64 / self.quant4_bytes_no_idx.max(1) as f64
    }
    pub fn storage_reduction_idx16(&self) -> f64 {
        (self.weights * 4) as f64 / self.quant4_bytes_idx16.max(1) as f64
    }
}

/// Account a graph under a sparsity profile (+4-bit quantization).
pub fn report(graph: &Graph, profile: &SparsityProfile) -> SizeReport {
    let weights = graph.weight_count();
    let nnz = profile.nnz(graph);
    SizeReport {
        model: graph.name.clone(),
        params: graph.param_count(),
        weights,
        dense_mb: graph.size_mb(),
        nnz,
        compression_rate: weights as f64 / nnz.max(1) as f64,
        sparse_bytes_idx16: nnz * 4 + nnz * 2,
        quant4_bytes_no_idx: (nnz * 4).div_ceil(8),
        quant4_bytes_idx16: (nnz * 4).div_ceil(8) + nnz * 2,
    }
}

/// One format's on-disk footprint for a concrete pruned matrix.
#[derive(Debug, Clone)]
pub struct FormatBytes {
    /// `csr`, `bsr4x1`, `bsr4x4`, `pattern` (matching
    /// `planner::SparseFormat` labels).
    pub format: String,
    /// On-disk bytes with 16-bit indices and `value_bits`-bit values.
    /// For `pattern` this includes the shared pattern table (positions +
    /// extents) next to the per-kernel ids — the table is part of the
    /// layer's payload, not free metadata.
    pub bytes_idx16: usize,
    /// nnz / stored values — 1.0 for CSR and Pattern (no padding); BSR
    /// pays padding below 1.0 and saves on indices (one per block
    /// instead of one per value).
    pub fill_ratio: f64,
}

/// Compare one pruned matrix's storage across the executable formats.
/// This is the fill-ratio accounting side of the planner's tradeoff: a
/// block format can be *smaller* than CSR despite padding (fewer
/// indices) when the sparsity is block-structured, and much larger when
/// it is scattered. `hwio` is the layer's `[kh, kw, cin, cout]` weight
/// shape; the pattern row appears whenever the shape is
/// pattern-eligible (spatial kernels within the table ceiling — see
/// [`crate::planner::pattern_eligible`]).
pub fn format_bytes(csr: &CsrMatrix, value_bits: usize, hwio: [usize; 4]) -> Vec<FormatBytes> {
    let mut out = vec![FormatBytes {
        format: "csr".to_string(),
        bytes_idx16: csr.bytes_on_disk_idx16(value_bits),
        fill_ratio: 1.0,
    }];
    for (br, bc) in [(4usize, 1usize), (4, 4)] {
        let b = BsrMatrix::from_csr(csr, br, bc);
        out.push(FormatBytes {
            format: format!("bsr{br}x{bc}"),
            bytes_idx16: b.bytes_on_disk_idx16(value_bits),
            fill_ratio: b.fill_ratio(),
        });
    }
    if crate::planner::pattern_eligible(csr, hwio) {
        let p = PatternMatrix::from_csr(csr, hwio[0], hwio[1], hwio[2]);
        out.push(FormatBytes {
            format: "pattern".to_string(),
            bytes_idx16: p.bytes_on_disk_idx16(value_bits),
            fill_ratio: 1.0,
        });
    }
    out
}

/// [`format_bytes`] with the value-precision axis: f32 delegates to the
/// plain rows; q8/q4 rows (`csr+q8`, `pattern+q4`, ...) account the
/// *actual* quantized payloads — structure at 16-bit indices, packed
/// codebook indices, **and the codebook itself** (fitted on the
/// matrix's real values, so the byte counts are what a serialized
/// artifact would ship, not an estimate). Fill ratios are unchanged:
/// quantization packs the same stored values.
pub fn format_bytes_valued(
    csr: &CsrMatrix,
    hwio: [usize; 4],
    value_bits: ValueBits,
) -> Vec<FormatBytes> {
    if !value_bits.quantized() {
        return format_bytes(csr, 32, hwio);
    }
    let bits = value_bits.bits() as u8;
    let suffix = value_bits.label();
    let mut out = vec![FormatBytes {
        format: format!("csr+{suffix}"),
        bytes_idx16: QCsr::from_csr(csr, bits).bytes_on_disk_idx16(),
        fill_ratio: 1.0,
    }];
    for (br, bc) in [(4usize, 1usize), (4, 4)] {
        let b = BsrMatrix::from_csr(csr, br, bc);
        out.push(FormatBytes {
            format: format!("bsr{br}x{bc}+{suffix}"),
            bytes_idx16: QBsr::from_bsr(&b, bits).bytes_on_disk_idx16(),
            fill_ratio: b.fill_ratio(),
        });
    }
    if crate::planner::pattern_eligible(csr, hwio) {
        let p = PatternMatrix::from_csr(csr, hwio[0], hwio[1], hwio[2]);
        out.push(FormatBytes {
            format: format!("pattern+{suffix}"),
            bytes_idx16: QPattern::from_pattern(&p, bits).bytes_on_disk_idx16(),
            fill_ratio: 1.0,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::profile::paper_profile;
    use crate::models;
    use crate::util::rng::Rng;

    #[test]
    fn lenet5_storage_reduction_two_orders() {
        // §3: "reduction of up to 3,438x in weight storage (LeNet-5, not
        // accounting for indices)" — dense f32 vs 4-bit on the surviving
        // weights. With our 348x profile: 348 * 8 = 2,784x; the paper's
        // 3,438x uses its slightly higher rate + 3-bit fc. Same order.
        let g = models::build("lenet5", 1).unwrap();
        let r = report(&g, &paper_profile(&g));
        let red = r.storage_reduction_no_idx();
        assert!(red > 2000.0, "storage reduction {red}");
        assert!(red < 5000.0);
    }

    #[test]
    fn table2_sizes() {
        for (model, mb) in [
            ("mobilenet_v1", 17.1),
            ("mobilenet_v2", 14.1),
            ("inception_v3", 95.4),
            ("resnet50", 102.4),
        ] {
            let g = models::build(model, 1).unwrap();
            let r = report(&g, &SparsityProfile::default());
            assert!((r.dense_mb - mb).abs() / mb < 0.02, "{model}: {}", r.dense_mb);
        }
    }

    #[test]
    fn sparse_smaller_than_dense_above_breakeven() {
        // CSR(f32+idx16) pays 1.5x per nnz: wins iff sparsity > 1/3.
        let g = models::build("alexnet", 1).unwrap();
        let r = report(&g, &paper_profile(&g));
        assert!(r.sparse_bytes_idx16 < r.weights * 4);
    }

    #[test]
    fn format_bytes_tracks_structure() {
        let (k, n) = (32usize, 32usize);
        // block-structured: whole 4x4 blocks, fill 1.0 -> BSR smaller
        let mut rng = Rng::new(1);
        let mut blocky = vec![0.0f32; k * n];
        for b in 0..k / 4 {
            for j in 0..n / 4 {
                if rng.f64() < 0.25 {
                    for p in 0..4 {
                        for x in 0..4 {
                            blocky[(b * 4 + p) * n + j * 4 + x] = rng.normal() as f32;
                        }
                    }
                }
            }
        }
        let csr = CsrMatrix::from_dense(&blocky, k, n);
        let sizes = format_bytes(&csr, 32, [1, 1, k, n]);
        let by = |f: &str| sizes.iter().find(|s| s.format == f).unwrap().clone();
        assert!((by("bsr4x4").fill_ratio - 1.0).abs() < 1e-12);
        assert!(by("bsr4x4").bytes_idx16 < by("csr").bytes_idx16);

        // scattered: BSR pays padding, fill < 1, bytes balloon
        let mut scattered = vec![0.0f32; k * n];
        for v in scattered.iter_mut() {
            if rng.f64() < 0.1 {
                *v = rng.normal() as f32;
            }
        }
        let csr2 = CsrMatrix::from_dense(&scattered, k, n);
        let sizes2 = format_bytes(&csr2, 32, [1, 1, k, n]);
        let b44 = sizes2.iter().find(|s| s.format == "bsr4x4").unwrap();
        assert!(b44.fill_ratio < 0.5, "fill {}", b44.fill_ratio);
        let c = sizes2.iter().find(|s| s.format == "csr").unwrap();
        assert!(b44.bytes_idx16 > c.bytes_idx16);
    }

    /// Pins the exact per-format byte formulas on a hand-computable
    /// matrix, so storage accounting cannot drift silently — in
    /// particular the pattern row must charge the shared pattern table
    /// (positions + extents), not just per-kernel ids.
    #[test]
    fn format_bytes_pinned_counts() {
        // 3x3 kernels, cin=2, cout=4 (K=18, N=4); three surviving
        // kernels over two 4-entry patterns, nnz = 12
        let (kh, kw, cin, cout) = (3usize, 3usize, 2usize, 4usize);
        let mut dense = vec![0.0f32; kh * kw * cin * cout];
        let mut put = |pos: usize, ci: usize, co: usize| {
            dense[(pos * cin + ci) * cout + co] = 1.0;
        };
        for pos in [0usize, 2, 4, 6] {
            put(pos, 0, 0); // kernel (0,0), pattern {0,2,4,6}
            put(pos, 1, 1); // kernel (1,1), same pattern
        }
        for pos in [1usize, 3, 5, 7] {
            put(pos, 1, 3); // kernel (1,3), pattern {1,3,5,7}
        }
        let csr = CsrMatrix::from_dense(&dense, kh * kw * cin, cout);
        assert_eq!(csr.nnz(), 12);
        let sizes = format_bytes(&csr, 32, [kh, kw, cin, cout]);
        let by = |f: &str| sizes.iter().find(|s| s.format == f).unwrap().bytes_idx16;
        // CSR: 19*4 row_ptr + 12*2 idx + 12*4 values
        assert_eq!(by("csr"), 76 + 24 + 48);
        // BSR 4x1: 12 blocks -> 6*4 row_ptr + 12*2 idx + 48*4 values
        assert_eq!(by("bsr4x1"), 24 + 24 + 192);
        // BSR 4x4: 4 blocks -> 6*4 row_ptr + 4*2 idx + 64*4 values
        assert_eq!(by("bsr4x4"), 24 + 8 + 256);
        // Pattern: 3*4 kernel_ptr + 3*2 col idx + 3*1 pattern ids
        //          + (8*1 positions + 3*2 extents) table + 12*4 values
        assert_eq!(by("pattern"), 12 + 6 + 3 + 8 + 6 + 48);
    }

    /// Pins the exact quantized-row byte formulas on the same
    /// hand-computable matrix as `format_bytes_pinned_counts`: packed
    /// indices at the declared width plus the codebook (2 f32 entries +
    /// 1 length byte here — every value is 1.0, so the fit is the
    /// smallest possible lossless codebook).
    #[test]
    fn format_bytes_quantized_pinned_counts() {
        let (kh, kw, cin, cout) = (3usize, 3usize, 2usize, 4usize);
        let mut dense = vec![0.0f32; kh * kw * cin * cout];
        let mut put = |pos: usize, ci: usize, co: usize| {
            dense[(pos * cin + ci) * cout + co] = 1.0;
        };
        for pos in [0usize, 2, 4, 6] {
            put(pos, 0, 0);
            put(pos, 1, 1);
        }
        for pos in [1usize, 3, 5, 7] {
            put(pos, 1, 3);
        }
        let csr = CsrMatrix::from_dense(&dense, kh * kw * cin, cout);
        assert_eq!(csr.nnz(), 12);
        let hwio = [kh, kw, cin, cout];
        // f32 delegates to the plain rows (labels unchanged)
        let f32_rows = format_bytes_valued(&csr, hwio, ValueBits::F32);
        assert_eq!(f32_rows[0].format, "csr");
        assert_eq!(f32_rows[0].bytes_idx16, 76 + 24 + 48);

        let codebook = 2 * 4 + 1; // [0.0, 1.0] + length byte
        let q4 = format_bytes_valued(&csr, hwio, ValueBits::Q4);
        let by4 = |f: &str| q4.iter().find(|s| s.format == f).unwrap().bytes_idx16;
        // CSR: 19*4 row_ptr + 12*2 idx + ceil(12*4/8) packed + codebook
        assert_eq!(by4("csr+q4"), 76 + 24 + 6 + codebook);
        // BSR 4x1: 12 blocks -> 6*4 + 12*2 + ceil(48*4/8) + codebook
        assert_eq!(by4("bsr4x1+q4"), 24 + 24 + 24 + codebook);
        // BSR 4x4: 4 blocks -> 6*4 + 4*2 + ceil(64*4/8) + codebook
        assert_eq!(by4("bsr4x4+q4"), 24 + 8 + 32 + codebook);
        // Pattern: structure as the f32 row + ceil(12*4/8) + codebook
        assert_eq!(by4("pattern+q4"), 12 + 6 + 3 + 8 + 6 + 6 + codebook);

        let q8 = format_bytes_valued(&csr, hwio, ValueBits::Q8);
        let by8 = |f: &str| q8.iter().find(|s| s.format == f).unwrap().bytes_idx16;
        assert_eq!(by8("csr+q8"), 76 + 24 + 12 + codebook);
        assert_eq!(by8("pattern+q8"), 12 + 6 + 3 + 8 + 6 + 12 + codebook);
        // fill accounting is unchanged by quantization
        let b44_f32 = format_bytes(&csr, 32, hwio)
            .into_iter()
            .find(|s| s.format == "bsr4x4")
            .unwrap();
        let b44_q4 = q4.iter().find(|s| s.format == "bsr4x4+q4").unwrap();
        assert_eq!(b44_f32.fill_ratio, b44_q4.fill_ratio);
    }

    #[test]
    fn rate_consistency() {
        let g = models::build("vgg16", 1).unwrap();
        let p = paper_profile(&g);
        let r = report(&g, &p);
        assert!((r.compression_rate - p.overall_rate(&g)).abs() < 0.5);
    }
}
