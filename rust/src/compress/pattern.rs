//! Pattern-based sparse weight matrices (PatDNN, Niu et al. 2020).
//!
//! Where BSR imposes structure on the *(K, N) matrix view*, the pattern
//! format imposes it on the *convolution kernels themselves*: every
//! surviving `kh x kw` kernel slice (one per (input channel, output
//! channel) pair) keeps its nonzeros at one of a small set of canonical
//! position sets — the *pattern table* — and whole low-energy kernels
//! are removed entirely (*connectivity pruning*). The encoding stores,
//! per surviving kernel, one output-channel index, one pattern id, and
//! only the true nonzero values; the pattern table (a few entries, shared
//! across the whole layer) is stored once.
//!
//! Compared to the other formats on a pattern-pruned 3x3 conv layer:
//!
//! - **no padding** — unlike BSR, stored values == true nonzeros
//!   (`fill_ratio` is 1.0 by construction);
//! - **amortized indices** — one column index per kernel (≈4 values)
//!   instead of CSR's one per value;
//! - **specialized inner loops** — the kernel's trip count and offsets
//!   are fixed by the pattern id, so `kernels::pattern` runs an unrolled
//!   accumulator per kernel instead of CSR's scattered updates.
//!
//! The row-major (K, N) view is shared with [`CsrMatrix`]: row
//! `(ky*kw + kx)*cin + ci`, column `co`. A kernel slice (ci, co) is the
//! `kh*kw` rows `{pos*cin + ci}` of column `co`.
//!
//! See `docs/PIPELINE.md` for where pattern pruning happens (the ADMM
//! z-step in `python/compile/admm.py` or the native engine's
//! [`prune_patterns`]) and `docs/FORMATS.md` for the storage formula.

use crate::compress::csr::CsrMatrix;
use crate::error::CadnnError;
use std::collections::BTreeMap;

/// Most kernel positions (`kh*kw`) the format supports: pattern ids are
/// u16 and a scattered support can intern up to `2^(kh*kw) - 1` distinct
/// masks, so 16 positions (e.g. 3x3 or 4x4 kernels) is the ceiling.
/// The planner only considers the format for eligible shapes.
pub const MAX_POSITIONS: usize = 16;

/// Pattern-library size used by [`prune_patterns`] when a caller has no
/// reason to choose otherwise (PatDNN finds 6-8 patterns sufficient).
pub const DEFAULT_LIBRARY: usize = 8;

/// Entries each canonical pattern keeps per kernel (PatDNN's 4-entry
/// patterns for 3x3 kernels).
pub const DEFAULT_ENTRIES: usize = 4;

/// Pattern-encoded sparse weights over the (K, N) im2col view with
/// `K = kh*kw*cin`, `N = cols` output channels.
///
/// Kernels are grouped by input channel: `kernel_ptr[ci]..kernel_ptr[ci+1]`
/// indexes the stored kernels of channel `ci`, each with an output channel
/// (`col_idx`), a pattern id (`pat_idx`) and its values
/// (`val_ptr[kn]..val_ptr[kn+1]`, in ascending-position order). The shared
/// pattern table lives in `pat_ptr`/`pat_pos`: pattern `p` occupies the
/// kernel positions `pat_pos[pat_ptr[p]..pat_ptr[p+1]]` (each in
/// `0..kh*kw`, strictly ascending).
///
/// # Examples
///
/// ```
/// use cadnn::compress::pattern::PatternMatrix;
///
/// // one input channel, two output channels, 3x3 kernels:
/// // column 0 keeps a 2-entry pattern, column 1 is connectivity-pruned
/// let (kh, kw, cin, cols) = (3, 3, 1, 2);
/// let mut dense = vec![0.0f32; kh * kw * cin * cols];
/// dense[0 * cols + 0] = 1.0; // position 0
/// dense[4 * cols + 0] = 2.0; // position 4 (kernel center)
/// let pat = PatternMatrix::from_dense(&dense, kh, kw, cin, cols);
/// assert_eq!(pat.kernels(), 1);
/// assert_eq!(pat.patterns(), 1);
/// assert_eq!(pat.nnz(), 2);
/// assert_eq!(pat.to_dense(), dense); // lossless round-trip
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct PatternMatrix {
    /// Logical rows, `kh * kw * cin`.
    pub rows: usize,
    /// Logical columns (output channels).
    pub cols: usize,
    pub kh: usize,
    pub kw: usize,
    pub cin: usize,
    /// Kernel extents per input channel, length `cin + 1`.
    pub kernel_ptr: Vec<u32>,
    /// Output channel per stored kernel, strictly ascending within a `ci`.
    pub col_idx: Vec<u32>,
    /// Pattern-table id per stored kernel.
    pub pat_idx: Vec<u16>,
    /// Value extents per stored kernel, length `kernels + 1`.
    pub val_ptr: Vec<u32>,
    /// True nonzero values, ascending-position order within each kernel.
    pub values: Vec<f32>,
    /// Pattern extents into `pat_pos`, length `patterns + 1`.
    pub pat_ptr: Vec<u32>,
    /// Kernel positions (`0..kh*kw`) of each pattern, strictly ascending.
    pub pat_pos: Vec<u8>,
}

impl PatternMatrix {
    /// Encode from a dense row-major (K, N) matrix. Every kernel slice's
    /// exact nonzero support becomes its pattern (interned into the
    /// shared table in first-seen order); kernels with no nonzeros are
    /// dropped, so the encoding is lossless and padding-free.
    pub fn from_dense(dense: &[f32], kh: usize, kw: usize, cin: usize, cols: usize) -> Self {
        assert!(kh > 0 && kw > 0 && cin > 0, "kernel dims must be nonzero");
        let kk = kh * kw;
        assert!(kk <= MAX_POSITIONS, "pattern format supports at most {MAX_POSITIONS} positions");
        let rows = kk * cin;
        assert_eq!(dense.len(), rows * cols);
        let mut table: Vec<Vec<u8>> = Vec::new();
        let mut intern: BTreeMap<Vec<u8>, u16> = BTreeMap::new();
        let mut kernel_ptr = Vec::with_capacity(cin + 1);
        let mut col_idx = Vec::new();
        let mut pat_idx = Vec::new();
        let mut val_ptr = vec![0u32];
        let mut values = Vec::new();
        kernel_ptr.push(0u32);
        for ci in 0..cin {
            for co in 0..cols {
                let mut mask: Vec<u8> = Vec::new();
                for pos in 0..kk {
                    if dense[(pos * cin + ci) * cols + co] != 0.0 {
                        mask.push(pos as u8);
                    }
                }
                if mask.is_empty() {
                    continue; // connectivity-pruned kernel
                }
                for &pos in &mask {
                    values.push(dense[(pos as usize * cin + ci) * cols + co]);
                }
                let next_id = table.len() as u16;
                let id = *intern.entry(mask.clone()).or_insert_with(|| {
                    table.push(mask.clone());
                    next_id
                });
                col_idx.push(co as u32);
                pat_idx.push(id);
                val_ptr.push(values.len() as u32);
            }
            kernel_ptr.push(col_idx.len() as u32);
        }
        let mut pat_ptr = vec![0u32];
        let mut pat_pos = Vec::new();
        for m in &table {
            pat_pos.extend_from_slice(m);
            pat_ptr.push(pat_pos.len() as u32);
        }
        PatternMatrix {
            rows,
            cols,
            kh,
            kw,
            cin,
            kernel_ptr,
            col_idx,
            pat_idx,
            val_ptr,
            values,
            pat_ptr,
            pat_pos,
        }
    }

    /// Re-encode an element-granular CSR matrix (`csr.rows` must equal
    /// `kh*kw*cin`).
    pub fn from_csr(csr: &CsrMatrix, kh: usize, kw: usize, cin: usize) -> Self {
        assert_eq!(csr.rows, kh * kw * cin, "csr rows inconsistent with kernel shape");
        Self::from_dense(&csr.to_dense(), kh, kw, cin, csr.cols)
    }

    /// Stored (surviving) kernels.
    pub fn kernels(&self) -> usize {
        self.col_idx.len()
    }

    /// Distinct patterns in the shared table.
    pub fn patterns(&self) -> usize {
        self.pat_ptr.len() - 1
    }

    /// True nonzeros — identical to stored values (no padding).
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// True-nonzero density over the logical matrix.
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols).max(1) as f64
    }

    /// Decode back to dense row-major.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for ci in 0..self.cin {
            let (s, e) = (self.kernel_ptr[ci] as usize, self.kernel_ptr[ci + 1] as usize);
            for kn in s..e {
                let co = self.col_idx[kn] as usize;
                let pid = self.pat_idx[kn] as usize;
                let (ps, pe) = (self.pat_ptr[pid] as usize, self.pat_ptr[pid + 1] as usize);
                let vals = &self.values[self.val_ptr[kn] as usize..self.val_ptr[kn + 1] as usize];
                for (x, &pos) in self.pat_pos[ps..pe].iter().enumerate() {
                    out[(pos as usize * self.cin + ci) * self.cols + co] = vals[x];
                }
            }
        }
        out
    }

    /// Decode to the element-granular CSR encoding (for cross-format
    /// comparisons and round-trip tests).
    pub fn to_csr(&self) -> CsrMatrix {
        CsrMatrix::from_dense(&self.to_dense(), self.rows, self.cols)
    }

    /// In-memory bytes (u32 pointers/indices, u16 pattern ids, u8
    /// positions, f32 values).
    pub fn bytes_in_memory(&self) -> usize {
        4 * (self.kernel_ptr.len() + self.col_idx.len() + self.val_ptr.len() + self.pat_ptr.len())
            + 4 * self.values.len()
            + 2 * self.pat_idx.len()
            + self.pat_pos.len()
    }

    /// On-disk bytes with 16-bit output-channel indices and
    /// `value_bits`-bit values, **including the shared pattern table**
    /// (positions at one byte each + 16-bit pattern extents). Pattern ids
    /// cost one byte while the table stays within 256 patterns (the
    /// pattern-pruned regime), two otherwise. `val_ptr` is derivable from
    /// the pattern popcounts, so it is not accounted.
    pub fn bytes_on_disk_idx16(&self, value_bits: usize) -> usize {
        let id_bytes = if self.patterns() <= 256 { 1 } else { 2 };
        self.kernel_ptr.len() * 4
            + self.col_idx.len() * 2
            + self.pat_idx.len() * id_bytes
            + self.pat_pos.len()
            + self.pat_ptr.len() * 2
            + (self.values.len() * value_bits).div_ceil(8)
    }

    /// Structural validation (used by property tests).
    pub fn validate(&self) -> Result<(), CadnnError> {
        let invalid =
            |reason: String| CadnnError::InvalidCsr { reason: format!("pattern: {reason}") };
        let kk = self.kh * self.kw;
        if self.kh == 0 || self.kw == 0 || self.cin == 0 {
            return Err(invalid("zero kernel dims".into()));
        }
        if self.rows != kk * self.cin {
            return Err(invalid("rows inconsistent with kh*kw*cin".into()));
        }
        if self.kernel_ptr.len() != self.cin + 1 {
            return Err(invalid("kernel_ptr length".into()));
        }
        if *self.kernel_ptr.last().unwrap() as usize != self.col_idx.len() {
            return Err(invalid("kernel_ptr tail".into()));
        }
        if self.pat_idx.len() != self.col_idx.len() {
            return Err(invalid("pat_idx length".into()));
        }
        if self.val_ptr.len() != self.col_idx.len() + 1 {
            return Err(invalid("val_ptr length".into()));
        }
        if *self.val_ptr.last().unwrap() as usize != self.values.len() {
            return Err(invalid("val_ptr tail".into()));
        }
        if self.pat_ptr.is_empty() || *self.pat_ptr.last().unwrap() as usize != self.pat_pos.len()
        {
            return Err(invalid("pat_ptr tail".into()));
        }
        // pattern table: ascending unique in-range positions, nonempty
        for p in 0..self.patterns() {
            let (s, e) = (self.pat_ptr[p] as usize, self.pat_ptr[p + 1] as usize);
            if s >= e {
                return Err(invalid(format!("pattern {p} empty or not monotone")));
            }
            let mut prev: i32 = -1;
            for &pos in &self.pat_pos[s..e] {
                if (pos as i32) <= prev || pos as usize >= kk {
                    return Err(invalid(format!("pattern {p} positions invalid")));
                }
                prev = pos as i32;
            }
        }
        // kernels: ascending cols per channel, pattern ids in range,
        // value extents matching the pattern popcount, values nonzero
        for ci in 0..self.cin {
            let (s, e) = (self.kernel_ptr[ci] as usize, self.kernel_ptr[ci + 1] as usize);
            if s > e || e > self.col_idx.len() {
                return Err(invalid(format!("channel {ci} kernel_ptr out of range")));
            }
            let mut prev: i64 = -1;
            for kn in s..e {
                let co = self.col_idx[kn] as i64;
                if co <= prev || co as usize >= self.cols {
                    return Err(invalid(format!("channel {ci} cols invalid")));
                }
                prev = co;
                let pid = self.pat_idx[kn] as usize;
                if pid >= self.patterns() {
                    return Err(invalid(format!("kernel {kn} pattern id out of range")));
                }
                let want = (self.pat_ptr[pid + 1] - self.pat_ptr[pid]) as usize;
                let got = (self.val_ptr[kn + 1] - self.val_ptr[kn]) as usize;
                if want != got {
                    return Err(invalid(format!("kernel {kn} has {got} values, pattern {want}")));
                }
            }
        }
        if self.values.iter().any(|v| *v == 0.0) {
            return Err(invalid("stored value is zero (padding is not allowed)".into()));
        }
        Ok(())
    }
}

/// Surviving-kernel count a pattern encoding of `csr` would have —
/// O(nnz), no densification. The planner's per-kernel-overhead estimator
/// (the value count is exactly `csr.nnz()`: the format stores no
/// padding).
pub fn count_kernels(csr: &CsrMatrix, cin: usize) -> usize {
    assert!(cin > 0);
    debug_assert_eq!(csr.rows % cin, 0, "rows must be kh*kw*cin");
    let slots = cin * csr.cols;
    let mut seen = vec![0u64; slots.div_ceil(64).max(1)];
    let mut count = 0usize;
    for r in 0..csr.rows {
        let ci = r % cin;
        let (s, e) = (csr.row_ptr[r] as usize, csr.row_ptr[r + 1] as usize);
        for idx in s..e {
            let key = ci * csr.cols + csr.col_idx[idx] as usize;
            let (w, b) = (key / 64, key % 64);
            if seen[w] & (1u64 << b) == 0 {
                seen[w] |= 1u64 << b;
                count += 1;
            }
        }
    }
    count
}

/// PatDNN-style pattern pruning of a dense (K, N) weight matrix, in
/// place — the native-engine analogue of `python/compile/admm.py`'s
/// `project_prune_pattern` z-step:
///
/// 1. each kernel nominates its top-`entries` magnitude positions;
/// 2. the `library_size` masks with the largest accumulated magnitude
///    form the layer's pattern library;
/// 3. every kernel is projected onto its best library pattern, and
///    *connectivity pruning* keeps only the highest-energy kernels —
///    enough that the surviving value count lands on
///    `round(len * (1 - sparsity))` (within half a pattern).
///
/// If the target density exceeds what `entries`-entry patterns can
/// express (`entries / (kh*kw)`), every kernel survives and the achieved
/// density saturates at that ceiling. Deterministic: ties break by
/// position, then kernel index.
#[allow(clippy::too_many_arguments)]
pub fn prune_patterns(
    mat: &mut [f32],
    kh: usize,
    kw: usize,
    cin: usize,
    cols: usize,
    sparsity: f64,
    entries: usize,
    library_size: usize,
) {
    let kk = kh * kw;
    assert_eq!(mat.len(), kk * cin * cols);
    if sparsity <= 0.0 || mat.is_empty() || kk <= 1 {
        return;
    }
    let library = select_pattern_library(mat, kh, kw, cin, cols, entries, library_size);
    prune_with_library(mat, kh, kw, cin, cols, sparsity, entries, &library);
}

/// Steps 1-2 of [`prune_patterns`]: nominate per-kernel candidate masks
/// and rank them into the layer's pattern library (`library_size` masks
/// of `entries` positions each). Split out so builds can select a
/// library once per layer *family* — PatDNN's observation that pattern
/// libraries transfer across same-shape layers — and reuse it via
/// [`prune_with_library`] (`crate::planner::PlanCache` does exactly
/// this). Returns an empty library for shapes patterns cannot encode
/// (`kh*kw <= 1`).
pub fn select_pattern_library(
    mat: &[f32],
    kh: usize,
    kw: usize,
    cin: usize,
    cols: usize,
    entries: usize,
    library_size: usize,
) -> Vec<Vec<u8>> {
    let kk = kh * kw;
    assert_eq!(mat.len(), kk * cin * cols);
    if mat.is_empty() || kk <= 1 {
        return Vec::new();
    }
    let entries = entries.clamp(1, kk);
    let at = |pos: usize, ci: usize, co: usize| mat[(pos * cin + ci) * cols + co];

    // 1. per-kernel candidate mask (top-`entries` magnitudes, ties by
    //    ascending position) with its accumulated magnitude
    let mut weight_of: BTreeMap<Vec<u8>, f64> = BTreeMap::new();
    for ci in 0..cin {
        for co in 0..cols {
            let mut idx: Vec<usize> = (0..kk).collect();
            idx.sort_by(|&x, &y| {
                let (mx, my) = (at(x, ci, co).abs(), at(y, ci, co).abs());
                my.partial_cmp(&mx).unwrap_or(std::cmp::Ordering::Equal).then(x.cmp(&y))
            });
            let mut mask: Vec<u8> = idx[..entries].iter().map(|&p| p as u8).collect();
            mask.sort_unstable();
            let score: f64 =
                mask.iter().map(|&p| at(p as usize, ci, co).abs() as f64).sum();
            *weight_of.entry(mask).or_insert(0.0) += score;
        }
    }

    // 2. library = top masks by accumulated magnitude (ties lexicographic)
    let mut ranked: Vec<(Vec<u8>, f64)> = weight_of.into_iter().collect();
    ranked.sort_by(|a, b| {
        b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal).then(a.0.cmp(&b.0))
    });
    ranked.truncate(library_size.max(1));
    ranked.into_iter().map(|(m, _)| m).collect()
}

/// How well a pattern `library` fits a layer's weights, in [0, 1]: the
/// magnitude its best per-kernel library mask captures, as a fraction of
/// the magnitude each kernel's own top-`entries` mask would capture
/// (the unconstrained optimum [`select_pattern_library`] nominates
/// from). 1.0 means the library loses nothing; a library selected on a
/// layer with a *different* magnitude layout scores low. `PlanCache`
/// uses this to decide whether a cached family library transfers to a
/// new layer or must be re-selected — the fix for same-shape layers
/// silently inheriting the first layer's patterns. Returns 1.0 for
/// all-zero weights or shapes patterns cannot encode.
pub fn library_fit(
    mat: &[f32],
    kh: usize,
    kw: usize,
    cin: usize,
    cols: usize,
    entries: usize,
    library: &[Vec<u8>],
) -> f64 {
    let kk = kh * kw;
    assert_eq!(mat.len(), kk * cin * cols);
    if mat.is_empty() || kk <= 1 || library.is_empty() {
        return 1.0;
    }
    let entries = entries.clamp(1, kk);
    let at = |pos: usize, ci: usize, co: usize| mat[(pos * cin + ci) * cols + co];
    let mut captured = 0.0f64;
    let mut ideal = 0.0f64;
    let mut mags = vec![0.0f64; kk];
    for ci in 0..cin {
        for co in 0..cols {
            for (pos, m) in mags.iter_mut().enumerate() {
                *m = at(pos, ci, co).abs() as f64;
            }
            let mut best = 0.0f64;
            for mask in library {
                let s: f64 = mask.iter().map(|&p| mags[p as usize]).sum();
                if s > best {
                    best = s;
                }
            }
            captured += best;
            let mut sorted = mags.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal));
            ideal += sorted[..entries].iter().sum::<f64>();
        }
    }
    if ideal <= 0.0 {
        return 1.0;
    }
    (captured / ideal).min(1.0)
}

/// Step 3 of [`prune_patterns`]: project every kernel onto its best
/// pattern from `library` (which may come from another layer of the same
/// (kh, kw, cin) family — see [`select_pattern_library`]) and apply
/// connectivity pruning down to the sparsity target. No-op on an empty
/// library or a non-positive sparsity.
#[allow(clippy::too_many_arguments)]
pub fn prune_with_library(
    mat: &mut [f32],
    kh: usize,
    kw: usize,
    cin: usize,
    cols: usize,
    sparsity: f64,
    entries: usize,
    library: &[Vec<u8>],
) {
    let kk = kh * kw;
    let rows = kk * cin;
    assert_eq!(mat.len(), rows * cols);
    if sparsity <= 0.0 || mat.is_empty() || kk <= 1 || library.is_empty() {
        return;
    }
    let entries = entries.clamp(1, kk);
    // floor of one element: like the element projection, extreme
    // sparsity keeps the single best kernel instead of zeroing the layer
    let target = (((mat.len() as f64) * (1.0 - sparsity)).round() as usize).max(1);
    let nk = cin * cols;
    let at = |pos: usize, ci: usize, co: usize| mat[(pos * cin + ci) * cols + co];

    // 3. project each kernel onto its best library pattern, then keep the
    //    highest-energy kernels up to the target value count
    let mut best = vec![(0usize, 0.0f64); nk];
    for ci in 0..cin {
        for co in 0..cols {
            let mut bi = 0usize;
            let mut bs = f64::NEG_INFINITY;
            for (li, m) in library.iter().enumerate() {
                let s: f64 = m.iter().map(|&p| at(p as usize, ci, co).abs() as f64).sum();
                if s > bs {
                    bs = s;
                    bi = li;
                }
            }
            best[ci * cols + co] = (bi, bs);
        }
    }
    // at least one kernel survives (target has a floor of one element)
    let n_keep = ((target as f64 / entries as f64).round() as usize).max(1).min(nk);
    let mut order: Vec<usize> = (0..nk).collect();
    order.sort_by(|&a, &b| {
        best[b].1.partial_cmp(&best[a].1).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    let mut keep = vec![false; nk];
    for &kn in order.iter().take(n_keep) {
        keep[kn] = true;
    }
    for ci in 0..cin {
        for co in 0..cols {
            let kn = ci * cols + co;
            let mask = &library[best[kn].0];
            for pos in 0..kk {
                let on = keep[kn] && mask.contains(&(pos as u8));
                if !on {
                    mat[(pos * cin + ci) * cols + co] = 0.0;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_sparse(rng: &mut Rng, len: usize, density: f64) -> Vec<f32> {
        let mut dense = vec![0.0f32; len];
        for v in dense.iter_mut() {
            if rng.f64() < density {
                *v = rng.normal() as f32;
            }
        }
        dense
    }

    /// The split entry points compose back into exactly `prune_patterns`.
    #[test]
    fn split_library_matches_prune_patterns() {
        let mut rng = Rng::new(31);
        let (kh, kw, cin, cols) = (3, 3, 4, 16);
        let mut a = random_sparse(&mut rng, kh * kw * cin * cols, 1.0);
        let mut b = a.clone();
        prune_patterns(&mut a, kh, kw, cin, cols, 0.75, 4, 8);
        let lib = select_pattern_library(&b, kh, kw, cin, cols, 4, 8);
        assert!(!lib.is_empty() && lib.len() <= 8);
        prune_with_library(&mut b, kh, kw, cin, cols, 0.75, 4, &lib);
        assert_eq!(a, b);
        // a foreign (family) library still prunes to the same target count
        let mut c = random_sparse(&mut rng, kh * kw * cin * cols, 1.0);
        prune_with_library(&mut c, kh, kw, cin, cols, 0.75, 4, &lib);
        let nnz = c.iter().filter(|v| **v != 0.0).count();
        let want = ((c.len() as f64) * 0.25).round() as usize;
        assert!(
            nnz.abs_diff(want) <= 2,
            "family-library prune landed at {nnz}, want ~{want}"
        );
    }

    #[test]
    fn roundtrip_small() {
        // 3x3, cin=2, cols=3 with a couple of kernels sharing a pattern
        let (kh, kw, cin, cols) = (3, 3, 2, 3);
        let mut dense = vec![0.0f32; kh * kw * cin * cols];
        for &(pos, ci, co, v) in
            &[(0usize, 0usize, 0usize, 1.0f32), (4, 0, 0, 2.0), (0, 1, 2, 3.0), (4, 1, 2, 4.0)]
        {
            dense[(pos * cin + ci) * cols + co] = v;
        }
        let pat = PatternMatrix::from_dense(&dense, kh, kw, cin, cols);
        pat.validate().unwrap();
        assert_eq!(pat.kernels(), 2);
        assert_eq!(pat.patterns(), 1, "identical supports must intern to one pattern");
        assert_eq!(pat.nnz(), 4);
        assert_eq!(pat.to_dense(), dense);
    }

    #[test]
    fn all_zero_matrix_stores_nothing() {
        let pat = PatternMatrix::from_dense(&vec![0.0; 9 * 4 * 8], 3, 3, 4, 8);
        pat.validate().unwrap();
        assert_eq!(pat.kernels(), 0);
        assert_eq!(pat.patterns(), 0);
        assert_eq!(pat.nnz(), 0);
        assert_eq!(pat.to_dense(), vec![0.0; 9 * 4 * 8]);
    }

    #[test]
    fn validate_rejects_padding_values() {
        let mut dense = vec![0.0f32; 9 * 1 * 2];
        dense[0] = 1.0;
        let mut pat = PatternMatrix::from_dense(&dense, 3, 3, 1, 2);
        pat.values[0] = 0.0;
        assert!(pat.validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_val_ptr() {
        let mut dense = vec![0.0f32; 9 * 1 * 2];
        dense[0] = 1.0;
        dense[2] = 2.0;
        let mut pat = PatternMatrix::from_dense(&dense, 3, 3, 1, 2);
        pat.val_ptr = vec![0, 1];
        assert!(pat.validate().is_err());
    }

    #[test]
    fn prune_patterns_hits_target_density_with_small_library() {
        let (kh, kw, cin, cols) = (3usize, 3usize, 8usize, 32usize);
        let mut rng = Rng::new(11);
        let mut mat = vec![0.0f32; kh * kw * cin * cols];
        rng.fill_normal(&mut mat, 0.5);
        let sparsity = 0.8;
        prune_patterns(&mut mat, kh, kw, cin, cols, sparsity, 4, 8);
        let nnz = mat.iter().filter(|v| **v != 0.0).count();
        let target = ((mat.len() as f64) * (1.0 - sparsity)).round() as usize;
        let rel = (nnz as f64 - target as f64).abs() / target as f64;
        assert!(rel < 0.01, "achieved nnz {nnz} vs target {target} ({rel:.4})");
        // every surviving kernel uses one of <= 8 patterns of exactly 4 entries
        let pat = PatternMatrix::from_dense(&mat, kh, kw, cin, cols);
        pat.validate().unwrap();
        assert!(pat.patterns() <= 8, "library leaked: {} patterns", pat.patterns());
        for p in 0..pat.patterns() {
            assert_eq!((pat.pat_ptr[p + 1] - pat.pat_ptr[p]), 4);
        }
        assert_eq!(pat.nnz(), nnz);
    }

    #[test]
    fn prune_patterns_saturates_at_entry_ceiling() {
        // requested density above entries/kk: every kernel survives
        let (kh, kw, cin, cols) = (3usize, 3usize, 2usize, 4usize);
        let mut rng = Rng::new(3);
        let mut mat = vec![0.0f32; kh * kw * cin * cols];
        rng.fill_normal(&mut mat, 0.5);
        prune_patterns(&mut mat, kh, kw, cin, cols, 0.2, 4, 8);
        let nnz = mat.iter().filter(|v| **v != 0.0).count();
        assert_eq!(nnz, 4 * cin * cols, "all kernels kept at 4 entries each");
    }

    #[test]
    fn prop_roundtrip_matches_csr_and_counts() {
        prop::check_n("pattern roundtrip", 64, |rng: &mut Rng| {
            let kh = [1usize, 2, 3][rng.below(3)];
            let kw = [1usize, 2, 3][rng.below(3)];
            let cin = rng.range(1, 9);
            let cols = rng.range(1, 17);
            let density = rng.f64();
            let dense = random_sparse(rng, kh * kw * cin * cols, density);
            let pat = PatternMatrix::from_dense(&dense, kh, kw, cin, cols);
            pat.validate()?;
            prop_assert!(pat.to_dense() == dense, "roundtrip mismatch");
            let csr = CsrMatrix::from_dense(&dense, kh * kw * cin, cols);
            prop_assert!(pat.nnz() == csr.nnz(), "nnz {} vs csr {}", pat.nnz(), csr.nnz());
            let via_csr = PatternMatrix::from_csr(&csr, kh, kw, cin);
            prop_assert!(via_csr == pat, "from_csr disagrees with from_dense");
            prop_assert!(
                count_kernels(&csr, cin) == pat.kernels(),
                "count_kernels {} vs stored {}",
                count_kernels(&csr, cin),
                pat.kernels()
            );
            prop_assert!(pat.to_csr() == csr, "to_csr mismatch");
            Ok(())
        });
    }

    #[test]
    fn disk_bytes_beat_csr_on_pattern_pruned_kernels() {
        // pattern-pruned 3x3 layer: one index + one id per 4 values vs
        // CSR's one index per value — pattern must be smaller even with
        // the table accounted
        let (kh, kw, cin, cols) = (3usize, 3usize, 16usize, 64usize);
        let mut rng = Rng::new(7);
        let mut mat = vec![0.0f32; kh * kw * cin * cols];
        rng.fill_normal(&mut mat, 0.5);
        prune_patterns(&mut mat, kh, kw, cin, cols, 0.8, 4, 8);
        let csr = CsrMatrix::from_dense(&mat, kh * kw * cin, cols);
        let pat = PatternMatrix::from_dense(&mat, kh, kw, cin, cols);
        assert!(
            pat.bytes_on_disk_idx16(32) < csr.bytes_on_disk_idx16(32),
            "pattern {} vs csr {}",
            pat.bytes_on_disk_idx16(32),
            csr.bytes_on_disk_idx16(32)
        );
    }
}
