//! Model compression representation and accounting (paper §3).
//!
//! The ADMM *training* lives in python (build-time); this module owns the
//! deployment-side artifacts of compression:
//! - per-layer sparsity profiles (paper-prescribed, or imported from
//!   `artifacts/compress_report.json` produced by the python run),
//! - the CSR encoding the element-granular CPU execution path uses,
//! - the BSR block format + filter-kernel reordering the structured
//!   execution path uses (see `docs/FORMATS.md`),
//! - the PatDNN pattern format (per-kernel canonical patterns + shared
//!   pattern table) and its structured pruners (`docs/PIPELINE.md`),
//! - k-bit codebook quantization metadata, and the quantized sparse
//!   payloads (`qsparse`) that pack every format's value array behind a
//!   shared codebook for the LUT execution path (`kernels::lut`),
//! - storage accounting that regenerates the §3 compression-rate and
//!   storage-reduction claims and Table 2 sizes.

pub mod bsr;
pub mod csr;
pub mod pattern;
pub mod profile;
pub mod qsparse;
pub mod quant;
pub mod reorder;
pub mod size;

pub use bsr::BsrMatrix;
pub use csr::CsrMatrix;
pub use pattern::PatternMatrix;
pub use profile::{PruneStructure, SparsityProfile, paper_profile};
pub use qsparse::{QBsr, QCsr, QPattern, QSparseMatrix, QuantizedValues, ValueBits};
pub use quant::QuantizedTensor;
pub use reorder::Permutation;
