//! BSR (block compressed sparse row) weight matrices — the structured
//! sparse format of the PatDNN-style execution path. Blocks are (br x bc)
//! tiles over the same row-major (K, N) weight view as [`CsrMatrix`]:
//! row = input feature, col = output channel.
//!
//! A block is stored iff it contains at least one nonzero; stored blocks
//! are dense (padding slots hold explicit zeros), so the micro-kernel
//! streams contiguous `br * bc` value runs with one column index per
//! block instead of one per element. The price is padding: the
//! [`BsrMatrix::fill_ratio`] (true nonzeros / stored values) quantifies
//! it, and the planner's cost model decides when the contiguity win pays
//! for the padded work (see `docs/FORMATS.md`).

use crate::compress::csr::CsrMatrix;
use crate::error::CadnnError;

/// Block-CSR with u32 block-column indices. Logical shape is
/// (`rows`, `cols`); the block grid is `ceil(rows/br) x ceil(cols/bc)`
/// with edge blocks zero-padded.
///
/// # Examples
///
/// ```
/// use cadnn::compress::bsr::BsrMatrix;
///
/// // one fully dense 4x4 block in an 8x8 matrix
/// let mut dense = vec![0.0f32; 64];
/// for r in 0..4 {
///     for c in 4..8 {
///         dense[r * 8 + c] = 1.0;
///     }
/// }
/// let bsr = BsrMatrix::from_dense(&dense, 8, 8, 4, 4);
/// assert_eq!(bsr.blocks(), 1);
/// assert_eq!(bsr.fill_ratio(), 1.0);      // no padding stored
/// assert_eq!(bsr.to_dense(), dense);      // lossless round-trip
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct BsrMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Block height (rows per block, along the K reduction axis).
    pub br: usize,
    /// Block width (cols per block, along the N output axis).
    pub bc: usize,
    /// Block-row pointers, length `ceil(rows/br) + 1`.
    pub row_ptr: Vec<u32>,
    /// Block-column index (grid coordinate, not element column) per block.
    pub col_idx: Vec<u32>,
    /// Stored blocks, `br * bc` row-major values each; padding is 0.0.
    pub values: Vec<f32>,
    /// True nonzero count (padding excluded) — fill accounting.
    nnz: usize,
}

impl BsrMatrix {
    /// Encode from a dense row-major matrix. Blocks with no nonzero are
    /// dropped; everything else is stored dense (zero-padded at edges).
    pub fn from_dense(dense: &[f32], rows: usize, cols: usize, br: usize, bc: usize) -> Self {
        assert!(br > 0 && bc > 0, "block dims must be nonzero");
        assert_eq!(dense.len(), rows * cols);
        let nbr = rows.div_ceil(br);
        let nbc = cols.div_ceil(bc);
        let mut row_ptr = Vec::with_capacity(nbr + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        let mut nnz = 0usize;
        let mut block = vec![0.0f32; br * bc];
        row_ptr.push(0u32);
        for b in 0..nbr {
            let r0 = b * br;
            let rl = br.min(rows - r0);
            for j in 0..nbc {
                let c0 = j * bc;
                let cl = bc.min(cols - c0);
                block.fill(0.0);
                let mut block_nnz = 0usize;
                for p in 0..rl {
                    let row = &dense[(r0 + p) * cols + c0..(r0 + p) * cols + c0 + cl];
                    for (x, &v) in row.iter().enumerate() {
                        if v != 0.0 {
                            block_nnz += 1;
                        }
                        block[p * bc + x] = v;
                    }
                }
                if block_nnz > 0 {
                    nnz += block_nnz;
                    col_idx.push(j as u32);
                    values.extend_from_slice(&block);
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        BsrMatrix { rows, cols, br, bc, row_ptr, col_idx, values, nnz }
    }

    /// Re-encode an element-granular CSR matrix into blocks.
    pub fn from_csr(csr: &CsrMatrix, br: usize, bc: usize) -> Self {
        Self::from_dense(&csr.to_dense(), csr.rows, csr.cols, br, bc)
    }

    /// Reassemble from raw structure + value arrays (the quantized
    /// payload's dequantization path — `compress::qsparse::QBsr`
    /// round-trips through this). The true-nonzero count is recomputed
    /// from the values; `validate` checks the rest.
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        rows: usize,
        cols: usize,
        br: usize,
        bc: usize,
        row_ptr: Vec<u32>,
        col_idx: Vec<u32>,
        values: Vec<f32>,
    ) -> Self {
        let nnz = values.iter().filter(|v| **v != 0.0).count();
        BsrMatrix { rows, cols, br, bc, row_ptr, col_idx, values, nnz }
    }

    /// Stored blocks.
    pub fn blocks(&self) -> usize {
        self.col_idx.len()
    }

    /// Block rows in the grid.
    pub fn block_rows(&self) -> usize {
        self.row_ptr.len() - 1
    }

    /// Stored values including padding (`blocks * br * bc`).
    pub fn stored(&self) -> usize {
        self.values.len()
    }

    /// True nonzeros (padding excluded).
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// nnz / stored — 1.0 means perfectly block-aligned sparsity, low
    /// values mean the format is paying for padded zeros.
    pub fn fill_ratio(&self) -> f64 {
        self.nnz as f64 / self.stored().max(1) as f64
    }

    /// True-nonzero density over the logical matrix.
    pub fn density(&self) -> f64 {
        self.nnz as f64 / (self.rows * self.cols).max(1) as f64
    }

    /// Decode back to dense row-major (padding vanishes).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for b in 0..self.block_rows() {
            let r0 = b * self.br;
            let rl = self.br.min(self.rows - r0);
            let (s, e) = (self.row_ptr[b] as usize, self.row_ptr[b + 1] as usize);
            for bi in s..e {
                let c0 = self.col_idx[bi] as usize * self.bc;
                let cl = self.bc.min(self.cols - c0);
                let vals = &self.values[bi * self.br * self.bc..];
                for p in 0..rl {
                    for x in 0..cl {
                        out[(r0 + p) * self.cols + c0 + x] = vals[p * self.bc + x];
                    }
                }
            }
        }
        out
    }

    /// In-memory bytes (u32 row_ptr + u32 block col_idx + f32 values).
    pub fn bytes_in_memory(&self) -> usize {
        4 * (self.row_ptr.len() + self.col_idx.len() + self.values.len())
    }

    /// On-disk bytes with 16-bit block-column indices and `value_bits`-bit
    /// values — one index per block is where BSR beats CSR on storage.
    pub fn bytes_on_disk_idx16(&self, value_bits: usize) -> usize {
        self.row_ptr.len() * 4
            + self.col_idx.len() * 2
            + (self.values.len() * value_bits).div_ceil(8)
    }

    /// Structural validation (used by property tests).
    pub fn validate(&self) -> Result<(), CadnnError> {
        let invalid = |reason: String| CadnnError::InvalidCsr { reason: format!("bsr: {reason}") };
        if self.br == 0 || self.bc == 0 {
            return Err(invalid("zero block dims".into()));
        }
        if self.row_ptr.len() != self.rows.div_ceil(self.br) + 1 {
            return Err(invalid("row_ptr length".into()));
        }
        if *self.row_ptr.last().unwrap() as usize != self.col_idx.len() {
            return Err(invalid("row_ptr tail".into()));
        }
        if self.values.len() != self.col_idx.len() * self.br * self.bc {
            return Err(invalid("values length".into()));
        }
        if self.nnz > self.values.len() {
            return Err(invalid("nnz exceeds stored values".into()));
        }
        let nbc = self.cols.div_ceil(self.bc);
        for b in 0..self.block_rows() {
            let (s, e) = (self.row_ptr[b] as usize, self.row_ptr[b + 1] as usize);
            if s > e {
                return Err(invalid(format!("block row {b} ptr not monotone")));
            }
            if e > self.col_idx.len() {
                return Err(invalid(format!("block row {b} ptr out of range")));
            }
            let mut prev: i64 = -1;
            for bi in s..e {
                let j = self.col_idx[bi] as i64;
                if j <= prev {
                    return Err(invalid(format!("block row {b} cols not strictly increasing")));
                }
                if j as usize >= nbc {
                    return Err(invalid(format!("block row {b} col out of range")));
                }
                prev = j;
            }
        }
        let true_nnz = self.values.iter().filter(|v| **v != 0.0).count();
        if true_nnz != self.nnz {
            return Err(invalid(format!("nnz {} != counted {true_nnz}", self.nnz)));
        }
        Ok(())
    }
}

/// Stored-block count a `(br x bc)` BSR encoding of `csr` would have —
/// O(nnz), no densification. The planner's fill estimator.
///
/// # Examples
///
/// ```
/// use cadnn::compress::bsr::{count_blocks, BsrMatrix};
/// use cadnn::compress::csr::CsrMatrix;
///
/// let mut dense = vec![0.0f32; 8 * 8];
/// dense[0] = 1.0;      // block (0, 0)
/// dense[5 * 8 + 7] = 2.0; // block (1, 1)
/// let csr = CsrMatrix::from_dense(&dense, 8, 8);
/// assert_eq!(count_blocks(&csr, 4, 4), 2);
/// // the estimate always matches what the encoder stores
/// assert_eq!(count_blocks(&csr, 4, 4), BsrMatrix::from_csr(&csr, 4, 4).blocks());
/// ```
pub fn count_blocks(csr: &CsrMatrix, br: usize, bc: usize) -> usize {
    count_blocks_impl(csr, br, bc, None)
}

/// [`count_blocks`] after applying a column permutation
/// (`col_to_new[old] = new`) — the planner's reorder-gain estimator.
/// Shares the counting loop with [`count_blocks`] so estimate and
/// encoder can't drift apart.
pub fn count_blocks_mapped(csr: &CsrMatrix, br: usize, bc: usize, col_to_new: &[u32]) -> usize {
    count_blocks_impl(csr, br, bc, Some(col_to_new))
}

fn count_blocks_impl(csr: &CsrMatrix, br: usize, bc: usize, map: Option<&[u32]>) -> usize {
    let nbr = csr.rows.div_ceil(br);
    let nbc = csr.cols.div_ceil(bc);
    let mut seen = vec![false; nbc];
    let mut touched: Vec<u32> = Vec::new();
    let mut total = 0usize;
    for b in 0..nbr {
        let r0 = b * br;
        let r1 = (r0 + br).min(csr.rows);
        for r in r0..r1 {
            let (s, e) = (csr.row_ptr[r] as usize, csr.row_ptr[r + 1] as usize);
            for idx in s..e {
                let col = csr.col_idx[idx] as usize;
                let col = match map {
                    Some(m) => m[col] as usize,
                    None => col,
                };
                let j = col / bc;
                if !seen[j] {
                    seen[j] = true;
                    touched.push(j as u32);
                }
            }
        }
        total += touched.len();
        for &j in &touched {
            seen[j as usize] = false;
        }
        touched.clear();
    }
    total
}

/// Block-structured pruning of a dense (rows x cols) matrix, in place —
/// the native-engine analogue of `python/compile/admm.py`'s
/// `project_prune_block` z-step. Tiles are ranked by Frobenius norm and
/// kept greedily (highest first, edge tiles at their true size) until
/// the surviving element count is as close as possible to
/// `round(len * (1 - sparsity))`; every other tile is zeroed whole, so
/// the surviving support is exactly `(br x bc)`-block-aligned and the
/// achieved density stays within one tile of the request. Deterministic:
/// ties break by tile index.
pub fn prune_blocks(
    mat: &mut [f32],
    rows: usize,
    cols: usize,
    br: usize,
    bc: usize,
    sparsity: f64,
) {
    assert!(br > 0 && bc > 0, "block dims must be nonzero");
    assert_eq!(mat.len(), rows * cols);
    if sparsity <= 0.0 || mat.is_empty() {
        return;
    }
    // floor of one element: like the element projection, extreme
    // sparsity keeps the single best tile instead of zeroing the layer
    let target = (((mat.len() as f64) * (1.0 - sparsity)).round() as usize).max(1);
    let (nbr, nbc) = (rows.div_ceil(br), cols.div_ceil(bc));
    // rank tiles by squared Frobenius norm (same order as by norm)
    let mut tiles: Vec<(f64, usize)> = Vec::with_capacity(nbr * nbc);
    for b in 0..nbr {
        for j in 0..nbc {
            let (r0, c0) = (b * br, j * bc);
            let (rl, cl) = (br.min(rows - r0), bc.min(cols - c0));
            let mut norm2 = 0.0f64;
            for p in 0..rl {
                for x in 0..cl {
                    let v = mat[(r0 + p) * cols + c0 + x] as f64;
                    norm2 += v * v;
                }
            }
            tiles.push((norm2, b * nbc + j));
        }
    }
    tiles.sort_by(|a, b| {
        b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
    });
    // greedy keep until the next tile would overshoot more than it
    // helps; the best tile always survives (a nonzero target must not
    // zero the whole layer)
    let mut keep = vec![false; nbr * nbc];
    let mut kept = 0usize;
    for &(_, t) in &tiles {
        let (b, j) = (t / nbc, t % nbc);
        let size = br.min(rows - b * br) * bc.min(cols - j * bc);
        if kept >= target {
            break;
        }
        if kept > 0 && kept + size > target && (kept + size - target) > (target - kept) {
            break;
        }
        keep[t] = true;
        kept += size;
    }
    for b in 0..nbr {
        for j in 0..nbc {
            if keep[b * nbc + j] {
                continue;
            }
            let (r0, c0) = (b * br, j * bc);
            let (rl, cl) = (br.min(rows - r0), bc.min(cols - c0));
            for p in 0..rl {
                for x in 0..cl {
                    mat[(r0 + p) * cols + c0 + x] = 0.0;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_sparse(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Vec<f32> {
        let mut dense = vec![0.0f32; rows * cols];
        for v in dense.iter_mut() {
            if rng.f64() < density {
                *v = rng.normal() as f32;
            }
        }
        dense
    }

    #[test]
    fn roundtrip_small_4x1() {
        // 6x3 with one dense column stripe
        let mut dense = vec![0.0f32; 18];
        for r in 0..6 {
            dense[r * 3 + 1] = (r + 1) as f32;
        }
        let bsr = BsrMatrix::from_dense(&dense, 6, 3, 4, 1);
        bsr.validate().unwrap();
        assert_eq!(bsr.nnz(), 6);
        assert_eq!(bsr.blocks(), 2); // two block rows, one block each
        assert_eq!(bsr.to_dense(), dense);
    }

    #[test]
    fn edge_blocks_are_padded_not_truncated() {
        // 5x5 with 4x4 blocks: grid is 2x2, edges padded
        let dense: Vec<f32> = (1..=25).map(|v| v as f32).collect();
        let bsr = BsrMatrix::from_dense(&dense, 5, 5, 4, 4);
        bsr.validate().unwrap();
        assert_eq!(bsr.blocks(), 4);
        assert_eq!(bsr.stored(), 4 * 16);
        assert_eq!(bsr.nnz(), 25);
        assert_eq!(bsr.to_dense(), dense);
        assert!((bsr.fill_ratio() - 25.0 / 64.0).abs() < 1e-12);
    }

    #[test]
    fn validate_rejects_out_of_range_row_ptr() {
        // intermediate row_ptr beyond col_idx: must Err, not panic
        let mut bsr = BsrMatrix::from_dense(&vec![1.0; 8 * 4], 8, 4, 4, 4);
        bsr.row_ptr = vec![0, 5, 2];
        assert!(bsr.validate().is_err());
    }

    #[test]
    fn all_zero_matrix_stores_nothing() {
        let bsr = BsrMatrix::from_dense(&vec![0.0; 12 * 8], 12, 8, 4, 4);
        bsr.validate().unwrap();
        assert_eq!(bsr.blocks(), 0);
        assert_eq!(bsr.nnz(), 0);
        assert_eq!(bsr.to_dense(), vec![0.0; 96]);
    }

    #[test]
    fn disk_bytes_prefer_bsr_on_block_structure() {
        // one fully dense 4x4 block in a 16x16 matrix
        let mut dense = vec![0.0f32; 256];
        for r in 4..8 {
            for c in 8..12 {
                dense[r * 16 + c] = 1.0;
            }
        }
        let csr = CsrMatrix::from_dense(&dense, 16, 16);
        let bsr = BsrMatrix::from_dense(&dense, 16, 16, 4, 4);
        assert_eq!(bsr.blocks(), 1);
        assert_eq!(bsr.fill_ratio(), 1.0);
        // same value payload, 16x fewer column indices
        assert!(bsr.bytes_on_disk_idx16(32) < csr.bytes_on_disk_idx16(32));
    }

    #[test]
    fn prune_blocks_is_block_aligned_and_density_exact() {
        let (k, n) = (64usize, 32usize);
        let mut rng = Rng::new(9);
        let mut mat = vec![0.0f32; k * n];
        rng.fill_normal(&mut mat, 0.5);
        let sparsity = 0.75;
        prune_blocks(&mut mat, k, n, 4, 4, sparsity);
        let nnz = mat.iter().filter(|v| **v != 0.0).count();
        let target = ((mat.len() as f64) * (1.0 - sparsity)).round() as usize;
        let rel = (nnz as f64 - target as f64).abs() / target as f64;
        assert!(rel < 0.01, "achieved nnz {nnz} vs target {target}");
        // surviving support is exactly block-aligned: fill ratio 1.0
        let bsr = BsrMatrix::from_dense(&mat, k, n, 4, 4);
        assert_eq!(bsr.fill_ratio(), 1.0, "non-block-aligned survivor");
        assert_eq!(bsr.nnz(), nnz);
    }

    #[test]
    fn prop_roundtrip_matches_csr_and_counts() {
        prop::check_n("bsr roundtrip", 64, |rng: &mut Rng| {
            let rows = rng.range(1, 40);
            let cols = rng.range(1, 40);
            let br = [1usize, 2, 4, 8][rng.below(4)];
            let bc = [1usize, 2, 4][rng.below(3)];
            let density = rng.f64();
            let dense = random_sparse(rng, rows, cols, density);
            let bsr = BsrMatrix::from_dense(&dense, rows, cols, br, bc);
            bsr.validate()?;
            prop_assert!(bsr.to_dense() == dense, "roundtrip mismatch");
            let csr = CsrMatrix::from_dense(&dense, rows, cols);
            prop_assert!(bsr.nnz() == csr.nnz(), "nnz {} vs csr {}", bsr.nnz(), csr.nnz());
            let via_csr = BsrMatrix::from_csr(&csr, br, bc);
            prop_assert!(via_csr == bsr, "from_csr disagrees with from_dense");
            prop_assert!(
                count_blocks(&csr, br, bc) == bsr.blocks(),
                "count_blocks {} vs stored {}",
                count_blocks(&csr, br, bc),
                bsr.blocks()
            );
            let ident: Vec<u32> = (0..cols as u32).collect();
            prop_assert!(
                count_blocks_mapped(&csr, br, bc, &ident) == bsr.blocks(),
                "identity map changed the block count"
            );
            prop_assert!(bsr.stored() >= bsr.nnz(), "stored < nnz");
            Ok(())
        });
    }
}
