//! Filter-kernel-style reordering (PatDNN §"filter kernel reorder").
//!
//! Pruned weight matrices rarely have block structure by accident; what
//! they do have is output channels (columns of the (K, N) weight view)
//! with *similar* support. Permuting columns so similar ones sit in the
//! same (br x bc) block raises the BSR fill ratio without changing the
//! computed function: the permutation is carried next to the weights, the
//! per-channel epilogue parameters are permuted with it, and the output
//! columns are scattered back through the inverse permutation after the
//! kernel runs. Because a column permutation never changes the reduction
//! order over K for any output element, the restored output is
//! bit-identical to the unreordered execution (property-tested in
//! `kernels::bsr`).

use crate::compress::csr::CsrMatrix;
use crate::error::CadnnError;

/// A column (output-channel) permutation: `perm[new] = old`, i.e. column
/// `new` of the reordered matrix is column `perm[new]` of the original.
///
/// # Examples
///
/// ```
/// use cadnn::compress::reorder::Permutation;
///
/// let p = Permutation { perm: vec![2, 0, 3, 1] };
/// p.validate().unwrap();
/// let inv = p.inverse();
/// // inverse composes back to the identity: perm[inv[old]] == old
/// for old in 0..4u32 {
///     assert_eq!(p.perm[inv.perm[old as usize] as usize], old);
/// }
/// assert!(Permutation::identity(4).is_identity());
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Permutation {
    pub perm: Vec<u32>,
}

impl Permutation {
    pub fn identity(n: usize) -> Self {
        Permutation { perm: (0..n as u32).collect() }
    }

    pub fn len(&self) -> usize {
        self.perm.len()
    }

    pub fn is_empty(&self) -> bool {
        self.perm.is_empty()
    }

    pub fn is_identity(&self) -> bool {
        self.perm.iter().enumerate().all(|(i, &p)| i as u32 == p)
    }

    /// The inverse mapping: `inv[old] = new`.
    pub fn inverse(&self) -> Permutation {
        let mut inv = vec![0u32; self.perm.len()];
        for (new, &old) in self.perm.iter().enumerate() {
            inv[old as usize] = new as u32;
        }
        Permutation { perm: inv }
    }

    /// Check this is a bijection over 0..len.
    pub fn validate(&self) -> Result<(), CadnnError> {
        let n = self.perm.len();
        let mut seen = vec![false; n];
        for &p in &self.perm {
            let p = p as usize;
            if p >= n || seen[p] {
                return Err(CadnnError::InvalidCsr {
                    reason: format!("reorder: not a permutation of 0..{n}"),
                });
            }
            seen[p] = true;
        }
        Ok(())
    }
}

/// Cluster the columns of a dense (rows x cols) matrix by their support
/// signature over `block_rows`-row stripes: columns whose nonzeros live in
/// the same stripes sort together, so a (block_rows x bc) BSR encoding of
/// the permuted matrix stores fewer, fuller blocks. Deterministic.
///
/// # Examples
///
/// ```
/// use cadnn::compress::reorder::{cluster_columns, permute_cols, unpermute_cols_inplace};
///
/// // 8x4: columns 0/2 live in the top stripe, columns 1/3 in the bottom
/// let mut dense = vec![0.0f32; 32];
/// for r in 0..4 {
///     dense[r * 4] = 1.0;
///     dense[r * 4 + 2] = 1.0;
/// }
/// for r in 4..8 {
///     dense[r * 4 + 1] = 1.0;
///     dense[r * 4 + 3] = 1.0;
/// }
/// let p = cluster_columns(&dense, 8, 4, 4);
/// // permute, then scatter back: identity
/// let mut reordered = permute_cols(&dense, 8, 4, &p);
/// unpermute_cols_inplace(&mut reordered, 8, 4, &p);
/// assert_eq!(reordered, dense);
/// ```
pub fn cluster_columns(dense: &[f32], rows: usize, cols: usize, block_rows: usize) -> Permutation {
    assert_eq!(dense.len(), rows * cols);
    let sigs = column_signatures(
        cols,
        rows.div_ceil(block_rows),
        (0..rows).flat_map(|r| {
            let row = &dense[r * cols..(r + 1) * cols];
            let b = r / block_rows;
            row.iter()
                .enumerate()
                .filter(|(_, v)| **v != 0.0)
                .map(move |(c, _)| (b, c))
        }),
    );
    order_by_signature(sigs)
}

/// [`cluster_columns`] straight from a CSR encoding (no densification) —
/// what the planner uses to estimate reorder benefit.
pub fn cluster_columns_csr(csr: &CsrMatrix, block_rows: usize) -> Permutation {
    let sigs = column_signatures(
        csr.cols,
        csr.rows.div_ceil(block_rows),
        (0..csr.rows).flat_map(|r| {
            let (s, e) = (csr.row_ptr[r] as usize, csr.row_ptr[r + 1] as usize);
            let b = r / block_rows;
            csr.col_idx[s..e].iter().map(move |&c| (b, c as usize))
        }),
    );
    order_by_signature(sigs)
}

/// Per-column occupancy bitmask over `stripes` block-row stripes, from a
/// (stripe, col) stream of nonzero positions.
fn column_signatures(
    cols: usize,
    stripes: usize,
    nonzeros: impl Iterator<Item = (usize, usize)>,
) -> Vec<Vec<u64>> {
    let words = stripes.div_ceil(64).max(1);
    let mut sigs = vec![vec![0u64; words]; cols];
    for (stripe, col) in nonzeros {
        sigs[col][stripe / 64] |= 1u64 << (stripe % 64);
    }
    sigs
}

/// Stable order: group identical signatures, then by descending stripe
/// count so dense columns cluster at the front; ties broken by original
/// index for determinism.
fn order_by_signature(sigs: Vec<Vec<u64>>) -> Permutation {
    let pop = |s: &[u64]| s.iter().map(|w| w.count_ones()).sum::<u32>();
    let mut order: Vec<u32> = (0..sigs.len() as u32).collect();
    order.sort_by(|&a, &b| {
        let (sa, sb) = (&sigs[a as usize], &sigs[b as usize]);
        pop(sb).cmp(&pop(sa)).then_with(|| sa.cmp(sb)).then(a.cmp(&b))
    });
    Permutation { perm: order }
}

/// Apply a column permutation to a dense (rows x cols) matrix:
/// `out[:, new] = dense[:, perm[new]]`.
pub fn permute_cols(dense: &[f32], rows: usize, cols: usize, p: &Permutation) -> Vec<f32> {
    assert_eq!(dense.len(), rows * cols);
    assert_eq!(p.len(), cols);
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let src = &dense[r * cols..(r + 1) * cols];
        let dst = &mut out[r * cols..(r + 1) * cols];
        for (new, &old) in p.perm.iter().enumerate() {
            dst[new] = src[old as usize];
        }
    }
    out
}

/// Scatter permuted output columns back to their original positions, in
/// place: `data[:, perm[j]] = data[:, j]` for every row of the
/// (rows x cols) buffer. Used on kernel outputs computed against
/// column-permuted weights.
pub fn unpermute_cols_inplace(data: &mut [f32], rows: usize, cols: usize, p: &Permutation) {
    assert_eq!(data.len(), rows * cols);
    assert_eq!(p.len(), cols);
    let mut buf = vec![0.0f32; cols];
    for r in 0..rows {
        let row = &mut data[r * cols..(r + 1) * cols];
        buf.copy_from_slice(row);
        for (new, &old) in p.perm.iter().enumerate() {
            row[old as usize] = buf[new];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::bsr::BsrMatrix;
    use crate::prop_assert;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn identity_roundtrip() {
        let p = Permutation::identity(5);
        assert!(p.is_identity());
        p.validate().unwrap();
        assert_eq!(p.inverse().perm, p.perm);
    }

    #[test]
    fn inverse_composes_to_identity() {
        let p = Permutation { perm: vec![2, 0, 3, 1] };
        p.validate().unwrap();
        let inv = p.inverse();
        for old in 0..4u32 {
            assert_eq!(p.perm[inv.perm[old as usize] as usize], old);
        }
    }

    #[test]
    fn validate_rejects_non_bijections() {
        assert!(Permutation { perm: vec![0, 0, 1] }.validate().is_err());
        assert!(Permutation { perm: vec![0, 5] }.validate().is_err());
    }

    #[test]
    fn clustering_groups_equal_support_columns() {
        // 8x4; columns 0 and 2 live in stripe 0, columns 1 and 3 in
        // stripe 1 — clustering must make the pairs adjacent.
        let mut dense = vec![0.0f32; 32];
        for r in 0..4 {
            dense[r * 4] = 1.0;
            dense[r * 4 + 2] = 1.0;
        }
        for r in 4..8 {
            dense[r * 4 + 1] = 1.0;
            dense[r * 4 + 3] = 1.0;
        }
        let p = cluster_columns(&dense, 8, 4, 4);
        p.validate().unwrap();
        let pos = p.inverse();
        let adjacent = |a: usize, b: usize| {
            (pos.perm[a] as i64 - pos.perm[b] as i64).abs() == 1
        };
        assert!(adjacent(0, 2), "perm {:?}", p.perm);
        assert!(adjacent(1, 3), "perm {:?}", p.perm);
        // reordered 4x2 blocks: 2 stored instead of 4
        let reordered = permute_cols(&dense, 8, 4, &p);
        let bsr = BsrMatrix::from_dense(&reordered, 8, 4, 4, 2);
        assert_eq!(bsr.blocks(), 2);
        assert_eq!(BsrMatrix::from_dense(&dense, 8, 4, 4, 2).blocks(), 4);
    }

    #[test]
    fn csr_clustering_matches_dense_clustering() {
        let mut rng = Rng::new(3);
        let mut dense = vec![0.0f32; 24 * 10];
        for v in dense.iter_mut() {
            if rng.f64() < 0.3 {
                *v = rng.normal() as f32;
            }
        }
        let csr = crate::compress::csr::CsrMatrix::from_dense(&dense, 24, 10);
        assert_eq!(cluster_columns(&dense, 24, 10, 4).perm, cluster_columns_csr(&csr, 4).perm);
    }

    #[test]
    fn prop_permute_then_unpermute_is_identity() {
        prop::check_n("reorder roundtrip", 64, |rng: &mut Rng| {
            let rows = rng.range(1, 12);
            let cols = rng.range(1, 20);
            let mut dense = vec![0.0f32; rows * cols];
            for v in dense.iter_mut() {
                if rng.f64() < 0.5 {
                    *v = rng.normal() as f32;
                }
            }
            let p = cluster_columns(&dense, rows, cols, 4);
            p.validate()?;
            let mut permuted = permute_cols(&dense, rows, cols, &p);
            unpermute_cols_inplace(&mut permuted, rows, cols, &p);
            prop_assert!(permuted == dense, "permute/unpermute not identity");
            Ok(())
        });
    }
}
