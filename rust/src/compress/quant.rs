//! k-bit codebook quantization (deployment side of the paper's unified
//! ADMM prune+quantize). Values are symmetric uniform levels
//! (-(2^(b-1)-1) .. 2^(b-1)-1) * step; zero is preserved so the pruning
//! support survives — matching python/compile/admm.py's projection.

#[derive(Debug, Clone, PartialEq)]
pub struct QuantizedTensor {
    pub bits: u8,
    pub step: f32,
    /// Signed level per element (fits in i8 for bits <= 8).
    pub levels: Vec<i8>,
    pub shape: Vec<usize>,
}

impl QuantizedTensor {
    /// Quantize an f32 tensor to `bits` (2..=8).
    pub fn quantize(data: &[f32], shape: &[usize], bits: u8) -> Self {
        assert!((2..=8).contains(&bits));
        assert_eq!(data.len(), shape.iter().product::<usize>());
        let amax = data.iter().fold(0f32, |m, v| m.max(v.abs())).max(1e-8);
        let n = (1i32 << (bits - 1)) - 1;
        let step = amax / n as f32;
        let levels = data
            .iter()
            .map(|&v| {
                if v == 0.0 {
                    0i8
                } else {
                    ((v / step).round() as i32).clamp(-n, n) as i8
                }
            })
            .collect();
        QuantizedTensor { bits, step, levels, shape: shape.to_vec() }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        self.levels.iter().map(|&l| l as f32 * self.step).collect()
    }

    pub fn numel(&self) -> usize {
        self.levels.len()
    }

    /// Max absolute reconstruction error bound: step/2 (plus clamping,
    /// which only affects |v| > amax — impossible by construction).
    pub fn error_bound(&self) -> f32 {
        self.step * 0.5
    }

    /// Packed storage bytes for the level array (no indices).
    pub fn packed_bytes(&self) -> usize {
        (self.numel() * self.bits as usize).div_ceil(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_error_bounded() {
        let data: Vec<f32> = (-50..50).map(|i| i as f32 * 0.013).collect();
        let q = QuantizedTensor::quantize(&data, &[100], 4);
        let back = q.dequantize();
        for (a, b) in data.iter().zip(&back) {
            assert!((a - b).abs() <= q.error_bound() + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn zero_preserved() {
        let data = vec![0.0, 0.7, 0.0, -0.2];
        let q = QuantizedTensor::quantize(&data, &[4], 4);
        assert_eq!(q.levels[0], 0);
        assert_eq!(q.levels[2], 0);
    }

    #[test]
    fn packed_bytes_4bit() {
        let q = QuantizedTensor::quantize(&vec![1.0; 100], &[100], 4);
        assert_eq!(q.packed_bytes(), 50);
    }

    #[test]
    fn level_range_respected() {
        let data = vec![1.0, -1.0, 0.5];
        for bits in 2..=8u8 {
            let q = QuantizedTensor::quantize(&data, &[3], bits);
            let n = (1i32 << (bits - 1)) - 1;
            assert!(q.levels.iter().all(|&l| (l as i32).abs() <= n));
        }
    }

    #[test]
    fn prop_quantize_error_bound_random() {
        prop::check("quant error bound", |rng: &mut Rng| {
            let n = rng.range(1, 200);
            let bits = rng.range(2, 8) as u8;
            let data: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let q = QuantizedTensor::quantize(&data, &[n], bits);
            let back = q.dequantize();
            for (a, b) in data.iter().zip(&back) {
                prop_assert!(
                    (a - b).abs() <= q.error_bound() + 1e-5,
                    "err {} > bound {}",
                    (a - b).abs(),
                    q.error_bound()
                );
            }
            Ok(())
        });
    }
}
