//! CSR (compressed sparse row) weight matrices — the element-granular
//! sparse format of the paper's CPU backend. Row-major over the (K, N)
//! weight-matrix view: row = input feature, col = output channel.

use crate::error::CadnnError;

/// CSR with u32 column indices (the paper's storage accounting uses
/// 16-bit indices where N < 65536; we keep u32 in memory and account
/// 16-bit on disk where applicable).
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub values: Vec<f32>,
}

impl CsrMatrix {
    /// Encode from a dense row-major matrix, dropping exact zeros.
    pub fn from_dense(dense: &[f32], rows: usize, cols: usize) -> Self {
        assert_eq!(dense.len(), rows * cols);
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0u32);
        for r in 0..rows {
            for c in 0..cols {
                let v = dense[r * cols + c];
                if v != 0.0 {
                    col_idx.push(c as u32);
                    values.push(v);
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        CsrMatrix { rows, cols, row_ptr, col_idx, values }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols).max(1) as f64
    }

    /// Decode back to dense row-major.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            let (a, b) = (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize);
            for i in a..b {
                out[r * self.cols + self.col_idx[i] as usize] = self.values[i];
            }
        }
        out
    }

    /// In-memory bytes (u32 indices + u32 row_ptr + f32 values).
    pub fn bytes_in_memory(&self) -> usize {
        4 * (self.row_ptr.len() + self.col_idx.len() + self.values.len())
    }

    /// On-disk bytes with 16-bit column indices + 32-bit row pointers,
    /// the convention of the paper's storage discussion.
    pub fn bytes_on_disk_idx16(&self, value_bits: usize) -> usize {
        self.row_ptr.len() * 4
            + self.col_idx.len() * 2
            + (self.values.len() * value_bits).div_ceil(8)
    }

    /// Structural validation (used by property tests).
    pub fn validate(&self) -> Result<(), CadnnError> {
        let invalid = |reason: String| CadnnError::InvalidCsr { reason };
        if self.row_ptr.len() != self.rows + 1 {
            return Err(invalid("row_ptr length".into()));
        }
        if *self.row_ptr.last().unwrap() as usize != self.values.len() {
            return Err(invalid("row_ptr tail".into()));
        }
        if self.col_idx.len() != self.values.len() {
            return Err(invalid("idx/val length mismatch".into()));
        }
        for r in 0..self.rows {
            let (a, b) = (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize);
            if a > b {
                return Err(invalid(format!("row {r} ptr not monotone")));
            }
            if b > self.col_idx.len() {
                return Err(invalid(format!("row {r} ptr out of range")));
            }
            let mut prev: i64 = -1;
            for i in a..b {
                let c = self.col_idx[i] as i64;
                if c <= prev {
                    return Err(invalid(format!("row {r} columns not strictly increasing")));
                }
                if c as usize >= self.cols {
                    return Err(invalid(format!("row {r} column out of range")));
                }
                prev = c;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_small() {
        let dense = vec![0.0, 1.5, 0.0, -2.0, 0.0, 0.0, 3.0, 0.0, 0.0];
        let csr = CsrMatrix::from_dense(&dense, 3, 3);
        assert_eq!(csr.nnz(), 3);
        assert_eq!(csr.to_dense(), dense);
        csr.validate().unwrap();
    }

    #[test]
    fn empty_matrix() {
        let csr = CsrMatrix::from_dense(&vec![0.0; 12], 3, 4);
        assert_eq!(csr.nnz(), 0);
        assert_eq!(csr.density(), 0.0);
        csr.validate().unwrap();
    }

    #[test]
    fn disk_bytes_formula() {
        let dense = vec![1.0; 10 * 10];
        let csr = CsrMatrix::from_dense(&dense, 10, 10);
        // 11*4 rowptr + 100*2 idx + 100*4 f32
        assert_eq!(csr.bytes_on_disk_idx16(32), 44 + 200 + 400);
        // 4-bit values: 100*4/8 = 50
        assert_eq!(csr.bytes_on_disk_idx16(4), 44 + 200 + 50);
    }

    #[test]
    fn validate_rejects_out_of_range_row_ptr() {
        // intermediate row_ptr beyond col_idx: must Err, not panic
        let mut csr = CsrMatrix::from_dense(&vec![1.0; 6], 3, 2);
        csr.row_ptr = vec![0, 9, 2, 6];
        assert!(csr.validate().is_err());
    }

    #[test]
    fn prop_roundtrip_random_sparse() {
        prop::check("csr roundtrip", |rng: &mut Rng| {
            let rows = rng.range(1, 20);
            let cols = rng.range(1, 20);
            let density = rng.f64();
            let mut dense = vec![0.0f32; rows * cols];
            for v in dense.iter_mut() {
                if rng.f64() < density {
                    *v = (rng.normal() as f32).max(f32::MIN_POSITIVE); // nonzero
                }
            }
            let csr = CsrMatrix::from_dense(&dense, rows, cols);
            csr.validate()?;
            prop_assert!(csr.to_dense() == dense, "roundtrip mismatch");
            prop_assert!(
                csr.nnz() == dense.iter().filter(|v| **v != 0.0).count(),
                "nnz mismatch"
            );
            Ok(())
        });
    }
}
