//! Per-layer sparsity profiles.
//!
//! A profile maps prunable layer names of an IR graph to sparsity in
//! [0,1) plus the *structure* the pruning imposed ([`PruneStructure`]):
//! element-granular magnitude pruning, whole (br x bc) blocks, or PatDNN
//! kernel patterns. The structure is what makes the per-layer format
//! planner's block/pattern formats win end-to-end — a sparsity fraction
//! alone cannot express it. `paper_profile` encodes the non-uniform
//! shapes the ADMM papers report (convs pruned less, FC much more),
//! scaled so the *overall* weight reduction matches the §3 claims;
//! profiles can also be imported from the python ADMM run
//! (`artifacts/compress_report.json`, whose per-layer entries carry an
//! optional `structure` label since the block/pattern projections
//! landed — see `docs/PIPELINE.md`).

use crate::ir::Graph;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// How a layer's pruning support is structured — the contract between
/// the build-time pruner (python ADMM or the native engine's generated
/// weights) and the execution planner.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PruneStructure {
    /// Scattered top-magnitude support (the paper's non-structured
    /// pruning; executes as CSR or rematerialized dense).
    #[default]
    Element,
    /// Whole (br x bc) tiles of the (K, N) weight view survive or die —
    /// the support BSR stores without padding.
    Block { br: usize, bc: usize },
    /// Each surviving kernel keeps `entries` positions from a small
    /// per-layer pattern library; whole kernels are connectivity-pruned
    /// (PatDNN) — the support the pattern format exists for.
    Pattern { entries: usize },
}

impl PruneStructure {
    /// Stable textual name (`element`, `block4x4`, `pattern4`) — the
    /// compress-report encoding.
    pub fn label(&self) -> String {
        match self {
            PruneStructure::Element => "element".to_string(),
            PruneStructure::Block { br, bc } => format!("block{br}x{bc}"),
            PruneStructure::Pattern { entries } => format!("pattern{entries}"),
        }
    }

    /// Inverse of [`PruneStructure::label`]; `None` on anything unknown
    /// (callers fall back to [`PruneStructure::Element`]).
    pub fn parse(s: &str) -> Option<PruneStructure> {
        if s == "element" {
            return Some(PruneStructure::Element);
        }
        if let Some(rest) = s.strip_prefix("block") {
            let (a, b) = rest.split_once('x')?;
            let (br, bc) = (a.parse().ok()?, b.parse().ok()?);
            if br == 0 || bc == 0 {
                return None;
            }
            return Some(PruneStructure::Block { br, bc });
        }
        if let Some(rest) = s.strip_prefix("pattern") {
            let entries: usize = rest.parse().ok()?;
            if entries == 0 {
                return None;
            }
            return Some(PruneStructure::Pattern { entries });
        }
        None
    }
}

/// A layer's exported quantization: the codebook width the python
/// unified prune+quantize run validated the layer at, plus the codebook
/// itself (informational — the native engine re-fits on its own
/// generated weights; the *width* is what drives
/// [`crate::planner::ValuePolicy::Auto`] toward a quantized payload).
#[derive(Debug, Clone, PartialEq)]
pub struct QuantSpec {
    /// Codebook index width (the report's `quant.bits`).
    pub bits: u8,
    /// Exported distinct nonzero levels (may be empty for hand-built
    /// profiles; at most `2^bits - 1` entries when exported).
    pub codebook: Vec<f32>,
}

#[derive(Debug, Clone, Default, PartialEq)]
pub struct SparsityProfile {
    /// layer name -> sparsity (fraction of weights pruned).
    pub layers: BTreeMap<String, f64>,
    /// layer name -> pruning structure; absent means
    /// [`PruneStructure::Element`].
    pub structures: BTreeMap<String, PruneStructure>,
    /// layer name -> exported codebook; absent means the layer was not
    /// quantized (f32 payload under `ValuePolicy::Auto`).
    pub quant: BTreeMap<String, QuantSpec>,
}

impl SparsityProfile {
    pub fn uniform(graph: &Graph, sparsity: f64) -> Self {
        Self::uniform_structured(graph, sparsity, PruneStructure::Element)
    }

    /// Uniform sparsity with an explicit pruning structure on every
    /// prunable layer (what `cadnn plan --pruning pattern` builds).
    pub fn uniform_structured(graph: &Graph, sparsity: f64, structure: PruneStructure) -> Self {
        let mut layers = BTreeMap::new();
        let mut structures = BTreeMap::new();
        for n in &graph.nodes {
            if n.op.prunable() {
                layers.insert(n.name.clone(), sparsity);
                if structure != PruneStructure::Element {
                    structures.insert(n.name.clone(), structure);
                }
            }
        }
        SparsityProfile { layers, structures, quant: BTreeMap::new() }
    }

    /// This profile with every pruned layer declared quantized at
    /// `bits` (empty codebooks — the engine fits its own): the
    /// hand-built analogue of a report whose layers all exported
    /// codebooks, used by `cadnn plan` and tests to drive
    /// `ValuePolicy::Auto` onto quantized payloads.
    pub fn with_uniform_quant(mut self, bits: u8) -> Self {
        let names: Vec<String> = self.layers.keys().cloned().collect();
        for name in names {
            self.quant.insert(name, QuantSpec { bits, codebook: Vec::new() });
        }
        self
    }

    pub fn get(&self, layer: &str) -> f64 {
        self.layers.get(layer).copied().unwrap_or(0.0)
    }

    /// The pruning structure recorded for a layer (Element when absent).
    pub fn structure(&self, layer: &str) -> PruneStructure {
        self.structures.get(layer).copied().unwrap_or_default()
    }

    /// The exported codebook width for a layer, if its compress report
    /// declared one — what `ValuePolicy::Auto` resolves value bits from.
    pub fn quant_bits(&self, layer: &str) -> Option<u8> {
        self.quant.get(layer).map(|q| q.bits)
    }

    /// Overall weight reduction rate over a graph: total / nnz.
    pub fn overall_rate(&self, graph: &Graph) -> f64 {
        let mut total = 0usize;
        let mut nnz = 0f64;
        for n in &graph.nodes {
            let w = n.op.weight_count();
            if w == 0 {
                continue;
            }
            total += w;
            nnz += w as f64 * (1.0 - self.get(&n.name));
        }
        total as f64 / nnz.max(1.0)
    }

    /// True when no layer carries a sparsity entry.
    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Profile layer names that match no *prunable* node of `graph` —
    /// entries the planner would silently ignore, planning Dense for the
    /// layers they were meant to cover. Imported reports (and parsed
    /// `.cadnn` hints) are keyed by layer name, so a rename on either
    /// side used to degrade to an all-Dense plan with no signal; callers
    /// ([`crate::api::EngineBuilder`], `cadnn plan`) now surface this
    /// list instead.
    pub fn unmatched_layers(&self, graph: &Graph) -> Vec<String> {
        self.layers
            .keys()
            .filter(|name| {
                !graph.nodes.iter().any(|n| n.op.prunable() && &n.name == *name)
            })
            .cloned()
            .collect()
    }

    /// Remaining (non-zero) weights over the graph.
    pub fn nnz(&self, graph: &Graph) -> usize {
        graph
            .nodes
            .iter()
            .map(|n| {
                let w = n.op.weight_count();
                (w as f64 * (1.0 - self.get(&n.name))).round() as usize
            })
            .sum()
    }

    /// Import the measured per-layer profile from compress_report.json
    /// ("measured" -> model -> "per_layer" -> {layer: {nnz, total,
    /// structure?, quant?}}). The optional `structure` label (written by
    /// the block/pattern ADMM projections) is parsed with
    /// [`PruneStructure::parse`]; unknown or absent labels degrade to
    /// element-granular, never fail the import. The optional `quant`
    /// object (`{bits, codebook}` — written by the unified
    /// prune+quantize export) is parsed into [`QuantSpec`]; malformed
    /// entries are dropped, never fail the import.
    pub fn from_report(report: &Json, model: &str) -> Option<Self> {
        let per_layer = report.get("measured")?.get(model)?.get("per_layer")?;
        let mut layers = BTreeMap::new();
        let mut structures = BTreeMap::new();
        let mut quant = BTreeMap::new();
        if let Json::Obj(kv) = per_layer {
            for (name, v) in kv {
                let nnz = v.get("nnz")?.as_f64()?;
                let total = v.get("total")?.as_f64()?;
                layers.insert(name.clone(), 1.0 - nnz / total.max(1.0));
                let s = v
                    .get("structure")
                    .and_then(|s| s.as_str())
                    .and_then(PruneStructure::parse)
                    .unwrap_or_default();
                if s != PruneStructure::Element {
                    structures.insert(name.clone(), s);
                }
                if let Some(spec) = v.get("quant").and_then(parse_quant) {
                    quant.insert(name.clone(), spec);
                }
            }
        }
        Some(SparsityProfile { layers, structures, quant })
    }
}

/// Parse one per-layer `quant` object: `bits` in 2..=8 required,
/// `codebook` an optional float array bounded by `2^bits - 1` nonzero
/// levels. Anything malformed yields `None` (the layer imports
/// unquantized — same degradation contract as unknown structure labels).
fn parse_quant(q: &Json) -> Option<QuantSpec> {
    let bits = q.get("bits")?.as_usize()?;
    if !(2..=8).contains(&bits) {
        return None;
    }
    let codebook: Vec<f32> = match q.get("codebook") {
        None => Vec::new(),
        Some(arr) => {
            let vals = arr.as_arr()?;
            if vals.len() > (1usize << bits) - 1 {
                return None;
            }
            vals.iter().map(|v| v.as_f64().map(|f| f as f32)).collect::<Option<Vec<f32>>>()?
        }
    };
    Some(QuantSpec { bits: bits as u8, codebook })
}

/// Paper-shaped profile for a named model, tuned so the overall rate
/// reproduces §3: LeNet-5 348x, AlexNet 36x, VGG-16 34x, ResNet-18 8x,
/// ResNet-50 9.2x. Conv layers keep more weights than FC layers, first
/// and last layers are pruned least — the shape every ADMM paper reports.
pub fn paper_profile(graph: &Graph) -> SparsityProfile {
    let mut layers = BTreeMap::new();
    match graph.name.as_str() {
        "lenet5" => {
            // 348x overall (~0.28% kept), per-layer shape from the
            // progressive-ADMM paper this work builds on.
            layers.insert("c1".into(), 0.93);
            layers.insert("c2".into(), 0.988);
            layers.insert("f1".into(), 0.9991);
            layers.insert("f2".into(), 0.9945);
            layers.insert("f3".into(), 0.955);
        }
        "alexnet" => {
            // 36x overall, matching Zhang et al.'s per-layer shape.
            layers.insert("conv1".into(), 0.16);
            layers.insert("conv2".into(), 0.65);
            layers.insert("conv3".into(), 0.70);
            layers.insert("conv4".into(), 0.66);
            layers.insert("conv5".into(), 0.66);
            layers.insert("fc6".into(), 0.988);
            layers.insert("fc7".into(), 0.986);
            layers.insert("fc8".into(), 0.95);
        }
        "vgg16" => {
            for (name, s) in [
                ("conv1_1", 0.42), ("conv1_2", 0.79),
                ("conv2_1", 0.78), ("conv2_2", 0.80),
                ("conv3_1", 0.77), ("conv3_2", 0.82), ("conv3_3", 0.80),
                ("conv4_1", 0.81), ("conv4_2", 0.82), ("conv4_3", 0.80),
                ("conv5_1", 0.78), ("conv5_2", 0.80), ("conv5_3", 0.78),
                ("fc6", 0.993), ("fc7", 0.99), ("fc8", 0.95),
            ] {
                layers.insert(name.into(), s);
            }
        }
        "resnet18" | "resnet50" => {
            // Residual nets have no big FC to feast on: ~8-9.2x overall
            // from uniform-ish conv pruning, stem/downsample kept denser.
            for n in &graph.nodes {
                if !n.op.prunable() {
                    continue;
                }
                let s = if n.name == "conv1" {
                    0.40
                } else if n.name == "fc" {
                    if graph.name == "resnet50" { 0.80 } else { 0.75 }
                } else if graph.name == "resnet50" {
                    0.8995
                } else {
                    0.881
                };
                layers.insert(n.name.clone(), s);
            }
        }
        // Figure 2 subjects without published per-layer tables: the
        // paper's CADNN-S variants; moderate conv pruning.
        "mobilenet_v1" | "mobilenet_v2" => {
            for n in &graph.nodes {
                if n.op.prunable() {
                    // pointwise convs tolerate more pruning than the stem
                    let s = if n.name.contains("pw") || n.name.contains("proj") || n.name.contains("exp") {
                        0.70
                    } else if n.name == "fc" {
                        0.75
                    } else {
                        0.30
                    };
                    layers.insert(n.name.clone(), s);
                }
            }
        }
        "inception_v3" => {
            for n in &graph.nodes {
                if n.op.prunable() {
                    let s = if n.name.starts_with("stem") { 0.45 } else { 0.80 };
                    layers.insert(n.name.clone(), s);
                }
            }
        }
        _ => {
            return SparsityProfile::uniform(graph, 0.5);
        }
    }
    SparsityProfile { layers, structures: BTreeMap::new(), quant: BTreeMap::new() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn uniform_profile_rate() {
        let g = models::build("lenet5", 1).unwrap();
        let p = SparsityProfile::uniform(&g, 0.9);
        assert!((p.overall_rate(&g) - 10.0).abs() < 0.2);
    }

    /// §3 pins: the paper-shaped profiles land on the claimed overall
    /// rates within 10%.
    #[test]
    fn paper_rates_reproduced() {
        for (model, claim) in [
            ("lenet5", 348.0),
            ("alexnet", 36.0),
            ("vgg16", 34.0),
            ("resnet18", 8.0),
            ("resnet50", 9.2),
        ] {
            let g = models::build(model, 1).unwrap();
            let rate = paper_profile(&g).overall_rate(&g);
            let rel = (rate - claim).abs() / claim;
            assert!(rel < 0.10, "{model}: rate {rate:.1} vs paper {claim} ({rel:.3})");
        }
    }

    #[test]
    fn profile_only_touches_prunable() {
        let g = models::build("mobilenet_v1", 1).unwrap();
        let p = paper_profile(&g);
        for name in p.layers.keys() {
            let n = g.nodes.iter().find(|n| &n.name == name).unwrap();
            assert!(n.op.prunable(), "{name} not prunable");
        }
    }

    #[test]
    fn import_from_report_json() {
        let src = r#"{"measured": {"lenet5": {"per_layer": {
            "c1": {"nnz": 50, "total": 150},
            "f1": {"nnz": 480, "total": 48000}
        }}}}"#;
        let j = Json::parse(src).unwrap();
        let p = SparsityProfile::from_report(&j, "lenet5").unwrap();
        assert!((p.get("c1") - 2.0 / 3.0).abs() < 1e-9);
        assert!((p.get("f1") - 0.99).abs() < 1e-9);
        assert_eq!(p.get("missing"), 0.0);
        assert_eq!(p.structure("c1"), PruneStructure::Element);
    }

    #[test]
    fn structure_labels_roundtrip() {
        for s in [
            PruneStructure::Element,
            PruneStructure::Block { br: 4, bc: 4 },
            PruneStructure::Pattern { entries: 4 },
        ] {
            assert_eq!(PruneStructure::parse(&s.label()), Some(s));
        }
        assert_eq!(PruneStructure::parse("block0x4"), None);
        assert_eq!(PruneStructure::parse("pattern0"), None);
        assert_eq!(PruneStructure::parse("banded"), None);
    }

    /// The codebook export lands in the profile: bits + levels parsed
    /// per layer, malformed entries dropped without failing the import,
    /// absent entries mean "not quantized".
    #[test]
    fn import_codebook_from_report_json() {
        let src = r#"{"measured": {"lenet5": {"per_layer": {
            "c1": {"nnz": 64, "total": 576, "structure": "pattern4",
                   "quant": {"bits": 4, "codebook": [-0.5, 0.25, 0.5]}},
            "c2": {"nnz": 64, "total": 256, "quant": {"bits": 8}},
            "f1": {"nnz": 480, "total": 48000, "quant": {"bits": 99}},
            "f2": {"nnz": 10, "total": 100}
        }}}}"#;
        let j = Json::parse(src).unwrap();
        let p = SparsityProfile::from_report(&j, "lenet5").unwrap();
        assert_eq!(p.quant_bits("c1"), Some(4));
        assert_eq!(
            p.quant.get("c1").unwrap().codebook,
            vec![-0.5f32, 0.25, 0.5],
            "exported levels survive the import"
        );
        assert_eq!(p.quant_bits("c2"), Some(8), "codebook array is optional");
        assert_eq!(p.quant_bits("f1"), None, "bad bits degrade to unquantized");
        assert_eq!(p.quant_bits("f2"), None);
        // oversized codebook for the declared width is malformed
        let src = r#"{"measured": {"m": {"per_layer": {
            "c": {"nnz": 1, "total": 2,
                  "quant": {"bits": 2, "codebook": [1.0, 2.0, 3.0, 4.0]}}
        }}}}"#;
        let p = SparsityProfile::from_report(&Json::parse(src).unwrap(), "m").unwrap();
        assert_eq!(p.quant_bits("c"), None);
    }

    #[test]
    fn uniform_quant_declares_every_pruned_layer() {
        let g = models::build("lenet5", 1).unwrap();
        let p = SparsityProfile::uniform(&g, 0.8).with_uniform_quant(4);
        for name in p.layers.keys() {
            assert_eq!(p.quant_bits(name), Some(4));
        }
        assert_eq!(p.quant_bits("not_a_layer"), None);
    }

    #[test]
    fn unmatched_layers_surface_renames() {
        let g = models::build("lenet5", 1).unwrap();
        let mut p = SparsityProfile::uniform(&g, 0.9);
        assert!(p.unmatched_layers(&g).is_empty());
        assert!(!p.is_empty());
        p.layers.insert("c1_typo".into(), 0.9);
        assert_eq!(p.unmatched_layers(&g), vec!["c1_typo".to_string()]);
        assert!(SparsityProfile::default().is_empty());
    }

    #[test]
    fn import_structure_from_report_json() {
        let src = r#"{"measured": {"lenet5": {"per_layer": {
            "c1": {"nnz": 64, "total": 576, "structure": "pattern4"},
            "c2": {"nnz": 64, "total": 256, "structure": "block4x4"},
            "f1": {"nnz": 480, "total": 48000, "structure": "martian"}
        }}}}"#;
        let j = Json::parse(src).unwrap();
        let p = SparsityProfile::from_report(&j, "lenet5").unwrap();
        assert_eq!(p.structure("c1"), PruneStructure::Pattern { entries: 4 });
        assert_eq!(p.structure("c2"), PruneStructure::Block { br: 4, bc: 4 });
        // unknown labels degrade to element, never fail the import
        assert_eq!(p.structure("f1"), PruneStructure::Element);
    }
}
