//! Framework personalities (the Figure 2 series, minus the device axis).

use crate::ir::Graph;
use crate::passes::{conv1x1_gemm::Conv1x1ToGemm, fusion::FusionPass, run_pipeline, Pass};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Personality {
    /// Dense, unfused, direct convolution — TensorFlow-Lite-like.
    TfLiteLike,
    /// Dense, fused, GEMM-transformed, default tiles — TVM-like.
    TvmLike,
    /// Dense + all CADNN architecture-aware optimizations (tuned tiles,
    /// layout, load hoisting) — CADNN-D.
    CadnnDense,
    /// Compressed (per-layer sparsity profile) + all optimizations —
    /// CADNN-S.
    CadnnSparse,
}

impl Personality {
    pub const ALL: [Personality; 4] = [
        Personality::TfLiteLike,
        Personality::TvmLike,
        Personality::CadnnDense,
        Personality::CadnnSparse,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            Personality::TfLiteLike => "TFLITE-like-D",
            Personality::TvmLike => "TVM-like-D",
            Personality::CadnnDense => "CADNN-D",
            Personality::CadnnSparse => "CADNN-S",
        }
    }

    /// Does this personality run the fusion + 1x1->GEMM pipeline?
    pub fn transforms(&self) -> bool {
        !matches!(self, Personality::TfLiteLike)
    }

    /// Direct-loop convolution engine (no im2col/GEMM)?
    pub fn direct_conv(&self) -> bool {
        matches!(self, Personality::TfLiteLike)
    }

    /// Per-layer tile tuning?
    pub fn tuned(&self) -> bool {
        matches!(self, Personality::CadnnDense | Personality::CadnnSparse)
    }

    /// Compressed weights?
    pub fn sparse(&self) -> bool {
        matches!(self, Personality::CadnnSparse)
    }

    /// Apply this personality's compiler passes to a pre-pass graph.
    pub fn lower(&self, g: &Graph) -> Graph {
        if self.transforms() {
            let fusion = FusionPass;
            let gemm = Conv1x1ToGemm;
            run_pipeline(g, &[&fusion as &dyn Pass, &gemm as &dyn Pass])
        } else {
            g.clone()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn labels_distinct() {
        let labels: std::collections::BTreeSet<_> =
            Personality::ALL.iter().map(|p| p.label()).collect();
        assert_eq!(labels.len(), 4);
    }

    #[test]
    fn tflite_does_not_transform() {
        let g = models::build("mobilenet_v1", 1).unwrap();
        let lowered = Personality::TfLiteLike.lower(&g);
        assert_eq!(lowered.len(), g.len());
    }

    #[test]
    fn cadnn_transforms_shrink_graph() {
        let g = models::build("mobilenet_v1", 1).unwrap();
        let lowered = Personality::CadnnDense.lower(&g);
        assert!(lowered.len() < g.len() / 2);
    }
}
