//! Graph executor with framework personalities.
//!
//! A `Personality` is a (passes, engine, tuning, sparsity) bundle — the
//! executable definition of each Figure 2 series:
//!
//! | personality   | passes              | conv engine   | tiles   | weights |
//! |---------------|---------------------|---------------|---------|---------|
//! | `TfLiteLike`  | none                | direct loops  | —       | dense   |
//! | `TvmLike`     | fusion + 1x1->GEMM  | im2col GEMM   | default | dense   |
//! | `CadnnDense`  | fusion + 1x1->GEMM  | im2col GEMM   | tuned   | dense   |
//! | `CadnnSparse` | fusion + 1x1->GEMM  | planned¹      | tuned   | pruned  |
//!
//! ¹ CadnnSparse's per-layer engine is chosen by [`crate::planner`]:
//! scalar CSR, block-sparse BSR (optionally filter-kernel-reordered),
//! PatDNN pattern-sparse, or dense rematerialization, whichever the cost
//! model (or the tuner's measured mode) expects to be fastest for that
//! layer's sparsity structure. Pruning follows the profile's
//! [`crate::compress::PruneStructure`] (element / block / pattern), so
//! the support the planner sees matches what the ADMM projections would
//! produce.
//!
//! Weights are generated deterministically from layer names, so every
//! personality of the same model computes the *same function* (the
//! correctness tests assert it); CadnnSparse computes the function of
//! the pruned weights, asserted against a dense run on those pruned
//! weights.

pub mod instance;
pub mod personality;

pub use instance::{ExecScratch, ModelInstance, NodeProfile, TensorPool};
pub use personality::Personality;
