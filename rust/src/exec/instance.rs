//! Executable model instance: lowered graph + generated weights +
//! per-layer kernel/tile choices, runnable on the native kernels.

use crate::compress::csr::CsrMatrix;
use crate::compress::profile::SparsityProfile;
use crate::ir::ops::{ActKind, Op, PoolKind};
use crate::ir::{Graph, NodeId};
use crate::kernels::conv as K;
use crate::kernels::{Epilogue, Tensor};
use crate::passes::layout::TileConfig;
use crate::tuner::TunerCache;
use crate::util::rng::Rng;
use std::collections::BTreeMap;

use super::personality::Personality;

/// Per-node weight payload.
#[derive(Debug, Clone)]
enum NodeWeights {
    /// (k x cout) weight matrix — the HWIO flatten; serves both the GEMM
    /// path (as-is) and the direct path (reinterpreted as HWIO tensor).
    Dense { mat: Vec<f32>, hwio: [usize; 4], epi: Epilogue },
    /// CSR weights for compressed layers.
    Sparse {
        csr: CsrMatrix,
        #[allow(dead_code)] // kept for debugging / future direct-sparse engines
        hwio: [usize; 4],
        epi: Epilogue,
    },
    /// Depthwise (kh, kw, c) weights.
    Dw { w: Tensor, epi: Epilogue },
    /// Standalone BatchNorm parameters (unfused personalities).
    Bn { scale: Vec<f32>, shift: Vec<f32> },
}

/// One node's measured execution profile (the paper's §6 "DNN profiler
/// ... to better detect the performance bottleneck" work-in-progress
/// item, implemented).
#[derive(Debug, Clone)]
pub struct NodeProfile {
    pub name: String,
    pub kind: &'static str,
    pub us: f64,
    pub flops: u64,
    pub out_bytes: usize,
}

impl NodeProfile {
    pub fn gflops(&self) -> f64 {
        self.flops as f64 / self.us.max(1e-9) / 1e3
    }
}

pub struct ModelInstance {
    pub name: String,
    pub personality: Personality,
    pub graph: Graph,
    weights: BTreeMap<NodeId, NodeWeights>,
    tiles: BTreeMap<NodeId, TileConfig>,
    /// Sparsity profile actually applied (CadnnSparse only).
    pub profile: Option<SparsityProfile>,
}

fn name_seed(name: &str) -> u64 {
    // FNV-1a over the layer name: deterministic across personalities.
    let mut h = 0xcbf29ce484222325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Deterministic per-layer parameters, keyed by layer name so every
/// personality sees identical functions.
fn gen_matrix(name: &str, k: usize, n: usize) -> Vec<f32> {
    let mut rng = Rng::new(name_seed(name));
    let scale = (2.0 / k.max(1) as f64).sqrt() as f32;
    let mut out = vec![0.0f32; k * n];
    rng.fill_normal(&mut out, scale);
    out
}

fn gen_bn(conv_name: &str, c: usize) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(name_seed(conv_name) ^ 0xB7);
    let scale: Vec<f32> = (0..c).map(|_| 0.5 + rng.f32()).collect();
    let shift: Vec<f32> = (0..c).map(|_| (rng.f32() - 0.5) * 0.2).collect();
    (scale, shift)
}

fn gen_bias(name: &str, c: usize) -> Vec<f32> {
    let mut rng = Rng::new(name_seed(name) ^ 0x5A);
    (0..c).map(|_| (rng.f32() - 0.5) * 0.1).collect()
}

/// Prune a weight matrix to the given sparsity by magnitude (matching
/// the ADMM projection's final support selection).
fn prune_matrix(mat: &mut [f32], sparsity: f64) {
    if sparsity <= 0.0 {
        return;
    }
    let mut mags: Vec<f32> = mat.iter().map(|v| v.abs()).collect();
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let cut = ((mat.len() as f64) * sparsity) as usize;
    if cut == 0 {
        return;
    }
    let thresh = mags[cut.min(mags.len() - 1)];
    for v in mat.iter_mut() {
        if v.abs() < thresh {
            *v = 0.0;
        }
    }
}

fn act_flags(act: ActKind) -> (bool, bool) {
    match act {
        ActKind::Relu => (true, false),
        ActKind::Relu6 => (true, true),
        ActKind::None => (false, false),
    }
}

impl ModelInstance {
    /// Build an instance for `model` under `personality`. `profile`
    /// provides per-layer sparsity for CadnnSparse (ignored otherwise).
    pub fn build(
        model: &Graph,
        personality: Personality,
        profile: Option<&SparsityProfile>,
        tuner: Option<&mut TunerCache>,
        cache_bytes: usize,
    ) -> Result<ModelInstance, String> {
        let graph = personality.lower(model);
        let mut weights = BTreeMap::new();
        let mut tiles = BTreeMap::new();
        let mut tuner = tuner;
        for n in &graph.nodes {
            match &n.op {
                Op::Conv2d { kh, kw, cin, cout, groups, bias, .. } => {
                    if *groups != 1 {
                        return Err(format!("grouped conv '{}' not executable", n.name));
                    }
                    let k = kh * kw * cin;
                    let mat = gen_matrix(&n.name, k, *cout);
                    let epi = if *bias {
                        Epilogue::bias_relu(gen_bias(&n.name, *cout), false)
                    } else {
                        Epilogue::None
                    };
                    weights.insert(
                        n.id,
                        NodeWeights::Dense { mat, hwio: [*kh, *kw, *cin, *cout], epi },
                    );
                }
                Op::FusedConvBnAct { kh, kw, cin, cout, act, groups, .. } => {
                    if *groups != 1 {
                        return Err(format!("grouped conv '{}' not executable", n.name));
                    }
                    let k = kh * kw * cin;
                    let mut mat = gen_matrix(&n.name, k, *cout);
                    let (scale, shift) = gen_bn(&n.name, *cout);
                    let (relu, relu6) = act_flags(*act);
                    let epi = Epilogue::bn_act(scale, shift, relu, relu6);
                    let sparsity = sparsity_of(personality, profile, &graph, n.id);
                    if sparsity > 0.0 {
                        prune_matrix(&mut mat, sparsity);
                        let csr = CsrMatrix::from_dense(&mat, k, *cout);
                        weights.insert(
                            n.id,
                            NodeWeights::Sparse { csr, hwio: [*kh, *kw, *cin, *cout], epi },
                        );
                    } else {
                        weights.insert(
                            n.id,
                            NodeWeights::Dense { mat, hwio: [*kh, *kw, *cin, *cout], epi },
                        );
                    }
                    if personality.tuned() {
                        if let Some(t) = tuner.as_deref_mut() {
                            let m = n.shape.n() * n.shape.h() * n.shape.w();
                            tiles.insert(n.id, t.get_or_tune(m, k, *cout, cache_bytes));
                        }
                    }
                }
                Op::Gemm { k, n: nn, act, out_shape, .. } => {
                    let mut mat = gen_matrix(&n.name, *k, *nn);
                    let (scale, shift) = gen_bn(&n.name, *nn);
                    let (relu, relu6) = act_flags(*act);
                    let epi = Epilogue::bn_act(scale, shift, relu, relu6);
                    let sparsity = sparsity_of(personality, profile, &graph, n.id);
                    let hwio = [1, 1, *k, *nn];
                    if sparsity > 0.0 {
                        prune_matrix(&mut mat, sparsity);
                        let csr = CsrMatrix::from_dense(&mat, *k, *nn);
                        weights.insert(n.id, NodeWeights::Sparse { csr, hwio, epi });
                    } else {
                        weights.insert(n.id, NodeWeights::Dense { mat, hwio, epi });
                    }
                    if personality.tuned() {
                        if let Some(t) = tuner.as_deref_mut() {
                            let m = out_shape.numel() / nn;
                            tiles.insert(n.id, t.get_or_tune(m, *k, *nn, cache_bytes));
                        }
                    }
                }
                Op::DepthwiseConv2d { kh, kw, c, .. } => {
                    let w = Tensor::from_vec(
                        &[*kh, *kw, *c],
                        gen_matrix(&n.name, kh * kw, *c),
                    );
                    weights.insert(n.id, NodeWeights::Dw { w, epi: Epilogue::None });
                }
                Op::FusedDwBnAct { kh, kw, c, act, .. } => {
                    let w = Tensor::from_vec(
                        &[*kh, *kw, *c],
                        gen_matrix(&n.name, kh * kw, *c),
                    );
                    let (scale, shift) = gen_bn(&n.name, *c);
                    let (relu, relu6) = act_flags(*act);
                    weights.insert(
                        n.id,
                        NodeWeights::Dw { w, epi: Epilogue::bn_act(scale, shift, relu, relu6) },
                    );
                }
                Op::BatchNorm { c } => {
                    // parameters keyed by the *producing conv's* name so the
                    // fused personalities fold the identical affine.
                    let conv_name = &graph.node(n.inputs[0]).name;
                    let (scale, shift) = gen_bn(conv_name, *c);
                    weights.insert(n.id, NodeWeights::Bn { scale, shift });
                }
                Op::FullyConnected { cin, cout, bias } => {
                    let mat = gen_matrix(&n.name, *cin, *cout);
                    let epi = if *bias {
                        Epilogue::bias_relu(gen_bias(&n.name, *cout), false)
                    } else {
                        Epilogue::None
                    };
                    weights.insert(n.id, NodeWeights::Dense { mat, hwio: [1, 1, *cin, *cout], epi });
                }
                _ => {}
            }
        }
        Ok(ModelInstance {
            name: model.name.clone(),
            personality,
            graph,
            weights,
            tiles,
            profile: profile.cloned().filter(|_| personality.sparse()),
        })
    }

    fn tile(&self, id: NodeId) -> TileConfig {
        self.tiles.get(&id).copied().unwrap_or(TileConfig::DEFAULT)
    }

    /// Per-node timing profile from `execute_profiled`.
    pub fn profile(&self, input: &Tensor, warmup: usize) -> Result<Vec<NodeProfile>, String> {
        for _ in 0..warmup {
            self.execute(input)?;
        }
        let g = &self.graph;
        let mut values: Vec<Option<Tensor>> = vec![None; g.len()];
        values[0] = Some(input.clone());
        let mut out = Vec::new();
        for n in g.nodes.iter().skip(1) {
            let t0 = std::time::Instant::now();
            let v = self.exec_node(n, &values)?;
            let us = t0.elapsed().as_secs_f64() * 1e6;
            let ins: Vec<&crate::ir::Shape> =
                n.inputs.iter().map(|&i| &g.nodes[i].shape).collect();
            out.push(NodeProfile {
                name: n.name.clone(),
                kind: n.op.name(),
                us,
                flops: n.op.flops(&ins, &n.shape),
                out_bytes: n.shape.bytes_f32(),
            });
            values[n.id] = Some(v);
        }
        Ok(out)
    }

    /// Run a forward pass. Input NHWC must match the graph input shape.
    pub fn execute(&self, input: &Tensor) -> Result<Tensor, String> {
        let g = &self.graph;
        if input.shape != g.nodes[0].shape.0 {
            return Err(format!(
                "input shape {:?} != model {:?}",
                input.shape, g.nodes[0].shape.0
            ));
        }
        let mut values: Vec<Option<Tensor>> = vec![None; g.len()];
        // liveness: free a value after its last consumer
        let mut last_use = vec![0usize; g.len()];
        for n in &g.nodes {
            for &i in &n.inputs {
                last_use[i] = last_use[i].max(n.id);
            }
        }
        values[0] = Some(input.clone());
        for n in g.nodes.iter().skip(1) {
            let out = self.exec_node(n, &values)?;
            values[n.id] = Some(out);
            // free dead values
            for &i in &n.inputs {
                if last_use[i] == n.id && i != g.output {
                    values[i] = None;
                }
            }
        }
        values[g.output]
            .take()
            .ok_or_else(|| "output value missing".into())
    }

    fn exec_node(&self, n: &crate::ir::Node, values: &[Option<Tensor>]) -> Result<Tensor, String> {
        let val = |i: usize| -> Result<&Tensor, String> {
            values[i].as_ref().ok_or_else(|| format!("value {i} freed too early"))
        };
        let x = val(n.inputs[0])?;
        let out = match &n.op {
            Op::Conv2d { kh, kw, cout, stride, padh, padw, .. } => {
                let Some(NodeWeights::Dense { mat, hwio, epi }) = self.weights.get(&n.id) else {
                    return Err(format!("missing weights for {}", n.name));
                };
                if self.personality.direct_conv() {
                    let w = Tensor::from_vec(&hwio.to_vec(), mat.clone());
                    let mut out = K::conv2d_direct(x, &w, *stride, *padh, *padw);
                    let (rows, ch) = (out.numel() / out.c(), out.c());
                    epi.apply(&mut out.data, rows, ch);
                    out
                } else {
                    K::conv2d_gemm(
                        x, mat, *kh, *kw, *cout, *stride, *padh, *padw,
                        &self.tile(n.id), epi,
                    )
                }
            }
            Op::FusedConvBnAct { kh, kw, cout, stride, padh, padw, .. } => match self
                .weights
                .get(&n.id)
            {
                Some(NodeWeights::Dense { mat, epi, .. }) => K::conv2d_gemm(
                    x, mat, *kh, *kw, *cout, *stride, *padh, *padw,
                    &self.tile(n.id), epi,
                ),
                Some(NodeWeights::Sparse { csr, epi, .. }) => {
                    K::conv2d_csr(x, csr, *kh, *kw, *stride, *padh, *padw, epi)
                }
                _ => return Err(format!("missing weights for {}", n.name)),
            },
            Op::Gemm { k, n: nn, out_shape, .. } => {
                let m = out_shape.numel() / nn;
                let mut out = Tensor::zeros(&out_shape.0);
                match self.weights.get(&n.id) {
                    Some(NodeWeights::Dense { mat, epi, .. }) => {
                        crate::kernels::gemm::gemm_parallel(
                            &x.data, mat, &mut out.data, m, *k, *nn,
                            &self.tile(n.id), epi,
                        );
                    }
                    Some(NodeWeights::Sparse { csr, epi, .. }) => {
                        crate::kernels::sparse::csr_gemm_parallel(
                            &x.data, csr, &mut out.data, m, epi,
                        );
                    }
                    _ => return Err(format!("missing weights for {}", n.name)),
                }
                out
            }
            Op::DepthwiseConv2d { stride, padding, .. } => {
                let Some(NodeWeights::Dw { w, epi }) = self.weights.get(&n.id) else {
                    return Err(format!("missing weights for {}", n.name));
                };
                K::depthwise(x, w, *stride, *padding, epi)
            }
            Op::FusedDwBnAct { stride, padding, .. } => {
                let Some(NodeWeights::Dw { w, epi }) = self.weights.get(&n.id) else {
                    return Err(format!("missing weights for {}", n.name));
                };
                K::depthwise(x, w, *stride, *padding, epi)
            }
            Op::BatchNorm { .. } => {
                let Some(NodeWeights::Bn { scale, shift }) = self.weights.get(&n.id) else {
                    return Err(format!("missing bn params for {}", n.name));
                };
                let mut out = x.clone();
                K::batchnorm(&mut out, scale, shift);
                out
            }
            Op::Activation { kind } => {
                let mut out = x.clone();
                match kind {
                    ActKind::Relu => K::relu(&mut out, None),
                    ActKind::Relu6 => K::relu(&mut out, Some(6.0)),
                    ActKind::None => {}
                }
                out
            }
            Op::Pool { kind, k, stride, padding } => {
                K::pool(x, *k, *stride, *padding, *kind == PoolKind::Max)
            }
            Op::GlobalAvgPool => K::global_avg_pool(x),
            Op::FullyConnected { cin, cout, .. } => {
                let Some(NodeWeights::Dense { mat, epi, .. }) = self.weights.get(&n.id) else {
                    return Err(format!("missing weights for {}", n.name));
                };
                let m = x.numel() / cin;
                let mut out = Tensor::zeros(&[m, *cout]);
                crate::kernels::gemm::gemm_parallel(
                    &x.data, mat, &mut out.data, m, *cin, *cout,
                    &self.tile(n.id), epi,
                );
                // FC in these nets is followed by explicit relu nodes; the
                // bias epilogue was applied above.
                out
            }
            Op::Add => {
                let y = val(n.inputs[1])?;
                K::add(x, y)
            }
            Op::Concat => {
                let mut parts: Vec<&Tensor> = Vec::with_capacity(n.inputs.len());
                for &i in &n.inputs {
                    parts.push(val(i)?);
                }
                K::concat_channels(&parts)
            }
            Op::Softmax => {
                let mut out = x.clone();
                K::softmax(&mut out);
                out
            }
            Op::Flatten => {
                let m = x.n();
                Tensor::from_vec(&[m, x.numel() / m], x.data.clone())
            }
            Op::Input { .. } => unreachable!("input handled by execute"),
        };
        Ok(out)
    }
}

fn sparsity_of(
    personality: Personality,
    profile: Option<&SparsityProfile>,
    graph: &Graph,
    id: NodeId,
) -> f64 {
    if !personality.sparse() {
        return 0.0;
    }
    let n = graph.node(id);
    if !n.op.prunable() {
        return 0.0;
    }
    profile.map(|p| p.get(&n.name)).unwrap_or(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::util::rng::Rng;

    fn input_for(g: &Graph, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let mut t = Tensor::zeros(&g.nodes[0].shape.0);
        rng.fill_normal(&mut t.data, 0.5);
        t
    }

    /// The headline semantics test: TFLite-like (unfused, direct conv)
    /// and CADNN-D (fused, GEMM, tuned) compute the same function.
    #[test]
    fn personalities_agree_lenet5() {
        let g = models::build("lenet5", 1).unwrap();
        let x = input_for(&g, 1);
        let tfl = ModelInstance::build(&g, Personality::TfLiteLike, None, None, 1 << 20).unwrap();
        let tvm = ModelInstance::build(&g, Personality::TvmLike, None, None, 1 << 20).unwrap();
        let a = tfl.execute(&x).unwrap();
        let b = tvm.execute(&x).unwrap();
        assert_eq!(a.shape, b.shape);
        assert!(a.max_abs_diff(&b) < 1e-3, "diff {}", a.max_abs_diff(&b));
    }

    #[test]
    fn personalities_agree_mobilenet_like() {
        // scaled-down residual+depthwise net: use mobilenet_v1 at batch 1
        // but on a reduced input via a custom tiny graph? mobilenet_v1 at
        // 224 is heavy for a unit test; use lenet + tinyresnet-analog.
        // Here: mobilenet_v1 graph truncated is complex — run resnet18 at
        // batch 1 with a 32x32 input variant instead.
        use crate::ir::ops::Op;
        use crate::ir::Shape;
        // small bn-conv-add net exercising fusion + gemm + residual
        let mut g = Graph::new("minires", Shape::nhwc(1, 10, 10, 3));
        let c1 = g.add("c1", Op::conv(3, 3, 3, 8, 1, 1), vec![0]);
        let b1 = g.add("c1_bn", Op::BatchNorm { c: 8 }, vec![c1]);
        let r1 = g.add("c1_relu", Op::Activation { kind: ActKind::Relu }, vec![b1]);
        let c2 = g.add("c2", Op::conv(1, 1, 8, 8, 1, 0), vec![r1]);
        let b2 = g.add("c2_bn", Op::BatchNorm { c: 8 }, vec![c2]);
        let a = g.add("add", Op::Add, vec![b2, r1]);
        let r2 = g.add("relu2", Op::Activation { kind: ActKind::Relu }, vec![a]);
        let p = g.add("gap", Op::GlobalAvgPool, vec![r2]);
        g.add("fc", Op::fc(8, 4), vec![p]);
        g.validate().unwrap();

        let x = input_for(&g, 3);
        let tfl = ModelInstance::build(&g, Personality::TfLiteLike, None, None, 1 << 20).unwrap();
        let cad = ModelInstance::build(&g, Personality::CadnnDense, None, None, 1 << 20).unwrap();
        let out_a = tfl.execute(&x).unwrap();
        let out_b = cad.execute(&x).unwrap();
        assert!(out_a.max_abs_diff(&out_b) < 1e-3, "diff {}", out_a.max_abs_diff(&out_b));
    }

    #[test]
    fn sparse_execution_matches_pruned_dense() {
        use crate::ir::Shape;
        let mut g = Graph::new("minisparse", Shape::nhwc(1, 8, 8, 4));
        let c1 = g.add("c1", Op::conv(3, 3, 4, 16, 1, 1), vec![0]);
        let b1 = g.add("c1_bn", Op::BatchNorm { c: 16 }, vec![c1]);
        let _ = g.add("c1_relu", Op::Activation { kind: ActKind::Relu }, vec![b1]);
        let x = input_for(&g, 5);

        let mut profile = SparsityProfile::default();
        profile.layers.insert("c1".into(), 0.7);

        let sparse =
            ModelInstance::build(&g, Personality::CadnnSparse, Some(&profile), None, 1 << 20)
                .unwrap();
        let out_s = sparse.execute(&x).unwrap();

        // dense execution on the SAME pruned weights: rebuild dense and
        // manually prune using the same code path
        let dense =
            ModelInstance::build(&g, Personality::CadnnDense, None, None, 1 << 20).unwrap();
        let out_d = dense.execute(&x).unwrap();
        // sparse output must differ from unpruned dense (it pruned 70%)...
        assert!(out_s.max_abs_diff(&out_d) > 1e-6);
        // ...but equal a dense instance whose weights went through the
        // same prune_matrix: verified structurally via CSR density
        let sp = match sparse.weights.get(&1) {
            Some(NodeWeights::Sparse { csr, .. }) => csr.density(),
            _ => panic!("expected sparse weights"),
        };
        assert!((sp - 0.3).abs() < 0.05, "density {sp}");
    }

    #[test]
    fn batch_execution_shapes() {
        let g = models::build("lenet5", 4).unwrap();
        let x = input_for(&g, 7);
        let inst = ModelInstance::build(&g, Personality::TvmLike, None, None, 1 << 20).unwrap();
        let out = inst.execute(&x).unwrap();
        assert_eq!(out.shape, vec![4, 10]);
        // softmax rows
        for r in 0..4 {
            let s: f32 = out.data[r * 10..(r + 1) * 10].iter().sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn wrong_input_shape_rejected() {
        let g = models::build("lenet5", 1).unwrap();
        let inst = ModelInstance::build(&g, Personality::TvmLike, None, None, 1 << 20).unwrap();
        let bad = Tensor::zeros(&[1, 27, 28, 1]);
        assert!(inst.execute(&bad).is_err());
    }
}
