//! Executable model instance: lowered graph + generated weights +
//! per-layer kernel/tile choices, runnable on the native kernels.
//!
//! Hot-path note: `execute` allocates a fresh value table per call; the
//! serving / benchmark loops should instead hold an [`ExecScratch`]
//! (via [`ModelInstance::scratch`]) and call [`ModelInstance::execute_with`]
//! or [`ModelInstance::execute_slice`], which reuse the per-node value
//! table and recycle intermediate tensors through a size-keyed pool.
//! `cadnn::api::Session` does exactly this.

use crate::compress::bsr::{self, BsrMatrix};
use crate::compress::csr::CsrMatrix;
use crate::compress::pattern::{self, PatternMatrix};
use crate::compress::profile::{PruneStructure, SparsityProfile};
use crate::compress::qsparse::{QBsr, QCsr, QPattern, QSparseMatrix};
use crate::compress::reorder::{self, Permutation};
use crate::error::CadnnError;
use crate::ir::ops::{ActKind, Op, PoolKind};
use crate::ir::{Graph, NodeId};
use crate::kernels::conv as K;
use crate::kernels::{Epilogue, Tensor, PARALLEL_M_CUTOVER};
use crate::passes::layout::TileConfig;
use crate::planner::{self, ExecPlan, FormatPolicy, SparseFormat, ValuePolicy};
use crate::tuner::TunerCache;
use crate::util::rng::Rng;
use std::collections::BTreeMap;

use super::personality::Personality;

/// Per-node weight payload.
#[derive(Debug, Clone)]
enum NodeWeights {
    /// (k x cout) weight matrix — the HWIO flatten; serves both the GEMM
    /// path (as-is) and the direct path (reinterpreted as HWIO tensor).
    Dense { mat: Vec<f32>, hwio: [usize; 4], epi: Epilogue },
    /// CSR weights for compressed layers. `hwio` feeds the format
    /// planner's spatial-vs-GEMM distinction; `cutover` is the
    /// planner-chosen serial→parallel row threshold.
    Sparse { csr: CsrMatrix, hwio: [usize; 4], epi: Epilogue, cutover: usize },
    /// BSR block weights for compressed layers the planner moved off
    /// CSR. When `perm` is set the weight columns (and the epilogue's
    /// per-channel parameters) are filter-kernel-reordered, and outputs
    /// are scattered back through the permutation after the kernel.
    BlockSparse {
        bsr: BsrMatrix,
        perm: Option<Permutation>,
        epi: Epilogue,
        cutover: usize,
    },
    /// PatDNN pattern weights (per-kernel pattern id + shared table) for
    /// pattern-pruned spatial conv layers the planner moved off CSR.
    PatternSparse { pat: PatternMatrix, epi: Epilogue, cutover: usize },
    /// Codebook-packed sparse weights (any sparse format) for layers the
    /// planner gave a quantized value store; executed through the LUT
    /// kernels (`kernels::lut`). `perm` carries the BSR reorder contract
    /// exactly as `BlockSparse` does.
    QuantSparse {
        mat: QSparseMatrix,
        perm: Option<Permutation>,
        epi: Epilogue,
        cutover: usize,
    },
    /// Depthwise (kh, kw, c) weights.
    Dw { w: Tensor, epi: Epilogue },
    /// Standalone BatchNorm parameters (unfused personalities).
    Bn { scale: Vec<f32>, shift: Vec<f32> },
}

/// One node's measured execution profile (the paper's §6 "DNN profiler
/// ... to better detect the performance bottleneck" work-in-progress
/// item, implemented).
#[derive(Debug, Clone)]
pub struct NodeProfile {
    pub name: String,
    pub kind: &'static str,
    pub us: f64,
    pub flops: u64,
    pub out_bytes: usize,
}

impl NodeProfile {
    pub fn gflops(&self) -> f64 {
        self.flops as f64 / self.us.max(1e-9) / 1e3
    }
}

/// Size-keyed free list of intermediate tensors. Kernels that allocate
/// internally donate their outputs on death; the executor-allocated ops
/// (GEMM, FC, elementwise, input staging) draw from it, so repeated runs
/// through one scratch stop allocating.
#[derive(Debug, Default)]
pub struct TensorPool {
    free: BTreeMap<usize, Vec<Tensor>>,
    allocs: u64,
    reuses: u64,
}

/// Bound on retained buffers per distinct size, so long-lived scratches
/// don't accumulate duplicates of kernel-allocated intermediates.
const POOL_MAX_PER_SIZE: usize = 4;

impl TensorPool {
    fn take_raw(&mut self, shape: &[usize]) -> Option<Tensor> {
        let numel: usize = shape.iter().product();
        match self.free.get_mut(&numel).and_then(|v| v.pop()) {
            Some(mut t) => {
                self.reuses += 1;
                t.shape = shape.to_vec();
                Some(t)
            }
            None => {
                self.allocs += 1;
                None
            }
        }
    }

    /// Zero-filled tensor of `shape` (for kernels that accumulate).
    fn take_zeroed(&mut self, shape: &[usize]) -> Tensor {
        match self.take_raw(shape) {
            Some(mut t) => {
                t.data.fill(0.0);
                t
            }
            None => Tensor::zeros(shape),
        }
    }

    /// Tensor of `shape` initialized from `src` (lengths must agree).
    fn take_copy(&mut self, shape: &[usize], src: &[f32]) -> Tensor {
        debug_assert_eq!(shape.iter().product::<usize>(), src.len());
        match self.take_raw(shape) {
            Some(mut t) => {
                t.data.copy_from_slice(src);
                t
            }
            None => Tensor::from_vec(shape, src.to_vec()),
        }
    }

    /// Return a tensor to the pool for later reuse.
    pub fn give(&mut self, t: Tensor) {
        let numel = t.numel();
        if numel == 0 {
            return;
        }
        let slot = self.free.entry(numel).or_default();
        if slot.len() < POOL_MAX_PER_SIZE {
            slot.push(t);
        }
    }
}

/// Reusable per-run state for [`ModelInstance::execute_with`]: the
/// per-node value table, the liveness schedule, and the tensor pool.
/// Create once per serving stream (`ModelInstance::scratch`) and reuse —
/// that removes the per-call `Vec<Option<Tensor>>` allocation and most
/// intermediate-tensor allocations from the hot path.
#[derive(Debug)]
pub struct ExecScratch {
    values: Vec<Option<Tensor>>,
    last_use: Vec<NodeId>,
    pool: TensorPool,
}

impl ExecScratch {
    /// Fresh tensor allocations made through the pool so far.
    pub fn buffer_allocs(&self) -> u64 {
        self.pool.allocs
    }

    /// Pool hits (reused buffers) so far.
    pub fn buffer_reuses(&self) -> u64 {
        self.pool.reuses
    }

    /// Donate a tensor (e.g. a returned output) back for reuse.
    pub fn recycle(&mut self, t: Tensor) {
        self.pool.give(t);
    }
}

pub struct ModelInstance {
    pub name: String,
    pub personality: Personality,
    pub graph: Graph,
    weights: BTreeMap<NodeId, NodeWeights>,
    tiles: BTreeMap<NodeId, TileConfig>,
    /// HWIO weight tensors pre-materialized for the direct-conv engine
    /// (TfLite-like), so the hot path stops cloning the weight matrix.
    direct_w: BTreeMap<NodeId, Tensor>,
    /// Sparsity profile actually applied (CadnnSparse only).
    pub profile: Option<SparsityProfile>,
    /// Per-layer format decisions the planner made (empty when nothing
    /// was pruned). Serialized into artifact manifests, shown by
    /// `cadnn plan`.
    pub plan: ExecPlan,
}

fn name_seed(name: &str) -> u64 {
    // FNV-1a over the layer name: deterministic across personalities.
    let mut h = 0xcbf29ce484222325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Deterministic per-layer parameters, keyed by layer name so every
/// personality sees identical functions.
fn gen_matrix(name: &str, k: usize, n: usize) -> Vec<f32> {
    let mut rng = Rng::new(name_seed(name));
    let scale = (2.0 / k.max(1) as f64).sqrt() as f32;
    let mut out = vec![0.0f32; k * n];
    rng.fill_normal(&mut out, scale);
    out
}

fn gen_bn(conv_name: &str, c: usize) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(name_seed(conv_name) ^ 0xB7);
    let scale: Vec<f32> = (0..c).map(|_| 0.5 + rng.f32()).collect();
    let shift: Vec<f32> = (0..c).map(|_| (rng.f32() - 0.5) * 0.2).collect();
    (scale, shift)
}

fn gen_bias(name: &str, c: usize) -> Vec<f32> {
    let mut rng = Rng::new(name_seed(name) ^ 0x5A);
    (0..c).map(|_| (rng.f32() - 0.5) * 0.1).collect()
}

/// Prune a weight matrix to the given sparsity by magnitude (matching
/// the ADMM projection's final support selection). The cut is exact:
/// `round(len * sparsity)` entries are zeroed, selected by sorted
/// (magnitude, index) order, so tied magnitudes cannot make the achieved
/// density drift from the requested sparsity.
fn prune_matrix(mat: &mut [f32], sparsity: f64) {
    if sparsity <= 0.0 || mat.is_empty() {
        return;
    }
    let cut = ((mat.len() as f64) * sparsity).round() as usize;
    let cut = cut.min(mat.len());
    if cut == 0 {
        return;
    }
    let mut idx: Vec<usize> = (0..mat.len()).collect();
    let cmp = |a: &usize, b: &usize| {
        mat[*a]
            .abs()
            .partial_cmp(&mat[*b].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(b))
    };
    let (smallest, nth, _) = idx.select_nth_unstable_by(cut - 1, cmp);
    for &i in smallest.iter() {
        mat[i] = 0.0;
    }
    mat[*nth] = 0.0;
}

/// Prune a weight matrix in the structure the profile prescribes: the
/// native-engine stand-in for the python ADMM projections (element /
/// block / pattern z-steps), so the planner sees the same support shape
/// a real compressed artifact would carry. Pattern structure needs
/// spatial kernel positions; on 1x1 / GEMM-shaped layers it degrades to
/// the element cut. The pattern library is selected once per
/// (kh, kw, cin) layer family through `cache` (PatDNN: libraries
/// transfer across same-shape layers), so tuned ResNet-50 builds stop
/// re-running library selection per layer and per batch variant.
fn prune_matrix_structured(
    mat: &mut [f32],
    hwio: [usize; 4],
    sparsity: f64,
    structure: PruneStructure,
    cache: &mut planner::PlanCache,
) {
    let (k, n) = (hwio[0] * hwio[1] * hwio[2], hwio[3]);
    debug_assert_eq!(mat.len(), k * n);
    match structure {
        PruneStructure::Element => prune_matrix(mat, sparsity),
        PruneStructure::Block { br, bc } => bsr::prune_blocks(mat, k, n, br, bc, sparsity),
        PruneStructure::Pattern { entries } => {
            if hwio[0] * hwio[1] > 1 {
                if sparsity <= 0.0 || mat.is_empty() {
                    return;
                }
                let lib =
                    cache.pattern_library(hwio[0], hwio[1], hwio[2], entries, hwio[3], mat);
                pattern::prune_with_library(
                    mat, hwio[0], hwio[1], hwio[2], hwio[3], sparsity, entries, &lib,
                );
            } else {
                prune_matrix(mat, sparsity);
            }
        }
    }
}

fn act_flags(act: ActKind) -> (bool, bool) {
    match act {
        ActKind::Relu => (true, false),
        ActKind::Relu6 => (true, true),
        ActKind::None => (false, false),
    }
}

impl ModelInstance {
    /// Build an instance for `model` under `personality`. `profile`
    /// provides per-layer sparsity for CadnnSparse (ignored otherwise).
    /// Pruned layers get their format planned under
    /// [`FormatPolicy::Auto`]; use [`ModelInstance::build_planned`] to
    /// pin a policy.
    pub fn build(
        model: &Graph,
        personality: Personality,
        profile: Option<&SparsityProfile>,
        tuner: Option<&mut TunerCache>,
        cache_bytes: usize,
    ) -> Result<ModelInstance, CadnnError> {
        Self::build_planned(model, personality, profile, tuner, cache_bytes, FormatPolicy::Auto)
    }

    /// [`ModelInstance::build`] with an explicit sparse-format policy.
    /// When a tuner is supplied, format choices are refined by the
    /// planner's measured mode (the same micro-benchmark loop as tile
    /// tuning); otherwise the cost-model heuristic decides. Value
    /// precision follows the profile ([`ValuePolicy::Auto`]); use
    /// [`ModelInstance::build_planned_cached`] to pin it.
    pub fn build_planned(
        model: &Graph,
        personality: Personality,
        profile: Option<&SparsityProfile>,
        tuner: Option<&mut TunerCache>,
        cache_bytes: usize,
        policy: FormatPolicy,
    ) -> Result<ModelInstance, CadnnError> {
        Self::build_planned_cached(
            model,
            personality,
            profile,
            tuner,
            cache_bytes,
            policy,
            ValuePolicy::Auto,
            None,
        )
    }

    /// [`ModelInstance::build_planned`] sharing a [`planner::PlanCache`]
    /// across calls, with an explicit value-precision policy
    /// (`EngineBuilder::value_bits`). `EngineBuilder` threads one cache
    /// through every batch variant it builds, so per-layer column
    /// clustering, densification, and pattern-library selection run once
    /// per pruned layer instead of once per batch variant — and within
    /// one build the payload rewrite reuses the exact `Permutation` the
    /// planner's estimate computed (nothing cache-derived enters the
    /// serialized [`ExecPlan`]).
    #[allow(clippy::too_many_arguments)]
    pub fn build_planned_cached(
        model: &Graph,
        personality: Personality,
        profile: Option<&SparsityProfile>,
        tuner: Option<&mut TunerCache>,
        cache_bytes: usize,
        policy: FormatPolicy,
        value_policy: ValuePolicy,
        plan_cache: Option<&mut planner::PlanCache>,
    ) -> Result<ModelInstance, CadnnError> {
        let mut local_cache = planner::PlanCache::default();
        let build_cache: &mut planner::PlanCache = match plan_cache {
            Some(c) => c,
            None => &mut local_cache,
        };
        let graph = personality.lower(model);
        let mut weights = BTreeMap::new();
        let mut tiles = BTreeMap::new();
        let mut direct_w = BTreeMap::new();
        let measured_formats = tuner.is_some();
        let mut tuner = tuner;
        for n in &graph.nodes {
            match &n.op {
                Op::Conv2d { kh, kw, cin, cout, groups, bias, .. } => {
                    if *groups != 1 {
                        return Err(CadnnError::UnsupportedOp {
                            node: n.name.clone(),
                            reason: format!("grouped conv (groups={groups}) not executable"),
                        });
                    }
                    let k = kh * kw * cin;
                    let mat = gen_matrix(&n.name, k, *cout);
                    let epi = if *bias {
                        Epilogue::bias_relu(gen_bias(&n.name, *cout), false)
                    } else {
                        Epilogue::None
                    };
                    if personality.direct_conv() {
                        direct_w.insert(
                            n.id,
                            Tensor::from_vec(&[*kh, *kw, *cin, *cout], mat.clone()),
                        );
                    }
                    weights.insert(
                        n.id,
                        NodeWeights::Dense { mat, hwio: [*kh, *kw, *cin, *cout], epi },
                    );
                }
                Op::FusedConvBnAct { kh, kw, cin, cout, act, groups, .. } => {
                    if *groups != 1 {
                        return Err(CadnnError::UnsupportedOp {
                            node: n.name.clone(),
                            reason: format!("grouped conv (groups={groups}) not executable"),
                        });
                    }
                    let k = kh * kw * cin;
                    let mut mat = gen_matrix(&n.name, k, *cout);
                    let (scale, shift) = gen_bn(&n.name, *cout);
                    let (relu, relu6) = act_flags(*act);
                    let epi = Epilogue::bn_act(scale, shift, relu, relu6);
                    let sparsity = sparsity_of(personality, profile, &graph, n.id);
                    if sparsity > 0.0 {
                        let hwio = [*kh, *kw, *cin, *cout];
                        let structure = structure_of(personality, profile, &graph, n.id);
                        prune_matrix_structured(&mut mat, hwio, sparsity, structure, build_cache);
                        let csr = CsrMatrix::from_dense(&mat, k, *cout);
                        weights.insert(
                            n.id,
                            NodeWeights::Sparse { csr, hwio, epi, cutover: PARALLEL_M_CUTOVER },
                        );
                    } else {
                        weights.insert(
                            n.id,
                            NodeWeights::Dense { mat, hwio: [*kh, *kw, *cin, *cout], epi },
                        );
                    }
                    if personality.tuned() {
                        if let Some(t) = tuner.as_deref_mut() {
                            let m = n.shape.n() * n.shape.h() * n.shape.w();
                            tiles.insert(n.id, t.get_or_tune(m, k, *cout, cache_bytes));
                        }
                    }
                }
                Op::Gemm { k, n: nn, act, out_shape, .. } => {
                    let mut mat = gen_matrix(&n.name, *k, *nn);
                    let (scale, shift) = gen_bn(&n.name, *nn);
                    let (relu, relu6) = act_flags(*act);
                    let epi = Epilogue::bn_act(scale, shift, relu, relu6);
                    let sparsity = sparsity_of(personality, profile, &graph, n.id);
                    let hwio = [1, 1, *k, *nn];
                    if sparsity > 0.0 {
                        let structure = structure_of(personality, profile, &graph, n.id);
                        prune_matrix_structured(&mut mat, hwio, sparsity, structure, build_cache);
                        let csr = CsrMatrix::from_dense(&mat, *k, *nn);
                        weights.insert(
                            n.id,
                            NodeWeights::Sparse { csr, hwio, epi, cutover: PARALLEL_M_CUTOVER },
                        );
                    } else {
                        weights.insert(n.id, NodeWeights::Dense { mat, hwio, epi });
                    }
                    if personality.tuned() {
                        if let Some(t) = tuner.as_deref_mut() {
                            let m = out_shape.numel() / nn;
                            tiles.insert(n.id, t.get_or_tune(m, *k, *nn, cache_bytes));
                        }
                    }
                }
                Op::DepthwiseConv2d { kh, kw, c, .. } => {
                    let w = Tensor::from_vec(
                        &[*kh, *kw, *c],
                        gen_matrix(&n.name, kh * kw, *c),
                    );
                    weights.insert(n.id, NodeWeights::Dw { w, epi: Epilogue::None });
                }
                Op::FusedDwBnAct { kh, kw, c, act, .. } => {
                    let w = Tensor::from_vec(
                        &[*kh, *kw, *c],
                        gen_matrix(&n.name, kh * kw, *c),
                    );
                    let (scale, shift) = gen_bn(&n.name, *c);
                    let (relu, relu6) = act_flags(*act);
                    weights.insert(
                        n.id,
                        NodeWeights::Dw { w, epi: Epilogue::bn_act(scale, shift, relu, relu6) },
                    );
                }
                Op::BatchNorm { c } => {
                    // parameters keyed by the *producing conv's* name so the
                    // fused personalities fold the identical affine.
                    let conv_name = &graph.node(n.inputs[0]).name;
                    let (scale, shift) = gen_bn(conv_name, *c);
                    weights.insert(n.id, NodeWeights::Bn { scale, shift });
                }
                Op::FullyConnected { cin, cout, bias } => {
                    let mat = gen_matrix(&n.name, *cin, *cout);
                    let epi = if *bias {
                        Epilogue::bias_relu(gen_bias(&n.name, *cout), false)
                    } else {
                        Epilogue::None
                    };
                    weights.insert(n.id, NodeWeights::Dense { mat, hwio: [1, 1, *cin, *cout], epi });
                }
                _ => {}
            }
        }
        // Per-layer format planning over the pruned layers — the BSR
        // conversion path. Consumes each Sparse entry's `hwio` (the
        // spatial-vs-GEMM signal) plus the node's GEMM row count, and
        // rewrites the payload to the planned format. Clustering and
        // densification flow through the layer's `PlanCache` slot, so
        // the estimate and the rewrite share one computation (and later
        // batch variants share it too).
        let batch = graph.nodes[0].shape.0.first().copied().unwrap_or(1).max(1);
        let mut plan = ExecPlan::default();
        for (id, w) in weights.iter_mut() {
            let NodeWeights::Sparse { csr, hwio, epi, cutover } = w else {
                continue;
            };
            let node = graph.node(*id);
            let m = node.shape.numel() / csr.cols.max(1);
            // the exported codebook width (if the compress report
            // declared one) is what ValuePolicy::Auto resolves against
            let declared = profile.and_then(|p| p.quant_bits(&node.name));
            let mut lp = build_cache.plan_node(
                &node.name,
                policy,
                value_policy,
                declared,
                csr,
                m,
                *hwio,
                measured_formats,
            );
            // one image contributes m/batch GEMM rows to this layer —
            // with cost_per_row this makes ExecPlan::cost_at batch-aware
            lp.rows_per_image = m / batch;
            plan.layers.insert(node.name.clone(), lp.clone());
            // re-borrow the layer's artifacts for the payload rewrite:
            // the same memoized permutation / densified matrix the plan
            // was priced with (computed on demand after a database hit)
            let arts = build_cache.layer(&node.name, csr);
            let qbits = lp.value_bits.bits() as u8;
            match lp.format {
                SparseFormat::Csr => {
                    if lp.value_bits.quantized() {
                        let new_w = NodeWeights::QuantSparse {
                            mat: QSparseMatrix::Csr(QCsr::from_csr(csr, qbits)),
                            perm: None,
                            epi: epi.clone(),
                            cutover: lp.parallel_cutover,
                        };
                        *w = new_w;
                    } else {
                        *cutover = lp.parallel_cutover;
                    }
                }
                SparseFormat::Dense => {
                    let new_w = NodeWeights::Dense {
                        mat: arts.dense(csr).as_ref().clone(),
                        hwio: *hwio,
                        epi: epi.clone(),
                    };
                    *w = new_w;
                }
                SparseFormat::Pattern => {
                    let pat = PatternMatrix::from_csr(csr, hwio[0], hwio[1], hwio[2]);
                    let new_w = if lp.value_bits.quantized() {
                        NodeWeights::QuantSparse {
                            mat: QSparseMatrix::Pattern(QPattern::from_pattern(&pat, qbits)),
                            perm: None,
                            epi: epi.clone(),
                            cutover: lp.parallel_cutover,
                        }
                    } else {
                        NodeWeights::PatternSparse {
                            pat,
                            epi: epi.clone(),
                            cutover: lp.parallel_cutover,
                        }
                    };
                    *w = new_w;
                }
                SparseFormat::Bsr { br, bc } => {
                    let (kk, nn) = (csr.rows, csr.cols);
                    let dense = arts.dense(csr);
                    let (bsr_mat, perm, epi2) = if lp.reorder {
                        // the cached permutation IS the one the planner's
                        // estimate used, so plan and payload agree and the
                        // clustering runs once per layer
                        let perm = arts.permutation(csr, br).clone();
                        let permuted = reorder::permute_cols(&dense, kk, nn, &perm);
                        (
                            BsrMatrix::from_dense(&permuted, kk, nn, br, bc),
                            Some(perm.clone()),
                            epi.permute_channels(&perm.perm),
                        )
                    } else {
                        (BsrMatrix::from_dense(&dense, kk, nn, br, bc), None, epi.clone())
                    };
                    let new_w = if lp.value_bits.quantized() {
                        NodeWeights::QuantSparse {
                            mat: QSparseMatrix::Bsr(QBsr::from_bsr(&bsr_mat, qbits)),
                            perm,
                            epi: epi2,
                            cutover: lp.parallel_cutover,
                        }
                    } else {
                        NodeWeights::BlockSparse {
                            bsr: bsr_mat,
                            perm,
                            epi: epi2,
                            cutover: lp.parallel_cutover,
                        }
                    };
                    *w = new_w;
                }
            }
        }
        Ok(ModelInstance {
            name: model.name.clone(),
            personality,
            graph,
            weights,
            tiles,
            direct_w,
            profile: profile.cloned().filter(|_| personality.sparse()),
            plan,
        })
    }

    fn tile(&self, id: NodeId) -> TileConfig {
        self.tiles.get(&id).copied().unwrap_or(TileConfig::DEFAULT)
    }

    /// The batch size this instance executes (its graph's input batch).
    pub fn batch(&self) -> usize {
        self.graph.nodes[0].shape.0.first().copied().unwrap_or(1).max(1)
    }

    /// Estimated planner cost (units) of executing one batch on this
    /// instance — `ExecPlan::cost_at` evaluated at this variant's batch
    /// size. `None` when nothing was pruned (empty plan): the engine's
    /// batch variants expose these to the serving scheduler
    /// ([`crate::api::Backend::plan_costs`]).
    pub fn plan_cost(&self) -> Option<f64> {
        self.plan.cost_at(self.batch())
    }

    /// Build a reusable scratch for this instance (value table sized to
    /// the lowered graph + precomputed liveness).
    pub fn scratch(&self) -> ExecScratch {
        let g = &self.graph;
        let mut last_use = vec![0usize; g.len()];
        for n in &g.nodes {
            for &i in &n.inputs {
                last_use[i] = last_use[i].max(n.id);
            }
        }
        ExecScratch {
            values: vec![None; g.len()],
            last_use,
            pool: TensorPool::default(),
        }
    }

    /// Per-node timing profile from repeated execution.
    pub fn profile(&self, input: &Tensor, warmup: usize) -> Result<Vec<NodeProfile>, CadnnError> {
        for _ in 0..warmup {
            self.execute(input)?;
        }
        let g = &self.graph;
        let mut pool = TensorPool::default();
        let mut values: Vec<Option<Tensor>> = vec![None; g.len()];
        values[0] = Some(input.clone());
        let mut out = Vec::new();
        for n in g.nodes.iter().skip(1) {
            let t0 = std::time::Instant::now();
            let v = self.exec_node(n, &values, &mut pool)?;
            let us = t0.elapsed().as_secs_f64() * 1e6;
            let ins: Vec<&crate::ir::Shape> =
                n.inputs.iter().map(|&i| &g.nodes[i].shape).collect();
            out.push(NodeProfile {
                name: n.name.clone(),
                kind: n.op.name(),
                us,
                flops: n.op.flops(&ins, &n.shape),
                out_bytes: n.shape.bytes_f32(),
            });
            values[n.id] = Some(v);
        }
        Ok(out)
    }

    /// Run a forward pass with a one-shot scratch. Input NHWC must match
    /// the graph input shape. Serving loops should prefer
    /// [`ModelInstance::execute_with`] with a held [`ExecScratch`].
    pub fn execute(&self, input: &Tensor) -> Result<Tensor, CadnnError> {
        let mut scratch = self.scratch();
        self.execute_with(input, &mut scratch)
    }

    /// Forward pass reusing `scratch` across calls.
    pub fn execute_with(
        &self,
        input: &Tensor,
        scratch: &mut ExecScratch,
    ) -> Result<Tensor, CadnnError> {
        let want = &self.graph.nodes[0].shape.0;
        if &input.shape != want {
            return Err(CadnnError::InputShape {
                expected: want.clone(),
                got: input.shape.clone(),
            });
        }
        self.execute_slice(&input.data, scratch)
    }

    /// Forward pass over a flat input buffer (interpreted as the graph's
    /// input shape), reusing `scratch` across calls.
    pub fn execute_slice(
        &self,
        input: &[f32],
        scratch: &mut ExecScratch,
    ) -> Result<Tensor, CadnnError> {
        let g = &self.graph;
        let in_shape = &g.nodes[0].shape.0;
        let want: usize = in_shape.iter().product();
        if input.len() != want {
            return Err(CadnnError::InvalidInput {
                reason: format!("input length {} != expected {want}", input.len()),
            });
        }
        if scratch.values.len() != g.len() {
            // scratch built for a different graph: rebuild rather than UB
            *scratch = self.scratch();
        }
        let ExecScratch { values, last_use, pool } = scratch;
        // recycle leftovers from the previous run
        for slot in values.iter_mut() {
            if let Some(t) = slot.take() {
                pool.give(t);
            }
        }
        values[0] = Some(pool.take_copy(in_shape, input));
        for n in g.nodes.iter().skip(1) {
            let obs_t0 = crate::obs::timer();
            let out = self.exec_node(n, values, pool)?;
            if let Some(t0) = obs_t0 {
                self.record_node_span(n, &out, t0);
            }
            values[n.id] = Some(out);
            // free dead values into the pool
            for &i in &n.inputs {
                if last_use[i] == n.id && i != g.output {
                    if let Some(t) = values[i].take() {
                        pool.give(t);
                    }
                }
            }
        }
        values[g.output]
            .take()
            .ok_or_else(|| CadnnError::execution("output value missing"))
    }

    /// Emit one `exec` span for a completed node: op, the layer plan's
    /// format label (`+q8`/`+q4` when the value store is quantized),
    /// value bits, GEMM rows produced, and the planner-predicted cost
    /// (`cost_per_row x rows`) that [`crate::obs::CostReport`] turns
    /// into residuals. Unplanned nodes (activations, pools, `none`
    /// format) carry `pred_units = 0` and are skipped by the fit.
    fn record_node_span(&self, n: &crate::ir::Node, out: &Tensor, t0_us: f64) {
        use crate::obs::{self, ArgValue};
        let rows = if out.rank() >= 2 { out.numel() / out.c() } else { 1 };
        let (format, bits, pred) = match self.plan.get(&n.name) {
            Some(lp) => {
                let mut f = lp.format.label();
                match lp.value_bits.bits() {
                    8 => f.push_str("+q8"),
                    4 => f.push_str("+q4"),
                    _ => {}
                }
                (f, lp.value_bits.bits(), lp.cost_per_row * rows as f64)
            }
            None => ("none".to_string(), 32, 0.0),
        };
        obs::span_since(
            obs::CAT_EXEC,
            n.name.clone(),
            t0_us,
            vec![
                ("op", ArgValue::Str(n.op.name().to_string())),
                ("format", ArgValue::Str(format)),
                ("bits", ArgValue::Num(bits as f64)),
                ("m", ArgValue::Num(rows as f64)),
                ("pred_units", ArgValue::Num(pred)),
            ],
        );
    }

    fn exec_node(
        &self,
        n: &crate::ir::Node,
        values: &[Option<Tensor>],
        pool: &mut TensorPool,
    ) -> Result<Tensor, CadnnError> {
        let val = |i: usize| -> Result<&Tensor, CadnnError> {
            values[i]
                .as_ref()
                .ok_or_else(|| CadnnError::execution(format!("value {i} freed too early")))
        };
        let missing = |name: &str| CadnnError::MissingWeights { node: name.to_string() };
        let x = val(n.inputs[0])?;
        let out = match &n.op {
            Op::Conv2d { kh, kw, cout, stride, padh, padw, .. } => {
                let Some(NodeWeights::Dense { mat, hwio, epi }) = self.weights.get(&n.id) else {
                    return Err(missing(&n.name));
                };
                if self.personality.direct_conv() {
                    let built;
                    let w = match self.direct_w.get(&n.id) {
                        Some(w) => w,
                        None => {
                            built = Tensor::from_vec(&hwio.to_vec(), mat.clone());
                            &built
                        }
                    };
                    let mut out = K::conv2d_direct(x, w, *stride, *padh, *padw);
                    let (rows, ch) = (out.numel() / out.c(), out.c());
                    epi.apply(&mut out.data, rows, ch);
                    out
                } else {
                    K::conv2d_gemm(
                        x, mat, *kh, *kw, *cout, *stride, *padh, *padw,
                        &self.tile(n.id), epi,
                    )
                }
            }
            Op::FusedConvBnAct { kh, kw, cout, stride, padh, padw, .. } => match self
                .weights
                .get(&n.id)
            {
                Some(NodeWeights::Dense { mat, epi, .. }) => K::conv2d_gemm(
                    x, mat, *kh, *kw, *cout, *stride, *padh, *padw,
                    &self.tile(n.id), epi,
                ),
                Some(NodeWeights::Sparse { csr, epi, cutover, .. }) => {
                    K::conv2d_csr(x, csr, *kh, *kw, *stride, *padh, *padw, epi, *cutover)
                }
                Some(NodeWeights::BlockSparse { bsr, perm, epi, cutover }) => {
                    let mut out =
                        K::conv2d_bsr(x, bsr, *kh, *kw, *stride, *padh, *padw, epi, *cutover);
                    if let Some(p) = perm {
                        let rows = out.numel() / out.c();
                        let ch = out.c();
                        reorder::unpermute_cols_inplace(&mut out.data, rows, ch, p);
                    }
                    out
                }
                Some(NodeWeights::PatternSparse { pat, epi, cutover }) => {
                    K::conv2d_pattern(x, pat, *kh, *kw, *stride, *padh, *padw, epi, *cutover)
                }
                Some(NodeWeights::QuantSparse { mat, perm, epi, cutover }) => {
                    let mut out =
                        K::conv2d_qsparse(x, mat, *kh, *kw, *stride, *padh, *padw, epi, *cutover);
                    if let Some(p) = perm {
                        let rows = out.numel() / out.c();
                        let ch = out.c();
                        reorder::unpermute_cols_inplace(&mut out.data, rows, ch, p);
                    }
                    out
                }
                _ => return Err(missing(&n.name)),
            },
            Op::Gemm { k, n: nn, out_shape, .. } => {
                let m = out_shape.numel() / nn;
                let mut out = pool.take_zeroed(&out_shape.0);
                match self.weights.get(&n.id) {
                    Some(NodeWeights::Dense { mat, epi, .. }) => {
                        crate::kernels::gemm::gemm_parallel(
                            &x.data, mat, &mut out.data, m, *k, *nn,
                            &self.tile(n.id), epi,
                        );
                    }
                    Some(NodeWeights::Sparse { csr, epi, cutover, .. }) => {
                        crate::kernels::sparse::csr_gemm_parallel_cutover(
                            &x.data, csr, &mut out.data, m, epi, *cutover,
                        );
                    }
                    Some(NodeWeights::BlockSparse { bsr, perm, epi, cutover }) => {
                        crate::kernels::bsr::bsr_gemm_parallel_cutover(
                            &x.data, bsr, &mut out.data, m, epi, *cutover,
                        );
                        if let Some(p) = perm {
                            reorder::unpermute_cols_inplace(&mut out.data, m, *nn, p);
                        }
                    }
                    Some(NodeWeights::PatternSparse { pat, epi, cutover }) => {
                        crate::kernels::pattern::pattern_gemm_parallel_cutover(
                            &x.data, pat, &mut out.data, m, epi, *cutover,
                        );
                    }
                    Some(NodeWeights::QuantSparse { mat, perm, epi, cutover }) => {
                        crate::kernels::lut::qsparse_gemm_parallel_cutover(
                            &x.data, mat, &mut out.data, m, epi, *cutover,
                        );
                        if let Some(p) = perm {
                            reorder::unpermute_cols_inplace(&mut out.data, m, *nn, p);
                        }
                    }
                    _ => return Err(missing(&n.name)),
                }
                out
            }
            Op::DepthwiseConv2d { stride, padding, .. } => {
                let Some(NodeWeights::Dw { w, epi }) = self.weights.get(&n.id) else {
                    return Err(missing(&n.name));
                };
                K::depthwise(x, w, *stride, *padding, epi)
            }
            Op::FusedDwBnAct { stride, padding, .. } => {
                let Some(NodeWeights::Dw { w, epi }) = self.weights.get(&n.id) else {
                    return Err(missing(&n.name));
                };
                K::depthwise(x, w, *stride, *padding, epi)
            }
            Op::BatchNorm { .. } => {
                let Some(NodeWeights::Bn { scale, shift }) = self.weights.get(&n.id) else {
                    return Err(missing(&n.name));
                };
                let mut out = pool.take_copy(&x.shape, &x.data);
                K::batchnorm(&mut out, scale, shift);
                out
            }
            Op::Activation { kind } => {
                let mut out = pool.take_copy(&x.shape, &x.data);
                match kind {
                    ActKind::Relu => K::relu(&mut out, None),
                    ActKind::Relu6 => K::relu(&mut out, Some(6.0)),
                    ActKind::None => {}
                }
                out
            }
            Op::Pool { kind, k, stride, padding } => {
                K::pool(x, *k, *stride, *padding, *kind == PoolKind::Max)
            }
            Op::GlobalAvgPool => K::global_avg_pool(x),
            Op::FullyConnected { cin, cout, .. } => {
                let Some(NodeWeights::Dense { mat, epi, .. }) = self.weights.get(&n.id) else {
                    return Err(missing(&n.name));
                };
                let m = x.numel() / cin;
                let mut out = pool.take_zeroed(&[m, *cout]);
                crate::kernels::gemm::gemm_parallel(
                    &x.data, mat, &mut out.data, m, *cin, *cout,
                    &self.tile(n.id), epi,
                );
                // FC in these nets is followed by explicit relu nodes; the
                // bias epilogue was applied above.
                out
            }
            Op::Add => {
                let y = val(n.inputs[1])?;
                if x.shape != y.shape {
                    return Err(CadnnError::execution(format!(
                        "add '{}': operand shapes {:?} vs {:?}",
                        n.name, x.shape, y.shape
                    )));
                }
                let mut out = pool.take_copy(&x.shape, &x.data);
                for (o, v) in out.data.iter_mut().zip(&y.data) {
                    *o += v;
                }
                out
            }
            Op::Concat => {
                let mut parts: Vec<&Tensor> = Vec::with_capacity(n.inputs.len());
                for &i in &n.inputs {
                    parts.push(val(i)?);
                }
                K::concat_channels(&parts)
            }
            Op::Softmax => {
                let mut out = pool.take_copy(&x.shape, &x.data);
                K::softmax(&mut out);
                out
            }
            Op::Flatten => {
                let m = x.n();
                pool.take_copy(&[m, x.numel() / m], &x.data)
            }
            Op::Input { .. } => unreachable!("input handled by execute"),
        };
        Ok(out)
    }
}

fn sparsity_of(
    personality: Personality,
    profile: Option<&SparsityProfile>,
    graph: &Graph,
    id: NodeId,
) -> f64 {
    if !personality.sparse() {
        return 0.0;
    }
    let n = graph.node(id);
    if !n.op.prunable() {
        return 0.0;
    }
    profile.map(|p| p.get(&n.name)).unwrap_or(0.0)
}

fn structure_of(
    personality: Personality,
    profile: Option<&SparsityProfile>,
    graph: &Graph,
    id: NodeId,
) -> PruneStructure {
    if !personality.sparse() {
        return PruneStructure::Element;
    }
    profile
        .map(|p| p.structure(&graph.node(id).name))
        .unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::util::rng::Rng;

    fn input_for(g: &Graph, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let mut t = Tensor::zeros(&g.nodes[0].shape.0);
        rng.fill_normal(&mut t.data, 0.5);
        t
    }

    /// The headline semantics test: TFLite-like (unfused, direct conv)
    /// and CADNN-D (fused, GEMM, tuned) compute the same function.
    #[test]
    fn personalities_agree_lenet5() {
        let g = models::build("lenet5", 1).unwrap();
        let x = input_for(&g, 1);
        let tfl = ModelInstance::build(&g, Personality::TfLiteLike, None, None, 1 << 20).unwrap();
        let tvm = ModelInstance::build(&g, Personality::TvmLike, None, None, 1 << 20).unwrap();
        let a = tfl.execute(&x).unwrap();
        let b = tvm.execute(&x).unwrap();
        assert_eq!(a.shape, b.shape);
        assert!(a.max_abs_diff(&b) < 1e-3, "diff {}", a.max_abs_diff(&b));
    }

    #[test]
    fn personalities_agree_mobilenet_like() {
        // scaled-down residual+depthwise net: use mobilenet_v1 at batch 1
        // but on a reduced input via a custom tiny graph? mobilenet_v1 at
        // 224 is heavy for a unit test; use lenet + tinyresnet-analog.
        // Here: mobilenet_v1 graph truncated is complex — run resnet18 at
        // batch 1 with a 32x32 input variant instead.
        use crate::ir::ops::Op;
        use crate::ir::Shape;
        // small bn-conv-add net exercising fusion + gemm + residual
        let mut g = Graph::new("minires", Shape::nhwc(1, 10, 10, 3));
        let c1 = g.add("c1", Op::conv(3, 3, 3, 8, 1, 1), vec![0]);
        let b1 = g.add("c1_bn", Op::BatchNorm { c: 8 }, vec![c1]);
        let r1 = g.add("c1_relu", Op::Activation { kind: ActKind::Relu }, vec![b1]);
        let c2 = g.add("c2", Op::conv(1, 1, 8, 8, 1, 0), vec![r1]);
        let b2 = g.add("c2_bn", Op::BatchNorm { c: 8 }, vec![c2]);
        let a = g.add("add", Op::Add, vec![b2, r1]);
        let r2 = g.add("relu2", Op::Activation { kind: ActKind::Relu }, vec![a]);
        let p = g.add("gap", Op::GlobalAvgPool, vec![r2]);
        g.add("fc", Op::fc(8, 4), vec![p]);
        g.validate().unwrap();

        let x = input_for(&g, 3);
        let tfl = ModelInstance::build(&g, Personality::TfLiteLike, None, None, 1 << 20).unwrap();
        let cad = ModelInstance::build(&g, Personality::CadnnDense, None, None, 1 << 20).unwrap();
        let out_a = tfl.execute(&x).unwrap();
        let out_b = cad.execute(&x).unwrap();
        assert!(out_a.max_abs_diff(&out_b) < 1e-3, "diff {}", out_a.max_abs_diff(&out_b));
    }

    #[test]
    fn sparse_execution_matches_pruned_dense() {
        use crate::ir::Shape;
        let mut g = Graph::new("minisparse", Shape::nhwc(1, 8, 8, 4));
        let c1 = g.add("c1", Op::conv(3, 3, 4, 16, 1, 1), vec![0]);
        let b1 = g.add("c1_bn", Op::BatchNorm { c: 16 }, vec![c1]);
        let _ = g.add("c1_relu", Op::Activation { kind: ActKind::Relu }, vec![b1]);
        let x = input_for(&g, 5);

        let mut profile = SparsityProfile::default();
        profile.layers.insert("c1".into(), 0.7);

        // pin the CSR format: at 30% density the Auto planner is free to
        // rematerialize dense, and this test inspects the CSR payload
        let sparse = ModelInstance::build_planned(
            &g,
            Personality::CadnnSparse,
            Some(&profile),
            None,
            1 << 20,
            FormatPolicy::Csr,
        )
        .unwrap();
        let out_s = sparse.execute(&x).unwrap();
        assert_eq!(
            sparse.plan.get("c1").map(|lp| lp.format),
            Some(SparseFormat::Csr),
            "pinned policy must reach the plan"
        );

        // dense execution on the SAME pruned weights: rebuild dense and
        // manually prune using the same code path
        let dense =
            ModelInstance::build(&g, Personality::CadnnDense, None, None, 1 << 20).unwrap();
        let out_d = dense.execute(&x).unwrap();
        // sparse output must differ from unpruned dense (it pruned 70%)...
        assert!(out_s.max_abs_diff(&out_d) > 1e-6);
        // ...and the achieved density must be *exactly* the requested one
        // (up to the integral cut): len = 3*3*4*16 = 576, cut = round(.7*576)
        let (nnz, total) = match sparse.weights.get(&1) {
            Some(NodeWeights::Sparse { csr, .. }) => (csr.nnz(), csr.rows * csr.cols),
            _ => panic!("expected sparse weights"),
        };
        let cut = ((total as f64) * 0.7).round() as usize;
        assert_eq!(nnz, total - cut, "inexact prune: nnz {nnz} of {total}");
    }

    /// Every format policy computes the same function on the same pruned
    /// weights; BSR must actually be exercised under the Bsr policy.
    #[test]
    fn format_policies_agree_on_pruned_model() {
        use crate::ir::Shape;
        let mut g = Graph::new("miniformats", Shape::nhwc(1, 8, 8, 4));
        let c1 = g.add("c1", Op::conv(3, 3, 4, 16, 1, 1), vec![0]);
        let b1 = g.add("c1_bn", Op::BatchNorm { c: 16 }, vec![c1]);
        let r1 = g.add("c1_relu", Op::Activation { kind: ActKind::Relu }, vec![b1]);
        let c2 = g.add("c2", Op::conv(1, 1, 16, 8, 1, 0), vec![r1]);
        let b2 = g.add("c2_bn", Op::BatchNorm { c: 8 }, vec![c2]);
        g.add("c2_relu", Op::Activation { kind: ActKind::Relu }, vec![b2]);
        g.validate().unwrap();
        let x = input_for(&g, 9);

        let mut profile = SparsityProfile::default();
        profile.layers.insert("c1".into(), 0.8);
        profile.layers.insert("c2".into(), 0.8);

        let build = |policy: FormatPolicy| {
            ModelInstance::build_planned(
                &g,
                Personality::CadnnSparse,
                Some(&profile),
                None,
                1 << 20,
                policy,
            )
            .unwrap()
        };
        let csr = build(FormatPolicy::Csr);
        let bsr = build(FormatPolicy::Bsr);
        let auto = build(FormatPolicy::Auto);
        assert!(
            bsr.plan
                .layers
                .values()
                .all(|lp| matches!(lp.format, SparseFormat::Bsr { .. })),
            "Bsr policy must block every pruned layer: {:?}",
            bsr.plan
        );
        let out_csr = csr.execute(&x).unwrap();
        let out_bsr = bsr.execute(&x).unwrap();
        let out_auto = auto.execute(&x).unwrap();
        assert!(out_csr.max_abs_diff(&out_bsr) < 1e-3, "{}", out_csr.max_abs_diff(&out_bsr));
        assert!(out_csr.max_abs_diff(&out_auto) < 1e-3, "{}", out_csr.max_abs_diff(&out_auto));
    }

    /// A pattern-structured profile must reach the pattern format under
    /// Auto planning and compute the same function as the CSR baseline
    /// on the identical pruned weights.
    #[test]
    fn pattern_profile_plans_and_executes_pattern_format() {
        use crate::ir::Shape;
        let mut g = Graph::new("minipattern", Shape::nhwc(1, 8, 8, 8));
        let c1 = g.add("c1", Op::conv(3, 3, 8, 32, 1, 1), vec![0]);
        let b1 = g.add("c1_bn", Op::BatchNorm { c: 32 }, vec![c1]);
        g.add("c1_relu", Op::Activation { kind: ActKind::Relu }, vec![b1]);
        g.validate().unwrap();
        let x = input_for(&g, 13);

        let profile = SparsityProfile::uniform_structured(
            &g,
            0.8,
            PruneStructure::Pattern { entries: 4 },
        );
        let build = |policy: FormatPolicy| {
            ModelInstance::build_planned(
                &g,
                Personality::CadnnSparse,
                Some(&profile),
                None,
                1 << 20,
                policy,
            )
            .unwrap()
        };
        let auto = build(FormatPolicy::Auto);
        assert_eq!(
            auto.plan.get("c1").map(|lp| lp.format),
            Some(SparseFormat::Pattern),
            "pattern-pruned conv must plan Pattern: {:?}",
            auto.plan
        );
        assert!(
            matches!(auto.weights.get(&1), Some(NodeWeights::PatternSparse { .. })),
            "payload must be rewritten to the pattern encoding"
        );
        let csr = build(FormatPolicy::Csr);
        let out_a = auto.execute(&x).unwrap();
        let out_c = csr.execute(&x).unwrap();
        assert!(out_a.max_abs_diff(&out_c) < 1e-3, "{}", out_a.max_abs_diff(&out_c));
    }

    /// The quantized-payload acceptance at the instance level: a
    /// pattern-pruned profile with an exported codebook width makes Auto
    /// planning choose a quantized pattern payload; the build rewrites
    /// the weights to `QuantSparse`; execution runs the LUT kernel and
    /// stays within the fit's propagated error bound of the f32 path.
    #[test]
    fn quantized_pattern_profile_builds_and_executes_lut_payload() {
        use crate::compress::qsparse::ValueBits;
        use crate::ir::Shape;
        let mut g = Graph::new("miniquant", Shape::nhwc(1, 8, 8, 8));
        let c1 = g.add("c1", Op::conv(3, 3, 8, 32, 1, 1), vec![0]);
        let b1 = g.add("c1_bn", Op::BatchNorm { c: 32 }, vec![c1]);
        g.add("c1_relu", Op::Activation { kind: ActKind::Relu }, vec![b1]);
        g.validate().unwrap();
        let x = input_for(&g, 19);

        let profile = SparsityProfile::uniform_structured(
            &g,
            0.8,
            PruneStructure::Pattern { entries: 4 },
        );
        let build = |p: &SparsityProfile, vp: ValuePolicy| {
            ModelInstance::build_planned_cached(
                &g,
                Personality::CadnnSparse,
                Some(p),
                None,
                1 << 20,
                FormatPolicy::Auto,
                vp,
                None,
            )
            .unwrap()
        };
        // without a declared codebook, Auto stays f32
        let f32_inst = build(&profile, ValuePolicy::Auto);
        let lp = f32_inst.plan.get("c1").unwrap();
        assert_eq!(lp.format, SparseFormat::Pattern);
        assert_eq!(lp.value_bits, ValueBits::F32);

        // with the exported codebook, Auto selects the quantized payload
        let qprofile = profile.clone().with_uniform_quant(4);
        let q_inst = build(&qprofile, ValuePolicy::Auto);
        let qlp = q_inst.plan.get("c1").unwrap();
        assert_eq!(qlp.format, SparseFormat::Pattern);
        assert_eq!(qlp.value_bits, ValueBits::Q4);
        assert!(
            qlp.cost_per_row > lp.cost_per_row,
            "the plan must price the LUT gather: {} vs {}",
            qlp.cost_per_row,
            lp.cost_per_row
        );
        let Some(NodeWeights::QuantSparse { mat, .. }) = q_inst.weights.get(&1) else {
            panic!("payload must be rewritten to the quantized encoding");
        };
        let QSparseMatrix::Pattern(qpat) = mat else {
            panic!("pattern plan must carry a pattern payload, got {mat:?}");
        };
        let eb = qpat.values.error_bound() as f64;

        // both instances prune identically (same deterministic weights +
        // profile), so |Δweight| <= eb elementwise with equal support:
        // each output differs by at most eb * sum|activation| per column
        // <= eb * max|x| * K, scaled by the BN epilogue's max |scale|
        let out_f = f32_inst.execute(&x).unwrap();
        let out_q = q_inst.execute(&x).unwrap();
        let k = (3 * 3 * 8) as f64;
        let amax = x.data.iter().fold(0.0f32, |m, v| m.max(v.abs())) as f64;
        let scale_max = 1.5; // gen_bn scales are 0.5 + U[0,1)
        let bound = (eb * amax * k * scale_max).max(1e-6) + 1e-4;
        let diff = out_f.max_abs_diff(&out_q);
        assert!(diff as f64 <= bound, "diff {diff} exceeds propagated bound {bound}");
        assert!(diff > 0.0, "q4 on rich values must actually differ from f32");

        // pinning F32 on the quantized profile restores the f32 payload
        let pinned = build(&qprofile, ValuePolicy::F32);
        assert_eq!(pinned.plan.get("c1").unwrap().value_bits, ValueBits::F32);
        assert!(matches!(pinned.weights.get(&1), Some(NodeWeights::PatternSparse { .. })));
        assert_eq!(pinned.execute(&x).unwrap().data, out_f.data);
    }

    /// One `PlanCache` across batch variants: the cached build produces
    /// the same plan, weights, and outputs as the uncached build, and
    /// per-variant plan costs scale with the batch while the per-image
    /// cost stays put.
    #[test]
    fn shared_plan_cache_matches_uncached_builds() {
        let g1 = models::build("lenet5", 1).unwrap();
        let g4 = models::build("lenet5", 4).unwrap();
        let profile = SparsityProfile::uniform(&g1, 0.8);
        let mut cache = planner::PlanCache::default();
        let build = |g: &Graph, c: Option<&mut planner::PlanCache>| {
            ModelInstance::build_planned_cached(
                g,
                Personality::CadnnSparse,
                Some(&profile),
                None,
                1 << 20,
                FormatPolicy::Auto,
                ValuePolicy::Auto,
                c,
            )
            .unwrap()
        };
        let i1 = build(&g1, Some(&mut cache));
        let i4 = build(&g4, Some(&mut cache));
        let fresh4 = build(&g4, None);
        assert_eq!(i4.plan, fresh4.plan, "cache must not change planning");
        let x = input_for(&g4, 17);
        let a = i4.execute(&x).unwrap();
        let b = fresh4.execute(&x).unwrap();
        assert_eq!(a.data, b.data, "cache must not change execution");
        // per-batch-variant plan costs: affine in the batch size
        let (c1, c4) = (i1.plan_cost().unwrap(), i4.plan_cost().unwrap());
        assert!(c4 > c1, "batch-4 cost {c4} must exceed batch-1 cost {c1}");
        assert_eq!(i1.batch(), 1);
        assert_eq!(i4.batch(), 4);
        let per_image = i1.plan.per_image_cost();
        assert!((i4.plan.per_image_cost() - per_image).abs() < 1e-9);
        assert!((c4 - c1 - 3.0 * per_image).abs() < 1e-6, "cost must be affine in m");
    }

    #[test]
    fn prune_matrix_exact_cut_with_ties() {
        // tied magnitudes must not change the cut count
        let mut mat = vec![0.5f32; 10];
        mat[3] = 0.1;
        mat[7] = -0.9;
        prune_matrix(&mut mat, 0.5);
        let zeros = mat.iter().filter(|v| **v == 0.0).count();
        assert_eq!(zeros, 5);
        assert_eq!(mat[7], -0.9, "largest magnitude must survive");
        assert_eq!(mat[3], 0.0, "smallest magnitude must be pruned");
    }

    #[test]
    fn batch_execution_shapes() {
        let g = models::build("lenet5", 4).unwrap();
        let x = input_for(&g, 7);
        let inst = ModelInstance::build(&g, Personality::TvmLike, None, None, 1 << 20).unwrap();
        let out = inst.execute(&x).unwrap();
        assert_eq!(out.shape, vec![4, 10]);
        // softmax rows
        for r in 0..4 {
            let s: f32 = out.data[r * 10..(r + 1) * 10].iter().sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn wrong_input_shape_rejected() {
        let g = models::build("lenet5", 1).unwrap();
        let inst = ModelInstance::build(&g, Personality::TvmLike, None, None, 1 << 20).unwrap();
        let bad = Tensor::zeros(&[1, 27, 28, 1]);
        match inst.execute(&bad) {
            Err(CadnnError::InputShape { expected, got }) => {
                assert_eq!(expected, vec![1, 28, 28, 1]);
                assert_eq!(got, vec![1, 27, 28, 1]);
            }
            other => panic!("expected InputShape error, got {other:?}"),
        }
    }

    #[test]
    fn scratch_reuses_buffers_across_runs() {
        let g = models::build("lenet5", 1).unwrap();
        let inst = ModelInstance::build(&g, Personality::TvmLike, None, None, 1 << 20).unwrap();
        let x = input_for(&g, 11);
        let mut s = inst.scratch();

        let a = inst.execute_with(&x, &mut s).unwrap();
        assert!(s.buffer_allocs() > 0);
        s.recycle(a.clone());
        let after_first = s.buffer_allocs();

        let b = inst.execute_with(&x, &mut s).unwrap();
        assert_eq!(a.data, b.data, "reused buffers changed the result");
        assert!(s.buffer_reuses() > 0, "second run must hit the pool");
        s.recycle(b);
        let after_second = s.buffer_allocs();

        let c = inst.execute_with(&x, &mut s).unwrap();
        assert_eq!(a.data, c.data);
        assert_eq!(
            s.buffer_allocs(),
            after_second,
            "steady state must stop allocating (first run: {after_first})"
        );
    }
}
