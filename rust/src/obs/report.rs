//! Predicted-vs-measured cost residuals: the feedback half of the
//! planner's cost model.
//!
//! [`ExecPlan::cost_at`](crate::planner::ExecPlan) predicts per-layer
//! cost in abstract *units* (`COST_*` constants x work items); `exec`
//! spans carry that prediction (`pred_units`) next to the measured
//! wall-clock µs. A [`CostReport`] fits the single global `us_per_unit`
//! scale by least squares through the origin, then expresses each
//! (op, format) group's deviation as a multiplicative **residual**:
//!
//! - residual ≈ 1.0 — the `COST_*` constant for that format is
//!   consistent with the others,
//! - residual > 1.0 — the format is *slower* than the model thinks
//!   (its constant should grow by that factor),
//! - residual < 1.0 — faster; the constant should shrink.
//!
//! `cadnn calibrate --cost-report <file>` turns the residuals into
//! concrete suggested values for `planner::COST_*` — closing the
//! measure → re-fit loop from ROADMAP item 1.

use super::{ArgValue, Span, CAT_EXEC};
use crate::util::json::Json;

/// Aggregated spans for one (op, format) pair. `format` is the layer
/// plan's format label with a `+q8` / `+q4` suffix when the payload is
/// quantized (LUT kernels have their own cost constants).
#[derive(Debug, Clone, PartialEq)]
pub struct CostGroup {
    pub op: String,
    pub format: String,
    /// Number of spans aggregated.
    pub spans: u64,
    /// Total planner-predicted cost (abstract units).
    pub pred_units: f64,
    /// Total measured wall-clock µs.
    pub measured_us: f64,
    /// This group's own scale: `measured_us / pred_units`.
    pub us_per_unit: f64,
    /// `us_per_unit / global us_per_unit` — the factor by which the
    /// format's `COST_*` constant under- (>1) or over- (<1) predicts.
    pub residual: f64,
}

/// Residual summary over one profiled run; see the module doc.
#[derive(Debug, Clone, PartialEq)]
pub struct CostReport {
    /// Global scale fitted over all groups: least squares through the
    /// origin, `Σ(us·pred) / Σ(pred²)`.
    pub us_per_unit: f64,
    /// Exec spans that carried a prediction (spans with no plan entry
    /// contribute nothing).
    pub spans: u64,
    /// Groups sorted by measured µs, heaviest first.
    pub groups: Vec<CostGroup>,
}

/// Map a group's format label to the `planner::COST_*` constant it
/// calibrates: `(constant name, current value)`. Quantized payloads map
/// to the LUT constants regardless of the container format. Shared with
/// the drift watchdog ([`super::drift`]), which names the stale
/// constant in its events.
pub(crate) fn cost_constant(format: &str) -> Option<(&'static str, f64)> {
    use crate::planner as p;
    if format.ends_with("+q8") {
        return Some(("COST_LUT_Q8", p::COST_LUT_Q8));
    }
    if format.ends_with("+q4") {
        return Some(("COST_LUT_Q4", p::COST_LUT_Q4));
    }
    match format {
        "dense" => Some(("COST_DENSE_MAC", p::COST_DENSE_MAC)),
        "csr" => Some(("COST_CSR_NNZ", p::COST_CSR_NNZ)),
        "bsr4x1" => Some(("COST_BSR_4X1", p::COST_BSR_4X1)),
        "bsr4x4" => Some(("COST_BSR_4X4", p::COST_BSR_4X4)),
        "pattern" => Some(("COST_PATTERN_VAL", p::COST_PATTERN_VAL)),
        _ => None,
    }
}

impl CostReport {
    /// Build a report from drained spans: keep `exec`-category spans
    /// whose `pred_units` arg is present and positive, group by
    /// (op, format), fit the global scale, compute residuals.
    pub fn from_spans(spans: &[Span]) -> CostReport {
        let mut groups: Vec<CostGroup> = Vec::new();
        let mut total_spans = 0u64;
        for s in spans {
            if s.cat != CAT_EXEC {
                continue;
            }
            let pred = match s.num_arg("pred_units") {
                Some(p) if p > 0.0 => p,
                _ => continue,
            };
            let op = s.str_arg("op").unwrap_or("?").to_string();
            let format = s.str_arg("format").unwrap_or("?").to_string();
            total_spans += 1;
            match groups.iter_mut().find(|g| g.op == op && g.format == format) {
                Some(g) => {
                    g.spans += 1;
                    g.pred_units += pred;
                    g.measured_us += s.dur_us;
                }
                None => groups.push(CostGroup {
                    op,
                    format,
                    spans: 1,
                    pred_units: pred,
                    measured_us: s.dur_us,
                    us_per_unit: 0.0,
                    residual: 0.0,
                }),
            }
        }
        // Global fit: minimize Σ(us_i - k·pred_i)² over the groups.
        let num: f64 = groups.iter().map(|g| g.measured_us * g.pred_units).sum();
        let den: f64 = groups.iter().map(|g| g.pred_units * g.pred_units).sum();
        let global = if den > 0.0 { num / den } else { 0.0 };
        for g in &mut groups {
            g.us_per_unit = g.measured_us / g.pred_units;
            g.residual = if global > 0.0 { g.us_per_unit / global } else { 0.0 };
        }
        groups.sort_by(|a, b| {
            b.measured_us.partial_cmp(&a.measured_us).unwrap_or(std::cmp::Ordering::Equal)
        });
        CostReport { us_per_unit: global, spans: total_spans, groups }
    }

    /// Suggested re-fits for `planner::COST_*`:
    /// `(constant name, current value, suggested = current x residual)`.
    /// Residuals of format groups sharing a constant (e.g. several conv
    /// ops on `csr`) are combined weighted by predicted units. Formats
    /// with no matching constant are skipped.
    pub fn suggestions(&self) -> Vec<(&'static str, f64, f64)> {
        let mut out: Vec<(&'static str, f64, f64, f64)> = Vec::new();
        for g in &self.groups {
            let Some((name, current)) = cost_constant(&g.format) else { continue };
            match out.iter_mut().find(|e| e.0 == name) {
                // Accumulate (Σ residual·weight, Σ weight) per constant.
                Some(e) => {
                    e.2 += g.residual * g.pred_units;
                    e.3 += g.pred_units;
                }
                None => out.push((name, current, g.residual * g.pred_units, g.pred_units)),
            }
        }
        out.into_iter()
            .filter(|&(_, _, _, w)| w > 0.0)
            .map(|(name, current, rw, w)| (name, current, current * (rw / w)))
            .collect()
    }

    /// Human-readable table for `cadnn calibrate` / `cadnn profile`.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "cost model fit: {} spans, global scale {:.4} us/unit\n",
            self.spans, self.us_per_unit
        ));
        s.push_str(&format!(
            "{:<12} {:<12} {:>6} {:>12} {:>12} {:>10} {:>9}\n",
            "op", "format", "spans", "pred_units", "measured_us", "us/unit", "residual"
        ));
        for g in &self.groups {
            s.push_str(&format!(
                "{:<12} {:<12} {:>6} {:>12.1} {:>12.1} {:>10.4} {:>9.3}\n",
                g.op, g.format, g.spans, g.pred_units, g.measured_us, g.us_per_unit, g.residual
            ));
        }
        let sug = self.suggestions();
        if !sug.is_empty() {
            s.push_str("suggested planner constants (current -> refit):\n");
            for (name, current, suggested) in sug {
                s.push_str(&format!("  {name:<18} {current:.3} -> {suggested:.3}\n"));
            }
        }
        s
    }

    /// Serialize for `cadnn profile --cost-report <file>`.
    pub fn to_json(&self) -> Json {
        let groups = self
            .groups
            .iter()
            .map(|g| {
                Json::Obj(vec![
                    ("op".into(), Json::Str(g.op.clone())),
                    ("format".into(), Json::Str(g.format.clone())),
                    ("spans".into(), Json::Num(g.spans as f64)),
                    ("pred_units".into(), Json::Num(g.pred_units)),
                    ("measured_us".into(), Json::Num(g.measured_us)),
                    ("us_per_unit".into(), Json::Num(g.us_per_unit)),
                    ("residual".into(), Json::Num(g.residual)),
                ])
            })
            .collect();
        Json::Obj(vec![
            ("us_per_unit".into(), Json::Num(self.us_per_unit)),
            ("spans".into(), Json::Num(self.spans as f64)),
            ("groups".into(), Json::Arr(groups)),
        ])
    }

    /// Inverse of [`CostReport::to_json`] — what `cadnn calibrate
    /// --cost-report <file>` reads back.
    pub fn from_json(j: &Json) -> Result<CostReport, String> {
        let num = |o: &Json, k: &str| {
            o.get(k).and_then(|v| v.as_f64()).ok_or_else(|| format!("missing number '{k}'"))
        };
        let us_per_unit = num(j, "us_per_unit")?;
        let spans = num(j, "spans")? as u64;
        let raw = j
            .get("groups")
            .and_then(|g| g.as_arr())
            .ok_or_else(|| "missing groups array".to_string())?;
        let mut groups = Vec::with_capacity(raw.len());
        for (i, g) in raw.iter().enumerate() {
            let txt = |k: &str| {
                g.get(k)
                    .and_then(|v| v.as_str())
                    .map(str::to_string)
                    .ok_or_else(|| format!("group {i}: missing string '{k}'"))
            };
            groups.push(CostGroup {
                op: txt("op")?,
                format: txt("format")?,
                spans: num(g, "spans")? as u64,
                pred_units: num(g, "pred_units")?,
                measured_us: num(g, "measured_us")?,
                us_per_unit: num(g, "us_per_unit")?,
                residual: num(g, "residual")?,
            });
        }
        Ok(CostReport { us_per_unit, spans, groups })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::CAT_SERVE;

    fn exec_span(op: &str, format: &str, pred: f64, us: f64) -> Span {
        Span {
            cat: CAT_EXEC,
            name: format!("{op}-node"),
            start_us: 0.0,
            dur_us: us,
            tid: 1,
            trace: 0,
            args: vec![
                ("op", ArgValue::Str(op.to_string())),
                ("format", ArgValue::Str(format.to_string())),
                ("pred_units", ArgValue::Num(pred)),
            ],
        }
    }

    #[test]
    fn residuals_recover_a_known_skew() {
        // Two groups, same predicted units; csr measures 2x slower than
        // bsr4x4. Global fit k = Σ(us·pred)/Σ(pred²) with pred=1000 each:
        // (2000·1000 + 1000·1000) / (2·1000²) = 1.5 us/unit.
        let spans = vec![
            exec_span("conv2d", "csr", 1000.0, 2000.0),
            exec_span("conv2d", "bsr4x4", 1000.0, 1000.0),
        ];
        let r = CostReport::from_spans(&spans);
        assert_eq!(r.spans, 2);
        assert!((r.us_per_unit - 1.5).abs() < 1e-12);
        // heaviest (csr, 2000us) first
        assert_eq!(r.groups[0].format, "csr");
        assert!((r.groups[0].residual - 2.0 / 1.5).abs() < 1e-12);
        assert!((r.groups[1].residual - 1.0 / 1.5).abs() < 1e-12);
        // suggestions scale the current constants by the residuals
        let sug = r.suggestions();
        let csr = sug.iter().find(|s| s.0 == "COST_CSR_NNZ").unwrap();
        assert!((csr.2 - csr.1 * (2.0 / 1.5)).abs() < 1e-9);
        let bsr = sug.iter().find(|s| s.0 == "COST_BSR_4X4").unwrap();
        assert!((bsr.2 - bsr.1 * (1.0 / 1.5)).abs() < 1e-9);
    }

    #[test]
    fn perfect_model_residuals_are_one() {
        let spans = vec![
            exec_span("conv2d", "csr", 500.0, 250.0),
            exec_span("dense", "dense", 2000.0, 1000.0),
        ];
        let r = CostReport::from_spans(&spans);
        assert!((r.us_per_unit - 0.5).abs() < 1e-12);
        for g in &r.groups {
            assert!((g.residual - 1.0).abs() < 1e-12, "{g:?}");
        }
    }

    #[test]
    fn ignores_unpredicted_and_non_exec_spans() {
        let mut serve = exec_span("request", "csr", 100.0, 50.0);
        serve.cat = CAT_SERVE;
        let mut unplanned = exec_span("relu", "csr", 0.0, 10.0);
        unplanned.args.retain(|(k, _)| *k != "pred_units");
        let spans = vec![serve, unplanned, exec_span("conv2d", "csr", 100.0, 70.0)];
        let r = CostReport::from_spans(&spans);
        assert_eq!(r.spans, 1);
        assert_eq!(r.groups.len(), 1);
    }

    #[test]
    fn quantized_formats_map_to_lut_constants() {
        let spans = vec![
            exec_span("conv2d", "csr+q8", 100.0, 100.0),
            exec_span("conv2d", "bsr4x1+q4", 100.0, 100.0),
        ];
        let sug = CostReport::from_spans(&spans).suggestions();
        assert!(sug.iter().any(|s| s.0 == "COST_LUT_Q8"));
        assert!(sug.iter().any(|s| s.0 == "COST_LUT_Q4"));
    }

    #[test]
    fn json_round_trip() {
        let spans = vec![
            exec_span("conv2d", "csr", 1000.0, 2000.0),
            exec_span("dense", "dense", 400.0, 300.0),
        ];
        let r = CostReport::from_spans(&spans);
        let back = CostReport::from_json(&r.to_json()).unwrap();
        assert_eq!(back, r);
        assert!(CostReport::from_json(&Json::Obj(vec![])).is_err());
    }

    #[test]
    fn render_mentions_suggestions() {
        let spans = vec![exec_span("conv2d", "pattern", 100.0, 100.0)];
        let txt = CostReport::from_spans(&spans).render();
        assert!(txt.contains("COST_PATTERN_VAL"));
        assert!(txt.contains("pattern"));
    }

    #[test]
    fn empty_input_is_well_formed() {
        let r = CostReport::from_spans(&[]);
        assert_eq!(r.spans, 0);
        assert_eq!(r.us_per_unit, 0.0);
        assert!(r.groups.is_empty());
        assert!(r.suggestions().is_empty());
    }
}
