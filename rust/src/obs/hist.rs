//! Fixed-bucket log₂ latency histograms.
//!
//! A [`Log2Hist`] is a lock-free accumulator: 40 power-of-two buckets
//! plus exact count / sum / min / max, every field a relaxed atomic, so
//! single-writer recording never contends with snapshot readers (the
//! serve worker records, [`crate::serve::Server::stats`] reads). The
//! bucket layout is pinned:
//!
//! - bucket 0 holds values `v < 1` (µs),
//! - bucket `i ≥ 1` holds `2^(i-1) <= v < 2^i`,
//! - the last bucket (39) is open-ended above `2^38` µs (~76 hours).
//!
//! Percentiles come from the bucket walk: nearest-rank over cumulative
//! counts, reported as the containing bucket's *upper edge* clamped to
//! the exact observed `[min, max]`. That makes the estimate conservative
//! (never under-reports) and at most 2x the true value — and exact
//! whenever all samples in the tail bucket are equal (min == max case).

use crate::util::json::Json;
use crate::util::stats::Summary;
use std::sync::atomic::{AtomicU64, Ordering};

/// Number of fixed buckets. Bucket 39 covers everything above 2^38 µs.
pub const BUCKETS: usize = 40;

/// Bucket index for a sample in microseconds (layout in the module doc).
/// Non-finite and negative samples land in bucket 0.
#[inline]
pub fn bucket_of(v_us: f64) -> usize {
    if !(v_us >= 1.0) {
        return 0;
    }
    let f = v_us.floor() as u64;
    let b = (63 - f.leading_zeros()) as usize + 1;
    b.min(BUCKETS - 1)
}

/// Upper edge (exclusive) of bucket `i`, in microseconds.
pub fn bucket_upper_us(i: usize) -> f64 {
    if i == 0 {
        1.0
    } else {
        (1u64 << i.min(63)) as f64
    }
}

/// Lock-free log₂ histogram (all-relaxed atomics; see module doc).
#[derive(Debug)]
pub struct Log2Hist {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    /// Sum of samples rounded to whole microseconds (mean to ±0.5µs).
    sum_us: AtomicU64,
    /// `u64::MAX` while empty.
    min_us: AtomicU64,
    max_us: AtomicU64,
}

impl Log2Hist {
    pub const fn new() -> Log2Hist {
        #[allow(clippy::declare_interior_mutable_const)]
        const Z: AtomicU64 = AtomicU64::new(0);
        Log2Hist {
            buckets: [Z; BUCKETS],
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            min_us: AtomicU64::new(u64::MAX),
            max_us: AtomicU64::new(0),
        }
    }

    /// Record one sample (µs). Never blocks: five relaxed atomic ops.
    pub fn record(&self, v_us: f64) {
        let v = if v_us.is_finite() && v_us > 0.0 { v_us } else { 0.0 };
        let w = v.round() as u64;
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(w, Ordering::Relaxed);
        self.min_us.fetch_min(w, Ordering::Relaxed);
        self.max_us.fetch_max(w, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn clear(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_us.store(0, Ordering::Relaxed);
        self.min_us.store(u64::MAX, Ordering::Relaxed);
        self.max_us.store(0, Ordering::Relaxed);
    }

    /// Freeze into a plain-data view; `None` while empty. Relaxed reads:
    /// a snapshot taken concurrently with recording may be mid-sample by
    /// one count, which is fine for metrics.
    pub fn snapshot(&self) -> Option<HistSnapshot> {
        let count = self.count.load(Ordering::Relaxed);
        if count == 0 {
            return None;
        }
        let mut buckets = Vec::new();
        for (i, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            if c > 0 {
                buckets.push((bucket_upper_us(i), c));
            }
        }
        Some(HistSnapshot {
            count,
            mean_us: self.sum_us.load(Ordering::Relaxed) as f64 / count as f64,
            min_us: self.min_us.load(Ordering::Relaxed) as f64,
            max_us: self.max_us.load(Ordering::Relaxed) as f64,
            buckets,
        })
    }
}

impl Default for Log2Hist {
    fn default() -> Self {
        Self::new()
    }
}

/// Point-in-time view of a [`Log2Hist`]: non-empty buckets as
/// `(upper_edge_us, count)` pairs in ascending edge order, plus exact
/// count / mean / min / max.
#[derive(Debug, Clone, PartialEq)]
pub struct HistSnapshot {
    pub count: u64,
    pub mean_us: f64,
    pub min_us: f64,
    pub max_us: f64,
    pub buckets: Vec<(f64, u64)>,
}

impl HistSnapshot {
    /// Nearest-rank quantile (`q` in [0, 1]): the upper edge of the
    /// bucket holding rank `ceil(q * count)`, clamped to `[min, max]`.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for &(edge, c) in &self.buckets {
            cum += c;
            if cum >= rank {
                return edge.clamp(self.min_us, self.max_us);
            }
        }
        self.max_us
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Bridge to the crate-wide [`Summary`] shape (what
    /// [`crate::serve::MetricsSnapshot`] carried before histograms):
    /// exact count / mean / min / max, bucket-walk percentiles.
    pub fn summary(&self) -> Summary {
        Summary {
            count: self.count as usize,
            mean: self.mean_us,
            min: self.min_us,
            p50: self.quantile(0.50),
            p90: self.quantile(0.90),
            p95: self.quantile(0.95),
            p99: self.quantile(0.99),
            max: self.max_us,
        }
    }

    /// Combine two snapshots as if every sample had been recorded into
    /// one histogram: bucket-wise count addition (merge-join on the
    /// bucket edges, which are exact powers of two, so `f64` equality is
    /// sound), exact count / min / max, count-weighted mean. The
    /// operation is associative and commutative — merging replica
    /// snapshots in any order or grouping yields the identical result,
    /// which `rust/tests/observability.rs` pins against a
    /// single-recorder oracle.
    pub fn merge(&self, other: &HistSnapshot) -> HistSnapshot {
        let count = self.count + other.count;
        let mut buckets: Vec<(f64, u64)> =
            Vec::with_capacity(self.buckets.len() + other.buckets.len());
        let (mut i, mut j) = (0, 0);
        while i < self.buckets.len() || j < other.buckets.len() {
            match (self.buckets.get(i), other.buckets.get(j)) {
                (Some(&(ea, ca)), Some(&(eb, cb))) if ea == eb => {
                    buckets.push((ea, ca + cb));
                    i += 1;
                    j += 1;
                }
                (Some(&(ea, ca)), Some(&(eb, _))) if ea < eb => {
                    buckets.push((ea, ca));
                    i += 1;
                }
                (Some(_), Some(&(eb, cb))) => {
                    buckets.push((eb, cb));
                    j += 1;
                }
                (Some(&(ea, ca)), None) => {
                    buckets.push((ea, ca));
                    i += 1;
                }
                (None, Some(&(eb, cb))) => {
                    buckets.push((eb, cb));
                    j += 1;
                }
                (None, None) => unreachable!("loop condition"),
            }
        }
        // means are integer-µs sums over counts, so the count-weighted
        // recombination reproduces the joint mean exactly
        let mean_us = if count == 0 {
            0.0
        } else {
            (self.mean_us * self.count as f64 + other.mean_us * other.count as f64) / count as f64
        };
        HistSnapshot {
            count,
            mean_us,
            min_us: self.min_us.min(other.min_us),
            max_us: self.max_us.max(other.max_us),
            buckets,
        }
    }

    /// JSON shape used by the benches' `BENCH_*.json` emissions.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("count".into(), Json::Num(self.count as f64)),
            ("mean_us".into(), Json::Num(self.mean_us)),
            ("min_us".into(), Json::Num(self.min_us)),
            ("max_us".into(), Json::Num(self.max_us)),
            ("p50_us".into(), Json::Num(self.p50())),
            ("p95_us".into(), Json::Num(self.p95())),
            ("p99_us".into(), Json::Num(self.p99())),
            (
                "buckets".into(),
                Json::Arr(
                    self.buckets
                        .iter()
                        .map(|&(edge, c)| {
                            Json::Arr(vec![Json::Num(edge), Json::Num(c as f64)])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_pinned() {
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(0.5), 0);
        assert_eq!(bucket_of(-3.0), 0);
        assert_eq!(bucket_of(f64::NAN), 0);
        assert_eq!(bucket_of(1.0), 1);
        assert_eq!(bucket_of(1.99), 1);
        assert_eq!(bucket_of(2.0), 2);
        assert_eq!(bucket_of(3.0), 2);
        assert_eq!(bucket_of(4.0), 3);
        assert_eq!(bucket_of(1023.0), 10);
        assert_eq!(bucket_of(1024.0), 11);
        assert_eq!(bucket_of(1e18), BUCKETS - 1);
        assert_eq!(bucket_upper_us(0), 1.0);
        assert_eq!(bucket_upper_us(1), 2.0);
        assert_eq!(bucket_upper_us(11), 2048.0);
    }

    #[test]
    fn empty_hist_snapshots_none() {
        let h = Log2Hist::new();
        assert!(h.snapshot().is_none());
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn single_sample_percentiles_exact() {
        let h = Log2Hist::new();
        h.record(3000.0);
        let s = h.snapshot().unwrap();
        // 3000µs sits in [2048, 4096) but the max clamp makes the
        // single-sample percentile exact
        assert_eq!(s.p50(), 3000.0);
        assert_eq!(s.p99(), 3000.0);
        assert_eq!(s.min_us, 3000.0);
        assert_eq!(s.max_us, 3000.0);
        assert_eq!(s.count, 1);
        assert_eq!(s.mean_us, 3000.0);
    }

    #[test]
    fn uniform_ramp_p99_pinned() {
        let h = Log2Hist::new();
        for v in 0..1000 {
            h.record(v as f64);
        }
        let s = h.snapshot().unwrap();
        // rank ceil(0.99 * 1000) = 990 lands in [512, 1024); the upper
        // edge 1024 clamps to the observed max 999
        assert_eq!(s.p99(), 999.0);
        // rank 500 lands in [256, 512): edge 512
        assert_eq!(s.p50(), 512.0);
        assert!(s.p50() <= s.p95() && s.p95() <= s.p99());
        assert_eq!(s.count, 1000);
        assert_eq!(s.min_us, 0.0);
    }

    #[test]
    fn percentile_overestimates_bounded_by_2x() {
        let h = Log2Hist::new();
        for v in [100.0, 200.0, 400.0, 800.0, 1600.0] {
            h.record(v);
        }
        let s = h.snapshot().unwrap();
        // true p50 is 400; bucket [256, 512) reports 512 <= 2 * 400
        assert_eq!(s.p50(), 512.0);
        assert!(s.p50() <= 2.0 * 400.0);
    }

    #[test]
    fn summary_bridge_and_json() {
        let h = Log2Hist::new();
        h.record(1000.0);
        h.record(3000.0);
        let s = h.snapshot().unwrap();
        let sum = s.summary();
        assert_eq!(sum.count, 2);
        assert_eq!(sum.mean, 2000.0);
        assert_eq!(sum.min, 1000.0);
        assert_eq!(sum.max, 3000.0);
        let j = s.to_json();
        assert_eq!(j.get("count").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(j.get("buckets").and_then(|b| b.as_arr()).unwrap().len(), 2);
    }

    #[test]
    fn merge_equals_single_recorder() {
        let samples = [3.0, 17.0, 900.0, 900.0, 4_000.0, 65.0, 0.4, 1.0];
        let (a, b, all) = (Log2Hist::new(), Log2Hist::new(), Log2Hist::new());
        for (i, &v) in samples.iter().enumerate() {
            if i % 2 == 0 { a.record(v) } else { b.record(v) }
            all.record(v);
        }
        let (sa, sb) = (a.snapshot().unwrap(), b.snapshot().unwrap());
        let oracle = all.snapshot().unwrap();
        let merged = sa.merge(&sb);
        assert_eq!(merged, oracle, "merge reproduces the single-recorder snapshot");
        assert_eq!(sb.merge(&sa), oracle, "commutative");
    }

    #[test]
    fn merge_is_associative() {
        let hs: Vec<Log2Hist> = (0..3).map(|_| Log2Hist::new()).collect();
        for (i, v) in [2.0, 40.0, 500.0, 7.0, 123.0, 9_000.0].iter().enumerate() {
            hs[i % 3].record(*v);
        }
        let s: Vec<HistSnapshot> = hs.iter().map(|h| h.snapshot().unwrap()).collect();
        assert_eq!(s[0].merge(&s[1]).merge(&s[2]), s[0].merge(&s[1].merge(&s[2])));
    }

    #[test]
    fn clear_resets() {
        let h = Log2Hist::new();
        h.record(5.0);
        h.clear();
        assert!(h.snapshot().is_none());
        h.record(7.0);
        assert_eq!(h.snapshot().unwrap().count, 1);
    }
}
