//! `cadnn::obs` — low-overhead tracing and profiling for every layer of
//! the stack (the paper's 26ms headline is a per-microsecond accounting
//! claim; this module is how the repo makes that accounting).
//!
//! Design (`docs/OBSERVABILITY.md` has the full walkthrough):
//!
//! - **Gate.** A single `AtomicBool` ([`enable`] / [`disable`]); every
//!   probe site checks [`on`] first, so the disabled cost is one relaxed
//!   load per site. Building with `--no-default-features` (dropping the
//!   `obs` cargo feature) turns [`on`] into a compile-time `false` and
//!   the probes vanish entirely.
//! - **Spans.** Thread-local ring buffers ([`RING_CAPACITY`] spans per
//!   thread, oldest dropped and counted on overflow). The hot path never
//!   blocks: a thread writes its own ring through `try_lock`, which only
//!   a concurrent [`drain`] can contend with — contended writes are
//!   dropped and counted instead of waiting.
//! - **Counters.** A fixed global array of relaxed `AtomicU64`s keyed by
//!   [`Counter`] — what the kernels record (rows, nnz, panels,
//!   parallel-vs-serial path) with zero allocation.
//! - **Exporters.** [`trace::chrome_trace`] (Chrome trace-event JSON for
//!   `chrome://tracing` / Perfetto), [`hist::Log2Hist`] (the latency
//!   histograms behind [`crate::serve::MetricsSnapshot`]), and
//!   [`report::CostReport`] (predicted-vs-measured cost residuals that
//!   `cadnn calibrate --cost-report` consumes to re-fit
//!   `planner::COST_*`).
//!
//! Instrumentation map: `exec` emits one span per executed node (op,
//! format, value_bits, rows, predicted cost units); `kernels` bump
//! counters; `serve` emits request lifecycle spans (enqueue →
//! batch-formed → executed → replied, with deadline slack).

pub mod drift;
pub mod export;
pub mod hist;
pub mod report;
pub mod sample;
pub mod trace;

pub use drift::{DriftConfig, DriftEvent, DriftWatchdog};
pub use export::{TelemetryLine, TelemetryWriter};
pub use hist::{HistSnapshot, Log2Hist};
pub use report::{CostGroup, CostReport};
pub use sample::{SampleConfig, Sampler};

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Spans recorded per thread before the oldest is dropped (and counted
/// in [`dropped_spans`]). 16Ki spans ≈ 2MiB per active thread, enough
/// for ~100 ResNet-50 passes between drains.
pub const RING_CAPACITY: usize = 16 * 1024;

/// Span category for per-node executor spans.
pub const CAT_EXEC: &str = "exec";
/// Span category for serving lifecycle spans (requests, batches).
pub const CAT_SERVE: &str = "serve";
/// Span category for kernel-family spans (one per parallel-dispatch
/// entry point: gemm / csr / bsr / pattern / lut).
pub const CAT_KERNEL: &str = "kernel";

/// True when the crate was built with the `obs` feature (the default).
pub const COMPILED: bool = cfg!(feature = "obs");

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is recording live? One relaxed load; compile-time `false` without the
/// `obs` feature. Probe sites check this before doing any work.
#[inline(always)]
pub fn on() -> bool {
    COMPILED && ENABLED.load(Ordering::Relaxed)
}

/// Turn recording on (no-op without the `obs` feature).
pub fn enable() {
    ENABLED.store(true, Ordering::Relaxed);
}

/// Turn recording off. Already-recorded spans stay until [`drain`] or
/// [`reset`].
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

// ---------------------------------------------------------------------
// time base

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the recorder epoch (first use in this process).
pub fn now_us() -> f64 {
    epoch().elapsed().as_secs_f64() * 1e6
}

/// Convert an [`Instant`] into recorder-epoch microseconds (0 for
/// instants before the epoch).
pub fn at_us(t: Instant) -> f64 {
    t.saturating_duration_since(epoch()).as_secs_f64() * 1e6
}

/// `Some(start timestamp)` when recording is live — the cheap way to
/// bracket a region:
///
/// ```ignore
/// let t0 = obs::timer();
/// work();
/// if let Some(t0) = t0 {
///     obs::span_since(obs::CAT_EXEC, "work".into(), t0, vec![]);
/// }
/// ```
#[inline]
pub fn timer() -> Option<f64> {
    if on() {
        Some(now_us())
    } else {
        None
    }
}

// ---------------------------------------------------------------------
// spans

/// A span argument value (rendered into Chrome trace `args`).
#[derive(Debug, Clone, PartialEq)]
pub enum ArgValue {
    Num(f64),
    Str(String),
}

/// One recorded span: a `[start, start+dur)` interval on one thread's
/// track, with a small set of key/value arguments.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    /// [`CAT_EXEC`], [`CAT_SERVE`] or [`CAT_KERNEL`].
    pub cat: &'static str,
    /// Node name for exec spans; `"request"` / `"batch"` for serve spans.
    pub name: String,
    /// Microseconds since the recorder epoch.
    pub start_us: f64,
    pub dur_us: f64,
    /// Small per-thread track id (assigned at first record on a thread).
    pub tid: u64,
    /// Request trace id ([`next_trace_id`]); 0 = not part of any trace.
    pub trace: u64,
    pub args: Vec<(&'static str, ArgValue)>,
}

impl Span {
    /// Numeric argument by key.
    pub fn num_arg(&self, key: &str) -> Option<f64> {
        self.args.iter().find_map(|(k, v)| match v {
            ArgValue::Num(n) if *k == key => Some(*n),
            _ => None,
        })
    }

    /// String argument by key.
    pub fn str_arg(&self, key: &str) -> Option<&str> {
        self.args.iter().find_map(|(k, v)| match v {
            ArgValue::Str(s) if *k == key => Some(s.as_str()),
            _ => None,
        })
    }
}

/// The argument keys spans may carry — a closed set so trace JSON parses
/// back into [`Span`]s without allocation games ([`intern_key`]).
pub const ARG_KEYS: &[&str] = &[
    "op", "format", "bits", "m", "pred_units", "model", "id", "batch", "used", "wait_us",
    "exec_us", "slack_us", "outcome", "cause", "nodes", "predicted_us",
];

/// Map an arbitrary string onto the matching entry of [`ARG_KEYS`].
pub fn intern_key(key: &str) -> Option<&'static str> {
    ARG_KEYS.iter().find(|&&k| k == key).copied()
}

/// Map a category string onto [`CAT_EXEC`] / [`CAT_SERVE`] /
/// [`CAT_KERNEL`].
pub fn intern_cat(cat: &str) -> Option<&'static str> {
    [CAT_EXEC, CAT_SERVE, CAT_KERNEL].into_iter().find(|&c| c == cat)
}

// ---------------------------------------------------------------------
// trace context

static NEXT_TRACE: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TRACE: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// Mint a fresh process-unique trace id (never 0).
pub fn next_trace_id() -> u64 {
    NEXT_TRACE.fetch_add(1, Ordering::Relaxed)
}

/// The trace id attached to spans recorded on this thread right now
/// (0 = none). Set by [`with_trace`].
#[inline]
pub fn current_trace() -> u64 {
    TRACE.with(|t| t.get())
}

/// Scope guard restoring the previous thread trace context on drop.
pub struct TraceGuard {
    prev: u64,
}

impl Drop for TraceGuard {
    fn drop(&mut self) {
        TRACE.with(|t| t.set(self.prev));
    }
}

/// Attach `trace` to every span this thread records until the returned
/// guard drops — the scoped thread-local trace context that lets deep
/// call sites ([`record_span`], `exec` node spans, kernel spans) pick up
/// the request's trace id without signature churn.
#[must_use = "the trace context ends when the guard drops"]
pub fn with_trace(trace: u64) -> TraceGuard {
    TRACE.with(|t| {
        let prev = t.get();
        t.set(trace);
        TraceGuard { prev }
    })
}

struct Ring {
    spans: std::collections::VecDeque<Span>,
}

struct ThreadTrack {
    ring: Mutex<Ring>,
    /// Writes lost to ring overflow or to a drain in progress.
    dropped: AtomicU64,
}

fn registry() -> &'static Mutex<Vec<Arc<ThreadTrack>>> {
    static REGISTRY: OnceLock<Mutex<Vec<Arc<ThreadTrack>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

/// Drop counts inherited from pruned dead-thread tracks (see [`drain`]).
static RETIRED_DROPPED: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static LOCAL: RefCell<Option<(Arc<ThreadTrack>, u64)>> = const { RefCell::new(None) };
}

fn register_thread() -> (Arc<ThreadTrack>, u64) {
    let track = Arc::new(ThreadTrack {
        ring: Mutex::new(Ring { spans: std::collections::VecDeque::with_capacity(64) }),
        dropped: AtomicU64::new(0),
    });
    registry().lock().unwrap().push(track.clone());
    (track, NEXT_TID.fetch_add(1, Ordering::Relaxed))
}

/// Record a finished span, stamped with this thread's current trace
/// context ([`with_trace`]). No-op when recording is off. Never blocks:
/// if a drain holds this thread's ring, the span is dropped and counted.
pub fn record_span(
    cat: &'static str,
    name: String,
    start_us: f64,
    dur_us: f64,
    args: Vec<(&'static str, ArgValue)>,
) {
    if !on() {
        return;
    }
    let trace = current_trace();
    LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        let (track, tid) = l.get_or_insert_with(register_thread);
        let span = Span { cat, name, start_us, dur_us, tid: *tid, trace, args };
        match track.ring.try_lock() {
            Ok(mut ring) => {
                if ring.spans.len() >= RING_CAPACITY {
                    ring.spans.pop_front();
                    track.dropped.fetch_add(1, Ordering::Relaxed);
                }
                ring.spans.push_back(span);
            }
            Err(_) => {
                track.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    });
}

/// Record a span that started at `t0_us` (from [`timer`]) and ends now.
pub fn span_since(
    cat: &'static str,
    name: String,
    t0_us: f64,
    args: Vec<(&'static str, ArgValue)>,
) {
    if !on() {
        return;
    }
    let dur = (now_us() - t0_us).max(0.0);
    record_span(cat, name, t0_us, dur, args);
}

/// Collect (and clear) every thread's recorded spans, sorted by start
/// time. Threads recording concurrently keep going: a write that races
/// the drain lands in the next drain or counts as dropped.
///
/// Exited threads' rings stay registered until drained here, so spans
/// recorded just before a worker shuts down still reach the final flush;
/// once emptied, a dead thread's track (registry holds the only `Arc`)
/// is pruned so a long-lived server does not accumulate tracks.
pub fn drain() -> Vec<Span> {
    let mut out = Vec::new();
    let mut tracks = registry().lock().unwrap();
    for track in tracks.iter() {
        let mut ring = track.ring.lock().unwrap();
        out.extend(ring.spans.drain(..));
    }
    tracks.retain(|t| {
        let live = Arc::strong_count(t) > 1;
        if !live {
            RETIRED_DROPPED.fetch_add(t.dropped.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        live
    });
    drop(tracks);
    out.sort_by(|a, b| {
        a.start_us
            .partial_cmp(&b.start_us)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.tid.cmp(&b.tid))
    });
    out
}

/// Total spans lost to ring overflow or drain contention since the last
/// [`reset`].
pub fn dropped_spans() -> u64 {
    RETIRED_DROPPED.load(Ordering::Relaxed)
        + registry()
            .lock()
            .unwrap()
            .iter()
            .map(|t| t.dropped.load(Ordering::Relaxed))
            .sum::<u64>()
}

/// Discard all recorded spans, zero the drop accounting and every
/// counter. Rings stay registered (threads keep their handles).
pub fn reset() {
    for track in registry().lock().unwrap().iter() {
        track.ring.lock().unwrap().spans.clear();
        track.dropped.store(0, Ordering::Relaxed);
    }
    RETIRED_DROPPED.store(0, Ordering::Relaxed);
    for c in counter_cells().iter() {
        c.store(0, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------
// counters

/// Kernel-side counters: what ran, how much of it, and which path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    /// Dense GEMM rows / path taken.
    GemmRows,
    GemmParallel,
    GemmSerial,
    /// CSR kernel rows, stored nonzeros, row panels, path taken.
    CsrRows,
    CsrNnz,
    CsrPanels,
    CsrParallel,
    CsrSerial,
    /// BSR kernel rows, stored blocks, row panels, path taken.
    BsrRows,
    BsrBlocks,
    BsrPanels,
    BsrParallel,
    BsrSerial,
    /// Pattern kernel rows, stored values, row panels, path taken.
    PatRows,
    PatVals,
    PatPanels,
    PatParallel,
    PatSerial,
    /// LUT (quantized) kernel rows, stored values, row panels, path.
    LutRows,
    LutVals,
    LutPanels,
    LutParallel,
    LutSerial,
    /// Serving admission: requests shed at enqueue, by cause.
    ServeShedDeadline,
    ServeShedQuota,
    ServeShedBacklog,
    /// Serving replica sharding: queue-tail steals between replicas.
    ServeSteals,
}

/// Number of distinct [`Counter`]s.
pub const COUNTER_COUNT: usize = 27;

/// Stable names, index-aligned with the [`Counter`] discriminants.
pub const COUNTER_NAMES: [&str; COUNTER_COUNT] = [
    "gemm_rows",
    "gemm_parallel",
    "gemm_serial",
    "csr_rows",
    "csr_nnz",
    "csr_panels",
    "csr_parallel",
    "csr_serial",
    "bsr_rows",
    "bsr_blocks",
    "bsr_panels",
    "bsr_parallel",
    "bsr_serial",
    "pat_rows",
    "pat_vals",
    "pat_panels",
    "pat_parallel",
    "pat_serial",
    "lut_rows",
    "lut_vals",
    "lut_panels",
    "lut_parallel",
    "lut_serial",
    "serve_shed_deadline",
    "serve_shed_quota",
    "serve_shed_backlog",
    "serve_steals",
];

fn counter_cells() -> &'static [AtomicU64; COUNTER_COUNT] {
    static CELLS: OnceLock<[AtomicU64; COUNTER_COUNT]> = OnceLock::new();
    CELLS.get_or_init(|| {
        #[allow(clippy::declare_interior_mutable_const)]
        const Z: AtomicU64 = AtomicU64::new(0);
        [Z; COUNTER_COUNT]
    })
}

/// Bump a counter by `n`. No-op when recording is off; one relaxed
/// fetch-add when it is on.
#[inline]
pub fn add(c: Counter, n: u64) {
    if !on() {
        return;
    }
    counter_cells()[c as usize].fetch_add(n, Ordering::Relaxed);
}

/// All counters as `(name, value)` pairs (zeros included, stable order).
pub fn counters() -> Vec<(&'static str, u64)> {
    counter_cells()
        .iter()
        .zip(COUNTER_NAMES.iter())
        .map(|(c, &n)| (n, c.load(Ordering::Relaxed)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_names_align_with_discriminants() {
        assert_eq!(COUNTER_NAMES.len(), COUNTER_COUNT);
        assert_eq!(COUNTER_NAMES[Counter::GemmRows as usize], "gemm_rows");
        assert_eq!(COUNTER_NAMES[Counter::CsrNnz as usize], "csr_nnz");
        assert_eq!(COUNTER_NAMES[Counter::BsrBlocks as usize], "bsr_blocks");
        assert_eq!(COUNTER_NAMES[Counter::PatSerial as usize], "pat_serial");
        assert_eq!(COUNTER_NAMES[Counter::LutSerial as usize], "lut_serial");
        assert_eq!(
            COUNTER_NAMES[Counter::ServeShedDeadline as usize],
            "serve_shed_deadline"
        );
        assert_eq!(COUNTER_NAMES[Counter::ServeSteals as usize], "serve_steals");
        assert_eq!(Counter::ServeSteals as usize, COUNTER_COUNT - 1);
    }

    #[test]
    fn key_and_cat_interning() {
        assert_eq!(intern_key("pred_units"), Some("pred_units"));
        assert_eq!(intern_key("nonsense"), None);
        assert_eq!(intern_cat("exec"), Some(CAT_EXEC));
        assert_eq!(intern_cat("serve"), Some(CAT_SERVE));
        assert_eq!(intern_cat("kernel"), Some(CAT_KERNEL));
        assert_eq!(intern_cat("metrics"), None);
    }

    #[test]
    fn trace_context_nests_and_restores() {
        assert_eq!(current_trace(), 0);
        {
            let _a = with_trace(7);
            assert_eq!(current_trace(), 7);
            {
                let _b = with_trace(9);
                assert_eq!(current_trace(), 9);
            }
            assert_eq!(current_trace(), 7);
        }
        assert_eq!(current_trace(), 0);
        let (a, b) = (next_trace_id(), next_trace_id());
        assert!(a > 0 && b > a);
    }

    #[test]
    fn span_arg_accessors() {
        let s = Span {
            cat: CAT_EXEC,
            name: "conv1".into(),
            start_us: 1.0,
            dur_us: 2.0,
            tid: 1,
            trace: 0,
            args: vec![
                ("m", ArgValue::Num(64.0)),
                ("format", ArgValue::Str("csr".into())),
            ],
        };
        assert_eq!(s.num_arg("m"), Some(64.0));
        assert_eq!(s.str_arg("format"), Some("csr"));
        assert_eq!(s.num_arg("format"), None);
        assert_eq!(s.str_arg("missing"), None);
    }
}
