//! Tail-biased span sampling for always-on production tracing.
//!
//! A [`Sampler`] decides, per *trace* (every span minted for one request
//! shares one trace id), whether the trace's spans survive into the
//! telemetry stream. Two composed policies:
//!
//! - **Head sampling.** A configurable rate applied deterministically to
//!   the trace-id hash ([`hash01`]) — no RNG state, so the
//!   `serve::sim::SimServer` twin reproduces the exact same sampled set
//!   for the same trace ids, and a trace is kept or dropped *whole*
//!   (every span of a head-kept trace survives, including kernel spans
//!   recorded long before the request's outcome is known).
//! - **Tail keeping.** Traces whose terminal `request` span reports a
//!   non-`ok` outcome (shed, deadline miss, backend error) are *always*
//!   retained, as are `ok` traces whose latency lands at or above the
//!   rolling p99 (a [`Log2Hist`] over previously observed ok-latencies).
//!   Until the outcome is known, a head-dropped trace's spans wait in a
//!   bounded pending buffer; the terminal span either flushes them into
//!   the output or drops them with accounting.
//!
//! Everything is bounded: the pending buffer, the kept/dropped trace
//! rings, and the drop counters make loss visible instead of silent.
//! Untraced spans (trace id 0 — anything recorded outside a request
//! scope) always pass through.

use super::hist::Log2Hist;
use super::{Span, CAT_SERVE};
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// Sampling policy knobs. `rate >= 1.0` keeps every trace at the head
/// (the CI smoke's `--sample-rate 1.0`); `rate <= 0.0` keeps only the
/// tail (non-ok outcomes and the latency p99).
#[derive(Debug, Clone, Copy)]
pub struct SampleConfig {
    /// Head-sampling probability in `[0, 1]`, applied to
    /// `hash01(trace)`.
    pub rate: f64,
    /// Max spans buffered for not-yet-decided traces; overflow drops the
    /// buffered spans of the oldest pending trace (counted).
    pub pending_cap: usize,
    /// Max remembered kept / dropped trace ids (each); oldest forgotten
    /// first. A forgotten trace's late spans fall back to the head
    /// decision, so the rings only bound memory, not correctness of the
    /// common case.
    pub trace_cap: usize,
    /// Ok-latency observations required before the rolling-p99 tail
    /// keeper arms (too-small samples would keep everything).
    pub min_hist: u64,
}

impl Default for SampleConfig {
    fn default() -> SampleConfig {
        SampleConfig { rate: 0.01, pending_cap: 4096, trace_cap: 1024, min_hist: 64 }
    }
}

/// Deterministic trace-id hash onto `[0, 1)` — the splitmix64 finalizer,
/// which spreads sequential ids uniformly.
pub fn hash01(trace: u64) -> f64 {
    let mut z = trace.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^= z >> 31;
    // take the top 53 bits: exactly representable in f64
    (z >> 11) as f64 / (1u64 << 53) as f64
}

/// Bounded insertion-ordered set of trace ids.
#[derive(Debug, Default)]
struct TraceRing {
    order: VecDeque<u64>,
    set: BTreeSet<u64>,
}

impl TraceRing {
    fn insert(&mut self, trace: u64, cap: usize) {
        if self.set.insert(trace) {
            self.order.push_back(trace);
            while self.order.len() > cap.max(1) {
                if let Some(old) = self.order.pop_front() {
                    self.set.remove(&old);
                }
            }
        }
    }

    fn contains(&self, trace: u64) -> bool {
        self.set.contains(&trace)
    }
}

/// The tail-biased sampler (module doc). Feed it span batches via
/// [`Sampler::filter`]; call [`Sampler::finish`] at shutdown to flush
/// still-undecided traces (conservatively kept).
#[derive(Debug)]
pub struct Sampler {
    cfg: SampleConfig,
    kept: TraceRing,
    dropped: TraceRing,
    /// Undecided traces' buffered spans, insertion-ordered by trace
    /// first-seen (`BTreeMap` keys are minted-in-order trace ids).
    pending: BTreeMap<u64, Vec<Span>>,
    pending_spans: usize,
    /// Rolling ok-latency histogram driving the p99 tail keeper.
    ok_hist: Log2Hist,
    head_kept: u64,
    tail_kept: u64,
    dropped_traces: u64,
    dropped_spans: u64,
}

impl Sampler {
    pub fn new(cfg: SampleConfig) -> Sampler {
        Sampler {
            cfg,
            kept: TraceRing::default(),
            dropped: TraceRing::default(),
            pending: BTreeMap::new(),
            pending_spans: 0,
            ok_hist: Log2Hist::new(),
            head_kept: 0,
            tail_kept: 0,
            dropped_traces: 0,
            dropped_spans: 0,
        }
    }

    /// Traces kept by the head sampler so far.
    pub fn head_kept(&self) -> u64 {
        self.head_kept
    }

    /// Traces rescued by the tail keeper (non-ok outcome or p99 tail).
    pub fn tail_kept(&self) -> u64 {
        self.tail_kept
    }

    /// Traces fully dropped so far.
    pub fn dropped_traces(&self) -> u64 {
        self.dropped_traces
    }

    /// Spans dropped so far (sampled out or pending-buffer overflow).
    pub fn dropped_spans(&self) -> u64 {
        self.dropped_spans
    }

    /// Spans currently buffered for undecided traces.
    pub fn pending_spans(&self) -> usize {
        self.pending_spans
    }

    fn terminal_outcome(span: &Span) -> Option<&str> {
        if span.cat == CAT_SERVE && span.name == "request" {
            span.str_arg("outcome")
        } else {
            None
        }
    }

    /// Rolling p99 threshold, `None` until `min_hist` ok-latencies
    /// have been observed.
    fn p99_threshold(&self) -> Option<f64> {
        let snap = self.ok_hist.snapshot()?;
        if snap.count < self.cfg.min_hist {
            return None;
        }
        Some(snap.p99())
    }

    fn keep_trace(&mut self, trace: u64, out: &mut Vec<Span>) {
        self.kept.insert(trace, self.cfg.trace_cap);
        if let Some(buf) = self.pending.remove(&trace) {
            self.pending_spans -= buf.len();
            out.extend(buf);
        }
    }

    fn drop_trace(&mut self, trace: u64, extra_spans: u64) {
        self.dropped.insert(trace, self.cfg.trace_cap);
        self.dropped_traces += 1;
        let buffered = self.pending.remove(&trace).map(|b| b.len() as u64).unwrap_or(0);
        self.pending_spans -= buffered as usize;
        self.dropped_spans += buffered + extra_spans;
    }

    fn buffer_pending(&mut self, span: Span) {
        // overflow evicts the *oldest* pending trace wholesale — its
        // spans are gone, so if its terminal span later tail-keeps, the
        // trace survives incomplete (visible in dropped_spans)
        while self.pending_spans >= self.cfg.pending_cap.max(1) {
            let Some((&oldest, _)) = self.pending.iter().next() else { break };
            let buf = self.pending.remove(&oldest).unwrap_or_default();
            self.pending_spans -= buf.len();
            self.dropped_spans += buf.len() as u64;
        }
        self.pending_spans += 1;
        self.pending.entry(span.trace).or_default().push(span);
    }

    /// Run one span batch through the sampler, returning the spans that
    /// survive (plus any earlier-buffered spans of traces that just
    /// became kept). Deterministic given the input sequence.
    pub fn filter(&mut self, spans: Vec<Span>) -> Vec<Span> {
        let mut out = Vec::new();
        for span in spans {
            let trace = span.trace;
            if trace == 0 || self.kept.contains(trace) {
                out.push(span);
                continue;
            }
            if self.dropped.contains(trace) {
                self.dropped_spans += 1;
                continue;
            }
            if hash01(trace) < self.cfg.rate {
                self.head_kept += 1;
                self.keep_trace(trace, &mut out);
                out.push(span);
                continue;
            }
            match Self::terminal_outcome(&span) {
                Some("ok") => {
                    let latency = span.dur_us;
                    // strictly above: the snapshot's p99 is clamped to
                    // the observed max, so `>=` would keep all of a
                    // uniform-latency stream
                    let tail = self.p99_threshold().is_some_and(|p99| latency > p99);
                    // the decision uses only *prior* traffic; record after
                    self.ok_hist.record(latency);
                    if tail {
                        self.tail_kept += 1;
                        self.keep_trace(trace, &mut out);
                        out.push(span);
                    } else {
                        self.drop_trace(trace, 1);
                    }
                }
                Some(_) => {
                    // shed / deadline / error: always kept, whole trace
                    self.tail_kept += 1;
                    self.keep_trace(trace, &mut out);
                    out.push(span);
                }
                None => self.buffer_pending(span),
            }
        }
        out
    }

    /// Flush still-undecided traces (conservatively kept) — the final
    /// telemetry flush at server shutdown calls this so in-flight
    /// requests' spans are not lost.
    pub fn finish(&mut self) -> Vec<Span> {
        let mut out = Vec::new();
        let traces: Vec<u64> = self.pending.keys().copied().collect();
        for t in traces {
            self.keep_trace(t, &mut out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{ArgValue, CAT_EXEC};

    fn req(trace: u64, outcome: &str, dur: f64) -> Span {
        Span {
            cat: CAT_SERVE,
            name: "request".into(),
            start_us: trace as f64,
            dur_us: dur,
            tid: 1,
            trace,
            args: vec![("outcome", ArgValue::Str(outcome.into()))],
        }
    }

    fn node(trace: u64) -> Span {
        Span {
            cat: CAT_EXEC,
            name: "fc".into(),
            start_us: trace as f64,
            dur_us: 1.0,
            tid: 1,
            trace,
            args: vec![],
        }
    }

    #[test]
    fn hash01_is_uniformish_and_deterministic() {
        let n = 10_000;
        let hits = (1..=n).filter(|&t| hash01(t) < 0.25).count();
        let frac = hits as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.02, "head rate off: {frac}");
        assert_eq!(hash01(42), hash01(42));
        assert!((0.0..1.0).contains(&hash01(0)) && (0.0..1.0).contains(&hash01(u64::MAX)));
    }

    #[test]
    fn rate_one_keeps_everything_rate_zero_keeps_only_tail() {
        let mut all = Sampler::new(SampleConfig { rate: 1.0, ..SampleConfig::default() });
        let spans: Vec<Span> =
            (1..=50).flat_map(|t| vec![node(t), req(t, "ok", 100.0)]).collect();
        assert_eq!(all.filter(spans.clone()).len(), spans.len());
        assert_eq!(all.dropped_spans(), 0);

        let mut none = Sampler::new(SampleConfig { rate: 0.0, ..SampleConfig::default() });
        let kept = none.filter(spans);
        assert!(kept.is_empty(), "ok traces below p99 must drop at rate 0");
        assert_eq!(none.dropped_traces(), 50);
    }

    #[test]
    fn non_ok_outcomes_always_survive_with_their_buffered_spans() {
        let mut s = Sampler::new(SampleConfig { rate: 0.0, ..SampleConfig::default() });
        let kept = s.filter(vec![node(5), node(5), req(5, "shed", 0.0)]);
        assert_eq!(kept.len(), 3, "whole trace flushes on tail keep");
        assert!(kept.iter().all(|sp| sp.trace == 5));
        // late spans of a kept trace pass straight through
        assert_eq!(s.filter(vec![node(5)]).len(), 1);
        assert_eq!(s.tail_kept(), 1);
        assert_eq!(s.dropped_spans(), 0);
    }

    #[test]
    fn p99_tail_keeper_arms_after_min_hist() {
        let cfg = SampleConfig { rate: 0.0, min_hist: 64, ..SampleConfig::default() };
        let mut s = Sampler::new(cfg);
        // 100 fast oks train the histogram and all drop: the keeper is
        // unarmed below min_hist, and after arming the rolling p99
        // clamps to the observed max (100us), which 100us does not
        // strictly exceed
        for t in 1..=100 {
            assert!(s.filter(vec![req(t, "ok", 100.0)]).is_empty());
        }
        // a 10x-latency straggler lands above the rolling p99
        let kept = s.filter(vec![req(1000, "ok", 1000.0)]);
        assert_eq!(kept.len(), 1);
        assert_eq!(s.tail_kept(), 1);
    }

    #[test]
    fn untraced_spans_pass_through() {
        let mut s = Sampler::new(SampleConfig { rate: 0.0, ..SampleConfig::default() });
        let kept = s.filter(vec![node(0)]);
        assert_eq!(kept.len(), 1);
        assert_eq!(s.dropped_spans(), 0);
    }

    #[test]
    fn pending_overflow_evicts_oldest_trace_and_counts() {
        let cfg = SampleConfig { rate: 0.0, pending_cap: 4, ..SampleConfig::default() };
        let mut s = Sampler::new(cfg);
        // 6 undecided single-span traces through a 4-span buffer
        for t in 1..=6 {
            assert!(s.filter(vec![node(t)]).is_empty());
        }
        assert_eq!(s.pending_spans(), 4);
        assert_eq!(s.dropped_spans(), 2);
        // finish() conservatively keeps what still waits
        assert_eq!(s.finish().len(), 4);
        assert_eq!(s.pending_spans(), 0);
    }

    #[test]
    fn same_input_same_decisions() {
        let mk = || {
            let spans: Vec<Span> = (1..=200)
                .flat_map(|t| {
                    let outcome = if t % 7 == 0 { "shed" } else { "ok" };
                    vec![node(t), req(t, outcome, 50.0 + (t % 13) as f64 * 40.0)]
                })
                .collect();
            let mut s = Sampler::new(SampleConfig { rate: 0.1, ..SampleConfig::default() });
            let mut kept = s.filter(spans);
            kept.extend(s.finish());
            (kept, s.head_kept(), s.tail_kept(), s.dropped_spans())
        };
        assert_eq!(mk(), mk());
    }
}
