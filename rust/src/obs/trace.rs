//! Chrome trace-event JSON export: `cadnn profile --trace out.json`
//! writes a file that loads directly in `chrome://tracing` or Perfetto.
//!
//! The emitted shape is the trace-event "JSON object format": every span
//! becomes one complete (`"ph": "X"`) event with `ts`/`dur` in
//! microseconds on a per-thread track, and the recorder's counters ride
//! along under `otherData`. [`parse_chrome_trace`] is the exact inverse
//! over events this module writes — the round-trip through
//! [`crate::util::json`] is pinned by `rust/tests/observability.rs`.

use super::{intern_cat, intern_key, ArgValue, Span};
use crate::util::json::Json;

/// Render one span as a Chrome trace-event object (`"ph": "X"`). The
/// trace id, when set, rides inside `args` under the reserved
/// `"trace_id"` key — shared by [`chrome_trace`] and the serve-side
/// telemetry exporter ([`super::export`]).
pub fn span_event(s: &Span) -> Json {
    let mut ev = vec![
        ("name".to_string(), Json::Str(s.name.clone())),
        ("cat".to_string(), Json::Str(s.cat.to_string())),
        ("ph".to_string(), Json::Str("X".to_string())),
        ("ts".to_string(), Json::Num(s.start_us)),
        ("dur".to_string(), Json::Num(s.dur_us)),
        ("pid".to_string(), Json::Num(1.0)),
        ("tid".to_string(), Json::Num(s.tid as f64)),
    ];
    if !s.args.is_empty() || s.trace != 0 {
        let mut args: Vec<(String, Json)> = Vec::with_capacity(s.args.len() + 1);
        if s.trace != 0 {
            args.push(("trace_id".to_string(), Json::Num(s.trace as f64)));
        }
        for (k, v) in &s.args {
            let jv = match v {
                ArgValue::Num(n) => Json::Num(*n),
                ArgValue::Str(t) => Json::Str(t.clone()),
            };
            args.push((k.to_string(), jv));
        }
        ev.push(("args".to_string(), Json::Obj(args)));
    }
    Json::Obj(ev)
}

/// Render spans (plus counters and the drop count) as a Chrome
/// trace-event JSON document.
pub fn chrome_trace(spans: &[Span], counters: &[(&'static str, u64)], dropped: u64) -> Json {
    let events = spans.iter().map(span_event).collect();
    let counter_obj = counters
        .iter()
        .map(|&(name, v)| (name.to_string(), Json::Num(v as f64)))
        .collect();
    Json::Obj(vec![
        ("traceEvents".to_string(), Json::Arr(events)),
        ("displayTimeUnit".to_string(), Json::Str("ms".to_string())),
        (
            "otherData".to_string(),
            Json::Obj(vec![
                ("dropped_spans".to_string(), Json::Num(dropped as f64)),
                ("counters".to_string(), Json::Obj(counter_obj)),
            ]),
        ),
    ])
}

/// Parse a document written by [`chrome_trace`] back into spans.
/// Categories and argument keys must belong to the recorder's closed
/// sets ([`super::intern_cat`], [`super::ARG_KEYS`]); anything else is
/// an error rather than a silent drop.
pub fn parse_chrome_trace(j: &Json) -> Result<Vec<Span>, String> {
    let events = j
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .ok_or("missing traceEvents array")?;
    let mut out = Vec::with_capacity(events.len());
    for (i, ev) in events.iter().enumerate() {
        out.push(parse_span_event(ev, i)?);
    }
    Ok(out)
}

/// Parse one event written by [`span_event`] back into a [`Span`] —
/// the exact inverse, `i` only labels errors.
pub fn parse_span_event(ev: &Json, i: usize) -> Result<Span, String> {
    let field = |key: &str| ev.get(key).ok_or_else(|| format!("event {i}: missing '{key}'"));
    let ph = field("ph")?.as_str().ok_or_else(|| format!("event {i}: ph not a string"))?;
    if ph != "X" {
        return Err(format!("event {i}: unsupported phase '{ph}' (writer emits X only)"));
    }
    let name = field("name")?
        .as_str()
        .ok_or_else(|| format!("event {i}: name not a string"))?
        .to_string();
    let cat_s = field("cat")?.as_str().ok_or_else(|| format!("event {i}: cat not a string"))?;
    let cat =
        intern_cat(cat_s).ok_or_else(|| format!("event {i}: unknown category '{cat_s}'"))?;
    let start_us = field("ts")?.as_f64().ok_or_else(|| format!("event {i}: ts not a number"))?;
    let dur_us = field("dur")?.as_f64().ok_or_else(|| format!("event {i}: dur not a number"))?;
    let tid = field("tid")?.as_f64().ok_or_else(|| format!("event {i}: tid not a number"))? as u64;
    let mut args = Vec::new();
    let mut trace = 0u64;
    if let Some(Json::Obj(kv)) = ev.get("args") {
        for (k, v) in kv {
            if k == "trace_id" {
                trace = v
                    .as_f64()
                    .ok_or_else(|| format!("event {i}: trace_id not a number"))?
                    as u64;
                continue;
            }
            let key = intern_key(k).ok_or_else(|| format!("event {i}: unknown arg key '{k}'"))?;
            let val = match v {
                Json::Num(n) => ArgValue::Num(*n),
                Json::Str(s) => ArgValue::Str(s.clone()),
                other => return Err(format!("event {i}: arg '{k}' bad type {other:?}")),
            };
            args.push((key, val));
        }
    }
    Ok(Span { cat, name, start_us, dur_us, tid, trace, args })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{CAT_EXEC, CAT_SERVE};

    fn sample_spans() -> Vec<Span> {
        vec![
            Span {
                cat: CAT_EXEC,
                name: "conv1".into(),
                start_us: 10.0,
                dur_us: 120.5,
                tid: 1,
                trace: 41,
                args: vec![
                    ("op", ArgValue::Str("conv2d".into())),
                    ("m", ArgValue::Num(3136.0)),
                    ("pred_units", ArgValue::Num(9000.0)),
                ],
            },
            Span {
                cat: CAT_SERVE,
                name: "request".into(),
                start_us: 0.0,
                dur_us: 900.0,
                tid: 2,
                trace: 0,
                args: vec![
                    ("model", ArgValue::Str("lenet5".into())),
                    ("id", ArgValue::Num(7.0)),
                    ("outcome", ArgValue::Str("ok".into())),
                ],
            },
        ]
    }

    #[test]
    fn round_trips_through_text() {
        let spans = sample_spans();
        let j = chrome_trace(&spans, &[("csr_rows", 42)], 3);
        // through the actual serialized text, not just the Json tree
        let text = j.to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        let back = parse_chrome_trace(&parsed).unwrap();
        assert_eq!(back, spans);
        // counters and drop accounting survive too
        let other = parsed.get("otherData").unwrap();
        assert_eq!(other.get("dropped_spans").and_then(|v| v.as_f64()), Some(3.0));
        let c = other.get("counters").unwrap();
        assert_eq!(c.get("csr_rows").and_then(|v| v.as_f64()), Some(42.0));
    }

    #[test]
    fn trace_id_survives_even_without_args() {
        use crate::obs::CAT_KERNEL;
        let spans = vec![Span {
            cat: CAT_KERNEL,
            name: "csr".into(),
            start_us: 1.0,
            dur_us: 2.0,
            tid: 3,
            trace: 99,
            args: vec![],
        }];
        let j = chrome_trace(&spans, &[], 0);
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        let back = parse_chrome_trace(&parsed).unwrap();
        assert_eq!(back, spans);
        assert_eq!(back[0].trace, 99);
    }

    #[test]
    fn unknown_keys_and_cats_rejected() {
        let mut j = chrome_trace(&sample_spans(), &[], 0);
        // corrupt the category of the first event
        if let Json::Obj(top) = &mut j {
            if let Some((_, Json::Arr(evs))) = top.iter_mut().find(|(k, _)| k == "traceEvents") {
                if let Json::Obj(kv) = &mut evs[0] {
                    for (k, v) in kv.iter_mut() {
                        if k == "cat" {
                            *v = Json::Str("mystery".into());
                        }
                    }
                }
            }
        }
        assert!(parse_chrome_trace(&j).unwrap_err().contains("unknown category"));
        assert!(parse_chrome_trace(&Json::Obj(vec![])).is_err());
    }
}
