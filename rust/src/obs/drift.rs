//! Cost-drift watchdog: the online half of the calibration loop.
//!
//! `cadnn profile --cost-report` → `cadnn calibrate --apply-db` is a
//! *pull* workflow — someone has to notice the planner's `COST_*`
//! constants went stale. The [`DriftWatchdog`] notices for you: it
//! streams the same predicted-vs-measured `exec` spans the
//! [`CostReport`](super::CostReport) fit consumes, closes a window every
//! [`DriftConfig::min_spans`] priced spans, and compares each
//! (op, format) group's residual (group µs/unit over the window's
//! global least-squares fit) against a threshold band. A group outside
//! the band for [`DriftConfig::windows`] *consecutive* windows raises
//! one structured [`DriftEvent`] into the telemetry stream — naming the
//! stale `planner::COST_*` constant, the suggested re-fit, and the
//! remediation command — then disarms for that group until a compliant
//! window passes (no event storms while the operator reacts).
//!
//! Residuals are *relative*: a uniform slowdown across every format is
//! absorbed by the global fit (that is a device-scale change, which the
//! serving scheduler's online `us_per_unit` calibration already tracks);
//! only per-format skew — exactly what makes the planner pick wrong
//! formats — trips the watchdog. Pure values in, values out: no
//! recorder coupling, deterministic, unit-testable.

use super::report::cost_constant;
use super::{Span, CAT_EXEC};
use crate::util::json::Json;

/// Watchdog thresholds.
#[derive(Debug, Clone, Copy)]
pub struct DriftConfig {
    /// A window's residual outside `[1/threshold, threshold]` counts as
    /// drifted.
    pub threshold: f64,
    /// Consecutive drifted windows required before an event fires.
    pub windows: u32,
    /// Priced exec spans that close one observation window.
    pub min_spans: u64,
}

impl Default for DriftConfig {
    fn default() -> DriftConfig {
        DriftConfig { threshold: 1.5, windows: 3, min_spans: 32 }
    }
}

/// One raised drift alarm (serialized into the telemetry stream as a
/// `{"type":"drift",...}` line).
#[derive(Debug, Clone, PartialEq)]
pub struct DriftEvent {
    pub op: String,
    pub format: String,
    /// The offending group's residual in the window that tripped the
    /// alarm.
    pub residual: f64,
    /// Consecutive drifted windows observed.
    pub windows: u32,
    /// The stale `planner::COST_*` constant, when the format maps to
    /// one.
    pub constant: Option<&'static str>,
    /// Its current compiled-in value.
    pub current: Option<f64>,
    /// `current × residual` — the re-fit a calibration run would land
    /// on.
    pub suggested: Option<f64>,
    /// What to run about it.
    pub remediation: &'static str,
}

impl DriftEvent {
    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            ("type".to_string(), Json::Str("drift".to_string())),
            ("op".to_string(), Json::Str(self.op.clone())),
            ("format".to_string(), Json::Str(self.format.clone())),
            ("residual".to_string(), Json::Num(self.residual)),
            ("windows".to_string(), Json::Num(self.windows as f64)),
        ];
        if let (Some(c), Some(cur), Some(sug)) = (self.constant, self.current, self.suggested) {
            kv.push(("constant".to_string(), Json::Str(c.to_string())));
            kv.push(("current".to_string(), Json::Num(cur)));
            kv.push(("suggested".to_string(), Json::Num(sug)));
        }
        kv.push(("remediation".to_string(), Json::Str(self.remediation.to_string())));
        Json::Obj(kv)
    }
}

/// The command that folds a re-fit into the plan database.
pub const REMEDIATION: &str =
    "cadnn profile --cost-report report.json && cadnn calibrate --cost-report report.json --apply-db";

/// Accumulated sums for one (op, format) group in the open window.
#[derive(Debug, Clone)]
struct GroupAcc {
    op: String,
    format: String,
    spans: u64,
    pred_units: f64,
    measured_us: f64,
}

/// Per-group streak state across windows.
#[derive(Debug, Clone)]
struct GroupStreak {
    op: String,
    format: String,
    /// Consecutive drifted windows.
    streak: u32,
    /// Last drifted residual (the one reported).
    residual: f64,
    /// `false` after an event fires, until a compliant window re-arms.
    armed: bool,
}

/// Streaming drift detector (module doc). Feed drained span batches to
/// [`DriftWatchdog::observe`]; it returns any events that fired.
#[derive(Debug)]
pub struct DriftWatchdog {
    cfg: DriftConfig,
    window: Vec<GroupAcc>,
    window_spans: u64,
    streaks: Vec<GroupStreak>,
    windows_closed: u64,
    events_fired: u64,
}

impl DriftWatchdog {
    pub fn new(cfg: DriftConfig) -> DriftWatchdog {
        DriftWatchdog {
            cfg,
            window: Vec::new(),
            window_spans: 0,
            streaks: Vec::new(),
            windows_closed: 0,
            events_fired: 0,
        }
    }

    /// Windows closed so far.
    pub fn windows_closed(&self) -> u64 {
        self.windows_closed
    }

    /// Events raised so far.
    pub fn events_fired(&self) -> u64 {
        self.events_fired
    }

    /// Stream a span batch through the watchdog; returns events that
    /// fired as windows closed. Only priced `exec` spans advance the
    /// window — kernel/serve spans pass through untouched.
    pub fn observe(&mut self, spans: &[Span]) -> Vec<DriftEvent> {
        let mut events = Vec::new();
        for s in spans {
            if s.cat != CAT_EXEC {
                continue;
            }
            let pred = match s.num_arg("pred_units") {
                Some(p) if p > 0.0 => p,
                _ => continue,
            };
            let op = s.str_arg("op").unwrap_or("?");
            let format = s.str_arg("format").unwrap_or("?");
            match self.window.iter_mut().find(|g| g.op == op && g.format == format) {
                Some(g) => {
                    g.spans += 1;
                    g.pred_units += pred;
                    g.measured_us += s.dur_us;
                }
                None => self.window.push(GroupAcc {
                    op: op.to_string(),
                    format: format.to_string(),
                    spans: 1,
                    pred_units: pred,
                    measured_us: s.dur_us,
                }),
            }
            self.window_spans += 1;
            if self.window_spans >= self.cfg.min_spans.max(1) {
                events.extend(self.close_window());
            }
        }
        events
    }

    fn close_window(&mut self) -> Vec<DriftEvent> {
        let window = std::mem::take(&mut self.window);
        self.window_spans = 0;
        self.windows_closed += 1;
        // the CostReport fit, over this window's sums
        let num: f64 = window.iter().map(|g| g.measured_us * g.pred_units).sum();
        let den: f64 = window.iter().map(|g| g.pred_units * g.pred_units).sum();
        let global = if den > 0.0 { num / den } else { 0.0 };
        let mut events = Vec::new();
        if global <= 0.0 {
            return events;
        }
        let band = self.cfg.threshold.max(1.0);
        for g in &window {
            let residual = (g.measured_us / g.pred_units) / global;
            let drifted = residual > band || residual < 1.0 / band;
            let streak = match self
                .streaks
                .iter_mut()
                .find(|s| s.op == g.op && s.format == g.format)
            {
                Some(s) => s,
                None => {
                    self.streaks.push(GroupStreak {
                        op: g.op.clone(),
                        format: g.format.clone(),
                        streak: 0,
                        residual: 1.0,
                        armed: true,
                    });
                    self.streaks.last_mut().expect("just pushed")
                }
            };
            if drifted {
                streak.streak += 1;
                streak.residual = residual;
                if streak.armed && streak.streak >= self.cfg.windows.max(1) {
                    streak.armed = false;
                    self.events_fired += 1;
                    let c = cost_constant(&g.format);
                    events.push(DriftEvent {
                        op: g.op.clone(),
                        format: g.format.clone(),
                        residual,
                        windows: streak.streak,
                        constant: c.map(|(name, _)| name),
                        current: c.map(|(_, v)| v),
                        suggested: c.map(|(_, v)| v * residual),
                        remediation: REMEDIATION,
                    });
                }
            } else {
                // a compliant window resets the streak and re-arms
                streak.streak = 0;
                streak.armed = true;
            }
        }
        events
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::ArgValue;

    fn exec_span(op: &str, format: &str, pred: f64, us: f64) -> Span {
        Span {
            cat: CAT_EXEC,
            name: format!("{op}-node"),
            start_us: 0.0,
            dur_us: us,
            tid: 1,
            trace: 0,
            args: vec![
                ("op", ArgValue::Str(op.to_string())),
                ("format", ArgValue::Str(format.to_string())),
                ("pred_units", ArgValue::Num(pred)),
            ],
        }
    }

    /// One window's worth of spans: two groups, csr `skew`× slower than
    /// its prediction relative to dense.
    fn window(skew: f64, n: u64) -> Vec<Span> {
        (0..n)
            .map(|i| {
                if i % 2 == 0 {
                    exec_span("conv2d", "csr", 100.0, 100.0 * skew)
                } else {
                    exec_span("conv2d", "dense", 100.0, 100.0)
                }
            })
            .collect()
    }

    fn cfg() -> DriftConfig {
        DriftConfig { threshold: 1.5, windows: 3, min_spans: 8 }
    }

    #[test]
    fn well_calibrated_stays_silent() {
        let mut w = DriftWatchdog::new(cfg());
        for _ in 0..10 {
            assert!(w.observe(&window(1.0, 8)).is_empty());
        }
        assert_eq!(w.windows_closed(), 10);
        assert_eq!(w.events_fired(), 0);
    }

    #[test]
    fn persistent_skew_fires_after_k_windows_and_names_the_constant() {
        let mut w = DriftWatchdog::new(cfg());
        // 3x skew: global fit = (300+100)/2 per 100 units = 2.0 us/unit;
        // csr residual = 3/2 = 1.5... borderline. Use 4x: global 2.5,
        // csr residual 4/2.5 = 1.6 > 1.5 and dense 1/2.5 = 0.4 < 1/1.5.
        assert!(w.observe(&window(4.0, 8)).is_empty(), "window 1: streak building");
        assert!(w.observe(&window(4.0, 8)).is_empty(), "window 2: streak building");
        let events = w.observe(&window(4.0, 8));
        // both groups drift (csr slow, dense relatively fast)
        assert_eq!(events.len(), 2, "{events:?}");
        let csr = events.iter().find(|e| e.format == "csr").unwrap();
        assert_eq!(csr.windows, 3);
        assert!(csr.residual > 1.5);
        assert_eq!(csr.constant, Some("COST_CSR_NNZ"));
        let (cur, sug) = (csr.current.unwrap(), csr.suggested.unwrap());
        assert!((sug / cur - csr.residual).abs() < 1e-9);
        assert!(csr.remediation.contains("calibrate --cost-report"));
        // disarmed: continuing skew does not storm
        assert!(w.observe(&window(4.0, 8)).is_empty());
        assert_eq!(w.events_fired(), 2);
        // a compliant window re-arms, then 3 more drifted windows refire
        assert!(w.observe(&window(1.0, 8)).is_empty());
        assert!(w.observe(&window(4.0, 8)).is_empty());
        assert!(w.observe(&window(4.0, 8)).is_empty());
        assert_eq!(w.observe(&window(4.0, 8)).len(), 2);
    }

    #[test]
    fn transient_blips_below_k_windows_never_fire() {
        let mut w = DriftWatchdog::new(cfg());
        for _ in 0..5 {
            assert!(w.observe(&window(4.0, 8)).is_empty());
            assert!(w.observe(&window(4.0, 8)).is_empty());
            assert!(w.observe(&window(1.0, 8)).is_empty(), "reset before the 3rd");
        }
        assert_eq!(w.events_fired(), 0);
    }

    #[test]
    fn uniform_slowdown_is_absorbed_by_the_global_fit() {
        // everything 5x slower: residuals all 1.0 (us_per_unit moved,
        // which is the scheduler's online calibration's job, not a
        // format-skew alarm)
        let mut w = DriftWatchdog::new(cfg());
        let spans: Vec<Span> = (0..32)
            .map(|i| {
                let f = if i % 2 == 0 { "csr" } else { "dense" };
                exec_span("conv2d", f, 100.0, 500.0)
            })
            .collect();
        assert!(w.observe(&spans).is_empty());
        assert_eq!(w.windows_closed(), 4);
        assert_eq!(w.events_fired(), 0);
    }

    #[test]
    fn single_group_never_drifts_against_itself() {
        // with one (op,format) the global fit IS the group fit
        let mut w = DriftWatchdog::new(cfg());
        for _ in 0..5 {
            let spans: Vec<Span> =
                (0..8).map(|_| exec_span("conv2d", "csr", 100.0, 900.0)).collect();
            assert!(w.observe(&spans).is_empty());
        }
        assert_eq!(w.events_fired(), 0);
    }

    #[test]
    fn event_json_carries_the_story() {
        let e = DriftEvent {
            op: "conv2d".into(),
            format: "csr".into(),
            residual: 1.8,
            windows: 3,
            constant: Some("COST_CSR_NNZ"),
            current: Some(1.0),
            suggested: Some(1.8),
            remediation: REMEDIATION,
        };
        let j = e.to_json();
        let text = j.to_string_compact();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("type").and_then(|v| v.as_str()), Some("drift"));
        assert_eq!(back.get("constant").and_then(|v| v.as_str()), Some("COST_CSR_NNZ"));
        assert_eq!(back.get("residual").and_then(|v| v.as_f64()), Some(1.8));
        assert!(back
            .get("remediation")
            .and_then(|v| v.as_str())
            .is_some_and(|r| r.contains("--apply-db")));
    }

    #[test]
    fn unpriced_and_non_exec_spans_do_not_advance_windows() {
        let mut w = DriftWatchdog::new(cfg());
        let mut s = exec_span("conv2d", "csr", 100.0, 100.0);
        s.cat = crate::obs::CAT_SERVE;
        let mut unpriced = exec_span("relu", "csr", 0.0, 10.0);
        unpriced.args.retain(|(k, _)| *k != "pred_units");
        for _ in 0..100 {
            assert!(w.observe(&[s.clone(), unpriced.clone()]).is_empty());
        }
        assert_eq!(w.windows_closed(), 0);
    }
}
