//! JSONL telemetry export: the on-disk stream behind `cadnn serve
//! --telemetry-out PATH` and the `cadnn tail FILE` reader.
//!
//! **Line shapes.** Every line is one complete JSON object with a
//! `"type"` discriminator:
//!
//! - `{"type":"spans","at_us":T,"events":[...],"dropped":N}` — a batch
//!   of sampled spans in the Chrome trace-event encoding
//!   ([`super::trace::span_event`], trace ids inside `args.trace_id`);
//!   `dropped` is the recorder+sampler span loss so far.
//! - `{"type":"snapshot","at_us":T,"model":"a","stats":{...},
//!   "counters":{...}}` — one model's merged
//!   [`crate::serve::MetricsSnapshot`] (`MetricsSnapshot::to_json`) plus
//!   the global kernel counters.
//! - `{"type":"drift", ...}` — a [`super::drift::DriftEvent`]
//!   ([`super::drift::DriftEvent::to_json`]).
//!
//! **Writer guarantees.** [`TelemetryWriter`] appends whole lines with a
//! single `write_all` each, rotates to `<path>.1` when the size cap is
//! exceeded, and *never* takes the server down: an unwritable path (or
//! any later I/O error) logs one warning and disables the writer — the
//! flusher keeps running, the workers never notice. The reader
//! ([`read_telemetry`]) is the mirror image: malformed or truncated
//! lines are skipped and counted, never a panic — a stream cut mid-line
//! by a crash or rotation stays readable.

use super::trace::{parse_span_event, span_event};
use super::Span;
use crate::util::json::Json;
use crate::util::log::{self, Level};
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

/// Default rotation cap (16 MiB) — the stream is a ring of two files
/// (`path` + `path.1`), so peak disk use is ~2× this.
pub const DEFAULT_MAX_BYTES: u64 = 16 * 1024 * 1024;

/// Size-capped, warn-once-and-disable JSONL appender (module doc).
#[derive(Debug)]
pub struct TelemetryWriter {
    path: PathBuf,
    file: Option<File>,
    written: u64,
    max_bytes: u64,
    /// Completed rotations (`path` renamed to `path.1`).
    rotations: u64,
}

impl TelemetryWriter {
    /// Open `path` for appending. An unwritable path degrades to a
    /// disabled writer (warned once) rather than an error: telemetry
    /// must never stop the server from starting.
    pub fn open(path: impl Into<PathBuf>, max_bytes: u64) -> TelemetryWriter {
        let path = path.into();
        let mut w = TelemetryWriter {
            path,
            file: None,
            written: 0,
            max_bytes: max_bytes.max(1),
            rotations: 0,
        };
        match OpenOptions::new().create(true).append(true).open(&w.path) {
            Ok(f) => {
                w.written = f.metadata().map(|m| m.len()).unwrap_or(0);
                w.file = Some(f);
            }
            Err(e) => w.disable("open", &e.to_string()),
        }
        w
    }

    /// Still writing? `false` after the first I/O failure.
    pub fn active(&self) -> bool {
        self.file.is_some()
    }

    /// Rotations performed so far.
    pub fn rotations(&self) -> u64 {
        self.rotations
    }

    fn disable(&mut self, what: &str, err: &str) {
        log::log(
            Level::Warn,
            "obs::export",
            format_args!(
                "telemetry {what} failed for {}: {err} — telemetry disabled, serving continues",
                self.path.display()
            ),
        );
        self.file = None;
    }

    /// Append one JSON document as a single line. Whole-line single
    /// `write_all`, so a reader tailing the file never observes a
    /// half-line except at a crash/rotation boundary (which
    /// [`read_telemetry`] tolerates).
    pub fn write_line(&mut self, doc: &Json) {
        if self.file.is_none() {
            return;
        }
        let mut line = doc.to_string_compact();
        line.push('\n');
        if self.written + line.len() as u64 > self.max_bytes && self.written > 0 {
            self.rotate();
            if self.file.is_none() {
                return;
            }
        }
        let Some(f) = self.file.as_mut() else { return };
        match f.write_all(line.as_bytes()) {
            Ok(()) => self.written += line.len() as u64,
            Err(e) => self.disable("write", &e.to_string()),
        }
    }

    /// `path` → `path.1` (clobbering the previous `.1`), then reopen a
    /// fresh `path`.
    fn rotate(&mut self) {
        self.file = None;
        let old = rotated_path(&self.path);
        if let Err(e) = std::fs::rename(&self.path, &old) {
            self.disable("rotate", &e.to_string());
            return;
        }
        match OpenOptions::new().create(true).append(true).open(&self.path) {
            Ok(f) => {
                self.written = 0;
                self.rotations += 1;
                self.file = Some(f);
            }
            Err(e) => self.disable("reopen", &e.to_string()),
        }
    }
}

/// Where rotation moves the previous stream: `t.jsonl` → `t.jsonl.1`.
pub fn rotated_path(path: &Path) -> PathBuf {
    let mut s = path.as_os_str().to_os_string();
    s.push(".1");
    PathBuf::from(s)
}

/// Build a `"spans"` line from already-sampled spans.
pub fn spans_line(at_us: f64, spans: &[Span], dropped: u64) -> Json {
    Json::Obj(vec![
        ("type".to_string(), Json::Str("spans".to_string())),
        ("at_us".to_string(), Json::Num(at_us)),
        ("events".to_string(), Json::Arr(spans.iter().map(span_event).collect())),
        ("dropped".to_string(), Json::Num(dropped as f64)),
    ])
}

/// Build a `"snapshot"` line for one model.
pub fn snapshot_line(
    at_us: f64,
    model: &str,
    stats: Json,
    counters: &[(&'static str, u64)],
) -> Json {
    let counter_obj = counters
        .iter()
        .map(|&(name, v)| (name.to_string(), Json::Num(v as f64)))
        .collect();
    Json::Obj(vec![
        ("type".to_string(), Json::Str("snapshot".to_string())),
        ("at_us".to_string(), Json::Num(at_us)),
        ("model".to_string(), Json::Str(model.to_string())),
        ("stats".to_string(), stats),
        ("counters".to_string(), Json::Obj(counter_obj)),
    ])
}

/// One parsed telemetry line (`cadnn tail`'s unit of work).
#[derive(Debug, Clone, PartialEq)]
pub enum TelemetryLine {
    Spans { at_us: f64, spans: Vec<Span>, dropped: u64 },
    Snapshot { at_us: f64, model: String, stats: Json, counters: Json },
    /// Drift events keep their raw JSON — the schema belongs to
    /// [`super::drift`], the stream just carries it.
    Drift(Json),
}

/// Parse one line of a telemetry stream. Errors describe what broke;
/// the bulk reader ([`read_telemetry`]) turns them into skip counts.
pub fn parse_telemetry_line(line: &str) -> Result<TelemetryLine, String> {
    let j = Json::parse(line.trim()).map_err(|e| format!("bad json: {e}"))?;
    let ty = j
        .get("type")
        .and_then(|t| t.as_str())
        .ok_or("missing 'type' discriminator")?;
    let at = |j: &Json| j.get("at_us").and_then(|v| v.as_f64()).unwrap_or(0.0);
    match ty {
        "spans" => {
            let events = j
                .get("events")
                .and_then(|e| e.as_arr())
                .ok_or("spans line missing events array")?;
            let mut spans = Vec::with_capacity(events.len());
            for (i, ev) in events.iter().enumerate() {
                spans.push(parse_span_event(ev, i)?);
            }
            let dropped = j.get("dropped").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
            Ok(TelemetryLine::Spans { at_us: at(&j), spans, dropped })
        }
        "snapshot" => {
            let model = j
                .get("model")
                .and_then(|m| m.as_str())
                .ok_or("snapshot line missing model")?
                .to_string();
            let stats = j.get("stats").cloned().ok_or("snapshot line missing stats")?;
            let counters = j.get("counters").cloned().unwrap_or(Json::Obj(vec![]));
            Ok(TelemetryLine::Snapshot { at_us: at(&j), model, stats, counters })
        }
        "drift" => Ok(TelemetryLine::Drift(j)),
        other => Err(format!("unknown line type '{other}'")),
    }
}

/// Read a telemetry file line by line: `(parsed lines, malformed
/// count)`. Malformed/truncated lines (and trailing blank lines) are
/// skipped and counted — never an error, never a panic — so a stream
/// cut mid-write stays usable.
pub fn read_telemetry(path: &Path) -> std::io::Result<(Vec<TelemetryLine>, usize)> {
    let text = std::fs::read_to_string(path)?;
    let mut out = Vec::new();
    let mut malformed = 0usize;
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        match parse_telemetry_line(line) {
            Ok(l) => out.push(l),
            Err(_) => malformed += 1,
        }
    }
    Ok((out, malformed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{ArgValue, CAT_SERVE};

    fn span(trace: u64) -> Span {
        Span {
            cat: CAT_SERVE,
            name: "request".into(),
            start_us: 1.0,
            dur_us: 2.0,
            tid: 1,
            trace,
            args: vec![("outcome", ArgValue::Str("ok".into()))],
        }
    }

    #[test]
    fn lines_round_trip() {
        let sl = spans_line(10.0, &[span(3)], 2);
        let parsed = parse_telemetry_line(&sl.to_string_compact()).unwrap();
        assert_eq!(
            parsed,
            TelemetryLine::Spans { at_us: 10.0, spans: vec![span(3)], dropped: 2 }
        );
        let snap = snapshot_line(11.0, "m", Json::Obj(vec![]), &[("csr_rows", 5)]);
        match parse_telemetry_line(&snap.to_string_compact()).unwrap() {
            TelemetryLine::Snapshot { model, counters, .. } => {
                assert_eq!(model, "m");
                assert_eq!(counters.get("csr_rows").and_then(|v| v.as_f64()), Some(5.0));
            }
            other => panic!("wrong line kind: {other:?}"),
        }
    }

    #[test]
    fn malformed_lines_skip_and_count() {
        let dir = std::env::temp_dir().join("cadnn_export_test_malformed");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        let good = spans_line(1.0, &[span(1)], 0).to_string_compact();
        // truncated tail simulates a crash mid-write
        let cut = &good[..good.len() / 2];
        std::fs::write(
            &path,
            format!("{good}\nnot json\n{{\"type\":\"mystery\"}}\n{good}\n{cut}"),
        )
        .unwrap();
        let (lines, malformed) = read_telemetry(&path).unwrap();
        assert_eq!(lines.len(), 2);
        assert_eq!(malformed, 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn writer_rotates_at_the_cap() {
        let dir = std::env::temp_dir().join("cadnn_export_test_rotate");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        let mut w = TelemetryWriter::open(&path, 256);
        let line = spans_line(1.0, &[span(9)], 0);
        for _ in 0..20 {
            w.write_line(&line);
        }
        assert!(w.active());
        assert!(w.rotations() >= 1, "20 ~100B lines through a 256B cap must rotate");
        // both generations stay within the cap (plus one line of slack)
        let main_len = std::fs::metadata(&path).unwrap().len();
        let old_len = std::fs::metadata(rotated_path(&path)).unwrap().len();
        assert!(main_len <= 512 && old_len <= 512, "{main_len} {old_len}");
        // and the surviving stream is readable
        let (lines, malformed) = read_telemetry(&path).unwrap();
        assert!(!lines.is_empty());
        assert_eq!(malformed, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unwritable_path_degrades_to_disabled() {
        let mut w =
            TelemetryWriter::open("/nonexistent-dir-cadnn/t.jsonl", DEFAULT_MAX_BYTES);
        assert!(!w.active());
        // writes are silent no-ops, never panics
        w.write_line(&spans_line(0.0, &[], 0));
        assert!(!w.active());
    }
}
