//! Textual model IR front-end: user-defined DNNs as `.cadnn` files.
//!
//! The four hand-built graphs in [`crate::models`] cap what the
//! compress → plan → serve pipeline can ever run. This module removes
//! that cap: a compact, line-oriented dialect covering the whole
//! pre-pass [`crate::ir::ops::Op`] surface (plus the fused/lowered ops,
//! so post-pass graphs print too), a recursive-descent [`parse`] into
//! [`crate::ir::Graph`], and a canonical [`print`]er whose output
//! reparses to the same graph node-for-node. Per-layer compression
//! hints (`sparsity=` / `prune=` / `quant=`) ride on the layer
//! statements and come back as a [`crate::compress::profile::SparsityProfile`]
//! keyed by node name, so a `.cadnn` file is a complete, self-contained
//! input to `cadnn plan` / `cadnn serve` (see `docs/MODEL_FORMAT.md`).
//!
//! ```
//! let src = "model m\ninput x [1,8,8,3]\nc = conv2d(x) k=3 cout=8 pad=1 sparsity=0.9\noutput c\n";
//! let parsed = cadnn::front::parse(src).unwrap();
//! assert_eq!(parsed.graph.nodes[1].shape, cadnn::ir::Shape::nhwc(1, 8, 8, 8));
//! assert_eq!(parsed.profile.get("c"), 0.9);
//! let text = cadnn::front::print(&parsed.graph);
//! assert_eq!(cadnn::front::parse(&text).unwrap().graph, parsed.graph);
//! ```
//!
//! Malformed input of any kind — truncation, unknown ops, shape
//! mismatches, overflow-baiting dimensions — yields a positioned
//! [`crate::error::CadnnError::Parse`], never a panic: the parser
//! pre-checks everything `Graph::add` and `Op::infer_shape` assume.

mod lexer;
mod parser;
mod printer;

pub use parser::{parse, ParsedModel};
pub use printer::{print, print_with_hints};

use crate::error::CadnnError;
use crate::ir::Graph;

/// Parse just the graph, discarding any inline compression hints.
pub fn parse_graph(src: &str) -> Result<Graph, CadnnError> {
    parse(src).map(|m| m.graph)
}

/// Read and parse a `.cadnn` model file. I/O failures surface as
/// [`CadnnError::Config`] (they are environment problems, not grammar
/// problems); everything else is a positioned
/// [`CadnnError::Parse`].
pub fn parse_file(path: &str) -> Result<ParsedModel, CadnnError> {
    let src = std::fs::read_to_string(path)
        .map_err(|e| CadnnError::config(format!("cannot read model file '{path}': {e}")))?;
    parse(&src)
}
