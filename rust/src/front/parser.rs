//! Recursive-descent parser: `.cadnn` text into [`crate::ir::Graph`].
//!
//! Grammar (full reference in `docs/MODEL_FORMAT.md`):
//!
//! ```text
//! model   := "model" name NL "input" name shape NL (node NL)* ["output" name NL]
//! node    := name "=" op "(" name ("," name)* ")" attr*
//! attr    := key "=" value | key            (flags: bias, epilogue)
//! shape   := "[" INT ("," INT)* "]"
//! ```
//!
//! The parser is *total* over untrusted text: every rejection is a
//! positioned [`CadnnError::Parse`], never a panic. That requires doing
//! all the shape/arity/overflow validation that `Graph::add` and
//! `Op::infer_shape` assume (their `debug_assert`s) up front, plus
//! anti-DoS caps on dimensions so downstream `numel`/`weight_count`/
//! `flops` arithmetic cannot overflow.

use std::collections::BTreeMap;

use super::lexer::{lex, Tok, Token};
use crate::compress::profile::{PruneStructure, QuantSpec, SparsityProfile};
use crate::error::CadnnError;
use crate::ir::ops::{ActKind, Op, PoolKind};
use crate::ir::{Graph, Shape};

/// A parsed `.cadnn` model: the graph plus any inline per-layer
/// compression hints (`sparsity=` / `prune=` / `quant=`).
#[derive(Debug, Clone, PartialEq)]
pub struct ParsedModel {
    pub graph: Graph,
    /// Hints keyed by node name; empty when the file carries none.
    pub profile: SparsityProfile,
}

// Anti-DoS caps (documented in MODEL_FORMAT.md). Chosen so that every
// derived quantity the rest of the stack computes eagerly — `numel`,
// `weight_count`, per-node and whole-graph `flops` — stays within usize
// / u64 with wide margin.
const MAX_RANK: usize = 8;
const MAX_DIM: usize = 1 << 20;
const MAX_NUMEL: u128 = 1 << 31;
const MAX_WEIGHTS: u128 = 1 << 31;
const MAX_KERNEL: usize = 1 << 10;
const MAX_RECEPTIVE: u128 = 1 << 20;
const MAX_NODES: usize = 2048;
const MAX_ATTR_INT: usize = 1 << 31;

fn perr<T>(
    line: usize,
    col: usize,
    token: impl Into<String>,
    reason: impl Into<String>,
) -> Result<T, CadnnError> {
    Err(CadnnError::parse(line, col, token, reason))
}

struct Parser {
    toks: Vec<Token>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> &Token {
        &self.toks[self.pos]
    }

    fn next(&mut self) -> Token {
        let t = self.toks[self.pos].clone();
        if !matches!(t.tok, Tok::Eof) {
            self.pos += 1;
        }
        t
    }

    fn err<T>(&self, t: &Token, reason: impl Into<String>) -> Result<T, CadnnError> {
        perr(t.line, t.col, t.tok.display(), reason)
    }

    fn skip_newlines(&mut self) {
        while matches!(self.peek().tok, Tok::Newline) {
            self.pos += 1;
        }
    }

    /// A name: bare identifier or quoted string.
    fn name(&mut self, what: &str) -> Result<(String, Token), CadnnError> {
        let t = self.next();
        let s = match &t.tok {
            Tok::Ident(s) | Tok::Str(s) => s.clone(),
            _ => return self.err(&t, format!("expected {what}")),
        };
        Ok((s, t))
    }

    fn end_of_stmt(&mut self) -> Result<(), CadnnError> {
        let t = self.next();
        match t.tok {
            Tok::Newline | Tok::Eof => Ok(()),
            _ => self.err(&t, "expected end of line"),
        }
    }

    /// `[d1,d2,...]`, capped so shape arithmetic cannot overflow.
    fn shape_literal(&mut self) -> Result<Shape, CadnnError> {
        let open = self.next();
        if !matches!(open.tok, Tok::LBracket) {
            return self.err(&open, "expected '[' to start a shape");
        }
        let mut dims = Vec::new();
        loop {
            let t = self.next();
            let d = match t.tok {
                Tok::Int(v) => v,
                _ => return self.err(&t, "expected a dimension (positive integer)"),
            };
            if !(1..=MAX_DIM).contains(&d) {
                return self.err(&t, format!("dimension must be in 1..={MAX_DIM}"));
            }
            dims.push(d);
            let t = self.next();
            match t.tok {
                Tok::Comma => continue,
                Tok::RBracket => break,
                _ => return self.err(&t, "expected ',' or ']' in shape"),
            }
        }
        if dims.len() > MAX_RANK {
            return self.err(&open, format!("shape rank {} exceeds max {MAX_RANK}", dims.len()));
        }
        let numel: u128 = dims.iter().map(|&d| d as u128).product();
        if numel > MAX_NUMEL {
            return self.err(&open, format!("shape has {numel} elements; max {MAX_NUMEL}"));
        }
        Ok(Shape(dims))
    }

    /// Trailing `key=value` / `key` attributes up to end of line.
    fn attrs(&mut self) -> Result<Attrs, CadnnError> {
        let mut list: Vec<Attr> = Vec::new();
        loop {
            let key = match &self.peek().tok {
                Tok::Ident(s) => s.clone(),
                _ => break,
            };
            let kt = self.next();
            if list.iter().any(|a| a.key == key) {
                return self.err(&kt, format!("duplicate attribute '{key}'"));
            }
            let val = if matches!(self.peek().tok, Tok::Eq) {
                self.pos += 1;
                if matches!(self.peek().tok, Tok::LBracket) {
                    AttrVal::Shape(self.shape_literal()?)
                } else {
                    let vt = self.next();
                    match vt.tok {
                        Tok::Int(v) => AttrVal::Int(v),
                        Tok::Pair(a, b) => AttrVal::Pair(a, b),
                        Tok::Float(v) => AttrVal::Float(v),
                        Tok::Ident(w) => AttrVal::Word(w),
                        _ => return self.err(&vt, format!("expected a value for '{key}'")),
                    }
                }
            } else {
                AttrVal::Flag
            };
            list.push(Attr { key, val, line: kt.line, col: kt.col });
        }
        Ok(Attrs(list))
    }
}

#[derive(Debug, Clone)]
enum AttrVal {
    Int(usize),
    Pair(usize, usize),
    Float(f64),
    Word(String),
    Shape(Shape),
    Flag,
}

#[derive(Debug, Clone)]
struct Attr {
    key: String,
    val: AttrVal,
    line: usize,
    col: usize,
}

/// Per-layer compression hints lifted off a node statement.
struct Hints {
    sparsity: f64,
    structure: PruneStructure,
    quant: Option<u8>,
    line: usize,
    col: usize,
}

struct Attrs(Vec<Attr>);

impl Attrs {
    fn take(&mut self, key: &str) -> Option<Attr> {
        self.0.iter().position(|a| a.key == key).map(|i| self.0.remove(i))
    }

    fn req_int(&mut self, key: &str, max: usize, op: &Token) -> Result<usize, CadnnError> {
        let a = match self.take(key) {
            Some(a) => a,
            None => {
                return perr(
                    op.line,
                    op.col,
                    op.tok.display(),
                    format!("missing required attribute '{key}'"),
                )
            }
        };
        match a.val {
            AttrVal::Int(v) if (1..=max).contains(&v) => Ok(v),
            AttrVal::Int(v) => {
                perr(a.line, a.col, v.to_string(), format!("'{key}' must be in 1..={max}"))
            }
            _ => perr(a.line, a.col, a.key.as_str(), format!("'{key}' takes a positive integer")),
        }
    }

    fn opt_int(
        &mut self,
        key: &str,
        default: usize,
        min: usize,
        max: usize,
    ) -> Result<usize, CadnnError> {
        let a = match self.take(key) {
            Some(a) => a,
            None => return Ok(default),
        };
        match a.val {
            AttrVal::Int(v) if (min..=max).contains(&v) => Ok(v),
            _ => perr(
                a.line,
                a.col,
                a.key.as_str(),
                format!("'{key}' must be an integer in {min}..={max}"),
            ),
        }
    }

    /// `k=` kernel: a single integer or an `HxW` pair.
    fn req_k(&mut self, op: &Token) -> Result<(usize, usize), CadnnError> {
        let a = match self.take("k") {
            Some(a) => a,
            None => {
                return perr(op.line, op.col, op.tok.display(), "missing required attribute 'k'")
            }
        };
        let (kh, kw) = match a.val {
            AttrVal::Int(v) => (v, v),
            AttrVal::Pair(h, w) => (h, w),
            _ => return perr(a.line, a.col, "k", "'k' takes an integer or HxW pair"),
        };
        if !(1..=MAX_KERNEL).contains(&kh) || !(1..=MAX_KERNEL).contains(&kw) {
            return perr(a.line, a.col, "k", format!("kernel dims must be in 1..={MAX_KERNEL}"));
        }
        Ok((kh, kw))
    }

    /// `pad=` padding: a single integer or an `HxW` pair; defaults to 0.
    fn opt_pad(&mut self) -> Result<(usize, usize), CadnnError> {
        let a = match self.take("pad") {
            Some(a) => a,
            None => return Ok((0, 0)),
        };
        let (ph, pw) = match a.val {
            AttrVal::Int(v) => (v, v),
            AttrVal::Pair(h, w) => (h, w),
            _ => return perr(a.line, a.col, "pad", "'pad' takes an integer or HxW pair"),
        };
        if ph > MAX_KERNEL || pw > MAX_KERNEL {
            return perr(a.line, a.col, "pad", format!("padding must be <= {MAX_KERNEL}"));
        }
        Ok((ph, pw))
    }

    /// Symmetric-only padding (dwconv / pool); defaults to 0.
    fn opt_pad_sym(&mut self) -> Result<usize, CadnnError> {
        let a = match self.take("pad") {
            Some(a) => a,
            None => return Ok(0),
        };
        match a.val {
            AttrVal::Int(v) if v <= MAX_KERNEL => Ok(v),
            AttrVal::Int(_) => {
                perr(a.line, a.col, "pad", format!("padding must be <= {MAX_KERNEL}"))
            }
            _ => perr(a.line, a.col, "pad", "this op takes a single symmetric 'pad' integer"),
        }
    }

    fn flag(&mut self, key: &str) -> Result<bool, CadnnError> {
        let a = match self.take(key) {
            Some(a) => a,
            None => return Ok(false),
        };
        match a.val {
            AttrVal::Flag => Ok(true),
            _ => perr(
                a.line,
                a.col,
                a.key.as_str(),
                format!("'{key}' is a flag and takes no value"),
            ),
        }
    }

    fn act(&mut self, op: &Token) -> Result<ActKind, CadnnError> {
        let a = match self.take("act") {
            Some(a) => a,
            None => {
                return perr(op.line, op.col, op.tok.display(), "missing required attribute 'act'")
            }
        };
        match &a.val {
            AttrVal::Word(w) if w == "relu" => Ok(ActKind::Relu),
            AttrVal::Word(w) if w == "relu6" => Ok(ActKind::Relu6),
            AttrVal::Word(w) if w == "none" => Ok(ActKind::None),
            _ => perr(a.line, a.col, "act", "'act' must be relu, relu6 or none"),
        }
    }

    fn req_shape(&mut self, key: &str, op: &Token) -> Result<Shape, CadnnError> {
        let a = match self.take(key) {
            Some(a) => a,
            None => {
                return perr(
                    op.line,
                    op.col,
                    op.tok.display(),
                    format!("missing required attribute '{key}'"),
                )
            }
        };
        match a.val {
            AttrVal::Shape(s) => Ok(s),
            _ => perr(
                a.line,
                a.col,
                a.key.as_str(),
                format!("'{key}' takes a shape like [1,56,56,64]"),
            ),
        }
    }

    /// Lift `sparsity=` / `prune=` / `quant=` off the attribute list.
    fn take_hints(&mut self) -> Result<Option<Hints>, CadnnError> {
        let sp = self.take("sparsity");
        let pr = self.take("prune");
        let qu = self.take("quant");
        let sp = match sp {
            Some(sp) => sp,
            None => {
                if let Some(a) = pr.or(qu) {
                    return perr(
                        a.line,
                        a.col,
                        a.key.as_str(),
                        "'prune'/'quant' hints require a 'sparsity' hint",
                    );
                }
                return Ok(None);
            }
        };
        let s = match sp.val {
            AttrVal::Float(v) => v,
            AttrVal::Int(v) => v as f64,
            _ => return perr(sp.line, sp.col, "sparsity", "'sparsity' takes a fraction like 0.9"),
        };
        if !(0.0..1.0).contains(&s) {
            return perr(sp.line, sp.col, "sparsity", "'sparsity' must be in [0, 1)");
        }
        let structure = match pr {
            None => PruneStructure::Element,
            Some(a) => match &a.val {
                AttrVal::Word(w) => match PruneStructure::parse(w) {
                    Some(st) => st,
                    None => {
                        return perr(
                            a.line,
                            a.col,
                            w.as_str(),
                            "unknown prune structure (element | block<R>x<C> | pattern<N>)",
                        )
                    }
                },
                _ => {
                    return perr(a.line, a.col, "prune", "'prune' takes a label like block4x4")
                }
            },
        };
        let quant = match qu {
            None => None,
            Some(a) => match a.val {
                AttrVal::Int(b) if (2..=8).contains(&b) => Some(b as u8),
                _ => return perr(a.line, a.col, "quant", "'quant' takes a bit width in 2..=8"),
            },
        };
        Ok(Some(Hints { sparsity: s, structure, quant, line: sp.line, col: sp.col }))
    }

    /// Error on anything the op builder did not consume.
    fn finish(&self, op_name: &str) -> Result<(), CadnnError> {
        if let Some(a) = self.0.first() {
            return perr(
                a.line,
                a.col,
                a.key.as_str(),
                format!("unknown attribute '{}' for op '{op_name}'", a.key),
            );
        }
        Ok(())
    }
}

fn numel_u128(s: &Shape) -> u128 {
    s.0.iter().map(|&d| d as u128).product()
}

fn one_input<'a>(op_name: &str, ot: &Token, ins: &'a [Shape]) -> Result<&'a Shape, CadnnError> {
    if ins.len() != 1 {
        return perr(
            ot.line,
            ot.col,
            op_name,
            format!("'{op_name}' takes exactly 1 input, got {}", ins.len()),
        );
    }
    Ok(&ins[0])
}

fn rank4(op_name: &str, ot: &Token, s: &Shape) -> Result<(), CadnnError> {
    if s.rank() != 4 {
        return perr(
            ot.line,
            ot.col,
            op_name,
            format!("'{op_name}' needs a rank-4 NHWC input, got rank {}", s.rank()),
        );
    }
    Ok(())
}

fn window_fits(
    op_name: &str,
    ot: &Token,
    s: &Shape,
    kh: usize,
    kw: usize,
    ph: usize,
    pw: usize,
) -> Result<(), CadnnError> {
    if s.h() + 2 * ph < kh || s.w() + 2 * pw < kw {
        return perr(
            ot.line,
            ot.col,
            op_name,
            format!("window {kh}x{kw} with pad {ph}x{pw} does not fit input {}x{}", s.h(), s.w()),
        );
    }
    Ok(())
}

fn check_numel(ot: &Token, numel: u128) -> Result<(), CadnnError> {
    if numel > MAX_NUMEL {
        return perr(
            ot.line,
            ot.col,
            ot.tok.display(),
            format!("output has {numel} elements; max {MAX_NUMEL}"),
        );
    }
    Ok(())
}

fn weights_err<T>(ot: &Token, op_name: &str) -> Result<T, CadnnError> {
    perr(ot.line, ot.col, op_name, format!("layer weight count exceeds max {MAX_WEIGHTS}"))
}

/// Build a fully validated `Op` for `op_name` — every `debug_assert`
/// downstream (`infer_shape`, `conv_out`) is pre-checked here.
fn build_op(
    op_name: &str,
    ot: &Token,
    ins: &[Shape],
    attrs: &mut Attrs,
) -> Result<Op, CadnnError> {
    let op = match op_name {
        "conv2d" | "fused_conv_bn_act" => {
            let s = one_input(op_name, ot, ins)?;
            rank4(op_name, ot, s)?;
            let (kh, kw) = attrs.req_k(ot)?;
            let cout = attrs.req_int("cout", MAX_ATTR_INT, ot)?;
            let stride = attrs.opt_int("stride", 1, 1, MAX_DIM)?;
            let (padh, padw) = attrs.opt_pad()?;
            let groups = attrs.opt_int("groups", 1, 1, MAX_DIM)?;
            let cin = s.c();
            if cin % groups != 0 || cout % groups != 0 {
                return perr(
                    ot.line,
                    ot.col,
                    op_name,
                    format!("groups={groups} must divide both cin={cin} and cout={cout}"),
                );
            }
            window_fits(op_name, ot, s, kh, kw, padh, padw)?;
            let receptive = kh as u128 * kw as u128 * (cin / groups) as u128;
            if receptive > MAX_RECEPTIVE {
                return perr(
                    ot.line,
                    ot.col,
                    op_name,
                    format!("receptive field {receptive} too large (max {MAX_RECEPTIVE})"),
                );
            }
            if receptive * cout as u128 > MAX_WEIGHTS {
                return weights_err(ot, op_name);
            }
            let oh = (s.h() + 2 * padh - kh) / stride + 1;
            let ow = (s.w() + 2 * padw - kw) / stride + 1;
            check_numel(ot, s.n() as u128 * oh as u128 * ow as u128 * cout as u128)?;
            if op_name == "conv2d" {
                let bias = attrs.flag("bias")?;
                Op::Conv2d { kh, kw, cin, cout, stride, padh, padw, bias, groups }
            } else {
                let act = attrs.act(ot)?;
                Op::FusedConvBnAct { kh, kw, cin, cout, stride, padh, padw, act, groups }
            }
        }
        "dwconv2d" | "fused_dw_bn_act" => {
            let s = one_input(op_name, ot, ins)?;
            rank4(op_name, ot, s)?;
            let (kh, kw) = attrs.req_k(ot)?;
            let stride = attrs.opt_int("stride", 1, 1, MAX_DIM)?;
            let padding = attrs.opt_pad_sym()?;
            let c = s.c();
            window_fits(op_name, ot, s, kh, kw, padding, padding)?;
            if kh as u128 * kw as u128 * c as u128 > MAX_WEIGHTS {
                return weights_err(ot, op_name);
            }
            let oh = (s.h() + 2 * padding - kh) / stride + 1;
            let ow = (s.w() + 2 * padding - kw) / stride + 1;
            check_numel(ot, s.n() as u128 * oh as u128 * ow as u128 * c as u128)?;
            if op_name == "dwconv2d" {
                Op::DepthwiseConv2d { kh, kw, c, stride, padding }
            } else {
                let act = attrs.act(ot)?;
                Op::FusedDwBnAct { kh, kw, c, stride, padding, act }
            }
        }
        "batchnorm" => {
            let s = one_input(op_name, ot, ins)?;
            Op::BatchNorm { c: s.c() }
        }
        "relu" => {
            one_input(op_name, ot, ins)?;
            Op::Activation { kind: ActKind::Relu }
        }
        "relu6" => {
            one_input(op_name, ot, ins)?;
            Op::Activation { kind: ActKind::Relu6 }
        }
        "identity" => {
            one_input(op_name, ot, ins)?;
            Op::Activation { kind: ActKind::None }
        }
        "maxpool" | "avgpool" => {
            let s = one_input(op_name, ot, ins)?;
            rank4(op_name, ot, s)?;
            let k = attrs.req_int("k", MAX_KERNEL, ot)?;
            let stride = attrs.opt_int("stride", k, 1, MAX_DIM)?;
            let padding = attrs.opt_pad_sym()?;
            window_fits(op_name, ot, s, k, k, padding, padding)?;
            let oh = (s.h() + 2 * padding - k) / stride + 1;
            let ow = (s.w() + 2 * padding - k) / stride + 1;
            check_numel(ot, s.n() as u128 * oh as u128 * ow as u128 * s.c() as u128)?;
            let kind = if op_name == "maxpool" { PoolKind::Max } else { PoolKind::Avg };
            Op::Pool { kind, k, stride, padding }
        }
        "global_avg_pool" => {
            let s = one_input(op_name, ot, ins)?;
            rank4(op_name, ot, s)?;
            Op::GlobalAvgPool
        }
        "dense" | "fc" => {
            let s = one_input(op_name, ot, ins)?;
            if s.rank() != 2 {
                return perr(
                    ot.line,
                    ot.col,
                    op_name,
                    format!(
                        "'{op_name}' needs a rank-2 [batch, features] input (got rank {}); \
                         insert flatten or global_avg_pool first",
                        s.rank()
                    ),
                );
            }
            let cout = attrs.req_int("cout", MAX_ATTR_INT, ot)?;
            let bias = attrs.flag("bias")?;
            let cin = s.0[1];
            if cin as u128 * cout as u128 > MAX_WEIGHTS {
                return weights_err(ot, op_name);
            }
            check_numel(ot, s.0[0] as u128 * cout as u128)?;
            Op::FullyConnected { cin, cout, bias }
        }
        "add" => {
            if ins.len() != 2 {
                return perr(
                    ot.line,
                    ot.col,
                    op_name,
                    format!("'add' takes exactly 2 inputs, got {}", ins.len()),
                );
            }
            if ins[0] != ins[1] {
                return perr(
                    ot.line,
                    ot.col,
                    op_name,
                    format!(
                        "'add' inputs must have identical shapes, got {} vs {}",
                        ins[0], ins[1]
                    ),
                );
            }
            Op::Add
        }
        "concat" => {
            if ins.len() < 2 {
                return perr(
                    ot.line,
                    ot.col,
                    op_name,
                    format!("'concat' takes at least 2 inputs, got {}", ins.len()),
                );
            }
            for s in ins {
                rank4(op_name, ot, s)?;
            }
            let s0 = &ins[0];
            for s in &ins[1..] {
                if s.n() != s0.n() || s.h() != s0.h() || s.w() != s0.w() {
                    return perr(
                        ot.line,
                        ot.col,
                        op_name,
                        format!("'concat' inputs must share N/H/W, got {s} vs {s0}"),
                    );
                }
            }
            let numel: u128 = ins.iter().map(numel_u128).sum();
            check_numel(ot, numel)?;
            Op::Concat
        }
        "softmax" => {
            one_input(op_name, ot, ins)?;
            Op::Softmax
        }
        "flatten" => {
            one_input(op_name, ot, ins)?;
            Op::Flatten
        }
        "gemm" => {
            let s = one_input(op_name, ot, ins)?;
            let m = attrs.req_int("m", MAX_ATTR_INT, ot)?;
            let k = attrs.req_int("k", MAX_ATTR_INT, ot)?;
            let n = attrs.req_int("n", MAX_ATTR_INT, ot)?;
            let act = attrs.act(ot)?;
            let fused_epilogue = attrs.flag("epilogue")?;
            let out_shape = attrs.req_shape("out", ot)?;
            let in_numel = numel_u128(s);
            let mk = m as u128 * k as u128;
            if mk != in_numel {
                return perr(
                    ot.line,
                    ot.col,
                    op_name,
                    format!("gemm m*k = {mk} must equal input numel {in_numel}"),
                );
            }
            let out_numel = numel_u128(&out_shape);
            let mn = m as u128 * n as u128;
            if mn != out_numel {
                return perr(
                    ot.line,
                    ot.col,
                    op_name,
                    format!("gemm m*n = {mn} must equal output numel {out_numel}"),
                );
            }
            if k as u128 * n as u128 > MAX_WEIGHTS {
                return weights_err(ot, op_name);
            }
            Op::Gemm { m, k, n, act, fused_epilogue, out_shape }
        }
        other => {
            return perr(
                ot.line,
                ot.col,
                other,
                format!(
                    "unknown op '{other}' (expected conv2d, dwconv2d, batchnorm, relu, relu6, \
                     identity, maxpool, avgpool, global_avg_pool, dense, add, concat, softmax, \
                     flatten, fused_conv_bn_act, fused_dw_bn_act, gemm)"
                ),
            );
        }
    };
    Ok(op)
}

/// Parse `.cadnn` source into a graph plus inline compression hints.
pub fn parse(src: &str) -> Result<ParsedModel, CadnnError> {
    let toks = lex(src)?;
    let mut p = Parser { toks, pos: 0 };
    p.skip_newlines();
    let t = p.next();
    match &t.tok {
        Tok::Ident(s) if s == "model" => {}
        _ => return p.err(&t, "expected 'model <name>' header"),
    }
    let (model_name, _) = p.name("a model name")?;
    p.end_of_stmt()?;
    p.skip_newlines();
    let t = p.next();
    match &t.tok {
        Tok::Ident(s) if s == "input" => {}
        _ => return p.err(&t, "expected 'input <name> [dims]' after the model header"),
    }
    let (input_name, _) = p.name("an input name")?;
    let shape = p.shape_literal()?;
    p.end_of_stmt()?;

    let mut graph = Graph::new(&model_name, shape);
    graph.nodes[0].name = input_name.clone();
    let mut ids: BTreeMap<String, usize> = BTreeMap::new();
    ids.insert(input_name, 0);
    let mut profile = SparsityProfile::default();

    loop {
        p.skip_newlines();
        if matches!(p.peek().tok, Tok::Eof) {
            break;
        }
        let (name, nt) = p.name("a node name or 'output'")?;
        if !matches!(p.peek().tok, Tok::Eq) {
            if name == "output" {
                let (target, tt) = p.name("an output node name")?;
                let id = match ids.get(&target) {
                    Some(&id) => id,
                    None => {
                        return perr(
                            tt.line,
                            tt.col,
                            target.as_str(),
                            format!("output references unknown node '{target}'"),
                        )
                    }
                };
                graph.output = id;
                p.end_of_stmt()?;
                p.skip_newlines();
                let t = p.peek().clone();
                if !matches!(t.tok, Tok::Eof) {
                    return p.err(&t, "'output' must be the last statement");
                }
                break;
            }
            if name == "input" {
                return p.err(&nt, "duplicate 'input' statement (a model has exactly one)");
            }
            let t = p.peek().clone();
            return p.err(&t, format!("expected '=' after node name '{name}'"));
        }
        if ids.contains_key(&name) {
            return p.err(&nt, format!("duplicate node name '{name}'"));
        }
        p.pos += 1; // consume '='
        let ot = p.next();
        let op_name = match &ot.tok {
            Tok::Ident(s) => s.clone(),
            _ => return p.err(&ot, "expected an op name"),
        };
        let t = p.next();
        if !matches!(t.tok, Tok::LParen) {
            return p.err(&t, format!("expected '(' after op '{op_name}'"));
        }
        let mut args: Vec<usize> = Vec::new();
        if matches!(p.peek().tok, Tok::RParen) {
            let t = p.next();
            return p.err(&t, format!("'{op_name}' needs at least one input"));
        }
        loop {
            let (an, at) = p.name("an op input name")?;
            let id = match ids.get(&an) {
                Some(&id) => id,
                None => {
                    return perr(
                        at.line,
                        at.col,
                        an.as_str(),
                        format!("unknown input '{an}' (nodes must be defined before use)"),
                    )
                }
            };
            args.push(id);
            let t = p.next();
            match t.tok {
                Tok::Comma => continue,
                Tok::RParen => break,
                _ => return p.err(&t, "expected ',' or ')' in op inputs"),
            }
        }
        let mut attrs = p.attrs()?;
        let hints = attrs.take_hints()?;
        if graph.len() >= MAX_NODES {
            return perr(
                nt.line,
                nt.col,
                name.as_str(),
                format!("model too large (max {MAX_NODES} nodes)"),
            );
        }
        let ins: Vec<Shape> = args.iter().map(|&i| graph.nodes[i].shape.clone()).collect();
        let op = build_op(&op_name, &ot, &ins, &mut attrs)?;
        attrs.finish(&op_name)?;
        if let Some(h) = hints {
            if !op.prunable() {
                return perr(
                    h.line,
                    h.col,
                    "sparsity",
                    format!("sparsity hints only apply to weight layers; '{op_name}' is not one"),
                );
            }
            profile.layers.insert(name.clone(), h.sparsity);
            if h.structure != PruneStructure::Element {
                profile.structures.insert(name.clone(), h.structure);
            }
            if let Some(bits) = h.quant {
                profile.quant.insert(name.clone(), QuantSpec { bits, codebook: Vec::new() });
            }
        }
        let id = graph.add(name.clone(), op, args);
        ids.insert(name, id);
        p.end_of_stmt()?;
    }
    Ok(ParsedModel { graph, profile })
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY: &str = "\
model tiny
input input [1,8,8,3]
c1 = conv2d(input) k=3 cout=8 stride=1 pad=1 sparsity=0.5
b1 = batchnorm(c1)
r1 = relu(b1)
p1 = maxpool(r1) k=2
gap = global_avg_pool(p1)
fc = dense(gap) cout=10 bias
out = softmax(fc)
output out
";

    #[test]
    fn parses_a_small_model() {
        let m = parse(TINY).unwrap();
        let g = &m.graph;
        g.validate().unwrap();
        assert_eq!(g.name, "tiny");
        assert_eq!(g.len(), 8);
        assert_eq!(g.nodes[1].shape, Shape::nhwc(1, 8, 8, 8));
        assert_eq!(g.nodes[4].shape, Shape::nhwc(1, 4, 4, 8));
        assert_eq!(g.nodes.last().unwrap().shape, Shape::vec2(1, 10));
        assert_eq!(g.output, 7);
        assert_eq!(m.profile.get("c1"), 0.5);
        assert!(m.profile.unmatched_layers(g).is_empty());
    }

    #[test]
    fn pool_stride_defaults_to_k() {
        let m = parse("model p\ninput x [1,8,8,4]\npl = avgpool(x) k=2\n").unwrap();
        match &m.graph.nodes[1].op {
            Op::Pool { kind, k, stride, padding } => {
                assert_eq!((*kind, *k, *stride, *padding), (PoolKind::Avg, 2, 2, 0));
            }
            other => panic!("expected pool, got {other:?}"),
        }
    }

    #[test]
    fn hints_build_a_profile() {
        let src = "model h\ninput x [1,8,8,4]\n\
                   c = conv2d(x) k=3 cout=8 pad=1 sparsity=0.9 prune=block4x4 quant=4\n";
        let m = parse(src).unwrap();
        assert_eq!(m.profile.get("c"), 0.9);
        assert_eq!(m.profile.structure("c"), PruneStructure::Block { br: 4, bc: 4 });
        assert_eq!(m.profile.quant_bits("c"), Some(4));
    }

    #[test]
    fn positioned_errors() {
        let src = "model t\ninput x [1,8,8,3]\nc = convv2d(x) k=3 cout=8\n";
        match parse(src) {
            Err(CadnnError::Parse { line, col, token, reason }) => {
                assert_eq!((line, col), (3, 5));
                assert_eq!(token, "convv2d");
                assert!(reason.contains("unknown op"), "{reason}");
            }
            other => panic!("expected Parse error, got {other:?}"),
        }
    }

    #[test]
    fn rejects_structural_mistakes() {
        for (src, frag) in [
            ("", "expected 'model"),
            ("model t\n", "expected 'input"),
            ("model t\ninput x [0]\n", "dimension must be"),
            ("model t\ninput x [1,4,4,2]\na = add(x, y)\n", "unknown input 'y'"),
            ("model t\ninput x [1,4,4,2]\nx = relu(x)\n", "duplicate node name"),
            ("model t\ninput x [1,4,4,2]\nc = conv2d(x) k=9 cout=4\n", "does not fit"),
            ("model t\ninput x [1,4,4,2]\nc = conv2d(x) k=3 pad=1\n", "missing required"),
            ("model t\ninput x [1,4,4,2]\nd = dense(x) cout=4\n", "rank-2"),
            ("model t\ninput x [1,4,4,2]\nr = relu(x) bogus=1\n", "unknown attribute"),
            ("model t\ninput x [1,4,4,2]\nr = relu(x) sparsity=0.5\n", "not"),
            ("model t\ninput x [1,4,4,2]\noutput y\n", "unknown node"),
            ("model t\ninput x [1,4,4,2]\noutput x\nr = relu(x)\n", "last statement"),
        ] {
            match parse(src) {
                Err(CadnnError::Parse { reason, .. }) => {
                    assert!(reason.contains(frag), "{src:?}: {reason}")
                }
                other => panic!("{src:?}: expected Parse error, got {other:?}"),
            }
        }
    }
}
