//! Pretty-printer: [`crate::ir::Graph`] back into canonical `.cadnn`
//! text. `parse(print(g))` reproduces `g` node-for-node, and
//! `print(parse(src))` is a fixpoint — the property the golden
//! `models/*.cadnn` files and the round-trip tests pin.
//!
//! Canonical form: one statement per line, no blank lines, attributes in
//! a fixed order, defaults printed explicitly (`stride=`, `pad=`) so a
//! file diff always shows the full layer configuration.

use std::fmt::Write;

use crate::compress::profile::{PruneStructure, SparsityProfile};
use crate::ir::ops::{ActKind, Op, PoolKind};
use crate::ir::Graph;

/// Print a graph in the canonical `.cadnn` dialect.
pub fn print(g: &Graph) -> String {
    print_inner(g, None)
}

/// Print a graph with per-layer `sparsity=` / `prune=` / `quant=` hints
/// taken from `profile` (layers the profile does not cover get none).
/// Hint values are only emitted for prunable nodes, mirroring what the
/// parser accepts.
pub fn print_with_hints(g: &Graph, profile: &SparsityProfile) -> String {
    print_inner(g, Some(profile))
}

fn ident_ok(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// Bare identifier when possible, quoted (with escapes) otherwise.
fn fmt_name(s: &str) -> String {
    if ident_ok(s) {
        s.to_string()
    } else {
        format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\""))
    }
}

fn act_label(a: ActKind) -> &'static str {
    match a {
        ActKind::Relu => "relu",
        ActKind::Relu6 => "relu6",
        ActKind::None => "none",
    }
}

/// `3` for symmetric values, `1x7` for asymmetric (kernels and pads).
fn fmt_hw(h: usize, w: usize) -> String {
    if h == w {
        format!("{h}")
    } else {
        format!("{h}x{w}")
    }
}

/// The op's surface syntax: name plus canonically ordered attributes.
fn op_surface(op: &Op) -> (&'static str, String) {
    match op {
        Op::Input { .. } => ("input", String::new()),
        Op::Conv2d { kh, kw, cin: _, cout, stride, padh, padw, bias, groups } => {
            let mut a = format!(
                " k={} cout={cout} stride={stride} pad={}",
                fmt_hw(*kh, *kw),
                fmt_hw(*padh, *padw)
            );
            if *bias {
                a.push_str(" bias");
            }
            if *groups > 1 {
                let _ = write!(a, " groups={groups}");
            }
            ("conv2d", a)
        }
        Op::DepthwiseConv2d { kh, kw, c: _, stride, padding } => {
            ("dwconv2d", format!(" k={} stride={stride} pad={padding}", fmt_hw(*kh, *kw)))
        }
        Op::BatchNorm { .. } => ("batchnorm", String::new()),
        Op::Activation { kind: ActKind::Relu } => ("relu", String::new()),
        Op::Activation { kind: ActKind::Relu6 } => ("relu6", String::new()),
        Op::Activation { kind: ActKind::None } => ("identity", String::new()),
        Op::Pool { kind, k, stride, padding } => {
            let name = match kind {
                PoolKind::Max => "maxpool",
                PoolKind::Avg => "avgpool",
            };
            (name, format!(" k={k} stride={stride} pad={padding}"))
        }
        Op::GlobalAvgPool => ("global_avg_pool", String::new()),
        Op::FullyConnected { cin: _, cout, bias } => {
            let mut a = format!(" cout={cout}");
            if *bias {
                a.push_str(" bias");
            }
            ("dense", a)
        }
        Op::Add => ("add", String::new()),
        Op::Concat => ("concat", String::new()),
        Op::Softmax => ("softmax", String::new()),
        Op::Flatten => ("flatten", String::new()),
        Op::FusedConvBnAct { kh, kw, cin: _, cout, stride, padh, padw, act, groups } => {
            let mut a = format!(
                " k={} cout={cout} stride={stride} pad={} act={}",
                fmt_hw(*kh, *kw),
                fmt_hw(*padh, *padw),
                act_label(*act)
            );
            if *groups > 1 {
                let _ = write!(a, " groups={groups}");
            }
            ("fused_conv_bn_act", a)
        }
        Op::FusedDwBnAct { kh, kw, c: _, stride, padding, act } => (
            "fused_dw_bn_act",
            format!(
                " k={} stride={stride} pad={padding} act={}",
                fmt_hw(*kh, *kw),
                act_label(*act)
            ),
        ),
        Op::Gemm { m, k, n, act, fused_epilogue, out_shape } => {
            let mut a = format!(" m={m} k={k} n={n} act={}", act_label(*act));
            if *fused_epilogue {
                a.push_str(" epilogue");
            }
            let _ = write!(a, " out={out_shape}");
            ("gemm", a)
        }
    }
}

fn print_inner(g: &Graph, profile: Option<&SparsityProfile>) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "model {}", fmt_name(&g.name));
    let _ = writeln!(out, "input {} {}", fmt_name(&g.nodes[0].name), g.nodes[0].shape);
    for n in g.nodes.iter().skip(1) {
        let args: Vec<String> = n.inputs.iter().map(|&i| fmt_name(&g.nodes[i].name)).collect();
        let (op_name, attrs) = op_surface(&n.op);
        let _ = write!(out, "{} = {op_name}({}){attrs}", fmt_name(&n.name), args.join(", "));
        if let Some(p) = profile {
            if n.op.prunable() {
                if let Some(&s) = p.layers.get(&n.name) {
                    let _ = write!(out, " sparsity={s}");
                    let st = p.structure(&n.name);
                    if st != PruneStructure::Element {
                        let _ = write!(out, " prune={}", st.label());
                    }
                    if let Some(bits) = p.quant_bits(&n.name) {
                        let _ = write!(out, " quant={bits}");
                    }
                }
            }
        }
        out.push('\n');
    }
    let _ = writeln!(out, "output {}", fmt_name(&g.nodes[g.output].name));
    out
}

#[cfg(test)]
mod tests {
    use super::super::parser::parse;
    use super::*;
    use crate::ir::ops::Op;
    use crate::ir::Shape;

    fn tiny() -> Graph {
        let mut g = Graph::new("tiny", Shape::nhwc(1, 8, 8, 3));
        let c = g.add("c1", Op::conv_b(3, 3, 3, 8, 1, 1), vec![0]);
        let b = g.add("b1", Op::BatchNorm { c: 8 }, vec![c]);
        let r = g.add("r1", Op::Activation { kind: ActKind::Relu }, vec![b]);
        let p = g.add("p1", Op::Pool { kind: PoolKind::Max, k: 2, stride: 2, padding: 0 }, vec![r]);
        let gp = g.add("gap", Op::GlobalAvgPool, vec![p]);
        g.add("fc", Op::fc(8, 10), vec![gp]);
        g
    }

    #[test]
    fn canonical_text_is_stable() {
        let text = print(&tiny());
        assert_eq!(
            text,
            "model tiny\n\
             input input [1,8,8,3]\n\
             c1 = conv2d(input) k=3 cout=8 stride=1 pad=1 bias\n\
             b1 = batchnorm(c1)\n\
             r1 = relu(b1)\n\
             p1 = maxpool(r1) k=2 stride=2 pad=0\n\
             gap = global_avg_pool(p1)\n\
             fc = dense(gap) cout=10 bias\n\
             output fc\n"
        );
    }

    #[test]
    fn print_parse_print_fixpoint() {
        let text = print(&tiny());
        let reparsed = parse(&text).unwrap();
        assert_eq!(reparsed.graph, tiny());
        assert_eq!(print(&reparsed.graph), text);
    }

    #[test]
    fn quoted_names_roundtrip() {
        let mut g = Graph::new("weird name", Shape::nhwc(1, 4, 4, 2));
        g.nodes[0].name = "the input".into();
        g.add("relu 1", Op::Activation { kind: ActKind::Relu }, vec![0]);
        g.add("q\"x\\y", Op::Softmax, vec![1]);
        let text = print(&g);
        let reparsed = parse(&text).unwrap();
        assert_eq!(reparsed.graph, g);
        assert_eq!(print(&reparsed.graph), text);
    }

    #[test]
    fn hints_roundtrip_through_text() {
        let g = tiny();
        let mut profile = SparsityProfile::default();
        profile.layers.insert("c1".into(), 0.93);
        profile.structures.insert("c1".into(), PruneStructure::Pattern { entries: 4 });
        profile.layers.insert("fc".into(), 0.75);
        let text = print_with_hints(&g, &profile);
        assert!(text.contains("sparsity=0.93 prune=pattern4"), "{text}");
        let reparsed = parse(&text).unwrap();
        assert_eq!(reparsed.graph, g);
        assert_eq!(reparsed.profile, profile);
    }

    #[test]
    fn asymmetric_and_fused_surfaces() {
        let mut g = Graph::new("asym", Shape::nhwc(1, 17, 17, 8));
        g.add("a", Op::conv_asym(1, 7, 8, 16, 1, 0, 3), vec![0]);
        g.add(
            "f",
            Op::FusedConvBnAct {
                kh: 3,
                kw: 3,
                cin: 16,
                cout: 16,
                stride: 1,
                padh: 1,
                padw: 1,
                act: ActKind::Relu6,
                groups: 2,
            },
            vec![1],
        );
        let text = print(&g);
        assert!(text.contains("k=1x7"), "{text}");
        assert!(text.contains("pad=0x3"), "{text}");
        assert!(text.contains("act=relu6 groups=2"), "{text}");
        let reparsed = parse(&text).unwrap();
        assert_eq!(reparsed.graph, g);
    }

    #[test]
    fn gemm_surface_roundtrips() {
        let mut g = Graph::new("low", Shape::nhwc(1, 4, 4, 8));
        g.add(
            "g0",
            Op::Gemm {
                m: 16,
                k: 8,
                n: 12,
                act: ActKind::Relu,
                fused_epilogue: true,
                out_shape: Shape::nhwc(1, 4, 4, 12),
            },
            vec![0],
        );
        let text = print(&g);
        assert!(text.contains("m=16 k=8 n=12 act=relu epilogue out=[1,4,4,12]"), "{text}");
        let reparsed = parse(&text).unwrap();
        assert_eq!(reparsed.graph, g);
    }
}
