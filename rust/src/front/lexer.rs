//! Tokenizer for the `.cadnn` textual model IR (`docs/MODEL_FORMAT.md`).
//!
//! Line-oriented: newlines terminate statements and are tokens in their
//! own right; `#` starts a comment that runs to end of line. Every token
//! carries its 1-based source position so the parser's
//! [`crate::error::CadnnError::Parse`] diagnostics can point at the
//! offending token.

use crate::error::CadnnError;

#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    /// `[A-Za-z_][A-Za-z0-9_]*` — names, op names, attribute keys.
    Ident(String),
    /// `"..."` with `\"` / `\\` escapes — names outside the ident charset.
    Str(String),
    Int(usize),
    /// `5x5` — a kernel/pad dimension pair.
    Pair(usize, usize),
    Float(f64),
    Eq,
    LParen,
    RParen,
    LBracket,
    RBracket,
    Comma,
    Newline,
    Eof,
}

impl Tok {
    /// Rendering used in diagnostics (`near '<token>'`).
    pub fn display(&self) -> String {
        match self {
            Tok::Ident(s) => s.clone(),
            Tok::Str(s) => format!("\"{s}\""),
            Tok::Int(v) => v.to_string(),
            Tok::Pair(a, b) => format!("{a}x{b}"),
            Tok::Float(v) => v.to_string(),
            Tok::Eq => "=".into(),
            Tok::LParen => "(".into(),
            Tok::RParen => ")".into(),
            Tok::LBracket => "[".into(),
            Tok::RBracket => "]".into(),
            Tok::Comma => ",".into(),
            Tok::Newline => "<newline>".into(),
            Tok::Eof => "<eof>".into(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct Token {
    pub tok: Tok,
    pub line: usize,
    pub col: usize,
}

fn perr<T>(line: usize, col: usize, token: &str, reason: impl Into<String>) -> Result<T, CadnnError> {
    Err(CadnnError::parse(line, col, token, reason))
}

/// Tokenize a whole source text. The resulting stream always ends with
/// [`Tok::Eof`]; malformed input yields a positioned parse error, never
/// a panic.
pub fn lex(src: &str) -> Result<Vec<Token>, CadnnError> {
    let chars: Vec<char> = src.chars().collect();
    let mut toks = Vec::new();
    let (mut line, mut col) = (1usize, 1usize);
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        let (tl, tc) = (line, col);
        let mut punct = |tok: Tok| toks.push(Token { tok, line: tl, col: tc });
        match c {
            '\n' => {
                punct(Tok::Newline);
                i += 1;
                line += 1;
                col = 1;
            }
            ' ' | '\t' | '\r' => {
                i += 1;
                col += 1;
            }
            '#' => {
                while i < chars.len() && chars[i] != '\n' {
                    i += 1;
                    col += 1;
                }
            }
            '=' => {
                punct(Tok::Eq);
                i += 1;
                col += 1;
            }
            '(' => {
                punct(Tok::LParen);
                i += 1;
                col += 1;
            }
            ')' => {
                punct(Tok::RParen);
                i += 1;
                col += 1;
            }
            '[' => {
                punct(Tok::LBracket);
                i += 1;
                col += 1;
            }
            ']' => {
                punct(Tok::RBracket);
                i += 1;
                col += 1;
            }
            ',' => {
                punct(Tok::Comma);
                i += 1;
                col += 1;
            }
            '"' => {
                i += 1;
                col += 1;
                let mut s = String::new();
                loop {
                    if i >= chars.len() || chars[i] == '\n' {
                        return perr(tl, tc, "\"", "unterminated string");
                    }
                    match chars[i] {
                        '"' => {
                            i += 1;
                            col += 1;
                            break;
                        }
                        '\\' => {
                            if i + 1 >= chars.len() {
                                return perr(tl, tc, "\"", "unterminated string");
                            }
                            let e = chars[i + 1];
                            if e != '"' && e != '\\' {
                                return perr(
                                    line,
                                    col,
                                    &format!("\\{e}"),
                                    "unknown escape (use \\\" or \\\\)",
                                );
                            }
                            s.push(e);
                            i += 2;
                            col += 2;
                        }
                        c => {
                            s.push(c);
                            i += 1;
                            col += 1;
                        }
                    }
                }
                toks.push(Token { tok: Tok::Str(s), line: tl, col: tc });
            }
            c if c.is_ascii_digit() => {
                let digits = |chars: &[char], mut j: usize| {
                    let start = j;
                    while j < chars.len() && chars[j].is_ascii_digit() {
                        j += 1;
                    }
                    let s: String = chars[start..j].iter().collect();
                    (s, j)
                };
                let (a, mut j) = digits(&chars, i);
                let tok = if j + 1 < chars.len() && chars[j] == '.' && chars[j + 1].is_ascii_digit()
                {
                    let (b, j2) = digits(&chars, j + 1);
                    j = j2;
                    let text = format!("{a}.{b}");
                    match text.parse::<f64>() {
                        Ok(v) => Tok::Float(v),
                        Err(_) => return perr(tl, tc, &text, "malformed number"),
                    }
                } else if j + 1 < chars.len() && chars[j] == 'x' && chars[j + 1].is_ascii_digit() {
                    let (b, j2) = digits(&chars, j + 1);
                    j = j2;
                    match (a.parse::<usize>(), b.parse::<usize>()) {
                        (Ok(x), Ok(y)) => Tok::Pair(x, y),
                        _ => return perr(tl, tc, &format!("{a}x{b}"), "dimension pair too large"),
                    }
                } else {
                    match a.parse::<usize>() {
                        Ok(v) => Tok::Int(v),
                        Err(_) => return perr(tl, tc, &a, "integer literal too large"),
                    }
                };
                col += j - i;
                i = j;
                toks.push(Token { tok, line: tl, col: tc });
            }
            c if c.is_ascii_alphabetic() || c == '_' => {
                let start = i;
                while i < chars.len() && (chars[i].is_ascii_alphanumeric() || chars[i] == '_') {
                    i += 1;
                    col += 1;
                }
                let s: String = chars[start..i].iter().collect();
                toks.push(Token { tok: Tok::Ident(s), line: tl, col: tc });
            }
            other => {
                return perr(tl, tc, &other.to_string(), "unexpected character");
            }
        }
    }
    toks.push(Token { tok: Tok::Eof, line, col });
    Ok(toks)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(src: &str) -> Vec<Tok> {
        lex(src).unwrap().into_iter().map(|t| t.tok).collect()
    }

    #[test]
    fn basic_stream() {
        assert_eq!(
            toks("c1 = conv2d(input) k=5x5 pad=2\n"),
            vec![
                Tok::Ident("c1".into()),
                Tok::Eq,
                Tok::Ident("conv2d".into()),
                Tok::LParen,
                Tok::Ident("input".into()),
                Tok::RParen,
                Tok::Ident("k".into()),
                Tok::Eq,
                Tok::Pair(5, 5),
                Tok::Ident("pad".into()),
                Tok::Eq,
                Tok::Int(2),
                Tok::Newline,
                Tok::Eof,
            ]
        );
    }

    #[test]
    fn comments_and_floats() {
        assert_eq!(
            toks("# header\nsparsity=0.93 # trailing\n"),
            vec![
                Tok::Newline,
                Tok::Ident("sparsity".into()),
                Tok::Eq,
                Tok::Float(0.93),
                Tok::Newline,
                Tok::Eof,
            ]
        );
    }

    #[test]
    fn quoted_names_unescape() {
        assert_eq!(
            toks(r#""a b" "q\"uote" "back\\slash""#),
            vec![
                Tok::Str("a b".into()),
                Tok::Str("q\"uote".into()),
                Tok::Str("back\\slash".into()),
                Tok::Eof,
            ]
        );
    }

    #[test]
    fn positions_are_one_based() {
        let ts = lex("a\n  b").unwrap();
        assert_eq!((ts[0].line, ts[0].col), (1, 1));
        assert_eq!((ts[2].line, ts[2].col), (2, 3));
    }

    #[test]
    fn errors_are_positioned_parse_errors() {
        for (src, frag) in [
            ("a @ b", "unexpected character"),
            ("\"open", "unterminated string"),
            ("\"bad \\n esc\"", "unknown escape"),
            ("999999999999999999999999999", "too large"),
        ] {
            match lex(src) {
                Err(CadnnError::Parse { reason, .. }) => {
                    assert!(reason.contains(frag), "{src}: {reason}")
                }
                other => panic!("{src}: expected Parse error, got {other:?}"),
            }
        }
    }
}
