//! Convolution and supporting layer kernels over NHWC tensors.
//!
//! Two conv engines, matching the personalities:
//! - `conv2d_direct` — the 7-loop direct convolution (TFLite-like
//!   baseline engine, no layout transformation);
//! - `im2col` + GEMM — the transformed path (TVM-like / CADNN), where
//!   the conv becomes the tiled (fused-epilogue) GEMM of `gemm.rs` or the
//!   CSR GEMM of `sparse.rs` when compressed.

use super::bsr::bsr_gemm_parallel_cutover;
use super::gemm::gemm_parallel;
use super::lut::qsparse_gemm_parallel_cutover;
use super::pattern::pattern_gemm_parallel_cutover;
use super::sparse::csr_gemm_parallel_cutover;
use super::{Epilogue, Tensor};
use crate::compress::bsr::BsrMatrix;
use crate::compress::csr::CsrMatrix;
use crate::compress::pattern::PatternMatrix;
use crate::compress::qsparse::QSparseMatrix;
use crate::passes::layout::TileConfig;

/// Direct NHWC convolution, weights HWIO (kh, kw, cin, cout), groups=1.
pub fn conv2d_direct(
    x: &Tensor,
    w: &Tensor,
    stride: usize,
    padh: usize,
    padw: usize,
) -> Tensor {
    let (n, h, wd, cin) = (x.n(), x.h(), x.w(), x.c());
    let (kh, kw, wcin, cout) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    assert_eq!(cin, wcin);
    let ho = (h + 2 * padh - kh) / stride + 1;
    let wo = (wd + 2 * padw - kw) / stride + 1;
    let mut out = Tensor::zeros(&[n, ho, wo, cout]);
    for b in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                let obase = ((b * ho + oy) * wo + ox) * cout;
                for ky in 0..kh {
                    let iy = oy * stride + ky;
                    if iy < padh || iy - padh >= h {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = ox * stride + kx;
                        if ix < padw || ix - padw >= wd {
                            continue;
                        }
                        let ibase = ((b * h + (iy - padh)) * wd + (ix - padw)) * cin;
                        let wbase = (ky * kw + kx) * cin * cout;
                        for ci in 0..cin {
                            let xv = x.data[ibase + ci];
                            if xv == 0.0 {
                                continue;
                            }
                            let wrow = &w.data[wbase + ci * cout..wbase + ci * cout + cout];
                            let orow = &mut out.data[obase..obase + cout];
                            for co in 0..cout {
                                orow[co] += xv * wrow[co];
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// im2col: NHWC -> (N*Ho*Wo, kh*kw*Cin) patch matrix. Column order is
/// (ky, kx, cin) — identical to the HWIO weight reshape and the python
/// kernels' layout.
pub fn im2col(
    x: &Tensor,
    kh: usize,
    kw: usize,
    stride: usize,
    padh: usize,
    padw: usize,
) -> (Tensor, usize, usize) {
    let (n, h, wd, c) = (x.n(), x.h(), x.w(), x.c());
    let ho = (h + 2 * padh - kh) / stride + 1;
    let wo = (wd + 2 * padw - kw) / stride + 1;
    let cols = kh * kw * c;
    let mut out = Tensor::zeros(&[n * ho * wo, cols]);
    for b in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                let row = (b * ho + oy) * wo + ox;
                let rbase = row * cols;
                for ky in 0..kh {
                    let iy = oy * stride + ky;
                    if iy < padh || iy - padh >= h {
                        continue; // padding region stays zero
                    }
                    for kx in 0..kw {
                        let ix = ox * stride + kx;
                        if ix < padw || ix - padw >= wd {
                            continue;
                        }
                        let src = ((b * h + (iy - padh)) * wd + (ix - padw)) * c;
                        let dst = rbase + (ky * kw + kx) * c;
                        out.data[dst..dst + c].copy_from_slice(&x.data[src..src + c]);
                    }
                }
            }
        }
    }
    (out, ho, wo)
}

/// Fused conv via im2col + blocked GEMM + epilogue (dense weights as the
/// (kh*kw*cin, cout) matrix).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_gemm(
    x: &Tensor,
    wmat: &[f32],
    kh: usize,
    kw: usize,
    cout: usize,
    stride: usize,
    padh: usize,
    padw: usize,
    tile: &TileConfig,
    epilogue: &Epilogue,
) -> Tensor {
    let cin = x.c();
    let k = kh * kw * cin;
    debug_assert_eq!(wmat.len(), k * cout);
    // 1x1 fast path: no im2col copy (the paper's transformation).
    if kh == 1 && kw == 1 && stride == 1 && padh == 0 && padw == 0 {
        let m = x.n() * x.h() * x.w();
        let mut out = Tensor::zeros(&[x.n(), x.h(), x.w(), cout]);
        gemm_parallel(&x.data, wmat, &mut out.data, m, cin, cout, tile, epilogue);
        return out;
    }
    let (patches, ho, wo) = im2col(x, kh, kw, stride, padh, padw);
    let m = x.n() * ho * wo;
    let mut out = Tensor::zeros(&[x.n(), ho, wo, cout]);
    gemm_parallel(&patches.data, wmat, &mut out.data, m, k, cout, tile, epilogue);
    out
}

/// Compressed fused conv: CSR weights over the same (k, cout) view.
/// `cutover` is the serial→parallel row threshold (planner-chosen; pass
/// [`super::PARALLEL_M_CUTOVER`] for the default).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_csr(
    x: &Tensor,
    w: &CsrMatrix,
    kh: usize,
    kw: usize,
    stride: usize,
    padh: usize,
    padw: usize,
    epilogue: &Epilogue,
    cutover: usize,
) -> Tensor {
    let cout = w.cols;
    if kh == 1 && kw == 1 && stride == 1 && padh == 0 && padw == 0 {
        let m = x.n() * x.h() * x.w();
        let mut out = Tensor::zeros(&[x.n(), x.h(), x.w(), cout]);
        csr_gemm_parallel_cutover(&x.data, w, &mut out.data, m, epilogue, cutover);
        return out;
    }
    let (patches, ho, wo) = im2col(x, kh, kw, stride, padh, padw);
    let m = x.n() * ho * wo;
    let mut out = Tensor::zeros(&[x.n(), ho, wo, cout]);
    csr_gemm_parallel_cutover(&patches.data, w, &mut out.data, m, epilogue, cutover);
    out
}

/// Block-compressed fused conv: BSR weights over the same (k, cout) view.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_bsr(
    x: &Tensor,
    w: &BsrMatrix,
    kh: usize,
    kw: usize,
    stride: usize,
    padh: usize,
    padw: usize,
    epilogue: &Epilogue,
    cutover: usize,
) -> Tensor {
    let cout = w.cols;
    if kh == 1 && kw == 1 && stride == 1 && padh == 0 && padw == 0 {
        let m = x.n() * x.h() * x.w();
        let mut out = Tensor::zeros(&[x.n(), x.h(), x.w(), cout]);
        bsr_gemm_parallel_cutover(&x.data, w, &mut out.data, m, epilogue, cutover);
        return out;
    }
    let (patches, ho, wo) = im2col(x, kh, kw, stride, padh, padw);
    let m = x.n() * ho * wo;
    let mut out = Tensor::zeros(&[x.n(), ho, wo, cout]);
    bsr_gemm_parallel_cutover(&patches.data, w, &mut out.data, m, epilogue, cutover);
    out
}

/// Pattern-compressed fused conv: PatDNN pattern weights over the same
/// (k, cout) view. The pattern positions index the same (ky, kx, cin)
/// im2col column order the dense reshape uses.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_pattern(
    x: &Tensor,
    w: &PatternMatrix,
    kh: usize,
    kw: usize,
    stride: usize,
    padh: usize,
    padw: usize,
    epilogue: &Epilogue,
    cutover: usize,
) -> Tensor {
    let cout = w.cols;
    if kh == 1 && kw == 1 && stride == 1 && padh == 0 && padw == 0 {
        let m = x.n() * x.h() * x.w();
        let mut out = Tensor::zeros(&[x.n(), x.h(), x.w(), cout]);
        pattern_gemm_parallel_cutover(&x.data, w, &mut out.data, m, epilogue, cutover);
        return out;
    }
    let (patches, ho, wo) = im2col(x, kh, kw, stride, padh, padw);
    let m = x.n() * ho * wo;
    let mut out = Tensor::zeros(&[x.n(), ho, wo, cout]);
    pattern_gemm_parallel_cutover(&patches.data, w, &mut out.data, m, epilogue, cutover);
    out
}

/// Quantized-payload fused conv: codebook-packed weights over the same
/// (k, cout) view, executed through the matching LUT micro-kernel
/// ([`crate::kernels::lut`]) — no dequantized weight buffer exists at
/// any point.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_qsparse(
    x: &Tensor,
    w: &QSparseMatrix,
    kh: usize,
    kw: usize,
    stride: usize,
    padh: usize,
    padw: usize,
    epilogue: &Epilogue,
    cutover: usize,
) -> Tensor {
    let cout = w.cols();
    if kh == 1 && kw == 1 && stride == 1 && padh == 0 && padw == 0 {
        let m = x.n() * x.h() * x.w();
        let mut out = Tensor::zeros(&[x.n(), x.h(), x.w(), cout]);
        qsparse_gemm_parallel_cutover(&x.data, w, &mut out.data, m, epilogue, cutover);
        return out;
    }
    let (patches, ho, wo) = im2col(x, kh, kw, stride, padh, padw);
    let m = x.n() * ho * wo;
    let mut out = Tensor::zeros(&[x.n(), ho, wo, cout]);
    qsparse_gemm_parallel_cutover(&patches.data, w, &mut out.data, m, epilogue, cutover);
    out
}

/// Depthwise conv (weights (kh, kw, c)) with fused epilogue.
pub fn depthwise(
    x: &Tensor,
    w: &Tensor,
    stride: usize,
    padding: usize,
    epilogue: &Epilogue,
) -> Tensor {
    let (n, h, wd, c) = (x.n(), x.h(), x.w(), x.c());
    let (kh, kw) = (w.shape[0], w.shape[1]);
    assert_eq!(w.shape[2], c);
    let ho = (h + 2 * padding - kh) / stride + 1;
    let wo = (wd + 2 * padding - kw) / stride + 1;
    let mut out = Tensor::zeros(&[n, ho, wo, c]);
    for b in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                let obase = ((b * ho + oy) * wo + ox) * c;
                for ky in 0..kh {
                    let iy = oy * stride + ky;
                    if iy < padding || iy - padding >= h {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = ox * stride + kx;
                        if ix < padding || ix - padding >= wd {
                            continue;
                        }
                        let ibase = ((b * h + (iy - padding)) * wd + (ix - padding)) * c;
                        let wbase = (ky * kw + kx) * c;
                        for ch in 0..c {
                            out.data[obase + ch] += x.data[ibase + ch] * w.data[wbase + ch];
                        }
                    }
                }
            }
        }
    }
    epilogue.apply(&mut out.data, n * ho * wo, c);
    out
}

/// Max / avg pooling (square window, symmetric padding; avg divides by
/// the full window — matching jax `avg_pool` with count_include_pad).
pub fn pool(x: &Tensor, k: usize, stride: usize, padding: usize, max_pool: bool) -> Tensor {
    let (n, h, wd, c) = (x.n(), x.h(), x.w(), x.c());
    let ho = (h + 2 * padding - k) / stride + 1;
    let wo = (wd + 2 * padding - k) / stride + 1;
    let mut out = Tensor::zeros(&[n, ho, wo, c]);
    for b in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                let obase = ((b * ho + oy) * wo + ox) * c;
                for ch in 0..c {
                    let mut acc = if max_pool { f32::NEG_INFINITY } else { 0.0 };
                    for ky in 0..k {
                        let iy = oy * stride + ky;
                        if iy < padding || iy - padding >= h {
                            if max_pool {
                                continue;
                            } else {
                                continue; // zero contribution
                            }
                        }
                        for kx in 0..k {
                            let ix = ox * stride + kx;
                            if ix < padding || ix - padding >= wd {
                                continue;
                            }
                            let v = x.at4(b, iy - padding, ix - padding, ch);
                            if max_pool {
                                acc = acc.max(v);
                            } else {
                                acc += v;
                            }
                        }
                    }
                    out.data[obase + ch] = if max_pool { acc } else { acc / (k * k) as f32 };
                }
            }
        }
    }
    out
}

pub fn global_avg_pool(x: &Tensor) -> Tensor {
    let (n, h, wd, c) = (x.n(), x.h(), x.w(), x.c());
    let mut out = Tensor::zeros(&[n, c]);
    for b in 0..n {
        for y in 0..h {
            for xx in 0..wd {
                let base = ((b * h + y) * wd + xx) * c;
                for ch in 0..c {
                    out.data[b * c + ch] += x.data[base + ch];
                }
            }
        }
    }
    for v in out.data.iter_mut() {
        *v /= (h * wd) as f32;
    }
    out
}

pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape, b.shape);
    let mut out = a.clone();
    for (o, v) in out.data.iter_mut().zip(&b.data) {
        *o += v;
    }
    out
}

pub fn relu(x: &mut Tensor, max: Option<f32>) {
    for v in x.data.iter_mut() {
        *v = v.max(0.0);
        if let Some(m) = max {
            *v = v.min(m);
        }
    }
}

/// Standalone inference BatchNorm (unfused personalities).
pub fn batchnorm(x: &mut Tensor, scale: &[f32], shift: &[f32]) {
    let c = x.c();
    let rows = x.numel() / c;
    Epilogue::Affine {
        scale: scale.to_vec(),
        shift: shift.to_vec(),
        relu_max: None,
        relu: false,
    }
    .apply(&mut x.data, rows, c);
}

pub fn softmax(x: &mut Tensor) {
    let c = *x.shape.last().unwrap();
    let rows = x.numel() / c;
    for r in 0..rows {
        let row = &mut x.data[r * c..(r + 1) * c];
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// Concat along the channel axis.
pub fn concat_channels(xs: &[&Tensor]) -> Tensor {
    let (n, h, w) = (xs[0].n(), xs[0].h(), xs[0].w());
    let ctot: usize = xs.iter().map(|t| t.c()).sum();
    let mut out = Tensor::zeros(&[n, h, w, ctot]);
    for b in 0..n {
        for y in 0..h {
            for x_ in 0..w {
                let mut off = 0;
                let dst_base = ((b * h + y) * w + x_) * ctot;
                for t in xs {
                    let c = t.c();
                    let src = ((b * h + y) * w + x_) * c;
                    out.data[dst_base + off..dst_base + off + c]
                        .copy_from_slice(&t.data[src..src + c]);
                    off += c;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_t(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::randn(shape, &mut rng, 1.0)
    }

    #[test]
    fn im2col_gemm_matches_direct() {
        for (kh, stride, pad) in [(1usize, 1usize, 0usize), (3, 1, 1), (3, 2, 1), (5, 2, 2)] {
            let x = rand_t(&[2, 9, 9, 4], 1);
            let w = rand_t(&[kh, kh, 4, 6], 2);
            let direct = conv2d_direct(&x, &w, stride, pad, pad);
            let got = conv2d_gemm(
                &x, &w.data, kh, kh, 6, stride, pad, pad,
                &TileConfig::DEFAULT, &Epilogue::None,
            );
            assert_eq!(direct.shape, got.shape, "k{kh}s{stride}p{pad}");
            assert!(direct.max_abs_diff(&got) < 1e-4, "k{kh}s{stride}p{pad}");
        }
    }

    #[test]
    fn csr_conv_matches_dense_conv() {
        let x = rand_t(&[1, 8, 8, 4], 3);
        let mut w = rand_t(&[3, 3, 4, 8], 4);
        // prune ~70%
        let mut rng = Rng::new(5);
        for v in w.data.iter_mut() {
            if rng.f64() < 0.7 {
                *v = 0.0;
            }
        }
        let dense = conv2d_direct(&x, &w, 1, 1, 1);
        let cut = crate::kernels::PARALLEL_M_CUTOVER;
        let csr = CsrMatrix::from_dense(&w.data, 36, 8);
        let got = conv2d_csr(&x, &csr, 3, 3, 1, 1, 1, &Epilogue::None, cut);
        assert!(dense.max_abs_diff(&got) < 1e-4);
        let bsr = BsrMatrix::from_dense(&w.data, 36, 8, 4, 4);
        let got_b = conv2d_bsr(&x, &bsr, 3, 3, 1, 1, 1, &Epilogue::None, cut);
        assert!(dense.max_abs_diff(&got_b) < 1e-4);
        let pat = PatternMatrix::from_dense(&w.data, 3, 3, 4, 8);
        let got_p = conv2d_pattern(&x, &pat, 3, 3, 1, 1, 1, &Epilogue::None, cut);
        assert!(dense.max_abs_diff(&got_p) < 1e-4);
    }

    #[test]
    fn depthwise_known_values() {
        // 1 channel, 2x2 input, 2x2 kernel of ones, no pad -> sum
        let x = Tensor::from_vec(&[1, 2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]);
        let w = Tensor::from_vec(&[2, 2, 1], vec![1.0; 4]);
        let out = depthwise(&x, &w, 1, 0, &Epilogue::None);
        assert_eq!(out.shape, vec![1, 1, 1, 1]);
        assert_eq!(out.data[0], 10.0);
    }

    #[test]
    fn maxpool_known_values() {
        let x = Tensor::from_vec(&[1, 2, 2, 1], vec![1.0, 5.0, 3.0, 2.0]);
        let out = pool(&x, 2, 2, 0, true);
        assert_eq!(out.data, vec![5.0]);
    }

    #[test]
    fn avgpool_divides_full_window() {
        let x = Tensor::from_vec(&[1, 2, 2, 1], vec![1.0, 5.0, 3.0, 3.0]);
        let out = pool(&x, 2, 2, 0, false);
        assert_eq!(out.data, vec![3.0]);
    }

    #[test]
    fn global_avg_pool_mean() {
        let x = Tensor::from_vec(&[1, 2, 2, 2], vec![1., 10., 2., 20., 3., 30., 4., 40.]);
        let out = global_avg_pool(&x);
        assert_eq!(out.data, vec![2.5, 25.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut x = rand_t(&[4, 10], 6);
        softmax(&mut x);
        for r in 0..4 {
            let s: f32 = x.data[r * 10..(r + 1) * 10].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn concat_channels_layout() {
        let a = Tensor::from_vec(&[1, 1, 2, 1], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[1, 1, 2, 2], vec![3.0, 4.0, 5.0, 6.0]);
        let out = concat_channels(&[&a, &b]);
        assert_eq!(out.shape, vec![1, 1, 2, 3]);
        assert_eq!(out.data, vec![1.0, 3.0, 4.0, 2.0, 5.0, 6.0]);
    }

    #[test]
    fn relu_and_bn() {
        let mut x = Tensor::from_vec(&[1, 1, 1, 2], vec![-1.0, 8.0]);
        relu(&mut x, Some(6.0));
        assert_eq!(x.data, vec![0.0, 6.0]);
        let mut y = Tensor::from_vec(&[1, 1, 1, 2], vec![2.0, 3.0]);
        batchnorm(&mut y, &[2.0, 0.5], &[1.0, 0.0]);
        assert_eq!(y.data, vec![5.0, 1.5]);
    }
}
