//! Sparse kernels: activations (dense, M x K) times CSR weights (K x N).
//!
//! This is the paper's compressed execution path on CPU: pruned weights
//! are never touched, so work scales with nnz. The row-major CSR over K
//! lets the kernel stream A columns and scatter into C rows with the
//! same register blocking as the dense micro-kernel.

use super::{Epilogue, SendPtr, PARALLEL_M_CUTOVER};
use crate::compress::csr::CsrMatrix;
use crate::obs::{self, Counter};
use crate::util::pool;

/// C(M,N) = A(M,K) @ W_csr(K,N), single thread.
pub fn csr_gemm(a: &[f32], w: &CsrMatrix, c: &mut [f32], m: usize, epilogue: &Epilogue) {
    let (k, n) = (w.rows, w.cols);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * n);
    csr_gemm_rows(a, w, c, 0, m, k, n);
    epilogue.apply(c, m, n);
}

fn csr_gemm_rows(a: &[f32], w: &CsrMatrix, c: &mut [f32], m0: usize, m1: usize, k: usize, n: usize) {
    c[m0 * n..m1 * n].fill(0.0);
    const MR: usize = 4;
    let mut i = m0;
    while i + MR <= m1 {
        for p in 0..k {
            // hoist MR activation values (one per row) into registers
            let av = [
                a[i * k + p],
                a[(i + 1) * k + p],
                a[(i + 2) * k + p],
                a[(i + 3) * k + p],
            ];
            if av == [0.0; 4] {
                continue;
            }
            let (s, e) = (w.row_ptr[p] as usize, w.row_ptr[p + 1] as usize);
            for idx in s..e {
                let col = w.col_idx[idx] as usize;
                let v = w.values[idx];
                c[i * n + col] += av[0] * v;
                c[(i + 1) * n + col] += av[1] * v;
                c[(i + 2) * n + col] += av[2] * v;
                c[(i + 3) * n + col] += av[3] * v;
            }
        }
        i += MR;
    }
    for ir in i..m1 {
        for p in 0..k {
            let av = a[ir * k + p];
            if av == 0.0 {
                continue;
            }
            let (s, e) = (w.row_ptr[p] as usize, w.row_ptr[p + 1] as usize);
            for idx in s..e {
                c[ir * n + w.col_idx[idx] as usize] += av * w.values[idx];
            }
        }
    }
}

/// Multithreaded CSR GEMM over disjoint row panels, default cutover.
pub fn csr_gemm_parallel(a: &[f32], w: &CsrMatrix, c: &mut [f32], m: usize, epilogue: &Epilogue) {
    csr_gemm_parallel_cutover(a, w, c, m, epilogue, PARALLEL_M_CUTOVER);
}

/// Multithreaded CSR GEMM with a caller-chosen serial cutover (the
/// planner's per-layer override; see [`PARALLEL_M_CUTOVER`]). Emits a
/// `kernel` span (family `csr`) when the recorder is on, inheriting the
/// calling thread's trace context.
pub fn csr_gemm_parallel_cutover(
    a: &[f32],
    w: &CsrMatrix,
    c: &mut [f32],
    m: usize,
    epilogue: &Epilogue,
    cutover: usize,
) {
    let t0 = obs::timer();
    csr_gemm_parallel_cutover_impl(a, w, c, m, epilogue, cutover);
    if let Some(t0) = t0 {
        obs::span_since(
            obs::CAT_KERNEL,
            "csr".to_string(),
            t0,
            vec![("m", obs::ArgValue::Num(m as f64))],
        );
    }
}

fn csr_gemm_parallel_cutover_impl(
    a: &[f32],
    w: &CsrMatrix,
    c: &mut [f32],
    m: usize,
    epilogue: &Epilogue,
    cutover: usize,
) {
    let (k, n) = (w.rows, w.cols);
    if obs::on() {
        obs::add(Counter::CsrRows, m as u64);
        obs::add(Counter::CsrNnz, w.nnz() as u64);
    }
    let threads = pool::global().size().min(m.div_ceil(64)).max(1);
    if threads <= 1 || m < cutover {
        obs::add(Counter::CsrSerial, 1);
        return csr_gemm(a, w, c, m, epilogue);
    }
    if obs::on() {
        obs::add(Counter::CsrParallel, 1);
        obs::add(Counter::CsrPanels, threads as u64);
    }
    let chunk = m.div_ceil(threads);
    let cptr = SendPtr(c.as_mut_ptr());
    pool::parallel_for_n(threads, threads, |t| {
        let m0 = t * chunk;
        let m1 = ((t + 1) * chunk).min(m);
        if m0 >= m1 {
            return;
        }
        // SAFETY: disjoint row panels.
        let c_all = unsafe { std::slice::from_raw_parts_mut(cptr.get(), m * n) };
        csr_gemm_rows(a, w, c_all, m0, m1, k, n);
        epilogue.apply(&mut c_all[m0 * n..m1 * n], m1 - m0, n);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gemm::gemm_naive;
    use crate::prop_assert;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn sparse_weights(k: usize, n: usize, density: f64, seed: u64) -> (Vec<f32>, CsrMatrix) {
        let mut rng = Rng::new(seed);
        let mut dense = vec![0.0f32; k * n];
        for v in dense.iter_mut() {
            if rng.f64() < density {
                *v = rng.normal() as f32;
            }
        }
        let csr = CsrMatrix::from_dense(&dense, k, n);
        (dense, csr)
    }

    #[test]
    fn csr_matches_dense_gemm() {
        let (m, k, n) = (17, 40, 23);
        let mut rng = Rng::new(1);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let (dense, csr) = sparse_weights(k, n, 0.2, 2);
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        gemm_naive(&a, &dense, &mut c1, m, k, n);
        csr_gemm(&a, &csr, &mut c2, m, &Epilogue::None);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let (m, k, n) = (300, 64, 32);
        let mut rng = Rng::new(3);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let (_, csr) = sparse_weights(k, n, 0.1, 4);
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        csr_gemm(&a, &csr, &mut c1, m, &Epilogue::None);
        csr_gemm_parallel(&a, &csr, &mut c2, m, &Epilogue::None);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn empty_weights_give_zero_plus_epilogue() {
        let (m, k, n) = (6, 10, 4);
        let a = vec![1.0; m * k];
        let csr = CsrMatrix::from_dense(&vec![0.0; k * n], k, n);
        let mut c = vec![9.0; m * n];
        let ep = Epilogue::bias_relu(vec![0.5; n], false);
        csr_gemm(&a, &csr, &mut c, m, &ep);
        assert!(c.iter().all(|&v| v == 0.5));
    }

    #[test]
    fn prop_csr_gemm_random() {
        prop::check_n("csr gemm vs dense", 40, |rng: &mut Rng| {
            let m = rng.range(1, 24);
            let k = rng.range(1, 24);
            let n = rng.range(1, 24);
            let density = rng.f64();
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let mut dense = vec![0.0f32; k * n];
            for v in dense.iter_mut() {
                if rng.f64() < density {
                    *v = rng.normal() as f32;
                }
            }
            let csr = CsrMatrix::from_dense(&dense, k, n);
            let mut c1 = vec![0.0; m * n];
            let mut c2 = vec![0.0; m * n];
            gemm_naive(&a, &dense, &mut c1, m, k, n);
            csr_gemm(&a, &csr, &mut c2, m, &Epilogue::None);
            for (x, y) in c1.iter().zip(&c2) {
                prop_assert!((x - y).abs() < 1e-3, "{} vs {}", x, y);
            }
            Ok(())
        });
    }
}
