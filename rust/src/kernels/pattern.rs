//! Pattern-sparse kernels: activations (dense, M x K) times
//! pattern-encoded weights (K x N) — the PatDNN execution path.
//!
//! Where the CSR kernel pays one column index and one scattered
//! read-modify-write per nonzero, the pattern kernel walks *kernels*
//! (surviving `(ci, co)` slices): it reads the kernel's `entries` values
//! contiguously, gathers the matching activations at offsets fixed by
//! the pattern id, reduces them in a register accumulator, and touches
//! `c[m, co]` exactly once per kernel. The 4-entry case (PatDNN's
//! canonical pattern size) is fully unrolled; other sizes take a short
//! generic loop. Per-pattern activation offsets (`pos * cin`) are
//! precomputed once per call, so the inner loop does no index
//! arithmetic beyond one add.
//!
//! Accumulation order per output element is (input channel, kernel
//! position) — ascending K *within* a kernel. The planner's cost model
//! for this kernel lives at `planner::COST_PATTERN_VAL` /
//! `planner::COST_PATTERN_KERNEL`.

use super::{Epilogue, SendPtr, PARALLEL_M_CUTOVER};
use crate::compress::pattern::PatternMatrix;
use crate::obs::{self, Counter};
use crate::util::pool;

/// C(M,N) = A(M,K) @ W_pattern(K,N), single thread.
pub fn pattern_gemm(a: &[f32], w: &PatternMatrix, c: &mut [f32], m: usize, epilogue: &Epilogue) {
    let (k, n) = (w.rows, w.cols);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * n);
    let offs = row_offsets(w);
    pattern_gemm_rows(a, w, &offs, c, 0, m, k, n);
    epilogue.apply(c, m, n);
}

/// Per-pattern activation row offsets (`pos * cin`), one per table
/// position — resolved once per call instead of once per FMA.
fn row_offsets(w: &PatternMatrix) -> Vec<usize> {
    w.pat_pos.iter().map(|&p| p as usize * w.cin).collect()
}

#[allow(clippy::too_many_arguments)]
fn pattern_gemm_rows(
    a: &[f32],
    w: &PatternMatrix,
    offs: &[usize],
    c: &mut [f32],
    m0: usize,
    m1: usize,
    k: usize,
    n: usize,
) {
    c[m0 * n..m1 * n].fill(0.0);
    const MR: usize = 4;
    let mut i = m0;
    while i + MR <= m1 {
        for ci in 0..w.cin {
            let (s, e) = (w.kernel_ptr[ci] as usize, w.kernel_ptr[ci + 1] as usize);
            for kn in s..e {
                let co = w.col_idx[kn] as usize;
                let pid = w.pat_idx[kn] as usize;
                let ps = w.pat_ptr[pid] as usize;
                let pe = w.pat_ptr[pid + 1] as usize;
                let vals = &w.values[w.val_ptr[kn] as usize..w.val_ptr[kn + 1] as usize];
                if pe - ps == 4 {
                    // canonical 4-entry pattern, fully unrolled
                    let o =
                        [offs[ps] + ci, offs[ps + 1] + ci, offs[ps + 2] + ci, offs[ps + 3] + ci];
                    for r in 0..MR {
                        let base = (i + r) * k;
                        let acc = a[base + o[0]] * vals[0]
                            + a[base + o[1]] * vals[1]
                            + a[base + o[2]] * vals[2]
                            + a[base + o[3]] * vals[3];
                        c[(i + r) * n + co] += acc;
                    }
                } else {
                    for r in 0..MR {
                        let base = (i + r) * k;
                        let mut acc = 0.0f32;
                        for (x, &v) in vals.iter().enumerate() {
                            acc += a[base + offs[ps + x] + ci] * v;
                        }
                        c[(i + r) * n + co] += acc;
                    }
                }
            }
        }
        i += MR;
    }
    // remainder rows (< MR), one at a time
    for ir in i..m1 {
        let base = ir * k;
        for ci in 0..w.cin {
            let (s, e) = (w.kernel_ptr[ci] as usize, w.kernel_ptr[ci + 1] as usize);
            for kn in s..e {
                let co = w.col_idx[kn] as usize;
                let pid = w.pat_idx[kn] as usize;
                let ps = w.pat_ptr[pid] as usize;
                let vals = &w.values[w.val_ptr[kn] as usize..w.val_ptr[kn + 1] as usize];
                let mut acc = 0.0f32;
                for (x, &v) in vals.iter().enumerate() {
                    acc += a[base + offs[ps + x] + ci] * v;
                }
                c[ir * n + co] += acc;
            }
        }
    }
}

/// Multithreaded pattern GEMM over disjoint row panels, default cutover.
pub fn pattern_gemm_parallel(
    a: &[f32],
    w: &PatternMatrix,
    c: &mut [f32],
    m: usize,
    epilogue: &Epilogue,
) {
    pattern_gemm_parallel_cutover(a, w, c, m, epilogue, PARALLEL_M_CUTOVER);
}

/// Multithreaded pattern GEMM with a caller-chosen serial cutover (the
/// planner's per-layer override; see [`PARALLEL_M_CUTOVER`]). Emits a
/// `kernel` span (family `pattern`) when the recorder is on, inheriting
/// the calling thread's trace context.
pub fn pattern_gemm_parallel_cutover(
    a: &[f32],
    w: &PatternMatrix,
    c: &mut [f32],
    m: usize,
    epilogue: &Epilogue,
    cutover: usize,
) {
    let t0 = obs::timer();
    pattern_gemm_parallel_cutover_impl(a, w, c, m, epilogue, cutover);
    if let Some(t0) = t0 {
        obs::span_since(
            obs::CAT_KERNEL,
            "pattern".to_string(),
            t0,
            vec![("m", obs::ArgValue::Num(m as f64))],
        );
    }
}

fn pattern_gemm_parallel_cutover_impl(
    a: &[f32],
    w: &PatternMatrix,
    c: &mut [f32],
    m: usize,
    epilogue: &Epilogue,
    cutover: usize,
) {
    let (k, n) = (w.rows, w.cols);
    if obs::on() {
        obs::add(Counter::PatRows, m as u64);
        obs::add(Counter::PatVals, w.nnz() as u64);
    }
    let threads = pool::global().size().min(m.div_ceil(64)).max(1);
    if threads <= 1 || m < cutover {
        obs::add(Counter::PatSerial, 1);
        return pattern_gemm(a, w, c, m, epilogue);
    }
    if obs::on() {
        obs::add(Counter::PatParallel, 1);
        obs::add(Counter::PatPanels, threads as u64);
    }
    let offs = row_offsets(w);
    let chunk = m.div_ceil(threads);
    let cptr = SendPtr(c.as_mut_ptr());
    pool::parallel_for_n(threads, threads, |t| {
        let m0 = t * chunk;
        let m1 = ((t + 1) * chunk).min(m);
        if m0 >= m1 {
            return;
        }
        // SAFETY: disjoint row panels.
        let c_all = unsafe { std::slice::from_raw_parts_mut(cptr.get(), m * n) };
        pattern_gemm_rows(a, w, &offs, c_all, m0, m1, k, n);
        epilogue.apply(&mut c_all[m0 * n..m1 * n], m1 - m0, n);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::pattern::prune_patterns;
    use crate::kernels::gemm::gemm_naive;
    use crate::prop_assert;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn sparse_dense(rng: &mut Rng, len: usize, density: f64) -> Vec<f32> {
        let mut dense = vec![0.0f32; len];
        for v in dense.iter_mut() {
            if rng.f64() < density {
                *v = rng.normal() as f32;
            }
        }
        dense
    }

    #[test]
    fn pattern_matches_dense_gemm() {
        let (kh, kw, cin, n) = (3usize, 3usize, 7usize, 13usize);
        let k = kh * kw * cin;
        let m = 11;
        let mut rng = Rng::new(1);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let dense = sparse_dense(&mut rng, k * n, 0.25);
        let pat = PatternMatrix::from_dense(&dense, kh, kw, cin, n);
        pat.validate().unwrap();
        let mut c_ref = vec![0.0; m * n];
        let mut c = vec![0.0; m * n];
        gemm_naive(&a, &dense, &mut c_ref, m, k, n);
        pattern_gemm(&a, &pat, &mut c, m, &Epilogue::None);
        for (x, y) in c_ref.iter().zip(&c) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let (kh, kw, cin, n) = (3usize, 3usize, 8usize, 16usize);
        let k = kh * kw * cin;
        let m = 300;
        let mut rng = Rng::new(3);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let mut dense = vec![0.0f32; k * n];
        rng.fill_normal(&mut dense, 0.5);
        prune_patterns(&mut dense, kh, kw, cin, n, 0.8, 4, 8);
        let pat = PatternMatrix::from_dense(&dense, kh, kw, cin, n);
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        pattern_gemm(&a, &pat, &mut c1, m, &Epilogue::None);
        pattern_gemm_parallel(&a, &pat, &mut c2, m, &Epilogue::None);
        assert_eq!(c1, c2, "row panels must not change the result");
    }

    #[test]
    fn cutover_forces_serial_with_identical_result() {
        let (kh, kw, cin, n) = (3usize, 3usize, 4usize, 8usize);
        let k = kh * kw * cin;
        let m = 200;
        let mut rng = Rng::new(5);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let dense = sparse_dense(&mut rng, k * n, 0.3);
        let pat = PatternMatrix::from_dense(&dense, kh, kw, cin, n);
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        pattern_gemm(&a, &pat, &mut c1, m, &Epilogue::None);
        pattern_gemm_parallel_cutover(&a, &pat, &mut c2, m, &Epilogue::None, m + 1);
        assert_eq!(c1, c2, "serial-cutover path must be the serial kernel");
    }

    #[test]
    fn empty_weights_give_zero_plus_epilogue() {
        let (m, k, n) = (6, 18, 4);
        let a = vec![1.0; m * k];
        let pat = PatternMatrix::from_dense(&vec![0.0; k * n], 3, 3, 2, n);
        let mut c = vec![9.0; m * n];
        let ep = Epilogue::bias_relu(vec![0.5; n], false);
        pattern_gemm(&a, &pat, &mut c, m, &ep);
        assert!(c.iter().all(|&v| v == 0.5));
    }

    #[test]
    fn prop_pattern_gemm_random() {
        prop::check_n("pattern gemm vs dense", 40, |rng: &mut Rng| {
            let kh = [1usize, 2, 3][rng.below(3)];
            let kw = [2usize, 3][rng.below(2)];
            let cin = rng.range(1, 9);
            let n = rng.range(1, 20);
            let k = kh * kw * cin;
            let m = rng.range(1, 20);
            let density = rng.f64() * rng.f64();
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let dense = sparse_dense(rng, k * n, density);
            let pat = PatternMatrix::from_dense(&dense, kh, kw, cin, n);
            pat.validate()?;
            let mut c1 = vec![0.0; m * n];
            let mut c2 = vec![0.0; m * n];
            gemm_naive(&a, &dense, &mut c1, m, k, n);
            pattern_gemm(&a, &pat, &mut c2, m, &Epilogue::None);
            for (x, y) in c1.iter().zip(&c2) {
                prop_assert!((x - y).abs() < 1e-3, "{} vs {}", x, y);
            }
            Ok(())
        });
    }
}
