//! Row-major f32 tensor (NHWC activations, (K, N) weight matrices).

use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    /// He-initialized random tensor (for synthetic weights).
    pub fn randn(shape: &[usize], rng: &mut Rng, scale: f32) -> Self {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, scale);
        t
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// NHWC accessors (rank-4 only).
    pub fn n(&self) -> usize {
        self.shape[0]
    }
    pub fn h(&self) -> usize {
        self.shape[1]
    }
    pub fn w(&self) -> usize {
        self.shape[2]
    }
    pub fn c(&self) -> usize {
        *self.shape.last().unwrap()
    }

    #[inline]
    pub fn at4(&self, n: usize, y: usize, x: usize, c: usize) -> f32 {
        let (h, w, ch) = (self.shape[1], self.shape[2], self.shape[3]);
        self.data[((n * h + y) * w + x) * ch + c]
    }

    /// Reinterpret as (rows, cols) without copying (row-major flatten).
    pub fn as_2d(&self, rows: usize, cols: usize) -> &[f32] {
        assert_eq!(rows * cols, self.data.len());
        &self.data
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_from_vec() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.numel(), 6);
        let u = Tensor::from_vec(&[2, 3], vec![1.0; 6]);
        assert_eq!(u.shape, vec![2, 3]);
    }

    #[test]
    fn at4_indexing() {
        let mut t = Tensor::zeros(&[1, 2, 2, 3]);
        t.data[((0 * 2 + 1) * 2 + 0) * 3 + 2] = 7.0;
        assert_eq!(t.at4(0, 1, 0, 2), 7.0);
    }

    #[test]
    fn randn_deterministic() {
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = Tensor::randn(&[10], &mut r1, 1.0);
        let b = Tensor::randn(&[10], &mut r2, 1.0);
        assert_eq!(a, b);
    }

    #[test]
    fn max_abs_diff() {
        let a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(&[3], vec![1.0, 2.5, 2.0]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }
}
