//! Dense GEMM kernels: C(M,N) = A(M,K) @ B(K,N), row-major.
//!
//! Three schedules, mirroring the paper's optimization ladder:
//! - `gemm_naive`   — textbook triple loop (the unoptimized reference and
//!   the TFLite-like personality's inner engine)
//! - `gemm_blocked` — cache-tiled (mc x kc x nc) with a register-
//!   resident micro-kernel (4 rows x 4-or-8 columns selected by the
//!   `unroll` tune parameter), load-hoisted exactly as the paper's
//!   redundant-load-elimination describes
//! - `gemm_parallel`— `gemm_blocked` sharded over row panels on the
//!   global thread pool
//!
//! All accept an `Epilogue` applied while the output panel is hot
//! (fusion); the unfused personalities pass `Epilogue::None` and run
//! separate bn/act sweeps instead.

use super::{Epilogue, SendPtr, PARALLEL_M_CUTOVER};
use crate::obs::{self, Counter};
use crate::passes::layout::TileConfig;
use crate::util::pool;

/// Textbook ikj loop (k-major inner for contiguous B rows).
pub fn gemm_naive(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..p * n + n];
            let crow = &mut c[i * n..i * n + n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// Cache-blocked GEMM with a register-resident MR x NR micro-kernel over
/// the row range [m0, m1).
///
/// §Perf note: the first implementation accumulated straight into C
/// (`c[..] += a*b` inside the p loop), re-loading/storing every
/// accumulator each reduction step — memory-bound at ~2 GFLOPS. The
/// micro-kernel now keeps an MR x NR accumulator block in registers for
/// the whole pb..pe reduction and stores once (EXPERIMENTS.md §Perf).
fn gemm_blocked_rows(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m0: usize,
    m1: usize,
    k: usize,
    n: usize,
    tile: &TileConfig,
) {
    const MR: usize = 4; // micro-kernel rows (matches load_elim::MICRO_ROWS)
    let (mc, kc, nc) = (tile.mc.max(MR), tile.kc.max(1), tile.nc.max(1));
    c[m0 * n..m1 * n].fill(0.0);
    // register-tile width from the tune parameter (8 suits AVX2 f32x8)
    let nr = if tile.unroll >= 8 { 8 } else { 4 };
    let mut ib = m0;
    while ib < m1 {
        let ie = (ib + mc).min(m1);
        let mut pb = 0;
        while pb < k {
            let pe = (pb + kc).min(k);
            let mut jb = 0;
            while jb < n {
                let je = (jb + nc).min(n);
                // macro tile [ib..ie) x [pb..pe) x [jb..je)
                let mut i = ib;
                while i + MR <= ie {
                    let mut j = jb;
                    if nr == 8 {
                        while j + 8 <= je {
                            micro_kernel::<MR, 8>(a, b, c, i, pb, pe, j, k, n);
                            j += 8;
                        }
                    }
                    while j + 4 <= je {
                        micro_kernel::<MR, 4>(a, b, c, i, pb, pe, j, k, n);
                        j += 4;
                    }
                    // remainder columns (< 4)
                    if j < je {
                        edge_kernel(a, b, c, i, i + MR, pb, pe, j, je, k, n);
                    }
                    i += MR;
                }
                // remainder rows
                if i < ie {
                    edge_kernel(a, b, c, i, ie, pb, pe, jb, je, k, n);
                }
                jb = je;
            }
            pb = pe;
        }
        ib = ie;
    }
}

/// MR x NR register micro-kernel: the accumulator block lives in
/// registers across the whole reduction; every A and B element is loaded
/// once per micro-tile (the paper's redundant-load elimination).
#[inline]
fn micro_kernel<const MR: usize, const NR: usize>(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    i: usize,
    pb: usize,
    pe: usize,
    j: usize,
    k: usize,
    n: usize,
) {
    let mut acc = [[0f32; NR]; MR];
    // load current C block (we may revisit the tile across kc panels)
    for r in 0..MR {
        let crow = &c[(i + r) * n + j..(i + r) * n + j + NR];
        acc[r].copy_from_slice(crow);
    }
    for p in pb..pe {
        let brow = &b[p * n + j..p * n + j + NR];
        for r in 0..MR {
            let av = a[(i + r) * k + p];
            for x in 0..NR {
                acc[r][x] += av * brow[x];
            }
        }
    }
    for r in 0..MR {
        c[(i + r) * n + j..(i + r) * n + j + NR].copy_from_slice(&acc[r]);
    }
}

/// Scalar fallback for ragged tile edges.
#[inline]
#[allow(clippy::too_many_arguments)]
fn edge_kernel(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    i0: usize,
    i1: usize,
    pb: usize,
    pe: usize,
    jb: usize,
    je: usize,
    k: usize,
    n: usize,
) {
    for ir in i0..i1 {
        for p in pb..pe {
            let av = a[ir * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..];
            let crow = &mut c[ir * n..];
            for j in jb..je {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// Blocked GEMM + fused epilogue (single thread).
pub fn gemm_blocked(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    tile: &TileConfig,
    epilogue: &Epilogue,
) {
    gemm_blocked_rows(a, b, c, 0, m, k, n, tile);
    epilogue.apply(c, m, n);
}

/// Multithreaded blocked GEMM: row panels are disjoint slices of C.
/// Emits a `kernel` span (family `gemm`) when the recorder is on,
/// inheriting the calling thread's trace context.
pub fn gemm_parallel(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    tile: &TileConfig,
    epilogue: &Epilogue,
) {
    let t0 = obs::timer();
    gemm_parallel_impl(a, b, c, m, k, n, tile, epilogue);
    if let Some(t0) = t0 {
        obs::span_since(
            obs::CAT_KERNEL,
            "gemm".to_string(),
            t0,
            vec![("m", obs::ArgValue::Num(m as f64))],
        );
    }
}

fn gemm_parallel_impl(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    tile: &TileConfig,
    epilogue: &Epilogue,
) {
    obs::add(Counter::GemmRows, m as u64);
    let threads = pool::global().size().min(m.div_ceil(64)).max(1);
    if threads <= 1 || m < PARALLEL_M_CUTOVER {
        obs::add(Counter::GemmSerial, 1);
        return gemm_blocked(a, b, c, m, k, n, tile, epilogue);
    }
    obs::add(Counter::GemmParallel, 1);
    let chunk = m.div_ceil(threads);
    let cptr = SendPtr(c.as_mut_ptr());
    pool::parallel_for_n(threads, threads, |t| {
        let m0 = t * chunk;
        let m1 = ((t + 1) * chunk).min(m);
        if m0 >= m1 {
            return;
        }
        // SAFETY: row panels [m0*n, m1*n) are disjoint across t.
        let c_all = unsafe { std::slice::from_raw_parts_mut(cptr.get(), m * n) };
        gemm_blocked_rows(a, b, c_all, m0, m1, k, n, tile);
        epilogue.apply(&mut c_all[m0 * n..m1 * n], m1 - m0, n);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + x.abs()), "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn blocked_matches_naive() {
        for (m, k, n) in [(1, 1, 1), (5, 7, 3), (33, 65, 17), (128, 64, 96)] {
            let a = randv(m * k, 1);
            let b = randv(k * n, 2);
            let mut c1 = vec![0.0; m * n];
            let mut c2 = vec![0.0; m * n];
            gemm_naive(&a, &b, &mut c1, m, k, n);
            gemm_blocked(&a, &b, &mut c2, m, k, n, &TileConfig::DEFAULT, &Epilogue::None);
            assert_close(&c1, &c2, 1e-4);
        }
    }

    #[test]
    fn blocked_with_odd_tiles_matches() {
        let (m, k, n) = (50, 30, 41);
        let a = randv(m * k, 3);
        let b = randv(k * n, 4);
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        gemm_naive(&a, &b, &mut c1, m, k, n);
        let tile = TileConfig { mc: 7, nc: 13, kc: 11, unroll: 2 };
        gemm_blocked(&a, &b, &mut c2, m, k, n, &tile, &Epilogue::None);
        assert_close(&c1, &c2, 1e-4);
    }

    #[test]
    fn parallel_matches_naive() {
        let (m, k, n) = (300, 64, 48);
        let a = randv(m * k, 5);
        let b = randv(k * n, 6);
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        gemm_naive(&a, &b, &mut c1, m, k, n);
        gemm_parallel(&a, &b, &mut c2, m, k, n, &TileConfig::DEFAULT, &Epilogue::None);
        assert_close(&c1, &c2, 1e-4);
    }

    #[test]
    fn fused_epilogue_equals_separate() {
        let (m, k, n) = (40, 20, 12);
        let a = randv(m * k, 7);
        let b = randv(k * n, 8);
        let scale: Vec<f32> = (0..n).map(|i| 0.5 + i as f32 * 0.1).collect();
        let shift: Vec<f32> = (0..n).map(|i| i as f32 * 0.01 - 0.05).collect();
        let ep = Epilogue::bn_act(scale.clone(), shift.clone(), true, false);
        let mut c1 = vec![0.0; m * n];
        gemm_naive(&a, &b, &mut c1, m, k, n);
        for r in 0..m {
            for j in 0..n {
                c1[r * n + j] = (c1[r * n + j] * scale[j] + shift[j]).max(0.0);
            }
        }
        let mut c2 = vec![0.0; m * n];
        gemm_blocked(&a, &b, &mut c2, m, k, n, &TileConfig::DEFAULT, &ep);
        assert_close(&c1, &c2, 1e-4);
    }
}
