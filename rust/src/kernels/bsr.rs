//! Block-sparse kernels: activations (dense, M x K) times BSR weights
//! (K x N).
//!
//! Where the CSR kernel pays one column index and one scattered store per
//! nonzero, the BSR kernel pays one index per (br x bc) block and streams
//! the block's values contiguously, keeping a bc-wide accumulator strip
//! in registers across the block's br-deep reduction. That makes the
//! per-stored-value cost much lower than CSR's — the planner's cost model
//! (`planner::COST_*`) trades that against the padding the block format
//! stores (see `docs/FORMATS.md`).
//!
//! Specialized micro-kernels exist for the planner's candidate shapes
//! (4x1 and 4x4); other block shapes fall back to a generic path.

use super::{Epilogue, SendPtr, PARALLEL_M_CUTOVER};
use crate::compress::bsr::BsrMatrix;
use crate::obs::{self, Counter};
use crate::util::pool;

/// C(M,N) = A(M,K) @ W_bsr(K,N), single thread.
pub fn bsr_gemm(a: &[f32], w: &BsrMatrix, c: &mut [f32], m: usize, epilogue: &Epilogue) {
    let (k, n) = (w.rows, w.cols);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * n);
    bsr_gemm_rows(a, w, c, 0, m, k, n);
    epilogue.apply(c, m, n);
}

fn bsr_gemm_rows(
    a: &[f32],
    w: &BsrMatrix,
    c: &mut [f32],
    m0: usize,
    m1: usize,
    k: usize,
    n: usize,
) {
    c[m0 * n..m1 * n].fill(0.0);
    match (w.br, w.bc) {
        (4, 1) => bsr_rows_spec::<4, 1>(a, w, c, m0, m1, k, n),
        (4, 4) => bsr_rows_spec::<4, 4>(a, w, c, m0, m1, k, n),
        (8, 1) => bsr_rows_spec::<8, 1>(a, w, c, m0, m1, k, n),
        (8, 4) => bsr_rows_spec::<8, 4>(a, w, c, m0, m1, k, n),
        _ => bsr_rows_generic(a, w, c, m0, m1, k, n),
    }
}

/// Monomorphized micro-kernel: MR=4 activation rows x (BR x BC) blocks.
/// The (MR x BR) activation panel is hoisted once per block row and the
/// BC-wide accumulator strip lives in registers across the BR reduction,
/// so each C element is loaded/stored once per stored block.
fn bsr_rows_spec<const BR: usize, const BC: usize>(
    a: &[f32],
    w: &BsrMatrix,
    c: &mut [f32],
    m0: usize,
    m1: usize,
    k: usize,
    n: usize,
) {
    const MR: usize = 4;
    let nbr = w.block_rows();
    let mut i = m0;
    while i + MR <= m1 {
        for kb in 0..nbr {
            let (s, e) = (w.row_ptr[kb] as usize, w.row_ptr[kb + 1] as usize);
            if s == e {
                // empty block row: skip before touching activations, so
                // deeply pruned layers keep scaling with stored blocks
                continue;
            }
            let p0 = kb * BR;
            let pl = BR.min(k - p0);
            // hoist the MR x BR activation panel (zeros past the K edge)
            let mut av = [[0f32; BR]; MR];
            let mut any = false;
            for (r, avr) in av.iter_mut().enumerate() {
                let base = (i + r) * k + p0;
                for (p, slot) in avr.iter_mut().take(pl).enumerate() {
                    let v = a[base + p];
                    *slot = v;
                    any |= v != 0.0;
                }
            }
            if !any {
                continue;
            }
            for bi in s..e {
                let j0 = w.col_idx[bi] as usize * BC;
                let vals = &w.values[bi * BR * BC..(bi + 1) * BR * BC];
                let cl = BC.min(n - j0);
                for (r, avr) in av.iter().enumerate() {
                    let mut acc = [0f32; BC];
                    for (p, &apv) in avr.iter().take(pl).enumerate() {
                        if apv == 0.0 {
                            continue;
                        }
                        let vrow = &vals[p * BC..p * BC + BC];
                        for x in 0..BC {
                            acc[x] += apv * vrow[x];
                        }
                    }
                    let crow = &mut c[(i + r) * n + j0..(i + r) * n + j0 + cl];
                    for (x, cv) in crow.iter_mut().enumerate() {
                        *cv += acc[x];
                    }
                }
            }
        }
        i += MR;
    }
    // remainder rows (< MR), one at a time
    for ir in i..m1 {
        for kb in 0..nbr {
            let (s, e) = (w.row_ptr[kb] as usize, w.row_ptr[kb + 1] as usize);
            if s == e {
                continue;
            }
            let p0 = kb * BR;
            let pl = BR.min(k - p0);
            let mut av = [0f32; BR];
            let mut any = false;
            let base = ir * k + p0;
            for (p, slot) in av.iter_mut().take(pl).enumerate() {
                let v = a[base + p];
                *slot = v;
                any |= v != 0.0;
            }
            if !any {
                continue;
            }
            for bi in s..e {
                let j0 = w.col_idx[bi] as usize * BC;
                let vals = &w.values[bi * BR * BC..(bi + 1) * BR * BC];
                let cl = BC.min(n - j0);
                let mut acc = [0f32; BC];
                for (p, &apv) in av.iter().take(pl).enumerate() {
                    if apv == 0.0 {
                        continue;
                    }
                    let vrow = &vals[p * BC..p * BC + BC];
                    for x in 0..BC {
                        acc[x] += apv * vrow[x];
                    }
                }
                let crow = &mut c[ir * n + j0..ir * n + j0 + cl];
                for (x, cv) in crow.iter_mut().enumerate() {
                    *cv += acc[x];
                }
            }
        }
    }
}

/// Generic fallback for unusual block shapes — correct for any (br, bc).
fn bsr_rows_generic(
    a: &[f32],
    w: &BsrMatrix,
    c: &mut [f32],
    m0: usize,
    m1: usize,
    k: usize,
    n: usize,
) {
    let (br, bc) = (w.br, w.bc);
    for ir in m0..m1 {
        for kb in 0..w.block_rows() {
            let p0 = kb * br;
            let pl = br.min(k - p0);
            let (s, e) = (w.row_ptr[kb] as usize, w.row_ptr[kb + 1] as usize);
            for bi in s..e {
                let j0 = w.col_idx[bi] as usize * bc;
                let vals = &w.values[bi * br * bc..(bi + 1) * br * bc];
                let cl = bc.min(n - j0);
                let crow = &mut c[ir * n + j0..ir * n + j0 + cl];
                for p in 0..pl {
                    let apv = a[ir * k + p0 + p];
                    if apv == 0.0 {
                        continue;
                    }
                    let vrow = &vals[p * bc..p * bc + cl];
                    for (cv, &wv) in crow.iter_mut().zip(vrow) {
                        *cv += apv * wv;
                    }
                }
            }
        }
    }
}

/// Multithreaded BSR GEMM over disjoint row panels, default cutover.
pub fn bsr_gemm_parallel(a: &[f32], w: &BsrMatrix, c: &mut [f32], m: usize, epilogue: &Epilogue) {
    bsr_gemm_parallel_cutover(a, w, c, m, epilogue, PARALLEL_M_CUTOVER);
}

/// Multithreaded BSR GEMM with a caller-chosen serial cutover (the
/// planner's per-layer override; see [`PARALLEL_M_CUTOVER`]). Emits a
/// `kernel` span (family `bsr`) when the recorder is on, inheriting the
/// calling thread's trace context.
pub fn bsr_gemm_parallel_cutover(
    a: &[f32],
    w: &BsrMatrix,
    c: &mut [f32],
    m: usize,
    epilogue: &Epilogue,
    cutover: usize,
) {
    let t0 = obs::timer();
    bsr_gemm_parallel_cutover_impl(a, w, c, m, epilogue, cutover);
    if let Some(t0) = t0 {
        obs::span_since(
            obs::CAT_KERNEL,
            "bsr".to_string(),
            t0,
            vec![("m", obs::ArgValue::Num(m as f64))],
        );
    }
}

fn bsr_gemm_parallel_cutover_impl(
    a: &[f32],
    w: &BsrMatrix,
    c: &mut [f32],
    m: usize,
    epilogue: &Epilogue,
    cutover: usize,
) {
    let (k, n) = (w.rows, w.cols);
    if obs::on() {
        obs::add(Counter::BsrRows, m as u64);
        obs::add(Counter::BsrBlocks, w.blocks() as u64);
    }
    let threads = pool::global().size().min(m.div_ceil(64)).max(1);
    if threads <= 1 || m < cutover {
        obs::add(Counter::BsrSerial, 1);
        return bsr_gemm(a, w, c, m, epilogue);
    }
    if obs::on() {
        obs::add(Counter::BsrParallel, 1);
        obs::add(Counter::BsrPanels, threads as u64);
    }
    let chunk = m.div_ceil(threads);
    let cptr = SendPtr(c.as_mut_ptr());
    pool::parallel_for_n(threads, threads, |t| {
        let m0 = t * chunk;
        let m1 = ((t + 1) * chunk).min(m);
        if m0 >= m1 {
            return;
        }
        // SAFETY: disjoint row panels.
        let c_all = unsafe { std::slice::from_raw_parts_mut(cptr.get(), m * n) };
        bsr_gemm_rows(a, w, c_all, m0, m1, k, n);
        epilogue.apply(&mut c_all[m0 * n..m1 * n], m1 - m0, n);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::reorder;
    use crate::kernels::gemm::gemm_naive;
    use crate::prop_assert;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn sparse_dense(rng: &mut Rng, k: usize, n: usize, density: f64) -> Vec<f32> {
        let mut dense = vec![0.0f32; k * n];
        for v in dense.iter_mut() {
            if rng.f64() < density {
                *v = rng.normal() as f32;
            }
        }
        dense
    }

    #[test]
    fn bsr_matches_dense_gemm_both_shapes() {
        let (m, k, n) = (13, 37, 21);
        let mut rng = Rng::new(1);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let dense = sparse_dense(&mut rng, k, n, 0.3);
        let mut c_ref = vec![0.0; m * n];
        gemm_naive(&a, &dense, &mut c_ref, m, k, n);
        for (br, bc) in [(4usize, 1usize), (4, 4), (8, 1), (3, 2)] {
            let bsr = BsrMatrix::from_dense(&dense, k, n, br, bc);
            let mut c = vec![0.0; m * n];
            bsr_gemm(&a, &bsr, &mut c, m, &Epilogue::None);
            for (x, y) in c_ref.iter().zip(&c) {
                assert!((x - y).abs() < 1e-4, "{br}x{bc}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let (m, k, n) = (300, 64, 32);
        let mut rng = Rng::new(3);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let dense = sparse_dense(&mut rng, k, n, 0.2);
        let bsr = BsrMatrix::from_dense(&dense, k, n, 4, 4);
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        bsr_gemm(&a, &bsr, &mut c1, m, &Epilogue::None);
        bsr_gemm_parallel(&a, &bsr, &mut c2, m, &Epilogue::None);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn cutover_forces_serial_with_identical_result() {
        let (m, k, n) = (200, 32, 16);
        let mut rng = Rng::new(5);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let dense = sparse_dense(&mut rng, k, n, 0.4);
        let bsr = BsrMatrix::from_dense(&dense, k, n, 4, 1);
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        bsr_gemm(&a, &bsr, &mut c1, m, &Epilogue::None);
        // cutover above m: parallel entry point must take the serial path
        bsr_gemm_parallel_cutover(&a, &bsr, &mut c2, m, &Epilogue::None, m + 1);
        assert_eq!(c1, c2, "serial-cutover path must be the serial kernel");
    }

    #[test]
    fn empty_weights_give_zero_plus_epilogue() {
        let (m, k, n) = (6, 10, 4);
        let a = vec![1.0; m * k];
        let bsr = BsrMatrix::from_dense(&vec![0.0; k * n], k, n, 4, 4);
        let mut c = vec![9.0; m * n];
        let ep = Epilogue::bias_relu(vec![0.5; n], false);
        bsr_gemm(&a, &bsr, &mut c, m, &ep);
        assert!(c.iter().all(|&v| v == 0.5));
    }

    /// Satellite (a): BSR x dense matches the naive reference across
    /// random densities, including matrices with all-zero blocks.
    #[test]
    fn prop_bsr_gemm_random() {
        prop::check_n("bsr gemm vs dense", 40, |rng: &mut Rng| {
            let m = rng.range(1, 24);
            let k = rng.range(1, 40);
            let n = rng.range(1, 24);
            let density = rng.f64() * rng.f64(); // skew sparse: zero blocks common
            let br = [4usize, 8][rng.below(2)];
            let bc = [1usize, 4][rng.below(2)];
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let dense = sparse_dense(rng, k, n, density);
            let bsr = BsrMatrix::from_dense(&dense, k, n, br, bc);
            bsr.validate()?;
            let mut c1 = vec![0.0; m * n];
            let mut c2 = vec![0.0; m * n];
            gemm_naive(&a, &dense, &mut c1, m, k, n);
            bsr_gemm(&a, &bsr, &mut c2, m, &Epilogue::None);
            for (x, y) in c1.iter().zip(&c2) {
                prop_assert!((x - y).abs() < 1e-3, "{} vs {}", x, y);
            }
            Ok(())
        });
    }

    /// Satellite (b): reorder -> execute -> inverse-permute is
    /// bit-identical to the unreordered path (a column permutation never
    /// changes any output element's reduction order over K).
    #[test]
    fn prop_reordered_execution_bit_identical() {
        prop::check_n("bsr reorder bit-identical", 40, |rng: &mut Rng| {
            let m = rng.range(1, 16);
            let k = rng.range(1, 32);
            let n = rng.range(1, 24);
            let density = rng.f64();
            let br = 4usize;
            let bc = [1usize, 4][rng.below(2)];
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let dense = sparse_dense(rng, k, n, density);
            let scale: Vec<f32> = (0..n).map(|_| 0.5 + rng.f32()).collect();
            let shift: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
            let epi = Epilogue::bn_act(scale, shift, true, false);

            // unreordered reference
            let bsr = BsrMatrix::from_dense(&dense, k, n, br, bc);
            let mut c_ref = vec![0.0; m * n];
            bsr_gemm(&a, &bsr, &mut c_ref, m, &epi);

            // reorder columns, permute the epilogue with them, execute,
            // scatter the output back
            let p = reorder::cluster_columns(&dense, k, n, br);
            p.validate()?;
            let permuted = reorder::permute_cols(&dense, k, n, &p);
            let bsr_p = BsrMatrix::from_dense(&permuted, k, n, br, bc);
            let epi_p = epi.permute_channels(&p.perm);
            let mut c = vec![0.0; m * n];
            bsr_gemm(&a, &bsr_p, &mut c, m, &epi_p);
            reorder::unpermute_cols_inplace(&mut c, m, n, &p);

            prop_assert!(c == c_ref, "reordered path not bit-identical");
            Ok(())
        });
    }
}
