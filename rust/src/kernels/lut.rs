//! LUT micro-kernels: activations (dense, M x K) times codebook-packed
//! sparse weights ([`crate::compress::qsparse`]) — quantized execution
//! without an intermediate dense (or dequantized) buffer.
//!
//! Each kernel is a literal mirror of its f32 counterpart
//! (`kernels::sparse` / `kernels::bsr` / `kernels::pattern`): the loop
//! structure, skip conditions, and accumulation order are identical, and
//! the only change is where a weight value comes from — `codebook[idx]`
//! gathered from the packed index stream instead of an f32 load. Because
//! the gathered float IS the dequantized value, every LUT kernel's
//! output is **bit-identical** to running the matching f32 kernel on the
//! dequantized matrix (property-tested below); the only approximation in
//! the whole path is the one-time value→codebook snap at fit time,
//! bounded by [`crate::compress::qsparse::QuantizedValues::error_bound`].
//!
//! Gather strategy per format:
//! - **CSR**: per-nonzero gather (`lut[idx]`), same MR=4 activation-row
//!   hoisting as `csr_gemm`.
//! - **BSR**: the block's `BR*BC` indices are expanded into a stack
//!   panel once per (row-panel, block) visit — the same per-visit value
//!   traffic as the f32 kernel, which also re-reads the block per
//!   row-panel — then the register-blocked accumulator strip runs
//!   unchanged.
//! - **Pattern**: the kernel's `entries` values are gathered into the
//!   unrolled 4-entry accumulator (contiguous `val_ptr` runs make the
//!   index stream sequential — the layout PatDNN's sub-byte packing
//!   argument is about).
//!
//! Cost-model hooks: `planner::COST_LUT_Q8` / `COST_LUT_Q4` price the
//! extra unpack+gather per value relative to the f32 kernels.

use super::{Epilogue, SendPtr, PARALLEL_M_CUTOVER};
use crate::compress::qsparse::{QBsr, QCsr, QPattern, QSparseMatrix};
use crate::obs::{self, Counter};
use crate::util::pool;

/// Counter bump shared by the three LUT dispatchers (`vals` = stored
/// quantized values the kernel will gather).
#[inline]
fn count_dispatch(m: usize, vals: usize, parallel: bool, panels: usize) {
    if !obs::on() {
        return;
    }
    obs::add(Counter::LutRows, m as u64);
    obs::add(Counter::LutVals, vals as u64);
    if parallel {
        obs::add(Counter::LutParallel, 1);
        obs::add(Counter::LutPanels, panels as u64);
    } else {
        obs::add(Counter::LutSerial, 1);
    }
}

// ---------------------------------------------------------------------------
// CSR
// ---------------------------------------------------------------------------

/// C(M,N) = A(M,K) @ W_qcsr(K,N), single thread — mirrors
/// [`crate::kernels::sparse::csr_gemm`].
pub fn qcsr_gemm(a: &[f32], w: &QCsr, c: &mut [f32], m: usize, epilogue: &Epilogue) {
    let (k, n) = (w.rows, w.cols);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * n);
    qcsr_gemm_rows(a, w, c, 0, m, k, n);
    epilogue.apply(c, m, n);
}

fn qcsr_gemm_rows(a: &[f32], w: &QCsr, c: &mut [f32], m0: usize, m1: usize, k: usize, n: usize) {
    c[m0 * n..m1 * n].fill(0.0);
    let lut = w.values.codebook.as_slice();
    const MR: usize = 4;
    let mut i = m0;
    while i + MR <= m1 {
        for p in 0..k {
            let av = [
                a[i * k + p],
                a[(i + 1) * k + p],
                a[(i + 2) * k + p],
                a[(i + 3) * k + p],
            ];
            if av == [0.0; 4] {
                continue;
            }
            let (s, e) = (w.row_ptr[p] as usize, w.row_ptr[p + 1] as usize);
            for idx in s..e {
                let col = w.col_idx[idx] as usize;
                let v = lut[w.values.index(idx)];
                c[i * n + col] += av[0] * v;
                c[(i + 1) * n + col] += av[1] * v;
                c[(i + 2) * n + col] += av[2] * v;
                c[(i + 3) * n + col] += av[3] * v;
            }
        }
        i += MR;
    }
    for ir in i..m1 {
        for p in 0..k {
            let av = a[ir * k + p];
            if av == 0.0 {
                continue;
            }
            let (s, e) = (w.row_ptr[p] as usize, w.row_ptr[p + 1] as usize);
            for idx in s..e {
                c[ir * n + w.col_idx[idx] as usize] += av * lut[w.values.index(idx)];
            }
        }
    }
}

/// Multithreaded LUT CSR GEMM over disjoint row panels, default cutover.
pub fn qcsr_gemm_parallel(a: &[f32], w: &QCsr, c: &mut [f32], m: usize, epilogue: &Epilogue) {
    qcsr_gemm_parallel_cutover(a, w, c, m, epilogue, PARALLEL_M_CUTOVER);
}

/// Multithreaded LUT CSR GEMM with a caller-chosen serial cutover.
pub fn qcsr_gemm_parallel_cutover(
    a: &[f32],
    w: &QCsr,
    c: &mut [f32],
    m: usize,
    epilogue: &Epilogue,
    cutover: usize,
) {
    let (k, n) = (w.rows, w.cols);
    let threads = pool::global().size().min(m.div_ceil(64)).max(1);
    if threads <= 1 || m < cutover {
        count_dispatch(m, w.nnz(), false, 0);
        return qcsr_gemm(a, w, c, m, epilogue);
    }
    count_dispatch(m, w.nnz(), true, threads);
    let chunk = m.div_ceil(threads);
    let cptr = SendPtr(c.as_mut_ptr());
    pool::parallel_for_n(threads, threads, |t| {
        let m0 = t * chunk;
        let m1 = ((t + 1) * chunk).min(m);
        if m0 >= m1 {
            return;
        }
        // SAFETY: disjoint row panels.
        let c_all = unsafe { std::slice::from_raw_parts_mut(cptr.get(), m * n) };
        qcsr_gemm_rows(a, w, c_all, m0, m1, k, n);
        epilogue.apply(&mut c_all[m0 * n..m1 * n], m1 - m0, n);
    });
}

// ---------------------------------------------------------------------------
// BSR
// ---------------------------------------------------------------------------

/// C(M,N) = A(M,K) @ W_qbsr(K,N), single thread — mirrors
/// [`crate::kernels::bsr::bsr_gemm`].
pub fn qbsr_gemm(a: &[f32], w: &QBsr, c: &mut [f32], m: usize, epilogue: &Epilogue) {
    let (k, n) = (w.rows, w.cols);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * n);
    qbsr_gemm_rows(a, w, c, 0, m, k, n);
    epilogue.apply(c, m, n);
}

fn qbsr_gemm_rows(a: &[f32], w: &QBsr, c: &mut [f32], m0: usize, m1: usize, k: usize, n: usize) {
    c[m0 * n..m1 * n].fill(0.0);
    match (w.br, w.bc) {
        (4, 1) => qbsr_rows_spec::<4, 1>(a, w, c, m0, m1, k, n),
        (4, 4) => qbsr_rows_spec::<4, 4>(a, w, c, m0, m1, k, n),
        (8, 1) => qbsr_rows_spec::<8, 1>(a, w, c, m0, m1, k, n),
        (8, 4) => qbsr_rows_spec::<8, 4>(a, w, c, m0, m1, k, n),
        _ => qbsr_rows_generic(a, w, c, m0, m1, k, n),
    }
}

/// Stack capacity for one expanded block (largest specialized shape is
/// 8x4 = 32 values); the panel lives in registers / L1, never the heap.
const MAX_BLOCK: usize = 32;

/// Expand one stored block's packed indices through the codebook into a
/// stack panel — the per-visit analogue of the f32 kernel's contiguous
/// block read (which also touches the whole block per row-panel visit).
#[inline(always)]
fn expand_block(w: &QBsr, bi: usize, brc: usize, lut: &[f32], blk: &mut [f32; MAX_BLOCK]) {
    debug_assert!(brc <= MAX_BLOCK);
    let base = bi * brc;
    for (t, slot) in blk.iter_mut().take(brc).enumerate() {
        *slot = lut[w.values.index(base + t)];
    }
}

fn qbsr_rows_spec<const BR: usize, const BC: usize>(
    a: &[f32],
    w: &QBsr,
    c: &mut [f32],
    m0: usize,
    m1: usize,
    k: usize,
    n: usize,
) {
    const MR: usize = 4;
    let lut = w.values.codebook.as_slice();
    let mut blk = [0f32; MAX_BLOCK];
    let nbr = w.block_rows();
    let mut i = m0;
    while i + MR <= m1 {
        for kb in 0..nbr {
            let (s, e) = (w.row_ptr[kb] as usize, w.row_ptr[kb + 1] as usize);
            if s == e {
                continue;
            }
            let p0 = kb * BR;
            let pl = BR.min(k - p0);
            let mut av = [[0f32; BR]; MR];
            let mut any = false;
            for (r, avr) in av.iter_mut().enumerate() {
                let base = (i + r) * k + p0;
                for (p, slot) in avr.iter_mut().take(pl).enumerate() {
                    let v = a[base + p];
                    *slot = v;
                    any |= v != 0.0;
                }
            }
            if !any {
                continue;
            }
            for bi in s..e {
                let j0 = w.col_idx[bi] as usize * BC;
                expand_block(w, bi, BR * BC, lut, &mut blk);
                let vals = &blk[..BR * BC];
                let cl = BC.min(n - j0);
                for (r, avr) in av.iter().enumerate() {
                    let mut acc = [0f32; BC];
                    for (p, &apv) in avr.iter().take(pl).enumerate() {
                        if apv == 0.0 {
                            continue;
                        }
                        let vrow = &vals[p * BC..p * BC + BC];
                        for x in 0..BC {
                            acc[x] += apv * vrow[x];
                        }
                    }
                    let crow = &mut c[(i + r) * n + j0..(i + r) * n + j0 + cl];
                    for (x, cv) in crow.iter_mut().enumerate() {
                        *cv += acc[x];
                    }
                }
            }
        }
        i += MR;
    }
    // remainder rows (< MR), one at a time
    for ir in i..m1 {
        for kb in 0..nbr {
            let (s, e) = (w.row_ptr[kb] as usize, w.row_ptr[kb + 1] as usize);
            if s == e {
                continue;
            }
            let p0 = kb * BR;
            let pl = BR.min(k - p0);
            let mut av = [0f32; BR];
            let mut any = false;
            let base = ir * k + p0;
            for (p, slot) in av.iter_mut().take(pl).enumerate() {
                let v = a[base + p];
                *slot = v;
                any |= v != 0.0;
            }
            if !any {
                continue;
            }
            for bi in s..e {
                let j0 = w.col_idx[bi] as usize * BC;
                expand_block(w, bi, BR * BC, lut, &mut blk);
                let vals = &blk[..BR * BC];
                let cl = BC.min(n - j0);
                let mut acc = [0f32; BC];
                for (p, &apv) in av.iter().take(pl).enumerate() {
                    if apv == 0.0 {
                        continue;
                    }
                    let vrow = &vals[p * BC..p * BC + BC];
                    for x in 0..BC {
                        acc[x] += apv * vrow[x];
                    }
                }
                let crow = &mut c[ir * n + j0..ir * n + j0 + cl];
                for (x, cv) in crow.iter_mut().enumerate() {
                    *cv += acc[x];
                }
            }
        }
    }
}

/// Generic fallback for unusual block shapes — correct for any (br, bc).
fn qbsr_rows_generic(
    a: &[f32],
    w: &QBsr,
    c: &mut [f32],
    m0: usize,
    m1: usize,
    k: usize,
    n: usize,
) {
    let (br, bc) = (w.br, w.bc);
    let lut = w.values.codebook.as_slice();
    for ir in m0..m1 {
        for kb in 0..w.block_rows() {
            let p0 = kb * br;
            let pl = br.min(k - p0);
            let (s, e) = (w.row_ptr[kb] as usize, w.row_ptr[kb + 1] as usize);
            for bi in s..e {
                let j0 = w.col_idx[bi] as usize * bc;
                let base = bi * br * bc;
                let cl = bc.min(n - j0);
                let crow = &mut c[ir * n + j0..ir * n + j0 + cl];
                for p in 0..pl {
                    let apv = a[ir * k + p0 + p];
                    if apv == 0.0 {
                        continue;
                    }
                    for (x, cv) in crow.iter_mut().enumerate() {
                        *cv += apv * lut[w.values.index(base + p * bc + x)];
                    }
                }
            }
        }
    }
}

/// Multithreaded LUT BSR GEMM over disjoint row panels, default cutover.
pub fn qbsr_gemm_parallel(a: &[f32], w: &QBsr, c: &mut [f32], m: usize, epilogue: &Epilogue) {
    qbsr_gemm_parallel_cutover(a, w, c, m, epilogue, PARALLEL_M_CUTOVER);
}

/// Multithreaded LUT BSR GEMM with a caller-chosen serial cutover.
pub fn qbsr_gemm_parallel_cutover(
    a: &[f32],
    w: &QBsr,
    c: &mut [f32],
    m: usize,
    epilogue: &Epilogue,
    cutover: usize,
) {
    let (k, n) = (w.rows, w.cols);
    let vals = w.col_idx.len() * w.br * w.bc;
    let threads = pool::global().size().min(m.div_ceil(64)).max(1);
    if threads <= 1 || m < cutover {
        count_dispatch(m, vals, false, 0);
        return qbsr_gemm(a, w, c, m, epilogue);
    }
    count_dispatch(m, vals, true, threads);
    let chunk = m.div_ceil(threads);
    let cptr = SendPtr(c.as_mut_ptr());
    pool::parallel_for_n(threads, threads, |t| {
        let m0 = t * chunk;
        let m1 = ((t + 1) * chunk).min(m);
        if m0 >= m1 {
            return;
        }
        // SAFETY: disjoint row panels.
        let c_all = unsafe { std::slice::from_raw_parts_mut(cptr.get(), m * n) };
        qbsr_gemm_rows(a, w, c_all, m0, m1, k, n);
        epilogue.apply(&mut c_all[m0 * n..m1 * n], m1 - m0, n);
    });
}

// ---------------------------------------------------------------------------
// Pattern
// ---------------------------------------------------------------------------

/// C(M,N) = A(M,K) @ W_qpattern(K,N), single thread — mirrors
/// [`crate::kernels::pattern::pattern_gemm`].
pub fn qpattern_gemm(a: &[f32], w: &QPattern, c: &mut [f32], m: usize, epilogue: &Epilogue) {
    let (k, n) = (w.rows, w.cols);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * n);
    let offs = row_offsets(w);
    qpattern_gemm_rows(a, w, &offs, c, 0, m, k, n);
    epilogue.apply(c, m, n);
}

/// Per-pattern activation row offsets (`pos * cin`) — resolved once per
/// call, exactly as the f32 pattern kernel does.
fn row_offsets(w: &QPattern) -> Vec<usize> {
    w.pat_pos.iter().map(|&p| p as usize * w.cin).collect()
}

#[allow(clippy::too_many_arguments)]
fn qpattern_gemm_rows(
    a: &[f32],
    w: &QPattern,
    offs: &[usize],
    c: &mut [f32],
    m0: usize,
    m1: usize,
    k: usize,
    n: usize,
) {
    c[m0 * n..m1 * n].fill(0.0);
    let lut = w.values.codebook.as_slice();
    const MR: usize = 4;
    let mut i = m0;
    while i + MR <= m1 {
        for ci in 0..w.cin {
            let (s, e) = (w.kernel_ptr[ci] as usize, w.kernel_ptr[ci + 1] as usize);
            for kn in s..e {
                let co = w.col_idx[kn] as usize;
                let pid = w.pat_idx[kn] as usize;
                let ps = w.pat_ptr[pid] as usize;
                let pe = w.pat_ptr[pid + 1] as usize;
                let vb = w.val_ptr[kn] as usize;
                if pe - ps == 4 {
                    // canonical 4-entry pattern, fully unrolled; the four
                    // codebook gathers replace the contiguous f32 run
                    let o =
                        [offs[ps] + ci, offs[ps + 1] + ci, offs[ps + 2] + ci, offs[ps + 3] + ci];
                    let vals = [
                        lut[w.values.index(vb)],
                        lut[w.values.index(vb + 1)],
                        lut[w.values.index(vb + 2)],
                        lut[w.values.index(vb + 3)],
                    ];
                    for r in 0..MR {
                        let base = (i + r) * k;
                        let acc = a[base + o[0]] * vals[0]
                            + a[base + o[1]] * vals[1]
                            + a[base + o[2]] * vals[2]
                            + a[base + o[3]] * vals[3];
                        c[(i + r) * n + co] += acc;
                    }
                } else {
                    let ve = w.val_ptr[kn + 1] as usize;
                    for r in 0..MR {
                        let base = (i + r) * k;
                        let mut acc = 0.0f32;
                        for (x, vi) in (vb..ve).enumerate() {
                            acc += a[base + offs[ps + x] + ci] * lut[w.values.index(vi)];
                        }
                        c[(i + r) * n + co] += acc;
                    }
                }
            }
        }
        i += MR;
    }
    // remainder rows (< MR), one at a time
    for ir in i..m1 {
        let base = ir * k;
        for ci in 0..w.cin {
            let (s, e) = (w.kernel_ptr[ci] as usize, w.kernel_ptr[ci + 1] as usize);
            for kn in s..e {
                let co = w.col_idx[kn] as usize;
                let pid = w.pat_idx[kn] as usize;
                let ps = w.pat_ptr[pid] as usize;
                let (vb, ve) = (w.val_ptr[kn] as usize, w.val_ptr[kn + 1] as usize);
                let mut acc = 0.0f32;
                for (x, vi) in (vb..ve).enumerate() {
                    acc += a[base + offs[ps + x] + ci] * lut[w.values.index(vi)];
                }
                c[ir * n + co] += acc;
            }
        }
    }
}

/// Multithreaded LUT pattern GEMM over disjoint row panels, default
/// cutover.
pub fn qpattern_gemm_parallel(
    a: &[f32],
    w: &QPattern,
    c: &mut [f32],
    m: usize,
    epilogue: &Epilogue,
) {
    qpattern_gemm_parallel_cutover(a, w, c, m, epilogue, PARALLEL_M_CUTOVER);
}

/// Multithreaded LUT pattern GEMM with a caller-chosen serial cutover.
pub fn qpattern_gemm_parallel_cutover(
    a: &[f32],
    w: &QPattern,
    c: &mut [f32],
    m: usize,
    epilogue: &Epilogue,
    cutover: usize,
) {
    let (k, n) = (w.rows, w.cols);
    let threads = pool::global().size().min(m.div_ceil(64)).max(1);
    if threads <= 1 || m < cutover {
        count_dispatch(m, w.nnz(), false, 0);
        return qpattern_gemm(a, w, c, m, epilogue);
    }
    count_dispatch(m, w.nnz(), true, threads);
    let offs = row_offsets(w);
    let chunk = m.div_ceil(threads);
    let cptr = SendPtr(c.as_mut_ptr());
    pool::parallel_for_n(threads, threads, |t| {
        let m0 = t * chunk;
        let m1 = ((t + 1) * chunk).min(m);
        if m0 >= m1 {
            return;
        }
        // SAFETY: disjoint row panels.
        let c_all = unsafe { std::slice::from_raw_parts_mut(cptr.get(), m * n) };
        qpattern_gemm_rows(a, w, &offs, c_all, m0, m1, k, n);
        epilogue.apply(&mut c_all[m0 * n..m1 * n], m1 - m0, n);
    });
}

// ---------------------------------------------------------------------------
// Dispatch
// ---------------------------------------------------------------------------

/// Run the matching LUT kernel for a quantized payload (the executor's
/// one entry point for `NodeWeights::QuantSparse`). Emits a `kernel`
/// span (family `lut`) when the recorder is on, inheriting the calling
/// thread's trace context.
pub fn qsparse_gemm_parallel_cutover(
    a: &[f32],
    w: &QSparseMatrix,
    c: &mut [f32],
    m: usize,
    epilogue: &Epilogue,
    cutover: usize,
) {
    let t0 = obs::timer();
    match w {
        QSparseMatrix::Csr(q) => qcsr_gemm_parallel_cutover(a, q, c, m, epilogue, cutover),
        QSparseMatrix::Bsr(q) => qbsr_gemm_parallel_cutover(a, q, c, m, epilogue, cutover),
        QSparseMatrix::Pattern(q) => qpattern_gemm_parallel_cutover(a, q, c, m, epilogue, cutover),
    }
    if let Some(t0) = t0 {
        obs::span_since(
            obs::CAT_KERNEL,
            "lut".to_string(),
            t0,
            vec![("m", obs::ArgValue::Num(m as f64))],
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::bsr::BsrMatrix;
    use crate::compress::csr::CsrMatrix;
    use crate::compress::pattern::{prune_patterns, PatternMatrix};
    use crate::kernels::{bsr::bsr_gemm, pattern::pattern_gemm, sparse::csr_gemm};
    use crate::prop_assert;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_sparse(rng: &mut Rng, len: usize, density: f64) -> Vec<f32> {
        let mut dense = vec![0.0f32; len];
        for v in dense.iter_mut() {
            if rng.f64() < density {
                *v = rng.normal() as f32;
            }
        }
        dense
    }

    /// The tentpole equivalence: every LUT kernel is bit-identical to
    /// its f32 kernel on the dequantized matrix — the quantization error
    /// lives entirely in the fit, never in the execution.
    #[test]
    fn prop_lut_kernels_bit_identical_to_dequantized_f32() {
        prop::check_n("lut vs dequantized f32", 48, |rng: &mut Rng| {
            let kh = [2usize, 3][rng.below(2)];
            let kw = [2usize, 3][rng.below(2)];
            let cin = rng.range(1, 7);
            let n = rng.range(1, 16);
            let k = kh * kw * cin;
            let m = rng.range(1, 18);
            let bits = [4u8, 8][rng.below(2)];
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let dense = random_sparse(rng, k * n, rng.f64());
            let epi = Epilogue::bias_relu((0..n).map(|_| rng.f32() - 0.5).collect(), true);

            let csr = CsrMatrix::from_dense(&dense, k, n);
            let qcsr = crate::compress::qsparse::QCsr::from_csr(&csr, bits);
            let mut c_ref = vec![0.0; m * n];
            let mut c = vec![0.0; m * n];
            csr_gemm(&a, &qcsr.to_csr(), &mut c_ref, m, &epi);
            qcsr_gemm(&a, &qcsr, &mut c, m, &epi);
            prop_assert!(c == c_ref, "qcsr not bit-identical");

            let (br, bc) = [(4usize, 1usize), (4, 4), (3, 2)][rng.below(3)];
            let bsr = BsrMatrix::from_dense(&dense, k, n, br, bc);
            let qbsr = crate::compress::qsparse::QBsr::from_bsr(&bsr, bits);
            let mut b_ref = vec![0.0; m * n];
            let mut b = vec![0.0; m * n];
            bsr_gemm(&a, &qbsr.to_bsr(), &mut b_ref, m, &epi);
            qbsr_gemm(&a, &qbsr, &mut b, m, &epi);
            prop_assert!(b == b_ref, "qbsr {br}x{bc} not bit-identical");

            let pat = PatternMatrix::from_dense(&dense, kh, kw, cin, n);
            let qpat = crate::compress::qsparse::QPattern::from_pattern(&pat, bits);
            let mut p_ref = vec![0.0; m * n];
            let mut p = vec![0.0; m * n];
            pattern_gemm(&a, &qpat.to_pattern(), &mut p_ref, m, &epi);
            qpattern_gemm(&a, &qpat, &mut p, m, &epi);
            prop_assert!(p == p_ref, "qpattern not bit-identical");
            Ok(())
        });
    }

    /// LUT output vs the *unquantized* f32 kernel stays within the
    /// fit's error bound propagated through the reduction: each output
    /// element sums at most (column nnz) perturbed products.
    #[test]
    fn lut_error_bounded_by_fit() {
        let (kh, kw, cin, n) = (3usize, 3usize, 8usize, 16usize);
        let k = kh * kw * cin;
        let m = 9;
        let mut rng = Rng::new(23);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let mut dense = vec![0.0f32; k * n];
        rng.fill_normal(&mut dense, 0.5);
        prune_patterns(&mut dense, kh, kw, cin, n, 0.8, 4, 8);
        let pat = PatternMatrix::from_dense(&dense, kh, kw, cin, n);
        let qpat = crate::compress::qsparse::QPattern::from_pattern(&pat, 4);
        let eb = qpat.values.error_bound() as f64;
        assert!(eb > 0.0, "rich normal values must not fit a 4-bit codebook losslessly");

        let mut c_f32 = vec![0.0; m * n];
        let mut c_q = vec![0.0; m * n];
        pattern_gemm(&a, &pat, &mut c_f32, m, &Epilogue::None);
        qpattern_gemm(&a, &qpat, &mut c_q, m, &Epilogue::None);
        let amax = a.iter().fold(0.0f32, |mx, v| mx.max(v.abs())) as f64;
        let bound = eb * amax * k as f64 + 1e-4;
        for (x, y) in c_f32.iter().zip(&c_q) {
            let d = (*x as f64 - *y as f64).abs();
            assert!(d <= bound, "diff {d} exceeds propagated bound {bound}");
        }
    }

    #[test]
    fn parallel_and_cutover_match_serial() {
        let (kh, kw, cin, n) = (3usize, 3usize, 4usize, 8usize);
        let k = kh * kw * cin;
        let m = 300;
        let mut rng = Rng::new(31);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let dense = random_sparse(&mut rng, k * n, 0.3);
        let csr = CsrMatrix::from_dense(&dense, k, n);
        let qcsr = crate::compress::qsparse::QCsr::from_csr(&csr, 8);
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        let mut c3 = vec![0.0; m * n];
        qcsr_gemm(&a, &qcsr, &mut c1, m, &Epilogue::None);
        qcsr_gemm_parallel_cutover(&a, &qcsr, &mut c2, m, &Epilogue::None, PARALLEL_M_CUTOVER);
        qcsr_gemm_parallel_cutover(&a, &qcsr, &mut c3, m, &Epilogue::None, m + 1);
        assert_eq!(c1, c2, "row panels must not change the result");
        assert_eq!(c1, c3, "serial-cutover path must be the serial kernel");

        let pat = PatternMatrix::from_dense(&dense, kh, kw, cin, n);
        let qpat = crate::compress::qsparse::QPattern::from_pattern(&pat, 4);
        let mut p1 = vec![0.0; m * n];
        let mut p2 = vec![0.0; m * n];
        qpattern_gemm(&a, &qpat, &mut p1, m, &Epilogue::None);
        qpattern_gemm_parallel_cutover(&a, &qpat, &mut p2, m, &Epilogue::None, PARALLEL_M_CUTOVER);
        assert_eq!(p1, p2);

        let bsr = BsrMatrix::from_dense(&dense, k, n, 4, 4);
        let qbsr = crate::compress::qsparse::QBsr::from_bsr(&bsr, 8);
        let mut b1 = vec![0.0; m * n];
        let mut b2 = vec![0.0; m * n];
        qbsr_gemm(&a, &qbsr, &mut b1, m, &Epilogue::None);
        qbsr_gemm_parallel_cutover(&a, &qbsr, &mut b2, m, &Epilogue::None, PARALLEL_M_CUTOVER);
        assert_eq!(b1, b2);
    }

    #[test]
    fn empty_weights_give_zero_plus_epilogue() {
        let (m, k, n) = (6, 18, 4);
        let a = vec![1.0; m * k];
        let csr = CsrMatrix::from_dense(&vec![0.0; k * n], k, n);
        let qcsr = crate::compress::qsparse::QCsr::from_csr(&csr, 4);
        let mut c = vec![9.0; m * n];
        let ep = Epilogue::bias_relu(vec![0.5; n], false);
        qcsr_gemm(&a, &qcsr, &mut c, m, &ep);
        assert!(c.iter().all(|&v| v == 0.5));
    }

    #[test]
    fn dispatch_routes_by_payload() {
        let (kh, kw, cin, n) = (3usize, 3usize, 2usize, 6usize);
        let k = kh * kw * cin;
        let m = 5;
        let mut rng = Rng::new(3);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let dense = random_sparse(&mut rng, k * n, 0.4);
        let csr = CsrMatrix::from_dense(&dense, k, n);
        let qcsr = crate::compress::qsparse::QCsr::from_csr(&csr, 8);
        // all three payloads fit on the same nonzero multiset (BSR's
        // padding zeros pack to the reserved entry), so one dequantized
        // CSR reference serves every variant
        let mut c_ref = vec![0.0; m * n];
        csr_gemm(&a, &qcsr.to_csr(), &mut c_ref, m, &Epilogue::None);
        let variants = [
            QSparseMatrix::Csr(qcsr),
            QSparseMatrix::Bsr(crate::compress::qsparse::QBsr::from_bsr(
                &BsrMatrix::from_dense(&dense, k, n, 4, 4),
                8,
            )),
            QSparseMatrix::Pattern(crate::compress::qsparse::QPattern::from_pattern(
                &PatternMatrix::from_dense(&dense, kh, kw, cin, n),
                8,
            )),
        ];
        for q in &variants {
            let mut c = vec![0.0; m * n];
            qsparse_gemm_parallel_cutover(&a, q, &mut c, m, &Epilogue::None, usize::MAX);
            for (x, y) in c_ref.iter().zip(&c) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        }
    }
}
