//! Native CPU kernels — the executable analogue of the paper's
//! compiler-generated mobile kernels. The framework personalities in
//! `exec/` compose these differently (direct vs im2col-GEMM conv, fused
//! vs separate epilogues, dense vs CSR) and the tuner picks tile
//! configurations; measured efficiency feeds the Figure-2 projection.

pub mod bsr;
pub mod conv;
pub mod gemm;
pub mod lut;
pub mod pattern;
pub mod sparse;
pub mod tensor;

pub use tensor::Tensor;

/// Row count below which the panel-parallel kernels (dense, CSR, BSR)
/// run their serial variant instead of fanning out to the thread pool.
///
/// Rationale: a row panel needs ~64+ rows per thread before the pool's
/// wake/join overhead amortizes, and M below this threshold usually means
/// a latency-sensitive small batch where cache-warm serial execution
/// wins. The planner can override it per layer ([`crate::planner`]
/// carries a `parallel_cutover` in each `LayerPlan`, refined by the
/// tuner's micro-benchmark loop when enabled); the `*_parallel` entry
/// points without a cutover argument use this default.
pub const PARALLEL_M_CUTOVER: usize = 128;

/// Pointer wrapper letting disjoint row panels of one output buffer be
/// written from the thread pool (shared by the dense/CSR/BSR parallel
/// kernels). SAFETY contract for users: each worker may write only
/// through ranges that no other worker touches.
pub(crate) struct SendPtr(pub(crate) *mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}
impl SendPtr {
    /// Method (not field) access so closures capture the whole wrapper,
    /// keeping the Sync impl in play under disjoint-capture rules.
    pub(crate) fn get(&self) -> *mut f32 {
        self.0
    }
}

/// Fused epilogue applied to a GEMM/conv output tile while it is hot:
/// out = act(out * scale[n] + shift[n]) — folded BatchNorm or bias.
#[derive(Debug, Clone, Default)]
pub enum Epilogue {
    #[default]
    None,
    /// Per-output-channel affine + optional ReLU/ReLU6 clamp.
    Affine { scale: Vec<f32>, shift: Vec<f32>, relu_max: Option<f32>, relu: bool },
}

impl Epilogue {
    pub fn bias_relu(bias: Vec<f32>, relu: bool) -> Self {
        let n = bias.len();
        Epilogue::Affine { scale: vec![1.0; n], shift: bias, relu_max: None, relu }
    }

    pub fn bn_act(scale: Vec<f32>, shift: Vec<f32>, relu: bool, relu6: bool) -> Self {
        Epilogue::Affine {
            scale,
            shift,
            relu_max: if relu6 { Some(6.0) } else { None },
            relu,
        }
    }

    /// Reorder the per-channel parameters to match a column permutation
    /// of the weight matrix (`perm[new] = old`), so a kernel running on
    /// column-reordered weights applies each channel's own affine (see
    /// [`crate::compress::reorder`]).
    pub fn permute_channels(&self, perm: &[u32]) -> Epilogue {
        match self {
            Epilogue::None => Epilogue::None,
            Epilogue::Affine { scale, shift, relu_max, relu } => Epilogue::Affine {
                scale: perm.iter().map(|&o| scale[o as usize]).collect(),
                shift: perm.iter().map(|&o| shift[o as usize]).collect(),
                relu_max: *relu_max,
                relu: *relu,
            },
        }
    }

    /// Apply to a row-major (rows x n) block in place.
    pub fn apply(&self, out: &mut [f32], rows: usize, n: usize) {
        match self {
            Epilogue::None => {}
            Epilogue::Affine { scale, shift, relu_max, relu } => {
                debug_assert!(scale.len() >= n && shift.len() >= n);
                for r in 0..rows {
                    let row = &mut out[r * n..r * n + n];
                    for (j, v) in row.iter_mut().enumerate() {
                        let mut x = *v * scale[j] + shift[j];
                        if *relu {
                            x = x.max(0.0);
                            if let Some(m) = relu_max {
                                x = x.min(*m);
                            }
                        }
                        *v = x;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epilogue_none_is_identity() {
        let mut v = vec![1.0, -2.0, 3.0];
        Epilogue::None.apply(&mut v, 1, 3);
        assert_eq!(v, vec![1.0, -2.0, 3.0]);
    }

    #[test]
    fn epilogue_bias_relu() {
        let mut v = vec![1.0, -2.0, 3.0, -4.0];
        let e = Epilogue::bias_relu(vec![0.5, 0.5], true);
        e.apply(&mut v, 2, 2);
        assert_eq!(v, vec![1.5, 0.0, 3.5, 0.0]);
    }

    #[test]
    fn epilogue_relu6_clamps() {
        let mut v = vec![10.0, 2.0];
        let e = Epilogue::bn_act(vec![1.0, 1.0], vec![0.0, 0.0], true, true);
        e.apply(&mut v, 1, 2);
        assert_eq!(v, vec![6.0, 2.0]);
    }
}
