//! Native CPU kernels — the executable analogue of the paper's
//! compiler-generated mobile kernels. The framework personalities in
//! `exec/` compose these differently (direct vs im2col-GEMM conv, fused
//! vs separate epilogues, dense vs CSR) and the tuner picks tile
//! configurations; measured efficiency feeds the Figure-2 projection.

pub mod conv;
pub mod gemm;
pub mod sparse;
pub mod tensor;

pub use tensor::Tensor;

/// Fused epilogue applied to a GEMM/conv output tile while it is hot:
/// out = act(out * scale[n] + shift[n]) — folded BatchNorm or bias.
#[derive(Debug, Clone, Default)]
pub enum Epilogue {
    #[default]
    None,
    /// Per-output-channel affine + optional ReLU/ReLU6 clamp.
    Affine { scale: Vec<f32>, shift: Vec<f32>, relu_max: Option<f32>, relu: bool },
}

impl Epilogue {
    pub fn bias_relu(bias: Vec<f32>, relu: bool) -> Self {
        let n = bias.len();
        Epilogue::Affine { scale: vec![1.0; n], shift: bias, relu_max: None, relu }
    }

    pub fn bn_act(scale: Vec<f32>, shift: Vec<f32>, relu: bool, relu6: bool) -> Self {
        Epilogue::Affine {
            scale,
            shift,
            relu_max: if relu6 { Some(6.0) } else { None },
            relu,
        }
    }

    /// Apply to a row-major (rows x n) block in place.
    pub fn apply(&self, out: &mut [f32], rows: usize, n: usize) {
        match self {
            Epilogue::None => {}
            Epilogue::Affine { scale, shift, relu_max, relu } => {
                debug_assert!(scale.len() >= n && shift.len() >= n);
                for r in 0..rows {
                    let row = &mut out[r * n..r * n + n];
                    for (j, v) in row.iter_mut().enumerate() {
                        let mut x = *v * scale[j] + shift[j];
                        if *relu {
                            x = x.max(0.0);
                            if let Some(m) = relu_max {
                                x = x.min(*m);
                            }
                        }
                        *v = x;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epilogue_none_is_identity() {
        let mut v = vec![1.0, -2.0, 3.0];
        Epilogue::None.apply(&mut v, 1, 3);
        assert_eq!(v, vec![1.0, -2.0, 3.0]);
    }

    #[test]
    fn epilogue_bias_relu() {
        let mut v = vec![1.0, -2.0, 3.0, -4.0];
        let e = Epilogue::bias_relu(vec![0.5, 0.5], true);
        e.apply(&mut v, 2, 2);
        assert_eq!(v, vec![1.5, 0.0, 3.5, 0.0]);
    }

    #[test]
    fn epilogue_relu6_clamps() {
        let mut v = vec![10.0, 2.0];
        let e = Epilogue::bn_act(vec![1.0, 1.0], vec![0.0, 0.0], true, true);
        e.apply(&mut v, 1, 2);
        assert_eq!(v, vec![6.0, 2.0]);
    }
}
