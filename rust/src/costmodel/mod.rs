//! Device cost model: projects a scheduled graph onto a target device
//! (DESIGN.md §6).
//!
//! latency(node) = max(flops / (peak_flops * eff_c),
//!                     bytes / (bandwidth * eff_m)) + dispatch overhead
//!
//! The efficiency factors eff_c are NOT hand-picked constants: they are
//! *measured* on the host by running the real Rust kernels on the layer's
//! GEMM shape and dividing achieved GFLOPS by the host's measured peak
//! (`calibrate`), then transported to the target device. This is the
//! substitution that replaces the paper's Snapdragon 835 testbed: the
//! *relative* speedups (fusion, 1x1->GEMM, tuning, sparsity) come from
//! real measured kernels; only the absolute scale comes from the device
//! descriptor.

pub mod calibrate;
pub mod devices;

pub use calibrate::{CalibrationTable, KernelClass};
pub use devices::DeviceSpec;

use crate::compress::profile::SparsityProfile;
use crate::ir::ops::Op;
use crate::ir::Graph;
use crate::passes::layout::LayoutPlan;

/// How a node is scheduled (what the personalities vary).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeSchedule {
    pub class: KernelClass,
    /// Fraction of weights pruned (0.0 for dense execution).
    pub sparsity: f64,
}

/// Per-node cost breakdown.
#[derive(Debug, Clone)]
pub struct NodeCost {
    pub name: String,
    pub flops: u64,
    pub bytes: u64,
    pub us: f64,
    pub compute_bound: bool,
}

/// Estimate one node's latency in microseconds.
pub fn node_cost(
    graph: &Graph,
    node_id: usize,
    sched: &NodeSchedule,
    device: &DeviceSpec,
    calib: &CalibrationTable,
) -> NodeCost {
    let n = graph.node(node_id);
    let ins: Vec<&crate::ir::Shape> =
        n.inputs.iter().map(|&i| &graph.nodes[i].shape).collect();
    let mut flops = n.op.flops(&ins, &n.shape);
    // sparse execution skips pruned MACs
    if sched.sparsity > 0.0 {
        flops = (flops as f64 * (1.0 - sched.sparsity)) as u64;
    }
    // memory traffic: activations in + weights (sparse: nnz * 1.5 for
    // values+idx16) + activations out
    let act_in: u64 = ins.iter().map(|s| s.bytes_f32() as u64).sum();
    let wdense = n.op.weight_count() as u64 * 4;
    let weights = if sched.sparsity > 0.0 {
        ((wdense as f64) * (1.0 - sched.sparsity) * 1.5) as u64
    } else {
        wdense
    };
    let bytes = act_in + weights + n.shape.bytes_f32() as u64;

    let eff = calib.efficiency(sched.class, sched.sparsity);
    let t_compute = flops as f64 / (device.peak_gflops * 1e3 * eff.compute);
    let t_memory = bytes as f64 / (device.mem_bw_gbps * 1e3 * eff.memory);
    let us = t_compute.max(t_memory) + device.dispatch_overhead_us;
    NodeCost {
        name: n.name.clone(),
        flops,
        bytes,
        us,
        compute_bound: t_compute >= t_memory,
    }
}

/// Derive the schedule class a personality uses for each node kind.
pub fn schedule_for(op: &Op, direct_conv: bool, sparsity: f64) -> Option<NodeSchedule> {
    let class = match op {
        Op::Conv2d { .. } | Op::FusedConvBnAct { .. } => {
            if direct_conv {
                KernelClass::DirectConv
            } else {
                KernelClass::GemmConv
            }
        }
        Op::Gemm { .. } | Op::FullyConnected { .. } => {
            if sparsity > 0.0 {
                KernelClass::CsrGemm
            } else {
                KernelClass::Gemm
            }
        }
        Op::DepthwiseConv2d { .. } | Op::FusedDwBnAct { .. } => KernelClass::Depthwise,
        Op::Pool { .. } | Op::GlobalAvgPool => KernelClass::Pool,
        Op::BatchNorm { .. } | Op::Activation { .. } | Op::Add | Op::Softmax | Op::Concat => {
            KernelClass::Elementwise
        }
        Op::Input { .. } | Op::Flatten => return None,
    };
    // conv with sparsity executes as CSR conv
    let class = if sparsity > 0.0 && class == KernelClass::GemmConv {
        KernelClass::CsrGemm
    } else {
        class
    };
    Some(NodeSchedule { class, sparsity })
}

/// Whole-graph latency under a personality schedule.
pub fn graph_cost(
    graph: &Graph,
    device: &DeviceSpec,
    calib: &CalibrationTable,
    direct_conv: bool,
    profile: Option<&SparsityProfile>,
    _plan: Option<&LayoutPlan>,
) -> (f64, Vec<NodeCost>) {
    let mut total = 0.0;
    let mut costs = Vec::new();
    for n in &graph.nodes {
        let sparsity = profile
            .map(|p| if n.op.prunable() { p.get(&n.name) } else { 0.0 })
            .unwrap_or(0.0);
        if let Some(sched) = schedule_for(&n.op, direct_conv, sparsity) {
            let c = node_cost(graph, n.id, &sched, device, calib);
            total += c.us;
            costs.push(c);
        }
    }
    (total, costs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn resnet50_latency_orders_of_magnitude() {
        let g = models::build("resnet50", 1).unwrap();
        let dev = devices::snapdragon835_cpu();
        let calib = CalibrationTable::nominal();
        let (us, costs) = graph_cost(&g, &dev, &calib, false, None, None);
        // tens to hundreds of ms on a phone CPU
        assert!(us > 10_000.0 && us < 2_000_000.0, "{us}");
        assert!(!costs.is_empty());
    }

    #[test]
    fn sparse_faster_than_dense() {
        let g = models::build("resnet50", 1).unwrap();
        let dev = devices::snapdragon835_cpu();
        let calib = CalibrationTable::nominal();
        let p = crate::compress::profile::paper_profile(&g);
        let (dense_us, _) = graph_cost(&g, &dev, &calib, false, None, None);
        let (sparse_us, _) = graph_cost(&g, &dev, &calib, false, Some(&p), None);
        assert!(sparse_us < dense_us, "{sparse_us} vs {dense_us}");
    }

    #[test]
    fn direct_conv_slower_than_gemm_conv() {
        let g = models::build("mobilenet_v1", 1).unwrap();
        let dev = devices::snapdragon835_cpu();
        let calib = CalibrationTable::nominal();
        let (direct_us, _) = graph_cost(&g, &dev, &calib, true, None, None);
        let (gemm_us, _) = graph_cost(&g, &dev, &calib, false, None, None);
        assert!(direct_us > gemm_us);
    }

    #[test]
    fn gpu_faster_than_cpu_on_big_models() {
        let g = models::build("inception_v3", 1).unwrap();
        let calib = CalibrationTable::nominal();
        let (cpu_us, _) =
            graph_cost(&g, &devices::snapdragon835_cpu(), &calib, false, None, None);
        let (gpu_us, _) =
            graph_cost(&g, &devices::adreno540_gpu(), &calib, false, None, None);
        assert!(gpu_us < cpu_us);
    }
}
