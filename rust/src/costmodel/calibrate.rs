//! Host calibration: measure the real Rust kernels to obtain per-class
//! efficiency ratios (achieved / peak) that the device projection reuses.
//!
//! `nominal()` provides deterministic defaults (used by unit tests and
//! when a bench wants reproducible numbers); `measure_host()` runs the
//! actual microbenchmarks and returns a table with measured ratios plus
//! the host peak. EXPERIMENTS.md records both.

use crate::kernels::gemm::{gemm_blocked, gemm_naive, gemm_parallel};
use crate::kernels::sparse::csr_gemm;
use crate::kernels::Epilogue;
use crate::compress::csr::CsrMatrix;
use crate::passes::layout::TileConfig;
use crate::util::rng::Rng;
use crate::util::stats;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelClass {
    /// Naive 7-loop convolution (TFLite-like engine).
    DirectConv,
    /// im2col + blocked GEMM convolution.
    GemmConv,
    /// Plain blocked GEMM (1x1 conv / FC).
    Gemm,
    /// CSR sparse GEMM (compressed layers).
    CsrGemm,
    Depthwise,
    Pool,
    Elementwise,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Efficiency {
    /// Fraction of device peak FLOPS this kernel class achieves.
    pub compute: f64,
    /// Fraction of device peak bandwidth for its memory streams.
    pub memory: f64,
}

#[derive(Debug, Clone)]
pub struct CalibrationTable {
    pub host_peak_gflops: f64,
    pub host_bw_gbps: f64,
    pub direct_conv: Efficiency,
    pub gemm_conv: Efficiency,
    pub gemm: Efficiency,
    pub csr_gemm: Efficiency,
    pub depthwise: Efficiency,
    pub pool: Efficiency,
    pub elementwise: Efficiency,
    /// True when ratios came from live measurement.
    pub measured: bool,
}

impl CalibrationTable {
    /// Deterministic defaults, shaped like typical measured ratios:
    /// blocked GEMM reaches ~half of a hand-measured peak, the naive
    /// direct loop ~an eighth of that, CSR about a third of dense GEMM
    /// per non-zero. Tests and reproducible benches use this.
    pub fn nominal() -> Self {
        CalibrationTable {
            host_peak_gflops: 0.0,
            host_bw_gbps: 0.0,
            // real TFLite ships optimized (if unfused, untransformed)
            // kernels — ~1/3 of a tuned GEMM, not our naive loop's 0.06.
            direct_conv: Efficiency { compute: 0.18, memory: 0.5 },
            gemm_conv: Efficiency { compute: 0.45, memory: 0.7 },
            gemm: Efficiency { compute: 0.50, memory: 0.7 },
            csr_gemm: Efficiency { compute: 0.18, memory: 0.65 },
            depthwise: Efficiency { compute: 0.12, memory: 0.6 },
            pool: Efficiency { compute: 0.05, memory: 0.6 },
            elementwise: Efficiency { compute: 0.04, memory: 0.8 },
            measured: false,
        }
    }

    pub fn efficiency(&self, class: KernelClass, sparsity: f64) -> Efficiency {
        let mut e = match class {
            KernelClass::DirectConv => self.direct_conv,
            KernelClass::GemmConv => self.gemm_conv,
            KernelClass::Gemm => self.gemm,
            KernelClass::CsrGemm => self.csr_gemm,
            KernelClass::Depthwise => self.depthwise,
            KernelClass::Pool => self.pool,
            KernelClass::Elementwise => self.elementwise,
        };
        // very high sparsity degrades per-nnz efficiency (irregular
        // gathers dominate) — measured shape on the host csr kernel.
        if class == KernelClass::CsrGemm && sparsity > 0.95 {
            e.compute *= 0.6;
        }
        e
    }

    /// Apply a tuned-tile uplift (CADNN vs TVM-like default tiles): the
    /// measured ratio between tuned and DEFAULT blocked GEMM on the host.
    pub fn with_tuning_uplift(mut self, uplift: f64) -> Self {
        self.gemm_conv.compute *= uplift;
        self.gemm.compute *= uplift;
        self.csr_gemm.compute *= uplift; // CADNN tunes sparse kernels too
        self
    }
}

fn gflops_of(flops: u64, us: f64) -> f64 {
    flops as f64 / us / 1e3
}

/// Measure host kernels and build a live table.
pub fn measure_host() -> CalibrationTable {
    let mut rng = Rng::new(42);
    // representative conv-as-GEMM shape (ResNet-50 3x3 stage-2-ish)
    let (m, k, n) = (784usize, 576usize, 128usize);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
    let mut c = vec![0.0f32; m * n];
    let flops = 2 * (m * k * n) as u64;

    // peak proxy: parallel blocked GEMM on a big square
    let samples = stats::measure_adaptive_us(200_000.0, 12, || {
        gemm_parallel(&a, &b, &mut c, m, k, n, &TileConfig::DEFAULT, &Epilogue::None);
    });
    let peak = gflops_of(flops, stats::Summary::from(&samples).unwrap().p50);

    // naive single-thread (direct-conv proxy: same loop structure)
    let (ms, ks, ns) = (196usize, 576usize, 64usize);
    let a2 = &a[..ms * ks];
    let b2 = &b[..ks * ns];
    let mut c2 = vec![0.0f32; ms * ns];
    let fl2 = 2 * (ms * ks * ns) as u64;
    let naive_s = stats::measure_adaptive_us(100_000.0, 8, || {
        gemm_naive(a2, b2, &mut c2, ms, ks, ns);
    });
    let naive = gflops_of(fl2, stats::Summary::from(&naive_s).unwrap().p50);

    // blocked single-thread
    let blocked_s = stats::measure_adaptive_us(100_000.0, 8, || {
        gemm_blocked(a2, b2, &mut c2, ms, ks, ns, &TileConfig::DEFAULT, &Epilogue::None);
    });
    let blocked = gflops_of(fl2, stats::Summary::from(&blocked_s).unwrap().p50);

    // csr at 90% sparsity: per-nnz achieved
    let mut dense = vec![0.0f32; ks * ns];
    for v in dense.iter_mut() {
        if rng.f64() < 0.1 {
            *v = rng.normal() as f32;
        }
    }
    let csr = CsrMatrix::from_dense(&dense, ks, ns);
    let nnz_flops = 2 * (ms * csr.nnz()) as u64;
    let csr_s = stats::measure_adaptive_us(100_000.0, 8, || {
        csr_gemm(a2, &csr, &mut c2, ms, &Epilogue::None);
    });
    let csr_g = gflops_of(nnz_flops, stats::Summary::from(&csr_s).unwrap().p50);

    // bandwidth proxy: big memcpy-like sweep
    let big = vec![1.0f32; 8 << 20];
    let mut dst = vec![0.0f32; 8 << 20];
    let bw_s = stats::measure_adaptive_us(100_000.0, 8, || {
        dst.copy_from_slice(&big);
    });
    let bw = (big.len() * 8) as f64 / stats::Summary::from(&bw_s).unwrap().p50 / 1e3;

    let nominal = CalibrationTable::nominal();
    CalibrationTable {
        host_peak_gflops: peak,
        host_bw_gbps: bw,
        // measured naive/peak is the floor; real TFLite kernels sit ~3x
        // above a textbook loop (documented in EXPERIMENTS.md §Figure2).
        direct_conv: Efficiency { compute: (naive / peak * 3.0).min(0.3), memory: 0.5 },
        gemm_conv: Efficiency { compute: (blocked / peak).min(1.0), memory: 0.7 },
        gemm: Efficiency { compute: (blocked / peak).min(1.0), memory: 0.7 },
        csr_gemm: Efficiency { compute: (csr_g / peak).min(1.0), memory: 0.65 },
        depthwise: nominal.depthwise,
        pool: nominal.pool,
        elementwise: nominal.elementwise,
        measured: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nominal_ordering_sane() {
        let t = CalibrationTable::nominal();
        assert!(t.gemm.compute > t.direct_conv.compute * 2.0);
        assert!(t.gemm.compute > t.csr_gemm.compute);
        assert!(!t.measured);
    }

    #[test]
    fn high_sparsity_penalty() {
        let t = CalibrationTable::nominal();
        let lo = t.efficiency(KernelClass::CsrGemm, 0.5);
        let hi = t.efficiency(KernelClass::CsrGemm, 0.99);
        assert!(hi.compute < lo.compute);
    }

    #[test]
    fn tuning_uplift_applies() {
        let t = CalibrationTable::nominal().with_tuning_uplift(1.3);
        assert!((t.gemm.compute - 0.65).abs() < 1e-9);
    }
}
