//! Device descriptors (Table 1 substitution — DESIGN.md §2).
//!
//! Published peaks for the Xiaomi 6's SoC; the host descriptor is
//! measured at calibration time.

#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSpec {
    pub name: String,
    /// Peak f32 throughput in GFLOPS (all cores / ALUs).
    pub peak_gflops: f64,
    /// Peak memory bandwidth, GB/s.
    pub mem_bw_gbps: f64,
    /// Per-kernel dispatch overhead in microseconds (GPU >> CPU).
    pub dispatch_overhead_us: f64,
    /// Last-level cache available to a core cluster, bytes (tuner budget).
    pub cache_bytes: usize,
    /// SIMD lanes (f32) — layout alignment target.
    pub simd_lanes: usize,
}

/// Snapdragon 835 CPU cluster: 4x Kryo 280 "big" @ 2.45 GHz, 2x 128-bit
/// NEON FMA pipes per core: 4 * 2.45e9 * 8 = 78.4 GFLOPS nominal peak;
/// LPDDR4X-1866 dual channel ~= 29.8 GB/s.
pub fn snapdragon835_cpu() -> DeviceSpec {
    DeviceSpec {
        name: "Snapdragon 835 CPU (4x Kryo280 2.45GHz)".into(),
        peak_gflops: 78.4,
        mem_bw_gbps: 29.8,
        dispatch_overhead_us: 2.0,
        cache_bytes: 2 * 1024 * 1024, // 2MB L2 on the big cluster
        simd_lanes: 4,                // 128-bit NEON f32
    }
}

/// Adreno 540 @ 710 MHz: 256 ALUs * 2 (FMA) * 0.71 GHz ~= 363 GFLOPS
/// nominal f32 peak; same shared LPDDR4X bandwidth; large kernel-launch
/// overhead typical of mobile GPU queues.
pub fn adreno540_gpu() -> DeviceSpec {
    DeviceSpec {
        name: "Adreno 540 GPU (710MHz)".into(),
        peak_gflops: 363.0,
        mem_bw_gbps: 29.8,
        dispatch_overhead_us: 25.0,
        cache_bytes: 1024 * 1024,
        simd_lanes: 32, // wave width
    }
}

/// Host CPU descriptor: peaks filled in by `calibrate::measure_host`.
pub fn host_cpu(peak_gflops: f64, mem_bw_gbps: f64) -> DeviceSpec {
    DeviceSpec {
        name: "host CPU (measured)".into(),
        peak_gflops,
        mem_bw_gbps,
        dispatch_overhead_us: 0.5,
        cache_bytes: 32 * 1024 * 1024,
        simd_lanes: 8,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_sane() {
        let cpu = snapdragon835_cpu();
        let gpu = adreno540_gpu();
        assert!(gpu.peak_gflops > cpu.peak_gflops);
        assert_eq!(cpu.mem_bw_gbps, gpu.mem_bw_gbps); // shared LPDDR4X
        assert!(gpu.dispatch_overhead_us > cpu.dispatch_overhead_us);
    }

    #[test]
    fn host_spec_paramized() {
        let h = host_cpu(100.0, 20.0);
        assert_eq!(h.peak_gflops, 100.0);
        assert_eq!(h.mem_bw_gbps, 20.0);
    }
}
