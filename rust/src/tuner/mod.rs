//! Optimization-parameter selection (paper §4): per-layer tile/unroll
//! search with architecture+DNN knowledge-based pruning of the space.
//!
//! The space is {mc, nc, kc} x unroll over powers of two. Pruning rules
//! (the paper's "knowledge from both DNNs and architectures"):
//! 1. working set of one macro-tile must fit the cache budget;
//! 2. tiles are clamped to the (padded) problem dims — oversize tiles
//!    only waste the remainder loops;
//! 3. unroll must divide nc and not exceed the SIMD-friendly width;
//! 4. kc is kept >= 32 where possible so the micro-kernel amortizes its
//!    loop overhead (reduction-major reuse).
//!
//! Search = pruned grid, measured with the *real* blocked GEMM on the
//! layer's shape, then a greedy neighborhood descent around the grid
//! winner. Results are cached per (m, k, n, cache) key.

use crate::kernels::gemm::gemm_blocked;
use crate::kernels::Epilogue;
use crate::passes::layout::TileConfig;
use crate::util::rng::Rng;
use crate::util::stats;
use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct TuneResult {
    pub best: TileConfig,
    pub best_us: f64,
    pub default_us: f64,
    pub evaluated: usize,
    pub pruned: usize,
}

impl TuneResult {
    pub fn speedup_vs_default(&self) -> f64 {
        self.default_us / self.best_us.max(1e-9)
    }
}

/// Enumerate the pruned candidate set for a problem shape.
pub fn candidates(m: usize, k: usize, n: usize, cache_bytes: usize) -> (Vec<TileConfig>, usize) {
    let pow2 = [16usize, 32, 64, 128, 256];
    let unrolls = [2usize, 4, 8];
    let mut out = Vec::new();
    let mut pruned = 0usize;
    for &mc in &pow2 {
        for &nc in &pow2 {
            for &kc in &pow2 {
                for &u in &unrolls {
                    let t = TileConfig { mc, nc, kc, unroll: u };
                    // rule 3: unroll divides nc
                    if nc % u != 0 {
                        pruned += 1;
                        continue;
                    }
                    // rule 4: amortize reduction loop
                    if kc < 32 && k >= 64 {
                        pruned += 1;
                        continue;
                    }
                    if !t.legal(m, k, n, cache_bytes) {
                        pruned += 1;
                        continue;
                    }
                    out.push(t);
                }
            }
        }
    }
    (out, pruned)
}

fn measure(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, t: &TileConfig) -> f64 {
    let samples = stats::measure_adaptive_us(
        4_000.0,
        6,
        || gemm_blocked(a, b, c, m, k, n, t, &Epilogue::None),
    );
    stats::Summary::from(&samples).unwrap().p50
}

/// Tune one GEMM shape. Deterministic given the seed.
pub fn tune(m: usize, k: usize, n: usize, cache_bytes: usize, seed: u64) -> TuneResult {
    let mut rng = Rng::new(seed);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
    let mut c = vec![0.0f32; m * n];

    let default_us = measure(&a, &b, &mut c, m, k, n, &TileConfig::DEFAULT);
    let (cands, pruned) = candidates(m, k, n, cache_bytes);
    let mut best = TileConfig::DEFAULT;
    let mut best_us = default_us;
    let mut evaluated = 1;
    // randomized subsample of the pruned grid keeps tuning fast; the
    // greedy descent below recovers local structure.
    let budget = 24.min(cands.len());
    let mut order: Vec<usize> = (0..cands.len()).collect();
    rng.shuffle(&mut order);
    for &i in order.iter().take(budget) {
        let t = cands[i];
        let us = measure(&a, &b, &mut c, m, k, n, &t);
        evaluated += 1;
        if us < best_us {
            best_us = us;
            best = t;
        }
    }
    // greedy neighborhood descent: halve/double one dimension at a time
    let mut improved = true;
    while improved {
        improved = false;
        for factor in [0usize, 1, 2, 3] {
            for dir in [0usize, 1] {
                let mut t = best;
                let f = |v: usize| if dir == 0 { (v / 2).max(8) } else { (v * 2).min(512) };
                match factor {
                    0 => t.mc = f(t.mc),
                    1 => t.nc = f(t.nc),
                    2 => t.kc = f(t.kc),
                    _ => t.unroll = if dir == 0 { (t.unroll / 2).max(1) } else { (t.unroll * 2).min(16) },
                }
                if t == best || !t.legal(m, k, n, cache_bytes) || t.nc % t.unroll != 0 {
                    continue;
                }
                let us = measure(&a, &b, &mut c, m, k, n, &t);
                evaluated += 1;
                if us < best_us * 0.98 {
                    best_us = us;
                    best = t;
                    improved = true;
                }
            }
        }
    }
    TuneResult { best, best_us, default_us, evaluated, pruned }
}

/// Per-layer tuning cache keyed by GEMM shape.
#[derive(Debug, Default)]
pub struct TunerCache {
    cache: BTreeMap<(usize, usize, usize), TileConfig>,
}

impl TunerCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn get_or_tune(&mut self, m: usize, k: usize, n: usize, cache_bytes: usize) -> TileConfig {
        // shape bucketing: round m to pow2-ish buckets so similar layers share
        let key = (m.next_power_of_two(), k, n);
        if let Some(t) = self.cache.get(&key) {
            return *t;
        }
        let r = tune(m, k, n, cache_bytes, 7);
        self.cache.insert(key, r.best);
        r.best
    }

    pub fn len(&self) -> usize {
        self.cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cache.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidates_respect_pruning_rules() {
        let (cands, pruned) = candidates(512, 256, 128, 1 << 20);
        assert!(!cands.is_empty());
        assert!(pruned > 0, "pruning rules should fire");
        for t in &cands {
            assert_eq!(t.nc % t.unroll, 0);
            assert!(t.working_set_bytes() <= 1 << 20);
        }
    }

    #[test]
    fn small_problem_small_tiles() {
        let (cands, _) = candidates(8, 8, 8, 1 << 20);
        // clamped by rule 2: no tile dim may exceed padded problem dims
        for t in &cands {
            assert!(t.mc <= 16 && t.nc <= 16);
        }
    }

    #[test]
    fn tune_never_worse_than_default() {
        // tuned result is by construction <= default (default is evaluated)
        let r = tune(128, 96, 64, 1 << 20, 1);
        assert!(r.best_us <= r.default_us * 1.05, "{} vs {}", r.best_us, r.default_us);
        assert!(r.evaluated >= 2);
    }

    #[test]
    fn cache_reuses_entries() {
        let mut c = TunerCache::new();
        let t1 = c.get_or_tune(100, 64, 32, 1 << 20);
        let t2 = c.get_or_tune(100, 64, 32, 1 << 20);
        assert_eq!(t1, t2);
        assert_eq!(c.len(), 1);
        // different shape -> new entry
        let _ = c.get_or_tune(100, 64, 48, 1 << 20);
        assert_eq!(c.len(), 2);
    }
}
