//! CADNN CLI: the leader entrypoint.
//!
//! ```text
//! cadnn figure2 [--measured] [--uplift X]   regenerate Figure 2
//! cadnn table2                              regenerate Table 2
//! cadnn compress [--report PATH]            §3 compression claims
//! cadnn tune [--model NAME]                 optimization-parameter selection demo
//! cadnn plan [--model NAME | --model-file F.cadnn]
//!            [--format auto|csr|bsr|pattern]
//!            [--value-bits auto|f32|q8|q4]
//!            [--pruning element|block|pattern] [--measured]
//!            [--tune] [--plan-db PATH]       per-layer sparse-format plan;
//!                                           --tune runs the beam search with
//!                                           kernel measurements, --plan-db
//!                                           persists/reuses results (a warm
//!                                           database replans with zero
//!                                           measurements; see docs/PLANDB.md)
//! cadnn db <stats|prune|export|import>
//!          [--plan-db PATH] [--out F] [--from F]
//!                                           manage the plan database
//! cadnn serve [--model M | --model-file F.cadnn] [--variant V]
//!             [--requests N] [--rps R] [--native]
//!             [--models a=lenet5,b=models/net.cadnn:sparse] [--deadline-ms D]
//!             [--greedy] [--no-planner] [--topk K]
//!             [--format auto|csr|bsr|pattern]
//!             [--telemetry-out T.jsonl] [--sample-rate R]
//!             [--plan-db PATH]              serve a Poisson trace and report
//!                                           (--native / --models: no artifacts
//!                                           needed — the multi-model Server
//!                                           batches over native engines with
//!                                           planner-informed, deadline-aware
//!                                           batch selection; --telemetry-out
//!                                           streams sampled request traces,
//!                                           metrics snapshots, and cost-drift
//!                                           events as JSONL)
//! cadnn tail FILE [--trace ID] [--model M]
//!                 [--kind spans|snapshot|drift] [--limit N]
//!                                           pretty-print a telemetry JSONL
//!                                           stream written by serve
//!                                           --telemetry-out (malformed lines
//!                                           are skipped and counted)
//! cadnn profile [--model NAME | --model-file F.cadnn] [--personality P]
//!               [--top N] [--trace OUT.json] [--cost-report OUT.json]
//!                                           per-layer timing table; --trace
//!                                           records obs spans and writes
//!                                           Chrome trace-event JSON
//!                                           (chrome://tracing / Perfetto),
//!                                           --cost-report writes the
//!                                           predicted-vs-measured residuals
//! cadnn calibrate [--cost-report FILE] [--apply-db PATH]
//!                                           host kernel calibration table;
//!                                           with --cost-report, re-fit the
//!                                           planner COST_* constants from a
//!                                           profile run's residuals;
//!                                           --apply-db folds the re-fits into
//!                                           the plan database as a new device
//!                                           generation (stale entries become
//!                                           search seeds, never answers)
//! ```
//!
//! Anywhere a builtin name is accepted, `--model-file` (or a `--models`
//! entry ending in `.cadnn`) substitutes a user-defined textual model —
//! grammar in `docs/MODEL_FORMAT.md`. Inline `sparsity=` hints in the
//! file drive the sparse planner; a hintless file under a sparse
//! personality falls back to the paper profile.

use anyhow::{anyhow, Result};
use cadnn::api::Engine;
use cadnn::bench::{figure2, print_table, table2};
use cadnn::compress::profile::paper_profile;
use cadnn::compress::size;
use cadnn::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig};
use cadnn::costmodel::calibrate;
use cadnn::exec::Personality;
use cadnn::models;
use cadnn::planner::{FormatPolicy, ValuePolicy};
use cadnn::serve::{AdmissionConfig, QueueConfig, ServeRequest, Server, TelemetryConfig};
use cadnn::util::json::Json;
use cadnn::util::rng::Rng;

fn flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn opt(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn format_policy(args: &[String]) -> Result<FormatPolicy> {
    match opt(args, "--format").as_deref() {
        None | Some("auto") => Ok(FormatPolicy::Auto),
        Some("csr") => Ok(FormatPolicy::Csr),
        Some("bsr") => Ok(FormatPolicy::Bsr),
        Some("pattern") => Ok(FormatPolicy::Pattern),
        Some(other) => Err(anyhow!("unknown --format '{other}' (auto|csr|bsr|pattern)")),
    }
}

/// `--value-bits` policy: how sparse payloads store their values (the
/// precision axis next to `--format`). `auto` follows the profile's
/// exported codebooks; `q8`/`q4` pin codebook payloads on the LUT
/// kernels; `f32` pins raw floats.
fn value_policy(args: &[String]) -> Result<ValuePolicy> {
    match opt(args, "--value-bits") {
        None => Ok(ValuePolicy::Auto),
        Some(s) => ValuePolicy::parse(&s)
            .ok_or_else(|| anyhow!("unknown --value-bits '{s}' (auto|f32|q8|q4)")),
    }
}

/// `models/resnet50.cadnn` → `resnet50`: the default alias for file models.
fn file_stem(path: &str) -> String {
    let base = path.rsplit('/').next().unwrap_or(path);
    base.strip_suffix(".cadnn").unwrap_or(base).to_string()
}

/// `--pruning` structure applied on top of the paper profile's per-layer
/// sparsities (element = the paper's scattered magnitude pruning; block /
/// pattern = the structured ADMM projections).
fn prune_structure(args: &[String]) -> Result<cadnn::compress::PruneStructure> {
    use cadnn::compress::PruneStructure;
    match opt(args, "--pruning").as_deref() {
        None | Some("element") => Ok(PruneStructure::Element),
        Some("block") => Ok(PruneStructure::Block { br: 4, bc: 4 }),
        Some("pattern") => Ok(PruneStructure::Pattern { entries: 4 }),
        Some(other) => Err(anyhow!("unknown --pruning '{other}' (element|block|pattern)")),
    }
}

fn main() -> Result<()> {
    cadnn::util::log::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("figure2") => cmd_figure2(&args),
        Some("table2") => cmd_table2(),
        Some("compress") => cmd_compress(&args),
        Some("tune") => cmd_tune(&args),
        Some("plan") => cmd_plan(&args),
        Some("db") => cmd_db(&args),
        Some("serve") => cmd_serve(&args),
        Some("profile") => cmd_profile(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("tail") => cmd_tail(&args),
        _ => {
            eprintln!(
                "usage: cadnn <figure2|table2|compress|tune|plan|db|serve|profile|calibrate|tail> [options]"
            );
            Ok(())
        }
    }
}

/// Per-layer sparse-format plan for a model under the paper profile —
/// the planner subsystem's front door.
fn cmd_plan(args: &[String]) -> Result<()> {
    let policy = format_policy(args)?;
    let vpolicy = value_policy(args)?;
    let structure = prune_structure(args)?;
    // a `.cadnn` file carries its own graph and (optionally) its own
    // per-layer hints; hintless files and builtin names use the paper
    // profile
    let model_file = opt(args, "--model-file");
    let (model, mut profile) = match &model_file {
        Some(path) => {
            let parsed = cadnn::front::parse_file(path)?;
            let label = format!("{} ({path})", parsed.graph.name);
            let profile = if parsed.profile.is_empty() {
                paper_profile(&parsed.graph)
            } else {
                parsed.profile
            };
            (label, profile)
        }
        None => {
            let model = opt(args, "--model").unwrap_or_else(|| "resnet50".into());
            let g = models::build(&model, 1).ok_or_else(|| anyhow!("unknown model {model}"))?;
            (model, paper_profile(&g))
        }
    };
    if structure != cadnn::compress::PruneStructure::Element {
        let names: Vec<String> = profile.layers.keys().cloned().collect();
        for name in names {
            profile.structures.insert(name, structure);
        }
    }
    let mut builder = match &model_file {
        Some(path) => Engine::from_model_file(path).batch_sizes(&[1]),
        None => Engine::native(&model),
    }
    .personality(Personality::CadnnSparse)
    .sparsity_profile(profile.clone())
    .sparse_format(policy)
    .value_bits(vpolicy);
    if flag(args, "--measured") {
        eprintln!("measuring candidate kernels per layer (tuner mode)...");
        builder = builder.tuned(true);
    }
    let tune = flag(args, "--tune");
    // --tune without an explicit --plan-db still persists: searching is
    // exactly the work the default database exists to amortize
    let plan_db_path = opt(args, "--plan-db").or_else(|| {
        tune.then(|| cadnn::planner::db::default_path().to_string_lossy().into_owned())
    });
    if let Some(p) = &plan_db_path {
        builder = builder.plan_db(p);
    }
    if tune {
        eprintln!("searching per-layer plans (beam search + kernel measurements)...");
        builder = builder.tune_plans(true);
    }
    let engine = builder.build()?;
    let inst = engine
        .native_backend()
        .and_then(|b| b.instance(1))
        .ok_or_else(|| anyhow!("planning needs a native batch-1 instance"))?;
    let mut rows = Vec::new();
    for (name, lp) in &inst.plan.layers {
        rows.push(vec![
            name.clone(),
            format!("{:.1}%", 100.0 * profile.get(name)),
            lp.format.label(),
            lp.value_bits.label().to_string(),
            if lp.reorder { "yes" } else { "-" }.to_string(),
            format!("{}", lp.parallel_cutover),
        ]);
    }
    println!("sparse-format plan for {model} ({:?} policy, {} values)\n", policy,
        vpolicy.label());
    print_table(&["layer", "sparsity", "format", "values", "reorder", "cutover"], &rows);
    let counts: Vec<String> = inst
        .plan
        .format_counts()
        .iter()
        .map(|(f, c)| format!("{f} x{c}"))
        .collect();
    println!("\n{} pruned layers planned: {}", inst.plan.len(), counts.join(", "));
    if tune || plan_db_path.is_some() {
        if let Some(ts) = engine.tune_stats() {
            println!("plan-db: {}", ts.render());
        }
        if let Some(p) = &plan_db_path {
            println!("plan-db path: {p}");
        }
    }
    Ok(())
}

/// Manage the persistent plan database (format and spec-key definition
/// in `docs/PLANDB.md`).
fn cmd_db(args: &[String]) -> Result<()> {
    use cadnn::planner::db::PlanDb;
    let path = opt(args, "--plan-db")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(cadnn::planner::db::default_path);
    match args.get(1).map(String::as_str) {
        Some("stats") => {
            let db = PlanDb::open(&path);
            if let Some(why) = db.degraded() {
                eprintln!("warning: {}: {why} (showing an empty database)", path.display());
            }
            println!("plan database {}", path.display());
            println!("{}", db.stats().render());
        }
        Some("prune") => {
            let mut db = PlanDb::open(&path);
            if let Some(why) = db.degraded() {
                return Err(anyhow!("{}: {why}; nothing to prune", path.display()));
            }
            let (kept, dropped) = db.prune();
            db.save().map_err(|e| anyhow!(e))?;
            println!("pruned {}: kept {kept}, dropped {dropped} stale entries", path.display());
        }
        Some("export") => {
            let db = PlanDb::open(&path);
            if let Some(why) = db.degraded() {
                return Err(anyhow!("{}: {why}; nothing to export", path.display()));
            }
            let text = db.to_json().to_string_pretty();
            match opt(args, "--out") {
                Some(out) => {
                    std::fs::write(&out, &text).map_err(|e| anyhow!("writing {out}: {e}"))?;
                    println!("exported {} entries -> {out}", db.len());
                }
                None => println!("{text}"),
            }
        }
        Some("import") => {
            let from =
                opt(args, "--from").ok_or_else(|| anyhow!("db import needs --from PATH"))?;
            let other = PlanDb::open(&from);
            if let Some(why) = other.degraded() {
                return Err(anyhow!("cannot import {from}: {why}"));
            }
            let mut db = PlanDb::open(&path);
            let (added, merged) = db.merge(&other);
            db.save().map_err(|e| anyhow!(e))?;
            println!(
                "imported {from} into {}: {added} new entries, {merged} merged",
                path.display()
            );
        }
        _ => {
            eprintln!(
                "usage: cadnn db <stats|prune|export|import> [--plan-db PATH] [--out F] [--from F]"
            );
        }
    }
    Ok(())
}

fn cmd_figure2(args: &[String]) -> Result<()> {
    let calib = if flag(args, "--measured") {
        eprintln!("calibrating host kernels...");
        calibrate::measure_host()
    } else {
        calibrate::CalibrationTable::nominal()
    };
    let uplift: f64 = opt(args, "--uplift").and_then(|s| s.parse().ok()).unwrap_or(1.25);
    println!("Figure 2 — inference latency (ms), projected onto the Xiaomi 6");
    println!("(Table 1 device model: Snapdragon 835 CPU @2.45GHz, Adreno 540 GPU @710MHz,");
    println!(" shared LPDDR4X; calibration: {})\n", if calib.measured { "host-measured" } else { "nominal" });
    let rows = figure2::figure2(&calib, uplift);
    let mut table = Vec::new();
    for m in models::EVAL_MODELS {
        let mut row = vec![m.to_string()];
        for s in figure2::SERIES {
            let v = rows
                .iter()
                .find(|r| r.model == m && r.series == s)
                .map(|r| format!("{:.1}", r.latency_ms))
                .unwrap_or_default();
            row.push(v);
        }
        table.push(row);
    }
    let mut headers = vec!["model"];
    headers.extend(figure2::SERIES);
    print_table(&headers, &table);
    let h = figure2::headline(&rows);
    println!();
    println!(
        "headline: resnet50 CADNN-SC {:.1} ms (paper: 26), CADNN-SG {:.1} ms (paper: 21)",
        h.resnet50_sc_ms, h.resnet50_sg_ms
    );
    println!("          inception_v3 best {:.1} ms (paper: 35)", h.inception_best_ms);
    println!(
        "          max speedup vs TFLite {:.1}x (paper: up to 8.8x), vs TVM {:.1}x (paper: up to 6.4x)",
        h.max_speedup_vs_tflite, h.max_speedup_vs_tvm
    );
    Ok(())
}

fn cmd_table2() -> Result<()> {
    println!("Table 2 — DNN configurations (top-1/top-5 quoted from the paper; no ImageNet here)\n");
    let rows: Vec<Vec<String>> = table2::table2()
        .iter()
        .map(|r| {
            vec![
                r.model.to_string(),
                format!("{:.1}", r.size_mb),
                format!("{:.1}", r.paper_size_mb),
                format!("{:.1}", r.top1),
                format!("{:.1}", r.top5),
                r.weight_layers.to_string(),
                r.compute_layers.to_string(),
                r.paper_layers.to_string(),
            ]
        })
        .collect();
    print_table(
        &["model", "size(MB)", "paper", "top1%", "top5%", "w-layers", "c-layers", "paper-layers"],
        &rows,
    );
    Ok(())
}

fn cmd_compress(args: &[String]) -> Result<()> {
    println!("§3 compression claims — accounting over exact architectures\n");
    let mut rows = Vec::new();
    for (name, claim) in [
        ("lenet5", 348.0),
        ("alexnet", 36.0),
        ("vgg16", 34.0),
        ("resnet18", 8.0),
        ("resnet50", 9.2),
    ] {
        let g = models::build(name, 1).unwrap();
        let p = paper_profile(&g);
        let r = size::report(&g, &p);
        rows.push(vec![
            name.to_string(),
            format!("{}", r.weights),
            format!("{:.1}x", r.compression_rate),
            format!("{claim}x"),
            format!("{:.1}", r.dense_mb),
            format!("{:.0}x", r.storage_reduction_no_idx()),
            format!("{:.0}x", r.storage_reduction_idx16()),
        ]);
    }
    print_table(
        &["model", "weights", "rate", "paper", "dense MB", "4b-quant(no idx)", "4b+idx16"],
        &rows,
    );
    // measured python run, if present
    let report_path = opt(args, "--report")
        .unwrap_or_else(|| "artifacts/compress_report.json".into());
    if let Ok(text) = std::fs::read_to_string(&report_path) {
        let j = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        if let Some(l) = j.get("measured").and_then(|m| m.get("lenet5")) {
            println!("\nmeasured (python ADMM on synthetic digits — {report_path}):");
            for key in [
                "dense_acc", "pruned_acc", "pruned_rate", "quant_acc", "quant_rate",
                "storage_reduction_no_idx",
            ] {
                if let Some(v) = l.get(key).and_then(|v| v.as_f64()) {
                    println!("  {key} = {v}");
                }
            }
        }
    } else {
        println!("\n(no measured report at {report_path}; run `make compress-report`)");
    }
    Ok(())
}

fn cmd_tune(args: &[String]) -> Result<()> {
    let model = opt(args, "--model").unwrap_or_else(|| "resnet50".into());
    let g = models::build(&model, 1).ok_or_else(|| anyhow!("unknown model {model}"))?;
    println!("optimization-parameter selection on {model} GEMM shapes\n");
    // representative conv-as-gemm shapes from the lowered graph
    let lowered = cadnn::exec::Personality::CadnnDense.lower(&g);
    let plan = cadnn::passes::layout::plan(&lowered);
    let mut shapes: Vec<(usize, usize, usize)> = plan
        .per_node
        .values()
        .map(|i| (i.gemm_m.min(4096), i.gemm_k, i.gemm_n))
        .collect();
    shapes.sort();
    shapes.dedup();
    shapes.truncate(6);
    let mut rows = Vec::new();
    for (m, k, n) in shapes {
        let r = cadnn::tuner::tune(m, k, n, 2 << 20, 7);
        rows.push(vec![
            format!("{m}x{k}x{n}"),
            format!("{:.0}", r.default_us),
            format!("{:.0}", r.best_us),
            format!("{:.2}x", r.speedup_vs_default()),
            format!("mc{} nc{} kc{} u{}", r.best.mc, r.best.nc, r.best.kc, r.best.unroll),
            format!("{}", r.evaluated),
            format!("{}", r.pruned),
        ]);
    }
    print_table(
        &["shape (MxKxN)", "default us", "tuned us", "speedup", "best config", "evals", "pruned"],
        &rows,
    );
    Ok(())
}

/// Parse `--models a=lenet5,b=lenet5:sparse` into
/// `(alias, model, sparse?)` triples. A bare entry (`lenet5`) registers
/// under its own name; a `:sparse` suffix serves the compressed variant.
/// A model ending in `.cadnn` is a textual model file; its bare alias is
/// the file stem (`models/net.cadnn` → `net`).
fn parse_model_specs(spec: &str) -> Result<Vec<(String, String, bool)>> {
    let mut out = Vec::new();
    for part in spec.split(',').filter(|s| !s.is_empty()) {
        let (alias, rest) = match part.split_once('=') {
            Some((a, r)) => (Some(a.to_string()), r),
            None => (None, part),
        };
        let (model, sparse) = match rest.rsplit_once(':') {
            Some((m, "sparse")) => (m.to_string(), true),
            Some((m, "dense")) => (m.to_string(), false),
            Some((_, v)) => return Err(anyhow!("unknown variant ':{v}' (dense|sparse)")),
            None => (rest.to_string(), false),
        };
        let alias = alias.unwrap_or_else(|| file_stem(&model));
        if alias.is_empty() || model.is_empty() {
            return Err(anyhow!("bad --models entry '{part}' (alias=model[:sparse])"));
        }
        out.push((alias, model, sparse));
    }
    if out.is_empty() {
        return Err(anyhow!("--models given but empty"));
    }
    Ok(out)
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let model = opt(args, "--model").unwrap_or_else(|| "lenet5".into());
    let variant = opt(args, "--variant").unwrap_or_else(|| "dense".into());
    let max_batch: usize = opt(args, "--max-batch").and_then(|s| s.parse().ok()).unwrap_or(8);
    let max_wait_us: u64 =
        opt(args, "--max-wait-us").and_then(|s| s.parse().ok()).unwrap_or(2000);
    let policy = if flag(args, "--greedy") { BatchPolicy::Greedy } else { BatchPolicy::PadToFit };
    let requests: usize = opt(args, "--requests").and_then(|s| s.parse().ok()).unwrap_or(64);
    let rps: f64 = opt(args, "--rps").and_then(|s| s.parse().ok()).unwrap_or(100.0);
    let deadline_ms: Option<u64> = opt(args, "--deadline-ms").and_then(|s| s.parse().ok());
    let topk: Option<usize> = opt(args, "--topk").and_then(|s| s.parse().ok());
    let models_spec = opt(args, "--models");
    let model_file = opt(args, "--model-file");
    let telemetry_out = opt(args, "--telemetry-out");
    let sample_rate: f64 = opt(args, "--sample-rate")
        .and_then(|s| s.parse().ok())
        .map(|r: f64| r.clamp(0.0, 1.0))
        .unwrap_or(0.01);

    if !flag(args, "--native") && models_spec.is_none() && model_file.is_none() {
        if telemetry_out.is_some() {
            return Err(anyhow!("--telemetry-out requires the native server (--native / --models)"));
        }
        // the artifact path keeps the original single-model coordinator
        let artifacts_dir = opt(args, "--artifacts").unwrap_or_else(|| "artifacts".into());
        println!(
            "serving {model}/{variant} from {artifacts_dir} — {requests} requests @ {rps:.0} req/s (Poisson)"
        );
        let coord = Coordinator::start(CoordinatorConfig {
            artifacts_dir: artifacts_dir.clone(),
            model: model.clone(),
            variant: variant.clone(),
            max_batch,
            max_wait_us,
            policy,
        })?;
        let input_len = coord.input_len;
        let mut rng = Rng::new(9);
        let mut pending = Vec::new();
        for _ in 0..requests {
            let mut img = vec![0.0f32; input_len];
            rng.fill_normal(&mut img, 0.5);
            pending.push(coord.submit(img)?);
            std::thread::sleep(std::time::Duration::from_secs_f64(rng.exp(rps)));
        }
        for rx in pending {
            let _ = rx.recv();
        }
        let (report, us_per_unit) = (coord.metrics.report(), coord.metrics.us_per_unit());
        println!("\n{report}");
        coord.shutdown()?;
        // persist the converged serving-cost calibration next to
        // exec_plan, so the next process's scheduler is deadline-accurate
        // from its first batch
        if let Some(u) = us_per_unit {
            let path = format!("{artifacts_dir}/manifest.json");
            match std::fs::read_to_string(&path)
                .map_err(anyhow::Error::from)
                .and_then(|text| cadnn::runtime::Manifest::parse(&text))
            {
                Ok(mut man) => {
                    if man.record_calibration(&model, &variant, u) > 0 {
                        std::fs::write(&path, man.to_json().to_string_pretty())?;
                        println!("persisted us_per_unit={u:.4} into {path}");
                    }
                }
                Err(e) => eprintln!("calibration not persisted ({path}: {e})"),
            }
        }
        return Ok(());
    }

    // native multi-model serving through cadnn::serve::Server
    let specs = match (&models_spec, &model_file) {
        (Some(s), _) => parse_model_specs(s)?,
        (None, Some(path)) => vec![(file_stem(path), path.clone(), variant == "sparse")],
        (None, None) => vec![(model.clone(), model.clone(), variant == "sparse")],
    };
    let policy_fmt = format_policy(args)?;
    if opt(args, "--format").is_some() && !specs.iter().any(|(_, _, sp)| *sp) {
        return Err(anyhow!("--format applies to sparse variants only"));
    }
    // sparse engines consult the plan database at model load, so a
    // database tuned offline (`cadnn plan --tune --plan-db`) makes serve
    // startup plan-search-free
    let plan_db = opt(args, "--plan-db");
    if plan_db.is_some() && !specs.iter().any(|(_, _, sp)| *sp) {
        return Err(anyhow!("--plan-db applies to sparse variants only"));
    }
    let replicas: usize =
        opt(args, "--replicas").and_then(|s| s.parse().ok()).unwrap_or(1).max(1);
    let quota_us: Option<u64> = opt(args, "--quota-us").and_then(|s| s.parse().ok());
    let backlog_us: Option<u64> = opt(args, "--backlog-us").and_then(|s| s.parse().ok());
    let calibration: Option<f64> = opt(args, "--calibration").and_then(|s| s.parse().ok());
    let qcfg = QueueConfig {
        max_batch,
        max_wait_us,
        fallback: policy,
        planned: !flag(args, "--no-planner"),
        replicas,
        quota_us,
        calibration,
        ..QueueConfig::default()
    };
    let sizes: Vec<usize> = [1usize, 2, 4, 8]
        .into_iter()
        .filter(|&b| b <= max_batch.max(1))
        .collect();
    let mut builder = Server::builder().admission(AdmissionConfig {
        enabled: !flag(args, "--no-admission"),
        max_backlog_us: backlog_us,
    });
    for (alias, name, sparse) in &specs {
        let is_file = name.ends_with(".cadnn");
        let mut eb = if is_file { Engine::from_model_file(name) } else { Engine::native(name) }
            .personality(if *sparse { Personality::CadnnSparse } else { Personality::CadnnDense })
            .batch_sizes(&sizes);
        if *sparse {
            if is_file {
                // inline hints (if any) attach inside the builder; a
                // hintless file gets the paper profile so `:sparse`
                // always means a planned sparse engine
                let parsed = cadnn::front::parse_file(name)?;
                if parsed.profile.is_empty() {
                    eb = eb.sparsity_profile(paper_profile(&parsed.graph));
                }
            } else {
                let g = models::build(name, 1).ok_or_else(|| anyhow!("unknown model {name}"))?;
                eb = eb.sparsity_profile(paper_profile(&g));
            }
            eb = eb.sparse_format(policy_fmt);
            if let Some(p) = &plan_db {
                eb = eb.plan_db(p);
            }
        }
        let engine = eb.build()?;
        if plan_db.is_some() {
            if let Some(ts) = engine.tune_stats() {
                println!("  plan-db: {}", ts.render());
            }
        }
        let planned = qcfg.planned && !engine.plan_costs().is_empty();
        println!(
            "registered '{alias}' -> {} ({} batch variants, {} replica(s){}, scheduler: {})",
            engine.name(),
            engine.batch_sizes().len(),
            replicas,
            quota_us.map(|q| format!(", quota {q}µs")).unwrap_or_default(),
            if planned { "planner cost model" } else { "policy fallback" },
        );
        builder = builder.engine_with(alias.as_str(), &engine, qcfg);
    }
    if let Some(path) = &telemetry_out {
        let mut tcfg = TelemetryConfig::new(path);
        tcfg.sample_rate = sample_rate;
        builder = builder.telemetry(tcfg);
        println!(
            "telemetry -> {path} (head sample rate {:.1}%, tail keeps sheds/misses/errors/p99)",
            sample_rate * 100.0
        );
    }
    let server = builder.build()?;
    println!(
        "serving {} model(s) — {requests} requests @ {rps:.0} req/s (Poisson){}",
        specs.len(),
        deadline_ms.map(|d| format!(", deadline {d}ms")).unwrap_or_default(),
    );

    let mut rng = Rng::new(9);
    let mut pending = Vec::new();
    for i in 0..requests {
        let alias = &specs[i % specs.len()].0;
        let mut img = vec![0.0f32; server.input_len(alias).unwrap()];
        rng.fill_normal(&mut img, 0.5);
        let mut req = ServeRequest::new(alias.clone(), img);
        if let Some(d) = deadline_ms {
            req = req.deadline_ms(d);
        }
        if let Some(k) = topk {
            req = req.topk(k);
        }
        pending.push(server.submit(req)?);
        std::thread::sleep(std::time::Duration::from_secs_f64(rng.exp(rps)));
    }
    let (mut ok, mut missed, mut shed, mut failed) = (0usize, 0usize, 0usize, 0usize);
    for rx in pending {
        match rx.recv() {
            Ok(resp) => match resp.outcome {
                Ok(_) => ok += 1,
                Err(cadnn::serve::ServeError::Deadline { .. }) => missed += 1,
                Err(cadnn::serve::ServeError::Shed { .. }) => shed += 1,
                Err(_) => failed += 1,
            },
            Err(_) => failed += 1,
        }
    }
    println!("\nok={ok} deadline_missed={missed} shed={shed} failed={failed}");
    // merged-across-replicas snapshots, admission accounting stamped
    let stats = server.stats();
    for (alias, _, _) in &specs {
        println!("--- {alias} ---\n{}", stats[alias.as_str()].report());
    }
    server.shutdown()?;
    Ok(())
}

/// The paper's §6 "DNN profiler" work-in-progress item: per-layer
/// measured timing of a model under a personality on the native executor.
fn cmd_profile(args: &[String]) -> Result<()> {
    use cadnn::kernels::Tensor;
    // full ImageNet models are heavy on one host core: profile a scaled
    // tower by default, or any named model with --model
    let model = opt(args, "--model").unwrap_or_else(|| "mobilenet_v1".into());
    let personality = match opt(args, "--personality").as_deref() {
        Some("tflite") => Personality::TfLiteLike,
        Some("tvm") => Personality::TvmLike,
        Some("cadnn-sparse") => Personality::CadnnSparse,
        _ => Personality::CadnnDense,
    };
    let top: usize = opt(args, "--top").and_then(|s| s.parse().ok()).unwrap_or(15);
    let policy = format_policy(args)?;
    if opt(args, "--format").is_some() && !personality.sparse() {
        return Err(anyhow!("--format requires --personality cadnn-sparse"));
    }
    let model_file = opt(args, "--model-file");
    let mut builder = match &model_file {
        Some(path) => Engine::from_model_file(path).batch_sizes(&[1]),
        None => Engine::native(&model),
    }
    .personality(personality);
    if personality.sparse() {
        match &model_file {
            // inline hints attach inside the builder; hintless files
            // and builtin names use the paper profile
            Some(path) => {
                let parsed = cadnn::front::parse_file(path)?;
                if parsed.profile.is_empty() {
                    builder = builder.sparsity_profile(paper_profile(&parsed.graph));
                }
            }
            None => {
                let g =
                    models::build(&model, 1).ok_or_else(|| anyhow!("unknown model {model}"))?;
                builder = builder.sparsity_profile(paper_profile(&g));
            }
        }
        builder = builder.sparse_format(policy);
    }
    let engine = builder.build()?;
    let inst = engine
        .native_backend()
        .and_then(|b| b.instance(1))
        .ok_or_else(|| anyhow!("profiling needs a native batch-1 instance"))?;
    let mut input = Tensor::zeros(&inst.graph.nodes[0].shape.0);
    let mut rng = Rng::new(1);
    rng.fill_normal(&mut input.data, 0.5);
    let label = model_file.as_deref().unwrap_or(&model);
    eprintln!("profiling {label} under {} ...", personality.label());
    let mut prof = inst.profile(&input, 1)?;
    let total: f64 = prof.iter().map(|p| p.us).sum();
    prof.sort_by(|a, b| b.us.partial_cmp(&a.us).unwrap());
    let mut rows = Vec::new();
    for p in prof.iter().take(top) {
        rows.push(vec![
            p.name.clone(),
            p.kind.to_string(),
            format!("{:.0}", p.us),
            format!("{:.1}%", 100.0 * p.us / total),
            format!("{:.2}", p.gflops()),
            format!("{}", p.out_bytes / 1024),
        ]);
    }
    println!("total {:.1} ms over {} nodes; top {top} layers:", total / 1e3, prof.len());
    print_table(&["layer", "kind", "us", "share", "GF/s", "out KiB"], &rows);

    // --trace / --cost-report: one instrumented forward pass through the
    // obs recorder — every node becomes an `exec` span carrying its
    // measured µs and the planner-predicted cost
    let trace_path = opt(args, "--trace");
    let cost_path = opt(args, "--cost-report");
    if trace_path.is_some() || cost_path.is_some() {
        use cadnn::obs;
        if !obs::COMPILED {
            return Err(anyhow!(
                "--trace/--cost-report need the 'obs' cargo feature (on by default; \
                 this binary was built with --no-default-features)"
            ));
        }
        obs::reset();
        obs::enable();
        let mut scratch = inst.scratch();
        let run = inst.execute_with(&input, &mut scratch);
        obs::disable();
        run?;
        let spans = obs::drain();
        let nodes = inst.graph.len() - 1; // node 0 is the input
        let exec_spans = spans.iter().filter(|s| s.cat == obs::CAT_EXEC).count();
        if exec_spans < nodes {
            return Err(anyhow!(
                "incomplete trace: {exec_spans} exec spans for {nodes} graph nodes \
                 (span ring overflowed?)"
            ));
        }
        let report = obs::CostReport::from_spans(&spans);
        if let Some(path) = &trace_path {
            let doc = obs::trace::chrome_trace(&spans, &obs::counters(), obs::dropped_spans());
            std::fs::write(path, doc.to_string_pretty())
                .map_err(|e| anyhow!("writing {path}: {e}"))?;
            println!(
                "trace: {exec_spans} exec spans over {nodes} nodes -> {path} \
                 (load in chrome://tracing or Perfetto)"
            );
        }
        if let Some(path) = &cost_path {
            std::fs::write(path, report.to_json().to_string_pretty())
                .map_err(|e| anyhow!("writing {path}: {e}"))?;
            println!("cost report -> {path} (feed to `cadnn calibrate --cost-report`)");
        }
        print!("{}", report.render());
    }
    Ok(())
}

fn cmd_calibrate(args: &[String]) -> Result<()> {
    // --cost-report: consume a profile run's residuals and suggest
    // re-fitted planner COST_* constants (the obs calibration loop)
    if let Some(path) = opt(args, "--cost-report") {
        let text = std::fs::read_to_string(&path).map_err(|e| anyhow!("reading {path}: {e}"))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))?;
        let report = cadnn::obs::CostReport::from_json(&json)
            .map_err(|e| anyhow!("invalid cost report {path}: {e}"))?;
        print!("{}", report.render());
        // --apply-db: fold the re-fitted constants into the plan database
        // as a new device generation; entries priced under the old table
        // stop answering exactly and become search seeds
        if let Some(dbp) = opt(args, "--apply-db") {
            use cadnn::planner::db::PlanDb;
            let mut db = PlanDb::open(&dbp);
            let sugg = report.suggestions();
            let gen = db
                .apply_calibration(
                    &sugg,
                    Some(report.us_per_unit),
                    &format!("calibrate --cost-report {path}"),
                )
                .map_err(|e| anyhow!(e))?;
            db.save().map_err(|e| anyhow!(e))?;
            println!(
                "applied {} constant re-fits as device generation {gen:016x} -> {dbp}",
                sugg.len()
            );
        }
        return Ok(());
    }
    if opt(args, "--apply-db").is_some() {
        return Err(anyhow!("--apply-db requires --cost-report FILE"));
    }
    println!("measuring host kernels...");
    let t = calibrate::measure_host();
    println!("host peak (parallel blocked gemm): {:.1} GFLOPS", t.host_peak_gflops);
    println!("host bandwidth (copy):             {:.1} GB/s", t.host_bw_gbps);
    println!("efficiency ratios (achieved/peak):");
    println!("  direct conv (naive): {:.3}", t.direct_conv.compute);
    println!("  blocked gemm:        {:.3}", t.gemm.compute);
    println!("  csr gemm (90% sp):   {:.3}", t.csr_gemm.compute);
    Ok(())
}

/// Pretty-print a telemetry JSONL stream written by
/// `serve --telemetry-out`: span batches, metrics snapshots, drift
/// events. `--trace` reconstructs one request's lifecycle across
/// batches; malformed lines (e.g. a truncated final line after a crash)
/// are skipped and counted, never fatal.
fn cmd_tail(args: &[String]) -> Result<()> {
    use cadnn::obs::export::{read_telemetry, TelemetryLine};
    let path = args
        .get(1)
        .filter(|a| !a.starts_with("--"))
        .ok_or_else(|| {
            anyhow!("usage: cadnn tail FILE [--trace ID] [--model M] [--kind spans|snapshot|drift] [--limit N]")
        })?;
    let trace: Option<u64> = opt(args, "--trace").and_then(|s| s.parse().ok());
    let model = opt(args, "--model");
    let kind = opt(args, "--kind");
    if let Some(k) = kind.as_deref() {
        if !matches!(k, "spans" | "snapshot" | "drift") {
            return Err(anyhow!("unknown --kind '{k}' (spans|snapshot|drift)"));
        }
    }
    let limit: usize = opt(args, "--limit").and_then(|s| s.parse().ok()).unwrap_or(usize::MAX);
    let (lines, malformed) = read_telemetry(std::path::Path::new(path))
        .map_err(|e| anyhow!("reading {path}: {e}"))?;
    let mut printed = 0usize;
    for line in &lines {
        if printed >= limit {
            break;
        }
        match line {
            TelemetryLine::Spans { at_us, spans, dropped } => {
                if kind.as_deref().is_some_and(|k| k != "spans") {
                    continue;
                }
                let picked: Vec<_> = spans
                    .iter()
                    .filter(|s| trace.is_none_or(|t| s.trace == t))
                    .filter(|s| {
                        model
                            .as_deref()
                            .is_none_or(|m| s.str_arg("model").is_none_or(|sm| sm == m))
                    })
                    .collect();
                if picked.is_empty() {
                    continue;
                }
                println!("[{at_us:.0}us] spans: {} kept, {dropped} dropped so far", picked.len());
                for s in picked {
                    let outcome = s
                        .str_arg("outcome")
                        .map(|o| format!(" outcome={o}"))
                        .unwrap_or_default();
                    println!(
                        "  trace={} {}/{} @{:.0}us +{:.0}us{}",
                        s.trace, s.cat, s.name, s.start_us, s.dur_us, outcome
                    );
                }
                printed += 1;
            }
            TelemetryLine::Snapshot { at_us, model: m, stats, .. } => {
                if kind.as_deref().is_some_and(|k| k != "snapshot") || trace.is_some() {
                    continue;
                }
                if model.as_deref().is_some_and(|f| f != m) {
                    continue;
                }
                let num = |key: &str| stats.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0);
                let p99 = stats
                    .get("latency")
                    .and_then(|l| l.get("p99_us"))
                    .and_then(|v| v.as_f64())
                    .map(|p| format!(" p99={p:.0}us"))
                    .unwrap_or_default();
                println!(
                    "[{at_us:.0}us] snapshot {m}: requests={:.0} shed={:.0} misses={:.0}{p99}",
                    num("requests"),
                    num("shed_total"),
                    num("deadline_misses"),
                );
                printed += 1;
            }
            TelemetryLine::Drift(j) => {
                if kind.as_deref().is_some_and(|k| k != "drift") || trace.is_some() {
                    continue;
                }
                println!("drift: {}", j.to_string_compact());
                printed += 1;
            }
        }
    }
    if malformed > 0 {
        eprintln!("({malformed} malformed line(s) skipped)");
    }
    Ok(())
}
